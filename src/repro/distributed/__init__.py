from repro.distributed.sharding import (
    AxisRules,
    RULES_BY_FAMILY,
    current_mesh,
    current_rules,
    logical_shard,
    logical_spec,
    param_shardings,
    use_mesh_rules,
)
from repro.distributed.topk import distributed_top_k, sharded_knn_topk
