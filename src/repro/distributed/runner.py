"""Fault-tolerant training runner: checkpoint/restart, failure injection,
straggler detection. The control-plane layer of DESIGN.md §4.

On real fleets, failures surface as raised exceptions from the step
function (XLA device errors, DMA timeouts) or as missing heartbeats. The
runner's contract:

  * every `ckpt_every` steps: async checkpoint (atomic, versioned);
  * on step failure: restore the latest checkpoint and replay — data
    order is reproducible because batches derive from (seed, step);
  * `max_restarts` bounds the retry budget; exhausted -> re-raise;
  * straggler detection: per-step wall times feed an EWMA; steps slower
    than `straggler_factor` x EWMA are counted and reported via metrics
    so the orchestration layer can trigger hot-spares. (On a real pod
    slice this hooks into the per-host heartbeat; on one process it is
    measurement-only.)

The runner is deliberately model-agnostic: state is (params, opt_state,
extra) pytrees, `step_fn(state, batch) -> (state, metrics)`, and
`batch_fn(step) -> batch` regenerates data deterministically for replay.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax

from repro.checkpoint.store import CheckpointStore

PyTree = Any


@dataclass
class RunnerReport:
    steps_run: int = 0
    restarts: int = 0
    straggler_steps: int = 0
    checkpoints: int = 0
    metrics_history: list = field(default_factory=list)
    step_times: list = field(default_factory=list)


class FaultTolerantRunner:
    def __init__(
        self,
        store: CheckpointStore,
        step_fn: Callable[[PyTree, Any], tuple[PyTree, dict]],
        batch_fn: Callable[[int], Any],
        *,
        ckpt_every: int = 50,
        max_restarts: int = 3,
        straggler_factor: float = 3.0,
        async_ckpt: bool = True,
    ):
        self.store = store
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.straggler_factor = straggler_factor
        self.async_ckpt = async_ckpt

    def run(
        self,
        state: PyTree,
        num_steps: int,
        *,
        start_step: int = 0,
        resume: bool = True,
        fail_at: Callable[[int], bool] | None = None,
        shardings: PyTree | None = None,
    ) -> tuple[PyTree, RunnerReport]:
        """Run to `num_steps`, surviving step failures.

        `fail_at(step)` is the failure-injection hook used by tests /
        chaos drills: when it returns True the runner behaves as if the
        device step raised.
        """
        report = RunnerReport()
        step = start_step
        if resume and self.store.latest_step() is not None:
            state, extra = self.store.restore(state, shardings=shardings)
            step = int(extra.get("next_step", self.store.latest_step()))
        restarts = 0
        ewma = None

        while step < num_steps:
            batch = self.batch_fn(step)
            t0 = time.perf_counter()
            try:
                if fail_at is not None and fail_at(step):
                    raise RuntimeError(f"injected failure at step {step}")
                state, metrics = self.step_fn(state, batch)
                metrics = jax.tree.map(
                    lambda x: x.item() if hasattr(x, "item") else x, metrics)
            except Exception:
                restarts += 1
                report.restarts = restarts
                if restarts > self.max_restarts:
                    self.store.wait()
                    raise
                if self.store.latest_step() is not None:
                    state, extra = self.store.restore(state, shardings=shardings)
                    step = int(extra.get("next_step", self.store.latest_step()))
                else:
                    step = start_step
                continue
            dt = time.perf_counter() - t0
            report.step_times.append(dt)
            if ewma is None:
                ewma = dt
            else:
                if dt > self.straggler_factor * ewma:
                    report.straggler_steps += 1
                ewma = 0.9 * ewma + 0.1 * dt
            report.metrics_history.append(metrics)
            report.steps_run += 1
            step += 1
            if step % self.ckpt_every == 0 or step == num_steps:
                save = self.store.save_async if self.async_ckpt else self.store.save
                save(step, state, extra={"next_step": step})
                report.checkpoints += 1
        self.store.wait()
        return state, report
