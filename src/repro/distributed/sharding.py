"""Logical-axis sharding rules (MaxText-style) for every architecture family.

Models annotate activations/params with *logical* axis names ('batch',
'seq', 'heads', 'embed', 'mlp', 'experts', 'vocab', 'table_rows', ...).
An `AxisRules` table maps logical names to physical mesh axes; the same
model code then runs

  * unsharded on 1 CPU device (smoke tests) — rules context unset, every
    constraint is a no-op;
  * sharded on the (data, model) single-pod mesh or the (pod, data, model)
    multi-pod mesh (dry-run / production) — constraints resolve to
    NamedSharding and GSPMD inserts the collectives.

The rules below encode the distribution design of DESIGN.md §4:
  - LM dense:  DP over (pod, data) + FSDP (params sharded over data) +
    TP over model (heads / d_ff / vocab).
  - LM MoE:    experts over model (EP) + FSDP elsewhere.
  - RecSys:    embedding-table rows over model, batch over (pod, data).
  - GNN:       nodes/edges over (pod, data), weights replicated.
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array

_ctx = threading.local()


@dataclass(frozen=True)
class AxisRules:
    """Mapping logical axis name -> mesh axis name(s) (or None = replicate)."""

    rules: Mapping[str, Any] = field(default_factory=dict)

    def resolve(self, *logical: str | None) -> P:
        """Logical axis names (one per tensor dim; None = replicated dim) ->
        PartitionSpec. Unknown names replicate."""
        return P(*[self.rules.get(name) if name else None for name in logical])

    def override(self, **updates) -> "AxisRules":
        """New AxisRules with some logical names remapped — per-shape-cell
        specialization (e.g. long_500k re-shards the KV cache sequence
        axis instead of the batch axis)."""
        merged = dict(self.rules)
        merged.update(updates)
        return AxisRules(merged)


# Physical axes: single-pod mesh ('data', 'model'); multi-pod adds 'pod'.
# Writing ('pod', 'data') in a rule is safe for the single-pod mesh ONLY if
# filtered; `_filter_spec` drops axes the mesh does not have.


def _filter_entry(entry, mesh_axes: frozenset[str]):
    if entry is None:
        return None
    if isinstance(entry, str):
        return entry if entry in mesh_axes else None
    kept = tuple(a for a in entry if a in mesh_axes)
    return kept if kept else None


def filter_rules(rules: AxisRules, mesh: Mesh) -> AxisRules:
    axes = frozenset(mesh.axis_names)
    return AxisRules({k: _filter_entry(v, axes) for k, v in rules.rules.items()})


# --------------------------------------------------------------------------
# Per-family rule tables
# --------------------------------------------------------------------------

LM_RULES = AxisRules({
    # activations
    "batch": ("pod", "data"),
    "seq": None,                 # sequence replicated by default (SP optional)
    "seq_shard": "model",        # KV-cache sequence sharding (decode cells)
    "seq_attn": "model",         # attention-score q-seq sharding (dense path)
    "heads": "model",
    "kv_heads": "model",
    "embed": None,               # activation d_model dim replicated
    "mlp": "model",              # activation d_ff dim (TP)
    "kv_batch": ("pod", "data"),
    # params: FSDP shards the non-TP dim over 'data'; TP dim over 'model'
    "embed_fsdp": "data",
    "vocab": "model",
    "qkv_in": "data",            # wq/wk/wv input dim (FSDP all-gather per layer)
    "qkv_out": "model",          # column parallel
    "o_in": "model",             # row parallel
    "o_out": "data",
    "ffn_in": "data",
    "ffn_out": "model",
    "ffn_down_in": "model",
    "ffn_down_out": "data",
    "experts": "model",          # EP
    # FSDP inside each expert shard. ('pod','data') = ZeRO-3 across pods:
    # required to FIT 1T-param optimizer state (DESIGN.md §4 records the
    # DCN cost; the single-pod mesh simply filters 'pod' away).
    "expert_in": ("pod", "data"),
    "expert_out": None,
    # dispatch-buffer capacity axis (sort-dispatch §Perf variant)
    "expert_cap": ("pod", "data"),
    "layers": None,              # scan-stacked leading dim, never sharded
    "norm": None,
})

RECSYS_RULES = AxisRules({
    "batch": ("pod", "data"),
    "seq": None,
    "heads": None,
    "embed": None,
    "mlp": None,
    "table_rows": "model",       # the tables ARE the model: row-sharded
    "table_dim": None,
    "candidates": ("data", "model"),  # retrieval: 10^6 candidates sharded
    "dense_in": None,            # small dense MLP replicated
    "dense_out": None,
    "norm": None,
    "layers": None,
    "vocab": "model",
})

GNN_RULES = AxisRules({
    "nodes": ("pod", "data"),
    "edges": ("pod", "data"),
    "batch": ("pod", "data"),
    "feat": None,
    "mlp_in": None,
    "mlp_out": None,
    "norm": None,
    "layers": None,
})

PAPER_RULES = AxisRules({
    # The ranking-under-constraints serving fleet (DESIGN.md §4): users over
    # (pod, data); the KNN train-user DB and item catalog over 'model'.
    "batch": ("pod", "data"),
    "users_db": "model",
    "items": "model",
    "covariates": None,
    "constraints": None,
    "embed": None,
    "mlp": None,
    "norm": None,
    "layers": None,
})

RULES_BY_FAMILY = {
    "lm": LM_RULES,
    "recsys": RECSYS_RULES,
    "gnn": GNN_RULES,
    "paper": PAPER_RULES,
}


# --------------------------------------------------------------------------
# Context + constraint helpers
# --------------------------------------------------------------------------

@contextlib.contextmanager
def use_mesh_rules(mesh: Mesh | None, rules: AxisRules | None):
    """Activate (mesh, rules) for `logical_shard` within the block."""
    prev = getattr(_ctx, "state", None)
    _ctx.state = (mesh, filter_rules(rules, mesh)) if (mesh and rules) else None
    try:
        if mesh is not None:
            from repro.distributed.compat import set_mesh
            with set_mesh(mesh):
                yield
        else:
            yield
    finally:
        _ctx.state = prev


def current_mesh() -> Mesh | None:
    st = getattr(_ctx, "state", None)
    return st[0] if st else None


def current_rules() -> AxisRules | None:
    st = getattr(_ctx, "state", None)
    return st[1] if st else None


def logical_spec(*logical: str | None) -> P:
    rules = current_rules()
    if rules is None:
        return P()
    return rules.resolve(*logical)


def _axis_prod(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh.shape[entry]
    n = 1
    for a in entry:
        n *= mesh.shape[a]
    return n


def drop_nondivisible(spec: P, shape, mesh: Mesh) -> P:
    """Relax a PartitionSpec: replicate any dim whose size is not an exact
    multiple of its mesh-axis product (e.g. 40 heads on a 16-way 'model'
    axis, or batch 1), and drop repeated mesh axes (a spec may map each
    axis to at most one dim — keep the first use). GSPMD remains free to
    choose a sharding for relaxed dims; we just do not constrain them."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    used: set = set()
    for dim, entry in zip(shape, entries):
        if dim % _axis_prod(mesh, entry) != 0:
            entry = None
        if entry is not None:
            axes = (entry,) if isinstance(entry, str) else tuple(entry)
            if any(a in used for a in axes):
                entry = None
            else:
                used.update(axes)
        out.append(entry)
    return P(*out)


def logical_shard(x: Array, *logical: str | None) -> Array:
    """with_sharding_constraint against the active (mesh, rules); identity
    when no context is active (single-device smoke tests). Constraints on
    non-divisible dims are dropped rather than erroring (see
    drop_nondivisible)."""
    st = getattr(_ctx, "state", None)
    if st is None:
        return x
    mesh, rules = st
    spec = drop_nondivisible(rules.resolve(*logical), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(*logical: str | None) -> NamedSharding | None:
    st = getattr(_ctx, "state", None)
    if st is None:
        return None
    mesh, rules = st
    return NamedSharding(mesh, rules.resolve(*logical))


# --------------------------------------------------------------------------
# Param shardings from logical-axis annotations
# --------------------------------------------------------------------------

def param_shardings(
    logical_axes: Any, mesh: Mesh, rules: AxisRules
) -> Any:
    """Map a pytree of per-dim logical-axis tuples to NamedShardings.

    `logical_axes` mirrors the params pytree; each leaf is a tuple of
    logical axis names (or None), one per tensor dimension.
    """
    rules = filter_rules(rules, mesh)

    def leaf(axes):
        return NamedSharding(mesh, rules.resolve(*axes))

    return jax.tree.map(
        leaf, logical_axes, is_leaf=lambda x: isinstance(x, tuple)
    )


def eval_shape_with_sharding(fn, logical_axes_fn, mesh, rules, *args):
    """jax.eval_shape + attach shardings (dry-run param stand-ins)."""
    shapes = jax.eval_shape(fn, *args)
    axes = logical_axes_fn()
    shardings = param_shardings(axes, mesh, rules)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes,
        shardings,
    )
