"""jax API compatibility shims.

The repo targets the current jax API (``jax.shard_map`` with ``check_vma``,
``jax.set_mesh``); older runtimes (jax <= 0.4.x, as baked into this
container) expose the same machinery under ``jax.experimental.shard_map``
(``check_rep``) and the ``Mesh`` context manager. Route every call site
through these wrappers so the rest of the tree can use the modern
spelling unconditionally.
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """jax.shard_map with graceful fallback to jax.experimental.shard_map."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


def set_mesh(mesh):
    """Context manager activating `mesh`: jax.set_mesh / use_mesh / Mesh.__enter__."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh  # older jax: Mesh is itself a context manager
