"""jax API compatibility shims.

The repo targets the current jax API (``jax.shard_map`` with ``check_vma``,
``jax.set_mesh``); older runtimes (jax <= 0.4.x, as baked into this
container) expose the same machinery under ``jax.experimental.shard_map``
(``check_rep``) and the ``Mesh`` context manager. Route every call site
through these wrappers so the rest of the tree can use the modern
spelling unconditionally.

Lifecycle — when these shims can be dropped (also tracked in ROADMAP):
each wrapper probes the modern API first, so nothing here has to change
as the container's jax moves forward; the shims just become dead
fallback branches. Delete this module (and inline the two ``jax.*``
calls at the call sites) once the container image ships a jax that has
BOTH top-level ``jax.shard_map`` accepting ``check_vma`` (jax >= 0.6)
and ``jax.set_mesh`` (jax >= 0.6.2). Call sites to update then:
``core/serving_dist.py`` (both shard_map entry points),
``distributed/topk.py``, ``distributed/runner.py``, and the
multi-device tests. Until that jax lands, every new shard_map/set_mesh
use MUST go through this module — mixing spellings is how the seed's
two test failures happened.
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """jax.shard_map with graceful fallback to jax.experimental.shard_map."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


def set_mesh(mesh):
    """Context manager activating `mesh`: jax.set_mesh / use_mesh / Mesh.__enter__."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh  # older jax: Mesh is itself a context manager
