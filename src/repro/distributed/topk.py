"""Distributed top-k merge primitives (DESIGN.md §4, serving fleet).

When the KNN train-user database (or the item catalog) is sharded over the
'model' mesh axis, each shard computes a local top-k and the results are
merged: lax.top_k per shard -> all_gather(k * n_shards) -> re-top-k. The
all-gather moves only k·n_shards candidates instead of the full database —
this is the collective pattern that keeps 10^6-candidate retrieval
(retrieval_cand) and million-user KNN serving inside the latency budget.

Written with shard_map so the collective is explicit in the lowered HLO
(the dry-run collective-bytes parser counts it).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.compat import shard_map

Array = jax.Array


def _take_last(p: Array, idx: Array) -> Array:
    """take_along_axis on the LAST axis; idx broadcasts over any leading
    payload dims (payloads may be (..., b, n))."""
    while idx.ndim < p.ndim:
        idx = idx[None]
    return jnp.take_along_axis(p, jnp.broadcast_to(idx, p.shape[:-1] + idx.shape[-1:]), axis=-1)


def _merge_topk(values: Array, indices: Array, k: int, payload=None):
    """Merge (b, n_cand) candidate (value, global-index) pairs -> top-k."""
    top_v, pos = jax.lax.top_k(values, k)
    top_i = jnp.take_along_axis(indices, pos, axis=-1)
    if payload is None:
        return top_v, top_i
    sel = jax.tree.map(lambda p: _take_last(p, pos), payload)
    return top_v, top_i, sel


def gather_merge_top_k(
    local_v: Array,             # (b, kk) per-shard pre-selected values
    local_i: Array,             # (b, kk) their GLOBAL indices
    k: int,
    axis_name: str,
    payload=None,               # pytree of (..., b, kk) selected slots
):
    """The collective half of distributed_top_k: all_gather each
    shard's already-selected (value, global index) candidates — and
    their payload slots — then re-top-k the union. Exposed on its own
    so bodies that produce their local top-k without a dense score
    matrix (e.g. the slab-streaming sharded KNN body in
    core.serving_dist) can join the same merge. Only k·shards
    candidates cross the interconnect."""

    def gather_flat(x):
        """(..., b?, kk) -> all_gather -> (..., shards*kk): the shard axis
        lands in front; fold it into the last axis."""
        g = jax.lax.all_gather(x, axis_name)       # (shards, ..., kk)
        g = jnp.moveaxis(g, 0, -2)                 # (..., shards, kk)
        return g.reshape(g.shape[:-2] + (-1,))

    all_v = gather_flat(local_v)
    all_i = gather_flat(local_i)
    all_p = None
    if payload is not None:
        all_p = jax.tree.map(gather_flat, payload)
    return _merge_topk(all_v, all_i, k, all_p)


def distributed_top_k(
    scores: Array,              # (b, n_local) per-shard scores
    k: int,
    axis_name: str,
    global_offset: Array | None = None,
    payload=None,
):
    """Inside shard_map: local top-k -> all_gather -> re-top-k.

    Returns (values (b, k) descending, global indices (b, k)) — plus the
    selected `payload` entries when a pytree of (b, n_local) payloads
    rides along (e.g. raw utilities / constraint attributes when
    selecting by adjusted score). `global_offset` is this shard's
    starting index in the global catalog (defaults to
    axis_index * n_local). Only k*shards candidates (and their payload
    slots) cross the interconnect.
    """
    b, n_local = scores.shape
    kk = min(k, n_local)
    local_v, local_i = jax.lax.top_k(scores, kk)
    local_p = None
    if payload is not None:
        local_p = jax.tree.map(lambda p: _take_last(p, local_i), payload)
    if global_offset is None:
        global_offset = jax.lax.axis_index(axis_name) * n_local
    local_i = local_i + global_offset
    return gather_merge_top_k(local_v, local_i, k, axis_name,
                              payload=local_p)


def sharded_knn_topk(
    mesh: Mesh,
    xq: Array,       # (b, d) queries, replicated over the model axis
    xdb: Array,      # (n, d) database, row-sharded over `shard_axis`
    k: int,
    *,
    shard_axis: str = "model",
    batch_axes=("pod", "data"),
):
    """k nearest database rows under squared L2, database sharded by rows.

    The distance matmul runs per shard (MXU); only k candidates per shard
    cross the interconnect. Returns (d2 (b,k) ascending, idx (b,k) global).
    """
    batch_axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    in_specs = (P(batch_axes, None), P(shard_axis, None))
    out_specs = (P(batch_axes, None), P(batch_axes, None))

    def body(xq_l, xdb_l):
        d2 = (
            jnp.sum(xq_l * xq_l, axis=-1, keepdims=True)
            - 2.0 * (xq_l @ xdb_l.T)
            + jnp.sum(xdb_l * xdb_l, axis=-1)[None, :]
        )
        d2 = jnp.maximum(d2, 0.0)
        neg_v, idx = distributed_top_k(-d2, k, shard_axis)
        return -neg_v, idx

    return shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )(xq, xdb)


def sharded_score_topk(
    mesh: Mesh,
    scores: Array,   # (b, n_candidates) sharded over candidates
    k: int,
    *,
    shard_axis: str = "model",
    batch_axes=("pod", "data"),
):
    """Top-k over a candidate axis that is sharded over `shard_axis`
    (retrieval_cand serving: 10^6 candidates, k winners)."""
    batch_axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    in_specs = (P(batch_axes, shard_axis),)
    out_specs = (P(batch_axes, None), P(batch_axes, None))

    def body(s_l):
        return distributed_top_k(s_l, k, shard_axis)

    return shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )(scores)
