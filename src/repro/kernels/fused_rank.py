"""fused_rank — the paper's online hot path as one Pallas TPU kernel.

Computes, per user n:   s = u + (1 + eps) * sum_k lam_k a_k
and streams the top-m2 (score, item) pairs out — the adjusted scores
NEVER materialize in HBM. For the retrieval_cand regime (m1 = 10^6
candidates, m2 = 50 slots) this turns

  XLA path:  read u (4 MB) + a (K*4 MB), write s (4 MB), read s (4 MB),
             top_k -> ~ (2K + 10) MB of HBM traffic per user
  kernel:    read u + a once, write m2 values  -> (K + 1) * 4 MB

i.e. strictly the compulsory traffic. The memory-bound roofline term
drops by ~(K+3)/(K+1) (measured in EXPERIMENTS.md §Perf).

Grid: (batch_tiles, m1_tiles); m1 is the minor (fastest) axis so the
running top-k scratch lives in VMEM across the whole m1 sweep of one
batch tile. BlockSpec tiles:
  u    (Bn, Tm)      VMEM
  a    (Bn, K, Tm)   VMEM  (K is small: 5-8 constraints)
  lam  (Bn, K)       VMEM, same block every m1 step
  out  (Bn, m2) x2   written on the last m1 step

Alignment: Tm is a multiple of 128 (lanes); Bn a multiple of 8
(sublanes, f32). m2 <= MAX_KERNEL_M2 keeps the merge cheap; bigger m2
falls back to the XLA path in ops.py (a full sort is the right tool
once m2 ~ m1).

rank+audit (`rank_audited_pallas`) extends the same sweep into the full
serving contract: the streaming merge carries each winner's raw utility
and K constraint-attribute values as VMEM payload columns
(common.topk_merge's payload ride-along), and the flush step computes
utility = sum(u_sel * gamma), exposure_k = sum(a_sel_k * gamma), and
compliant = all(exposure >= b - tol) before anything leaves the kernel.
The post-rank XLA epilogue (gather u/a by the emitted indices, einsum
against gamma) is gone: its HBM cost — an O((K+1)·m2) random gather
back into the (n, K, m1) attribute tensor plus a materialized
(n, K, m2) int32 index tensor — collapses to the (K+1)·m2 payload
values already resident in VMEM scratch. Audit math mirrors
core.ranking.audit_selected op-for-op so the outputs are bitwise
identical to the rank_given_lambda oracle (tests/test_rank_audited.py).

predict+rank+audit (`linear_rank_audited_pallas`) folds the λ-predictor
itself into the kernel prologue for the affine predictor families
(linear ridge and the covariate-free mean): at the first m1 step of
each batch tile it computes lam = X_blk @ W.T + c (optionally clamped
at 0, the ridge predictor's head) into a VMEM scratch buffer, and the
rest of the sweep reads λ̂ from that scratch. λ̂ never exists in HBM
between a predict program and a rank program — the only λ̂ bytes that
move are the tiny (n, K) output written at the flush step so callers
still get RankingOutput.lam. The prologue mirrors
core.predictors.LinearLambdaPredictor.predict op-for-op (one jnp.dot
plus the same max), so predict_rank_audited is bitwise-identical to
predict-then-rank for these families (tests/test_predict_rank.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.common import NEG_INF, topk_merge

MAX_KERNEL_M2 = 128


def _fused_rank_kernel(
    lam_ref, u_ref, a_ref,                 # inputs
    vals_ref, idx_ref,                     # outputs
    run_v, run_i,                          # VMEM scratch
    *, eps: float, m2: int, tile_m: int, num_k: int,
):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        run_v[...] = jnp.full_like(run_v, NEG_INF)
        run_i[...] = jnp.zeros_like(run_i)

    u = u_ref[...].astype(jnp.float32)                   # (Bn, Tm)
    lam = lam_ref[...].astype(jnp.float32)               # (Bn, K)
    # K static and small: unrolled axpy chain (no dot_general needed)
    s = u
    for k in range(num_k):
        s = s + (1.0 + eps) * lam[:, k][:, None] * a_ref[:, k, :].astype(jnp.float32)

    base = t * tile_m
    gidx = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, dimension=1)
    new_v, new_i = topk_merge(run_v[...], run_i[...], s, gidx, m2)
    run_v[...] = new_v
    run_i[...] = new_i

    @pl.when(t == pl.num_programs(1) - 1)
    def _flush():
        vals_ref[...] = run_v[...]
        idx_ref[...] = run_i[...]


@functools.partial(
    jax.jit,
    static_argnames=("m2", "eps", "tile_b", "tile_m", "interpret"))
def fused_rank_pallas(
    u: jax.Array,        # (n, m1)
    a: jax.Array,        # (n, K, m1)
    lam: jax.Array,      # (n, K)
    *,
    m2: int,
    eps: float = 1e-4,
    tile_b: int = 8,
    tile_m: int = 512,
    interpret: bool = False,
):
    """Returns (top scores (n, m2) descending f32, item indices (n, m2))."""
    n, m1 = u.shape
    K = a.shape[1]
    if m2 > MAX_KERNEL_M2:
        raise ValueError(f"kernel path supports m2 <= {MAX_KERNEL_M2}; "
                         f"use repro.kernels.ops.fused_rank (XLA fallback)")
    if n % tile_b or m1 % tile_m:
        raise ValueError(f"(n={n}, m1={m1}) must tile by ({tile_b}, {tile_m})")

    grid = (n // tile_b, m1 // tile_m)
    kernel = functools.partial(
        _fused_rank_kernel, eps=eps, m2=m2, tile_m=tile_m, num_k=K)
    vals, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, K), lambda b, t: (b, 0)),
            pl.BlockSpec((tile_b, tile_m), lambda b, t: (b, t)),
            pl.BlockSpec((tile_b, K, tile_m), lambda b, t: (b, 0, t)),
        ],
        out_specs=[
            pl.BlockSpec((tile_b, m2), lambda b, t: (b, 0)),
            pl.BlockSpec((tile_b, m2), lambda b, t: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, m2), jnp.float32),
            jax.ShapeDtypeStruct((n, m2), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile_b, m2), jnp.float32),
            pltpu.VMEM((tile_b, m2), jnp.int32),
        ],
        interpret=interpret,
    )(lam, u, a)
    return vals, idx


# ---------------------------------------------------------------------------
# rank + audit: selection AND utility/exposure/compliance in one sweep
# ---------------------------------------------------------------------------

def _merge_scored_tile(
    t, lam, u_ref, a_ref, run_v, run_i, run_u, run_a,
    *, eps: float, m2: int, tile_m: int, num_k: int,
):
    """One m1 step of the rank+audit sweep: adjusted scores for this
    tile, merged into the running top-m2 with u/a payload ride-along.
    Shared verbatim by the lam-input and predictor-prologue kernels so
    their selections can never drift apart."""
    u = u_ref[...].astype(jnp.float32)                   # (Bn, Tm)
    a = a_ref[...].astype(jnp.float32)                   # (Bn, K, Tm)
    s = u
    for k in range(num_k):
        s = s + (1.0 + eps) * lam[:, k][:, None] * a[:, k, :]

    base = t * tile_m
    gidx = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, dimension=1)
    new_v, new_i, new_p = topk_merge(
        run_v[...], run_i[...], s, gidx, m2,
        run_payload={"u": run_u[...], "a": run_a[...]},
        tile_payload={"u": u, "a": a})
    run_v[...] = new_v
    run_i[...] = new_i
    run_u[...] = new_p["u"]
    run_a[...] = new_p["a"]


def _audit_flush(
    gamma_ref, b_ref, vals_ref, idx_ref, util_ref, expo_ref, comp_ref,
    run_v, run_i, run_u, run_a, *, tol: float,
):
    """The audit epilogue, entirely on VMEM residents: mirrors
    core.ranking.audit_selected op-for-op (bitwise parity)."""
    gamma = gamma_ref[...].astype(jnp.float32)           # (Bn, m2)
    b = b_ref[...].astype(jnp.float32)                   # (Bn, K)
    u_sel = run_u[...]                                   # (Bn, m2)
    a_sel = run_a[...]                                   # (Bn, K, m2)
    expo = jnp.sum(a_sel * gamma[:, None, :], axis=-1)   # (Bn, K)
    vals_ref[...] = run_v[...]
    idx_ref[...] = run_i[...]
    util_ref[...] = jnp.sum(u_sel * gamma, axis=-1, keepdims=True)
    expo_ref[...] = expo
    comp_ref[...] = jnp.all(
        expo >= b - tol, axis=-1, keepdims=True).astype(jnp.int32)


def _rank_audited_kernel(
    lam_ref, b_ref, gamma_ref, u_ref, a_ref,        # inputs
    vals_ref, idx_ref, util_ref, expo_ref, comp_ref,  # outputs
    run_v, run_i, run_u, run_a,                     # VMEM scratch
    *, eps: float, m2: int, tile_m: int, num_k: int, tol: float,
):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        run_v[...] = jnp.full_like(run_v, NEG_INF)
        run_i[...] = jnp.zeros_like(run_i)
        run_u[...] = jnp.zeros_like(run_u)
        run_a[...] = jnp.zeros_like(run_a)

    lam = lam_ref[...].astype(jnp.float32)               # (Bn, K)
    _merge_scored_tile(t, lam, u_ref, a_ref, run_v, run_i, run_u, run_a,
                       eps=eps, m2=m2, tile_m=tile_m, num_k=num_k)

    @pl.when(t == pl.num_programs(1) - 1)
    def _flush():
        _audit_flush(gamma_ref, b_ref, vals_ref, idx_ref, util_ref,
                     expo_ref, comp_ref, run_v, run_i, run_u, run_a,
                     tol=tol)


@functools.partial(
    jax.jit,
    static_argnames=("m2", "eps", "tol", "tile_b", "tile_m", "interpret"))
def rank_audited_pallas(
    u: jax.Array,        # (n, m1)
    a: jax.Array,        # (n, K, m1)
    b: jax.Array,        # (n, K)
    lam: jax.Array,      # (n, K)
    gamma: jax.Array,    # (n, m2)
    *,
    m2: int,
    eps: float = 1e-4,
    tol: float = 1e-6,
    tile_b: int = 8,
    tile_m: int = 512,
    interpret: bool = False,
):
    """Fused rank+audit: returns (vals (n, m2) f32 desc, idx (n, m2) i32,
    utility (n, 1) f32, exposure (n, K) f32, compliant (n, 1) i32).

    The (K+1) payload columns per winner live in VMEM scratch for the
    whole m1 sweep; u/a are read exactly once and never re-gathered."""
    n, m1 = u.shape
    K = a.shape[1]
    if m2 > MAX_KERNEL_M2:
        raise ValueError(f"kernel path supports m2 <= {MAX_KERNEL_M2}; "
                         f"use repro.kernels.ops.rank_audited (XLA fallback)")
    if n % tile_b or m1 % tile_m:
        raise ValueError(f"(n={n}, m1={m1}) must tile by ({tile_b}, {tile_m})")

    grid = (n // tile_b, m1 // tile_m)
    kernel = functools.partial(
        _rank_audited_kernel, eps=eps, m2=m2, tile_m=tile_m, num_k=K, tol=tol)
    vals, idx, util, expo, comp = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, K), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, K), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, m2), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, tile_m), lambda bi, t: (bi, t)),
            pl.BlockSpec((tile_b, K, tile_m), lambda bi, t: (bi, 0, t)),
        ],
        out_specs=[
            pl.BlockSpec((tile_b, m2), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, m2), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, 1), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, K), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, 1), lambda bi, t: (bi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, m2), jnp.float32),
            jax.ShapeDtypeStruct((n, m2), jnp.int32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, K), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile_b, m2), jnp.float32),
            pltpu.VMEM((tile_b, m2), jnp.int32),
            pltpu.VMEM((tile_b, m2), jnp.float32),
            pltpu.VMEM((tile_b, K, m2), jnp.float32),
        ],
        interpret=interpret,
    )(lam, b, gamma, u, a)
    return vals, idx, util, expo, comp


# ---------------------------------------------------------------------------
# predict + rank + audit: the affine λ-predictor folded into the prologue
# ---------------------------------------------------------------------------

def _linear_rank_audited_kernel(
    w_ref, c_ref, x_ref, b_ref, gamma_ref, u_ref, a_ref,     # inputs
    vals_ref, idx_ref, util_ref, expo_ref, comp_ref, lam_ref,  # outputs
    run_v, run_i, run_u, run_a, lam_scr,                     # VMEM scratch
    *, eps: float, m2: int, tile_m: int, num_k: int, tol: float, relu: bool,
):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        run_v[...] = jnp.full_like(run_v, NEG_INF)
        run_i[...] = jnp.zeros_like(run_i)
        run_u[...] = jnp.zeros_like(run_u)
        run_a[...] = jnp.zeros_like(run_a)
        # The predictor prologue: λ̂ = X W^T + c for this batch tile,
        # computed once per tile into VMEM scratch — the m1 sweep reads
        # it from there; λ̂ never round-trips HBM between predict and
        # rank. Ops mirror LinearLambdaPredictor.predict exactly
        # (jnp.maximum clamp when relu; the mean predictor is the
        # W = 0 degenerate case with the clamp off).
        x = x_ref[...].astype(jnp.float32)               # (Bn, d)
        w = w_ref[...].astype(jnp.float32)               # (K, d)
        lam = jnp.dot(x, w.T) + c_ref[...].astype(jnp.float32)
        if relu:
            lam = jnp.maximum(lam, 0.0)
        lam_scr[...] = lam

    _merge_scored_tile(t, lam_scr[...], u_ref, a_ref,
                       run_v, run_i, run_u, run_a,
                       eps=eps, m2=m2, tile_m=tile_m, num_k=num_k)

    @pl.when(t == pl.num_programs(1) - 1)
    def _flush():
        _audit_flush(gamma_ref, b_ref, vals_ref, idx_ref, util_ref,
                     expo_ref, comp_ref, run_v, run_i, run_u, run_a,
                     tol=tol)
        lam_ref[...] = lam_scr[...]


@functools.partial(
    jax.jit,
    static_argnames=("m2", "eps", "tol", "relu", "tile_b", "tile_m",
                     "interpret"))
def linear_rank_audited_pallas(
    u: jax.Array,        # (n, m1)
    a: jax.Array,        # (n, K, m1)
    b: jax.Array,        # (n, K)
    X: jax.Array,        # (n, d) covariates
    W: jax.Array,        # (K, d) predictor weights (0 for the mean family)
    c: jax.Array,        # (1, K) predictor intercept (row vector)
    gamma: jax.Array,    # (n, m2)
    *,
    m2: int,
    eps: float = 1e-4,
    tol: float = 1e-6,
    relu: bool = True,
    tile_b: int = 8,
    tile_m: int = 512,
    interpret: bool = False,
):
    """Predict+rank+audit in one sweep for affine λ predictors: returns
    (vals (n, m2) f32 desc, idx (n, m2) i32, utility (n, 1) f32,
    exposure (n, K) f32, compliant (n, 1) i32, lam (n, K) f32).

    λ̂ lives in VMEM scratch for the whole m1 sweep; the (n, K) lam
    output written at the flush step is the only λ̂ HBM traffic — there
    is no predict-program → rank-program handoff at all."""
    n, m1 = u.shape
    K = a.shape[1]
    d = X.shape[1]
    if m2 > MAX_KERNEL_M2:
        raise ValueError(f"kernel path supports m2 <= {MAX_KERNEL_M2}; "
                         f"use repro.kernels.ops.predict_rank_audited "
                         f"(XLA fallback)")
    if n % tile_b or m1 % tile_m:
        raise ValueError(f"(n={n}, m1={m1}) must tile by ({tile_b}, {tile_m})")
    if W.shape != (K, d) or c.shape != (1, K):
        raise ValueError(f"predictor shapes W{W.shape}/c{c.shape} do not "
                         f"match (K={K}, d={d})")

    grid = (n // tile_b, m1 // tile_m)
    kernel = functools.partial(
        _linear_rank_audited_kernel, eps=eps, m2=m2, tile_m=tile_m,
        num_k=K, tol=tol, relu=relu)
    vals, idx, util, expo, comp, lam = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, d), lambda bi, t: (0, 0)),
            pl.BlockSpec((1, K), lambda bi, t: (0, 0)),
            pl.BlockSpec((tile_b, d), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, K), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, m2), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, tile_m), lambda bi, t: (bi, t)),
            pl.BlockSpec((tile_b, K, tile_m), lambda bi, t: (bi, 0, t)),
        ],
        out_specs=[
            pl.BlockSpec((tile_b, m2), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, m2), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, 1), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, K), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, 1), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, K), lambda bi, t: (bi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, m2), jnp.float32),
            jax.ShapeDtypeStruct((n, m2), jnp.int32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, K), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
            jax.ShapeDtypeStruct((n, K), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile_b, m2), jnp.float32),
            pltpu.VMEM((tile_b, m2), jnp.int32),
            pltpu.VMEM((tile_b, m2), jnp.float32),
            pltpu.VMEM((tile_b, K, m2), jnp.float32),
            pltpu.VMEM((tile_b, K), jnp.float32),
        ],
        interpret=interpret,
    )(W, c, X, b, gamma, u, a)
    return vals, idx, util, expo, comp, lam
