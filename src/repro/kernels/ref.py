"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics contracts: tests sweep shapes/dtypes and assert
the kernels (run with interpret=True on CPU) match these references.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def knn_topk_ref(xq: Array, xdb: Array, k: int) -> tuple[Array, Array]:
    """k nearest database rows per query under squared-L2 distance.

    xq: (B, D), xdb: (N, D) -> (dists (B, k) ascending, idx (B, k)).
    Ties broken by lower index (stable), matching the kernel's
    iterative-argmin selection.
    """
    xq = xq.astype(jnp.float32)
    xdb = xdb.astype(jnp.float32)
    d2 = (
        jnp.sum(xq * xq, axis=-1, keepdims=True)
        - 2.0 * (xq @ xdb.T)
        + jnp.sum(xdb * xdb, axis=-1)[None, :]
    )
    d2 = jnp.maximum(d2, 0.0)
    # Stable ascending selection: argsort is stable in jnp.
    order = jnp.argsort(d2, axis=-1, stable=True)[:, :k]
    return jnp.take_along_axis(d2, order, axis=-1), order


def fused_rank_ref(
    u: Array, a: Array, lam: Array, m2: int, eps: float = 1e-4
) -> tuple[Array, Array]:
    """Adjusted-score top-m2 selection (the paper's online hot path).

    u: (n, m1); a: (n, K, m1); lam: (n, K).
    s = u + (1 + eps) * lam @ a;  returns (top scores (n, m2) descending,
    item indices (n, m2)). Ties broken by lower item index.
    """
    s = u.astype(jnp.float32) + (1.0 + eps) * jnp.einsum(
        "nk,nkm->nm", lam.astype(jnp.float32), a.astype(jnp.float32)
    )
    order = jnp.argsort(-s, axis=-1, stable=True)[:, :m2]
    return jnp.take_along_axis(s, order, axis=-1), order


def rank_audited_ref(
    u: Array,      # (n, m1)
    a: Array,      # (n, K, m1)
    b: Array,      # (n, K)
    lam: Array,    # (n, K)
    gamma: Array,  # (n, m2)
    m2: int,
    eps: float = 1e-4,
    tol: float | None = None,
):
    """Rank + audit in one contract: fused_rank_ref's selection followed
    by the shared audit epilogue on the selected values.

    Returns (vals (n, m2) desc f32, idx (n, m2), utility (n,),
    exposure (n, K), compliant (n,) bool). This is both the semantics
    oracle for the Pallas rank+audit kernel and the XLA fallback body in
    ops.rank_audited — note the gathers use a broadcast index
    (idx (n, 1, m2) against a (n, K, m1)), not a materialized
    (n, K, m2) index tensor. ``tol=None`` resolves to the shared
    core.ranking.AUDIT_TOL.
    """
    from repro.core.ranking import AUDIT_TOL, audit_selected  # deferred: no cycle

    if tol is None:
        tol = AUDIT_TOL
    vals, idx = fused_rank_ref(u, a, lam, m2, eps)
    af = a.astype(jnp.float32)
    u_sel = jnp.take_along_axis(u.astype(jnp.float32), idx, axis=-1)
    a_sel = jnp.take_along_axis(af, idx[:, None, :], axis=-1)   # (n, K, m2)
    utility, exposure, compliant = audit_selected(
        u_sel, a_sel, gamma.astype(jnp.float32), b.astype(jnp.float32),
        tol=tol)
    return vals, idx, utility, exposure, compliant


def knn_lambda_ref(xq: Array, xdb: Array, lam_db: Array, k: int) -> Array:
    """Inverse-distance-weighted KNN λ regression on knn_topk_ref's
    neighbours — the semantics oracle for knn_lambda_pallas. The
    weighting tail is the predictor's own _idw_lambda (one source of
    truth: weights, exact-match override, normalization), so the only
    difference from core.predictors.knn_predict is the stable-argsort
    neighbour selection shared with knn_topk_ref.
    """
    from repro.core.predictors import _idw_lambda  # deferred: no cycle

    xq = xq.astype(jnp.float32)
    d2, idx = knn_topk_ref(xq, xdb, k)
    x2 = jnp.sum(xq * xq, axis=-1, keepdims=True)
    y2 = jnp.sum(xdb.astype(jnp.float32) ** 2, axis=-1)[idx]
    return _idw_lambda(d2, x2, y2, lam_db.astype(jnp.float32)[idx])


def knn_quant_select_ref(
    xq: Array,       # (B, D) queries, f32
    X_q: Array,      # (n_pad, D) packed db (predictors.pack_knn_db)
    q_scale: Array,  # (n_slabs, 1) per-slab dequant scales
    y2_q: Array,     # (n_pad, 1) exact |x̃|^2 (PAD_Y2 on pad rows)
    k: int,
    *,
    k_extra: int | None = None,
    mode: str = "int8",
):
    """Oracle for the QUANTIZED selection path: build the full quantized
    distance matrix slab by slab with the SAME shared math the kernels
    run (common.quant_d2_tile), take the top-(k + k_extra) survivors by
    stable argsort, re-score them exactly in f32 on the dequantized
    rows, and re-rank to the final k with ties to the lowest global
    index. Returns (d2 (B, k) ascending exact-on-x̃, idx (B, k),
    guard (B, 1) i32) — bitwise the kernels' selection, λ̂ inputs, and
    margin-guard flags.
    """
    from repro.kernels.common import (  # deferred: no cycle
        QUANT_EXTRA, bottomk_rerank, exact_rescore, quant_d2_err,
        quant_d2_tile)

    if k_extra is None:
        k_extra = QUANT_EXTRA
    k_keep = k + k_extra
    xq = xq.astype(jnp.float32)
    B, D = xq.shape
    n_pad = X_q.shape[0]
    n_slabs = q_scale.shape[0]
    slab = n_pad // n_slabs
    d2q_cols = []
    for s in range(n_slabs):
        db = X_q[s * slab:(s + 1) * slab]
        y2_row = jnp.broadcast_to(y2_q[s * slab:(s + 1) * slab, 0][None, :],
                                  (B, slab))
        d2q_cols.append(
            quant_d2_tile(xq, db, q_scale[s, 0], y2_row, mode=mode))
    d2q = jnp.concatenate(d2q_cols, axis=-1)                 # (B, n_pad)
    order = jnp.argsort(d2q, axis=-1, stable=True)[:, :k_keep]
    d2q_keep = jnp.take_along_axis(d2q, order, axis=-1)

    scale_rows = q_scale[order // slab, 0]                   # (B, k_keep)
    x_sel = X_q[order].astype(jnp.float32) * scale_rows[..., None]
    y2_sel = y2_q[order, 0]
    x_cols = x_sel.transpose(0, 2, 1)                        # (B, D, k_keep)
    d2x = exact_rescore(xq, x_cols, y2_sel)

    # margin guard on the QUANTIZED order (same rule as the kernels):
    # gap vs the boundary pair's EXACT quantization errors
    gap = d2q_keep[:, k:k + 1] - d2q_keep[:, k - 1:k]
    errs = quant_d2_err(xq, x_cols, mode=mode)
    guard = (gap <= errs[:, k - 1:k] + errs[:, k:k + 1]).astype(jnp.int32)
    d2_top, idx_top = bottomk_rerank(d2x, order, k)
    return d2_top, idx_top, guard


def knn_quant_lambda_ref(
    xq: Array, X_q: Array, q_scale: Array, y2_q: Array, lam_db: Array,
    k: int, *, k_extra: int | None = None, mode: str = "int8",
):
    """λ̂ through the quantized selection oracle: knn_quant_select_ref's
    neighbours weighted by the predictor's own _idw_lambda — the
    semantics contract for knn_lambda_quant_pallas and the quantized
    phase of knn_rank_audited_quant_pallas. Returns (lam_hat (B, K),
    guard (B, 1) i32)."""
    from repro.core.predictors import _idw_lambda  # deferred: no cycle

    xq = xq.astype(jnp.float32)
    d2, idx, guard = knn_quant_select_ref(
        xq, X_q, q_scale, y2_q, k, k_extra=k_extra, mode=mode)
    x2 = jnp.sum(xq * xq, axis=-1, keepdims=True)
    lam = _idw_lambda(d2, x2, y2_q[idx, 0],
                      lam_db.astype(jnp.float32)[idx])
    return lam, guard


def check_pred_width(k_pred: int, k_bucket: int) -> None:
    """The one place the predictor-width contract is enforced: a
    predictor may emit FEWER shadow prices than the problem has
    constraint rows (the extras get lam = 0, the bucket-padding
    scheme), never more. Shared by the kernel dispatcher and this
    fallback so the two paths reject identically."""
    if k_pred > k_bucket:
        raise ValueError(
            f"predictor emits {k_pred} shadow prices but the problem "
            f"carries only {k_bucket} constraint rows; serving a "
            f"constraint the predictor was not fit for needs lam, not X")


def predict_rank_audited_ref(
    X: Array,      # (n, d) covariates
    predictor,     # fitted λ predictor pytree (predict(X) -> (n, K_pred))
    u: Array,      # (n, m1)
    a: Array,      # (n, K, m1)
    b: Array,      # (n, K)
    gamma: Array,  # (n, m2)
    m2: int,
    eps: float = 1e-4,
    tol: float | None = None,
):
    """Predict-then-rank+audit as two explicit XLA stages — the
    semantics oracle (and fallback body) for the single-sweep
    ops.predict_rank_audited dispatcher. λ̂ comes from the predictor's
    own predict(); extra constraint columns in `a` beyond the
    predictor's output (bucket-padded K) get zero shadow prices,
    matching the serving engine's padding scheme.

    Returns (vals, idx, utility, exposure, compliant, lam) — the
    rank_audited_ref tuple plus the (n, K) λ̂ actually used.
    """
    lam = predictor.predict(X).astype(jnp.float32)
    check_pred_width(lam.shape[-1], a.shape[1])
    pad_k = a.shape[1] - lam.shape[-1]
    if pad_k:
        lam = jnp.pad(lam, ((0, 0), (0, pad_k)))
    vals, idx, utility, exposure, compliant = rank_audited_ref(
        u, a, b, lam, gamma, m2, eps, tol)
    return vals, idx, utility, exposure, compliant, lam


def embedding_bag_ref(
    table: Array, indices: Array, weights: Array | None = None
) -> Array:
    """Multi-hot embedding-bag (sum mode), the recsys lookup hot path.

    table: (V, D); indices: (n_bags, bag) int32, entries < 0 are padding;
    weights: optional (n_bags, bag) per-sample weights.
    Returns (n_bags, D) = sum_j w[i,j] * table[indices[i,j]].
    """
    valid = (indices >= 0).astype(table.dtype)
    idx = jnp.maximum(indices, 0)
    rows = table[idx]                                   # (n_bags, bag, D)
    w = valid if weights is None else weights * valid
    return jnp.einsum("nb,nbd->nd", w.astype(table.dtype), rows)


def dual_adjust_ref(u: Array, a: Array, lam: Array, eps: float = 0.0) -> Array:
    """Just the adjusted score s = u + (1+eps) lam @ a (no selection)."""
    return u + (1.0 + eps) * jnp.einsum("nk,nkm->nm", lam, a)
