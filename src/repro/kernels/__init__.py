# Pallas TPU kernels for the compute hot-spots the paper optimizes:
#   fused_rank    — adjusted-score ranking (the <50 ms online hot path)
#   rank_audited  — rank + in-VMEM audit: one kernel emits the complete
#                   RankingOutput (perm/utility/exposure/compliance) with
#                   zero post-kernel reads of u/a
#   knn_topk      — lambda-predictor KNN over the train-user database
#   embedding_bag — recsys sparse-lookup substrate
# Each has a pure-jnp oracle in ref.py; ops.py wraps with padding +
# XLA fallbacks. Validated with interpret=True on CPU (tests/test_kernels.py,
# tests/test_rank_audited.py).
from repro.kernels import ref
from repro.kernels.ops import (
    embedding_bag,
    fused_rank,
    knn_predict_kernel,
    knn_topk,
    rank_audited,
)
