# Pallas TPU kernels for the compute hot-spots the paper optimizes:
#   fused_rank    — adjusted-score ranking (the <50 ms online hot path)
#   rank_audited  — rank + in-VMEM audit: one kernel emits the complete
#                   RankingOutput (perm/utility/exposure/compliance) with
#                   zero post-kernel reads of u/a
#   predict_rank_audited — the whole online stage (λ̂ = f(X), rank,
#                   audit) as one device program: affine predictors fold
#                   into the rank kernel's prologue, KNN fuses its
#                   weighting into the db sweep's flush, MLP joins the
#                   same executable as XLA matmuls
#   knn_topk / knn_lambda — lambda-predictor KNN over the train-user
#                   database (top-k pairs / fused λ̂ emission)
#   embedding_bag — recsys sparse-lookup substrate
# Each has a pure-jnp oracle in ref.py; ops.py wraps with padding +
# XLA fallbacks. Validated with interpret=True on CPU (tests/test_kernels.py,
# tests/test_rank_audited.py, tests/test_predict_rank.py).
from repro.kernels import ref
from repro.kernels.ops import (
    embedding_bag,
    fused_rank,
    knn_lambda,
    knn_predict_kernel,
    knn_topk,
    predict_rank_audited,
    rank_audited,
)
