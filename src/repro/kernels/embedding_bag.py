"""embedding_bag — multi-hot gather + weighted sum (Pallas TPU).

The recsys lookup hot path: out[i] = sum_j w[i,j] * table[idx[i,j]].
JAX has no native EmbeddingBag; the XLA reference (ref.py) is a gather
that materializes (n_bags, bag, D) rows in HBM before reducing. This
kernel keeps the table in HBM (memory_space=ANY), DMAs one row per
(bag-slot) directly into the VMEM accumulator tile, and never
materializes the (bag, D) intermediate:

  HBM traffic:  XLA gather ~ 2 * n_bags*bag*D  (write rows + read rows)
  kernel       ~     n_bags*bag*D              (read rows once)

Grid: one step per bag tile. Indices/weights ride in SMEM (scalars
drive the DMA addresses); the accumulator is a (tile_b, D) VMEM
scratch. This is the idiomatic TPU embedding design (row-granular DMA
gather), minus the multi-buffered DMA pipelining a production kernel
would add — the roofline term is already compulsory traffic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _embedding_bag_kernel(
    idx_ref, w_ref,               # SMEM: (tile_b, bag) int32 / f32
    table_ref,                    # ANY/HBM: (V, D)
    out_ref,                      # VMEM out: (tile_b, D)
    acc_ref,                      # VMEM scratch: (tile_b, D) f32
    *, bag: int, tile_b: int,
):
    acc_ref[...] = jnp.zeros_like(acc_ref)

    def slot(j, _):
        def row(i, _):
            ix = idx_ref[i, j]
            w = w_ref[i, j]
            valid = ix >= 0
            ix_safe = jnp.where(valid, ix, 0)
            r = pl.load(table_ref, (pl.dslice(ix_safe, 1), slice(None)))
            r = r.astype(jnp.float32) * jnp.where(valid, w, 0.0)
            cur = pl.load(acc_ref, (pl.dslice(i, 1), slice(None)))
            pl.store(acc_ref, (pl.dslice(i, 1), slice(None)), cur + r)
            return 0
        jax.lax.fori_loop(0, tile_b, row, 0)
        return 0

    jax.lax.fori_loop(0, bag, slot, 0)
    out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def embedding_bag_pallas(
    table: jax.Array,          # (V, D)
    indices: jax.Array,        # (n_bags, bag) int32, < 0 = padding
    weights: jax.Array | None = None,
    *,
    tile_b: int = 8,
    interpret: bool = False,
):
    """Sum-mode EmbeddingBag. Returns (n_bags, D) in table.dtype."""
    n_bags, bag = indices.shape
    V, D = table.shape
    if n_bags % tile_b:
        raise ValueError(f"n_bags={n_bags} must tile by {tile_b}")
    if weights is None:
        weights = jnp.ones((n_bags, bag), jnp.float32)

    grid = (n_bags // tile_b,)
    kernel = functools.partial(_embedding_bag_kernel, bag=bag, tile_b=tile_b)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, bag), lambda b: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((tile_b, bag), lambda b: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),     # whole table in HBM
        ],
        out_specs=pl.BlockSpec((tile_b, D), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((n_bags, D), table.dtype),
        scratch_shapes=[pltpu.VMEM((tile_b, D), jnp.float32)],
        interpret=interpret,
    )(indices, weights.astype(jnp.float32), table)
