"""Shared in-kernel primitives for the Pallas TPU kernels.

`topk_merge` is the streaming-selection building block used by knn_topk
and fused_rank: merge a fresh (B, T) score tile into a running (B, k)
top-k buffer held in VMEM scratch, flash-attention-style. k passes of
(max + first-argmax + mask) over the concatenated (B, k+T) tile; every
op is a lane reduction or elementwise — no sorts, no gathers, TPU-lowerable.

Ties break toward the candidate that comes FIRST in the concatenated
order. Because the running buffer (earlier tiles) precedes the fresh tile
and within a tile iota order is ascending, global tie-breaking is 'lowest
index wins' — matching the jnp stable-argsort oracle in ref.py.

The merge optionally carries PAYLOAD columns: a pytree of arrays whose
last axis is the candidate axis (running (B, ..., k), tile (B, ..., T)).
Each selected winner drags its payload slots along, so a kernel can keep
per-candidate side data resident in VMEM across the whole streaming
sweep and never re-gather it from HBM afterwards. Three kernels build
on it:
  * fused_rank.rank_audited_pallas — raw utilities + K attribute
    columns ride along so the audit runs at the flush step;
  * fused_rank.linear_rank_audited_pallas — same sweep, with the
    affine λ-predictor folded into the prologue (λ̂ itself lives in a
    VMEM scratch, not a payload — it is per-row, not per-candidate);
  * knn_topk.knn_lambda_pallas — each neighbour's λ row + |x_n|^2 ride
    along so the inverse-distance weighting runs at the flush step and
    the kernel emits λ̂ directly, no d2/idx pairs in HBM;
  * knn_topk.knn_rank_audited_pallas — BOTH of the above in one grid:
    the db-sweep merge feeds a λ̂ flush into VMEM scratch, then the
    rank-sweep merge audits at the final flush — the whole KNN online
    stage in one kernel launch.
It is also the in-VMEM twin of the payload ride-along in
repro.distributed.topk.distributed_top_k.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = float(-1e30)

# ---------------------------------------------------------------------------
# Shared tiling knobs. Every kernel wrapper in ops.py defaults to these,
# so a TPU-generation retune is a one-file edit and the benchmarks'
# traffic models can import the exact geometry the kernels run with.
# ---------------------------------------------------------------------------

LANE = 128      # TPU lane width: minor-dim alignment boundary
SUBLANE = 8     # f32 sublane count: batch-row alignment boundary

TILE_B = SUBLANE   # batch rows resident per grid step (rank + KNN sweeps)
TILE_M = 512       # candidate (m1) columns per rank-sweep tile
DB_SLAB = 512      # train-db rows per VMEM slab in the KNN db sweeps


def first_argmax(x: jnp.ndarray) -> jnp.ndarray:
    """(B, N) -> (B,) index of the first maximum along the last axis,
    via the iota-min trick (jnp.argmax's tie semantics, TPU-friendly)."""
    m = jnp.max(x, axis=-1, keepdims=True)
    iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, dimension=x.ndim - 1)
    masked = jnp.where(x >= m, iota, jnp.iinfo(jnp.int32).max)
    return jnp.min(masked, axis=-1)


def _select_one(p: jnp.ndarray, onehot: jnp.ndarray) -> jnp.ndarray:
    """Extract the single onehot-marked candidate column of a payload.

    p (B, ..., C), onehot (B, C) with exactly one True per row ->
    (B, ...). Sum-of-masked is exact (x + 0.0 == x in IEEE), works for
    any signed payload, and is a pure lane reduction."""
    oh = onehot.reshape(onehot.shape[:1] + (1,) * (p.ndim - 2)
                        + onehot.shape[-1:])
    return jnp.sum(jnp.where(oh, p, jnp.zeros_like(p)), axis=-1)


def _write_col(out: jnp.ndarray, val: jnp.ndarray, col: jnp.ndarray):
    """Write val (B, ...) into the col-marked last-axis slot of
    out (B, ..., k); col is a (B, k) onehot column mask."""
    cb = col.reshape(col.shape[:1] + (1,) * (out.ndim - 2) + col.shape[-1:])
    return jnp.where(cb, val[..., None], out)


def topk_merge(
    run_vals: jnp.ndarray,   # (B, k) running top values (descending-ish)
    run_idx: jnp.ndarray,    # (B, k) their global indices
    tile_vals: jnp.ndarray,  # (B, T) fresh candidate values
    tile_idx: jnp.ndarray,   # (B, T) their global indices
    k: int,
    run_payload=None,        # pytree of (B, ..., k) per-slot side data
    tile_payload=None,       # matching pytree of (B, ..., T)
):
    """Return new (run_vals, run_idx[, run_payload]): top-k of the union,
    descending, ties to lower concat position (running buffer first).
    When payloads ride along, each winner's payload slots are selected by
    the same onehot that selects its value — (vals, idx, payload)."""
    B = run_vals.shape[0]
    has_payload = run_payload is not None
    cand_v = jnp.concatenate([run_vals, tile_vals], axis=-1)   # (B, k+T)
    cand_i = jnp.concatenate([run_idx, tile_idx], axis=-1)
    cand_p = None
    if has_payload:
        cand_p = jax.tree.map(
            lambda rp, tp: jnp.concatenate([rp, tp], axis=-1),
            run_payload, tile_payload)
    iota = jax.lax.broadcasted_iota(jnp.int32, cand_v.shape, dimension=1)

    out_v = jnp.full((B, k), NEG_INF, cand_v.dtype)
    out_i = jnp.zeros((B, k), jnp.int32)
    out_p = (jax.tree.map(
        lambda p: jnp.zeros(p.shape[:-1] + (k,), p.dtype), cand_p)
        if has_payload else None)

    def body(j, carry):
        cand_v, out_v, out_i, out_p = carry
        sel = first_argmax(cand_v)                             # (B,)
        onehot = iota == sel[:, None]                          # (B, k+T)
        v = jnp.max(jnp.where(onehot, cand_v, NEG_INF), axis=-1)
        gi = jnp.max(jnp.where(onehot, cand_i, -1), axis=-1)
        # write column j of the output buffers
        col = jax.lax.broadcasted_iota(jnp.int32, (B, k), dimension=1) == j
        out_v = jnp.where(col, v[:, None], out_v)
        out_i = jnp.where(col, gi[:, None], out_i)
        if has_payload:
            out_p = jax.tree.map(
                lambda op, cp: _write_col(op, _select_one(cp, onehot), col),
                out_p, cand_p)
        cand_v = jnp.where(onehot, NEG_INF, cand_v)
        return cand_v, out_v, out_i, out_p

    _, out_v, out_i, out_p = jax.lax.fori_loop(
        0, k, body, (cand_v, out_v, out_i, out_p))
    if has_payload:
        return out_v, out_i, out_p
    return out_v, out_i


# ---------------------------------------------------------------------------
# Quantized db-sweep primitives.
#
# The KNN kernels' runtime is dominated by the (B, d) x (d, n_train)
# distance dot of the db-slab sweep. Storing the db as int8 (or bf16)
# cuts the HBM bytes streamed per sweep 4x (2x) and moves the dot onto
# the low-precision MXU path; a small survivor set (k + QUANT_EXTRA per
# row) is then re-scored EXACTLY in f32 at the flush step, so the final
# selection — and everything derived from it (λ̂, permutation, utility,
# exposure, compliance) — is computed at full precision.
#
# Semantics: the quantized path's ground truth is the DEQUANTIZED db
# x̃ = int8_row * slab_scale. Quantization of the stored rows is a
# representation choice (lossy vs the original f32 db unless the db was
# int8-representable to begin with); everything downstream of the pack is
# exact-on-x̃, and ref.knn_quant_select_ref reproduces the selection
# bitwise from the same packed arrays. The query stays f32 in the bf16
# mode and is symmetrically int8-quantized (per-row scale) in the int8
# mode; quant_d2_err computes, per survivor, the EXACT d2 error
# introduced by the QUERY quantization, which is what the margin guard
# tests.
#
# Every helper below is shared verbatim by the Pallas kernels
# (knn_topk.py), the XLA scan path (predictors.knn_quant_scan), and the
# oracle (ref.py) — single-source math is what makes bitwise
# kernel/oracle parity hold on both interpret and compiled backends.
# ---------------------------------------------------------------------------

QUANT_MODES = ("off", "bf16", "int8")

# Survivor over-retention: the quantized sweep keeps k + QUANT_EXTRA
# candidates so that quantization-induced rank inversions near the k-th
# place are repaired by the exact re-score instead of lost.
QUANT_EXTRA = 8

# Exact |x̃|^2 streamed alongside the quantized slabs; padding rows get
# this sentinel so they can never survive the sweep (int8 cannot encode
# a far-away row the way the f32 path's 1e15 padding does).
PAD_Y2 = float(1e30)


def quantize_query(q: jnp.ndarray):
    """Symmetric per-row int8 quantization of the query block.

    q (B, d) f32 -> (qi (B, d) f32 holding integer values in [-127, 127],
    sq (B, 1) f32 scale). qi stays f32: the MXU consumes it directly and
    f32 dots of integer-valued operands are exact for d * 127^2 < 2^24,
    so interpret-mode (CPU f32) and compiled int8-MXU (int32 accumulate)
    agree bitwise."""
    sq = jnp.max(jnp.abs(q), axis=-1, keepdims=True) / 127.0
    sq = jnp.where(sq > 0, sq, jnp.ones_like(sq))
    qi = jnp.clip(jnp.round(q / sq), -127.0, 127.0)
    return qi, sq


def dequant_rows(rows_q: jnp.ndarray, scale) -> jnp.ndarray:
    """x̃ = stored rows * slab scale. rows_q (n, d) int8-or-f32,
    scale scalar or broadcastable; returns f32."""
    return rows_q.astype(jnp.float32) * jnp.asarray(scale, jnp.float32)


def quant_d2_tile(q: jnp.ndarray, db_q: jnp.ndarray, scale,
                  y2_row: jnp.ndarray, *, mode: str) -> jnp.ndarray:
    """Quantized squared distances of a query block to one db slab.

    q (B, d) f32, db_q (T, d) stored slab, scale scalar slab scale,
    y2_row (B, T) exact |x̃|^2 broadcast across the batch -> (B, T) f32.

    int8: the query is quantized per-row and the cross term is a single
    integer-valued dot scaled back by (2 * sq * scale); d2 is exact in
    the db term (y2 streamed at f32) and approximate only through the
    query rounding. bf16: the slab is dequantized and the dot runs at
    f32 on the already-rounded values — no query error (bound 0)."""
    if mode == "int8":
        qi, sq = quantize_query(q)
        cross = jax.lax.dot_general(
            qi, db_q.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # (B, T)
        q2 = jnp.sum(q * q, axis=-1, keepdims=True)
        d2 = q2 - (2.0 * sq * jnp.asarray(scale, jnp.float32)) * cross \
            + y2_row
    elif mode == "bf16":
        xt = dequant_rows(db_q, scale)                   # (T, d) f32
        cross = jax.lax.dot_general(
            q, xt, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        q2 = jnp.sum(q * q, axis=-1, keepdims=True)
        d2 = q2 - 2.0 * cross + y2_row
    else:  # pragma: no cover - callers gate on mode
        raise ValueError(f"quant_d2_tile: bad mode {mode!r}")
    return jnp.maximum(d2, 0.0)


def exact_rescore(q: jnp.ndarray, x_sel: jnp.ndarray,
                  y2_sel: jnp.ndarray) -> jnp.ndarray:
    """Exact f32 squared distances of each row's survivor set.

    q (B, d), x_sel (B, d, k') dequantized survivor rows,
    y2_sel (B, k') their exact |x̃|^2 -> (B, k') f32."""
    q2 = jnp.sum(q * q, axis=-1, keepdims=True)          # (B, 1)
    cross = jnp.einsum("bd,bdk->bk", q, x_sel)           # (B, k')
    return jnp.maximum(q2 - 2.0 * cross + y2_sel, 0.0)


def quant_d2_err(q: jnp.ndarray, x_sel: jnp.ndarray, *,
                 mode: str) -> jnp.ndarray:
    """EXACT per-survivor quantization error of the sweep distances.

    The int8 cross term uses q̃ = sq * round(q / sq), so for a survivor
    with dequantized row x̃:  d2_quant - d2_exact = 2 (q - q̃) · x̃ —
    and at the flush the survivors' x̃ columns are VMEM-resident
    (x_sel (B, d, k')), so the error needs no bound at all: one small
    einsum computes it exactly. The margin guard compares the quantized
    k/(k+1) gap against the two boundary candidates' |err| sum — the
    precise condition under which query rounding could have swapped
    their order. bf16 mode rounds the db only (no query error) -> 0.
    Returns (B, k') f32 = |d2q - d2x| per survivor."""
    if mode != "int8":
        return jnp.zeros(x_sel.shape[:1] + x_sel.shape[-1:], jnp.float32)
    qi, sq = quantize_query(q)
    e = q - sq * qi                                          # (B, d)
    return jnp.abs(2.0 * jnp.einsum("bd,bdk->bk", e, x_sel))


def bottomk_rerank(d2: jnp.ndarray, gidx: jnp.ndarray, k: int,
                   payload=None):
    """Exact ascending top-k over a small candidate set, ties to the
    LOWEST GLOBAL INDEX — the stable-argsort tie rule of the f32 oracle.

    d2 (B, k') exact distances, gidx (B, k') global indices -> (d2_top
    (B, k), idx_top (B, k)[, payload_top]). k passes of (min-d2, then
    min-gidx among the tied, onehot select, mask +inf); every op is a
    lane reduction, so it runs identically in-kernel and under XLA."""
    B, kp = d2.shape
    INF = jnp.float32(jnp.inf)
    has_payload = payload is not None
    out_v = jnp.zeros((B, k), jnp.float32)
    out_i = jnp.zeros((B, k), jnp.int32)
    out_p = (jax.tree.map(
        lambda p: jnp.zeros(p.shape[:-1] + (k,), p.dtype), payload)
        if has_payload else None)
    big = jnp.iinfo(jnp.int32).max

    def body(j, carry):
        d2c, out_v, out_i, out_p = carry
        m = jnp.min(d2c, axis=-1, keepdims=True)                 # (B, 1)
        tied = d2c <= m                                          # (B, k')
        gi_sel = jnp.min(jnp.where(tied, gidx, big), axis=-1)    # (B,)
        onehot = jnp.logical_and(tied, gidx == gi_sel[:, None])  # (B, k')
        v = jnp.min(jnp.where(onehot, d2c, INF), axis=-1)
        col = jax.lax.broadcasted_iota(jnp.int32, (B, k), dimension=1) == j
        out_v = jnp.where(col, v[:, None], out_v)
        out_i = jnp.where(col, gi_sel[:, None], out_i)
        if has_payload:
            out_p = jax.tree.map(
                lambda op, cp: _write_col(op, _select_one(cp, onehot), col),
                out_p, payload)
        d2c = jnp.where(onehot, INF, d2c)
        return d2c, out_v, out_i, out_p

    _, out_v, out_i, out_p = jax.lax.fori_loop(
        0, k, body, (d2, out_v, out_i, out_p))
    if has_payload:
        return out_v, out_i, out_p
    return out_v, out_i
