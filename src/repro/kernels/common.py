"""Shared in-kernel primitives for the Pallas TPU kernels.

`topk_merge` is the streaming-selection building block used by knn_topk
and fused_rank: merge a fresh (B, T) score tile into a running (B, k)
top-k buffer held in VMEM scratch, flash-attention-style. k passes of
(max + first-argmax + mask) over the concatenated (B, k+T) tile; every
op is a lane reduction or elementwise — no sorts, no gathers, TPU-lowerable.

Ties break toward the candidate that comes FIRST in the concatenated
order. Because the running buffer (earlier tiles) precedes the fresh tile
and within a tile iota order is ascending, global tie-breaking is 'lowest
index wins' — matching the jnp stable-argsort oracle in ref.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = float(-1e30)


def first_argmax(x: jnp.ndarray) -> jnp.ndarray:
    """(B, N) -> (B,) index of the first maximum along the last axis,
    via the iota-min trick (jnp.argmax's tie semantics, TPU-friendly)."""
    m = jnp.max(x, axis=-1, keepdims=True)
    iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, dimension=x.ndim - 1)
    masked = jnp.where(x >= m, iota, jnp.iinfo(jnp.int32).max)
    return jnp.min(masked, axis=-1)


def topk_merge(
    run_vals: jnp.ndarray,   # (B, k) running top values (descending-ish)
    run_idx: jnp.ndarray,    # (B, k) their global indices
    tile_vals: jnp.ndarray,  # (B, T) fresh candidate values
    tile_idx: jnp.ndarray,   # (B, T) their global indices
    k: int,
):
    """Return new (run_vals, run_idx): top-k of the union, descending,
    ties to lower concat position (running buffer first)."""
    B = run_vals.shape[0]
    cand_v = jnp.concatenate([run_vals, tile_vals], axis=-1)   # (B, k+T)
    cand_i = jnp.concatenate([run_idx, tile_idx], axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, cand_v.shape, dimension=1)

    out_v = jnp.full((B, k), NEG_INF, cand_v.dtype)
    out_i = jnp.zeros((B, k), jnp.int32)

    def body(j, carry):
        cand_v, out_v, out_i = carry
        sel = first_argmax(cand_v)                             # (B,)
        onehot = iota == sel[:, None]                          # (B, k+T)
        v = jnp.max(jnp.where(onehot, cand_v, NEG_INF), axis=-1)
        gi = jnp.max(jnp.where(onehot, cand_i, -1), axis=-1)
        # write column j of the output buffers
        col = jax.lax.broadcasted_iota(jnp.int32, (B, k), dimension=1) == j
        out_v = jnp.where(col, v[:, None], out_v)
        out_i = jnp.where(col, gi[:, None], out_i)
        cand_v = jnp.where(onehot, NEG_INF, cand_v)
        return cand_v, out_v, out_i

    _, out_v, out_i = jax.lax.fori_loop(0, k, body, (cand_v, out_v, out_i))
    return out_v, out_i
