"""Shared in-kernel primitives for the Pallas TPU kernels.

`topk_merge` is the streaming-selection building block used by knn_topk
and fused_rank: merge a fresh (B, T) score tile into a running (B, k)
top-k buffer held in VMEM scratch, flash-attention-style. k passes of
(max + first-argmax + mask) over the concatenated (B, k+T) tile; every
op is a lane reduction or elementwise — no sorts, no gathers, TPU-lowerable.

Ties break toward the candidate that comes FIRST in the concatenated
order. Because the running buffer (earlier tiles) precedes the fresh tile
and within a tile iota order is ascending, global tie-breaking is 'lowest
index wins' — matching the jnp stable-argsort oracle in ref.py.

The merge optionally carries PAYLOAD columns: a pytree of arrays whose
last axis is the candidate axis (running (B, ..., k), tile (B, ..., T)).
Each selected winner drags its payload slots along, so a kernel can keep
per-candidate side data resident in VMEM across the whole streaming
sweep and never re-gather it from HBM afterwards. Three kernels build
on it:
  * fused_rank.rank_audited_pallas — raw utilities + K attribute
    columns ride along so the audit runs at the flush step;
  * fused_rank.linear_rank_audited_pallas — same sweep, with the
    affine λ-predictor folded into the prologue (λ̂ itself lives in a
    VMEM scratch, not a payload — it is per-row, not per-candidate);
  * knn_topk.knn_lambda_pallas — each neighbour's λ row + |x_n|^2 ride
    along so the inverse-distance weighting runs at the flush step and
    the kernel emits λ̂ directly, no d2/idx pairs in HBM;
  * knn_topk.knn_rank_audited_pallas — BOTH of the above in one grid:
    the db-sweep merge feeds a λ̂ flush into VMEM scratch, then the
    rank-sweep merge audits at the final flush — the whole KNN online
    stage in one kernel launch.
It is also the in-VMEM twin of the payload ride-along in
repro.distributed.topk.distributed_top_k.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = float(-1e30)

# ---------------------------------------------------------------------------
# Shared tiling knobs. Every kernel wrapper in ops.py defaults to these,
# so a TPU-generation retune is a one-file edit and the benchmarks'
# traffic models can import the exact geometry the kernels run with.
# ---------------------------------------------------------------------------

LANE = 128      # TPU lane width: minor-dim alignment boundary
SUBLANE = 8     # f32 sublane count: batch-row alignment boundary

TILE_B = SUBLANE   # batch rows resident per grid step (rank + KNN sweeps)
TILE_M = 512       # candidate (m1) columns per rank-sweep tile
DB_SLAB = 512      # train-db rows per VMEM slab in the KNN db sweeps


def first_argmax(x: jnp.ndarray) -> jnp.ndarray:
    """(B, N) -> (B,) index of the first maximum along the last axis,
    via the iota-min trick (jnp.argmax's tie semantics, TPU-friendly)."""
    m = jnp.max(x, axis=-1, keepdims=True)
    iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, dimension=x.ndim - 1)
    masked = jnp.where(x >= m, iota, jnp.iinfo(jnp.int32).max)
    return jnp.min(masked, axis=-1)


def _select_one(p: jnp.ndarray, onehot: jnp.ndarray) -> jnp.ndarray:
    """Extract the single onehot-marked candidate column of a payload.

    p (B, ..., C), onehot (B, C) with exactly one True per row ->
    (B, ...). Sum-of-masked is exact (x + 0.0 == x in IEEE), works for
    any signed payload, and is a pure lane reduction."""
    oh = onehot.reshape(onehot.shape[:1] + (1,) * (p.ndim - 2)
                        + onehot.shape[-1:])
    return jnp.sum(jnp.where(oh, p, jnp.zeros_like(p)), axis=-1)


def _write_col(out: jnp.ndarray, val: jnp.ndarray, col: jnp.ndarray):
    """Write val (B, ...) into the col-marked last-axis slot of
    out (B, ..., k); col is a (B, k) onehot column mask."""
    cb = col.reshape(col.shape[:1] + (1,) * (out.ndim - 2) + col.shape[-1:])
    return jnp.where(cb, val[..., None], out)


def topk_merge(
    run_vals: jnp.ndarray,   # (B, k) running top values (descending-ish)
    run_idx: jnp.ndarray,    # (B, k) their global indices
    tile_vals: jnp.ndarray,  # (B, T) fresh candidate values
    tile_idx: jnp.ndarray,   # (B, T) their global indices
    k: int,
    run_payload=None,        # pytree of (B, ..., k) per-slot side data
    tile_payload=None,       # matching pytree of (B, ..., T)
):
    """Return new (run_vals, run_idx[, run_payload]): top-k of the union,
    descending, ties to lower concat position (running buffer first).
    When payloads ride along, each winner's payload slots are selected by
    the same onehot that selects its value — (vals, idx, payload)."""
    B = run_vals.shape[0]
    has_payload = run_payload is not None
    cand_v = jnp.concatenate([run_vals, tile_vals], axis=-1)   # (B, k+T)
    cand_i = jnp.concatenate([run_idx, tile_idx], axis=-1)
    cand_p = None
    if has_payload:
        cand_p = jax.tree.map(
            lambda rp, tp: jnp.concatenate([rp, tp], axis=-1),
            run_payload, tile_payload)
    iota = jax.lax.broadcasted_iota(jnp.int32, cand_v.shape, dimension=1)

    out_v = jnp.full((B, k), NEG_INF, cand_v.dtype)
    out_i = jnp.zeros((B, k), jnp.int32)
    out_p = (jax.tree.map(
        lambda p: jnp.zeros(p.shape[:-1] + (k,), p.dtype), cand_p)
        if has_payload else None)

    def body(j, carry):
        cand_v, out_v, out_i, out_p = carry
        sel = first_argmax(cand_v)                             # (B,)
        onehot = iota == sel[:, None]                          # (B, k+T)
        v = jnp.max(jnp.where(onehot, cand_v, NEG_INF), axis=-1)
        gi = jnp.max(jnp.where(onehot, cand_i, -1), axis=-1)
        # write column j of the output buffers
        col = jax.lax.broadcasted_iota(jnp.int32, (B, k), dimension=1) == j
        out_v = jnp.where(col, v[:, None], out_v)
        out_i = jnp.where(col, gi[:, None], out_i)
        if has_payload:
            out_p = jax.tree.map(
                lambda op, cp: _write_col(op, _select_one(cp, onehot), col),
                out_p, cand_p)
        cand_v = jnp.where(onehot, NEG_INF, cand_v)
        return cand_v, out_v, out_i, out_p

    _, out_v, out_i, out_p = jax.lax.fori_loop(
        0, k, body, (cand_v, out_v, out_i, out_p))
    if has_payload:
        return out_v, out_i, out_p
    return out_v, out_i
