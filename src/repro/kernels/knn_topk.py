"""knn_topk — tiled squared-L2 distances + streaming top-k (Pallas TPU).

The paper's lambda predictor is an exact KNN regressor; its serving cost
is one (batch x train-users) distance computation + top-k. This kernel
streams the train-user database HBM->VMEM exactly once per query tile:

  d2[b, n] = |q_b|^2 - 2 q_b . x_n + |x_n|^2

The cross term is an MXU matmul per (query tile, db tile); |x_n|^2 is
recomputed per tile (D multiplies — negligible vs the matmul); the
running top-k (negated distances) lives in VMEM scratch across the db
sweep. VMEM working set per step:
  q (Bq, D) + db (Tn, D) + d2 (Bq, Tn) + 2 (Bq, k) buffers.

Grid: (query_tiles, db_tiles), db minor so scratch persists. Alignment:
D and Tn multiples of 128 for the MXU; Bq multiple of 8.

`knn_lambda_pallas` extends the same sweep into the paper's full
predictor: the merge carries each neighbour's λ row (K values) and its
|x_n|^2 as VMEM payload columns (common.topk_merge ride-along), and the
flush step computes the inverse-distance weights — exact-match override
included — and emits λ̂ (B, K) directly. The (B, k) d2/idx pairs that
XLA would otherwise write out, re-read, and re-gather against the λ
database never exist in HBM; neither does the (B, n_train) distance
matrix the brute-force XLA path materializes. This is the KNN half of
the single-sweep predict+rank+audit dispatcher
(repro.kernels.ops.predict_rank_audited).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.predictors import _idw_lambda
from repro.kernels.common import NEG_INF, topk_merge


def _knn_kernel(
    q_ref, db_ref,                 # inputs
    d2_ref, idx_ref,               # outputs
    run_v, run_i,                  # scratch
    *, k: int, tile_n: int,
):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        run_v[...] = jnp.full_like(run_v, NEG_INF)
        run_i[...] = jnp.zeros_like(run_i)

    q = q_ref[...].astype(jnp.float32)                       # (Bq, D)
    db = db_ref[...].astype(jnp.float32)                     # (Tn, D)
    q2 = jnp.sum(q * q, axis=-1, keepdims=True)              # (Bq, 1)
    db2 = jnp.sum(db * db, axis=-1)                          # (Tn,)
    cross = jnp.dot(q, db.T, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(q2 - 2.0 * cross + db2[None, :], 0.0)   # (Bq, Tn)

    base = t * tile_n
    gidx = base + jax.lax.broadcasted_iota(jnp.int32, d2.shape, dimension=1)
    new_v, new_i = topk_merge(run_v[...], run_i[...], -d2, gidx, k)
    run_v[...] = new_v
    run_i[...] = new_i

    @pl.when(t == pl.num_programs(1) - 1)
    def _flush():
        d2_ref[...] = -run_v[...]
        idx_ref[...] = run_i[...]


@functools.partial(
    jax.jit, static_argnames=("k", "tile_q", "tile_n", "interpret"))
def knn_topk_pallas(
    xq: jax.Array,    # (B, D) queries
    xdb: jax.Array,   # (N, D) database
    *,
    k: int = 10,
    tile_q: int = 8,
    tile_n: int = 512,
    interpret: bool = False,
):
    """Returns (d2 (B, k) ascending, idx (B, k) — ties to lower index)."""
    B, D = xq.shape
    N = xdb.shape[0]
    if B % tile_q or N % tile_n:
        raise ValueError(f"(B={B}, N={N}) must tile by ({tile_q}, {tile_n})")

    grid = (B // tile_q, N // tile_n)
    kernel = functools.partial(_knn_kernel, k=k, tile_n=tile_n)
    d2, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, D), lambda b, t: (b, 0)),
            pl.BlockSpec((tile_n, D), lambda b, t: (t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_q, k), lambda b, t: (b, 0)),
            pl.BlockSpec((tile_q, k), lambda b, t: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile_q, k), jnp.float32),
            pltpu.VMEM((tile_q, k), jnp.int32),
        ],
        interpret=interpret,
    )(xq, xdb)
    return d2, idx


# ---------------------------------------------------------------------------
# knn_lambda: distances + top-k + inverse-distance weighting in one sweep
# ---------------------------------------------------------------------------

def _knn_lambda_kernel(
    q_ref, db_ref, lamdb_ref,      # inputs
    lam_ref,                       # output: lam_hat (Bq, K)
    run_v, run_i, run_lam, run_y2,  # scratch
    *, k: int, tile_n: int, num_k: int,
):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        run_v[...] = jnp.full_like(run_v, NEG_INF)
        run_i[...] = jnp.zeros_like(run_i)
        run_lam[...] = jnp.zeros_like(run_lam)
        run_y2[...] = jnp.zeros_like(run_y2)

    q = q_ref[...].astype(jnp.float32)                       # (Bq, D)
    db = db_ref[...].astype(jnp.float32)                     # (Tn, D)
    lamdb = lamdb_ref[...].astype(jnp.float32)               # (Tn, K)
    bq = q.shape[0]
    q2 = jnp.sum(q * q, axis=-1, keepdims=True)              # (Bq, 1)
    db2 = jnp.sum(db * db, axis=-1)                          # (Tn,)
    cross = jnp.dot(q, db.T, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(q2 - 2.0 * cross + db2[None, :], 0.0)   # (Bq, Tn)

    base = t * tile_n
    gidx = base + jax.lax.broadcasted_iota(jnp.int32, d2.shape, dimension=1)
    # each candidate's payload: its λ row (constraint-major) and |x_n|^2
    tile_lam = jnp.broadcast_to(lamdb.T[None], (bq, num_k, tile_n))
    tile_y2 = jnp.broadcast_to(db2[None, :], (bq, tile_n))
    new_v, new_i, new_p = topk_merge(
        run_v[...], run_i[...], -d2, gidx, k,
        run_payload={"lam": run_lam[...], "y2": run_y2[...]},
        tile_payload={"lam": tile_lam, "y2": tile_y2})
    run_v[...] = new_v
    run_i[...] = new_i
    run_lam[...] = new_p["lam"]
    run_y2[...] = new_p["y2"]

    @pl.when(t == pl.num_programs(1) - 1)
    def _flush():
        # Inverse-distance weighting on the VMEM-resident neighbours:
        # the predictor's own _idw_lambda (one source of truth for the
        # weights, exact-match override, and normalization), applied to
        # payload columns instead of HBM gathers — the payload is
        # constraint-major (Bq, K, k), so transpose to its (b, k, C)
        # neighbour-major convention.
        lam_ref[...] = _idw_lambda(
            -run_v[...], q2, run_y2[...],
            run_lam[...].transpose(0, 2, 1))


@functools.partial(
    jax.jit, static_argnames=("k", "tile_q", "tile_n", "interpret"))
def knn_lambda_pallas(
    xq: jax.Array,      # (B, D) queries
    xdb: jax.Array,     # (N, D) train database
    lam_db: jax.Array,  # (N, K) train shadow prices
    *,
    k: int = 10,
    tile_q: int = 8,
    tile_n: int = 512,
    interpret: bool = False,
):
    """Returns lam_hat (B, K): the inverse-distance-weighted KNN λ
    prediction, with the d2/idx intermediates and the (B, N) distance
    matrix never leaving VMEM. Requires N >= k real database rows (the
    KNN contract) so far-away padding rows can never enter a top-k."""
    B, D = xq.shape
    N, K = lam_db.shape
    if xdb.shape != (N, D):
        raise ValueError(f"xdb {xdb.shape} vs lam_db {lam_db.shape}: "
                         f"row counts must match")
    if B % tile_q or N % tile_n:
        raise ValueError(f"(B={B}, N={N}) must tile by ({tile_q}, {tile_n})")

    grid = (B // tile_q, N // tile_n)
    kernel = functools.partial(_knn_lambda_kernel, k=k, tile_n=tile_n,
                               num_k=K)
    lam = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, D), lambda b, t: (b, 0)),
            pl.BlockSpec((tile_n, D), lambda b, t: (t, 0)),
            pl.BlockSpec((tile_n, K), lambda b, t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((tile_q, K), lambda b, t: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((tile_q, k), jnp.float32),
            pltpu.VMEM((tile_q, k), jnp.int32),
            pltpu.VMEM((tile_q, K, k), jnp.float32),
            pltpu.VMEM((tile_q, k), jnp.float32),
        ],
        interpret=interpret,
    )(xq, xdb, lam_db)
    return lam
