"""knn_topk — tiled squared-L2 distances + streaming top-k (Pallas TPU).

The paper's lambda predictor is an exact KNN regressor; its serving cost
is one (batch x train-users) distance computation + top-k. This kernel
streams the train-user database HBM->VMEM exactly once per query tile:

  d2[b, n] = |q_b|^2 - 2 q_b . x_n + |x_n|^2

The cross term is an MXU matmul per (query tile, db tile); |x_n|^2 is
recomputed per tile (D multiplies — negligible vs the matmul); the
running top-k (negated distances) lives in VMEM scratch across the db
sweep. VMEM working set per step:
  q (Bq, D) + db (Tn, D) + d2 (Bq, Tn) + 2 (Bq, k) buffers.

Grid: (query_tiles, db_tiles), db minor so scratch persists. Alignment:
D and Tn multiples of 128 for the MXU; Bq multiple of 8.

`knn_lambda_pallas` extends the same sweep into the paper's full
predictor: the merge carries each neighbour's λ row (K values) and its
|x_n|^2 as VMEM payload columns (common.topk_merge ride-along), and the
flush step computes the inverse-distance weights — exact-match override
included — and emits λ̂ (B, K) directly. The (B, k) d2/idx pairs that
XLA would otherwise write out, re-read, and re-gather against the λ
database never exist in HBM; neither does the (B, n_train) distance
matrix the brute-force XLA path materializes. Since the single-grid
kernel below landed, this is the predict half of the RETAINED
two-kernel chain (ops.predict_rank_audited(knn_chain=True)) — the
parity oracle and A/B baseline for the fused grid.

`knn_rank_audited_pallas` is the whole KNN online stage as ONE grid:
per batch tile the minor axis first streams the S db slabs (the
knn_lambda sweep, double-buffered by the Pallas pipeline: slab t+1's
HBM->VMEM copy overlaps slab t's distance dot + merge), computes λ̂ at
the slab-sweep flush into a VMEM scratch, and then — still inside the
same program — continues through the M candidate tiles of the
rank+audit sweep (fused_rank's shared merge body reading λ̂ from that
scratch) and emits the complete RankingOutput at the final flush. λ̂
never exists in HBM at all (the (B, K) lam output written at the end is
observability, not a handoff), and the per-micro-batch kernel-launch
count drops from two to one. The db-sweep and rank-sweep bodies are the
SAME functions the two-kernel chain runs (_db_slab_merge /
_idw_lambda_flush here, _merge_scored_tile / _audit_flush in
fused_rank), so the fused program is bitwise-identical to the chain at
matched tile geometry (tests/test_knn_fused.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.predictors import _idw_lambda
from repro.kernels.common import (
    DB_SLAB,
    NEG_INF,
    PAD_Y2,
    QUANT_EXTRA,
    TILE_B,
    TILE_M,
    bottomk_rerank,
    dequant_rows,
    exact_rescore,
    quant_d2_err,
    quant_d2_tile,
    topk_merge,
)
from repro.kernels.fused_rank import (
    MAX_KERNEL_M2,
    _audit_flush,
    _merge_scored_tile,
)


def _knn_kernel(
    q_ref, db_ref,                 # inputs
    d2_ref, idx_ref,               # outputs
    run_v, run_i,                  # scratch
    *, k: int, tile_n: int,
):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        run_v[...] = jnp.full_like(run_v, NEG_INF)
        run_i[...] = jnp.zeros_like(run_i)

    q = q_ref[...].astype(jnp.float32)                       # (Bq, D)
    db = db_ref[...].astype(jnp.float32)                     # (Tn, D)
    q2 = jnp.sum(q * q, axis=-1, keepdims=True)              # (Bq, 1)
    db2 = jnp.sum(db * db, axis=-1)                          # (Tn,)
    cross = jnp.dot(q, db.T, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(q2 - 2.0 * cross + db2[None, :], 0.0)   # (Bq, Tn)

    base = t * tile_n
    gidx = base + jax.lax.broadcasted_iota(jnp.int32, d2.shape, dimension=1)
    new_v, new_i = topk_merge(run_v[...], run_i[...], -d2, gidx, k)
    run_v[...] = new_v
    run_i[...] = new_i

    @pl.when(t == pl.num_programs(1) - 1)
    def _flush():
        d2_ref[...] = -run_v[...]
        idx_ref[...] = run_i[...]


@functools.partial(
    jax.jit, static_argnames=("k", "tile_q", "tile_n", "interpret"))
def knn_topk_pallas(
    xq: jax.Array,    # (B, D) queries
    xdb: jax.Array,   # (N, D) database
    *,
    k: int = 10,
    tile_q: int = TILE_B,
    tile_n: int = DB_SLAB,
    interpret: bool = False,
):
    """Returns (d2 (B, k) ascending, idx (B, k) — ties to lower index)."""
    B, D = xq.shape
    N = xdb.shape[0]
    if B % tile_q or N % tile_n:
        raise ValueError(f"(B={B}, N={N}) must tile by ({tile_q}, {tile_n})")

    grid = (B // tile_q, N // tile_n)
    kernel = functools.partial(_knn_kernel, k=k, tile_n=tile_n)
    d2, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, D), lambda b, t: (b, 0)),
            pl.BlockSpec((tile_n, D), lambda b, t: (t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_q, k), lambda b, t: (b, 0)),
            pl.BlockSpec((tile_q, k), lambda b, t: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile_q, k), jnp.float32),
            pltpu.VMEM((tile_q, k), jnp.int32),
        ],
        interpret=interpret,
    )(xq, xdb)
    return d2, idx


# ---------------------------------------------------------------------------
# knn_lambda: distances + top-k + inverse-distance weighting in one sweep
# ---------------------------------------------------------------------------

def _db_slab_merge(
    slab, q_ref, db_ref, lamdb_ref, run_v, run_i, run_lam, run_y2,
    *, k: int, tile_n: int, num_k: int,
):
    """One db-slab step of the KNN λ sweep: squared-L2 distances for
    this slab, merged into the running top-k with each neighbour's λ row
    and |x_n|^2 riding along as payload. Shared verbatim by
    knn_lambda_pallas and the single-grid knn_rank_audited_pallas so
    their neighbour selections (and therefore λ̂) can never drift."""
    q = q_ref[...].astype(jnp.float32)                       # (Bq, D)
    db = db_ref[...].astype(jnp.float32)                     # (Tn, D)
    lamdb = lamdb_ref[...].astype(jnp.float32)               # (Tn, K)
    bq = q.shape[0]
    q2 = jnp.sum(q * q, axis=-1, keepdims=True)              # (Bq, 1)
    db2 = jnp.sum(db * db, axis=-1)                          # (Tn,)
    cross = jnp.dot(q, db.T, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(q2 - 2.0 * cross + db2[None, :], 0.0)   # (Bq, Tn)

    base = slab * tile_n
    gidx = base + jax.lax.broadcasted_iota(jnp.int32, d2.shape, dimension=1)
    # each candidate's payload: its λ row (constraint-major) and |x_n|^2
    tile_lam = jnp.broadcast_to(lamdb.T[None], (bq, num_k, tile_n))
    tile_y2 = jnp.broadcast_to(db2[None, :], (bq, tile_n))
    new_v, new_i, new_p = topk_merge(
        run_v[...], run_i[...], -d2, gidx, k,
        run_payload={"lam": run_lam[...], "y2": run_y2[...]},
        tile_payload={"lam": tile_lam, "y2": tile_y2})
    run_v[...] = new_v
    run_i[...] = new_i
    run_lam[...] = new_p["lam"]
    run_y2[...] = new_p["y2"]


def _idw_lambda_flush(q_ref, run_v, run_lam, run_y2):
    """Inverse-distance weighting on the VMEM-resident neighbours: the
    predictor's own _idw_lambda (one source of truth for the weights,
    exact-match override, and normalization), applied to payload columns
    instead of HBM gathers — the payload is constraint-major (Bq, K, k),
    so transpose to its (b, k, C) neighbour-major convention."""
    q = q_ref[...].astype(jnp.float32)
    q2 = jnp.sum(q * q, axis=-1, keepdims=True)              # (Bq, 1)
    return _idw_lambda(
        -run_v[...], q2, run_y2[...], run_lam[...].transpose(0, 2, 1))


def _knn_lambda_kernel(
    q_ref, db_ref, lamdb_ref,      # inputs
    lam_ref,                       # output: lam_hat (Bq, K)
    run_v, run_i, run_lam, run_y2,  # scratch
    *, k: int, tile_n: int, num_k: int,
):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        run_v[...] = jnp.full_like(run_v, NEG_INF)
        run_i[...] = jnp.zeros_like(run_i)
        run_lam[...] = jnp.zeros_like(run_lam)
        run_y2[...] = jnp.zeros_like(run_y2)

    _db_slab_merge(t, q_ref, db_ref, lamdb_ref,
                   run_v, run_i, run_lam, run_y2,
                   k=k, tile_n=tile_n, num_k=num_k)

    @pl.when(t == pl.num_programs(1) - 1)
    def _flush():
        lam_ref[...] = _idw_lambda_flush(q_ref, run_v, run_lam, run_y2)


@functools.partial(
    jax.jit, static_argnames=("k", "tile_q", "tile_n", "interpret"))
def knn_lambda_pallas(
    xq: jax.Array,      # (B, D) queries
    xdb: jax.Array,     # (N, D) train database
    lam_db: jax.Array,  # (N, K) train shadow prices
    *,
    k: int = 10,
    tile_q: int = TILE_B,
    tile_n: int = DB_SLAB,
    interpret: bool = False,
):
    """Returns lam_hat (B, K): the inverse-distance-weighted KNN λ
    prediction, with the d2/idx intermediates and the (B, N) distance
    matrix never leaving VMEM. Requires N >= k real database rows (the
    KNN contract) so far-away padding rows can never enter a top-k."""
    B, D = xq.shape
    N, K = lam_db.shape
    if xdb.shape != (N, D):
        raise ValueError(f"xdb {xdb.shape} vs lam_db {lam_db.shape}: "
                         f"row counts must match")
    if B % tile_q or N % tile_n:
        raise ValueError(f"(B={B}, N={N}) must tile by ({tile_q}, {tile_n})")

    grid = (B // tile_q, N // tile_n)
    kernel = functools.partial(_knn_lambda_kernel, k=k, tile_n=tile_n,
                               num_k=K)
    lam = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, D), lambda b, t: (b, 0)),
            pl.BlockSpec((tile_n, D), lambda b, t: (t, 0)),
            pl.BlockSpec((tile_n, K), lambda b, t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((tile_q, K), lambda b, t: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((tile_q, k), jnp.float32),
            pltpu.VMEM((tile_q, k), jnp.int32),
            pltpu.VMEM((tile_q, K, k), jnp.float32),
            pltpu.VMEM((tile_q, k), jnp.float32),
        ],
        interpret=interpret,
    )(xq, xdb, lam_db)
    return lam


# ---------------------------------------------------------------------------
# Quantized db sweep: low-precision slab distances + exact survivor re-score
# ---------------------------------------------------------------------------

def _db_slab_merge_quant(
    slab, q_ref, dbq_ref, scale_ref, y2q_ref, lamdb_ref,
    run_v, run_i, run_lam, run_y2, run_xr,
    *, k_keep: int, tile_n: int, num_k: int, mode: str,
):
    """One QUANTIZED db-slab step: slab distances via the low-precision
    cross term (common.quant_d2_tile — int8 integer dot or bf16 dequant
    dot), merged into a running top-k_keep with each survivor's λ row,
    exact |x̃|^2, and DEQUANTIZED f32 row riding along as payload, so the
    flush can re-score survivors exactly without any HBM gather. The
    survivor buffer over-retains (k_keep = k + QUANT_EXTRA) so
    quantization-induced rank inversions near the k-th place are
    repaired by the exact re-score instead of lost."""
    q = q_ref[...].astype(jnp.float32)                       # (Bq, D)
    dbq = dbq_ref[...]                                       # (Tn, D) stored
    scale = scale_ref[0, 0]                                  # slab scale
    lamdb = lamdb_ref[...].astype(jnp.float32)               # (Tn, K)
    y2 = y2q_ref[...].astype(jnp.float32)[:, 0]              # (Tn,) exact |x̃|²
    bq, d_dim = q.shape
    y2_row = jnp.broadcast_to(y2[None, :], (bq, tile_n))
    d2q = quant_d2_tile(q, dbq, scale, y2_row, mode=mode)    # (Bq, Tn)

    base = slab * tile_n
    gidx = base + jax.lax.broadcasted_iota(jnp.int32, d2q.shape, dimension=1)
    xt = dequant_rows(dbq, scale)                            # (Tn, D) f32
    tile_lam = jnp.broadcast_to(lamdb.T[None], (bq, num_k, tile_n))
    tile_y2 = y2_row
    tile_xr = jnp.broadcast_to(xt.T[None], (bq, d_dim, tile_n))
    new_v, new_i, new_p = topk_merge(
        run_v[...], run_i[...], -d2q, gidx, k_keep,
        run_payload={"lam": run_lam[...], "y2": run_y2[...],
                     "xr": run_xr[...]},
        tile_payload={"lam": tile_lam, "y2": tile_y2, "xr": tile_xr})
    run_v[...] = new_v
    run_i[...] = new_i
    run_lam[...] = new_p["lam"]
    run_y2[...] = new_p["y2"]
    run_xr[...] = new_p["xr"]


def _quant_init(run_v, run_i, run_lam, run_y2, run_xr):
    """Quantized-sweep scratch init. run_y2 starts at PAD_Y2 (not 0) so
    never-filled survivor slots re-score to ~1e30 and cannot shadow a
    real neighbour in the exact re-rank."""
    run_v[...] = jnp.full_like(run_v, NEG_INF)
    run_i[...] = jnp.zeros_like(run_i)
    run_lam[...] = jnp.zeros_like(run_lam)
    run_y2[...] = jnp.full_like(run_y2, PAD_Y2)
    run_xr[...] = jnp.zeros_like(run_xr)


def _quant_lambda_flush(
    q_ref, run_v, run_i, run_lam, run_y2, run_xr,
    *, k: int, mode: str,
):
    """Flush of the quantized sweep: exact f32 re-score of the survivor
    set, exact re-rank to the final k (ties to lower global index — the
    f32 oracle's rule), then the shared inverse-distance weighting on
    the re-ranked neighbours. Returns (lam_hat (Bq, K), guard (Bq, 1)
    i32). guard flags rows whose quantized k/(k+1) distance gap is
    within the two boundary candidates' EXACT quantization errors
    (common.quant_d2_err on the VMEM-resident survivor rows): for those
    rows the quantized ORDER was ambiguous and only the exact re-score
    (always applied, branchless) pins the selection; the flag is
    observability for the fallback rate, not a branch."""
    q = q_ref[...].astype(jnp.float32)
    q2 = jnp.sum(q * q, axis=-1, keepdims=True)              # (Bq, 1)
    d2q = -run_v[...]                                        # (Bq, k_keep) asc
    gap = d2q[:, k:k + 1] - d2q[:, k - 1:k]                  # (Bq, 1)
    errs = quant_d2_err(q, run_xr[...], mode=mode)           # (Bq, k_keep)
    bound = errs[:, k - 1:k] + errs[:, k:k + 1]              # (Bq, 1)
    guard = (gap <= bound).astype(jnp.int32)                 # (Bq, 1)

    d2x = exact_rescore(q, run_xr[...], run_y2[...])         # (Bq, k_keep)
    d2_top, _, p = bottomk_rerank(
        d2x, run_i[...], k,
        payload={"lam": run_lam[...], "y2": run_y2[...]})
    lam_hat = _idw_lambda(d2_top, q2, p["y2"], p["lam"].transpose(0, 2, 1))
    return lam_hat, guard


def _knn_lambda_quant_kernel(
    q_ref, dbq_ref, scale_ref, y2q_ref, lamdb_ref,             # inputs
    lam_ref, guard_ref,                                        # outputs
    run_v, run_i, run_lam, run_y2, run_xr,                     # scratch
    *, k: int, k_keep: int, tile_n: int, num_k: int, mode: str,
):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        _quant_init(run_v, run_i, run_lam, run_y2, run_xr)

    _db_slab_merge_quant(t, q_ref, dbq_ref, scale_ref, y2q_ref, lamdb_ref,
                         run_v, run_i, run_lam, run_y2, run_xr,
                         k_keep=k_keep, tile_n=tile_n, num_k=num_k,
                         mode=mode)

    @pl.when(t == pl.num_programs(1) - 1)
    def _flush():
        lam, guard = _quant_lambda_flush(
            q_ref, run_v, run_i, run_lam, run_y2, run_xr,
            k=k, mode=mode)
        lam_ref[...] = lam
        guard_ref[...] = guard


@functools.partial(
    jax.jit,
    static_argnames=("k", "k_extra", "mode", "tile_q", "tile_n", "interpret"))
def knn_lambda_quant_pallas(
    xq: jax.Array,       # (B, D) queries, f32
    xdb_q: jax.Array,    # (N, D) quantized db (int8 or bf16 storage)
    q_scale: jax.Array,  # (n_slabs, 1) per-slab dequant scales
    y2_q: jax.Array,     # (N, 1) exact |x̃|^2 (PAD_Y2 on padding rows)
    lam_db: jax.Array,   # (N, K) train shadow prices
    *,
    k: int = 10,
    k_extra: int = QUANT_EXTRA,
    mode: str = "int8",
    tile_q: int = TILE_B,
    tile_n: int = DB_SLAB,
    interpret: bool = False,
):
    """Quantized-sweep twin of knn_lambda_pallas. Returns (lam_hat
    (B, K) f32, guard (B, 1) i32). The slab distance sweep runs at low
    precision on the packed db; the top-(k + k_extra) survivor set is
    re-scored exactly in f32 at the flush and re-ranked to the final k,
    so lam_hat is exact-on-x̃ (x̃ = dequantized rows — see
    kernels/common.py). The pack (predictors.pack_knn_db) must use the
    serving tile_n as its slab size: q_scale rows ARE the slab blocks."""
    B, D = xq.shape
    N, K = lam_db.shape
    if xdb_q.shape != (N, D):
        raise ValueError(f"xdb_q {xdb_q.shape} vs lam_db {lam_db.shape}: "
                         f"row counts must match")
    if B % tile_q or N % tile_n:
        raise ValueError(f"(B={B}, N={N}) must tile by ({tile_q}, {tile_n})")
    n_slabs = N // tile_n
    if q_scale.shape != (n_slabs, 1):
        raise ValueError(f"q_scale {q_scale.shape} must be ({n_slabs}, 1): "
                         f"pack slab size must equal serving tile_n={tile_n}")
    k_keep = k + k_extra

    grid = (B // tile_q, n_slabs)
    kernel = functools.partial(
        _knn_lambda_quant_kernel, k=k, k_keep=k_keep, tile_n=tile_n,
        num_k=K, mode=mode)
    lam, guard = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, D), lambda b, t: (b, 0)),
            pl.BlockSpec((tile_n, D), lambda b, t: (t, 0)),
            pl.BlockSpec((1, 1), lambda b, t: (t, 0)),
            pl.BlockSpec((tile_n, 1), lambda b, t: (t, 0)),
            pl.BlockSpec((tile_n, K), lambda b, t: (t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_q, K), lambda b, t: (b, 0)),
            pl.BlockSpec((tile_q, 1), lambda b, t: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, K), jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile_q, k_keep), jnp.float32),
            pltpu.VMEM((tile_q, k_keep), jnp.int32),
            pltpu.VMEM((tile_q, K, k_keep), jnp.float32),
            pltpu.VMEM((tile_q, k_keep), jnp.float32),
            pltpu.VMEM((tile_q, D, k_keep), jnp.float32),
        ],
        interpret=interpret,
    )(xq, xdb_q, q_scale, y2_q, lam_db)
    return lam, guard


# ---------------------------------------------------------------------------
# knn_rank_audited: predict + rank + audit as ONE grid (the KNN online stage)
# ---------------------------------------------------------------------------

def _knn_rank_audited_kernel(
    q_ref, db_ref, lamdb_ref, b_ref, gamma_ref, u_ref, a_ref,   # inputs
    vals_ref, idx_ref, util_ref, expo_ref, comp_ref, lam_ref,   # outputs
    kv, ki, klam, ky2, lam_scr, rv, ri, ru, ra,                 # scratch
    *, k: int, tile_n: int, n_slabs: int,
    eps: float, m2: int, tile_m: int, num_k: int, tol: float,
):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        kv[...] = jnp.full_like(kv, NEG_INF)
        ki[...] = jnp.zeros_like(ki)
        klam[...] = jnp.zeros_like(klam)
        ky2[...] = jnp.zeros_like(ky2)
        rv[...] = jnp.full_like(rv, NEG_INF)
        ri[...] = jnp.zeros_like(ri)
        ru[...] = jnp.zeros_like(ru)
        ra[...] = jnp.zeros_like(ra)

    # Phase 1 — db slab sweep (steps 0..n_slabs-1): knn_lambda's merge,
    # verbatim. The Pallas pipeline double-buffers the slab blocks, so
    # slab t+1's HBM->VMEM copy overlaps slab t's distance dot + merge.
    @pl.when(t < n_slabs)
    def _db_step():
        _db_slab_merge(t, q_ref, db_ref, lamdb_ref, kv, ki, klam, ky2,
                       k=k, tile_n=tile_n, num_k=num_k)

    # λ̂ flush: the slab sweep ends and the rank sweep begins inside the
    # same program step — λ̂ goes VMEM scratch -> VMEM scratch, no HBM.
    @pl.when(t == n_slabs - 1)
    def _lam_flush():
        lam_scr[...] = _idw_lambda_flush(q_ref, kv, klam, ky2)

    # Phase 2 — candidate tile sweep (steps n_slabs..n_slabs+M-1):
    # rank_audited's merge, verbatim, reading λ̂ from scratch.
    @pl.when(t >= n_slabs)
    def _rank_step():
        _merge_scored_tile(t - n_slabs, lam_scr[...], u_ref, a_ref,
                           rv, ri, ru, ra,
                           eps=eps, m2=m2, tile_m=tile_m, num_k=num_k)

    @pl.when(t == pl.num_programs(1) - 1)
    def _final_flush():
        _audit_flush(gamma_ref, b_ref, vals_ref, idx_ref, util_ref,
                     expo_ref, comp_ref, rv, ri, ru, ra, tol=tol)
        lam_ref[...] = lam_scr[...]


@functools.partial(
    jax.jit,
    static_argnames=("k", "m2", "eps", "tol", "tile_b", "tile_n", "tile_m",
                     "interpret"))
def knn_rank_audited_pallas(
    xq: jax.Array,       # (B, D) query covariates
    xdb: jax.Array,      # (N, D) train database
    lam_db: jax.Array,   # (N, K) train shadow prices (K = constraint tier)
    u: jax.Array,        # (B, m1)
    a: jax.Array,        # (B, K, m1)
    b: jax.Array,        # (B, K)
    gamma: jax.Array,    # (B, m2)
    *,
    k: int = 10,
    m2: int,
    eps: float = 1e-4,
    tol: float = 1e-6,
    tile_b: int = TILE_B,
    tile_n: int = DB_SLAB,
    tile_m: int = TILE_M,
    interpret: bool = False,
):
    """The paper's whole KNN online stage — λ̂ prediction, adjusted-score
    ranking, and the audit — as ONE pallas_call with grid
    (B/tile_b, n_slabs + m1_tiles). Returns (vals (B, m2) f32 desc,
    idx (B, m2) i32, utility (B, 1) f32, exposure (B, K) f32,
    compliant (B, 1) i32, lam (B, K) f32).

    Per batch tile the minor axis streams the db slabs first (running
    top-k + λ-row/|x_n|^2 payload in VMEM scratch), flushes λ̂ into a
    VMEM scratch at the last slab, then keeps going straight into the
    candidate tiles of the rank+audit sweep. The block index maps clamp:
    during the db phase the u/a maps sit on candidate tile 0 and during
    the rank phase the db maps sit on the last slab, so no block is
    refetched and the only HBM traffic is the compulsory stream of each
    input plus the tiny outputs — λ̂ (B, K) included purely as
    observability, never read back. Requires N >= k real database rows
    (the KNN contract) so far-away padding rows can never enter a top-k.
    """
    B, D = xq.shape
    N, K = lam_db.shape
    m1 = u.shape[1]
    if xdb.shape != (N, D):
        raise ValueError(f"xdb {xdb.shape} vs lam_db {lam_db.shape}: "
                         f"row counts must match")
    if a.shape != (B, K, m1):
        raise ValueError(f"a {a.shape} must be ({B}, {K}, {m1})")
    if m2 > MAX_KERNEL_M2:
        raise ValueError(f"kernel path supports m2 <= {MAX_KERNEL_M2}; "
                         f"use repro.kernels.ops.predict_rank_audited "
                         f"(XLA fallback)")
    if B % tile_b or N % tile_n or m1 % tile_m:
        raise ValueError(f"(B={B}, N={N}, m1={m1}) must tile by "
                         f"({tile_b}, {tile_n}, {tile_m})")

    n_slabs = N // tile_n
    grid = (B // tile_b, n_slabs + m1 // tile_m)
    kernel = functools.partial(
        _knn_rank_audited_kernel, k=k, tile_n=tile_n, n_slabs=n_slabs,
        eps=eps, m2=m2, tile_m=tile_m, num_k=K, tol=tol)
    # db blocks advance then park on the last slab; u/a blocks park on
    # candidate tile 0 until the rank phase starts. Pallas skips the
    # copy whenever a block index repeats, so parking is free.
    db_map = lambda bi, t: (jnp.minimum(t, n_slabs - 1), 0)
    cand = lambda t: jnp.maximum(t - n_slabs, 0)
    vals, idx, util, expo, comp, lam = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, D), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_n, D), db_map),
            pl.BlockSpec((tile_n, K), db_map),
            pl.BlockSpec((tile_b, K), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, m2), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, tile_m), lambda bi, t: (bi, cand(t))),
            pl.BlockSpec((tile_b, K, tile_m),
                         lambda bi, t: (bi, 0, cand(t))),
        ],
        out_specs=[
            pl.BlockSpec((tile_b, m2), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, m2), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, 1), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, K), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, 1), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, K), lambda bi, t: (bi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, m2), jnp.float32),
            jax.ShapeDtypeStruct((B, m2), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, K), jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, K), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile_b, k), jnp.float32),      # kv: running -d2
            pltpu.VMEM((tile_b, k), jnp.int32),        # ki: neighbour idx
            pltpu.VMEM((tile_b, K, k), jnp.float32),   # klam: λ payload
            pltpu.VMEM((tile_b, k), jnp.float32),      # ky2: |x_n|^2 payload
            pltpu.VMEM((tile_b, K), jnp.float32),      # lam_scr: λ̂
            pltpu.VMEM((tile_b, m2), jnp.float32),     # rv: running scores
            pltpu.VMEM((tile_b, m2), jnp.int32),       # ri: running items
            pltpu.VMEM((tile_b, m2), jnp.float32),     # ru: u payload
            pltpu.VMEM((tile_b, K, m2), jnp.float32),  # ra: a payload
        ],
        interpret=interpret,
    )(xq, xdb, lam_db, b, gamma, u, a)
    return vals, idx, util, expo, comp, lam


def _knn_rank_audited_quant_kernel(
    q_ref, dbq_ref, scale_ref, y2q_ref, lamdb_ref,              # inputs
    b_ref, gamma_ref, u_ref, a_ref,
    vals_ref, idx_ref, util_ref, expo_ref, comp_ref, lam_ref,   # outputs
    guard_ref,
    kv, ki, klam, ky2, kxr, lam_scr, rv, ri, ru, ra,            # scratch
    *, k: int, k_keep: int, tile_n: int, n_slabs: int,
    eps: float, m2: int, tile_m: int, num_k: int, tol: float, mode: str,
):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        _quant_init(kv, ki, klam, ky2, kxr)
        rv[...] = jnp.full_like(rv, NEG_INF)
        ri[...] = jnp.zeros_like(ri)
        ru[...] = jnp.zeros_like(ru)
        ra[...] = jnp.zeros_like(ra)

    # Phase 1 — QUANTIZED db slab sweep (steps 0..n_slabs-1).
    @pl.when(t < n_slabs)
    def _db_step():
        _db_slab_merge_quant(t, q_ref, dbq_ref, scale_ref, y2q_ref,
                             lamdb_ref, kv, ki, klam, ky2, kxr,
                             k_keep=k_keep, tile_n=tile_n, num_k=num_k,
                             mode=mode)

    # λ̂ flush: exact survivor re-score + re-rank + IDW, VMEM -> VMEM.
    @pl.when(t == n_slabs - 1)
    def _lam_flush():
        lam, guard = _quant_lambda_flush(
            q_ref, kv, ki, klam, ky2, kxr, k=k, mode=mode)
        lam_scr[...] = lam
        guard_ref[...] = guard

    # Phase 2 — candidate tile sweep: the f32 kernel's bodies, verbatim.
    @pl.when(t >= n_slabs)
    def _rank_step():
        _merge_scored_tile(t - n_slabs, lam_scr[...], u_ref, a_ref,
                           rv, ri, ru, ra,
                           eps=eps, m2=m2, tile_m=tile_m, num_k=num_k)

    @pl.when(t == pl.num_programs(1) - 1)
    def _final_flush():
        _audit_flush(gamma_ref, b_ref, vals_ref, idx_ref, util_ref,
                     expo_ref, comp_ref, rv, ri, ru, ra, tol=tol)
        lam_ref[...] = lam_scr[...]


@functools.partial(
    jax.jit,
    static_argnames=("k", "k_extra", "mode", "m2", "eps", "tol",
                     "tile_b", "tile_n", "tile_m", "interpret"))
def knn_rank_audited_quant_pallas(
    xq: jax.Array,       # (B, D) query covariates, f32
    xdb_q: jax.Array,    # (N, D) quantized db (int8 or bf16 storage)
    q_scale: jax.Array,  # (n_slabs, 1) per-slab dequant scales
    y2_q: jax.Array,     # (N, 1) exact |x̃|^2 (PAD_Y2 on padding rows)
    lam_db: jax.Array,   # (N, K) train shadow prices
    u: jax.Array,        # (B, m1)
    a: jax.Array,        # (B, K, m1)
    b: jax.Array,        # (B, K)
    gamma: jax.Array,    # (B, m2)
    *,
    k: int = 10,
    k_extra: int = QUANT_EXTRA,
    mode: str = "int8",
    m2: int,
    eps: float = 1e-4,
    tol: float = 1e-6,
    tile_b: int = TILE_B,
    tile_n: int = DB_SLAB,
    tile_m: int = TILE_M,
    interpret: bool = False,
):
    """Quantized-sweep twin of knn_rank_audited_pallas: still ONE
    pallas_call for the whole KNN online stage, but the db slab sweep
    streams the int8/bf16 packed db (4x / 2x fewer HBM bytes than f32)
    and runs the distance dot at low precision; the survivor set is
    re-scored exactly in f32 at the λ̂ flush. Returns the f32 kernel's
    six outputs plus guard (B, 1) i32 — the margin-guard fallback flag
    per row (see _quant_lambda_flush). The rank+audit phase is the f32
    kernel's code verbatim, so with a lossless pack (dequant(pack(X))
    == X) the full RankingOutput is bitwise-identical to the f32 path."""
    B, D = xq.shape
    N, K = lam_db.shape
    m1 = u.shape[1]
    if xdb_q.shape != (N, D):
        raise ValueError(f"xdb_q {xdb_q.shape} vs lam_db {lam_db.shape}: "
                         f"row counts must match")
    if a.shape != (B, K, m1):
        raise ValueError(f"a {a.shape} must be ({B}, {K}, {m1})")
    if m2 > MAX_KERNEL_M2:
        raise ValueError(f"kernel path supports m2 <= {MAX_KERNEL_M2}; "
                         f"use repro.kernels.ops.predict_rank_audited "
                         f"(XLA fallback)")
    if B % tile_b or N % tile_n or m1 % tile_m:
        raise ValueError(f"(B={B}, N={N}, m1={m1}) must tile by "
                         f"({tile_b}, {tile_n}, {tile_m})")
    n_slabs = N // tile_n
    if q_scale.shape != (n_slabs, 1):
        raise ValueError(f"q_scale {q_scale.shape} must be ({n_slabs}, 1): "
                         f"pack slab size must equal serving tile_n={tile_n}")
    k_keep = k + k_extra

    grid = (B // tile_b, n_slabs + m1 // tile_m)
    kernel = functools.partial(
        _knn_rank_audited_quant_kernel, k=k, k_keep=k_keep, tile_n=tile_n,
        n_slabs=n_slabs, eps=eps, m2=m2, tile_m=tile_m, num_k=K, tol=tol,
        mode=mode)
    db_map = lambda bi, t: (jnp.minimum(t, n_slabs - 1), 0)
    cand = lambda t: jnp.maximum(t - n_slabs, 0)
    vals, idx, util, expo, comp, lam, guard = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, D), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_n, D), db_map),
            pl.BlockSpec((1, 1), db_map),
            pl.BlockSpec((tile_n, 1), db_map),
            pl.BlockSpec((tile_n, K), db_map),
            pl.BlockSpec((tile_b, K), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, m2), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, tile_m), lambda bi, t: (bi, cand(t))),
            pl.BlockSpec((tile_b, K, tile_m),
                         lambda bi, t: (bi, 0, cand(t))),
        ],
        out_specs=[
            pl.BlockSpec((tile_b, m2), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, m2), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, 1), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, K), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, 1), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, K), lambda bi, t: (bi, 0)),
            pl.BlockSpec((tile_b, 1), lambda bi, t: (bi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, m2), jnp.float32),
            jax.ShapeDtypeStruct((B, m2), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, K), jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, K), jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile_b, k_keep), jnp.float32),     # kv: running -d2q
            pltpu.VMEM((tile_b, k_keep), jnp.int32),       # ki: neighbour idx
            pltpu.VMEM((tile_b, K, k_keep), jnp.float32),  # klam: λ payload
            pltpu.VMEM((tile_b, k_keep), jnp.float32),     # ky2: |x̃|² payload
            pltpu.VMEM((tile_b, D, k_keep), jnp.float32),  # kxr: dequant rows
            pltpu.VMEM((tile_b, K), jnp.float32),          # lam_scr: λ̂
            pltpu.VMEM((tile_b, m2), jnp.float32),         # rv: running scores
            pltpu.VMEM((tile_b, m2), jnp.int32),           # ri: running items
            pltpu.VMEM((tile_b, m2), jnp.float32),         # ru: u payload
            pltpu.VMEM((tile_b, K, m2), jnp.float32),      # ra: a payload
        ],
        interpret=interpret,
    )(xq, xdb_q, q_scale, y2_q, lam_db, b, gamma, u, a)
    return vals, idx, util, expo, comp, lam, guard
