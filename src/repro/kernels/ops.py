"""jit'd public wrappers for the Pallas kernels, with XLA fallbacks.

Call these, not the kernels directly: they pad awkward shapes to tile
boundaries, dispatch to the XLA reference when the kernel's static
constraints don't hold (huge m2, CPU runtime without interpret), and
return results in the oracle's exact format so callers can swap paths
without code changes.

On this container (CPU) the kernels run with interpret=True; on TPU the
same call sites compile the real kernels (interpret=False default).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.common import (
    DB_SLAB,
    LANE,
    NEG_INF,
    QUANT_EXTRA,
    QUANT_MODES,
    TILE_B,
    TILE_M,
)
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.fused_rank import (
    MAX_KERNEL_M2,
    fused_rank_pallas,
    linear_rank_audited_pallas,
    rank_audited_pallas,
)
from repro.kernels.knn_topk import (
    knn_lambda_pallas,
    knn_lambda_quant_pallas,
    knn_rank_audited_pallas,
    knn_rank_audited_quant_pallas,
    knn_topk_pallas,
)

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: Array, axis: int, mult: int, value):
    n = x.shape[axis]
    rem = (-n) % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# fused_rank
# ---------------------------------------------------------------------------

def fused_rank(
    u: Array, a: Array, lam: Array, *, m2: int, eps: float = 1e-4,
    use_kernel: bool | None = None, interpret: bool | None = None,
    tile_b: int = TILE_B, tile_m: int = TILE_M,
):
    """(top scores (n, m2) desc f32, item idx (n, m2)). See ref.fused_rank_ref."""
    if use_kernel is None:
        use_kernel = m2 <= MAX_KERNEL_M2
    if not use_kernel:
        return ref.fused_rank_ref(u, a, lam, m2, eps)
    if interpret is None:
        interpret = not _on_tpu()
    n, m1 = u.shape
    u_p = _pad_to(_pad_to(u, 0, tile_b, 0.0), 1, tile_m, -jnp.inf)
    a_p = _pad_to(_pad_to(a, 0, tile_b, 0.0), 2, tile_m, 0.0)
    lam_p = _pad_to(lam, 0, tile_b, 0.0)
    vals, idx = fused_rank_pallas(
        u_p, a_p, lam_p, m2=m2, eps=eps, tile_b=tile_b, tile_m=tile_m,
        interpret=interpret)
    return vals[:n], idx[:n]


# ---------------------------------------------------------------------------
# rank_audited
# ---------------------------------------------------------------------------

def rank_audited(
    u: Array,            # (n, m1)
    a: Array,            # (n, K, m1) or (K, m1)
    b: Array,            # (n, K) or (K,)
    lam: Array,          # (n, K)
    gamma: Array,        # (m2,) or (n, m2)
    *,
    m2: int,
    eps: float = 1e-4,
    tol: float | None = None,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
    tile_b: int = TILE_B,
    tile_m: int = TILE_M,
):
    """Fused rank+audit dispatcher: one kernel emits the complete
    RankingOutput (perm, utility, exposure, compliant, lam) with zero
    post-kernel reads of ``u``/``a`` — the audit runs on the (K+1)·m2
    payload values the streaming top-m2 merge kept in VMEM.

    Accepts the same shared-vs-per-request broadcast forms as
    core.ranking.rank_given_lambda. ``tol`` defaults to the shared
    core.ranking.AUDIT_TOL so the compliance slack can never drift
    between the jnp and kernel paths. Falls back to the XLA oracle
    (ref.rank_audited_ref — broadcast gathers, no materialized index
    tensor) when m2 > MAX_KERNEL_M2 or ``use_kernel=False``; runs
    interpret=True off-TPU by default.
    """
    from repro.core.ranking import AUDIT_TOL, RankingOutput  # deferred: no cycle

    if tol is None:
        tol = AUDIT_TOL
    n = u.shape[0]
    if a.ndim == 2:
        a = jnp.broadcast_to(a, (n,) + a.shape)
    if b.ndim == 1:
        b = jnp.broadcast_to(b, (n,) + b.shape)
    if gamma.ndim == 1:
        gamma = jnp.broadcast_to(gamma, (n,) + gamma.shape)
    if use_kernel is None:
        use_kernel = m2 <= MAX_KERNEL_M2
    if not use_kernel:
        _, idx, utility, exposure, compliant = ref.rank_audited_ref(
            u, a, b, lam, gamma, m2, eps, tol)
        return RankingOutput(perm=idx, utility=utility, exposure=exposure,
                             compliant=compliant, lam=lam)
    if interpret is None:
        interpret = not _on_tpu()
    # NEG_INF (finite -1e30) keeps candidate padding out of every top-m2
    # while 0-discount slots still contribute exactly 0.0 to utility.
    u_p = _pad_to(_pad_to(u, 0, tile_b, 0.0), 1, tile_m, NEG_INF)
    a_p = _pad_to(_pad_to(a, 0, tile_b, 0.0), 2, tile_m, 0.0)
    b_p = _pad_to(b, 0, tile_b, 0.0)
    lam_p = _pad_to(lam, 0, tile_b, 0.0)
    gamma_p = _pad_to(gamma, 0, tile_b, 0.0)
    _, idx, util, expo, comp = rank_audited_pallas(
        u_p, a_p, b_p, lam_p, gamma_p, m2=m2, eps=eps, tol=tol,
        tile_b=tile_b, tile_m=tile_m, interpret=interpret)
    return RankingOutput(
        perm=idx[:n], utility=util[:n, 0], exposure=expo[:n],
        compliant=comp[:n, 0].astype(bool), lam=lam)


# ---------------------------------------------------------------------------
# predict_rank_audited: λ-predictor + rank + audit, one device program
# ---------------------------------------------------------------------------

def predict_rank_audited(
    X,                   # (n, d) covariates
    predictor,           # fitted λ predictor pytree (core.predictors)
    u: Array,            # (n, m1)
    a: Array,            # (n, K, m1) or (K, m1)
    b: Array,            # (n, K) or (K,)
    gamma: Array,        # (m2,) or (n, m2)
    *,
    m2: int,
    eps: float = 1e-4,
    tol: float | None = None,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
    tile_b: int | None = None,
    tile_m: int = TILE_M,
    tile_n: int = DB_SLAB,
    knn_chain: bool = False,
    pad_lanes: bool | None = None,
):
    """The paper's ENTIRE online stage — predict λ̂ = f(X), rank, audit
    — as one dispatcher that lowers to a single device program, routed
    by predictor family:

      linear / mean   λ̂ = max(W x + c, 0) folds into the prologue of
                      the rank+audit kernel (linear_rank_audited_pallas)
                      — λ̂ is computed per batch tile into VMEM scratch
                      and never exists in HBM between predict and rank;
                      the mean predictor is the W = 0, no-clamp case.
                      Bitwise-identical to predict-then-rank.
      knn             knn_rank_audited_pallas: ONE pallas_call whose
                      grid streams the train db in tile_n-row slabs,
                      flushes λ̂ into VMEM scratch, and continues
                      straight into the rank+audit sweep — one kernel
                      launch per micro-batch, λ̂ never in HBM.
                      ``knn_chain=True`` keeps the pre-fusion two-kernel
                      chain (knn_lambda_pallas -> rank_audited_pallas
                      inside one jit executable, λ̂ handed off through
                      an HBM buffer) as the parity oracle the fused
                      grid is tested bitwise against at matched tiles.
      mlp / other     λ̂ = predictor.predict(X) stays XLA (matmuls are
                      already MXU-shaped) and joins the same jit
                      executable ahead of the rank+audit kernel.

    ``pad_lanes`` widens the affine prologue's covariate dim d to the
    128-lane boundary with zero W/X columns (exact: trailing zeros add
    nothing to the dot) — default on for compiled TPU kernels, OFF on
    the interpret path, whose bitwise-parity contract pins the dot's
    reduction length.

    Extra constraint rows in ``a`` beyond the predictor's output width
    (bucket-padded K) get zero shadow prices — exactly the serving
    engine's padding scheme. Falls back to the two-stage XLA oracle
    (ref.predict_rank_audited_ref) when m2 > MAX_KERNEL_M2 or
    ``use_kernel=False``; interpret=True off-TPU by default. Returns a
    complete RankingOutput whose ``lam`` is the λ̂ actually used.
    """
    from repro.core.predictors import (  # deferred: keep import DAG flat
        KNNLambdaPredictor,
        LinearLambdaPredictor,
        MeanLambdaPredictor,
    )
    from repro.core.ranking import AUDIT_TOL, RankingOutput

    if tol is None:
        tol = AUDIT_TOL
    n = u.shape[0]
    if X.shape[0] != n:
        # the kernel path pads X rows for tiling; a row-count mismatch
        # must be a loud caller error, never silently intercept-served
        raise ValueError(f"X carries {X.shape[0]} covariate rows but the "
                         f"problem has {n} users")
    if a.ndim == 2:
        a = jnp.broadcast_to(a, (n,) + a.shape)
    if b.ndim == 1:
        b = jnp.broadcast_to(b, (n,) + b.shape)
    if gamma.ndim == 1:
        gamma = jnp.broadcast_to(gamma, (n,) + gamma.shape)
    Kp = a.shape[1]
    if use_kernel is None:
        use_kernel = m2 <= MAX_KERNEL_M2
    if not use_kernel:
        _, idx, utility, exposure, compliant, lam = (
            ref.predict_rank_audited_ref(X, predictor, u, a, b, gamma,
                                         m2, eps, tol))
        return RankingOutput(perm=idx, utility=utility, exposure=exposure,
                             compliant=compliant, lam=lam)
    if interpret is None:
        interpret = not _on_tpu()

    if isinstance(predictor, KNNLambdaPredictor):
        # the KNN route picks its own batch tile: a wide resident query
        # tile divides the db-streaming cost (one sweep per tile), so it
        # defaults to knn_lambda_tile_q — 32 when the batch fills it —
        # exactly the geometry the PR 4 chain ran.
        if tile_b is None:
            tile_b = knn_lambda_tile_q(n)
    elif tile_b is None:
        tile_b = TILE_B

    if isinstance(predictor, (LinearLambdaPredictor, MeanLambdaPredictor)):
        if isinstance(predictor, LinearLambdaPredictor):
            W, c, relu = predictor.W, predictor.c, True
        else:
            # mean λ is affine with zero weights; no clamp (predict()
            # broadcasts mean_lam verbatim, clamped or not)
            W = jnp.zeros((predictor.mean_lam.shape[0], X.shape[1]),
                          jnp.float32)
            c, relu = predictor.mean_lam, False
        k_pred = W.shape[0]
        ref.check_pred_width(k_pred, Kp)
        # zero rows/intercepts for bucket-padded constraints: the
        # prologue emits exactly the 0.0 λ̂ the padding scheme wants.
        W_p = jnp.pad(W.astype(jnp.float32), ((0, Kp - k_pred), (0, 0)))
        c_p = jnp.pad(c.astype(jnp.float32), (0, Kp - k_pred))[None, :]
        u_p = _pad_to(_pad_to(u, 0, tile_b, 0.0), 1, tile_m, NEG_INF)
        a_p = _pad_to(_pad_to(a, 0, tile_b, 0.0), 2, tile_m, 0.0)
        b_p = _pad_to(b, 0, tile_b, 0.0)
        gamma_p = _pad_to(gamma, 0, tile_b, 0.0)
        X_p = _pad_to(jnp.asarray(X, jnp.float32), 0, tile_b, 0.0)
        # MXU lane alignment for the prologue dot: widen d to the
        # 128-lane boundary with zero columns of X AND zero columns of
        # W. Trailing zeros append exactly-0.0 terms at the END of the
        # reduction, so the math is exact — but the reduction LENGTH
        # changes, which on the interpret path would void the
        # bitwise-vs-predict() contract; hence the gate (compiled TPU
        # kernels only, unless a caller forces it).
        if pad_lanes is None:
            pad_lanes = not interpret
        if pad_lanes:
            X_p = _pad_to(X_p, 1, LANE, 0.0)
            W_p = _pad_to(W_p, 1, LANE, 0.0)
        _, idx, util, expo, comp, lam = linear_rank_audited_pallas(
            u_p, a_p, b_p, X_p, W_p, c_p, gamma_p, m2=m2, eps=eps, tol=tol,
            relu=relu, tile_b=tile_b, tile_m=tile_m, interpret=interpret)
        return RankingOutput(
            perm=idx[:n], utility=util[:n, 0], exposure=expo[:n],
            compliant=comp[:n, 0].astype(bool), lam=lam[:n])

    if isinstance(predictor, KNNLambdaPredictor):
        # a packed predictor (KNNLambdaPredictor.quantized) routes to
        # the quantized sweep; quant is a STATIC predictor field, so
        # the jit trace through the stateful seam branches on it as a
        # Python constant — no recompiles on state swaps.
        quant = predictor.quant if predictor.X_q is not None else "off"
        if quant != "off":
            # the per-slab scales ARE the kernel's slab blocks: the
            # pack geometry dictates the serving slab, so the sweep
            # tile follows the predictor rather than the default
            tile_n = predictor.X_q.shape[0] // predictor.q_scale.shape[0]
        if not knn_chain:
            return knn_rank_audited(
                X, predictor.X_db, predictor.lam_db, u, a, b, gamma,
                k=predictor.k, m2=m2, eps=eps, tol=tol,
                interpret=interpret, tile_b=tile_b, tile_n=tile_n,
                tile_m=tile_m, quant=quant, X_q=predictor.X_q,
                q_scale=predictor.q_scale, y2_q=predictor.y2_q)
        # the pre-fusion two-kernel chain: knn_lambda_pallas emits λ̂
        # through an HBM buffer, rank_audited_pallas reads it back —
        # kept as the single-grid kernel's bitwise parity oracle (and
        # for A/B measurement); tile_q matches the fused grid's batch
        # tile so the slab sweeps see identical tile geometry.
        lam = knn_lambda(X, predictor.X_db, predictor.lam_db,
                         k=predictor.k, interpret=interpret,
                         tile_q=tile_b, tile_n=tile_n, quant=quant,
                         X_q=predictor.X_q, q_scale=predictor.q_scale,
                         y2_q=predictor.y2_q)
        ref.check_pred_width(lam.shape[-1], Kp)
        lam = jnp.pad(lam, ((0, 0), (0, Kp - lam.shape[-1])))
    else:
        lam = predictor.predict(X).astype(jnp.float32)
        ref.check_pred_width(lam.shape[-1], Kp)
        lam = jnp.pad(lam, ((0, 0), (0, Kp - lam.shape[-1])))
    return rank_audited(u, a, b, lam, gamma, m2=m2, eps=eps, tol=tol,
                        interpret=interpret, tile_b=tile_b, tile_m=tile_m)


def predict_rank_audited_stateful(
    state: dict,         # predictor_state(predictor): the array leaves
    predictor,           # the STATIC template (family + non-array fields)
    X,
    u: Array,
    a: Array,
    b: Array,
    gamma: Array,
    **kwargs,
):
    """predict_rank_audited with the predictor's ARRAY state threaded
    as a leading argument — the hot-swap seam the serving engine jits.

    Closing a predictor over a jit body bakes its arrays in as
    executable constants, so refreshing them would force a retrace.
    Here `state` (core.predictors.predictor_state) enters the trace as
    a pytree ARGUMENT: swapping in new arrays of identical structure /
    shape / dtype hits the same compile-cache entry — zero recompiles —
    while `predictor` stays the static template whose family routes the
    dispatch and whose non-array fields (KNN's k) shape the trace.
    """
    from repro.core.predictors import with_state  # deferred: no cycle

    return predict_rank_audited(X, with_state(predictor, state),
                                u, a, b, gamma, **kwargs)


def _quant_db(X_db, X_q, q_scale, y2_q, *, quant: str, tile_n: int):
    """Resolve the packed-db triple for the quantized sweep: validate
    the pack-slab == serving-tile_n contract (the per-slab scales ARE
    the kernel's slab blocks) or auto-pack at tile_n when the caller
    hands only the f32 db. Returns (X_q, q_scale, y2_q)."""
    from repro.core.predictors import pack_knn_db  # deferred: no cycle

    if quant not in QUANT_MODES:
        raise ValueError(f"quant must be one of {QUANT_MODES}, got {quant!r}")
    if X_q is None:
        return pack_knn_db(X_db, mode=quant, slab=tile_n)
    n_pad = X_q.shape[0]
    if n_pad % tile_n or q_scale.shape[0] * tile_n != n_pad:
        raise ValueError(
            f"quantized db packed at slab={n_pad // max(q_scale.shape[0], 1)}"
            f" but serving tile_n={tile_n}: repack with slab=tile_n "
            f"(KNNLambdaPredictor.quantized(slab=tile_n))")
    return X_q, q_scale, y2_q


def knn_rank_audited(
    X: Array,            # (n, d) query covariates
    X_db: Array,         # (n_train, d) train database
    lam_db: Array,       # (n_train, K_pred) train shadow prices
    u: Array,            # (n, m1)
    a: Array,            # (n, K, m1)
    b: Array,            # (n, K)
    gamma: Array,        # (n, m2)
    *,
    k: int = 10,
    m2: int,
    eps: float = 1e-4,
    tol: float | None = None,
    interpret: bool | None = None,
    tile_b: int | None = None,
    tile_n: int = DB_SLAB,
    tile_m: int = TILE_M,
    quant: str = "off",
    X_q: Array | None = None,       # packed db (predictors.pack_knn_db)
    q_scale: Array | None = None,   # (n_slabs, 1) per-slab scales
    y2_q: Array | None = None,      # (n_pad, 1) exact |x̃|^2
    k_extra: int = QUANT_EXTRA,
    return_guard: bool = False,
):
    """The single-grid KNN online stage (knn_rank_audited_pallas) with
    the padding contract of the other dispatchers: rows to tile_b
    (default knn_lambda_tile_q — wide resident query tiles divide the
    db-streaming cost; zero covariates — phantom rows score 0
    everywhere and are sliced off),
    db rows to tile_n with far-away 1e15 rows (never top-k while the
    KNN contract n_train >= k holds; their λ rows zeroed for hygiene),
    candidates to tile_m with NEG_INF utilities, and bucket-padded
    constraint rows beyond the predictor's width priced at exactly 0.0
    (zero lam_db columns make the flush-step einsum emit 0.0). Returns
    a complete RankingOutput.

    quant='int8'|'bf16' routes to the quantized-sweep twin
    (knn_rank_audited_quant_pallas): the db streams in low precision
    (4x / 2x fewer HBM bytes) and the top-(k + k_extra) survivors are
    re-scored exactly in f32 at the flush. The packed triple comes from
    the caller (pack slab MUST equal tile_n) or is packed here at
    tile_n. ``return_guard=True`` appends the per-row margin-guard
    fallback flags ((n, 1) i32) to the return."""
    from repro.core.ranking import AUDIT_TOL, RankingOutput  # deferred: no cycle

    if tol is None:
        tol = AUDIT_TOL
    if X_db.shape[0] < k:
        raise ValueError(f"n_train={X_db.shape[0]} < k={k}")
    if interpret is None:
        interpret = not _on_tpu()
    n = u.shape[0]
    if X.shape[0] != n:
        # same loud contract as predict_rank_audited: row padding is
        # the kernel's job, a row-count mismatch is a caller bug that
        # must never be silently intercept-served or sliced away
        raise ValueError(f"X carries {X.shape[0]} covariate rows but the "
                         f"problem has {n} users")
    if tile_b is None:
        tile_b = knn_lambda_tile_q(n)
    Kp = a.shape[1]
    k_pred = lam_db.shape[1]
    ref.check_pred_width(k_pred, Kp)
    Xq_p = _pad_to(jnp.asarray(X, jnp.float32), 0, tile_b, 0.0)
    u_p = _pad_to(_pad_to(u, 0, tile_b, 0.0), 1, tile_m, NEG_INF)
    a_p = _pad_to(_pad_to(a, 0, tile_b, 0.0), 2, tile_m, 0.0)
    b_p = _pad_to(b, 0, tile_b, 0.0)
    gamma_p = _pad_to(gamma, 0, tile_b, 0.0)

    if quant != "off":
        X_q, q_scale, y2_q = _quant_db(
            X_db, X_q, q_scale, y2_q, quant=quant, tile_n=tile_n)
        # lam rows pad to the PACKED row count (pack pads with zero
        # rows + PAD_Y2, which the sweep can never select)
        lamdb_p = jnp.pad(
            lam_db, ((0, X_q.shape[0] - lam_db.shape[0]), (0, Kp - k_pred)))
        _, idx, util, expo, comp, lam, guard = knn_rank_audited_quant_pallas(
            Xq_p, X_q, q_scale, y2_q, lamdb_p, u_p, a_p, b_p, gamma_p,
            k=k, k_extra=k_extra, mode=quant, m2=m2, eps=eps, tol=tol,
            tile_b=tile_b, tile_n=tile_n, tile_m=tile_m,
            interpret=interpret)
        out = RankingOutput(
            perm=idx[:n], utility=util[:n, 0], exposure=expo[:n],
            compliant=comp[:n, 0].astype(bool), lam=lam[:n])
        return (out, guard[:n]) if return_guard else out

    xdb_p = _pad_to(X_db, 0, tile_n, 1e15)
    lamdb_p = _pad_to(
        jnp.pad(lam_db, ((0, 0), (0, Kp - k_pred))), 0, tile_n, 0.0)
    _, idx, util, expo, comp, lam = knn_rank_audited_pallas(
        Xq_p, xdb_p, lamdb_p, u_p, a_p, b_p, gamma_p, k=k, m2=m2,
        eps=eps, tol=tol, tile_b=tile_b, tile_n=tile_n, tile_m=tile_m,
        interpret=interpret)
    out = RankingOutput(
        perm=idx[:n], utility=util[:n, 0], exposure=expo[:n],
        compliant=comp[:n, 0].astype(bool), lam=lam[:n])
    if return_guard:
        return out, jnp.zeros((n, 1), jnp.int32)
    return out


def kernel_launch_count(predictor, m2: int, *,
                        use_kernel: bool | None = None,
                        knn_chain: bool = False) -> int:
    """Pallas kernel launches per dispatcher call, by route — the
    number EngineMetrics charges each flushed micro-batch with.
    ``predictor=None`` is the λ-carrying rank_audited path. Zero means
    the XLA fallback owns the batch (m2 > MAX_KERNEL_M2 or
    use_kernel=False)."""
    from repro.core.predictors import KNNLambdaPredictor  # deferred

    if use_kernel is None:
        use_kernel = m2 <= MAX_KERNEL_M2
    if not use_kernel or m2 > MAX_KERNEL_M2:
        return 0
    if isinstance(predictor, KNNLambdaPredictor) and knn_chain:
        return 2      # the pre-fusion chain: knn_lambda + rank_audited
    return 1          # affine prologue / single-grid KNN / mlp + rank


# ---------------------------------------------------------------------------
# knn_topk
# ---------------------------------------------------------------------------

def knn_topk(
    xq: Array, xdb: Array, *, k: int = 10,
    use_kernel: bool = True, interpret: bool | None = None,
    tile_q: int = TILE_B, tile_n: int = DB_SLAB,
):
    """(d2 (B, k) ascending, idx (B, k)). See ref.knn_topk_ref."""
    if not use_kernel:
        return ref.knn_topk_ref(xq, xdb, k)
    if interpret is None:
        interpret = not _on_tpu()
    B, D = xq.shape
    N = xdb.shape[0]
    # pad the db with far-away rows so padded entries never enter top-k
    xq_p = _pad_to(xq, 0, tile_q, 0.0)
    xdb_p = _pad_to(xdb, 0, tile_n, 1e15)
    d2, idx = knn_topk_pallas(
        xq_p, xdb_p, k=k, tile_q=tile_q, tile_n=tile_n, interpret=interpret)
    return d2[:B], idx[:B]


def knn_lambda_tile_q(batch: int) -> int:
    """Default resident-query-tile width for the fused KNN λ kernel: a
    wider tile divides the per-request db-streaming cost (one sweep per
    tile) — 32 when the batch fills it, the top-k kernel's 8 otherwise.
    Shared with benchmarks/kernel_bench's traffic model so the modeled
    sweep count always matches the kernel configuration that runs."""
    return 32 if batch >= 32 else 8


def knn_lambda(
    X: Array, X_db: Array, lam_db: Array, *, k: int = 10,
    use_kernel: bool = True, interpret: bool | None = None,
    tile_q: int | None = None, tile_n: int = DB_SLAB,
    quant: str = "off",
    X_q: Array | None = None, q_scale: Array | None = None,
    y2_q: Array | None = None, k_extra: int = QUANT_EXTRA,
) -> Array:
    """λ̂ (B, K) from the fused KNN kernel (knn_lambda_pallas): one db
    sweep per query tile, weighting at the flush step, no d2/idx or
    distance-matrix HBM traffic. tile_q defaults to 32 when the batch
    allows it — a bigger resident query tile divides the db-streaming
    cost by 4 vs the top-k kernel's default of 8. quant='int8'|'bf16'
    streams the packed db instead (knn_lambda_quant_pallas — exact f32
    survivor re-score at the flush, see kernels/common.py)."""
    if X_db.shape[0] < k:
        # same contract every other KNN path enforces — without it the
        # far-away db padding rows would silently enter the top-k
        raise ValueError(f"n_train={X_db.shape[0]} < k={k}")
    if not use_kernel:
        return ref.knn_lambda_ref(X, X_db, lam_db, k)
    if interpret is None:
        interpret = not _on_tpu()
    if tile_q is None:
        tile_q = knn_lambda_tile_q(X.shape[0])
    B = X.shape[0]
    Xq_p = _pad_to(jnp.asarray(X, jnp.float32), 0, tile_q, 0.0)
    if quant != "off":
        X_q, q_scale, y2_q = _quant_db(
            X_db, X_q, q_scale, y2_q, quant=quant, tile_n=tile_n)
        lamdb_p = jnp.pad(lam_db, ((0, X_q.shape[0] - lam_db.shape[0]),
                                   (0, 0)))
        lam, _guard = knn_lambda_quant_pallas(
            Xq_p, X_q, q_scale, y2_q, lamdb_p, k=k, k_extra=k_extra,
            mode=quant, tile_q=tile_q, tile_n=tile_n, interpret=interpret)
        return lam[:B]
    # far-away padding rows can never enter a top-k (requires the KNN
    # contract N >= k real rows); their λ rows are zeroed for hygiene
    xdb_p = _pad_to(X_db, 0, tile_n, 1e15)
    lamdb_p = _pad_to(lam_db, 0, tile_n, 0.0)
    lam = knn_lambda_pallas(Xq_p, xdb_p, lamdb_p, k=k, tile_q=tile_q,
                            tile_n=tile_n, interpret=interpret)
    return lam[:B]


def knn_predict_kernel(
    X_db: Array, lam_db: Array, X: Array, *, k: int = 10,
    interpret: bool | None = None,
) -> Array:
    """Kernel-backed twin of repro.core.predictors.knn_predict (same
    inverse-distance weighting and exact-match semantics)."""
    squeeze = X.ndim == 1
    Xq = jnp.atleast_2d(X)
    d2, idx = knn_topk(Xq, X_db, k=k, interpret=interpret)
    dist = jnp.sqrt(d2)
    x2 = jnp.sum(Xq * Xq, axis=-1, keepdims=True)
    y2 = jnp.sum(X_db * X_db, axis=-1)[idx]
    exact = d2 <= 1e-6 * (x2 + y2 + 1e-12)
    any_exact = jnp.any(exact, axis=-1, keepdims=True)
    w_inv = 1.0 / jnp.maximum(dist, 1e-12)
    w = jnp.where(any_exact, exact.astype(d2.dtype), w_inv)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    out = jnp.einsum("bk,bkc->bc", w, lam_db[idx])
    return out[0] if squeeze else out


# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------

def embedding_bag(
    table: Array, indices: Array, weights: Array | None = None, *,
    use_kernel: bool = True, interpret: bool | None = None, tile_b: int = 8,
):
    """(n_bags, D) sum-mode bag. See ref.embedding_bag_ref."""
    if not use_kernel:
        return ref.embedding_bag_ref(table, indices, weights)
    if interpret is None:
        interpret = not _on_tpu()
    n_bags = indices.shape[0]
    idx_p = _pad_to(indices, 0, tile_b, -1)
    w_p = None if weights is None else _pad_to(weights, 0, tile_b, 0.0)
    out = embedding_bag_pallas(
        table, idx_p, w_p, tile_b=tile_b, interpret=interpret)
    return out[:n_bags]
