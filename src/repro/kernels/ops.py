"""jit'd public wrappers for the Pallas kernels, with XLA fallbacks.

Call these, not the kernels directly: they pad awkward shapes to tile
boundaries, dispatch to the XLA reference when the kernel's static
constraints don't hold (huge m2, CPU runtime without interpret), and
return results in the oracle's exact format so callers can swap paths
without code changes.

On this container (CPU) the kernels run with interpret=True; on TPU the
same call sites compile the real kernels (interpret=False default).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.common import NEG_INF
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.fused_rank import (
    MAX_KERNEL_M2,
    fused_rank_pallas,
    rank_audited_pallas,
)
from repro.kernels.knn_topk import knn_topk_pallas

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: Array, axis: int, mult: int, value):
    n = x.shape[axis]
    rem = (-n) % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# fused_rank
# ---------------------------------------------------------------------------

def fused_rank(
    u: Array, a: Array, lam: Array, *, m2: int, eps: float = 1e-4,
    use_kernel: bool | None = None, interpret: bool | None = None,
    tile_b: int = 8, tile_m: int = 512,
):
    """(top scores (n, m2) desc f32, item idx (n, m2)). See ref.fused_rank_ref."""
    if use_kernel is None:
        use_kernel = m2 <= MAX_KERNEL_M2
    if not use_kernel:
        return ref.fused_rank_ref(u, a, lam, m2, eps)
    if interpret is None:
        interpret = not _on_tpu()
    n, m1 = u.shape
    u_p = _pad_to(_pad_to(u, 0, tile_b, 0.0), 1, tile_m, -jnp.inf)
    a_p = _pad_to(_pad_to(a, 0, tile_b, 0.0), 2, tile_m, 0.0)
    lam_p = _pad_to(lam, 0, tile_b, 0.0)
    vals, idx = fused_rank_pallas(
        u_p, a_p, lam_p, m2=m2, eps=eps, tile_b=tile_b, tile_m=tile_m,
        interpret=interpret)
    return vals[:n], idx[:n]


# ---------------------------------------------------------------------------
# rank_audited
# ---------------------------------------------------------------------------

def rank_audited(
    u: Array,            # (n, m1)
    a: Array,            # (n, K, m1) or (K, m1)
    b: Array,            # (n, K) or (K,)
    lam: Array,          # (n, K)
    gamma: Array,        # (m2,) or (n, m2)
    *,
    m2: int,
    eps: float = 1e-4,
    tol: float | None = None,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
    tile_b: int = 8,
    tile_m: int = 512,
):
    """Fused rank+audit dispatcher: one kernel emits the complete
    RankingOutput (perm, utility, exposure, compliant, lam) with zero
    post-kernel reads of ``u``/``a`` — the audit runs on the (K+1)·m2
    payload values the streaming top-m2 merge kept in VMEM.

    Accepts the same shared-vs-per-request broadcast forms as
    core.ranking.rank_given_lambda. ``tol`` defaults to the shared
    core.ranking.AUDIT_TOL so the compliance slack can never drift
    between the jnp and kernel paths. Falls back to the XLA oracle
    (ref.rank_audited_ref — broadcast gathers, no materialized index
    tensor) when m2 > MAX_KERNEL_M2 or ``use_kernel=False``; runs
    interpret=True off-TPU by default.
    """
    from repro.core.ranking import AUDIT_TOL, RankingOutput  # deferred: no cycle

    if tol is None:
        tol = AUDIT_TOL
    n = u.shape[0]
    if a.ndim == 2:
        a = jnp.broadcast_to(a, (n,) + a.shape)
    if b.ndim == 1:
        b = jnp.broadcast_to(b, (n,) + b.shape)
    if gamma.ndim == 1:
        gamma = jnp.broadcast_to(gamma, (n,) + gamma.shape)
    if use_kernel is None:
        use_kernel = m2 <= MAX_KERNEL_M2
    if not use_kernel:
        _, idx, utility, exposure, compliant = ref.rank_audited_ref(
            u, a, b, lam, gamma, m2, eps, tol)
        return RankingOutput(perm=idx, utility=utility, exposure=exposure,
                             compliant=compliant, lam=lam)
    if interpret is None:
        interpret = not _on_tpu()
    # NEG_INF (finite -1e30) keeps candidate padding out of every top-m2
    # while 0-discount slots still contribute exactly 0.0 to utility.
    u_p = _pad_to(_pad_to(u, 0, tile_b, 0.0), 1, tile_m, NEG_INF)
    a_p = _pad_to(_pad_to(a, 0, tile_b, 0.0), 2, tile_m, 0.0)
    b_p = _pad_to(b, 0, tile_b, 0.0)
    lam_p = _pad_to(lam, 0, tile_b, 0.0)
    gamma_p = _pad_to(gamma, 0, tile_b, 0.0)
    _, idx, util, expo, comp = rank_audited_pallas(
        u_p, a_p, b_p, lam_p, gamma_p, m2=m2, eps=eps, tol=tol,
        tile_b=tile_b, tile_m=tile_m, interpret=interpret)
    return RankingOutput(
        perm=idx[:n], utility=util[:n, 0], exposure=expo[:n],
        compliant=comp[:n, 0].astype(bool), lam=lam)


# ---------------------------------------------------------------------------
# knn_topk
# ---------------------------------------------------------------------------

def knn_topk(
    xq: Array, xdb: Array, *, k: int = 10,
    use_kernel: bool = True, interpret: bool | None = None,
    tile_q: int = 8, tile_n: int = 512,
):
    """(d2 (B, k) ascending, idx (B, k)). See ref.knn_topk_ref."""
    if not use_kernel:
        return ref.knn_topk_ref(xq, xdb, k)
    if interpret is None:
        interpret = not _on_tpu()
    B, D = xq.shape
    N = xdb.shape[0]
    # pad the db with far-away rows so padded entries never enter top-k
    xq_p = _pad_to(xq, 0, tile_q, 0.0)
    xdb_p = _pad_to(xdb, 0, tile_n, 1e15)
    d2, idx = knn_topk_pallas(
        xq_p, xdb_p, k=k, tile_q=tile_q, tile_n=tile_n, interpret=interpret)
    return d2[:B], idx[:B]


def knn_predict_kernel(
    X_db: Array, lam_db: Array, X: Array, *, k: int = 10,
    interpret: bool | None = None,
) -> Array:
    """Kernel-backed twin of repro.core.predictors.knn_predict (same
    inverse-distance weighting and exact-match semantics)."""
    squeeze = X.ndim == 1
    Xq = jnp.atleast_2d(X)
    d2, idx = knn_topk(Xq, X_db, k=k, interpret=interpret)
    dist = jnp.sqrt(d2)
    x2 = jnp.sum(Xq * Xq, axis=-1, keepdims=True)
    y2 = jnp.sum(X_db * X_db, axis=-1)[idx]
    exact = d2 <= 1e-6 * (x2 + y2 + 1e-12)
    any_exact = jnp.any(exact, axis=-1, keepdims=True)
    w_inv = 1.0 / jnp.maximum(dist, 1e-12)
    w = jnp.where(any_exact, exact.astype(d2.dtype), w_inv)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    out = jnp.einsum("bk,bkc->bc", w, lam_db[idx])
    return out[0] if squeeze else out


# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------

def embedding_bag(
    table: Array, indices: Array, weights: Array | None = None, *,
    use_kernel: bool = True, interpret: bool | None = None, tile_b: int = 8,
):
    """(n_bags, D) sum-mode bag. See ref.embedding_bag_ref."""
    if not use_kernel:
        return ref.embedding_bag_ref(table, indices, weights)
    if interpret is None:
        interpret = not _on_tpu()
    n_bags = indices.shape[0]
    idx_p = _pad_to(indices, 0, tile_b, -1)
    w_p = None if weights is None else _pad_to(weights, 0, tile_b, 0.0)
    out = embedding_bag_pallas(
        table, idx_p, w_p, tile_b=tile_b, interpret=interpret)
    return out[:n_bags]
