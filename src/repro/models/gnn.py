"""MeshGraphNet (arXiv:2010.03409) — encode-process-decode GNN.

Kernel regime: SpMM-style message passing. JAX sparse is BCOO-only, so
messages are computed on an explicit edge list and aggregated with
``jax.ops.segment_sum`` over the receiver index — this IS the system's
scatter substrate (kernel_taxonomy §GNN), shared with the recsys
EmbeddingBag.

Three execution modes matching the assigned shape cells:
  * full-graph training (full_graph_sm / ogb_products): one big
    (senders, receivers, edge_feat) edge list, nodes+edges sharded over
    (pod, data); segment_sum across edge shards lowers to a psum over the
    partial node aggregates.
  * sampled minibatch (minibatch_lg): `neighbor_sample` draws a
    static-shape uniform-fanout subgraph (GraphSAGE-style, duplicates
    kept so shapes stay static) from a CSR adjacency; the same network
    runs on the sampled block.
  * batched small graphs (molecule): vmap over a (B, n_nodes, ...) batch.

Processor steps are *unshared* (15 independent weight sets, per the
paper), scan-stacked on a leading L axis like the LM layers.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_shard
from repro.models.layers import dense_init

Array = jax.Array


@dataclass(frozen=True)
class GNNConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15          # processor message-passing steps
    d_hidden: int = 128
    mlp_layers: int = 2         # hidden layers per MLP block
    aggregator: str = "sum"     # sum | mean | max
    d_node_in: int = 16
    d_edge_in: int = 8
    d_out: int = 3
    layer_norm: bool = True
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    remat: bool = True

    @property
    def n_params(self) -> int:
        def mlp(d_in, d_out):
            n, prev = 0, d_in
            for _ in range(self.mlp_layers):
                n += prev * self.d_hidden + self.d_hidden
                prev = self.d_hidden
            n += prev * d_out + d_out
            if self.layer_norm:
                n += 2 * d_out
            return n

        h = self.d_hidden
        enc = mlp(self.d_node_in, h) + mlp(self.d_edge_in, h)
        proc = self.n_layers * (mlp(3 * h, h) + mlp(2 * h, h))
        dec = mlp(h, self.d_out)
        return enc + proc + dec


# --------------------------------------------------------------------------
# MLP block (Linear x mlp_layers + out, ReLU, optional LayerNorm at output)
# --------------------------------------------------------------------------

def _mlp_init(key, d_in: int, d_out: int, cfg: GNNConfig) -> dict:
    dims = [d_in] + [cfg.d_hidden] * cfg.mlp_layers + [d_out]
    ks = jax.random.split(key, len(dims) - 1)
    p = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        p[f"w{i}"] = dense_init(ks[i], (a, b), cfg.param_dtype)
        p[f"b{i}"] = jnp.zeros((b,), cfg.param_dtype)
    if cfg.layer_norm:
        p["ln_scale"] = jnp.ones((d_out,), cfg.param_dtype)
        p["ln_bias"] = jnp.zeros((d_out,), cfg.param_dtype)
    return p


def _mlp_axes(cfg: GNNConfig) -> dict:
    p = {}
    for i in range(cfg.mlp_layers + 1):
        p[f"w{i}"] = ("mlp_in", "mlp_out")
        p[f"b{i}"] = (None,)
    if cfg.layer_norm:
        p["ln_scale"] = (None,)
        p["ln_bias"] = (None,)
    return p


def _mlp_apply(p: dict, x: Array, cfg: GNNConfig) -> Array:
    n = cfg.mlp_layers + 1
    for i in range(n):
        x = x @ p[f"w{i}"].astype(x.dtype) + p[f"b{i}"].astype(x.dtype)
        if i < n - 1:
            x = jax.nn.relu(x)
    if cfg.layer_norm:
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + 1e-6)
        x = x * p["ln_scale"].astype(x.dtype) + p["ln_bias"].astype(x.dtype)
    return x


# --------------------------------------------------------------------------
# Aggregation (the SpMM substrate: segment ops over the receiver index)
# --------------------------------------------------------------------------

def aggregate(messages: Array, receivers: Array, n_nodes: int, mode: str) -> Array:
    """(n_edges, D) messages -> (n_nodes, D) per-receiver aggregate."""
    if mode == "sum":
        return jax.ops.segment_sum(messages, receivers, num_segments=n_nodes)
    if mode == "mean":
        s = jax.ops.segment_sum(messages, receivers, num_segments=n_nodes)
        cnt = jax.ops.segment_sum(
            jnp.ones((messages.shape[0],), messages.dtype), receivers,
            num_segments=n_nodes)
        return s / jnp.maximum(cnt, 1.0)[:, None]
    if mode == "max":
        return jax.ops.segment_max(messages, receivers, num_segments=n_nodes)
    raise ValueError(mode)


# --------------------------------------------------------------------------
# MeshGraphNet
# --------------------------------------------------------------------------

class MeshGraphNet:
    """Encode-process-decode on an explicit edge list.

    Graph batch dict:
      nodes     (N, d_node_in)   node features
      edges     (E, d_edge_in)   edge features
      senders   (E,) int32       source node per edge
      receivers (E,) int32       destination node per edge
      [targets  (N, d_out)]      regression targets (train)
      [node_mask (N,)]           1.0 for real nodes (padding from sampling)
    """

    def __init__(self, cfg: GNNConfig):
        self.cfg = cfg

    # -- params ------------------------------------------------------------

    def init(self, key) -> dict:
        cfg = self.cfg
        ke, kp, kd = jax.random.split(key, 3)
        ken, kee = jax.random.split(ke)
        h = cfg.d_hidden

        def proc_layer(k):
            k1, k2 = jax.random.split(k)
            return {
                "edge_mlp": _mlp_init(k1, 3 * h, h, cfg),
                "node_mlp": _mlp_init(k2, 2 * h, h, cfg),
            }

        proc_keys = jax.random.split(kp, cfg.n_layers)
        return {
            "node_encoder": _mlp_init(ken, cfg.d_node_in, h, cfg),
            "edge_encoder": _mlp_init(kee, cfg.d_edge_in, h, cfg),
            "processor": jax.vmap(proc_layer)(proc_keys),  # scan-stacked
            "decoder": _mlp_init(kd, h, cfg.d_out, cfg),
        }

    def logical_axes(self) -> dict:
        cfg = self.cfg
        m = _mlp_axes(cfg)
        stack = lambda t: ("layers",) + t
        proc = {
            "edge_mlp": {k: stack(v) for k, v in m.items()},
            "node_mlp": {k: stack(v) for k, v in m.items()},
        }
        return {
            "node_encoder": dict(m),
            "edge_encoder": dict(m),
            "processor": proc,
            "decoder": dict(m),
        }

    # -- forward -----------------------------------------------------------

    def forward(self, params, graph: dict) -> Array:
        """-> (N, d_out) per-node predictions."""
        cfg = self.cfg
        nodes = graph["nodes"].astype(cfg.dtype)
        edges = graph["edges"].astype(cfg.dtype)
        senders, receivers = graph["senders"], graph["receivers"]
        N = nodes.shape[0]

        v = _mlp_apply(params["node_encoder"], nodes, cfg)
        e = _mlp_apply(params["edge_encoder"], edges, cfg)
        v = logical_shard(v, "nodes", None)
        e = logical_shard(e, "edges", None)

        def step(carry, p_layer):
            v, e = carry
            msg_in = jnp.concatenate([e, v[senders], v[receivers]], axis=-1)
            e = e + _mlp_apply(p_layer["edge_mlp"], msg_in, cfg)
            agg = aggregate(e, receivers, N, cfg.aggregator)
            v = v + _mlp_apply(
                p_layer["node_mlp"], jnp.concatenate([v, agg], axis=-1), cfg)
            v = logical_shard(v, "nodes", None)
            e = logical_shard(e, "edges", None)
            return (v, e), None

        step_fn = jax.checkpoint(step) if cfg.remat else step
        (v, e), _ = jax.lax.scan(step_fn, (v, e), params["processor"])
        return _mlp_apply(params["decoder"], v, cfg)

    def forward_batched(self, params, graph: dict) -> Array:
        """molecule cell: graph leaves have a leading batch dim."""
        return jax.vmap(lambda g: self.forward(params, g))(graph)

    # -- loss / train ------------------------------------------------------

    def loss(self, params, graph: dict):
        batched = graph["nodes"].ndim == 3
        pred = (self.forward_batched if batched else self.forward)(params, graph)
        err = (pred - graph["targets"].astype(pred.dtype)) ** 2
        mask = graph.get("node_mask")
        if mask is not None:
            err = err * mask[..., None].astype(pred.dtype)
            denom = jnp.sum(mask) * pred.shape[-1] + 1e-9
            loss = jnp.sum(err) / denom
        else:
            loss = jnp.mean(err)
        return loss, {"loss": loss}

    def train_step(self, params, opt_state, graph, *, lr=1e-3):
        from repro.optim import adam_update
        from repro.optim.clip import clip_by_global_norm

        (loss, metrics), grads = jax.value_and_grad(
            lambda p: self.loss(p, graph), has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = adam_update(grads, opt_state, params, lr=lr)
        return params, opt_state, dict(metrics, grad_norm=gnorm)

    # -- paper-technique compatibility (API check only; see DESIGN.md §5) --

    def node_scores(self, params, graph: dict) -> Array:
        """First output channel as a per-node 'utility' — lets the
        constrained-ranking head consume GNN outputs in tests."""
        return self.forward(params, graph)[:, 0]


# --------------------------------------------------------------------------
# Uniform-fanout neighbor sampler (minibatch_lg)
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("fanouts",))
def neighbor_sample(
    key: Array,
    indptr: Array,       # (N+1,) CSR row offsets
    indices: Array,      # (n_edges,) CSR column indices
    seeds: Array,        # (B,) int32 seed node ids
    fanouts: tuple[int, ...] = (15, 10),
):
    """GraphSAGE-style uniform neighbor sampling with static shapes.

    Layer l frontier F_l: F_0 = seeds (B,); F_{l+1} has |F_l| * fanout_l
    entries (sampled with replacement — duplicates keep shapes static;
    zero-degree nodes self-loop). Returns a dict:

      node_ids  (T,)  sampled node ids, T = B * prod-prefix sums
      senders   (Etot,) / receivers (Etot,) indices INTO node_ids
      (receivers point at the coarser layer, messages flow child -> parent)

    The caller gathers features for node_ids and runs the network on the
    block; seed predictions are node_ids[:B].
    """
    layers = [seeds]
    edge_src, edge_dst = [], []
    offset = 0
    frontier = seeds
    for l, f in enumerate(fanouts):
        key, sub = jax.random.split(key)
        deg = (indptr[frontier + 1] - indptr[frontier]).astype(jnp.int32)
        u = jax.random.uniform(sub, (frontier.shape[0], f))
        pick = (u * jnp.maximum(deg, 1)[:, None]).astype(jnp.int32)
        nbr = indices[indptr[frontier][:, None] + pick]          # (F, f)
        # zero-degree: self loop
        nbr = jnp.where(deg[:, None] > 0, nbr, frontier[:, None])
        new_frontier = nbr.reshape(-1)
        n_par = frontier.shape[0]
        child_off = offset + n_par
        # edges: child (new layer) -> parent (current layer)
        src = child_off + jnp.arange(n_par * f)
        dst = offset + jnp.repeat(jnp.arange(n_par), f)
        edge_src.append(src)
        edge_dst.append(dst)
        layers.append(new_frontier)
        offset = child_off
        frontier = new_frontier
    node_ids = jnp.concatenate(layers)
    return {
        "node_ids": node_ids,
        "senders": jnp.concatenate(edge_src).astype(jnp.int32),
        "receivers": jnp.concatenate(edge_dst).astype(jnp.int32),
        "n_seeds": seeds.shape[0],
    }


def sampled_sizes(batch_nodes: int, fanouts: tuple[int, ...]) -> tuple[int, int]:
    """(n_sampled_nodes, n_sampled_edges) for static-shape dry-run specs."""
    n_nodes, n_edges, layer = batch_nodes, 0, batch_nodes
    for f in fanouts:
        n_edges += layer * f
        layer = layer * f
        n_nodes += layer
    return n_nodes, n_edges


def block_graph_from_sample(sample: dict, feats: Array, d_edge: int) -> dict:
    """Assemble a MeshGraphNet graph dict from a neighbor_sample block.

    feats: (T, d_node_in) features for sample['node_ids'] (gathered by the
    data pipeline). Edge features are relative: |x_src - x_dst| projected
    to d_edge dims (cheap stand-in for mesh-relative coordinates).
    """
    x = feats
    s, r = sample["senders"], sample["receivers"]
    diff = x[s, :d_edge] - x[r, :d_edge]
    return {
        "nodes": x,
        "edges": diff,
        "senders": s,
        "receivers": r,
    }
