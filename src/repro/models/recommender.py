"""The paper's Appendix-B recommender — embedding-MLP rating model.

"Matrix factorization flavour where the dot product is replaced with a
neural net" (Mnih & Salakhutdinov 2008 / Covington et al. 2016 style):

  * user/item embeddings of dim 20 (e_u, e_i),
  * user/item per-rating intercept vectors of dim 5 (q_u, q_i),
  * concat(e_u, e_i) -> hidden 15 -> ReLU -> dropout 0.1 -> 5 utilities,
  * + q_u + q_i, softmax over the 5 rating levels {1..5},
  * point prediction = probability-weighted sum of rating values.

Trained with Adam(lr=0.01), batch 200, 5 epochs, cross-entropy — exactly
the Appendix-B recipe. The learned user embeddings are the covariates X
consumed by the paper's lambda predictor (Algorithm 1), and
``utilities()`` produces the per-user item-utility vector u in [1, 5]
that seeds the constrained ranking problem.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_shard
from repro.models.layers import dense_init

Array = jax.Array

RATING_VALUES = jnp.arange(1.0, 6.0)  # {1,2,3,4,5}


@dataclass(frozen=True)
class RecommenderConfig:
    name: str = "paper_recommender"
    n_users: int = 1000
    n_items: int = 1000
    d_embed: int = 20
    n_ratings: int = 5
    d_hidden: int = 15
    dropout: float = 0.1
    dtype: Any = jnp.float32

    @property
    def n_params(self) -> int:
        emb = (self.n_users + self.n_items) * (self.d_embed + self.n_ratings)
        mlp = (2 * self.d_embed) * self.d_hidden + self.d_hidden
        out = self.d_hidden * self.n_ratings + self.n_ratings
        return emb + mlp + out


class PaperRecommender:
    def __init__(self, cfg: RecommenderConfig):
        self.cfg = cfg

    def init(self, key) -> dict:
        cfg = self.cfg
        ku, ki, k1, k2 = jax.random.split(key, 4)
        return {
            "user_emb": jax.random.normal(ku, (cfg.n_users, cfg.d_embed)) * 0.1,
            "item_emb": jax.random.normal(ki, (cfg.n_items, cfg.d_embed)) * 0.1,
            "user_int": jnp.zeros((cfg.n_users, cfg.n_ratings)),
            "item_int": jnp.zeros((cfg.n_items, cfg.n_ratings)),
            "w1": dense_init(k1, (2 * cfg.d_embed, cfg.d_hidden), cfg.dtype),
            "b1": jnp.zeros((cfg.d_hidden,)),
            "w2": dense_init(k2, (cfg.d_hidden, cfg.n_ratings), cfg.dtype),
            "b2": jnp.zeros((cfg.n_ratings,)),
        }

    def logical_axes(self) -> dict:
        return {
            "user_emb": ("users_db", None),
            "item_emb": ("items", None),
            "user_int": ("users_db", None),
            "item_int": ("items", None),
            "w1": ("mlp", None), "b1": (None,),
            "w2": (None, None), "b2": (None,),
        }

    # -- forward -------------------------------------------------------

    def rating_logits(self, params, uid: Array, iid: Array,
                      *, key: Array | None = None) -> Array:
        """(B,) user ids x (B,) item ids -> (B, 5) rating logits."""
        cfg = self.cfg
        eu = params["user_emb"][uid]
        ei = params["item_emb"][iid]
        h = jnp.concatenate([eu, ei], axis=-1)
        h = jax.nn.relu(h @ params["w1"] + params["b1"])
        if key is not None and cfg.dropout > 0:
            keep = jax.random.bernoulli(key, 1.0 - cfg.dropout, h.shape)
            h = h * keep / (1.0 - cfg.dropout)
        logits = h @ params["w2"] + params["b2"]
        return logits + params["user_int"][uid] + params["item_int"][iid]

    def predict_rating(self, params, uid: Array, iid: Array) -> Array:
        """Point prediction in [1, 5]: probability-weighted rating sum."""
        probs = jax.nn.softmax(self.rating_logits(params, uid, iid), axis=-1)
        return probs @ RATING_VALUES

    def utilities(self, params, uid: Array) -> Array:
        """(B,) user ids -> (B, n_items) utility matrix u (in [1,5]).

        The per-user item-utility vector that seeds the ranking problem.
        Item axis shardable over 'items' ('model' mesh axis) for the
        serving-fleet layout.
        """
        cfg = self.cfg
        B = uid.shape[0]
        all_items = jnp.arange(cfg.n_items)
        uid_g = jnp.repeat(uid, cfg.n_items)
        iid_g = jnp.tile(all_items, B)
        u = self.predict_rating(params, uid_g, iid_g).reshape(B, cfg.n_items)
        return logical_shard(u, "batch", "items")

    def user_covariates(self, params, uid: Array) -> Array:
        """Learned user embeddings = the paper's covariates X."""
        return params["user_emb"][uid]

    # -- train (Appendix-B recipe) ---------------------------------------

    def loss(self, params, batch, *, key: Array | None = None):
        logits = self.rating_logits(
            params, batch["uid"], batch["iid"], key=key)
        labels = batch["rating"] - 1                         # 1..5 -> 0..4
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
        return nll, {"loss": nll}

    def train(self, params, data: dict, *, key: Array, epochs: int = 5,
              batch_size: int = 200, lr: float = 0.01):
        """Mini-batch Adam training per Appendix B. data: {uid, iid, rating}
        flat arrays of observed ratings."""
        from repro.optim import adam_init, adam_update

        n = data["uid"].shape[0]
        steps_per_epoch = max(n // batch_size, 1)
        opt = adam_init(params)

        @jax.jit
        def step(params, opt, idx, key):
            batch = {k: v[idx] for k, v in data.items()}
            (loss, _), grads = jax.value_and_grad(
                lambda p: self.loss(p, batch, key=key), has_aux=True)(params)
            params, opt = adam_update(grads, opt, params, lr=lr)
            return params, opt, loss

        losses = []
        for _ in range(epochs):
            key, kperm = jax.random.split(key)
            perm = jax.random.permutation(kperm, n)
            for s in range(steps_per_epoch):
                key, kd = jax.random.split(key)
                idx = jax.lax.dynamic_slice_in_dim(
                    perm, s * batch_size, batch_size)
                params, opt, loss = step(params, opt, idx, kd)
            losses.append(float(loss))
        return params, losses
