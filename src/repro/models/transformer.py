"""Decoder-only transformer LM family (dense + MoE) — pjit-native.

One parameterization, three lowered entry points (matching the assigned
shape cells):

  * ``train_step``   — causal-LM step: fwd (chunked-flash attention) +
                       bwd + Adam update. Layers are scan-stacked (compact
                       HLO, O(1) compile in depth) and remat'ed.
  * ``prefill``      — build the KV cache for a prompt, return last-token
                       logits (inference-prefill cells).
  * ``decode_step``  — one new token against a KV cache of static length
                       (inference-decode / long-context cells).

Params are plain dicts with scan-stacked layer leaves (leading dim L).
`lm_logical_axes` mirrors the params tree with per-dim logical axis names
consumed by repro.distributed.sharding (FSDP over 'data', TP/EP over
'model', DP over ('pod','data')).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_shard
from repro.models.layers import (
    apply_rope,
    attn_axes,
    attn_init,
    chunked_causal_attention,
    decode_attention,
    dense_causal_attention,
    dense_init,
    embed_init,
    ffn_apply,
    ffn_axes,
    ffn_init,
    moe_apply,
    moe_axes,
    moe_init,
    rms_norm,
)

Array = jax.Array


@dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 2
    d_head: int = 64
    d_ff: int = 512
    vocab: int = 1024
    # MoE
    moe: bool = False
    n_experts: int = 8
    top_k: int = 2
    d_ff_moe: int = 256
    shared_expert: bool = False
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    # 'onehot': (T*K, E) one-hot cumsum positions (baseline).
    # 'sort':   sort-based positions + capacity-sharded dispatch buffers
    #           (§Perf variant: no (T*K, E) matrices, no full-buffer
    #           all-reduce).
    moe_dispatch: str = "onehot"
    # numerics / execution
    rope_theta: float = 500_000.0
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.bfloat16
    remat: str = "full"            # 'none' | 'full' | 'dots'
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 512
    attn_skip_masked: bool = True
    dense_attn_threshold: int = 1024   # S <= this -> dense attention
    tie_embeddings: bool = False
    moment_dtype: Any = jnp.float32

    @property
    def params_per_layer(self) -> int:
        D, H, KV, Dh = self.d_model, self.n_heads, self.n_kv_heads, self.d_head
        attn = D * H * Dh + 2 * D * KV * Dh + H * Dh * D
        if self.moe:
            ffn = self.n_experts * 3 * D * self.d_ff_moe + D * self.n_experts
            if self.shared_expert:
                ffn += 3 * D * self.d_ff_moe
        else:
            ffn = 3 * D * self.d_ff
        return attn + ffn + 2 * D

    @property
    def n_params(self) -> int:
        embed = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        return self.n_layers * self.params_per_layer + embed + self.d_model

    @property
    def active_params_per_token(self) -> int:
        """N_active for MODEL_FLOPS = 6 * N_active * D_tokens."""
        D, H, KV, Dh = self.d_model, self.n_heads, self.n_kv_heads, self.d_head
        attn = D * H * Dh + 2 * D * KV * Dh + H * Dh * D
        if self.moe:
            ffn = self.top_k * 3 * D * self.d_ff_moe
            if self.shared_expert:
                ffn += 3 * D * self.d_ff_moe
        else:
            ffn = 3 * D * self.d_ff
        layer = attn + ffn
        embed = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        return self.n_layers * layer + embed


# --------------------------------------------------------------------------
# Init + logical axes
# --------------------------------------------------------------------------

def _layer_init(key, cfg: LMConfig) -> dict:
    ka, kf = jax.random.split(key)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), cfg.param_dtype),
        "ln2": jnp.zeros((cfg.d_model,), cfg.param_dtype),
        "attn": attn_init(ka, cfg),
    }
    if cfg.moe:
        p["moe"] = moe_init(kf, cfg)
    else:
        p["ffn"] = ffn_init(kf, cfg)
    return p


def lm_init(key, cfg: LMConfig) -> dict:
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    # Stack per-layer params on a leading L axis (scan convention).
    layers = jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys)
    params = {
        "embed": embed_init(ke, cfg.vocab, cfg.d_model, cfg.param_dtype),
        "layers": layers,
        "final_ln": jnp.zeros((cfg.d_model,), cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kh, (cfg.d_model, cfg.vocab),
                                       cfg.param_dtype)
    return params


def lm_logical_axes(cfg: LMConfig) -> dict:
    L = "layers"
    layer_axes = {
        "ln1": (L, "norm"),
        "ln2": (L, "norm"),
        "attn": {k: (L,) + v for k, v in attn_axes().items()},
    }
    if cfg.moe:
        ma = moe_axes(cfg)
        layer_axes["moe"] = jax.tree.map(
            lambda v: (L,) + v, ma, is_leaf=lambda x: isinstance(x, tuple)
        )
    else:
        layer_axes["ffn"] = {k: (L,) + v for k, v in ffn_axes().items()}
    axes = {
        "embed": ("vocab", "embed_fsdp"),
        "layers": layer_axes,
        "final_ln": ("norm",),
    }
    if not cfg.tie_embeddings:
        axes["lm_head"] = ("embed_fsdp", "vocab")
    return axes


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------

def _attention_fwd(p_attn, x, cfg: LMConfig, positions):
    B, S, D = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = (x @ p_attn["wq"].astype(x.dtype)).reshape(B, S, H, Dh)
    k = (x @ p_attn["wk"].astype(x.dtype)).reshape(B, S, KV, Dh)
    v = (x @ p_attn["wv"].astype(x.dtype)).reshape(B, S, KV, Dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = logical_shard(q, "batch", "seq", "heads", None)
    k = logical_shard(k, "batch", "seq", "kv_heads", None)
    v = logical_shard(v, "batch", "seq", "kv_heads", None)
    if S <= cfg.dense_attn_threshold:
        out = dense_causal_attention(q, k, v)
    else:
        cq = min(cfg.attn_chunk_q, S)
        ck = min(cfg.attn_chunk_kv, S)
        out = chunked_causal_attention(
            q, k, v, chunk_q=cq, chunk_kv=ck,
            skip_masked_chunks=cfg.attn_skip_masked,
        )
    out = out.reshape(B, S, H * Dh)
    return out @ p_attn["wo"].astype(x.dtype), (k, v)


def _layer_fwd(p, x, cfg: LMConfig, positions):
    h, kv = _attention_fwd(p["attn"], rms_norm(x, p["ln1"]), cfg, positions)
    x = x + h
    x = logical_shard(x, "batch", "seq", "embed")
    hn = rms_norm(x, p["ln2"])
    if cfg.moe:
        h2, aux = _moe_dispatching(p["moe"], hn, cfg)
    else:
        h2, aux = ffn_apply(p["ffn"], hn), jnp.zeros((), jnp.float32)
    x = x + h2
    x = logical_shard(x, "batch", "seq", "embed")
    return x, aux, kv


def _moe_dispatching(p_moe, hn, cfg: LMConfig):
    """Select the MoE execution path. 'shmap' (§Perf variant) requires an
    active mesh whose 'model' axis divides n_experts; otherwise falls
    back to the GSPMD-global dispatch."""
    if cfg.moe_dispatch == "shmap":
        from repro.distributed.sharding import current_mesh
        from repro.models.layers import moe_apply_shmap
        mesh = current_mesh()
        ep = mesh.shape.get("model", 1) if mesh is not None else 1
        if mesh is not None and cfg.n_experts % ep == 0:
            return moe_apply_shmap(p_moe, hn, cfg, mesh)
    return moe_apply(p_moe, hn, cfg)


def _maybe_remat(fn, cfg: LMConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


def lm_forward(params, tokens: Array, cfg: LMConfig) -> tuple[Array, Array]:
    """tokens (B, S) -> (logits (B, S, V), aux_loss)."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    x = logical_shard(x, "batch", "seq", "embed")
    positions = jnp.arange(S)[None, :]

    def body(x, p_layer):
        y, aux, _ = _layer_fwd(p_layer, x, cfg, positions)
        return y, aux

    body = _maybe_remat(body, cfg)
    x, aux = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["final_ln"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head.astype(x.dtype)
    logits = logical_shard(logits, "batch", "seq", "vocab")
    return logits, jnp.sum(aux)


def lm_loss(params, batch, cfg: LMConfig):
    """batch: {'tokens': (B,S), 'labels': (B,S)} -> (loss, metrics)."""
    logits, aux = lm_forward(params, batch["tokens"], cfg)
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    # Label logit as a masked reduction (not take_along_axis): the vocab
    # axis is sharded over 'model'; a gather along a sharded dim would
    # make GSPMD all-gather the full (B, S, V) logits. The masked-sum
    # stays elementwise-sharded and reduces with one tiny psum.
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    label_mask = vocab_iota == batch["labels"][..., None]
    label_logit = jnp.sum(jnp.where(label_mask, logits, 0.0), axis=-1)
    nll = jnp.mean(lse - label_logit)
    loss = nll + cfg.aux_loss_weight * aux
    return loss, {"nll": nll, "aux": aux}


# --------------------------------------------------------------------------
# Train step
# --------------------------------------------------------------------------

def lm_train_step(params, opt_state, batch, cfg: LMConfig, *, lr: float = 3e-4,
                  clip_norm: float = 1.0):
    from repro.optim import adam_update
    from repro.optim.clip import clip_by_global_norm

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm_loss(p, batch, cfg), has_aux=True
    )(params)
    grads, gnorm = clip_by_global_norm(grads, clip_norm)
    params, opt_state = adam_update(grads, opt_state, params, lr=lr)
    metrics = dict(metrics, loss=loss, grad_norm=gnorm)
    return params, opt_state, metrics


# --------------------------------------------------------------------------
# Inference: prefill + decode
# --------------------------------------------------------------------------

def lm_prefill(params, tokens: Array, cfg: LMConfig):
    """Build the KV cache for a prompt.

    Returns (cache {'k','v': (L, B, S, KV, Dh)}, last-token logits (B, V)).
    """
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    x = logical_shard(x, "batch", "seq", "embed")
    positions = jnp.arange(S)[None, :]

    def body(x, p_layer):
        y, _, (k, v) = _layer_fwd(p_layer, x, cfg, positions)
        k = logical_shard(k, "kv_batch", "seq_shard", None, None)
        v = logical_shard(v, "kv_batch", "seq_shard", None, None)
        return y, (k, v)

    body = _maybe_remat(body, cfg)
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x_last = rms_norm(x[:, -1, :], params["final_ln"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (x_last @ head.astype(x.dtype)).astype(jnp.float32)
    return {"k": ks, "v": vs}, logits


def make_decode_cache(cfg: LMConfig, batch: int, max_seq: int, dtype=None):
    dtype = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.d_head)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def cache_logical_axes(cfg: LMConfig) -> dict:
    return {
        "k": ("layers", "kv_batch", "seq_shard", None, None),
        "v": ("layers", "kv_batch", "seq_shard", None, None),
    }


def lm_decode_step(params, cache, token: Array, pos: Array, cfg: LMConfig):
    """One decode step. token: (B,) int32; pos: scalar int32 (next write
    index; tokens at cache positions <= pos are attended after the write).

    Returns (logits (B, V) f32, updated cache).
    """
    B = token.shape[0]
    x = jnp.take(params["embed"], token, axis=0).astype(cfg.dtype)[:, None, :]
    positions = jnp.full((B, 1), pos, jnp.int32)
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head

    def body(x, layer_in):
        p, k_cache, v_cache = layer_in
        hn = rms_norm(x, p["ln1"])
        q = (hn @ p["attn"]["wq"].astype(x.dtype)).reshape(B, 1, H, Dh)
        k = (hn @ p["attn"]["wk"].astype(x.dtype)).reshape(B, 1, KV, Dh)
        v = (hn @ p["attn"]["wv"].astype(x.dtype)).reshape(B, 1, KV, Dh)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), pos, axis=1)
        k_cache = logical_shard(k_cache, "kv_batch", "seq_shard", None, None)
        v_cache = logical_shard(v_cache, "kv_batch", "seq_shard", None, None)
        att = decode_attention(q, k_cache, v_cache, pos)
        h = att.reshape(B, 1, H * Dh) @ p["attn"]["wo"].astype(x.dtype)
        x = x + h
        hn2 = rms_norm(x, p["ln2"])
        if cfg.moe:
            h2, _ = moe_apply(p["moe"], hn2, cfg)
        else:
            h2 = ffn_apply(p["ffn"], hn2)
        return x + h2, (k_cache, v_cache)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x_last = rms_norm(x[:, 0, :], params["final_ln"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (x_last @ head.astype(x.dtype)).astype(jnp.float32)
    return logits, {"k": ks, "v": vs}


class TransformerLM:
    """Thin OO wrapper binding an LMConfig to the functional entry points."""

    def __init__(self, cfg: LMConfig):
        self.cfg = cfg

    def init(self, key):
        return lm_init(key, self.cfg)

    def logical_axes(self):
        return lm_logical_axes(self.cfg)

    def forward(self, params, tokens):
        return lm_forward(params, tokens, self.cfg)

    def loss(self, params, batch):
        return lm_loss(params, batch, self.cfg)

    def train_step(self, params, opt_state, batch, **kw):
        return lm_train_step(params, opt_state, batch, self.cfg, **kw)

    def prefill(self, params, tokens):
        return lm_prefill(params, tokens, self.cfg)

    def decode_step(self, params, cache, token, pos):
        return lm_decode_step(params, cache, token, pos, self.cfg)

    def make_cache(self, batch, max_seq, dtype=None):
        return make_decode_cache(self.cfg, batch, max_seq, dtype)
