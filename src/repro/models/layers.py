"""Shared neural building blocks — pure-function style, pjit/shard_map
friendly (no framework; params are plain dict pytrees; every block has an
`*_axes` twin returning per-dim logical axis names for the sharding rules).

Blocks: RMSNorm, RoPE, GQA attention (training, chunked-flash prefill,
KV-cache decode), SwiGLU FFN, scatter-dispatch MoE (EP-shardable),
embedding. Numerics: params in cfg.param_dtype, activations in cfg.dtype,
softmax/statistics in f32.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.compat import shard_map
from repro.distributed.sharding import logical_shard

Array = jax.Array


# --------------------------------------------------------------------------
# Init helpers
# --------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------

def rms_norm(x: Array, w: Array, eps: float = 1e-6) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, jnp.float32) / d_head))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                       # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]                     # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : d_head // 2], x32[..., d_head // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention — three execution modes sharing one parameterization
# --------------------------------------------------------------------------

def attn_init(key, cfg) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    D, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    return {
        "wq": dense_init(kq, (D, H * Dh), cfg.param_dtype),
        "wk": dense_init(kk, (D, KV * Dh), cfg.param_dtype),
        "wv": dense_init(kv, (D, KV * Dh), cfg.param_dtype),
        "wo": dense_init(ko, (H * Dh, D), cfg.param_dtype, scale=1.0 / math.sqrt(H * Dh)),
    }


def attn_axes() -> dict:
    return {
        "wq": ("qkv_in", "qkv_out"),
        "wk": ("qkv_in", "qkv_out"),
        "wv": ("qkv_in", "qkv_out"),
        "wo": ("o_in", "o_out"),
    }


def _qkv(params, x, cfg, positions):
    B, S, D = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = (x @ params["wq"].astype(x.dtype)).reshape(B, S, H, Dh)
    k = (x @ params["wk"].astype(x.dtype)).reshape(B, S, KV, Dh)
    v = (x @ params["wv"].astype(x.dtype)).reshape(B, S, KV, Dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = logical_shard(q, "batch", "seq", "heads", None)
    k = logical_shard(k, "batch", "seq", "kv_heads", None)
    v = logical_shard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def _gqa_logits(q: Array, k: Array) -> Array:
    """q: (B, Sq, H, Dh), k: (B, Sk, KV, Dh) -> (B, KV, G, Sq, Sk) f32."""
    B, Sq, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, Dh)
    logits = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32
    )
    return logits / math.sqrt(Dh)


def _gqa_combine(probs: Array, v: Array, dtype) -> Array:
    """probs: (B, KV, G, Sq, Sk), v: (B, Sk, KV, Dh) -> (B, Sq, H, Dh)."""
    B, KV, G, Sq, Sk = probs.shape
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(dtype), v)
    return out.reshape(B, Sq, KV * G, v.shape[-1])


def dense_causal_attention(q, k, v) -> Array:
    """Full-materialization causal attention.

    The (B, KV, G, Sq, Sk) score tensor is sharded on the QUERY-sequence
    axis ('seq_attn' -> 'model'): when the head count does not divide the
    TP axis (phi3: 40 heads on 16) head-dim constraints are dropped and
    XLA would otherwise replicate attention activations per device —
    Sq-sharding restores 16-way parallelism for any head count
    (§Perf hillclimb C). The constraint is a no-op off-mesh.
    """
    B, Sq, H, Dh = q.shape
    Sk = k.shape[1]
    # Pin the WHOLE path to one Sq scheme: q and out Sq-sharded, k/v
    # replicated over 'model'. Constraining only the scores lets GSPMD
    # propagate a conflicting layout into the backward and all-gather the
    # full (B, KV, G, Sq, Sk) probs (43 GB f32/layer at phi3 scale).
    q = logical_shard(q, "batch", "seq_attn", None, None)
    k = logical_shard(k, "batch", None, None, None)
    v = logical_shard(v, "batch", None, None, None)
    logits = _gqa_logits(q, k)
    logits = logical_shard(logits, "batch", None, None, "seq_attn", None)
    qi = jnp.arange(Sq)[:, None] + (Sk - Sq)
    ki = jnp.arange(Sk)[None, :]
    mask = qi >= ki
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = logical_shard(probs, "batch", None, None, "seq_attn", None)
    out = _gqa_combine(probs, v, q.dtype)
    return logical_shard(out, "batch", "seq_attn", None, None)


def chunked_causal_attention(
    q: Array, k: Array, v: Array, *, chunk_q: int, chunk_kv: int,
    skip_masked_chunks: bool = True,
) -> Array:
    """Flash-style double-chunked causal attention in pure JAX.

    Never materializes the (Sq, Sk) score matrix: scans q in chunks of
    `chunk_q`; for each q chunk scans kv chunks with a running
    (max, denominator, accumulator). TPU-native adaptation of the memory
    hierarchy argument — each chunk's score tile lives in VMEM.

    With `skip_masked_chunks` (beyond-paper perf option) fully-masked kv
    chunks are skipped via early bailout inside the kv scan (saves ~2x
    FLOPs for causal attention, matching an upper-triangular schedule).
    """
    B, Sq, H, Dh = q.shape
    Sk = k.shape[1]
    assert Sq % chunk_q == 0 and Sk % chunk_kv == 0, (Sq, Sk, chunk_q, chunk_kv)
    nq, nk = Sq // chunk_q, Sk // chunk_kv
    KV = k.shape[2]
    G = H // KV
    offset = Sk - Sq  # query i attends to keys <= i + offset

    k_chunks = k.reshape(B, nk, chunk_kv, KV, Dh)
    v_chunks = v.reshape(B, nk, chunk_kv, KV, Dh)

    def q_chunk_body(_, qi):
        q_c = jax.lax.dynamic_slice_in_dim(q, qi * chunk_q, chunk_q, axis=1)
        q_pos = qi * chunk_q + jnp.arange(chunk_q) + offset

        def kv_body(carry, kj):
            m, l, acc = carry
            k_c = k_chunks[:, kj]
            v_c = v_chunks[:, kj]
            logits = _gqa_logits(q_c, k_c)          # (B,KV,G,cq,ck) f32
            k_pos = kj * chunk_kv + jnp.arange(chunk_kv)
            mask = q_pos[:, None] >= k_pos[None, :]
            logits = jnp.where(mask, logits, -1e30)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            scale = jnp.exp(m - m_new)
            l_new = l * scale + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bkgqs,bskd->bkgqd", p, v_c.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * scale[..., None] + pv
            return (m_new, l_new, acc_new), None

        def kv_skip(carry, kj):
            # Chunk entirely in the masked (future) region: no-op.
            del kj
            return carry, None

        def kv_step(carry, kj):
            if not skip_masked_chunks:
                return kv_body(carry, kj)
            first_q = qi * chunk_q + offset
            needed = kj * chunk_kv <= first_q + chunk_q - 1
            return jax.lax.cond(needed, kv_body, kv_skip, carry, kj)

        m0 = jnp.full((B, KV, G, chunk_q), -1e30, jnp.float32)
        l0 = jnp.zeros((B, KV, G, chunk_q), jnp.float32)
        a0 = jnp.zeros((B, KV, G, chunk_q, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        out = jnp.moveaxis(out, 3, 1)               # (B,cq,KV,G,Dh)
        return None, out.reshape(B, chunk_q, KV * G, Dh).astype(q.dtype)

    _, chunks = jax.lax.scan(q_chunk_body, None, jnp.arange(nq))
    # chunks: (nq, B, chunk_q, H, Dh) -> (B, Sq, H, Dh)
    return jnp.moveaxis(chunks, 0, 1).reshape(B, Sq, H, Dh)


def decode_attention(q: Array, k_cache: Array, v_cache: Array, pos: Array) -> Array:
    """One-token attention against a KV cache.

    q: (B, 1, H, Dh); caches: (B, S_max, KV, Dh); pos: () current length-1
    index (entries at positions > pos are masked). Memory-bound: streams
    the cache once. Softmax stats in f32; safe under sequence sharding
    (GSPMD reduces the stats over the sharded axis).
    """
    B, _, H, Dh = q.shape
    S = k_cache.shape[1]
    logits = _gqa_logits(q, k_cache)                # (B,KV,G,1,S)
    valid = jnp.arange(S) <= pos                    # pos: scalar int32
    logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    probs = p / denom
    return _gqa_combine(probs, v_cache, q.dtype)


# --------------------------------------------------------------------------
# SwiGLU FFN
# --------------------------------------------------------------------------

def ffn_init(key, cfg, d_ff: int | None = None) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    return {
        "w_gate": dense_init(k1, (D, F), cfg.param_dtype),
        "w_up": dense_init(k2, (D, F), cfg.param_dtype),
        "w_down": dense_init(k3, (F, D), cfg.param_dtype, scale=1.0 / math.sqrt(F)),
    }


def ffn_axes() -> dict:
    return {
        "w_gate": ("ffn_in", "ffn_out"),
        "w_up": ("ffn_in", "ffn_out"),
        "w_down": ("ffn_down_in", "ffn_down_out"),
    }


def ffn_apply(params, x: Array) -> Array:
    h = jax.nn.silu(x @ params["w_gate"].astype(x.dtype)) * (
        x @ params["w_up"].astype(x.dtype)
    )
    h = logical_shard(h, "batch", "seq", "mlp")
    return h @ params["w_down"].astype(x.dtype)


# --------------------------------------------------------------------------
# Mixture of Experts — scatter dispatch (no T·E·C·D one-hot einsum)
# --------------------------------------------------------------------------
#
# Dispatch = sort tokens by expert + scatter into an (E, C, D) buffer;
# data movement O(T·k·D) instead of the Mesh-TF dispatch einsum's
# O(T·E·C·D) FLOPs (which would dominate the roofline at E=384). The
# expert matmuls are batched einsums over the (sharded) expert axis — EP
# over 'model' with GSPMD-inserted redistribution at the scatter/gather.

def moe_init(key, cfg) -> dict:
    kr, ke, ks = jax.random.split(key, 3)
    D, E, Fm = cfg.d_model, cfg.n_experts, cfg.d_ff_moe
    k1, k2, k3 = jax.random.split(ke, 3)
    params = {
        "router": dense_init(kr, (D, E), jnp.float32),
        "experts": {
            "w_gate": dense_init(k1, (E, D, Fm), cfg.param_dtype),
            "w_up": dense_init(k2, (E, D, Fm), cfg.param_dtype),
            "w_down": dense_init(k3, (E, Fm, D), cfg.param_dtype,
                                 scale=1.0 / math.sqrt(Fm)),
        },
    }
    if cfg.shared_expert:
        params["shared"] = ffn_init(ks, cfg, d_ff=cfg.d_ff_moe)
    return params


def moe_axes(cfg) -> dict:
    axes = {
        "router": (None, None),
        "experts": {
            "w_gate": ("experts", "expert_in", "expert_out"),
            "w_up": ("experts", "expert_in", "expert_out"),
            "w_down": ("experts", "expert_out", "expert_in"),
        },
    }
    if cfg.shared_expert:
        axes["shared"] = ffn_axes()
    return axes


def moe_capacity(n_tokens: int, cfg) -> int:
    c = int(math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    if getattr(cfg, "moe_dispatch", "onehot") == "sort":
        # capacity axis is sharded over ('pod','data') in the sort path
        return max(256, -(-c // 256) * 256)
    return max(8, -(-c // 8) * 8)  # round up to 8 for lane alignment


def moe_apply(params, x: Array, cfg) -> tuple[Array, Array]:
    """x: (B, S, D) -> (y (B,S,D), aux_loss scalar).

    Top-k routing with capacity; overflow tokens are dropped (contribute
    only through the shared expert / residual). Load-balance aux loss per
    Shazeer et al. / Switch.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    C = moe_capacity(T, cfg)
    xt = x.reshape(T, D)

    router_logits = (xt.astype(jnp.float32) @ params["router"])   # (T, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)               # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Load-balance loss: E * sum_e (frac_tokens_e * mean_prob_e)
    me = jnp.mean(probs, axis=0)
    one_hot_top1 = jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux_loss = E * jnp.sum(me * ce)

    # ---- scatter dispatch ----
    flat_e = expert_ids.reshape(-1)                                # (T*K,)
    flat_g = gate_vals.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), K)
    if getattr(cfg, "moe_dispatch", "onehot") == "sort":
        # Sort-based positions: O(T·K) vectors only. The one-hot variant
        # below builds (T·K, E) int32 matrices whose partitioned cumsum
        # makes GSPMD all-gather ~13 GB/layer/device at kimi-k2 scale
        # (§Perf). Stable sort keeps token order within an expert, so
        # capacity drop semantics match the one-hot path exactly.
        TK = flat_e.shape[0]
        order = jnp.argsort(flat_e, stable=True)                   # (TK,)
        sorted_e = flat_e[order]
        counts = jax.ops.segment_sum(
            jnp.ones((TK,), jnp.int32), flat_e, num_segments=E)    # (E,)
        starts = jnp.cumsum(counts) - counts                       # (E,)
        pos_sorted = jnp.arange(TK, dtype=jnp.int32) - starts[sorted_e]
        pos = jnp.zeros((TK,), jnp.int32).at[order].set(pos_sorted)
    else:
        # Position of each assignment within its expert = rank among equal
        # expert ids in stable token order, via one-hot cumsum.
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # (TK, E)
        pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)           # counts before
        pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos < C
    safe_pos = jnp.where(keep, pos, 0)

    buf = jnp.zeros((E, C, D), x.dtype)
    src = jnp.where(keep[:, None], xt[flat_tok], 0)
    buf = buf.at[flat_e, safe_pos].add(src, mode="drop")
    if getattr(cfg, "moe_dispatch", "onehot") == "sort":
        # capacity axis sharded over ('pod','data'): the scatter-add
        # partial reduction moves buf-shard-sized pieces, not full bufs
        buf = logical_shard(buf, "experts", "expert_cap", None)
    else:
        buf = logical_shard(buf, "experts", None, None)

    # ---- expert FFN (batched over sharded expert axis) ----
    sort_path = getattr(cfg, "moe_dispatch", "onehot") == "sort"
    cap_axis = "expert_cap" if sort_path else None
    we = params["experts"]
    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", buf, we["w_gate"].astype(x.dtype))
    ) * jnp.einsum("ecd,edf->ecf", buf, we["w_up"].astype(x.dtype))
    h = logical_shard(h, "experts", cap_axis, "expert_out")
    out_buf = jnp.einsum("ecf,efd->ecd", h, we["w_down"].astype(x.dtype))
    out_buf = logical_shard(out_buf, "experts", cap_axis, None)

    # ---- combine (gather back) ----
    gathered = out_buf[flat_e, safe_pos]                           # (TK, D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    y = jnp.zeros((T, D), x.dtype)
    y = y.at[flat_tok].add(gathered * flat_g[:, None].astype(x.dtype))

    if cfg.shared_expert:
        y = y + ffn_apply(params["shared"], xt)
    return y.reshape(B, S, D), aux_loss


# --------------------------------------------------------------------------
# shard_map expert-parallel MoE (§Perf variant 'shmap')
# --------------------------------------------------------------------------
#
# The GSPMD-global dispatch above lets the partitioner choose the
# communication for the (E, C, D) scatter — at kimi-k2 scale it chooses
# full-buffer all-reduces over 'data' (37 GB/layer/device) plus (T, D)
# all-reduces for the combine (§Perf log). This manual version makes the
# EP structure explicit:
#
#   * tokens are sharded over ('pod','data') and REPLICATED over 'model'
#     -> each model shard already holds every token it could need, so
#     DISPATCH IS COMMUNICATION-FREE: each shard scatters its local
#     tokens into buffers for ITS OWN E/16 experts;
#   * expert weights stay ZeRO-3-sharded over ('pod','data'); the
#     explicit all-gather here is the standard FSDP per-layer gather
#     (backward auto-generates the reduce-scatter);
#   * COMBINE is one psum over 'model' of the (T_local, D) partial sums.
#
# Capacity is enforced per data shard (C_local = ceil-div of the global
# C), the standard EP drop semantics; with capacity_factor 1.25 the
# difference from global capacity is negligible (and exact when no
# tokens drop — asserted in tests).

def _positions_by_expert(flat_e: Array, n_experts: int) -> Array:
    """Stable rank of each assignment within its expert id — O(TK log TK)
    sort, no (TK, E) matrices."""
    TK = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jax.ops.segment_sum(
        jnp.ones((TK,), jnp.int32), flat_e, num_segments=n_experts)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(TK, dtype=jnp.int32) - starts[sorted_e]
    return jnp.zeros((TK,), jnp.int32).at[order].set(pos_sorted)


def moe_apply_shmap(params, x: Array, cfg, mesh) -> tuple[Array, Array]:
    """shard_map twin of moe_apply. x: (B, S, D) global."""
    from jax.sharding import PartitionSpec as P

    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_batch = 1
    for a in batch_axes:
        n_batch *= mesh.shape[a]
    ep = mesh.shape.get("model", 1)
    E_l = E // ep if E % ep == 0 else E
    T = B * S
    T_l = T // n_batch
    C_l = moe_capacity(T_l, cfg)

    def body(x_l, router_w, wg_l, wu_l, wd_l):
        B_l = x_l.shape[0]
        xt = x_l.reshape(B_l * S, D)
        tl = xt.shape[0]
        probs = jax.nn.softmax(xt.astype(jnp.float32) @ router_w, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
        # combine in activation dtype: keeping f32 gates makes AD produce
        # f32 (T*K, D) tensors in the backward (2x the HBM traffic of the
        # whole dispatch path — §Perf log)
        gate_vals = gate_vals.astype(x_l.dtype)

        # load-balance aux loss with GLOBAL token statistics
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32),
                      axis=0)
        if batch_axes:
            me = jax.lax.pmean(me, batch_axes)
            ce = jax.lax.pmean(ce, batch_axes)
        aux = E * jnp.sum(me * ce)

        flat_e = expert_ids.reshape(-1)
        flat_g = gate_vals.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(tl), K)
        pos = _positions_by_expert(flat_e, E)

        e0 = (jax.lax.axis_index("model") * E_l
              if "model" in mesh.axis_names else 0)
        local = jnp.logical_and(flat_e >= e0, flat_e < e0 + E_l)
        keep = jnp.logical_and(local, pos < C_l)
        el = jnp.clip(flat_e - e0, 0, E_l - 1)
        safe_pos = jnp.where(keep, pos, 0)

        # Slot-centric dispatch: scatter only the (tiny, int) slot->token
        # and slot->gate maps, then GATHER token rows per expert slot.
        # Slot count E_l*C_l is ~T*K/ep — scattering (T*K, D) token
        # copies (the naive form) moves ep-times more data and, under AD,
        # materializes (T*K, D) cotangents (§Perf log).
        # Invalid assignments scatter OUT OF BOUNDS (pos = C_l) and are
        # dropped — .set() with in-bounds collisions would be
        # nondeterministic.
        drop_pos = jnp.where(keep, safe_pos, C_l)
        slot_tok = jnp.zeros((E_l, C_l), jnp.int32).at[el, drop_pos].set(
            flat_tok, mode="drop")
        slot_gate = jnp.zeros((E_l, C_l), x_l.dtype).at[el, drop_pos].set(
            flat_g, mode="drop")
        slot_valid = jnp.zeros((E_l, C_l), x_l.dtype).at[el, drop_pos].set(
            jnp.ones_like(flat_g), mode="drop")

        buf = xt[slot_tok] * slot_valid[..., None]           # (E_l, C_l, D)

        # FSDP gather of this shard's expert weights (ZeRO-3).
        # w_gate/w_up shard D on axis 1; w_down (E, F, D) shards D on
        # axis 2 (its expert_in dim).
        if batch_axes:
            wg = jax.lax.all_gather(wg_l, batch_axes, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu_l, batch_axes, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd_l, batch_axes, axis=2, tiled=True)
        else:
            wg, wu, wd = wg_l, wu_l, wd_l

        h = jax.nn.silu(
            jnp.einsum("ecd,edf->ecf", buf, wg.astype(x_l.dtype))
        ) * jnp.einsum("ecd,edf->ecf", buf, wu.astype(x_l.dtype))
        out_buf = jnp.einsum("ecf,efd->ecd", h, wd.astype(x_l.dtype))

        # Slot-driven combine: each valid slot adds gate * out_row to its
        # token — E_l*C_l rows moved, never (T*K, D).
        weighted = out_buf * (slot_gate * slot_valid)[..., None]
        y = jnp.zeros((tl, D), x_l.dtype)
        y = y.at[slot_tok.reshape(-1)].add(
            weighted.reshape(-1, D), mode="drop")
        if "model" in mesh.axis_names:
            y = jax.lax.psum(y, "model")        # the EP combine
        return y.reshape(B_l, S, D), aux

    batch_spec = batch_axes if batch_axes else None
    w_spec = P("model", batch_spec, None)
    we = params["experts"]
    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_spec, None, None), P(), w_spec, w_spec,
                  P("model", None, batch_spec)),
        out_specs=(P(batch_spec, None, None), P()),
        check_vma=False,
    )(x, params["router"], we["w_gate"], we["w_up"], we["w_down"])

    if cfg.shared_expert:
        B, S, D = x.shape
        y = y + ffn_apply(params["shared"], x.reshape(B * S, D)).reshape(
            B, S, D)
    return y, aux


# --------------------------------------------------------------------------
# Embedding
# --------------------------------------------------------------------------

def embed_init(key, vocab: int, d: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def embed_lookup(table: Array, ids: Array) -> Array:
    return jnp.take(table, ids, axis=0)
