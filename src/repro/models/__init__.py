from repro.models.transformer import (
    LMConfig,
    TransformerLM,
    lm_logical_axes,
)
from repro.models.recsys import (
    RecsysConfig,
    BERT4Rec,
    DeepFM,
    MIND,
    SASRec,
    embedding_bag,
)
from repro.models.gnn import GNNConfig, MeshGraphNet, neighbor_sample
from repro.models.recommender import PaperRecommender, RecommenderConfig
