"""RecSys architectures: DeepFM, SASRec, BERT4Rec, MIND.

The hot path for all four is the sparse embedding lookup. JAX has no
native EmbeddingBag / CSR — `embedding_bag` below implements it with
``jnp.take`` + ``jax.ops.segment_sum`` (and a Pallas kernel twin in
repro.kernels.embedding_bag for the VMEM-tiled version). Embedding
tables are row-sharded over the 'model' mesh axis (the tables ARE the
model); the dense towers are small and replicated.

Every model exposes:
  init(key) / logical_axes() / forward / loss / train_step /
  serve(...)            — pointwise scoring (serve_p99 / serve_bulk cells)
  retrieval_scores(...) — one user vs n_candidates items (retrieval_cand),
                          feeding the paper's constrained-ranking head.
  user_covariates(...)  — the covariate vector X consumed by the paper's
                          lambda predictor (Algorithm 1).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_shard
from repro.models.layers import dense_init, rms_norm

Array = jax.Array


# --------------------------------------------------------------------------
# EmbeddingBag — take + segment_sum (THE recsys substrate op)
# --------------------------------------------------------------------------

def embedding_bag(
    table: Array,          # (V, D) — row-sharded over 'model'
    indices: Array,        # (n_bags, bag) int32; < 0 = padding
    weights: Array | None = None,
) -> Array:
    """Sum-mode EmbeddingBag: out[i] = sum_j w[i,j] * table[idx[i,j]]."""
    n_bags, bag = indices.shape
    valid = indices >= 0
    idx = jnp.where(valid, indices, 0)
    rows = jnp.take(table, idx.reshape(-1), axis=0)          # (n*bag, D)
    w = valid.astype(table.dtype)
    if weights is not None:
        w = w * weights.astype(table.dtype)
    rows = rows * w.reshape(-1, 1)
    seg = jnp.repeat(jnp.arange(n_bags), bag)
    return jax.ops.segment_sum(rows, seg, num_segments=n_bags)


# --------------------------------------------------------------------------
# Config
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class RecsysConfig:
    name: str = "recsys"
    kind: str = "deepfm"           # deepfm | sasrec | bert4rec | mind
    # deepfm
    n_sparse: int = 39
    field_vocab: int = 1_000_000
    embed_dim: int = 10
    mlp_dims: tuple = (400, 400, 400)
    # sequence models
    n_items: int = 1_000_000
    seq_len: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    # mind
    n_interests: int = 4
    capsule_iters: int = 3
    # training
    n_neg: int = 127               # sampled-softmax negatives
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    # §Perf variant: replicate the item/field table instead of row-sharding
    # it over 'model' — wins when the table is small enough to fit per-chip
    # (every lookup/negative-sampling gather becomes local; the DP gradient
    # all-reduce replaces the per-step gather collectives)
    replicate_tables: bool = False

    @property
    def n_params(self) -> int:
        if self.kind == "deepfm":
            emb = self.n_sparse * self.field_vocab * (self.embed_dim + 1)
            d_in = self.n_sparse * self.embed_dim
            mlp = 0
            prev = d_in
            for h in self.mlp_dims:
                mlp += prev * h + h
                prev = h
            return emb + mlp + prev + 1
        return self.n_items * self.embed_dim  # dominated by the item table


# --------------------------------------------------------------------------
# DeepFM
# --------------------------------------------------------------------------

class DeepFM:
    """Factorization-machine + deep tower CTR model (arXiv:1703.04247).

    Input: (B, n_sparse) global ids (field f uses rows
    [f*field_vocab, (f+1)*field_vocab)). One flat (n_sparse*field_vocab, D)
    table so row-sharding covers all fields uniformly.
    """

    def __init__(self, cfg: RecsysConfig):
        self.cfg = cfg

    def init(self, key):
        cfg = self.cfg
        V = cfg.n_sparse * cfg.field_vocab
        ks = jax.random.split(key, 4 + len(cfg.mlp_dims))
        params = {
            "table": (jax.random.normal(ks[0], (V, cfg.embed_dim), jnp.float32)
                      * 0.01).astype(cfg.param_dtype),
            "w_linear": (jax.random.normal(ks[1], (V, 1), jnp.float32)
                         * 0.01).astype(cfg.param_dtype),
            "bias": jnp.zeros((), cfg.param_dtype),
            "mlp": {},
        }
        prev = cfg.n_sparse * cfg.embed_dim
        for i, h in enumerate(cfg.mlp_dims):
            params["mlp"][f"w{i}"] = dense_init(ks[2 + i], (prev, h), cfg.param_dtype)
            params["mlp"][f"b{i}"] = jnp.zeros((h,), cfg.param_dtype)
            prev = h
        params["mlp"]["w_out"] = dense_init(ks[-1], (prev, 1), cfg.param_dtype)
        return params

    def logical_axes(self):
        cfg = self.cfg
        axes = {
            "table": ("table_rows", "table_dim"),
            "w_linear": ("table_rows", None),
            "bias": (),
            "mlp": {},
        }
        for i in range(len(cfg.mlp_dims)):
            axes["mlp"][f"w{i}"] = ("dense_in", "dense_out")
            axes["mlp"][f"b{i}"] = (None,)
        axes["mlp"]["w_out"] = ("dense_in", None)
        return axes

    def forward(self, params, ids: Array) -> Array:
        """ids: (B, n_sparse) -> logits (B,)."""
        cfg = self.cfg
        B = ids.shape[0]
        emb = jnp.take(params["table"], ids.reshape(-1), axis=0)
        emb = emb.reshape(B, cfg.n_sparse, cfg.embed_dim)
        emb = logical_shard(emb, "batch", None, None)
        # FM 2nd order: 0.5 * ((sum v)^2 - sum v^2) summed over dim
        s = jnp.sum(emb, axis=1)
        fm2 = 0.5 * jnp.sum(s * s - jnp.sum(emb * emb, axis=1), axis=-1)
        # 1st order
        lin = jnp.take(params["w_linear"], ids.reshape(-1), axis=0)
        fm1 = jnp.sum(lin.reshape(B, cfg.n_sparse), axis=1)
        # deep tower
        h = emb.reshape(B, cfg.n_sparse * cfg.embed_dim)
        for i in range(len(cfg.mlp_dims)):
            h = jax.nn.relu(h @ params["mlp"][f"w{i}"] + params["mlp"][f"b{i}"])
        deep = (h @ params["mlp"]["w_out"])[:, 0]
        return fm1 + fm2 + deep + params["bias"]

    def loss(self, params, batch):
        logits = self.forward(params, batch["ids"])
        y = batch["labels"].astype(jnp.float32)
        loss = jnp.mean(
            jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
        )
        return loss, {"loss": loss}

    def serve(self, params, ids: Array) -> Array:
        return jax.nn.sigmoid(self.forward(params, ids))

    def user_covariates(self, params, ids: Array) -> Array:
        """Mean field embedding = the user-side covariate vector X.
        Accepts any number of fields (context-only ids at retrieval)."""
        cfg = self.cfg
        B = ids.shape[0]
        emb = jnp.take(params["table"], ids.reshape(-1), axis=0)
        return jnp.mean(emb.reshape(B, -1, cfg.embed_dim), axis=1)

    def retrieval_scores(self, params, user_ids: Array, cand_ids: Array) -> Array:
        """user_ids: (B, n_sparse-1) context fields; cand_ids: (n_cand,)
        candidate values for the item field (field 0). Scores (B, n_cand):
        batch-free recompute of the FM + deep tower per candidate would be
        O(n_cand * mlp); instead we score with the FM interaction between
        the candidate embedding and the summed context (dot-product
        decomposition), which is the standard retrieval-tower reduction."""
        cfg = self.cfg
        B = user_ids.shape[0]
        ctx = jnp.take(params["table"], user_ids.reshape(-1), axis=0)
        ctx = ctx.reshape(B, -1, cfg.embed_dim).sum(axis=1)       # (B, D)
        cand = jnp.take(params["table"], cand_ids, axis=0)        # (n, D)
        cand = logical_shard(cand, "candidates", None)
        lin = jnp.take(params["w_linear"], cand_ids, axis=0)[:, 0]
        return ctx @ cand.T + lin[None, :]

    def train_step(self, params, opt_state, batch, *, lr=1e-3):
        return _generic_train_step(self, params, opt_state, batch, lr)


# --------------------------------------------------------------------------
# Shared transformer block for SASRec / BERT4Rec
# --------------------------------------------------------------------------

def _block_init(key, d: int, n_heads: int, d_ff: int, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wqkv": dense_init(k1, (d, 3 * d), dtype),
        "wo": dense_init(k2, (d, d), dtype),
        "w1": dense_init(k3, (d, d_ff), dtype),
        "w2": dense_init(k4, (d_ff, d), dtype),
        "ln1": jnp.zeros((d,), dtype),
        "ln2": jnp.zeros((d,), dtype),
    }


_BLOCK_AXES = {
    "wqkv": ("dense_in", "dense_out"),
    "wo": ("dense_in", "dense_out"),
    "w1": ("dense_in", "dense_out"),
    "w2": ("dense_in", "dense_out"),
    "ln1": (None,),
    "ln2": (None,),
}


def _block_apply(p, x: Array, n_heads: int, causal: bool) -> Array:
    B, S, D = x.shape
    Dh = D // n_heads
    h = rms_norm(x, p["ln1"])
    qkv = (h @ p["wqkv"]).reshape(B, S, 3, n_heads, Dh)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(Dh)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    att = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(B, S, D)
    x = x + att @ p["wo"]
    h = rms_norm(x, p["ln2"])
    x = x + jax.nn.relu(h @ p["w1"]) @ p["w2"]
    return x


class _SeqRecBase:
    """Shared machinery for sequential recommenders."""

    causal = True

    def __init__(self, cfg: RecsysConfig):
        self.cfg = cfg

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 3 + cfg.n_blocks)
        params = {
            "items": (jax.random.normal(ks[0], (cfg.n_items, cfg.embed_dim),
                                        jnp.float32) * 0.02).astype(cfg.param_dtype),
            "pos": (jax.random.normal(ks[1], (cfg.seq_len, cfg.embed_dim),
                                      jnp.float32) * 0.02).astype(cfg.param_dtype),
            "blocks": [
                _block_init(ks[2 + i], cfg.embed_dim, cfg.n_heads,
                            4 * cfg.embed_dim, cfg.param_dtype)
                for i in range(cfg.n_blocks)
            ],
            "final_ln": jnp.zeros((cfg.embed_dim,), cfg.param_dtype),
        }
        return params

    def logical_axes(self):
        return {
            "items": ("table_rows", "table_dim"),
            "pos": (None, None),
            "blocks": [dict(_BLOCK_AXES) for _ in range(self.cfg.n_blocks)],
            "final_ln": (None,),
        }

    def encode(self, params, seq: Array) -> Array:
        """seq: (B, S) item ids (< 0 = padding) -> (B, S, D) states."""
        cfg = self.cfg
        valid = seq >= 0
        ids = jnp.where(valid, seq, 0)
        x = jnp.take(params["items"], ids, axis=0)
        x = x * valid[..., None].astype(x.dtype)
        x = x + params["pos"][None, : seq.shape[1]]
        x = logical_shard(x, "batch", "seq", None)
        for blk in params["blocks"]:
            x = _block_apply(blk, x, cfg.n_heads, self.causal)
        return rms_norm(x, params["final_ln"])

    def user_repr(self, params, seq: Array) -> Array:
        """(B, D) — last-position state (the query vector for retrieval)."""
        return self.encode(params, seq)[:, -1]

    # covariates for the paper's lambda predictor
    def user_covariates(self, params, seq: Array) -> Array:
        return self.user_repr(params, seq)

    def retrieval_scores(self, params, seq: Array, cand_ids: Array) -> Array:
        """(B, n_cand): user query dot candidate item embeddings."""
        q = self.user_repr(params, seq)                         # (B, D)
        cand = jnp.take(params["items"], cand_ids, axis=0)      # (n, D)
        cand = logical_shard(cand, "candidates", None)
        return q @ cand.T

    def serve(self, params, seq: Array, target: Array) -> Array:
        """Pointwise scoring of (user sequence, target item) pairs."""
        q = self.user_repr(params, seq)
        t = jnp.take(params["items"], target, axis=0)
        return jnp.sum(q * t, axis=-1)

    def _sampled_softmax(self, q: Array, pos_ids: Array, neg_ids: Array,
                         params) -> Array:
        """q: (B, D); pos: (B,); neg: (B, n_neg) -> mean CE loss."""
        pos_e = jnp.take(params["items"], pos_ids, axis=0)
        neg_e = jnp.take(params["items"], neg_ids, axis=0)
        pos_logit = jnp.sum(q * pos_e, axis=-1, keepdims=True)   # (B,1)
        neg_logit = jnp.einsum("bd,bnd->bn", q, neg_e)
        logits = jnp.concatenate([pos_logit, neg_logit], axis=-1)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        return jnp.mean(lse - pos_logit[:, 0])

    def train_step(self, params, opt_state, batch, *, lr=1e-3):
        return _generic_train_step(self, params, opt_state, batch, lr)


class SASRec(_SeqRecBase):
    """Self-attentive sequential recommendation (arXiv:1808.09781).

    Next-item prediction: state at position t scores item t+1. Sampled
    softmax (1 pos + n_neg uniform negatives) — full-softmax over 10^6
    items would be a (B*S, 10^6) matmul; sampled softmax is the standard
    industrial reduction (noted in DESIGN.md)."""

    causal = True

    def loss(self, params, batch):
        # batch: seq (B,S), pos (B,S) next items, neg (B,S,n_neg)
        h = self.encode(params, batch["seq"])                   # (B,S,D)
        B, S, D = h.shape
        q = h.reshape(B * S, D)
        loss = self._sampled_softmax(
            q, batch["pos"].reshape(-1), batch["neg"].reshape(B * S, -1), params
        )
        return loss, {"loss": loss}


class BERT4Rec(_SeqRecBase):
    """Bidirectional masked-item model (arXiv:1904.06690). Encoder-only:
    no decode step exists for this arch (noted in DESIGN.md)."""

    causal = False

    def loss(self, params, batch):
        # batch: seq with [MASK]=id 0 at masked slots, mask_pos (B, n_mask),
        # mask_target (B, n_mask), neg (B, n_mask, n_neg)
        h = self.encode(params, batch["seq"])
        q = jnp.take_along_axis(
            h, batch["mask_pos"][..., None].astype(jnp.int32), axis=1
        )                                                       # (B,n_mask,D)
        B, M, D = q.shape
        loss = self._sampled_softmax(
            q.reshape(B * M, D),
            batch["mask_target"].reshape(-1),
            batch["neg"].reshape(B * M, -1),
            params,
        )
        return loss, {"loss": loss}


class MIND(_SeqRecBase):
    """Multi-Interest Network with Dynamic routing (arXiv:1904.08030).

    Behaviour sequence -> n_interests capsules via B2I dynamic routing
    (fixed `capsule_iters` iterations, squash nonlinearity); label-aware
    attention at train; serve = max over interests."""

    causal = False

    def init(self, key):
        params = super().init(key)
        cfg = self.cfg
        kb = jax.random.fold_in(key, 7)
        params["bilinear"] = dense_init(kb, (cfg.embed_dim, cfg.embed_dim),
                                        cfg.param_dtype)
        return params

    def logical_axes(self):
        axes = super().logical_axes()
        axes["bilinear"] = ("dense_in", "dense_out")
        return axes

    @staticmethod
    def _squash(x: Array) -> Array:
        n2 = jnp.sum(x * x, axis=-1, keepdims=True)
        return (n2 / (1.0 + n2)) * x / jnp.sqrt(n2 + 1e-9)

    def interests(self, params, seq: Array) -> Array:
        """(B, n_interests, D) capsules from the behaviour sequence."""
        cfg = self.cfg
        valid = (seq >= 0)
        ids = jnp.where(valid, seq, 0)
        e = jnp.take(params["items"], ids, axis=0)              # (B,S,D)
        e = e * valid[..., None].astype(e.dtype)
        eh = e @ params["bilinear"]                             # (B,S,D)
        B, S, D = eh.shape
        K = cfg.n_interests
        b_logit = jnp.zeros((B, K, S), eh.dtype)
        neg_mask = jnp.where(valid[:, None, :], 0.0, -1e30).astype(eh.dtype)
        u = jnp.zeros((B, K, D), eh.dtype)
        for _ in range(cfg.capsule_iters):
            w = jax.nn.softmax(b_logit + neg_mask, axis=1)      # over capsules
            u = self._squash(jnp.einsum("bks,bsd->bkd", w, eh))
            b_logit = b_logit + jnp.einsum("bkd,bsd->bks", u, eh)
        return u

    def user_repr(self, params, seq: Array) -> Array:
        # single-vector fallback: mean of interests
        return jnp.mean(self.interests(params, seq), axis=1)

    def user_covariates(self, params, seq: Array) -> Array:
        B = seq.shape[0]
        return self.interests(params, seq).reshape(B, -1)

    def retrieval_scores(self, params, seq: Array, cand_ids: Array) -> Array:
        """max over interests of interest·candidate (the MIND serving rule)."""
        u = self.interests(params, seq)                         # (B,K,D)
        cand = jnp.take(params["items"], cand_ids, axis=0)      # (n,D)
        cand = logical_shard(cand, "candidates", None)
        scores = jnp.einsum("bkd,nd->bkn", u, cand)
        return jnp.max(scores, axis=1)

    def loss(self, params, batch):
        # label-aware attention: weight interests by similarity^p to target
        u = self.interests(params, batch["seq"])                # (B,K,D)
        pos_e = jnp.take(params["items"], batch["pos"], axis=0)  # (B,D)
        att = jax.nn.softmax(
            jnp.einsum("bkd,bd->bk", u, pos_e) * 2.0, axis=-1
        )
        q = jnp.einsum("bk,bkd->bd", att, u)
        loss = self._sampled_softmax(q, batch["pos"], batch["neg"], params)
        return loss, {"loss": loss}


# --------------------------------------------------------------------------
# Generic train step
# --------------------------------------------------------------------------

def _generic_train_step(model, params, opt_state, batch, lr):
    from repro.optim import adam_update
    from repro.optim.clip import clip_by_global_norm

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model.loss(p, batch), has_aux=True
    )(params)
    grads, gnorm = clip_by_global_norm(grads, 1.0)
    params, opt_state = adam_update(grads, opt_state, params, lr=lr)
    return params, opt_state, dict(metrics, grad_norm=gnorm)


RECSYS_REGISTRY = {
    "deepfm": DeepFM,
    "sasrec": SASRec,
    "bert4rec": BERT4Rec,
    "mind": MIND,
}
