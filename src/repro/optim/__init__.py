from repro.optim.adafactor import (
    FactoredState,
    adafactor_init,
    adafactor_update,
)
from repro.optim.adam import AdamState, adam_init, adam_update
from repro.optim.schedules import constant_schedule, cosine_schedule, linear_warmup_cosine
from repro.optim.clip import clip_by_global_norm
from repro.optim.compression import compress_int8, decompress_int8, compressed_psum
