"""Gradient compression for cross-pod data parallelism.

At 1000+ node scale the pod-axis gradient all-reduce crosses DCN (slow)
links. We provide int8 quantized all-reduce with per-tensor scales and
error feedback (residual carried to the next step), the standard
distributed-optimization trick (1-bit Adam / PowerSGD lineage, here the
int8 variant that is bandwidth-optimal on TPU DCN without SVD cost).

`compressed_psum` is written against `jax.lax.psum` inside shard_map so it
lowers to a real collective in the compiled HLO; the dry-run counts its
bytes at int8 width (4x reduction vs f32 / 2x vs bf16).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def compress_int8(x: Array) -> tuple[Array, Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: Array, axis_name: str) -> Array:
    """int8-quantized psum over `axis_name` (e.g. the cross-pod axis).

    Quantize locally -> all-reduce int32 accumulators + max scale ->
    dequantize. Error is bounded by scale/2 per element per step; callers
    should pair with error feedback for training-quality parity.
    """
    q, scale = compress_int8(x)
    # Use a shared scale (max over the axis) so summed int values are
    # commensurable; re-quantize against it.
    scale_max = jax.lax.pmax(scale, axis_name)
    q2 = jnp.clip(jnp.round(x / scale_max), -127, 127).astype(jnp.int8)
    acc = jax.lax.psum(q2.astype(jnp.int32), axis_name)
    return acc.astype(jnp.float32) * scale_max


def psum_with_error_feedback(x: Array, residual: Array, axis_name: str):
    """Compressed psum with error feedback: returns (mean_grad, new_residual)."""
    xc = x + residual
    q, scale = compress_int8(xc)
    deq_local = decompress_int8(q, scale)
    new_residual = xc - deq_local
    summed = compressed_psum(xc, axis_name)
    n = jax.lax.psum(jnp.ones((), x.dtype), axis_name)
    return summed / n, new_residual
