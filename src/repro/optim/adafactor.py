"""Factored second-moment Adam (Adafactor-style, Shazeer & Stern 2018).

For a (n, m) parameter the second moment is stored as a rank-1 outer
product of row/column statistics — O(n+m) instead of O(n·m). At kimi-k2
scale that turns 2.06 TB of nu into ~0.3 GB, which is what lets the 1T
config's optimizer state approach a single-pod fit (EXPERIMENTS.md
§Dry-run fit math). First moment stays dense (optionally bf16).

1-D (and scalar) params fall back to dense nu. Update rule matches Adam
otherwise (beta2 bias correction included) so small-scale training curves
are comparable.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class FactoredState:
    step: Array
    mu: PyTree          # dense first moments
    nu_row: PyTree      # (..., n) row stats for >=2-D leaves, else dense nu
    nu_col: PyTree      # (..., m) col stats for >=2-D leaves, else None-like


def _is_factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params: PyTree, moment_dtype=jnp.bfloat16) -> FactoredState:
    def mu0(p):
        return jnp.zeros(p.shape, moment_dtype)

    def row0(p):
        if _is_factored(p):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)     # dense nu fallback

    def col0(p):
        if _is_factored(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,), jnp.float32)        # placeholder

    return FactoredState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(mu0, params),
        nu_row=jax.tree.map(row0, params),
        nu_col=jax.tree.map(col0, params),
    )


def adafactor_update(
    grads: PyTree,
    state: FactoredState,
    params: PyTree,
    *,
    lr: float | Array,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-30,
    eps_scale: float = 1e-8,
) -> tuple[PyTree, FactoredState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(g, m, vr, vc, p):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + (1.0 - b1) * g32
        g2 = g32 * g32 + eps
        if _is_factored(p):
            vr32 = vr * b2 + (1.0 - b2) * jnp.mean(g2, axis=-1)
            vc32 = vc * b2 + (1.0 - b2) * jnp.mean(g2, axis=-2)
            # rank-1 reconstruction: v ~ vr vc / mean(vr)
            denom = jnp.mean(vr32, axis=-1, keepdims=True) + eps
            v_hat = (vr32[..., None] * vc32[..., None, :]) / denom[..., None]
        else:
            vr32 = vr * b2 + (1.0 - b2) * g2
            vc32 = vc
            v_hat = vr32
        u = (m32 / bc1) / (jnp.sqrt(v_hat / bc2) + eps_scale)
        new_p = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return new_p, m32.astype(m.dtype), vr32, vc32

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_r = treedef.flatten_up_to(state.nu_row)
    flat_c = treedef.flatten_up_to(state.nu_col)
    out = [upd(g, m, r, c, p)
           for g, m, r, c, p in zip(flat_g, flat_m, flat_r, flat_c, flat_p)]
    return treedef.unflatten([o[0] for o in out]), FactoredState(
        step=step,
        mu=treedef.unflatten([o[1] for o in out]),
        nu_row=treedef.unflatten([o[2] for o in out]),
        nu_col=treedef.unflatten([o[3] for o in out]),
    )


def state_bytes(params: PyTree, *, factored: bool) -> int:
    """Optimizer-state bytes for the fit math (EXPERIMENTS.md §Dry-run)."""
    total = 0
    for p in jax.tree.leaves(params):
        total += p.size * 2                                   # mu bf16
        if factored and p.ndim >= 2:
            total += (int(jnp.prod(jnp.asarray(p.shape[:-1])))
                      + int(jnp.prod(jnp.asarray(p.shape[:-2] + p.shape[-1:])))
                      ) * 4
        else:
            total += p.size * 4                               # dense nu f32
    return total
