"""Adam optimizer as pure pytree functions (no optax in this environment).

Supports reduced-precision moments (`moment_dtype=bfloat16`) — at 1T-param
scale (kimi-k2) fp32 moments alone are 8 TB; bf16 moments halve optimizer
HBM and are standard practice for large MoE training. Master params stay in
the param dtype; updates are computed in fp32.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class AdamState:
    step: Array
    mu: PyTree
    nu: PyTree


def adam_init(params: PyTree, moment_dtype=jnp.float32) -> AdamState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return AdamState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adam_update(
    grads: PyTree,
    state: AdamState,
    params: PyTree,
    *,
    lr: float | Array,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> tuple[PyTree, AdamState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + (1.0 - b1) * g32
        v32 = v.astype(jnp.float32) * b2 + (1.0 - b2) * g32 * g32
        u = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + eps)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamState(step=step, mu=new_m, nu=new_v)
