"""Per-replica health state machines for the serving fleet.

A fleet router cannot ask a wedged replica whether it is wedged — it
has to infer health from the signals that keep flowing on the healthy
path anyway: heartbeats (delivered by FleetRouter's tick, suppressed
when the replica is crashed or its heartbeat channel is blackholed),
the per-replica service-latency EWMA the router measures on every
completed attempt, and the consecutive-failure counter its submission
attempts feed. Those three signals drive a four-state machine:

    HEALTHY ──(heartbeat stale ≥ suspect_after_s,
               or lag EWMA ≥ lag_suspect_ms,
               or a non-fatal failure)──────────────▶ SUSPECT
    SUSPECT ──(fresh heartbeat AND lag below the
               hysteresis threshold AND no recent
               failure)────────────────────────────▶ HEALTHY
    SUSPECT ──(heartbeat stale ≥ dead_after_s, or
               consecutive failures ≥ threshold)───▶ DEAD
    HEALTHY ──(fatal failure, e.g. ReplicaCrash)───▶ DEAD
    DEAD ────(supervised restart begins)───────────▶ RECOVERING
    RECOVERING ──(restart completed: checkpoint
               restored + bucket subset re-warmed)─▶ HEALTHY

DEAD is absorbing until the supervisor (FleetRouter) begins a restart:
a replica that stopped heartbeating does not resurrect itself just
because a late heartbeat straggles in — the router owns the
DEAD → RECOVERING → HEALTHY path, so routing decisions and restart
side effects (checkpoint restore, re-warm) can never disagree about
who is serving.

Asymmetric thresholds are the anti-flap design: entering SUSPECT is
cheap (a hedge costs one duplicate micro-batch row), so the suspect
deadline is short; entering DEAD triggers a restart (checkpoint
restore + re-warm), so it takes a much staler heartbeat or repeated
hard failures. Leaving SUSPECT requires the lag EWMA to fall below
`lag_hysteresis * lag_suspect_ms`, not merely below the entry
threshold — a replica hovering at the threshold hedges continuously
rather than toggling.

Everything is driven by an injected clock (`now` parameters), so the
FrozenClock tests replay every transition deterministically.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field

__all__ = [
    "HEALTHY", "SUSPECT", "DEAD", "RECOVERING",
    "HealthConfig", "ReplicaHealth", "backoff_s",
]

HEALTHY = "healthy"
SUSPECT = "suspect"
DEAD = "dead"
RECOVERING = "recovering"


@dataclass(frozen=True)
class HealthConfig:
    """Thresholds for one replica's health machine.

    suspect_after_s   heartbeat staleness that makes a replica SUSPECT
                      (hedging starts).
    dead_after_s      heartbeat staleness that makes it DEAD (restart
                      + failover). Must exceed suspect_after_s.
    lag_suspect_ms    service-latency EWMA at which a live replica is
                      SUSPECT anyway — a wedged-but-heartbeating
                      replica (the slow-replica fault) is as useless
                      as a dead one for deadline traffic.
    lag_hysteresis    SUSPECT clears only once the lag EWMA falls
                      below lag_hysteresis * lag_suspect_ms.
    lag_alpha         EWMA smoothing for observe_lag.
    fail_threshold    consecutive non-fatal failures that escalate to
                      DEAD (a single fatal failure — ReplicaCrash —
                      goes straight there).
    """

    suspect_after_s: float = 0.15
    dead_after_s: float = 0.50
    lag_suspect_ms: float = 250.0
    lag_hysteresis: float = 0.5
    lag_alpha: float = 0.3
    fail_threshold: int = 3

    def __post_init__(self):
        if self.dead_after_s <= self.suspect_after_s:
            raise ValueError(
                f"dead_after_s ({self.dead_after_s}) must exceed "
                f"suspect_after_s ({self.suspect_after_s})")
        if not 0.0 < self.lag_hysteresis <= 1.0:
            raise ValueError(
                f"lag_hysteresis must be in (0, 1], got {self.lag_hysteresis}")


@dataclass
class ReplicaHealth:
    """One replica's health state machine (see module doc for the
    transition diagram). The router feeds it heartbeats, per-attempt
    latency samples, and success/failure outcomes; `evaluate(now)`
    applies the deadline rules and returns the current state."""

    name: str
    config: HealthConfig = field(default_factory=HealthConfig)
    state: str = HEALTHY
    last_heartbeat: float = 0.0
    lag_ewma_ms: float = 0.0
    consecutive_failures: int = 0
    # audit trail of (t, from_state, to_state, reason) — what the
    # chaos tests replay against the fault plan.
    transitions: list = field(default_factory=list)

    def _move(self, now: float, to: str, reason: str) -> None:
        if to != self.state:
            self.transitions.append((now, self.state, to, reason))
            self.state = to

    # -- signals -------------------------------------------------------------

    def heartbeat(self, now: float) -> None:
        """A heartbeat was DELIVERED (the router's tick got a liveness
        ack; a blackholed or crashed replica never reaches here)."""
        self.last_heartbeat = now

    def observe_lag(self, lag_ms: float) -> None:
        """One completed attempt's submit→result latency on this
        replica — the wedged-replica signal."""
        a = self.config.lag_alpha
        self.lag_ewma_ms = (1.0 - a) * self.lag_ewma_ms + a * max(
            0.0, float(lag_ms))

    def on_success(self, now: float) -> None:
        self.consecutive_failures = 0

    def on_failure(self, now: float, *, fatal: bool = False) -> None:
        """A submission attempt on this replica failed. `fatal` (a
        ReplicaCrash — the process is gone) goes straight to DEAD;
        non-fatal failures escalate through SUSPECT to DEAD at the
        consecutive-failure threshold."""
        self.consecutive_failures += 1
        if self.state in (DEAD, RECOVERING):
            return
        if fatal:
            self._move(now, DEAD, "fatal-failure")
        elif self.consecutive_failures >= self.config.fail_threshold:
            self._move(now, DEAD,
                       f"{self.consecutive_failures}-consecutive-failures")
        else:
            self._move(now, SUSPECT, "failure")

    # -- state machine -------------------------------------------------------

    def evaluate(self, now: float) -> str:
        """Apply the heartbeat-deadline and lag-threshold rules and
        return the current state. DEAD and RECOVERING are untouched —
        only the supervisor's begin_recovery/mark_recovered move them."""
        cfg = self.config
        if self.state in (DEAD, RECOVERING):
            return self.state
        stale = now - self.last_heartbeat
        if stale >= cfg.dead_after_s:
            self._move(now, DEAD, f"heartbeat-stale-{stale:.3f}s")
        elif self.state == HEALTHY:
            if stale >= cfg.suspect_after_s:
                self._move(now, SUSPECT, f"heartbeat-stale-{stale:.3f}s")
            elif self.lag_ewma_ms >= cfg.lag_suspect_ms:
                self._move(now, SUSPECT,
                           f"lag-ewma-{self.lag_ewma_ms:.1f}ms")
        elif self.state == SUSPECT:
            fresh = stale < cfg.suspect_after_s
            calm = self.lag_ewma_ms < cfg.lag_hysteresis * cfg.lag_suspect_ms
            if fresh and calm and self.consecutive_failures == 0:
                self._move(now, HEALTHY, "recovered-signals")
        return self.state

    def begin_recovery(self, now: float) -> None:
        """The supervisor started a restart: DEAD → RECOVERING. The
        replica takes no routed traffic until mark_recovered."""
        if self.state != DEAD:
            raise RuntimeError(
                f"replica {self.name!r}: begin_recovery from {self.state} "
                f"(only DEAD replicas restart)")
        self._move(now, RECOVERING, "restart-begun")

    def mark_recovered(self, now: float) -> None:
        """Restart completed (state restored, bucket subset re-warmed):
        RECOVERING → HEALTHY with fresh signals."""
        if self.state != RECOVERING:
            raise RuntimeError(
                f"replica {self.name!r}: mark_recovered from {self.state}")
        self.consecutive_failures = 0
        self.lag_ewma_ms = 0.0
        self.last_heartbeat = now
        self._move(now, HEALTHY, "restart-completed")

    def fail_recovery(self, now: float) -> None:
        """The restart itself failed: RECOVERING → DEAD, so the
        supervisor's backoff schedule gets another attempt."""
        if self.state != RECOVERING:
            raise RuntimeError(
                f"replica {self.name!r}: fail_recovery from {self.state}")
        self._move(now, DEAD, "restart-failed")

    @property
    def routable(self) -> bool:
        """May the router send this replica traffic at all? (SUSPECT is
        routable — it just gets hedged.)"""
        return self.state in (HEALTHY, SUSPECT)


def backoff_s(attempt: int, *, base_s: float = 0.05, cap_s: float = 2.0,
              seed: int = 0) -> float:
    """Capped exponential backoff with deterministic jitter for restart
    attempt `attempt` (0-based): min(cap, base * 2^attempt) scaled by a
    jitter factor in [0.5, 1.0] derived by hashing (seed, attempt) —
    the decorrelation real jitter buys, replayable because the chaos
    harness replays everything."""
    if attempt < 0:
        raise ValueError(f"attempt must be >= 0, got {attempt}")
    raw = min(float(cap_s), float(base_s) * (2.0 ** attempt))
    digest = hashlib.blake2b(f"{seed}:{attempt}".encode(),
                             digest_size=8).digest()
    u = int.from_bytes(digest, "big") / float(2 ** 64)    # [0, 1)
    return raw * (0.5 + 0.5 * u)
