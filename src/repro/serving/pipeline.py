"""Async double-buffered execution pipeline for the serving engine.

The engine's hot path is split in two (see docs/serving.md for the
timeline diagrams):

  submission side   the caller's thread: bucketing, host-side batch
                    assembly into a recycled StagingRing buffer, and
                    jit dispatch. Dispatch is asynchronous — the jit
                    call returns device futures immediately — so the
                    submission thread goes straight back to assembling
                    the next micro-batch.

  completion side   one worker thread (per ExecutionPipeline) that
                    retires dispatched batches in dispatch order: it
                    blocks on the device→host transfer of batch N's
                    outputs while the device is already executing batch
                    N+1, then marks every RankFuture of the batch done
                    and recycles the staging buffers. The worker does
                    NOTHING else — per-request unpadding and result
                    construction are Python-heavy (they would hold the
                    GIL against the submission thread), so they run
                    lazily on whichever consumer thread first asks:
                    `RankFuture.result()` or the engine's collect path
                    (submit/poll/drain return values). Each result is
                    built exactly once (futures memoize under a lock).

The bounded in-flight queue IS the double buffer: `depth` is how many
dispatched batches may queue behind the one the worker is currently
materializing, so depth=1 keeps (at most) two batches alive between
dispatch and retirement — classic double buffering — and a further
dispatch blocks the submission side (backpressure) instead of growing
an unbounded device queue. StagingRing carries one slot more than the
in-flight window (depth queued + 1 materializing) so assembly of the
next batch always has a free buffer while earlier batches are in
flight; a buffer is recycled only after its batch's outputs have fully
materialized, so reuse can never race an in-flight transfer (and, on
accelerator backends, never races a donated device buffer).

Nothing in this module knows about ranking — PendingBatch's
`materialize` and `build` callables (bound by the engine) own
device→host copies, unpadding, and metrics. This module owns only
threads, queues, futures, and lifetime.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.serving.buckets import Bucket, alloc_staging

__all__ = ["RankFuture", "StagingRing", "PendingBatch", "ExecutionPipeline"]


class RankFuture:
    """Handle for one submitted request's eventual RankResult.

    Marked done by the completion side when the request's micro-batch
    outputs reach the host. `result()` blocks (optionally with a
    timeout) and builds/memoizes the RankResult on the calling thread;
    `done()` and `add_done_callback()` never block. Callbacks run on
    the thread that marks the future done — the pipeline worker in
    async mode, the submitting thread in sync mode — so they must be
    cheap; call `result()` inside one only if doing the unpadding work
    on that thread is acceptable.

    Settlement is FIRST-WINS: `_finish`/`_resolve`/`_fail` each return
    True only for the call that settled the future; later calls are
    no-ops returning False. Exactly-once resolution is what the fleet
    layer's hedging leans on — a hedged request holds one fleet-level
    future that both replica attempts race to settle, and the loser's
    completion (or crash) must never overwrite the winner's result.
    """

    __slots__ = ("rid", "bucket_name", "_event", "_batch", "_index",
                 "_result", "_error", "_callbacks", "_lock")

    def __init__(self, rid: int, bucket_name: str):
        self.rid = rid
        self.bucket_name = bucket_name
        self._event = threading.Event()
        self._batch: "PendingBatch | None" = None
        self._index = -1
        self._result = None
        self._error: BaseException | None = None
        self._callbacks: list[Callable] = []
        self._lock = threading.Lock()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None):
        """The RankResult, blocking until the batch's outputs are home."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid}: no result within "
                               f"{timeout}s (did you drain()?)")
        with self._lock:
            if self._error is not None:
                raise self._error
            if self._result is None:
                self._result = self._batch.build(self._batch, self._index)
                # a held future must not pin the whole batch (padded
                # outputs + every row's request arrays) once its own
                # row is memoized.
                self._batch = None
            return self._result

    def add_done_callback(self, cb: Callable[["RankFuture"], None]) -> None:
        with self._lock:
            if not self._event.is_set():
                self._callbacks.append(cb)
                return
        cb(self)

    def _fire_callbacks(self) -> None:
        with self._lock:
            callbacks, self._callbacks = self._callbacks, []
        for cb in callbacks:
            cb(self)

    def _finish(self, batch: "PendingBatch", index: int) -> bool:
        with self._lock:
            if self._event.is_set():
                return False
            self._batch, self._index = batch, index
        self._event.set()
        self._fire_callbacks()
        return True

    def _resolve(self, result) -> bool:
        """Resolve immediately with a pre-built result — the shed path
        (typed Shed, not an exception) and the fleet's hedge-winner
        path. First caller wins; a settled future is never rewritten."""
        with self._lock:
            if self._event.is_set():
                return False
            self._result = result
        self._event.set()
        self._fire_callbacks()
        return True

    def _fail(self, error: BaseException) -> bool:
        with self._lock:
            if self._event.is_set():
                return False
            self._error = error
        self._event.set()
        self._fire_callbacks()
        return True


class StagingRing:
    """Fixed ring of reusable host staging-buffer sets for one bucket.

    `acquire` hands out a free buffer set and blocks when every set is
    attached to an in-flight batch — backpressure that bounds host
    memory to `depth` buffer sets per bucket regardless of offered
    load. `release` (called by the completion side once a batch's
    outputs have materialized) returns the set for reuse.

    The buffers are PINNED: allocated exactly once here, page-aligned
    (buckets._aligned_empty), and recycled for the ring's whole
    lifetime. `release` asserts the returned set is one the ring handed
    out — a foreign dict means some path re-allocated staging on the
    submission side, which is precisely the per-batch host allocation
    the ring exists to eliminate. `reuses` counts acquires beyond the
    first per buffer, so tests can assert steady state allocates
    nothing.
    """

    def __init__(self, bucket: Bucket, *, d_cov: int | None, depth: int):
        self.bucket = bucket
        self.depth = int(depth)
        self._free: queue.Queue = queue.Queue()
        self._owned: frozenset[int] = frozenset()
        self._handed_out = 0
        owned = []
        for _ in range(self.depth):
            staged = alloc_staging(bucket, d_cov=d_cov)
            owned.append(id(staged))
            self._free.put(staged)
        self._owned = frozenset(owned)

    @property
    def allocated(self) -> int:
        """Buffer sets this ring ever allocated — depth, by construction,
        for the ring's whole lifetime."""
        return len(self._owned)

    @property
    def reuses(self) -> int:
        """Acquires beyond the first use of each buffer set."""
        return max(0, self._handed_out - self.depth)

    def acquire(self) -> dict:
        self._handed_out += 1
        return self._free.get()

    def release(self, staged: dict) -> None:
        if id(staged) not in self._owned:
            raise AssertionError(
                f"StagingRing[{self.bucket.name}]: released a buffer set "
                f"it never allocated — a submission path allocated fresh "
                f"staging instead of reusing the pinned ring")
        self._free.put(staged)


@dataclass
class PendingBatch:
    """One dispatched micro-batch, from dispatch through result build.

    Created by the submission side at dispatch time. `materialize`
    (engine-bound) blocks on the device→host transfer, restamps `out`
    with host arrays, sets `t_done`, and recycles `staged`; `build`
    (engine-bound) unpads row `i` into a RankResult. The completion
    worker calls only `materialize` — `build` runs lazily on consumer
    threads via RankFuture.
    """

    bucket: Bucket
    entries: list                     # [engine._QueueEntry] (req, t_enq,
                                      # deadline, rung)
    futures: list                     # [RankFuture], aligned with entries
    out: Any                          # RankingOutput: device, then host arrays
    staged: dict | None               # staging buffers to recycle
    ring: StagingRing | None
    t_launch: float
    trigger: str
    materialize: Callable = None      # (PendingBatch) -> None
    build: Callable = None            # (PendingBatch, i) -> RankResult
    t_done: float | None = None
    assembly_ms: float = 0.0
    dispatch_ms: float = 0.0
    depth_at_dispatch: int = 0
    fill: dict = field(default_factory=dict)
    # predictor generation the batch was dispatched against (engine
    # epoch fence): every row of the batch shares it — a swap lands
    # between batches, never inside one. 0 for raw-lam buckets.
    epoch: int = 0
    # lattice generation at dispatch (same fence discipline): a lattice
    # swap lands between batches, so every row of a batch was bucketed
    # and served under one lattice. 0 = the boot power-of-two lattice.
    lattice_epoch: int = 0

    def finish(self) -> None:
        """Materialize outputs and mark every future done. Called by
        the pipeline worker (async) or inline after dispatch (sync)."""
        self.materialize(self)
        for i, fut in enumerate(self.futures):
            fut._finish(self, i)

    def results(self) -> list:
        """Build (or fetch memoized) results for all rows, in order."""
        return [fut.result(timeout=0) for fut in self.futures]


class ExecutionPipeline:
    """Completion side: a worker thread retiring batches in dispatch order.

    `submit` enqueues a PendingBatch (blocking when `depth` batches are
    already in flight), the worker calls `pending.finish()` on each —
    the blocking device→host wait — and finished batches accumulate
    until the submission side collects them with `collect`
    (non-blocking) or `flush` (barrier: waits for every in-flight
    batch). A worker error is captured, fails that batch's futures,
    and re-raises on the next `flush`/`submit` so a single-threaded
    driver still sees it.
    """

    def __init__(self, *, depth: int):
        if depth < 1:
            raise ValueError(f"pipeline depth must be >= 1, got {depth}")
        self.depth = int(depth)
        self._inflight: queue.Queue = queue.Queue(maxsize=self.depth)
        self._retired: queue.Queue = queue.Queue()
        self._error: BaseException | None = None
        self._worker: threading.Thread | None = None
        self._closed = False
        self._lock = threading.Lock()

    # -- worker -------------------------------------------------------------

    def _ensure_worker(self) -> None:
        with self._lock:
            if self._worker is None:
                self._worker = threading.Thread(
                    target=self._run, name="serving-pipeline", daemon=True)
                self._worker.start()

    def _run(self) -> None:
        while True:
            pending = self._inflight.get()
            if pending is None:                       # shutdown sentinel
                self._inflight.task_done()
                return
            try:
                pending.finish()
                self._retired.put(pending)
            except BaseException as e:                # noqa: BLE001
                self._error = self._error or e
                for fut in pending.futures:
                    fut._fail(e)
                # recycle the staging buffers even on failure — the
                # ring is finite, and leaking one set per error would
                # eventually deadlock acquire() on the submission side.
                if pending.ring is not None and pending.staged is not None:
                    pending.ring.release(pending.staged)
                    pending.staged = None
            finally:
                self._inflight.task_done()

    # -- submission-side API ------------------------------------------------

    def submit(self, pending: PendingBatch) -> None:
        """Hand a dispatched batch to the completion side. Blocks while
        `depth` batches are in flight (backpressure). A stored worker
        error re-raises here, but only AFTER this batch is enqueued —
        the batch was already dispatched, and dropping it would leak
        its staging buffers and leave its futures unresolved forever."""
        if self._closed:
            raise RuntimeError("pipeline is closed")
        self._ensure_worker()
        pending.depth_at_dispatch = self._inflight.qsize()
        self._inflight.put(pending)
        self._raise_pending_error()

    def inflight(self) -> int:
        """Batches dispatched but not yet retired (approximate: the
        batch currently being materialized no longer counts)."""
        return self._inflight.qsize()

    def collect(self) -> list:
        """All batches retired so far; never blocks."""
        out = []
        while True:
            try:
                out.append(self._retired.get_nowait())
            except queue.Empty:
                return out

    def flush(self) -> list:
        """Barrier: wait until every in-flight batch has retired, then
        return everything collected (including earlier retirees)."""
        if self._worker is not None:
            self._inflight.join()
        self._raise_pending_error()
        return self.collect()

    def close(self) -> None:
        """Graceful shutdown: retire everything in flight, then stop
        the worker. Idempotent; the pipeline rejects submits after."""
        if self._closed:
            return
        self._closed = True
        if self._worker is not None:
            self._inflight.put(None)
            self._worker.join()
            self._worker = None

    def _raise_pending_error(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise err
