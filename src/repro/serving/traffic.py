"""Scenario-mix request generator: realistic mixed-arch traffic for the
serving engine.

A live fleet multiplexes surfaces — a home feed with ~500 candidates and
50 slots, a related-items strip with ~1k candidates and 20 slots, a
notification ranker with tiny slates, a retrieval head with 10^5+
candidates — each behind a different recommender architecture with its
own constraint system. A Scenario captures one such surface's geometry
distribution; `make_stream` interleaves scenarios by weight into a
single request sequence the engine can be driven with.

Payloads are synthetic (utilities ~ U[1, 5], sparse topic attributes,
thresholds as a fraction of the total slot discount — the same
conventions as benchmarks/ and the dual-solver tests) but every request
is a well-posed instance of the paper's online problem, so compliance
numbers are meaningful, not decorative. Plugging real backbone scores in
instead is a one-line swap (see repro.launch.serve).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.constraints import dcg_discount
from repro.serving.engine import LAM_TAG, RankRequest


@dataclass(frozen=True)
class Scenario:
    """One traffic surface: a geometry distribution + arrival weight."""

    name: str
    m1: int                    # nominal candidate count
    m2: int                    # nominal slot count
    K: int                     # constraint count
    weight: float = 1.0        # relative arrival rate
    tag: str = LAM_TAG         # predictor tag ('_lam' = request carries lam)
    d_cov: int = 20            # covariate dim (used when tag != '_lam')
    m1_jitter: float = 0.5     # m1 sampled from [m1*(1-jitter), m1]
    topic_rate: float = 0.15   # sparsity of the constraint attributes
    b_frac: float = 0.06       # threshold as fraction of sum(gamma)


# A default mix spanning >= 3 geometries and 2 "archs" (surfaces): the
# shapes mirror the repo's recsys configs (sasrec feed, bert4rec strip,
# mind notifications, deepfm retrieval).
DEFAULT_MIX = (
    Scenario("feed_sasrec", m1=500, m2=50, K=5, weight=4.0),
    Scenario("strip_bert4rec", m1=1000, m2=20, K=5, weight=2.0),
    Scenario("notif_mind", m1=120, m2=8, K=3, weight=1.0),
    Scenario("retrieval_deepfm", m1=4000, m2=50, K=8, weight=1.0),
)


def make_request(rng: np.random.Generator, scenario: Scenario,
                 rid: int) -> RankRequest:
    """One synthetic request drawn from the scenario's distribution."""
    lo = max(scenario.m2, int(scenario.m1 * (1.0 - scenario.m1_jitter)))
    m1 = int(rng.integers(lo, scenario.m1 + 1))
    m2, K = scenario.m2, scenario.K
    u = rng.uniform(1.0, 5.0, m1).astype(np.float32)
    a = (rng.random((K, m1)) < scenario.topic_rate).astype(np.float32)
    gamma = np.asarray(dcg_discount(m2), np.float32)
    b = (scenario.b_frac * float(gamma.sum())
         * np.ones(K, np.float32))
    lam = X = None
    if scenario.tag == LAM_TAG:
        lam = rng.exponential(0.5, K).astype(np.float32)
    else:
        X = rng.normal(size=scenario.d_cov).astype(np.float32)
    return RankRequest(rid=rid, u=u, a=a, b=b, m2=m2, lam=lam, X=X,
                       tag=scenario.tag, gamma=gamma)


def make_stream(scenarios=DEFAULT_MIX, *, n_requests: int = 256,
                seed: int = 0) -> list[RankRequest]:
    """Weighted interleaving of the scenarios into one request stream."""
    rng = np.random.default_rng(seed)
    w = np.asarray([s.weight for s in scenarios], np.float64)
    w = w / w.sum()
    picks = rng.choice(len(scenarios), size=n_requests, p=w)
    return [make_request(rng, scenarios[int(i)], rid)
            for rid, i in enumerate(picks)]
