"""Scenario-mix request generator + paced open-loop load generation for
the serving engine.

A live fleet multiplexes surfaces — a home feed with ~500 candidates and
50 slots, a related-items strip with ~1k candidates and 20 slots, a
notification ranker with tiny slates, a retrieval head with 10^5+
candidates — each behind a different recommender architecture with its
own constraint system. A Scenario captures one such surface's geometry
distribution; `make_stream` interleaves scenarios by weight into a
single request sequence the engine can be driven with.

Payloads are synthetic (utilities ~ U[1, 5], sparse topic attributes,
thresholds as a fraction of the total slot discount — the same
conventions as benchmarks/ and the dual-solver tests) but every request
is a well-posed instance of the paper's online problem, so compliance
numbers are meaningful, not decorative. Plugging real backbone scores in
instead is a one-line swap (see repro.launch.serve).

Load generation: `poisson_arrivals` + `serve_open_loop` drive a stream
OPEN-LOOP — request i is submitted at its pre-drawn Poisson arrival
time regardless of how far behind the engine is. A closed-loop driver
(submit back-to-back, next request waits for the previous dispatch)
measures only the engine's saturated throughput and silently hides
queueing delay: offered load can never exceed service rate, so the
latency/throughput frontier is invisible. Open-loop pacing is what
exposes it — below saturation, p99 reflects batching + service time;
approaching saturation, queueing delay blows the tail up
(benchmarks/latency_serve.py --frontier sweeps this curve).
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core.constraints import dcg_discount
from repro.serving.engine import LAM_TAG, RankRequest


@dataclass(frozen=True)
class Scenario:
    """One traffic surface: a geometry distribution + arrival weight."""

    name: str
    m1: int                    # nominal candidate count
    m2: int                    # nominal slot count
    K: int                     # constraint count
    weight: float = 1.0        # relative arrival rate
    tag: str = LAM_TAG         # predictor tag ('_lam' = request carries lam)
    d_cov: int = 20            # covariate dim (used when tag != '_lam')
    m1_jitter: float = 0.5     # m1 sampled from [m1*(1-jitter), m1]
    topic_rate: float = 0.15   # sparsity of the constraint attributes
    b_frac: float = 0.06       # threshold as fraction of sum(gamma)
    surface: str = "default"   # budget class (engine.surface_budgets)


# A default mix spanning >= 3 geometries and 2 "archs" (surfaces): the
# shapes mirror the repo's recsys configs (sasrec feed, bert4rec strip,
# mind notifications, deepfm retrieval).
DEFAULT_MIX = (
    Scenario("feed_sasrec", m1=500, m2=50, K=5, weight=4.0),
    Scenario("strip_bert4rec", m1=1000, m2=20, K=5, weight=2.0),
    Scenario("notif_mind", m1=120, m2=8, K=3, weight=1.0),
    Scenario("retrieval_deepfm", m1=4000, m2=50, K=8, weight=1.0),
)


def make_request(rng: np.random.Generator, scenario: Scenario,
                 rid: int) -> RankRequest:
    """One synthetic request drawn from the scenario's distribution."""
    lo = max(scenario.m2, int(scenario.m1 * (1.0 - scenario.m1_jitter)))
    m1 = int(rng.integers(lo, scenario.m1 + 1))
    m2, K = scenario.m2, scenario.K
    u = rng.uniform(1.0, 5.0, m1).astype(np.float32)
    a = (rng.random((K, m1)) < scenario.topic_rate).astype(np.float32)
    gamma = np.asarray(dcg_discount(m2), np.float32)
    b = (scenario.b_frac * float(gamma.sum())
         * np.ones(K, np.float32))
    lam = X = None
    if scenario.tag == LAM_TAG:
        lam = rng.exponential(0.5, K).astype(np.float32)
    else:
        X = rng.normal(size=scenario.d_cov).astype(np.float32)
    return RankRequest(rid=rid, u=u, a=a, b=b, m2=m2, lam=lam, X=X,
                       tag=scenario.tag, gamma=gamma,
                       surface=scenario.surface)


def make_stream(scenarios=DEFAULT_MIX, *, n_requests: int = 256,
                seed: int = 0) -> list[RankRequest]:
    """Weighted interleaving of the scenarios into one request stream."""
    rng = np.random.default_rng(seed)
    w = np.asarray([s.weight for s in scenarios], np.float64)
    w = w / w.sum()
    picks = rng.choice(len(scenarios), size=n_requests, p=w)
    return [make_request(rng, scenarios[int(i)], rid)
            for rid, i in enumerate(picks)]


def make_drift_stream(spec, *, tag: str, n_requests: int = 256,
                      m1: int = 256, m2: int = 16, K: int = 4,
                      d_cov: int = 20, topic_rate: float = 0.15,
                      b_frac: float = 0.03, seed: int = 0
                      ) -> list[RankRequest]:
    """A single-surface covariate stream whose distribution drifts
    mid-stream per `spec` (data.synthetic.DriftSpec): request i sits at
    stream fraction i/(n-1) on the drift ramp. Fixed geometry — the
    drift scenarios isolate DISTRIBUTION shift from shape churn, so a
    refresh-on/refresh-off comparison sees identical bucketing and
    batch composition. `tag` must name a registered predictor (the
    stream carries covariates, never raw λ)."""
    from repro.data.synthetic import drift_request_params  # deferred

    if tag == LAM_TAG:
        raise ValueError("drift streams are covariate streams: pass a "
                         "predictor tag, not the raw-lam tag")
    rng = np.random.default_rng(seed)
    denom = max(n_requests - 1, 1)
    reqs = []
    for rid in range(n_requests):
        p = drift_request_params(
            rng, spec, rid / denom, m1=m1, m2=m2, K=K, d_cov=d_cov,
            topic_rate=topic_rate, b_frac=b_frac)
        reqs.append(RankRequest(rid=rid, u=p["u"], a=p["a"], b=p["b"],
                                m2=m2, X=p["X"], tag=tag,
                                gamma=p["gamma"]))
    return reqs


# ---------------------------------------------------------------------------
# Paced open-loop load generation
# ---------------------------------------------------------------------------

def poisson_arrivals(n_requests: int, qps: float, *, seed: int = 0,
                     start: float = 0.0) -> np.ndarray:
    """Arrival times (seconds, relative to the stream start) of a Poisson
    process at rate `qps`: i.i.d. exponential inter-arrival gaps with
    mean 1/qps. The canonical open-loop offered-load model — arrivals do
    not react to the server, and bursts (several requests inside one
    service time) occur with the probability real traffic has."""
    if qps <= 0:
        raise ValueError(f"qps must be > 0, got {qps}")
    rng = np.random.default_rng(seed)
    return start + np.cumsum(rng.exponential(1.0 / qps, int(n_requests)))


def serve_open_loop(engine, requests, arrivals, *,
                    clock=time.perf_counter, sleep=time.sleep,
                    poll_interval_s: float = 5e-4,
                    deadline_budget_s: float | None = None):
    """Drive `engine` open-loop: submit requests[i] once the stream clock
    reaches arrivals[i], never waiting on completions. While pacing
    between arrivals the engine is polled so deadline flushes fire on
    schedule. Returns (results, stats) — stats carries the wall clock
    and the SUBMISSION-LAG profile (ms by which each submit trailed its
    scheduled arrival).

    With `deadline_budget_s` set, every request is stamped with the
    ABSOLUTE deadline `t0 + arrival + deadline_budget_s` before submit
    — the budget runs from the request's scheduled arrival, the way a
    caller-side SLA does. A relative budget (engine default) would
    restart the clock at submit time, silently forgiving any lateness
    the load generator accumulated blocking on engine backpressure —
    exactly the lateness an overloaded server inflicts.

    Open-loop semantics under overload: submission keeps pressing at the
    offered rate; the only thing allowed to slow it down is the engine's
    own backpressure (a full pipeline window blocking `submit`), which
    is exactly the queueing delay a saturated server inflicts — it shows
    up in per-request latency instead of being silently absorbed by the
    load generator, so the measured frontier is honest. Below saturation
    lag stays bounded (sleep-granularity noise); past it, lag grows over
    the stream.

    The lag profile is DECOMPOSED so the saturation detector (and the
    admission controller, which consumes it online via
    `engine.observe_submission_lag`) never trips on pacing jitter:

      queue_lag_ms  lateness already present when the driver REACHES an
                    arrival's pacing loop — carry-over from earlier
                    submits that blocked on engine backpressure. Zero
                    below saturation; grows over the stream past it.
                    `queue_lag_ms['last']` is the saturation telltale.
      drift_ms      lateness accrued INSIDE the pacing wait — sleep
                    granularity overshoot + in-loop poll time. Bounded
                    by the platform timer resolution at any load;
                    charging it to the engine (the pre-decomposition
                    bug) made the detector trip on pacing jitter.
      lag_ms        the sum: total lateness at submit time (kept for
                    continuity with earlier frontier artifacts).
    """
    requests = list(requests)
    arrivals = np.asarray(arrivals, np.float64)
    if len(requests) != len(arrivals):
        raise ValueError(f"{len(requests)} requests vs {len(arrivals)} "
                         f"arrival times")
    if not requests:
        raise ValueError("empty request stream: an open-loop run needs at "
                         "least one arrival")
    feed_lag = getattr(engine, "observe_submission_lag", None)
    results = []
    lags = np.zeros(len(requests))
    queue_lags = np.zeros(len(requests))
    t0 = clock()
    for i, (req, due) in enumerate(zip(requests, arrivals)):
        # lateness at ENTRY is queueing carry-over (earlier submits
        # blocked on backpressure), not pacing noise: nothing in this
        # arrival's own pacing loop has run yet.
        queue_lags[i] = max(0.0, (clock() - t0 - due)) * 1e3
        while clock() - t0 < due:
            results += engine.poll()
            remaining = due - (clock() - t0)
            if remaining > 0:
                sleep(min(remaining, poll_interval_s))
        lags[i] = (clock() - t0 - due) * 1e3
        if feed_lag is not None:
            feed_lag(queue_lags[i])
        if deadline_budget_s is not None:
            req.deadline = t0 + due + deadline_budget_s
        results += engine.submit(req)
        results += engine.poll()
    results += engine.drain()
    wall = clock() - t0
    drifts = lags - queue_lags

    def _profile(xs):
        return {
            "mean": float(xs.mean()),
            "p50": float(np.percentile(xs, 50)),
            "p99": float(np.percentile(xs, 99)),
            "max": float(xs.max()),
            "last": float(xs[-1]),
        }

    stats = {
        "wall_s": wall,
        "offered_qps": len(requests) / float(arrivals[-1]),
        "achieved_qps": len(requests) / wall,
        "lag_ms": _profile(lags),
        "queue_lag_ms": _profile(queue_lags),
        "drift_ms": _profile(drifts),
    }
    return results, stats
