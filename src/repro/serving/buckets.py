"""Shape-bucket geometry for the streaming serving engine.

A live request stream is shape-heterogeneous: every request carries its
own candidate count m1, slot count m2 and constraint count K (and mixed
recommender architectures upstream produce different mixes of all
three). XLA compiles one executable per distinct input shape, so feeding
raw shapes to jit would recompile on nearly every request — fatal inside
a 50 ms budget (a CPU compile is ~100 ms-1 s; a TPU compile worse).

The classic fix (cf. serving stacks like TF-Serving's batching layer and
inference engines with shape polymorphism) is to quantize shapes into a
small lattice of buckets and pad every request up to its bucket:

  m1, m2  -> power-of-two ceilings (>= MIN_M1 / MIN_M2 floors)
  K       -> fixed tiers K_TIERS (constraint counts cluster tightly in
             practice: the paper runs 5; our scenarios run 3-16)
  batch   -> one fixed micro-batch capacity per bucket

so the total executable count is bounded by the lattice size, every
executable is pre-warmable, and steady state never recompiles.

Padding must not change the answer. The scheme (verified exactly in
tests/test_serving.py against the unpadded path):

  candidates m1 -> m1p : u filled with NEG_FILL (a finite -1e30 — large
      enough that no padded candidate ever enters a top-m2, finite so
      0-discount slots contribute exactly 0.0, not NaN, to utility);
      attribute columns a filled with 0.
  slots m2 -> m2p      : the per-request discount vector gamma is
      zero-extended. Utility and exposure are gamma-weighted sums, so
      phantom slots contribute nothing; the real ranking is the first
      m2 entries of the padded perm (scores sort descending and padded
      candidates sort last).
  constraints K -> Kp  : zero rows in a, zero thresholds in b, zero
      shadow prices in lam. Exposure of a phantom constraint is 0 >= 0,
      so compliance is unchanged.
  batch n -> capacity  : whole phantom rows (NEG_FILL utilities, zero
      constraints); sliced off before results leave the engine.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass

import numpy as np

from repro.core.constraints import dcg_discount

# Finite "minus infinity" for padded candidate utilities: keeps padded
# candidates out of every top-m2 while 0.0 * NEG_FILL == 0.0 exactly.
NEG_FILL = -1.0e30

MIN_M1 = 128       # lane-aligned floor for the candidate axis
MIN_M2 = 8         # sublane-aligned floor for the slot axis
K_TIERS = (4, 8, 16, 32)


def ceil_pow2(n: int, floor: int = 1) -> int:
    """Smallest power of two >= max(n, floor)."""
    n = max(int(n), int(floor))
    return 1 << (n - 1).bit_length()


def k_tier(K: int, tiers=K_TIERS) -> int:
    """Smallest tier >= K; oversize K falls back to its pow2 ceiling
    (still a valid bucket — just outside the pre-warmed lattice)."""
    for t in tiers:
        if K <= t:
            return t
    return ceil_pow2(K)


@dataclass(frozen=True, order=True)
class Bucket:
    """One compiled-shape equivalence class (and jit-cache key)."""

    tag: str      # executor affinity: predictor/arch tag ('_lam' = raw lam)
    m1: int       # padded candidate count
    m2: int       # padded slot count
    K: int        # padded constraint count
    batch: int    # micro-batch capacity (requests per executable call)

    @property
    def name(self) -> str:
        return f"{self.tag}/m1={self.m1}/m2={self.m2}/K={self.K}/B={self.batch}"


# ---------------------------------------------------------------------------
# Per-geometry kernel autotune table
# ---------------------------------------------------------------------------
# benchmarks/autotune.py sweeps TILE_B / TILE_M / DB_SLAB / quant mode
# per bucket geometry on the target backend and caches the winners as
# JSON next to the bucket lattice; the engine loads the table at
# construction and applies each bucket's entry when it builds that
# bucket's executable. Keys are tag-independent (the kernel geometry is
# what the tiles tune, not the predictor identity).

DEFAULT_AUTOTUNE_PATH = "experiments/bench/autotune_table.json"

# the tunable knobs an autotune entry may carry; anything else in an
# entry is ignored by the engine (forward compatibility)
AUTOTUNE_KEYS = ("tile_b", "tile_m", "tile_n", "quant")


def geometry_key(bucket: Bucket, *, d_cov: int | None = None) -> str:
    """The autotune-table key for a bucket: its padded kernel geometry,
    without the tag (two tags sharing a geometry share tiles). The key
    is a pure function of the ACTUAL (m1, m2, K, B[, d_cov]) numbers —
    never of the bucket's position in any lattice — so tuned tiles
    survive an adaptive-lattice swap: a corner that moves from slot 3
    to slot 1 still resolves to the same entry."""
    key = f"m1={bucket.m1}/m2={bucket.m2}/K={bucket.K}/B={bucket.batch}"
    if d_cov is not None:
        key += f"/d={int(d_cov)}"
    return key


def resolve_autotune(table: dict, bucket: Bucket, *,
                     d_cov: int | None = None) -> dict:
    """Resolve `bucket`'s tuned knobs from an autotune table, surviving
    lattice swaps. Lookup chain:

      1. exact geometry key with the covariate width (".../d=16");
      2. the legacy tag-free key without it (tables tuned before
         covariate-aware keys existed);
      3. the nearest tuned geometry that COVERS this bucket (same batch,
         m1/m2/K all >=), tiles clamped to this bucket's extents — a
         freshly-learned adaptive corner inherits its power-of-two
         parent's tiles instead of silently falling back to defaults.

    Returns {} when nothing applies (the engine serves on defaults).
    """
    if not table:
        return {}
    if d_cov is not None:
        hit = table.get(geometry_key(bucket, d_cov=d_cov))
        if hit:
            return dict(hit)
    hit = table.get(geometry_key(bucket))
    if hit:
        return dict(hit)
    best, best_cost = None, None
    for key, entry in table.items():
        dims = {}
        for part in key.split("/"):
            name, _, val = part.partition("=")
            if val:
                try:
                    dims[name] = int(val)
                except ValueError:
                    pass
        if not {"m1", "m2", "K", "B"} <= dims.keys():
            continue
        if dims["B"] != bucket.batch:
            continue
        if (dims["m1"] < bucket.m1 or dims["m2"] < bucket.m2
                or dims["K"] < bucket.K):
            continue
        cost = dims["m1"] * dims["m2"] + dims["K"] * dims["m1"]
        if best_cost is None or cost < best_cost:
            best, best_cost = entry, cost
    if best is None:
        return {}
    out = dict(best)
    # clamp inherited tiles so they still divide into this (smaller)
    # corner's extents
    if "tile_b" in out:
        out["tile_b"] = min(int(out["tile_b"]), bucket.batch)
    if "tile_m" in out:
        out["tile_m"] = min(int(out["tile_m"]), bucket.m1)
    return out


def save_autotune_table(table: dict, path: str = DEFAULT_AUTOTUNE_PATH
                        ) -> str:
    """Write {geometry_key: {tile_b/tile_m/tile_n/quant, ...}} as JSON.
    Round-trips through load_autotune_table bit-for-bit (str keys, int
    tiles, str quant mode)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump({"version": 1, "table": table}, f, indent=1, sort_keys=True)
    return path


def load_autotune_table(path: str = DEFAULT_AUTOTUNE_PATH) -> dict:
    """Load a saved autotune table; {} when the file is absent (an
    engine without a table serves on the defaults)."""
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        payload = json.load(f)
    return payload.get("table", {})


def bucket_for(*, m1: int, m2: int, K: int, tag: str, batch: int) -> Bucket:
    """Map a request geometry to its bucket. m2p is clamped to m1p so a
    bucket is always a well-posed ranking problem (m2 <= m1 is already
    required of requests; padding preserves it)."""
    if m2 > m1:
        raise ValueError(f"request needs m2 <= m1, got m2={m2} > m1={m1}")
    m1p = ceil_pow2(m1, MIN_M1)
    m2p = min(ceil_pow2(m2, MIN_M2), m1p)
    return Bucket(tag=tag, m1=m1p, m2=m2p, K=k_tier(K), batch=int(batch))


# ---------------------------------------------------------------------------
# Batch assembly (host-side, numpy: cheap writes into reusable staging buffers)
# ---------------------------------------------------------------------------

PAGE = 4096  # host page size the pinned staging buffers align to


def _aligned_empty(shape, dtype=np.float32, align: int = PAGE) -> np.ndarray:
    """A page-aligned uninitialized host array. Page alignment is what
    pinned-memory registration and zero-copy H2D DMA want; numpy's
    default allocator gives 16/32-byte alignment, so we over-allocate a
    byte buffer and slice to the first page boundary. The returned view
    owns a reference to its base, is C-contiguous and writeable."""
    dtype = np.dtype(dtype)
    nbytes = int(np.prod(shape)) * dtype.itemsize
    raw = np.empty(nbytes + align, np.uint8)
    offset = (-raw.ctypes.data) % align
    view = raw[offset:offset + nbytes].view(dtype).reshape(shape)
    assert view.ctypes.data % align == 0
    return view


def alloc_staging(bucket: Bucket, *, d_cov: int | None = None) -> dict:
    """Allocate one set of host staging buffers for `bucket`.

    Returns dict with u (B, m1), a (B, K, m1), b (B, K), gamma (B, m2)
    and either lam (B, K) (tag '_lam') or X (B, d_cov). Buffers are
    PAGE-aligned (see _aligned_empty) so an accelerator runtime can
    pin/register them for async H2D; `fill_staging` resets and packs
    them per micro-batch, and `repro.serving.pipeline.StagingRing`
    recycles a fixed set of them so steady state allocates nothing on
    the submission path (the ring asserts this — every buffer released
    to it must be one it handed out).
    """
    B, m1p, m2p, Kp = bucket.batch, bucket.m1, bucket.m2, bucket.K
    staged = {
        "u": _aligned_empty((B, m1p)),
        "a": _aligned_empty((B, Kp, m1p)),
        "b": _aligned_empty((B, Kp)),
        "gamma": _aligned_empty((B, m2p)),
    }
    if d_cov is None:
        staged["lam"] = _aligned_empty((B, Kp))
    else:
        staged["X"] = _aligned_empty((B, d_cov))
    return staged


def fill_staging(staged: dict, requests, bucket: Bucket) -> dict:
    """Reset `staged` to the padding identity and pack `requests` in.

    In-place: the arrays in `staged` are reused across micro-batches
    (their previous contents are fully overwritten — phantom rows
    included — so recycling a buffer can never leak a stale request).
    """
    n = len(requests)
    if n > bucket.batch:
        raise ValueError(f"{n} requests > bucket capacity {bucket.batch}")
    staged["u"].fill(NEG_FILL)
    staged["a"].fill(0.0)
    staged["b"].fill(0.0)
    staged["gamma"].fill(0.0)
    if "lam" in staged:
        staged["lam"].fill(0.0)
    else:
        staged["X"].fill(0.0)
    for i, r in enumerate(requests):
        m1, K, m2 = r.u.shape[0], r.a.shape[0], r.m2
        staged["u"][i, :m1] = r.u
        staged["a"][i, :K, :m1] = r.a
        staged["b"][i, :K] = r.b
        g = r.gamma if r.gamma is not None else dcg_discount(m2)
        staged["gamma"][i, :m2] = np.asarray(g, np.float32)
        if r.lam is not None:
            staged["lam"][i, :K] = r.lam
        if "X" in staged:
            staged["X"][i] = r.X
    return staged


def assemble_batch(requests, bucket: Bucket, *, d_cov: int | None = None):
    """Pack up to `bucket.batch` requests into fresh padded staging
    arrays (alloc_staging + fill_staging). The engine's hot path goes
    through a StagingRing instead so buffers are recycled; this
    fresh-allocation form is used by warmup and by tests."""
    return fill_staging(alloc_staging(bucket, d_cov=d_cov), requests, bucket)


def unpad_result(out, i: int, request):
    """Slice row `i` of a batched RankingOutput back to the request's
    real geometry: (perm (m2,), utility, exposure (K,), compliant)."""
    m2, K = request.m2, request.a.shape[0]
    perm = np.asarray(out.perm[i, :m2])
    utility = float(out.utility[i])
    exposure = np.asarray(out.exposure[i, :K])
    compliant = bool(out.compliant[i])
    return perm, utility, exposure, compliant


def fill_stats(requests, bucket: Bucket) -> dict:
    """Padding overhead of a micro-batch: real vs padded (batch x m1)
    cells AND real vs padded sweep FLOPs (rank m1*m2 + audit K*m1 per
    request) — the price paid for the bounded-executable-count
    guarantee, and the raw numbers behind the engine's
    padding_waste_ratio."""
    real = 0
    real_flops = 0
    for r in requests:
        m1, K, m2 = int(r.u.shape[0]), int(r.a.shape[0]), int(r.m2)
        real += m1
        real_flops += m1 * m2 + K * m1
    padded = bucket.batch * bucket.m1
    padded_flops = bucket.batch * (bucket.m1 * bucket.m2
                                   + bucket.K * bucket.m1)
    return {"real_cells": real, "padded_cells": padded,
            "real_flops": real_flops, "padded_flops": padded_flops,
            "fill": real / padded if padded else 0.0}
