"""Online λ-refresh lane: predictor updates from serving telemetry,
hot-swapped into the warmed executables with zero recompiles.

The paper freezes the λ-predictor at deployment. The primal-dual view
(Shah et al., arXiv:1702.06971) says it doesn't have to be: for each
served request the fused kernel already audits the realized exposure
against the thresholds, and `b - exposure` IS the subgradient of the
dual objective at the served λ̂. One projected subgradient step per
request,

    λ_target = max(0, λ̂_served + η · (b − exposure)),

yields a fresh (X, λ_target) supervision pair at zero extra device cost
— the audit outputs come home with every batch anyway. The lane
accumulates these pairs per predictor tag (engine._build_result feeds
`observe`), folds them into the predictor's ARRAY state per family, and
publishes the new generation through `engine.swap_predictor`:

  KNN      ring-write the newest (X, λ_target) rows over the oldest db
           rows (`knn_ring_update`) — n_train is frozen, so shapes (and
           therefore the warmed executables) never change; eviction is
           strictly oldest-first.
  linear   anchored ridge re-solve (`ridge_refresh`): minimize
           Σ‖y − W̃x̃‖² + μ‖W̃ − W̃_live‖² over the augmented x̃ = [x; 1]
           — each sample contributes a rank-1 x̃x̃ᵀ update to the Gram
           matrix, and the live (W, c) is the prior anchor, so history
           carries recursively across refreshes.
  mean     running intercept (`running_mean_update`): the live mean is
           a prior observation of weight w, the targets average in.
  mlp      warm-start re-fit: MLPLambdaPredictor.fit(init_params=live,
           num_steps=small) — a few Adam steps of the one-jit lax.scan
           fit from the serving parameters, not a from-scratch train.

Swap safety is the engine's epoch fence (engine.swap_predictor): new
buffers are validated (structure/shape/dtype/finiteness) and published
to the device BEFORE the (state, epoch) pair flips under the same lock
every flush reads it under — a micro-batch is always served by exactly
one generation, and a refused (poisoned) generation leaves serving on
last-good with `refresh_failures` incremented. `rollback` re-publishes
the state that was live before the most recent successful swap.

Stationarity gate (two-sided): a refresh only publishes when the
drained telemetry shows dual PRESSURE in either direction —
under-exposure shortfall (clip(b − exposure, 0), pushes λ up) or
over-satisfaction decay (clip(exposure − b, 0) on rows whose served
λ̂ > 0: a constraint exceeded while still paying a utility boost, so
the symmetric step in dual_refresh_targets relaxes its λ toward 0 and
recovers utility). Traffic with neither — compliant AND either
exactly-met or unpriced (λ̂ = 0) — teaches the lane nothing: λ_target
degenerates to λ̂_served, the lane never swaps, and serving is bitwise
identical to refresh-off (tests/test_refresh.py asserts both the
neutrality and the decay-toward-zero direction).

`refresh()` can be driven synchronously (every N requests — the
deterministic mode the drift tests use) or from the background thread
(`start(interval_s)`), which contains crashes: an exception inside the
loop counts a refresh failure and the lane keeps running — it never
takes serving down.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "RefreshLane",
    "dual_refresh_targets",
    "knn_ring_update",
    "ridge_refresh",
    "running_mean_update",
]


# ---------------------------------------------------------------------------
# Pure update rules (property-tested in tests/test_refresh.py)
# ---------------------------------------------------------------------------

def dual_refresh_targets(lam, b, exposure, *, eta: float) -> np.ndarray:
    """Projected dual-subgradient targets: one step of size `eta` along
    b − exposure (the dual subgradient at the served λ̂), projected onto
    λ ≥ 0. Under-exposed constraints push λ up, over-exposed ones relax
    it, exactly-met ones return λ̂ unchanged."""
    lam = np.asarray(lam, np.float32)
    step = np.asarray(b, np.float32) - np.asarray(exposure, np.float32)
    return np.maximum(lam + np.float32(eta) * step, 0.0).astype(np.float32)


def knn_ring_update(X_db, lam_db, X_new, lam_new, cursor: int,
                    *, return_written: bool = False):
    """Append-with-evict for a frozen-shape KNN db: write the new rows
    over the oldest ones at `cursor` (wrapping), return host copies of
    the updated (X_db, lam_db) and the advanced cursor. When more new
    rows arrive than the db holds, only the newest n_train survive —
    the same rows a from-scratch fit on the trailing window would hold
    (the append/evict parity property). With `return_written`, a fourth
    element carries the sorted unique row indices actually written —
    the quantized-db refresh repacks exactly those rows' slabs and no
    others, so a swap can never publish a scale that predates its
    slab's rows."""
    X_db = np.array(X_db)                   # host copies; inputs untouched
    lam_db = np.array(lam_db)
    X_new = np.asarray(X_new, X_db.dtype)
    lam_new = np.asarray(lam_new, lam_db.dtype)
    n_train = X_db.shape[0]
    n = X_new.shape[0]
    if n == 0:
        idx = np.zeros((0,), np.int64)
        return ((X_db, lam_db, cursor, idx) if return_written
                else (X_db, lam_db, cursor))
    if n > n_train:                         # only the newest rows survive
        X_new, lam_new = X_new[n - n_train:], lam_new[n - n_train:]
        cursor, n = (cursor + (n - n_train)) % n_train, n_train
    idx = (cursor + np.arange(n)) % n_train
    X_db[idx] = X_new
    lam_db[idx] = lam_new
    cursor = int((cursor + n) % n_train)
    if return_written:
        return X_db, lam_db, cursor, np.unique(idx)
    return X_db, lam_db, cursor


def ridge_refresh(W, c, X_new, targets, *, mu: float = 32.0
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Anchored ridge re-solve on the augmented design x̃ = [x; 1]:
    argmin_W̃ Σ‖y − W̃x̃‖² + μ‖W̃ − W̃_0‖²_F with W̃_0 = [W | c] the live
    weights. Closed form (d+1 × d+1 solve): each sample is a rank-1
    x̃x̃ᵀ Gram update, and as μ → ∞ the update vanishes — the anchor is
    what carries history across refreshes."""
    W = np.asarray(W, np.float64)
    c = np.asarray(c, np.float64)
    X_new = np.asarray(X_new, np.float64)
    Y = np.asarray(targets, np.float64)
    d = W.shape[1]
    Xa = np.concatenate([X_new, np.ones((X_new.shape[0], 1))], axis=1)
    G = mu * np.eye(d + 1) + Xa.T @ Xa                    # (d+1, d+1)
    W0a = np.concatenate([W, c[:, None]], axis=1)         # (K, d+1)
    rhs = mu * W0a.T + Xa.T @ Y                           # (d+1, K)
    Wa = np.linalg.solve(G, rhs).T                        # (K, d+1)
    return Wa[:, :d].astype(np.float32), Wa[:, d].astype(np.float32)


def running_mean_update(mean_lam, weight: float, targets
                        ) -> tuple[np.ndarray, float]:
    """Running intercept: the live mean counts as `weight` prior
    observations, the target rows average in. Returns (new mean,
    new weight)."""
    mean_lam = np.asarray(mean_lam, np.float64)
    Y = np.asarray(targets, np.float64)
    n = Y.shape[0]
    new = (weight * mean_lam + Y.sum(axis=0)) / (weight + n)
    return new.astype(np.float32), float(weight + n)


# ---------------------------------------------------------------------------
# The lane
# ---------------------------------------------------------------------------

@dataclass
class _TagBuffer:
    """Telemetry rows accumulated for one predictor tag since its last
    refresh. Bounded: only the newest `capacity` rows are kept."""

    X: list = field(default_factory=list)
    lam: list = field(default_factory=list)
    exposure: list = field(default_factory=list)
    b: list = field(default_factory=list)

    def trim(self, capacity: int) -> None:
        if len(self.X) > capacity:
            for rows in (self.X, self.lam, self.exposure, self.b):
                del rows[:len(rows) - capacity]


class RefreshLane:
    """Background refresh lane for one ServingEngine (see module doc).

    eta             dual-subgradient step size.
    capacity        max telemetry rows buffered per tag (newest win).
    min_samples     rows required before a refresh will publish.
    min_shortfall   stationarity gate: publish only if some buffered
                    row's exposure shortfall sum — or its λ-weighted
                    over-satisfaction (decay pressure) sum — exceeds
                    this.
    mu              ridge anchor weight (linear family).
    mean_weight     prior weight of the live mean (mean family).
    mlp_steps/lr    warm-start re-fit budget (mlp family).
    checkpoint      optional checkpoint.CheckpointStore: every
                    successfully published generation is ALSO written
                    as a per-(tag, epoch) checkpoint
                    (save_predictor_epoch) — what a fleet supervisor
                    restores a restarted replica from, so it resumes
                    at last-good λ̂ instead of the cold generation 0.
                    A failed checkpoint write never un-publishes the
                    swap (the report carries `checkpointed`).
    publish_filter  optional hook (tag, state) -> state applied to the
                    candidate state just before the swap — the fault
                    harness's poisoned-swap seam (serving/faults.py);
                    a filter that returns poisoned state exercises the
                    engine's refusal path, not a mock of it.
    """

    def __init__(self, engine, *, eta: float = 0.5, capacity: int = 4096,
                 min_samples: int = 8, min_shortfall: float = 0.0,
                 mu: float = 32.0, mean_weight: float = 32.0,
                 mlp_steps: int = 50, mlp_lr: float = 1e-2,
                 checkpoint=None, publish_filter=None):
        self.engine = engine
        self.eta = float(eta)
        self.capacity = int(capacity)
        self.min_samples = int(min_samples)
        self.min_shortfall = float(min_shortfall)
        self.mu = float(mu)
        self.mlp_steps = int(mlp_steps)
        self.mlp_lr = float(mlp_lr)
        self.checkpoint = checkpoint
        self.publish_filter = publish_filter
        self._lock = threading.Lock()
        # serializes whole refresh passes: the background loop, any
        # synchronous refresh() caller, and stop()'s final refresh
        # must never interleave — two concurrent _refresh_tag calls on
        # one tag would read the same live state and double-publish
        # one telemetry window (racing _knn_cursor / _mean_weight).
        self._refresh_lock = threading.Lock()
        self._buf: dict[str, _TagBuffer] = {}
        self._mean_weight: dict[str, float] = {}
        self._default_mean_weight = float(mean_weight)
        self._knn_cursor: dict[str, int] = {}
        # the state that was live before the most recent successful
        # swap, per tag — what rollback() re-publishes.
        self._last_good: dict[str, dict] = {}
        self._thread: threading.Thread | None = None
        self._stop_evt = threading.Event()
        engine.attach_refresh(self)

    # -- telemetry ingest (called by engine._build_result) -------------------

    def observe(self, tag: str, *, X, lam, exposure, b) -> None:
        """One served request's telemetry row: covariates, the λ̂ the
        executable actually used, and the audited exposure against the
        thresholds — all at the tag's predictor width, all host numpy
        (the batch's outputs were already materialized)."""
        with self._lock:
            buf = self._buf.setdefault(tag, _TagBuffer())
            buf.X.append(np.asarray(X, np.float32))
            buf.lam.append(np.asarray(lam, np.float32))
            buf.exposure.append(np.asarray(exposure, np.float32))
            buf.b.append(np.asarray(b, np.float32))
            buf.trim(self.capacity)

    def pending(self, tag: str) -> int:
        """Telemetry rows buffered for `tag` since its last refresh."""
        with self._lock:
            buf = self._buf.get(tag)
            return 0 if buf is None else len(buf.X)

    # -- refresh -------------------------------------------------------------

    def refresh(self, tag: str | None = None) -> dict:
        """Drain the buffered telemetry and, where it warrants one,
        publish a new predictor generation. Never raises on a failed
        publish: the engine refuses bad state, `refresh_failures`
        increments, serving stays on last-good, and the report says
        what happened. Returns {tag: report} (one tag when given).

        Whole passes are serialized (`_refresh_lock`): a synchronous
        caller — including stop()'s final refresh — never interleaves
        with the background loop's in-flight pass."""
        with self._refresh_lock:
            tags = ([tag] if tag is not None
                    else sorted(self._buf))
            return {t: self._refresh_tag(t) for t in tags}

    def _drain(self, tag: str):
        with self._lock:
            buf = self._buf.pop(tag, None)
        if buf is None or not buf.X:
            return None
        return (np.stack(buf.X), np.stack(buf.lam),
                np.stack(buf.exposure), np.stack(buf.b))

    def _refresh_tag(self, tag: str) -> dict:
        report = {"swapped": False, "epoch": None, "n": 0,
                  "max_shortfall": 0.0, "max_decay": 0.0, "reason": None}
        drained = self._drain(tag)
        if drained is None:
            report["reason"] = "no-telemetry"
            return report
        X, lam, exposure, b = drained
        report["n"] = int(X.shape[0])
        if X.shape[0] < self.min_samples:
            report["reason"] = "below-min-samples"
            return report
        shortfall = np.clip(b - exposure, 0.0, None).sum(axis=1)
        # decay pressure: over-satisfied constraints that still carry a
        # positive served λ̂ — the symmetric subgradient step relaxes
        # them toward 0, recovering the utility the boost was costing.
        decay = (np.clip(exposure - b, 0.0, None)
                 * (lam > 0.0)).sum(axis=1)
        report["max_shortfall"] = float(shortfall.max())
        report["max_decay"] = float(decay.max())
        if (report["max_shortfall"] <= self.min_shortfall
                and report["max_decay"] <= self.min_shortfall):
            # stationarity gate: traffic with no dual pressure in
            # either direction teaches nothing — publishing would
            # still perturb KNN neighbourhoods, so don't (bitwise
            # neutrality under a stationary stream).
            report["reason"] = "no-pressure"
            return report
        targets = dual_refresh_targets(lam, b, exposure, eta=self.eta)
        try:
            new_state = self._updated_state(tag, X, targets)
            if self.publish_filter is not None:
                new_state = self.publish_filter(tag, new_state)
            prev = self.engine.predictor_state_of(tag)
            epoch = self.engine.swap_predictor(tag, new_state)
        except Exception as e:            # noqa: BLE001 — lane must survive
            self.engine.metrics.on_refresh_failure(tag)
            report["reason"] = f"refused: {e}"
            return report
        self._last_good[tag] = prev
        report["swapped"] = True
        report["epoch"] = epoch
        if self.checkpoint is not None:
            # persist the published generation for the fleet's restart
            # path. The swap already flipped — a failed write degrades
            # restartability, never liveness, so it only marks the
            # report (and counts a refresh failure for observability).
            try:
                self.checkpoint.save_predictor_epoch(tag, epoch, new_state)
                report["checkpointed"] = True
            except Exception:             # noqa: BLE001
                self.engine.metrics.on_refresh_failure(tag)
                report["checkpointed"] = False
        return report

    def _updated_state(self, tag: str, X: np.ndarray,
                       targets: np.ndarray) -> dict:
        """The tag's next-generation state dict, built on the LIVE one
        — per-family incremental update, frozen shapes throughout."""
        from repro.core.predictors import (  # deferred: keep DAG flat
            KNNLambdaPredictor,
            LinearLambdaPredictor,
            MeanLambdaPredictor,
            MLPLambdaPredictor,
        )

        template = self.engine.predictor_template(tag)
        state = self.engine.predictor_state_of(tag)
        if isinstance(template, KNNLambdaPredictor):
            cursor = self._knn_cursor.get(tag, 0)
            X_db, lam_db, cursor, written = knn_ring_update(
                state["X_db"], state["lam_db"], X, targets, cursor,
                return_written=True)
            self._knn_cursor[tag] = cursor
            if template.X_q is None:
                return {"X_db": X_db, "lam_db": lam_db}
            # quantized db: repack ONLY the slabs the ring write
            # touched — each touched slab gets a fresh scale computed
            # from its post-write rows (bitwise what a full repack
            # would produce), untouched slabs keep their buffers. The
            # swap therefore can never serve a scale that is stale
            # relative to its slab's rows.
            from repro.core.predictors import repack_knn_slabs
            slab = (state["X_q"].shape[0]
                    // max(state["q_scale"].shape[0], 1))
            X_q, q_scale, y2_q = repack_knn_slabs(
                X_db, state["X_q"], state["q_scale"], state["y2_q"],
                written, mode=template.quant, slab=slab)
            return {"X_db": X_db, "lam_db": lam_db, "X_q": X_q,
                    "q_scale": q_scale, "y2_q": y2_q}
        if isinstance(template, LinearLambdaPredictor):
            W, c = ridge_refresh(state["W"], state["c"], X, targets,
                                 mu=self.mu)
            return {"W": W, "c": c}
        if isinstance(template, MeanLambdaPredictor):
            weight = self._mean_weight.get(tag, self._default_mean_weight)
            mean, weight = running_mean_update(
                state["mean_lam"], weight, targets)
            self._mean_weight[tag] = weight
            return {"mean_lam": mean}
        if isinstance(template, MLPLambdaPredictor):
            refit = MLPLambdaPredictor.fit(
                X, targets, init_params=state["params"],
                num_steps=self.mlp_steps, lr=self.mlp_lr)
            return {"params": refit.params}
        raise TypeError(f"no refresh rule for "
                        f"{type(template).__name__}")

    def rollback(self, tag: str) -> int:
        """Re-publish the generation that was live before the most
        recent successful swap (a NEW epoch — the fence still applies;
        in-flight batches finish on whatever they were dispatched
        against). Raises KeyError if this lane never swapped `tag`."""
        prev = self._last_good.get(tag)
        if prev is None:
            raise KeyError(f"no pre-swap state recorded for {tag!r}")
        return self.engine.swap_predictor(tag, prev)

    # -- background thread ---------------------------------------------------

    def start(self, interval_s: float) -> None:
        """Run `refresh()` every `interval_s` on a daemon thread.
        Crash containment: an exception inside the loop (refresh() only
        raises on lane bugs, never on refused swaps) counts one refresh
        failure and the loop continues — serving is never taken down by
        its refresh lane."""
        if self._thread is not None:
            raise RuntimeError("refresh lane already started")
        self._stop_evt.clear()

        def loop():
            while not self._stop_evt.wait(interval_s):
                try:
                    self.refresh()
                except Exception:         # noqa: BLE001 — contain crashes
                    self.engine.metrics.on_refresh_failure("_lane")
        self._thread = threading.Thread(
            target=loop, name="refresh-lane", daemon=True)
        self._thread.start()

    def stop(self, *, final_refresh: bool = False) -> None:
        """Stop the background thread (idempotent). With
        `final_refresh`, drain the remaining telemetry through one last
        synchronous refresh AFTER the thread has fully exited.

        The lane thread is drained to completion — joined in a loop,
        never abandoned on a timeout. The old bounded join could give
        up while a background refresh pass was still in flight and run
        the final refresh concurrently with it: both passes would
        build on the same live state and double-publish one telemetry
        window (tests/test_refresh.py has the regression). Belt and
        braces, `refresh()` itself is also serialized on
        `_refresh_lock`, so even a pathological scheduler cannot
        interleave two passes."""
        self._stop_evt.set()
        thread, self._thread = self._thread, None
        if thread is not None:
            while thread.is_alive():
                thread.join(timeout=1.0)
        if final_refresh:
            self.refresh()
