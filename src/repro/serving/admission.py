"""Deadline-aware admission control for the serving engine.

The paper's whole premise is serving constrained rankings "within the
required 50 milliseconds" — but an engine without a notion of the
budget will happily queue past saturation and return every answer
late. Production LP-serving systems treat the latency budget as a
first-class admission signal; the online primal-dual view justifies
degrading to a cheaper predictor instead of dropping requests (the
served audit outputs are dual subgradients — compliance is recoverable
downstream), and shedding only when even the cheapest rung would miss.

The controller makes a three-way decision at `submit` time, before a
request ever enters a bucket queue:

  admit    rung 0 — the request's own predictor/bucket — is predicted
           to complete inside the deadline;
  degrade  rung 0 would miss, but a cheaper rung of the request's
           degradation ladder (e.g. KNN -> affine/mean: both already
           warmed, so no recompile-contract violation) is predicted to
           make it; the request is served from that rung's bucket and
           its compliance cost is accounted per rung;
  shed     every rung would miss: the request's RankFuture resolves
           immediately with a typed `Shed` result (engine.Shed) rather
           than queueing work that is already dead on arrival.

Prediction model (deliberately simple — EWMAs a property-based test
can reason about, not a learned latency model):

    predicted_ms(bucket, q, inflight) =
        lag_ewma                       # online saturation signal: the
                                       # open-loop driver's queueing-
                                       # lag profile (serving.traffic
                                       # separates it from pacing
                                       # clock-drift), fed back via
                                       # engine.observe_submission_lag
      + max_wait_ms                    # worst-case assembly wait (the
                                       # deadline-flush bound)
      + inflight * exec_ewma(bucket)   # pipeline window ahead of us
      + exec_ewma(bucket) * (1 + q/B)  # our own batch; a fuller queue
                                       # means a fuller (costlier)
                                       # flush and a busier engine

Every term is monotone non-decreasing in queue depth, in-flight count,
and observed lag — which yields the two invariants
tests/test_admission.py proves with hypothesis:

  * a request admitted at queue depth q is admitted at every depth
    < q (no admit/shed flapping as the queue drains);
  * the chosen degradation rung is monotone non-decreasing in the
    predicted lag (load only ever pushes DOWN the ladder, never back
    up mid-decision).

Service-time EWMAs are seeded by `ServingEngine.warmup` (one timed
post-compile execution per bucket) and updated online from each
retired micro-batch's launch->outputs-home time, so the controller
tracks the live service rate without ever blocking the hot path.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass

import numpy as np

__all__ = ["AdmissionController", "AdmissionDecision", "SHED_RUNG"]

# Rung index reported for a shed decision (no rung served).
SHED_RUNG = -1


@dataclass(frozen=True)
class AdmissionDecision:
    """Outcome of one submit-time admission check.

    action        'admit' | 'degrade' | 'shed'
    rung          ladder rung to serve from (0 = the request's own
                  bucket); SHED_RUNG (-1) when shedding
    predicted_ms  predicted completion latency of the chosen rung (for
                  'shed': of the cheapest rung — the best the engine
                  could have done)
    budget_ms     the deadline headroom the decision was made against
    """

    action: str
    rung: int
    predicted_ms: float
    budget_ms: float

    @property
    def admitted(self) -> bool:
        return self.action != "shed"


class AdmissionController:
    """Queue-depth- and EWMA-service-time-aware admission control.

    headroom      fraction of the budget a rung's prediction must fit
                  inside (0.85: leave 15% for unpadding/jitter that the
                  launch->home EWMA cannot see)
    ewma_alpha    smoothing of the per-bucket service-time EWMAs and
                  the submission-lag EWMA
    prior_exec_ms service-time prior for a bucket never yet observed
                  (warmup seeds real values; the prior only matters for
                  traffic hitting an unwarmed bucket)

    Measured-trend ladder (the windowed p99 tracker): per-request
    prediction is optimistic exactly when it matters — under load the
    EWMAs trail the true service rate, so requests keep being admitted
    on rung 0 while measured latency is already blowing budgets. The
    tracker accumulates each result's latency/budget RATIO
    (`observe_result`, fed by the engine at result-build time); every
    `p99_window` results it takes the window p99 and compares it to
    1.0 (= the budget):

      p99 over budget for `p99_patience` CONSECUTIVE windows
          → `default_rung` += 1: first-fit decisions start one rung
            further down the ladder (prediction has been lying — stop
            trusting rung 0);
      p99 under `p99_hysteresis` (strictly BELOW budget, not merely
          at it) for `p99_patience` consecutive windows
          → `default_rung` -= 1.

    The patience requirement plus the hysteresis band is the anti-flap
    design: a transient spike fills at most one window and resets
    nothing permanent, and a p99 hovering between hysteresis·budget
    and budget moves the rung in NEITHER direction.
    `rung_shifts` records every shift (for tests and dashboards).

    Thread-safety: `observe_service` runs on the completion worker,
    `observe_lag` on whichever thread drives the open-loop pacing,
    `observe_result` on whichever consumer thread builds results, and
    `predict_ms`/`decide` on the submission thread — all touch shared
    EWMAs, so updates take a small lock (reads of a stale EWMA are
    harmless; torn dict updates are not).
    """

    def __init__(self, *, headroom: float = 0.85, ewma_alpha: float = 0.25,
                 prior_exec_ms: float = 5.0, p99_window: int = 64,
                 p99_patience: int = 3, p99_hysteresis: float = 0.7,
                 max_default_rung: int = 8):
        if not 0.0 < headroom <= 1.0:
            raise ValueError(f"headroom must be in (0, 1], got {headroom}")
        if not 0.0 < ewma_alpha <= 1.0:
            raise ValueError(f"ewma_alpha must be in (0, 1], got {ewma_alpha}")
        if p99_window < 1:
            raise ValueError(f"p99_window must be >= 1, got {p99_window}")
        if p99_patience < 1:
            raise ValueError(f"p99_patience must be >= 1, got {p99_patience}")
        if not 0.0 < p99_hysteresis < 1.0:
            raise ValueError(f"p99_hysteresis must be in (0, 1), got "
                             f"{p99_hysteresis}")
        self.headroom = float(headroom)
        self.ewma_alpha = float(ewma_alpha)
        self.prior_exec_ms = float(prior_exec_ms)
        self.p99_window = int(p99_window)
        self.p99_patience = int(p99_patience)
        self.p99_hysteresis = float(p99_hysteresis)
        self.max_default_rung = int(max_default_rung)
        self.lag_ms = 0.0
        self.default_rung = 0
        self.rung_shifts: list[tuple[str, int, float]] = []
        self._exec_ms: dict[str, float] = {}
        self._ratio_win: list[float] = []
        self._over_windows = 0
        self._under_windows = 0
        self._lock = threading.Lock()
        # decision tallies (the engine's metrics carry the per-request
        # accounting; these are the controller's own view for debugging)
        self.decisions = {"admit": 0, "degrade": 0, "shed": 0}

    # -- observation --------------------------------------------------------

    def observe_service(self, bucket_name: str, exec_ms: float) -> None:
        """One micro-batch of `bucket_name` took `exec_ms` from launch
        to outputs-home. First observation seeds the EWMA directly."""
        exec_ms = max(0.0, float(exec_ms))
        with self._lock:
            prev = self._exec_ms.get(bucket_name)
            if prev is None:
                self._exec_ms[bucket_name] = exec_ms
            else:
                a = self.ewma_alpha
                self._exec_ms[bucket_name] = (1.0 - a) * prev + a * exec_ms

    def observe_lag(self, lag_ms: float) -> None:
        """Feed the open-loop driver's QUEUEING lag (not pacing
        clock-drift — serving.traffic.serve_open_loop separates the
        two) as the online saturation signal."""
        lag_ms = max(0.0, float(lag_ms))
        with self._lock:
            a = self.ewma_alpha
            self.lag_ms = (1.0 - a) * self.lag_ms + a * lag_ms

    def observe_result(self, latency_ms: float, budget_ms: float) -> None:
        """One served result's MEASURED latency against its own budget
        — the windowed p99 tracker's feed (see class doc). Called by
        the engine at result-build time; requests without a positive
        budget are skipped (nothing to compare against)."""
        if budget_ms <= 0.0:
            return
        with self._lock:
            self._ratio_win.append(float(latency_ms) / float(budget_ms))
            if len(self._ratio_win) < self.p99_window:
                return
            r99 = float(np.percentile(self._ratio_win, 99))
            self._ratio_win = []
            if r99 > 1.0:
                self._over_windows += 1
                self._under_windows = 0
                if (self._over_windows >= self.p99_patience
                        and self.default_rung < self.max_default_rung):
                    self.default_rung += 1
                    self._over_windows = 0
                    self.rung_shifts.append(("down", self.default_rung, r99))
            elif r99 < self.p99_hysteresis:
                self._under_windows += 1
                self._over_windows = 0
                if (self._under_windows >= self.p99_patience
                        and self.default_rung > 0):
                    self.default_rung -= 1
                    self._under_windows = 0
                    self.rung_shifts.append(("up", self.default_rung, r99))
            else:
                # the hysteresis band: neither trend accumulates.
                self._over_windows = 0
                self._under_windows = 0

    def service_ms(self, bucket_name: str) -> float:
        with self._lock:
            return self._exec_ms.get(bucket_name, self.prior_exec_ms)

    # -- prediction + decision ----------------------------------------------

    def predict_ms(self, bucket_name: str, *, queue_len: int, batch_cap: int,
                   inflight: int, max_wait_ms: float) -> float:
        """Predicted completion latency (ms) for a request joining
        `bucket_name`'s queue now. Monotone non-decreasing in
        queue_len, inflight, and the observed lag EWMA — the admission
        invariants depend on exactly this."""
        exec_ms = self.service_ms(bucket_name)
        fill = queue_len / max(1, batch_cap)
        return (self.lag_ms
                + float(max_wait_ms)
                + max(0, inflight) * exec_ms
                + exec_ms * (1.0 + fill))

    def decide(self, *, budget_ms: float,
               rung_predictions) -> AdmissionDecision:
        """Pick the FIRST (highest-quality) rung whose prediction fits
        inside headroom * budget; shed when none does.

        rung_predictions: [(rung_index, predicted_ms)] ordered rung 0
        first. First-fit makes the chosen rung monotone non-decreasing
        in any uniform lag shift: a rung that fits under more lag also
        fit under less.

        The measured-trend floor: rungs above `default_rung` (shifted
        by the windowed p99 tracker) are skipped — when trailing
        MEASURED p99 has been blowing budgets, per-request prediction
        has lost the benefit of the doubt. A ladder too short to reach
        the floor keeps its deepest rung eligible (the floor degrades,
        it never turns into a shed).
        """
        rung_predictions = list(rung_predictions)
        if not rung_predictions:
            raise ValueError("decide() needs at least rung 0")
        with self._lock:
            floor = self.default_rung
        eligible = [(r, p) for r, p in rung_predictions if r >= floor]
        if not eligible:
            eligible = [rung_predictions[-1]]
        limit = self.headroom * float(budget_ms)
        for rung, predicted in eligible:
            if predicted <= limit:
                action = "admit" if rung == 0 else "degrade"
                self.decisions[action] += 1
                return AdmissionDecision(action, rung, float(predicted),
                                         float(budget_ms))
        self.decisions["shed"] += 1
        cheapest = min(p for _, p in eligible)
        return AdmissionDecision("shed", SHED_RUNG, float(cheapest),
                                 float(budget_ms))
