"""Serving-engine observability: latency percentiles, compliance,
bucket hit / compile counters.

The compile counters are the contract the engine is built around: after
`warmup()`, `compiles_post_warmup` must stay 0 across any request stream
whose geometries fall inside the warmed bucket lattice (asserted in
tests/test_serving.py via these counters AND the underlying jit cache
sizes).
"""

from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field

import numpy as np


@dataclass
class EngineMetrics:
    requests: int = 0
    results: int = 0
    batches: int = 0
    # shape-lattice behaviour
    bucket_hits: dict = field(default_factory=lambda: defaultdict(int))
    compiles: int = 0                 # executables built, ever
    compiles_post_warmup: int = 0     # executables built after warmup()
    warmed: bool = False
    oversize_requests: int = 0        # fell outside the warmed lattice
    # flush triggers
    capacity_flushes: int = 0
    deadline_flushes: int = 0
    drain_flushes: int = 0
    # padding overhead
    real_cells: int = 0
    padded_cells: int = 0
    # quality / latency
    compliant_sum: float = 0.0
    latencies_ms: list = field(default_factory=list)
    queue_wait_ms: list = field(default_factory=list)
    exec_ms: list = field(default_factory=list)

    # -- recording ----------------------------------------------------------

    def on_submit(self, bucket, known: bool) -> None:
        self.requests += 1
        self.bucket_hits[bucket.name] += 1
        if self.warmed and not known:
            self.oversize_requests += 1

    def on_compile(self) -> None:
        self.compiles += 1
        if self.warmed:
            self.compiles_post_warmup += 1

    def on_batch(self, bucket, n_real: int, exec_ms: float, trigger: str,
                 fill: dict) -> None:
        self.batches += 1
        self.exec_ms.append(exec_ms)
        if trigger == "capacity":
            self.capacity_flushes += 1
        elif trigger == "deadline":
            self.deadline_flushes += 1
        else:
            self.drain_flushes += 1
        self.real_cells += fill["real_cells"]
        self.padded_cells += fill["padded_cells"]

    def on_result(self, latency_ms: float, wait_ms: float,
                  compliant: bool) -> None:
        self.results += 1
        self.latencies_ms.append(latency_ms)
        self.queue_wait_ms.append(wait_ms)
        self.compliant_sum += float(compliant)

    # -- reporting ----------------------------------------------------------

    @staticmethod
    def _pct(xs, qs=(50, 95, 99)):
        if not xs:
            return {f"p{q}": float("nan") for q in qs}
        arr = np.asarray(xs)
        return {f"p{q}": round(float(np.percentile(arr, q)), 3) for q in qs}

    def summary(self) -> dict:
        lat = self._pct(self.latencies_ms)
        return {
            "requests": self.requests,
            "results": self.results,
            "batches": self.batches,
            "buckets_used": len(self.bucket_hits),
            "compiles": self.compiles,
            "compiles_post_warmup": self.compiles_post_warmup,
            "oversize_requests": self.oversize_requests,
            "flushes": {"capacity": self.capacity_flushes,
                        "deadline": self.deadline_flushes,
                        "drain": self.drain_flushes},
            "fill_rate": round(self.real_cells / self.padded_cells, 3)
                         if self.padded_cells else float("nan"),
            "latency_ms": lat,
            "queue_wait_ms": self._pct(self.queue_wait_ms),
            "exec_ms_per_batch": self._pct(self.exec_ms),
            "compliance": round(self.compliant_sum / self.results, 3)
                          if self.results else float("nan"),
        }
