"""Serving-engine observability: latency percentiles, compliance,
bucket hit / compile counters, and pipeline stage timelines.

The compile counters are the contract the engine is built around: after
`warmup()`, `compiles_post_warmup` must stay 0 across any request stream
whose geometries fall inside the warmed bucket lattice (asserted in
tests/test_serving.py via these counters AND the underlying jit cache
sizes).

With the async pipeline, recording is split the same way the engine is:
`on_dispatch` fires on the submission thread when a micro-batch is
assembled and launched; `on_retire` / `on_result` fire on the
completion side when its outputs materialize. Everything here is
either a scalar add or a list append under the GIL, so the two sides
can record concurrently without a lock.
"""

from __future__ import annotations

import threading
from collections import defaultdict
from dataclasses import dataclass, field

import numpy as np


@dataclass
class EngineMetrics:
    requests: int = 0
    results: int = 0
    batches: int = 0
    # accounting for the single-dispatch contract: bucket-executable
    # invocations outside warmup, i.e. device programs the ENGINE
    # launches. One per flushed micro-batch, covariate streams included
    # — λ̂ prediction happens inside the bucket executable
    # (kernels.ops.predict_rank_audited), never as a second program.
    # NOTE this counter is incremented at the engine's one dispatch
    # site, so it reports the contract rather than proving it; the
    # proving assertions live in tests/test_serving.py (per-bucket jit
    # cache sizes stay 1, and a predictor's Python predict() is never
    # re-entered after warmup — a second per-batch program would
    # re-enter it or retrace).
    executable_calls: int = 0
    # Pallas kernel launches those executables contained (statically
    # known per bucket route: 1 for every fused-executor kernel bucket
    # — the single-grid KNN program included, which is the point: the
    # pre-fusion KNN chain charged 2 — and 0 for XLA-bodied buckets).
    kernel_launches: int = 0
    # shape-lattice behaviour
    bucket_hits: dict = field(default_factory=lambda: defaultdict(int))
    compiles: int = 0                 # executables built, ever
    compiles_post_warmup: int = 0     # executables built after warmup()
    warmed: bool = False
    oversize_requests: int = 0        # fell outside the warmed lattice
    # flush triggers
    capacity_flushes: int = 0
    deadline_flushes: int = 0
    drain_flushes: int = 0
    # padding overhead: cells are batch x m1 rows (host-transfer view),
    # flops are the rank+audit sweep work (m1*m2 + K*m1 per request vs
    # the bucket corner's) — padding_waste_ratio in summary() is the
    # padded/real quotient of each.
    real_cells: int = 0
    padded_cells: int = 0
    real_flops: int = 0
    padded_flops: int = 0
    # pipeline stage timelines (per micro-batch, ms)
    assembly_ms: list = field(default_factory=list)   # host packing
    dispatch_ms: list = field(default_factory=list)   # jit call -> futures
    exec_ms: list = field(default_factory=list)       # launch -> outputs home
    queue_depth: list = field(default_factory=list)   # in-flight at dispatch
    # serving window (for the overlap ratio): first dispatch, last retire
    t_first_dispatch: float | None = None
    t_last_retire: float | None = None
    # quality / latency
    compliant_sum: float = 0.0
    latencies_ms: list = field(default_factory=list)
    queue_wait_ms: list = field(default_factory=list)
    # deadline accounting: every result is checked against its
    # request's (absolute) deadline at build time; sheds/degrades are
    # the admission controller's submit-time decisions. rung_stats
    # accumulates, per degradation rung actually served, the
    # compliance cost of serving from that rung — the audit outputs
    # (exposure/compliance) come free out of the fused kernel, so the
    # per-rung exposure shortfall sum(max(b - exposure, 0)) costs one
    # tiny numpy op per result.
    deadline_hits: int = 0
    deadline_misses: int = 0
    sheds: int = 0
    degrades: int = 0
    # refresh lane / hot swap accounting: swaps = predictor generations
    # published + flipped (engine.swap_predictor successes);
    # refresh_failures = refresh attempts that produced state the
    # engine refused (poisoned / wrong structure) or that crashed in
    # the lane — serving stayed on last-good each time;
    # states_retired = superseded generations whose device buffers were
    # released after their last in-flight batch materialized.
    swaps: int = 0
    refresh_failures: int = 0
    states_retired: int = 0
    swaps_by_tag: dict = field(default_factory=lambda: defaultdict(int))
    # adaptive-lattice lane accounting: lattice_swaps = lattice
    # generations flipped live (engine.swap_lattice successes, == the
    # live lattice epoch); lattice_rollbacks = re-warm attempts that
    # failed (compile/validation error, crash in the lane) — serving
    # stayed on the last-good lattice each time; shadow_compiles =
    # executables built OFF the dispatch path by shadow_warm_lattice
    # (cache growth is legal only here and in warmup — the refined
    # no-recompile contract keeps compiles_post_warmup a pure
    # dispatch-path counter); shadow_warm_ms = wall time of each
    # shadow-warm window.
    lattice_swaps: int = 0
    lattice_rollbacks: int = 0
    shadow_compiles: int = 0
    shadow_warm_ms: list = field(default_factory=list)
    rung_stats: dict = field(default_factory=lambda: defaultdict(
        lambda: {"served": 0, "compliant": 0.0, "shortfall": 0.0}))
    # per-surface budget classes (RankRequest.surface): every deadline
    # outcome — hit/miss at build time, shed/degrade at submit time —
    # is also attributed to the request's surface, so a feed-vs-search
    # SLA split is readable straight off deadline_summary()['surfaces'].
    surface_stats: dict = field(default_factory=lambda: defaultdict(
        lambda: {"hits": 0, "misses": 0, "sheds": 0, "degrades": 0}))
    # on_result runs on whichever consumer thread builds a result
    # (future.result() is a public API), so unlike the submission/
    # completion pair its read-modify-writes need a real lock.
    _result_lock: threading.Lock = field(default_factory=threading.Lock,
                                         repr=False)

    # -- recording ----------------------------------------------------------

    def on_submit(self, bucket, known: bool) -> None:
        self.requests += 1
        self.bucket_hits[bucket.name] += 1
        if self.warmed and not known:
            self.oversize_requests += 1

    def on_compile(self, in_warmup: bool = False) -> None:
        """A bucket executable was built. Compiles inside `warmup` —
        including a later re-warm extending the lattice — never count
        against the post-warmup contract; only compiles forced by
        traffic (an unwarmed bucket hit by a live request) do."""
        self.compiles += 1
        if self.warmed and not in_warmup:
            self.compiles_post_warmup += 1

    def on_executable_call(self, kernel_launches: int = 0) -> None:
        """Submission side: one bucket executable was invoked (the
        whole predict+rank+audit program for its micro-batch).
        ``kernel_launches`` is how many Pallas kernel launches that
        executable contains (kernels.ops.kernel_launch_count for the
        bucket's route)."""
        self.executable_calls += 1
        self.kernel_launches += kernel_launches

    def on_dispatch(self, bucket, n_real: int, trigger: str, fill: dict,
                    *, assembly_ms: float, dispatch_ms: float,
                    depth: int, t_now: float) -> None:
        """Submission side: a micro-batch was assembled and launched."""
        self.batches += 1
        self.assembly_ms.append(assembly_ms)
        self.dispatch_ms.append(dispatch_ms)
        self.queue_depth.append(depth)
        if trigger == "capacity":
            self.capacity_flushes += 1
        elif trigger == "deadline":
            self.deadline_flushes += 1
        else:
            self.drain_flushes += 1
        self.real_cells += fill["real_cells"]
        self.padded_cells += fill["padded_cells"]
        self.real_flops += fill.get("real_flops", 0)
        self.padded_flops += fill.get("padded_flops", 0)
        if self.t_first_dispatch is None:
            self.t_first_dispatch = t_now

    def on_retire(self, exec_ms: float, t_now: float) -> None:
        """Completion side: a micro-batch's outputs reached the host."""
        self.exec_ms.append(exec_ms)
        self.t_last_retire = t_now

    def on_result(self, latency_ms: float, wait_ms: float,
                  compliant: bool, *, deadline_hit: bool | None = None,
                  rung: int = 0, shortfall: float = 0.0,
                  surface: str = "default") -> None:
        with self._result_lock:
            self.results += 1
            self.latencies_ms.append(latency_ms)
            self.queue_wait_ms.append(wait_ms)
            self.compliant_sum += float(compliant)
            if deadline_hit is not None:
                if deadline_hit:
                    self.deadline_hits += 1
                    self.surface_stats[surface]["hits"] += 1
                else:
                    self.deadline_misses += 1
                    self.surface_stats[surface]["misses"] += 1
            rs = self.rung_stats[int(rung)]
            rs["served"] += 1
            rs["compliant"] += float(compliant)
            rs["shortfall"] += float(shortfall)

    def on_shed(self, bucket, *, surface: str = "default") -> None:
        """Submission side: a request was shed at admission (its
        RankFuture resolved with a typed Shed result — it never
        entered a queue, so it appears in no other counter)."""
        with self._result_lock:
            self.sheds += 1
            self.surface_stats[surface]["sheds"] += 1

    def on_degrade(self, rung: int, *, surface: str = "default") -> None:
        """Submission side: a request was admitted on a cheaper
        degradation-ladder rung instead of its own bucket."""
        with self._result_lock:
            self.degrades += 1
            self.surface_stats[surface]["degrades"] += 1

    def on_swap(self, tag: str) -> None:
        """Refresh lane: a new predictor generation was published and
        flipped live (engine.swap_predictor succeeded)."""
        with self._result_lock:
            self.swaps += 1
            self.swaps_by_tag[tag] += 1

    def on_refresh_failure(self, tag: str) -> None:
        """Refresh lane: a refresh attempt failed (state the engine
        refused, or a crash inside the lane) — serving kept the
        last-good generation."""
        with self._result_lock:
            self.refresh_failures += 1

    def on_state_retired(self, tag: str) -> None:
        """A superseded predictor generation's buffers were released
        (its last in-flight batch materialized)."""
        with self._result_lock:
            self.states_retired += 1

    def on_shadow_compile(self) -> None:
        """Lattice lane: one executable was built OFF the dispatch path
        inside a shadow-warm window (legal cache growth under the
        refined contract — never counted in compiles_post_warmup)."""
        with self._result_lock:
            self.shadow_compiles += 1

    def on_lattice_swap(self, epoch: int, *, warm_ms: float = 0.0) -> None:
        """Lattice lane: a new bucket lattice was shadow-warmed and
        flipped live (engine.swap_lattice succeeded)."""
        with self._result_lock:
            self.lattice_swaps += 1
            if warm_ms:
                self.shadow_warm_ms.append(float(warm_ms))

    def on_lattice_rollback(self) -> None:
        """Lattice lane: a re-warm attempt failed (compile/validation
        error or a crash in the lane) — serving kept the last-good
        lattice and its warmed executables."""
        with self._result_lock:
            self.lattice_rollbacks += 1

    # -- reporting ----------------------------------------------------------

    @staticmethod
    def _pct(xs, qs=(50, 95, 99)):
        if not xs:
            return {f"p{q}": float("nan") for q in qs}
        arr = np.asarray(xs)
        return {f"p{q}": round(float(np.percentile(arr, q)), 3) for q in qs}

    def overlap_ratio(self) -> float:
        """How much pipelining compressed the serving window.

        serial = what the stages would cost laid end to end
        (Σ assembly + Σ dispatch + Σ execute/transfer); wall = first
        dispatch → last retire. 0 means fully serialized (the sync
        engine), values toward 1 mean host assembly ran almost entirely
        under device execution. Only meaningful for back-to-back
        streams — arrival gaps inflate the wall and deflate the ratio.
        """
        if self.t_first_dispatch is None or self.t_last_retire is None:
            return 0.0
        serial = (sum(self.assembly_ms) + sum(self.dispatch_ms)
                  + sum(self.exec_ms))
        wall = (self.t_last_retire - self.t_first_dispatch) * 1e3
        if serial <= 0.0 or wall <= 0.0:
            return 0.0
        return max(0.0, min(1.0, 1.0 - wall / serial))

    def summary(self) -> dict:
        lat = self._pct(self.latencies_ms)
        return {
            "requests": self.requests,
            "results": self.results,
            "batches": self.batches,
            "executable_calls": self.executable_calls,
            "dispatches_per_batch": round(
                self.executable_calls / self.batches, 3)
                if self.batches else float("nan"),
            "kernel_launches": self.kernel_launches,
            "kernel_launches_per_batch": round(
                self.kernel_launches / self.batches, 3)
                if self.batches else float("nan"),
            "buckets_used": len(self.bucket_hits),
            "compiles": self.compiles,
            "compiles_post_warmup": self.compiles_post_warmup,
            "oversize_requests": self.oversize_requests,
            "flushes": {"capacity": self.capacity_flushes,
                        "deadline": self.deadline_flushes,
                        "drain": self.drain_flushes},
            "fill_rate": round(self.real_cells / self.padded_cells, 3)
                         if self.padded_cells else float("nan"),
            "padding": self.padding_summary(),
            "latency_ms": lat,
            "queue_wait_ms": self._pct(self.queue_wait_ms),
            "pipeline": {
                "assembly_ms_per_batch": self._pct(self.assembly_ms),
                "dispatch_ms_per_batch": self._pct(self.dispatch_ms),
                "exec_ms_per_batch": self._pct(self.exec_ms),
                "queue_depth_max": max(self.queue_depth, default=0),
                "queue_depth_mean": round(float(np.mean(self.queue_depth)), 3)
                                    if self.queue_depth else 0.0,
                "overlap_ratio": round(self.overlap_ratio(), 3),
            },
            "compliance": round(self.compliant_sum / self.results, 3)
                          if self.results else float("nan"),
            "deadline": self.deadline_summary(),
            "refresh": self.refresh_summary(),
            "lattice": self.lattice_summary(),
        }

    def padding_summary(self) -> dict:
        """Padded/real work ratios (>= 1.0; lower is better): rows is
        the batch x m1 host-transfer view, flops the rank+audit sweep
        view — the number the adaptive lattice exists to shrink."""
        return {
            "waste_rows": round(self.padded_cells / self.real_cells, 4)
                          if self.real_cells else float("nan"),
            "waste_flops": round(self.padded_flops / self.real_flops, 4)
                           if self.real_flops else float("nan"),
            "real_flops": self.real_flops,
            "padded_flops": self.padded_flops,
        }

    def lattice_summary(self) -> dict:
        """Adaptive-lattice lane view: generations flipped (== live
        epoch), failed re-warms (serving stayed last-good), off-path
        shadow compiles, and shadow-warm window wall times."""
        return {
            "lattice_swaps": self.lattice_swaps,
            "lattice_rollbacks": self.lattice_rollbacks,
            "shadow_compiles": self.shadow_compiles,
            "shadow_warm_ms": self._pct(self.shadow_warm_ms),
        }

    def refresh_summary(self) -> dict:
        """Hot-swap view: generations published, refreshes refused or
        crashed (serving stayed last-good), superseded generations
        whose buffers were released."""
        return {
            "swaps": self.swaps,
            "swaps_by_tag": dict(self.swaps_by_tag),
            "refresh_failures": self.refresh_failures,
            "states_retired": self.states_retired,
        }

    def deadline_summary(self) -> dict:
        """Deadline/admission view: hit rate over SERVED requests
        (sheds are the admission controller doing its job, not
        misses), shed/degrade decision counts, and the per-rung
        compliance-cost accumulator."""
        tracked = self.deadline_hits + self.deadline_misses
        return {
            "hits": self.deadline_hits,
            "misses": self.deadline_misses,
            "hit_rate": round(self.deadline_hits / tracked, 4)
                        if tracked else float("nan"),
            "sheds": self.sheds,
            "degrades": self.degrades,
            "surfaces": {
                surface: {
                    **ss,
                    "hit_rate": round(
                        ss["hits"] / (ss["hits"] + ss["misses"]), 4)
                        if ss["hits"] + ss["misses"] else float("nan"),
                }
                for surface, ss in sorted(self.surface_stats.items())
            },
            "rungs": {
                str(rung): {
                    "served": rs["served"],
                    "compliance": round(rs["compliant"] / rs["served"], 3)
                                  if rs["served"] else float("nan"),
                    "mean_shortfall": round(rs["shortfall"] / rs["served"], 4)
                                      if rs["served"] else float("nan"),
                }
                for rung, rs in sorted(self.rung_stats.items())
            },
        }
