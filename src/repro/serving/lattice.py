"""Adaptive bucket lattice: traffic-learned shapes, padded-work
accounting, and trough-time shadow re-warm.

The static power-of-two lattice (repro.serving.buckets) buys the
no-recompile contract by rounding every request up to the next
(m1, m2, K) corner — and on skewed production-like traffic that
rounding is expensive: a surface serving m1~520 candidates pads to
1024, so roughly half the rank-sweep FLOPs and db-sweep bytes of every
launch are phantom work. This module closes the loop:

  ShapeHistogram   exact per-(tag, surface, m1, m2, K, d_cov) counts
                   with decayed EWMA weights, fed by the engine at
                   enqueue (a dict update per request — no device
                   reads). Serialized as JSON beside the autotune
                   table, so a restarted engine can re-learn from the
                   fleet's accumulated traffic instead of cold counts.

  optimize_lattice a greedy corner chooser over the histogram: start
                   from the power-of-two grouping, SHRINK each group's
                   corner to the aligned cover of the shapes it
                   actually serves (never worse than power-of-two),
                   then merge groups while over the executable budget
                   and split the wasteful ones along histogram
                   quantiles while under it. The objective is expected
                   padded work per request — rank rows*m2 + audit
                   K*m1 cells plus the amortized db-sweep bytes, the
                   same analytic accounting style as
                   benchmarks/kernel_bench's traffic models. Invariants
                   (property-tested in tests/test_lattice.py): every
                   observed shape is covered, the corner count never
                   exceeds the budget, and expected padded work never
                   exceeds the power-of-two lattice's whenever that
                   lattice itself fits the budget.

  TroughDetector   arrival-rate EWMA + the admission lane's
                   submission-lag EWMA; a trough is both signals quiet
                   for a patience window. Re-warming compiles — doing
                   it mid-rush would steal host cycles from assembly,
                   so the lane waits for a trough.

  LatticeLane      the background re-warm lane (RefreshLane's sibling):
                   propose an optimized lattice from the live
                   histogram, have the engine compile its executables
                   OFF the dispatch path (engine.shadow_warm_lattice),
                   then atomically flip lattice + warmed cache under
                   the flush lock exactly like `swap_predictor`
                   (engine.swap_lattice: epoch-fenced, monotone,
                   `RankResult.lattice_epoch` stamps every served
                   row). Any compile or validation failure rolls back
                   to last-good: nothing was flipped, serving never
                   paused, and the failure is a counter
                   (metrics.lattice_rollbacks), not an outage.

The refined no-recompile contract: ZERO compiles on the dispatch path.
`compiles_post_warmup` still must stay 0 across any stream inside the
warmed lattice; compile-cache growth is legal only inside warmup and
shadow-warm windows (counted separately as `metrics.shadow_compiles`).

See docs/serving.md §Lattice for lifecycle diagrams and the metrics
glossary.
"""

from __future__ import annotations

import json
import math
import os
import threading
import time
from dataclasses import dataclass, field

from repro.serving.buckets import (
    MIN_M1,
    MIN_M2,
    Bucket,
    bucket_for,
)

__all__ = [
    "DEFAULT_HISTOGRAM_PATH",
    "LatticeLane",
    "Lattice",
    "ShapeHistogram",
    "TroughDetector",
    "expected_padded_work",
    "optimize_lattice",
    "padded_work",
    "padding_waste",
]

# Serialized beside the autotune table (same experiments/bench/ home):
# the two files together are the engine's learned serving profile.
DEFAULT_HISTOGRAM_PATH = "experiments/bench/shape_histogram.json"

# Adaptive-corner alignment: m1 to 64 lanes (finer than the power-of-two
# ceiling, still vector-register friendly), m2 to the sublane floor,
# K to quads. Floors match the static lattice so an adaptive corner is
# never smaller than the smallest shape the kernels were sized for.
ALIGN_M1, ALIGN_M2, ALIGN_K = 64, 8, 4
FLOOR_K = 4


def _align_up(n: int, align: int, floor: int) -> int:
    n = max(int(n), int(floor))
    return ((n + align - 1) // align) * align


# ---------------------------------------------------------------------------
# Padded-work model (kernel_bench traffic-model accounting style)
# ---------------------------------------------------------------------------

def padded_work(m1: int, m2: int, K: int, *, d_cov: int = 0,
                n_db: int = 0, batch: int = 1) -> float:
    """Analytic work of serving ONE request at geometry (m1, m2, K):
    the rank sweep touches m1*m2 score cells, the fused audit reads
    K*m1 attribute cells, and a KNN-backed bucket amortizes its
    db-sweep bytes (n_db rows x d_cov f32) over the micro-batch. Same
    accounting style as benchmarks/kernel_bench's traffic models —
    relative, not absolute: the optimizer only ever compares corners.
    """
    work = float(m1) * float(m2) + float(K) * float(m1)
    if n_db and d_cov and batch:
        work += (float(n_db) * float(d_cov) * 4.0) / float(batch)
    return work


# ---------------------------------------------------------------------------
# The lattice
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Lattice:
    """A set of (m1, m2, K) bucket corners, or the power-of-two default.

    `corners=None` is the static power-of-two lattice (exactly
    buckets.bucket_for — generation 0 of every engine). An adaptive
    lattice routes each request to its cheapest covering corner and
    FALLS BACK to the power-of-two ceiling for shapes outside every
    corner, so routing is total: an unforeseen shape degrades to the
    old behavior (and the old warmed executables) instead of failing.
    """

    corners: tuple | None = None      # ((m1, m2, K), ...) or None = pow2
    epoch: int = 0                    # informational label (engine owns
                                      # the authoritative epoch counter)

    @property
    def adaptive(self) -> bool:
        return self.corners is not None

    def validate(self) -> None:
        """Structural check (the swap's phase-1 gate): every corner is
        a well-posed ranking geometry. Raises ValueError otherwise."""
        if self.corners is None:
            return
        if not self.corners:
            raise ValueError("adaptive lattice with zero corners")
        for c in self.corners:
            if len(c) != 3:
                raise ValueError(f"corner {c!r}: need (m1, m2, K)")
            m1, m2, K = (int(x) for x in c)
            if m1 <= 0 or m2 <= 0 or K <= 0:
                raise ValueError(f"corner {c!r}: non-positive dimension")
            if m2 > m1:
                raise ValueError(f"corner {c!r}: m2 > m1 is not a "
                                 f"well-posed ranking problem")

    def covering_corner(self, m1: int, m2: int, K: int):
        """The cheapest corner covering (m1, m2, K), or None."""
        if not self.corners:
            return None
        best, best_cost = None, math.inf
        for c in self.corners:
            c1, c2, c3 = c
            if c1 >= m1 and c2 >= m2 and c3 >= K:
                cost = padded_work(c1, c2, c3)
                if cost < best_cost:
                    best, best_cost = c, cost
        return best

    def bucket_for(self, *, m1: int, m2: int, K: int, tag: str,
                   batch: int) -> Bucket:
        """Route a request geometry: cheapest covering corner, else the
        power-of-two fallback (identical to the static lattice)."""
        if m2 > m1:
            raise ValueError(f"request needs m2 <= m1, got m2={m2} > "
                             f"m1={m1}")
        c = self.covering_corner(m1, m2, K)
        if c is None:
            return bucket_for(m1=m1, m2=m2, K=K, tag=tag, batch=batch)
        return Bucket(tag=tag, m1=int(c[0]), m2=int(c[1]), K=int(c[2]),
                      batch=int(batch))


# ---------------------------------------------------------------------------
# Shape-histogram telemetry
# ---------------------------------------------------------------------------

class ShapeHistogram:
    """Exact per-(tag, surface, m1, m2, K, d_cov) arrival counts with a
    decayed EWMA weight per cell.

    The EWMA clock is the OBSERVATION counter, not wall time: each
    arrival discounts every cell's weight by `decay` per observation
    elapsed since that cell was last touched (applied lazily, so
    observe stays O(1)). Deterministic — replaying a stream reproduces
    the histogram bit-for-bit, which is what makes the lattice swap
    tests and the CI gate replayable.
    """

    def __init__(self, *, decay: float = 0.999):
        if not (0.0 < decay <= 1.0):
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        self.decay = float(decay)
        self._cells: dict[tuple, dict] = {}
        self._t = 0

    def observe(self, *, tag: str, m1: int, m2: int, K: int,
                d_cov: int | None = None, surface: str = "default",
                weight: float = 1.0) -> None:
        self._t += 1
        key = (str(tag), str(surface), int(m1), int(m2), int(K),
               -1 if d_cov is None else int(d_cov))
        cell = self._cells.get(key)
        if cell is None:
            cell = self._cells[key] = {"count": 0, "ewma": 0.0,
                                       "t": self._t}
        cell["ewma"] = (cell["ewma"] * self.decay ** (self._t - cell["t"])
                        + float(weight))
        cell["t"] = self._t
        cell["count"] += 1

    @property
    def total(self) -> int:
        """Total observations ever (the EWMA clock)."""
        return self._t

    def __len__(self) -> int:
        return len(self._cells)

    def shapes(self, *, min_weight: float = 0.0) -> list:
        """[(tag, surface, m1, m2, K, d_cov, weight)] with every cell's
        EWMA decayed to now; d_cov is None for raw-lam cells."""
        out = []
        for key, cell in list(self._cells.items()):
            w = cell["ewma"] * self.decay ** (self._t - cell["t"])
            if w < min_weight:
                continue
            tag, surface, m1, m2, K, d = key
            out.append((tag, surface, m1, m2, K,
                        None if d < 0 else d, w))
        out.sort(key=lambda s: (s[0], s[1], s[2], s[3], s[4]))
        return out

    def geometry_weights(self) -> dict:
        """{(m1, m2, K): weight} aggregated over tags and surfaces —
        the optimizer's view (corners are tag-independent, exactly like
        the autotune table's geometry keys)."""
        agg: dict[tuple, float] = {}
        for _, _, m1, m2, K, _, w in self.shapes():
            agg[(m1, m2, K)] = agg.get((m1, m2, K), 0.0) + w
        return agg

    # -- serialization (beside the autotune table) --------------------------

    def snapshot(self) -> dict:
        return {
            "version": 1,
            "decay": self.decay,
            "t": self._t,
            "cells": [
                {"tag": k[0], "surface": k[1], "m1": k[2], "m2": k[3],
                 "K": k[4], "d_cov": k[5], **c}
                for k, c in sorted(self._cells.items())
            ],
        }

    def save(self, path: str = DEFAULT_HISTOGRAM_PATH) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1, sort_keys=True)
        return path

    @classmethod
    def load(cls, path: str = DEFAULT_HISTOGRAM_PATH) -> "ShapeHistogram":
        """Load a saved histogram; empty when the file is absent."""
        hist = cls()
        if not os.path.exists(path):
            return hist
        with open(path) as f:
            payload = json.load(f)
        hist.decay = float(payload.get("decay", hist.decay))
        hist._t = int(payload.get("t", 0))
        for c in payload.get("cells", ()):
            key = (str(c["tag"]), str(c["surface"]), int(c["m1"]),
                   int(c["m2"]), int(c["K"]), int(c["d_cov"]))
            hist._cells[key] = {"count": int(c["count"]),
                                "ewma": float(c["ewma"]),
                                "t": int(c["t"])}
        return hist


# ---------------------------------------------------------------------------
# The lattice optimizer
# ---------------------------------------------------------------------------

def _cover(shapes) -> tuple:
    """The aligned componentwise-max corner of a shape group."""
    m1 = _align_up(max(s[0] for s in shapes), ALIGN_M1, MIN_M1)
    m2 = _align_up(max(s[1] for s in shapes), ALIGN_M2, MIN_M2)
    K = _align_up(max(s[2] for s in shapes), ALIGN_K, FLOOR_K)
    return (m1, min(m2, m1), K)


def expected_padded_work(lattice: Lattice, weights: dict, *,
                         batch: int = 1, d_cov: int = 0,
                         n_db: int = 0) -> float:
    """Expected per-request padded work of serving `weights`
    ({(m1, m2, K): weight}) on `lattice` — the optimizer's objective
    and the padding-waste accountant's numerator."""
    total = 0.0
    for (m1, m2, K), w in weights.items():
        bk = lattice.bucket_for(m1=m1, m2=m2, K=K, tag="_", batch=batch)
        total += w * padded_work(bk.m1, bk.m2, bk.K, d_cov=d_cov,
                                 n_db=n_db, batch=batch)
    return total


def padding_waste(lattice: Lattice, weights: dict, *,
                  batch: int = 1) -> float:
    """padded/real work ratio (>= 1.0) of serving `weights` on
    `lattice` — the padding_waste_ratio the metrics report, computed
    analytically from the histogram instead of from live counters."""
    real = sum(w * padded_work(m1, m2, K)
               for (m1, m2, K), w in weights.items())
    if real <= 0.0:
        return float("nan")
    return expected_padded_work(lattice, weights, batch=batch) / real


def _route_cost(corners: list, weights: dict, batch: int = 1) -> float:
    """Optimizer objective: expected routing work PLUS each corner's
    batch-fragmentation cost. Every live corner drains on average half
    a partial micro-batch of pure padding per serving window, so a
    split must buy more routing work than the (batch/2) padded rows it
    adds — without this term the analytic objective happily shatters
    one traffic group across corners that then never fill."""
    lat = Lattice(corners=tuple(corners))
    cost = expected_padded_work(lat, weights)
    if batch > 1:
        cost += (batch / 2.0) * sum(padded_work(*c) for c in corners)
    return cost


def _quantile_cuts(values: list, max_cuts: int = 16) -> list:
    """Candidate cut points: every distinct boundary when few, weighted
    quantiles when many (the 'greedy over histogram quantiles' part —
    a group with hundreds of distinct m1 values gets O(max_cuts)
    candidate splits, not O(n))."""
    distinct = sorted(set(values))
    if len(distinct) <= max_cuts + 1:
        return distinct[:-1]          # cut AFTER each value except the max
    step = len(distinct) / (max_cuts + 1)
    return [distinct[int(step * (i + 1)) - 1] for i in range(max_cuts)]


def optimize_lattice(hist: ShapeHistogram | dict, *,
                     max_executables: int = 16,
                     min_weight: float = 0.0,
                     batch: int = 1) -> Lattice:
    """Pick bucket corners for the observed traffic.

    Greedy with a provable anchor: (1) group shapes by their
    power-of-two corner and SHRINK each corner to the aligned cover of
    its members — componentwise <= the power-of-two corner, so the
    expected padded work can only drop; (2) while over the executable
    budget, merge the pair of corners whose union costs least; (3)
    while under it, split the group whose best quantile cut saves the
    most expected work, where "cost" charges each corner batch/2
    padded rows of drain-time fragmentation on top of its routing work
    (pass the engine's max_batch — a split that shatters a group into
    corners that never fill a micro-batch is a net loss and is
    rejected). Guarantees: every observed shape is covered (by
    construction every group keeps a cover corner), the corner count
    never exceeds `max_executables`, and whenever the power-of-two
    lattice itself fits the budget the result's expected padded work
    is <= the power-of-two lattice's (step 1 starts componentwise
    below it, splits only replace a corner with componentwise-smaller
    covers, and merges only run past the budget anchor).

    `hist` is a ShapeHistogram or a pre-aggregated
    {(m1, m2, K): weight} dict. Returns the power-of-two lattice when
    there is nothing to learn from (empty histogram).
    """
    if max_executables < 1:
        raise ValueError(f"max_executables must be >= 1, got "
                         f"{max_executables}")
    weights = (hist.geometry_weights() if isinstance(hist, ShapeHistogram)
               else dict(hist))
    if min_weight > 0.0:
        kept = {s: w for s, w in weights.items() if w >= min_weight}
        weights = kept or weights     # never drop EVERYTHING
    if not weights:
        return Lattice(corners=None)

    # 1) power-of-two grouping, then shrink each corner to its cover
    pow2 = Lattice(corners=None)
    groups: dict[Bucket, list] = {}
    for shape in weights:
        m1, m2, K = shape
        bk = pow2.bucket_for(m1=m1, m2=m2, K=K, tag="_", batch=1)
        groups.setdefault(bk, []).append(shape)
    members: list[list] = [sorted(g) for g in groups.values()]
    corners: list[tuple] = [_cover(g) for g in members]

    # 2) merge while over budget (cheapest-union first)
    while len(corners) > max_executables:
        best, best_cost = None, math.inf
        for i in range(len(corners)):
            for j in range(i + 1, len(corners)):
                merged = _cover(members[i] + members[j])
                trial = ([c for k, c in enumerate(corners)
                          if k not in (i, j)] + [merged])
                cost = _route_cost(trial, weights, batch)
                if cost < best_cost:
                    best, best_cost = (i, j, merged), cost
        i, j, merged = best
        members[i] = sorted(members[i] + members[j])
        corners[i] = merged
        del members[j], corners[j]

    # 3) split while under budget (largest quantile-cut saving first)
    while len(corners) < max_executables:
        cost_now = _route_cost(corners, weights, batch)
        best, best_cost = None, cost_now
        for gi, group in enumerate(members):
            if len(group) < 2:
                continue
            for axis in (0, 1, 2):
                for cut in _quantile_cuts([s[axis] for s in group]):
                    lo = [s for s in group if s[axis] <= cut]
                    hi = [s for s in group if s[axis] > cut]
                    if not lo or not hi:
                        continue
                    trial = ([c for k, c in enumerate(corners) if k != gi]
                             + [_cover(lo), _cover(hi)])
                    cost = _route_cost(trial, weights, batch)
                    if cost < best_cost:
                        best, best_cost = (gi, lo, hi), cost
        if best is None:              # no improving split anywhere
            break
        gi, lo, hi = best
        members[gi] = lo
        corners[gi] = _cover(lo)
        members.append(hi)
        corners.append(_cover(hi))

    return Lattice(corners=tuple(sorted(set(corners))))


# ---------------------------------------------------------------------------
# Trough detection (when is re-warming free?)
# ---------------------------------------------------------------------------

@dataclass
class TroughDetector:
    """Arrival-rate EWMA + the admission lane's submission-lag EWMA,
    with a patience window: `in_trough(now)` is True only after BOTH
    signals have been quiet for `patience_s` straight.

    The lag signal is the same one the admission controller consumes
    (engine.observe_submission_lag feeds both) — a backed-up engine is
    never "in a trough" no matter how slow arrivals look, because the
    backlog still needs the host cycles a re-warm would steal.
    """

    rate_threshold_qps: float = 100.0
    lag_threshold_ms: float = 5.0
    patience_s: float = 0.5
    alpha: float = 0.2                # EWMA weight of each new sample

    _gap_ewma_s: float | None = field(default=None, repr=False)
    _lag_ewma_ms: float = field(default=0.0, repr=False)
    _last_arrival: float | None = field(default=None, repr=False)
    _quiet_since: float | None = field(default=None, repr=False)

    def observe_arrival(self, now: float) -> None:
        if self._last_arrival is not None:
            gap = max(now - self._last_arrival, 1e-9)
            self._gap_ewma_s = (gap if self._gap_ewma_s is None else
                                (1.0 - self.alpha) * self._gap_ewma_s
                                + self.alpha * gap)
        self._last_arrival = now
        self._update_quiet(now)

    def observe_lag(self, lag_ms: float) -> None:
        self._lag_ewma_ms = ((1.0 - self.alpha) * self._lag_ewma_ms
                             + self.alpha * float(lag_ms))

    def rate_qps(self, now: float) -> float:
        """The smoothed arrival rate, with the time since the LAST
        arrival folded in so a stream that simply stopped decays toward
        zero instead of freezing at its last busy estimate."""
        if self._last_arrival is None or self._gap_ewma_s is None:
            return 0.0
        gap = max(self._gap_ewma_s, now - self._last_arrival, 1e-9)
        return 1.0 / gap

    def _quiet(self, now: float) -> bool:
        return (self.rate_qps(now) < self.rate_threshold_qps
                and self._lag_ewma_ms < self.lag_threshold_ms)

    def _update_quiet(self, now: float) -> None:
        if self._quiet(now):
            if self._quiet_since is None:
                self._quiet_since = now
        else:
            self._quiet_since = None

    def in_trough(self, now: float) -> bool:
        self._update_quiet(now)
        return (self._quiet_since is not None
                and now - self._quiet_since >= self.patience_s)


# ---------------------------------------------------------------------------
# The shadow re-warm lane
# ---------------------------------------------------------------------------

class LatticeLane:
    """Background lattice re-warm lane (the RefreshLane pattern applied
    to SHAPES instead of predictor state).

    The engine feeds the lane's trough detector at enqueue
    (arrival times) and through observe_submission_lag (the admission
    lag signal); `maybe_rewarm(now)` — called from a driver loop or the
    `start()` background thread — proposes an optimized lattice from
    the live histogram whenever the detector reports a trough and
    enough new traffic has accumulated, shadow-warms it off the
    dispatch path, and flips it under the flush lock. Failures of any
    kind (compile, validation, a poisoned proposal) roll back to
    last-good: nothing flips, serving never pauses, and the attempt is
    counted in metrics.lattice_rollbacks.
    """

    def __init__(self, engine, *, max_executables: int = 16,
                 min_samples: int = 64, detector: TroughDetector | None = None,
                 histogram_path: str | None = None):
        self.engine = engine
        self.max_executables = int(max_executables)
        self.min_samples = int(min_samples)
        self.detector = detector if detector is not None else TroughDetector()
        self.histogram_path = histogram_path
        self._lock = threading.Lock()   # serializes rewarm attempts
        self._samples_at_last = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        engine.attach_lattice_lane(self)

    # -- telemetry feeds (engine seam) --------------------------------------

    def observe_arrival(self, now: float) -> None:
        self.detector.observe_arrival(now)

    def observe_lag(self, lag_ms: float) -> None:
        self.detector.observe_lag(lag_ms)

    # -- proposing / re-warming ---------------------------------------------

    def propose(self) -> Lattice:
        """The optimizer's lattice for the engine's live histogram,
        with split fragmentation priced at the engine's micro-batch."""
        return optimize_lattice(self.engine.shape_histogram,
                                max_executables=self.max_executables,
                                batch=self.engine.max_batch)

    def maybe_rewarm(self, now: float | None = None) -> dict:
        """One lane tick: re-warm iff the detector reports a trough AND
        at least `min_samples` new observations arrived since the last
        attempt. Returns a report dict (swapped: bool, reason: str)."""
        now = time.perf_counter() if now is None else now
        hist = self.engine.shape_histogram
        if hist.total - self._samples_at_last < self.min_samples:
            return {"swapped": False, "reason": "too-few-samples"}
        if not self.detector.in_trough(now):
            return {"swapped": False, "reason": "no-trough"}
        return self.rewarm()

    def rewarm(self) -> dict:
        """Force one shadow re-warm attempt now (trough check skipped —
        what the CI gate and a manual operator call). Serialized: a
        second caller waits for the first attempt to finish."""
        with self._lock:
            self._samples_at_last = self.engine.shape_histogram.total
            proposal = self.propose()
            live = self.engine.lattice()
            if proposal.corners == live.corners:
                return {"swapped": False, "reason": "no-change",
                        "epoch": self.engine.lattice_epoch()}
            try:
                report = self.engine.rewarm_lattice(proposal)
            except BaseException as e:          # noqa: BLE001
                # rollback to last-good is a no-op by construction:
                # nothing flipped, the live lattice and its warmed
                # executables keep serving.
                self.engine.metrics.on_lattice_rollback()
                return {"swapped": False,
                        "reason": f"rewarm-failed: {type(e).__name__}: {e}",
                        "epoch": self.engine.lattice_epoch()}
            if self.histogram_path:
                self.engine.shape_histogram.save(self.histogram_path)
            return {"swapped": True, "epoch": report["epoch"],
                    "corners": proposal.corners,
                    "warm_ms": report["warm_ms"],
                    "buckets": report["buckets"]}

    # -- background thread (crash-contained, RefreshLane-style) -------------

    def start(self, interval_s: float = 0.25) -> None:
        """Run `maybe_rewarm` every `interval_s` on a daemon thread. A
        crash inside one tick is contained (counted as a rollback) —
        the lane keeps ticking and serving is never interrupted."""
        if self._thread is not None:
            raise RuntimeError("lattice lane already started")
        self._stop.clear()

        def loop():
            while not self._stop.wait(interval_s):
                try:
                    self.maybe_rewarm()
                except BaseException:           # noqa: BLE001
                    self.engine.metrics.on_lattice_rollback()

        self._thread = threading.Thread(target=loop, name="lattice-lane",
                                        daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
