"""Streaming serving engine: shape-bucketed micro-batching over the
constrained-ranking online path, with async double-buffered execution.

The unit of work is one RankRequest — one user's candidate utilities,
constraint attributes/thresholds, slot count, and either precomputed
shadow prices (lam) or the covariate vector X for an attached lambda
predictor. Requests stream in with heterogeneous geometry (m1, m2, K)
from heterogeneous upstream recommenders; the engine:

  1. maps each request to a shape Bucket (repro.serving.buckets),
     mints its RankFuture, and appends it to that bucket's queue;
  2. flushes a queue when it reaches the bucket's micro-batch capacity
     (capacity flush) or when its oldest request has waited max_wait_ms
     (deadline flush, checked by `poll`), or on `drain`;
  3. SUBMISSION SIDE (the caller's thread): assembles the flushed batch
     into a recycled StagingRing host buffer and dispatches it through
     ONE cached, pre-warmed jit executable per bucket — the existing
     online path (core.ranking.rank_given_lambda /
     kernels.ops.fused_rank / core.serving_dist.rank_distributed when
     a mesh is present) — with the big staging buffers donated to the
     runtime. Dispatch is asynchronous: the jit call returns device
     futures immediately and the submission side moves on to the next
     batch;
  4. COMPLETION SIDE (the pipeline worker thread): while the device
     executes batch N+1, the worker blocks on batch N's device→host
     transfer (GIL released), stamps completion, recycles N's staging
     buffers, and marks each of N's RankFutures done. Per-row
     unpadding to the request's real geometry is Python work, so it
     runs lazily on the consuming thread — future.result() or the
     collect path behind submit/poll/drain — never on the worker.

Steady state therefore never recompiles (the jit cache is the bucket
lattice, populated by `warmup` — the only place `block_until_ready`
survives), never pays per-request dispatch (amortized over the
micro-batch), and never serializes host assembly against device
execution (the sole job of the old blocking `rank()` call, retired in
favor of futures). `pipeline_depth` bounds the in-flight window —
depth 1 (the default) is classic double buffering: one batch
materializing while the next is assembled and dispatched; depth 0
recovers the synchronous single-threaded engine (same results, no
overlap), which is what the sync column of
benchmarks/latency_serve.py measures and what the equivalence tests
in tests/test_serving_pipeline.py compare against.

See docs/serving.md for timelines and backpressure semantics, and
docs/api.md for the public API.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.predictors import predictor_state, with_state
from repro.core.ranking import RankingOutput, rank_given_lambda
from repro.serving.admission import SHED_RUNG, AdmissionController
from repro.serving.buckets import (
    AUTOTUNE_KEYS,
    Bucket,
    assemble_batch,
    fill_staging,
    fill_stats,
    load_autotune_table,
    resolve_autotune,
    unpad_result,
)
from repro.serving.lattice import Lattice, ShapeHistogram
from repro.serving.metrics import EngineMetrics
from repro.serving.pipeline import (
    ExecutionPipeline,
    PendingBatch,
    RankFuture,
    StagingRing,
)

LAM_TAG = "_lam"   # requests that carry shadow prices directly

# Default per-request latency budget: the paper's 50 ms claim.
DEFAULT_BUDGET_S = 0.050


@dataclass
class RankRequest:
    """One user's ranking problem. Arrays are host (numpy) payloads —
    the engine owns staging/padding and device transfer.

    Deadline semantics: `deadline` is ABSOLUTE (in the engine's clock
    domain — time.perf_counter by default); `budget_s` is RELATIVE to
    enqueue time. When both are None the engine's default_budget_s
    (50 ms, the paper's budget) applies; when both are set, `deadline`
    wins."""

    rid: int
    u: np.ndarray                     # (m1,) candidate utilities
    a: np.ndarray                     # (K, m1) constraint attributes
    b: np.ndarray                     # (K,) exposure thresholds
    m2: int                           # slots to fill (m2 <= m1)
    lam: np.ndarray | None = None     # (K,) shadow prices, if precomputed
    X: np.ndarray | None = None      # (d,) covariates for the predictor
    tag: str = LAM_TAG                # predictor/arch affinity
    gamma: np.ndarray | None = None  # (m2,) slot discounts; default DCG
    deadline: float | None = None    # absolute deadline (engine clock)
    budget_s: float | None = None    # relative budget (enqueue + budget_s)
    surface: str = "default"         # budget class (engine.surface_budgets)

    def __post_init__(self):
        if self.lam is None and self.X is None:
            raise ValueError(f"request {self.rid}: need lam or X")
        if self.m2 > self.u.shape[0]:
            raise ValueError(f"request {self.rid}: m2 > m1")


@dataclass
class RankResult:
    rid: int
    perm: np.ndarray                  # (m2,) item indices by slot
    utility: float
    exposure: np.ndarray              # (K,)
    compliant: bool
    bucket: str
    latency_ms: float                 # enqueue -> result materialized
    wait_ms: float                    # enqueue -> batch launch
    deadline_hit: bool | None = None  # materialized before the deadline?
    rung: int = 0                     # degradation rung served (0 = own)
    epoch: int = 0                    # predictor generation that served it
    lattice_epoch: int = 0            # bucket-lattice generation at dispatch


@dataclass
class Shed:
    """Typed admission-shed outcome: the request's RankFuture resolves
    with THIS (not an exception) when every degradation rung was
    predicted to miss the deadline. `predicted_ms` is the cheapest
    rung's predicted completion — the best the engine could have done
    against `budget_ms` of headroom."""

    rid: int
    bucket: str                       # the request's home bucket
    predicted_ms: float
    budget_ms: float
    reason: str = "predicted-miss-at-every-rung"
    rung: int = SHED_RUNG


@dataclass
class _QueueEntry:
    """One admitted request waiting in (or flushed from) a bucket
    queue: the request plus its admission-time bookkeeping."""

    req: RankRequest
    t_enq: float
    fut: Any                          # RankFuture
    deadline: float                   # absolute, engine clock
    rung: int                         # degradation rung being served


@dataclass
class _PredictorEntry:
    predictor: Any                    # pytree with .predict(X) -> (n, K)
    d_cov: int
    K: int


class ServingEngine:
    """Shape-bucketed micro-batching executor for ranking requests.

    executor: 'xla'   — rank_given_lambda (default; the jnp hot path)
              'fused' — kernels.ops.fused_rank (Pallas on TPU,
                        interpret-mode on CPU)
              'dist'  — core.serving_dist.rank_distributed on `mesh`
                        (candidate axis sharded; requires mesh)

    pipeline_depth: how many micro-batches the submission side may run
    ahead of the one currently materializing. 1 (default) is classic
    double buffering — batch N+1 is assembled and dispatched while
    batch N's outputs transfer back — and measures best on CPU, where
    deeper windows make XLA execute batches concurrently and thrash
    the cores; on an accelerator backend a deeper window can hide
    longer transfer tails. The submission side blocks (backpressure)
    once the window is full. 0 disables the pipeline: every flush
    dispatches, materializes, and resolves inline on the calling
    thread — bitwise the same results, strictly serial timing.

    admission: deadline-aware admission control (serving/admission.py).
    None (default) admits everything — results still carry
    `deadline_hit` against the 50 ms default budget, so an
    admission-disabled engine reports its misses. With a controller
    attached, every submit is checked against the request's deadline:
    admit on rung 0, degrade down the tag's registered ladder
    (`set_degradation_ladder`) to a cheaper pre-warmed predictor
    bucket, or shed (the RankFuture resolves with a typed `Shed`).
    At zero load admission is non-interfering: served results are
    bitwise identical to the admission-disabled engine
    (tests/test_serving_pipeline.py asserts this).
    """

    def __init__(
        self,
        *,
        max_batch: int = 32,
        max_wait_ms: float = 2.0,
        eps: float = 1e-4,
        executor: str = "xla",
        mesh=None,
        donate: bool | None = None,
        pipeline_depth: int = 1,
        admission: AdmissionController | bool | None = None,
        default_budget_s: float = DEFAULT_BUDGET_S,
        surface_budgets: dict[str, float] | None = None,
        autotune_table: dict | str | None = None,
        lattice: Lattice | None = None,
        clock: Callable[[], float] = time.perf_counter,
    ):
        if executor not in ("xla", "fused", "dist"):
            raise ValueError(f"unknown executor {executor!r}")
        if executor == "dist" and mesh is None:
            raise ValueError("executor='dist' needs a mesh")
        if pipeline_depth < 0:
            raise ValueError(f"pipeline_depth must be >= 0, got "
                             f"{pipeline_depth}")
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)
        self.eps = float(eps)
        self.executor = executor
        self.mesh = mesh
        if donate is None:  # CPU ignores donation (and warns); skip there
            donate = jax.default_backend() != "cpu"
        self.donate = bool(donate)
        self.pipeline_depth = int(pipeline_depth)
        # admission control: None/False = every request is admitted on
        # rung 0 (pre-admission behavior, deadline tracking still on);
        # True = a default AdmissionController; or pass a configured one.
        if admission is True:
            admission = AdmissionController()
        elif admission is False:
            admission = None
        self.admission: AdmissionController | None = admission
        self.default_budget_s = float(default_budget_s)
        # per-surface budget classes: a request that carries neither a
        # deadline nor a budget_s gets its SURFACE's default budget
        # (e.g. {"feed": 0.05, "search": 0.1}); surfaces not listed
        # fall back to default_budget_s. Deadline hit/miss/shed/degrade
        # are reported per class in metrics.deadline_summary().
        self.surface_budgets = {str(k): float(v)
                                for k, v in (surface_budgets or {}).items()}
        # per-geometry kernel autotune table (benchmarks/autotune.py):
        # a dict {geometry_key: {tile_b/tile_m/tile_n/quant}}, or a
        # path to a saved JSON table (loaded here — absent file = empty
        # table = defaults). Applied per bucket in _build_executor.
        if isinstance(autotune_table, str):
            autotune_table = load_autotune_table(autotune_table)
        self.autotune_table: dict = dict(autotune_table or {})
        self.autotuned_buckets: int = 0
        # bucket lattice (repro.serving.lattice): None/default = the
        # static power-of-two lattice. The live lattice routes every
        # bucket_of; swap_lattice flips it epoch-fenced under the flush
        # lock, exactly like swap_predictor flips predictor state.
        # shape_histogram accumulates the exact per-(tag, surface,
        # m1, m2, K, d_cov) arrival counts at enqueue — what the
        # LatticeLane's optimizer proposes new corners from.
        lattice = Lattice() if lattice is None else lattice
        lattice.validate()
        self._lattice: Lattice = lattice
        self._lattice_epoch: int = 0
        self.shape_histogram = ShapeHistogram()
        self._lattice_lane = None
        self.clock = clock
        self.metrics = EngineMetrics()
        self._predictors: dict[str, _PredictorEntry] = {}
        # hot-swap generations: per tag, the LIVE state dict the bucket
        # executables are fed (threaded as jit argument 0 — never baked
        # into the trace, see _build_executor) plus a monotone epoch.
        # Superseded generations park in _old_states until every batch
        # dispatched against them has materialized (_inflight_gens
        # pins), then retire — on accelerator backends that release
        # their device buffers.
        self._pred_state: dict[str, dict] = {}
        self._pred_epoch: dict[str, int] = {}
        self._old_states: dict[str, dict[int, dict]] = {}
        self._inflight_gens: dict[tuple[str, int], int] = {}
        self._swap_lock = threading.Lock()
        self._refresh = None              # attached RefreshLane, if any
        self._ladders: dict[str, tuple[str, ...]] = {}
        self._uncollected_sheds: list[Shed] = []
        self._exec: dict[Bucket, Callable] = {}
        # Pallas kernel launches per bucket-executable invocation
        # (kernels.ops.kernel_launch_count of the bucket's route) —
        # what metrics.kernel_launches charges each flushed batch.
        self._kernel_launches: dict[Bucket, int] = {}
        self._queues: dict[Bucket, list] = {}
        self._rings: dict[Bucket, StagingRing] = {}
        self._warmed: set[Bucket] = set()
        self._in_warmup = False           # re-warm compiles aren't violations
        self._retired_sync: list = []     # sync-mode batches awaiting collect
        self._pipeline = (ExecutionPipeline(depth=self.pipeline_depth)
                          if self.pipeline_depth > 0 else None)

    # -- predictors ---------------------------------------------------------

    def register_predictor(self, tag: str, predictor: Any, *, d_cov: int) -> None:
        """Attach a fitted lambda predictor under `tag`; requests with
        X and this tag get lam predicted inside the bucket executable
        (one dispatch for predict + rank)."""
        if tag == LAM_TAG:
            raise ValueError(f"{LAM_TAG!r} is reserved for raw-lam requests")
        probe = predictor.predict(jnp.zeros((1, d_cov), jnp.float32))
        self._predictors[tag] = _PredictorEntry(
            predictor=predictor, d_cov=int(d_cov), K=int(probe.shape[-1]))
        with self._swap_lock:
            self._pred_state[tag] = jax.device_put(
                predictor_state(predictor))
            self._pred_epoch[tag] = 0

    def set_degradation_ladder(self, tag: str, fallbacks) -> None:
        """Register `tag`'s degradation ladder: when admission predicts
        rung 0 (the tag's own predictor, e.g. the KNN single-grid
        executable) would miss a deadline, requests route to
        fallbacks[0], then fallbacks[1], ... (cheaper, already-warmed
        predictor buckets — e.g. affine, then mean) before shedding.
        Every fallback must already be registered, accept the same
        covariates, and price at least as many constraints as `tag`
        (a rung that silently ignored constraints would fake its
        compliance numbers)."""
        if tag not in self._predictors:
            raise KeyError(f"no predictor registered for tag {tag!r}")
        fallbacks = tuple(fallbacks)
        primary = self._predictors[tag]
        for fb in fallbacks:
            if fb not in self._predictors:
                raise KeyError(f"ladder fallback {fb!r} is not a "
                               f"registered predictor")
            entry = self._predictors[fb]
            if entry.d_cov != primary.d_cov:
                raise ValueError(
                    f"ladder fallback {fb!r}: d_cov {entry.d_cov} != "
                    f"{primary.d_cov} of {tag!r}")
            if entry.K < primary.K:
                raise ValueError(
                    f"ladder fallback {fb!r} emits {entry.K} shadow "
                    f"prices < the {primary.K} that {tag!r} serves")
        self._ladders[tag] = fallbacks

    # -- predictor hot swap (serving/refresh.py's engine seam) ---------------

    def attach_refresh(self, lane) -> None:
        """Attach a refresh lane: every predictor-served result's
        (X, λ̂, exposure, b) row is fed to `lane.observe` at build time
        — the audit outputs are already on the host, so telemetry costs
        zero extra device reads."""
        self._refresh = lane

    def predictor_epoch(self, tag: str) -> int:
        """The tag's current predictor generation (0 = as registered)."""
        return self._pred_epoch[tag]

    def predictor_tags(self) -> tuple[str, ...]:
        """Registered predictor tags — what a fleet supervisor iterates
        when restoring a restarted replica from epoch checkpoints."""
        return tuple(self._predictors)

    def predictor_state_of(self, tag: str) -> dict:
        """The tag's LIVE state dict (device arrays) — what the next
        flush will dispatch against. The refresh lane builds its
        incremental updates on this."""
        with self._swap_lock:
            return self._pred_state[tag]

    def predictor_template(self, tag: str):
        """The registered predictor instance (the static template whose
        family routes kernel dispatch; its arrays are the generation-0
        state, NOT necessarily the live one)."""
        return self._predictors[tag].predictor

    def swap_predictor(self, tag: str, new, *, epoch: int | None = None
                       ) -> int:
        """Epoch-fenced two-phase hot swap of `tag`'s predictor state.

        `new` is a state dict (core.predictors.predictor_state) or a
        predictor instance to take the state from. Phase 1 (publish)
        validates structure/shape/dtype against the live generation —
        a mismatch would silently retrace the bucket executables, so it
        raises ValueError and the engine keeps serving last-good — and
        checks every leaf finite (a poisoned refresh must never reach
        the executables), then transfers the new buffers to the device.
        Phase 2 (flip) swaps the (state, epoch) pair under the same
        lock every flush reads it under, so the flip lands exactly at a
        micro-batch boundary: a batch is dispatched entirely against
        one generation, never a torn mix. The superseded generation is
        retired once its last in-flight batch materializes.

        Returns the new epoch. Never recompiles: the state enters the
        warmed executables as an argument with unchanged treedef.

        `epoch` pins the published generation's number instead of
        current+1 — the checkpoint-restore path: a restarted replica
        swapping in its last-good state must RESUME that state's epoch,
        so results it serves are labeled with the same generation the
        pre-crash replica's were. Epochs stay monotone: a pinned epoch
        at or below the live one raises.
        """
        if tag not in self._predictors:
            raise KeyError(f"no predictor registered for tag {tag!r}")
        state = dict(new) if isinstance(new, dict) else predictor_state(new)
        cur = self._pred_state[tag]
        if not cur:
            raise ValueError(
                f"swap {tag!r}: predictor family has no registered "
                f"refreshable state (core.predictors.STATE_FIELDS)")
        if set(state) != set(cur):
            raise ValueError(
                f"swap {tag!r}: state keys {sorted(state)} != "
                f"{sorted(cur)} of the live generation")
        cur_leaves = jax.tree_util.tree_leaves_with_path(cur)
        new_leaves = jax.tree_util.tree_leaves_with_path(state)
        if [p for p, _ in new_leaves] != [p for p, _ in cur_leaves]:
            raise ValueError(
                f"swap {tag!r}: state tree structure differs from the "
                f"live generation (would retrace the warmed executables)")
        for (path, new_leaf), (_, cur_leaf) in zip(new_leaves, cur_leaves):
            new_leaf = jnp.asarray(new_leaf)
            if (new_leaf.shape != cur_leaf.shape
                    or new_leaf.dtype != cur_leaf.dtype):
                raise ValueError(
                    f"swap {tag!r}: leaf {jax.tree_util.keystr(path)} is "
                    f"{new_leaf.shape}/{new_leaf.dtype}, live generation "
                    f"has {cur_leaf.shape}/{cur_leaf.dtype} — shapes are "
                    f"frozen (the no-recompile contract)")
            if not bool(np.all(np.isfinite(np.asarray(new_leaf)))):
                raise ValueError(
                    f"swap {tag!r}: non-finite values in leaf "
                    f"{jax.tree_util.keystr(path)} — poisoned state "
                    f"refused, serving stays on the live generation")
        state = jax.device_put(state)     # phase 1: publish new buffers
        with self._swap_lock:             # phase 2: flip at batch boundary
            old_epoch = self._pred_epoch[tag]
            new_epoch = old_epoch + 1 if epoch is None else int(epoch)
            if new_epoch <= old_epoch:
                raise ValueError(
                    f"swap {tag!r}: pinned epoch {new_epoch} <= live epoch "
                    f"{old_epoch} — epochs are monotone (restore resumes, "
                    f"never rewinds)")
            self._old_states.setdefault(tag, {})[old_epoch] = cur
            self._pred_state[tag] = state
            self._pred_epoch[tag] = new_epoch
            self._retire_unpinned(tag)
        self.metrics.on_swap(tag)
        return new_epoch

    def _current_gen(self, tag: str) -> tuple[dict, int]:
        """The (state, epoch) pair a flush dispatches against, read
        atomically — the other half of the swap fence."""
        with self._swap_lock:
            epoch = self._pred_epoch[tag]
            self._inflight_gens[(tag, epoch)] = (
                self._inflight_gens.get((tag, epoch), 0) + 1)
            return self._pred_state[tag], epoch

    def _release_gen(self, tag: str, epoch: int) -> None:
        """A batch dispatched against (tag, epoch) has materialized:
        unpin the generation and retire it if it is superseded and no
        other batch still holds it."""
        key = (tag, epoch)
        with self._swap_lock:
            n = self._inflight_gens.get(key, 1) - 1
            if n <= 0:
                self._inflight_gens.pop(key, None)
            else:
                self._inflight_gens[key] = n
            self._retire_unpinned(tag)

    def _retire_unpinned(self, tag: str) -> None:
        # caller holds _swap_lock
        old = self._old_states.get(tag)
        if not old:
            return
        cur = self._pred_epoch[tag]
        for epoch in [e for e in old
                      if e < cur and (tag, e) not in self._inflight_gens]:
            del old[epoch]
            self.metrics.on_state_retired(tag)

    # -- bucketing ----------------------------------------------------------

    def bucket_of(self, req: RankRequest,
                  lattice: Lattice | None = None) -> Bucket:
        """Route a request to its bucket on the LIVE lattice (or an
        explicit one — what shadow warm uses to pre-route against a
        proposal before it flips)."""
        lattice = self._lattice if lattice is None else lattice
        tag = LAM_TAG if req.lam is not None else req.tag
        K = req.a.shape[0]
        if tag != LAM_TAG:
            if tag not in self._predictors:
                raise KeyError(f"no predictor registered for tag {tag!r}")
            K_pred = self._predictors[tag].K
            if K > K_pred:
                # the predictor cannot price constraints it was not fit
                # for; serving them with lam=0 would silently ignore them.
                raise ValueError(
                    f"request {req.rid}: {K} constraints but predictor "
                    f"{tag!r} emits only {K_pred} shadow prices")
            # the bucket tier must hold every predicted entry; extra
            # predicted entries beyond the request's K hit zero a-rows.
            K = K_pred
        return lattice.bucket_for(m1=req.u.shape[0], m2=req.m2, K=K,
                                  tag=tag, batch=self.max_batch)

    def _rung_buckets(self, req: RankRequest, home: Bucket,
                      lattice: Lattice | None = None
                      ) -> list[tuple[int, Bucket]]:
        """The request's degradation ladder as (rung, bucket) pairs,
        rung 0 (its own bucket) first. Raw-lam requests have no ladder
        — the rank itself is already the cheapest program."""
        lattice = self._lattice if lattice is None else lattice
        rungs = [(0, home)]
        if req.X is None or home.tag == LAM_TAG:
            return rungs
        K_req = req.a.shape[0]
        for i, fb in enumerate(self._ladders.get(req.tag, ()), start=1):
            entry = self._predictors[fb]
            if entry.K < K_req:      # cannot price this request's system
                continue
            rungs.append((i, lattice.bucket_for(
                m1=req.u.shape[0], m2=req.m2, K=entry.K, tag=fb,
                batch=self.max_batch)))
        return rungs

    # -- adaptive lattice: telemetry, shadow warm, epoch-fenced swap --------

    def attach_lattice_lane(self, lane) -> None:
        """Attach a LatticeLane: the engine feeds its trough detector
        arrival times at enqueue and lag samples through
        observe_submission_lag — the same admission signal."""
        self._lattice_lane = lane

    def lattice(self) -> Lattice:
        """The live bucket lattice (what bucket_of routes on)."""
        return self._lattice

    def lattice_epoch(self) -> int:
        """The live lattice generation (0 = the boot lattice)."""
        return self._lattice_epoch

    def _lattice_buckets(self, lattice: Lattice,
                         sample=None) -> set[Bucket]:
        """Every bucket the OBSERVED traffic (the shape histogram, plus
        an optional sample of RankRequests/Buckets) reaches on
        `lattice`, ladder rungs included — the set a shadow warm must
        compile so the flipped lattice never forces a dispatch-path
        compile on traffic shaped like what we've seen."""
        buckets: set[Bucket] = set()
        for tag, _, m1, m2, K, _, _ in self.shape_histogram.shapes():
            if tag != LAM_TAG and tag not in self._predictors:
                continue                      # tag retired since observed
            K_route = K
            if tag != LAM_TAG:
                K_pred = self._predictors[tag].K
                if K > K_pred:
                    continue                  # bucket_of would refuse it
                K_route = K_pred
            buckets.add(lattice.bucket_for(m1=m1, m2=m2, K=K_route,
                                           tag=tag, batch=self.max_batch))
            # ladder rungs, mirroring _rung_buckets against the
            # request's REAL constraint count
            if tag != LAM_TAG:
                for fb in self._ladders.get(tag, ()):
                    entry = self._predictors[fb]
                    if entry.K < K:
                        continue
                    buckets.add(lattice.bucket_for(
                        m1=m1, m2=m2, K=entry.K, tag=fb,
                        batch=self.max_batch))
        for r in sample or ():
            if isinstance(r, Bucket):
                buckets.add(r)
                continue
            home = self.bucket_of(r, lattice)
            for _, bk in self._rung_buckets(r, home, lattice):
                buckets.add(bk)
        return buckets

    def shadow_warm_lattice(self, new_lattice: Lattice,
                            sample=None) -> dict:
        """Compile + warm `new_lattice`'s executables OFF the dispatch
        path: every bucket the observed traffic would reach on the new
        lattice that is not already warmed gets built and executed on a
        phantom batch here — on the calling thread (the LatticeLane's
        background thread in production), never on a flush. Warmed
        executables are installed into the live cache under the swap
        lock; until swap_lattice flips, routing still uses the old
        lattice, so this is pure cache growth (counted as
        metrics.shadow_compiles — the refined no-recompile contract
        allows cache growth ONLY here and in warmup()).

        Raises on any compile/validation failure — nothing was flipped,
        so the engine keeps serving the last-good lattice untouched.
        """
        new_lattice.validate()
        t0 = self.clock()
        compiled = []
        buckets = self._lattice_buckets(new_lattice, sample)
        for bucket in sorted(buckets):
            if bucket in self._warmed:
                continue
            fn = self._build_executor(bucket)
            staged = assemble_batch([], bucket, d_cov=self._dcov(bucket))
            jax.block_until_ready(self._call(fn, bucket, staged).perm)
            if self.admission is not None:
                t0b = self.clock()
                jax.block_until_ready(self._call(fn, bucket, staged).perm)
                self.admission.observe_service(
                    bucket.name, (self.clock() - t0b) * 1e3)
            with self._swap_lock:
                self._exec[bucket] = fn
                self._warmed.add(bucket)
            self.metrics.on_shadow_compile()
            compiled.append(bucket.name)
        return {"buckets": sorted(b.name for b in buckets),
                "compiled": compiled,
                "warm_ms": (self.clock() - t0) * 1e3}

    def swap_lattice(self, new_lattice: Lattice, *,
                     epoch: int | None = None,
                     warm_ms: float = 0.0) -> int:
        """Epoch-fenced flip of the live lattice, exactly like
        swap_predictor's phase 2: validate that every bucket the
        observed traffic reaches on `new_lattice` is already warmed
        (shadow_warm_lattice's job — an unwarmed corner would compile
        ON the dispatch path, the one thing the contract forbids), then
        swap (lattice, epoch) under the same lock every flush stamps
        its batch under. A batch is bucketed-and-dispatched entirely
        within one lattice generation; old-lattice buckets stay warmed
        in the cache, so queued/in-flight work routed before the flip
        drains with zero recompiles. Epochs are monotone; `epoch` pins
        the generation number for checkpoint-restore paths."""
        new_lattice.validate()
        missing = [b.name for b in sorted(self._lattice_buckets(new_lattice))
                   if b not in self._warmed]
        if missing:
            raise ValueError(
                f"swap_lattice: observed traffic reaches unwarmed buckets "
                f"{missing} — run shadow_warm_lattice first (a cold corner "
                f"would compile on the dispatch path)")
        with self._swap_lock:
            old_epoch = self._lattice_epoch
            new_epoch = old_epoch + 1 if epoch is None else int(epoch)
            if new_epoch <= old_epoch:
                raise ValueError(
                    f"swap_lattice: pinned epoch {new_epoch} <= live epoch "
                    f"{old_epoch} — epochs are monotone")
            self._lattice = new_lattice
            self._lattice_epoch = new_epoch
        self.metrics.on_lattice_swap(new_epoch, warm_ms=warm_ms)
        return new_epoch

    def rewarm_lattice(self, new_lattice: Lattice, sample=None) -> dict:
        """shadow_warm_lattice + swap_lattice in one move — what the
        LatticeLane calls in a trough. Any failure propagates BEFORE
        the flip, so the caller's rollback is a no-op: the last-good
        lattice and its warmed executables never stopped serving."""
        report = self.shadow_warm_lattice(new_lattice, sample)
        report["epoch"] = self.swap_lattice(new_lattice,
                                            warm_ms=report["warm_ms"])
        return report

    # -- executables --------------------------------------------------------

    def _rank_fn(self, bucket: Bucket):
        """The bucket's rank body over already-padded device arrays."""
        m2, eps = bucket.m2, self.eps
        if self.executor == "dist":
            mesh = self.mesh
            from repro.core.serving_dist import rank_distributed

            def rank(u, a, b, lam, gamma):
                return rank_distributed(mesh, u, a, b, lam, gamma,
                                        m2=m2, eps=eps)
        elif self.executor == "fused":
            # One fused rank+audit kernel: utility/exposure/compliance are
            # computed in VMEM at the flush step — no post-kernel gather
            # or einsum ever reads u/a again (kernels/fused_rank.py).
            from repro.kernels.ops import rank_audited

            def rank(u, a, b, lam, gamma):
                return rank_audited(u, a, b, lam, gamma, m2=m2, eps=eps)
        else:
            rank = partial(rank_given_lambda, m2=m2, eps=eps)
        return rank

    def _build_executor(self, bucket: Bucket) -> Callable:
        """One fresh jit wrapper per bucket: its compile cache holds
        exactly one entry, so `jit_cache_sizes` exposes recompiles."""
        from repro.kernels.ops import kernel_launch_count

        predictor = (None if bucket.tag == LAM_TAG
                     else self._predictors[bucket.tag].predictor)
        self._kernel_launches[bucket] = (
            kernel_launch_count(predictor, bucket.m2)
            if self.executor == "fused" else 0)
        rank = self._rank_fn(bucket)
        if bucket.tag == LAM_TAG:

            def fn(b, gamma, u, a, lam):
                return rank(u, a, b, lam, gamma)

            return jax.jit(fn, donate_argnums=(2, 3) if self.donate else ())

        # Predictor-tagged buckets take the predictor's ARRAY state as
        # argument 0 instead of closing over it: closed-over arrays are
        # baked into the executable as constants, so a λ-refresh would
        # force a retrace. As an argument with frozen pytree structure
        # + shapes + dtypes, a hot-swapped generation hits the same
        # compile-cache entry — the no-recompile contract holds across
        # swaps. Only the static template (family, KNN's k) is closed
        # over. u/a stay the donated staging buffers; the state is NOT
        # donated — it serves every batch until the next swap.
        entry = self._predictors[bucket.tag]
        pred = entry.predictor              # static template
        donate = (3, 4) if self.donate else ()
        if self.executor == "dist":
            # the mesh-sharded rank body keeps its own predict stage
            # (still inside this one jit executable)
            pad_k = bucket.K - entry.K

            def fn(state, b, gamma, u, a, X):
                lam = with_state(pred, state).predict(X)    # (B, K_pred)
                lam = jnp.pad(lam, ((0, 0), (0, pad_k)))
                return rank(u, a, b, lam, gamma)

            return jax.jit(fn, donate_argnums=donate)

        # The single-sweep dispatcher (kernels.ops.predict_rank_audited
        # behind its stateful seam): predict + rank + audit lower to
        # ONE device program per flushed batch — for the fused executor
        # the affine families fold λ̂ into the rank kernel's VMEM
        # prologue and KNN fuses its weighting into the db sweep; the
        # xla executor runs the same dispatcher's two-stage XLA body
        # (use_kernel=False), still one executable.
        # metrics.executable_calls counts the contract.
        from repro.kernels.ops import predict_rank_audited_stateful

        m2, eps = bucket.m2, self.eps
        use_kernel = None if self.executor == "fused" else False
        # autotuned tile geometry for this bucket (benchmarks/autotune):
        # tile_* feed the dispatcher's kernel tiling; a 'quant' entry is
        # advisory — the packed predictor's own static quant field (and
        # its pack slab) route the quantized sweep, so the table entry
        # documents the winning mode rather than forcing a repack here.
        # resolved against ACTUAL geometry (never lattice position), so
        # tuned tiles survive a lattice swap; an adaptive corner with no
        # exact entry inherits its nearest covering tuned geometry's
        # tiles, clamped to fit (buckets.resolve_autotune).
        tune = resolve_autotune(self.autotune_table, bucket,
                                d_cov=self._dcov(bucket))
        tiles = {kk: int(v) for kk, v in tune.items()
                 if kk in AUTOTUNE_KEYS and kk != "quant"}
        if tune:
            self.autotuned_buckets += 1

        def fn(state, b, gamma, u, a, X):
            return predict_rank_audited_stateful(state, pred, X, u, a, b,
                                                 gamma, m2=m2, eps=eps,
                                                 use_kernel=use_kernel,
                                                 **tiles)

        return jax.jit(fn, donate_argnums=donate)

    def _executor_for(self, bucket: Bucket) -> Callable:
        fn = self._exec.get(bucket)
        if fn is None:
            fn = self._exec[bucket] = self._build_executor(bucket)
            self.metrics.on_compile(in_warmup=self._in_warmup)
        return fn

    def warmup(self, sample) -> dict:
        """Compile every bucket reachable from `sample` (RankRequests or
        Buckets) by executing one phantom batch per bucket — including
        every degradation-ladder rung of each request's tag, so a
        degrade decision can never trip the no-recompile contract.
        After this, any stream inside the lattice runs with zero
        recompiles. This is the only place the engine blocks on the
        device directly. With admission attached, a second (compiled)
        phantom execution per bucket seeds the controller's
        service-time EWMAs, so the very first live decision already
        has a real estimate instead of the prior."""
        buckets = set()
        for r in sample:
            if isinstance(r, Bucket):
                buckets.add(r)
                continue
            home = self.bucket_of(r)
            for _, bk in self._rung_buckets(r, home):
                buckets.add(bk)
        self._in_warmup = True
        try:
            for bucket in sorted(buckets):
                fn = self._executor_for(bucket)
                staged = assemble_batch([], bucket, d_cov=self._dcov(bucket))
                jax.block_until_ready(self._call(fn, bucket, staged).perm)
                if self.admission is not None:
                    t0 = self.clock()
                    jax.block_until_ready(
                        self._call(fn, bucket, staged).perm)
                    self.admission.observe_service(
                        bucket.name, (self.clock() - t0) * 1e3)
                self._warmed.add(bucket)
        finally:
            self._in_warmup = False
        self.metrics.warmed = True
        return {"buckets": [b.name for b in sorted(buckets)],
                "compiles": self.metrics.compiles}

    def _dcov(self, bucket: Bucket) -> int | None:
        if bucket.tag == LAM_TAG:
            return None
        return self._predictors[bucket.tag].d_cov

    def _call(self, fn, bucket: Bucket, staged: dict,
              state: dict | None = None) -> RankingOutput:
        if bucket.tag == LAM_TAG:
            return fn(staged["b"], staged["gamma"], staged["u"], staged["a"],
                      staged["lam"])
        if state is None:                  # warmup path: no gen pinning
            state = self._pred_state[bucket.tag]
        return fn(state, staged["b"], staged["gamma"], staged["u"],
                  staged["a"], staged["X"])

    def jit_cache_sizes(self) -> dict[str, int]:
        """Per-bucket jit compile-cache sizes (1 = exactly the warmed
        executable; >1 = something retraced). The no-recompile test
        asserts every value stays 1 across a mixed-shape stream."""
        return {b.name: fn._cache_size() for b, fn in self._exec.items()}

    # -- submission side: queueing / flushing -------------------------------

    def submit(self, req: RankRequest, now: float | None = None):
        """Enqueue; returns whatever results have retired so far (the
        capacity-flushed batch itself, when the pipeline is enabled,
        retires asynchronously — collect it from later submit/poll
        calls or from `drain`)."""
        self._enqueue(req, now)
        return self._collect()

    def submit_future(self, req: RankRequest,
                      now: float | None = None) -> RankFuture:
        """Enqueue and return this request's RankFuture. The future
        resolves when the request's micro-batch retires — or
        immediately, with a typed `Shed` result, when admission sheds
        it. Completed results also keep flowing through
        submit/poll/drain, so mixing the two styles is safe (same
        underlying results objects)."""
        return self._enqueue(req, now)

    def observe_submission_lag(self, lag_ms: float) -> None:
        """Feed the open-loop driver's queueing-lag sample (pacing
        clock-drift already separated out by serve_open_loop) to the
        admission controller as its online saturation signal. No-op
        without a controller."""
        if self.admission is not None:
            self.admission.observe_lag(lag_ms)
        if self._lattice_lane is not None:
            self._lattice_lane.observe_lag(lag_ms)

    def _deadline_of(self, req: RankRequest, now: float) -> float:
        if req.deadline is not None:
            return float(req.deadline)
        if req.budget_s is not None:
            budget = req.budget_s
        else:
            budget = self.surface_budgets.get(req.surface,
                                              self.default_budget_s)
        return now + float(budget)

    def _enqueue(self, req: RankRequest, now: float | None) -> RankFuture:
        now = self.clock() if now is None else now
        bucket = self.bucket_of(req)
        self.metrics.on_submit(bucket, known=bucket in self._warmed)
        # shape telemetry: the request's REAL geometry (pre-padding,
        # pre-K-widening) — what the lattice optimizer learns corners
        # from. A dict update per request; no device reads.
        self.shape_histogram.observe(
            tag=bucket.tag, m1=req.u.shape[0], m2=req.m2,
            K=req.a.shape[0],
            d_cov=None if req.X is None else req.X.shape[-1],
            surface=req.surface)
        if self._lattice_lane is not None:
            self._lattice_lane.observe_arrival(now)
        fut = RankFuture(req.rid, bucket.name)
        deadline = self._deadline_of(req, now)
        rung = 0
        if self.admission is not None:
            rungs = self._rung_buckets(req, bucket)
            inflight = (self._pipeline.inflight()
                        if self._pipeline is not None else 0)
            preds = [(r, self.admission.predict_ms(
                          bk.name,
                          queue_len=len(self._queues.get(bk, ())),
                          batch_cap=bk.batch, inflight=inflight,
                          max_wait_ms=self.max_wait_ms))
                     for r, bk in rungs]
            decision = self.admission.decide(
                budget_ms=(deadline - now) * 1e3, rung_predictions=preds)
            if not decision.admitted:
                self.metrics.on_shed(bucket, surface=req.surface)
                shed = Shed(rid=req.rid, bucket=bucket.name,
                            predicted_ms=decision.predicted_ms,
                            budget_ms=decision.budget_ms)
                fut._resolve(shed)
                self._uncollected_sheds.append(shed)
                return fut
            if decision.rung > 0:
                rung = decision.rung
                bucket = dict(rungs)[rung]
                self.metrics.on_degrade(rung, surface=req.surface)
        q = self._queues.setdefault(bucket, [])
        q.append(_QueueEntry(req=req, t_enq=now, fut=fut,
                             deadline=deadline, rung=rung))
        if len(q) >= bucket.batch:
            self._flush_bucket(bucket, trigger="capacity")
        return fut

    def poll(self, now: float | None = None):
        """Deadline check: flush every queue whose oldest request has
        waited longer than max_wait_ms; returns results retired so far."""
        now = self.clock() if now is None else now
        for bucket in list(self._queues):
            q = self._queues[bucket]
            if q and (now - q[0].t_enq) * 1e3 >= self.max_wait_ms:
                self._flush_bucket(bucket, trigger="deadline")
        return self._collect()

    def drain(self):
        """Flush every queue and wait for all in-flight batches to
        retire (stream end / graceful shutdown barrier). Returns every
        result not yet collected."""
        for bucket in list(self._queues):
            if self._queues[bucket]:
                self._flush_bucket(bucket, trigger="drain")
        if self._pipeline is not None:
            results = self._take_sheds()
            for pending in self._pipeline.flush():
                results += pending.results()
            return results
        return self._collect()

    def handoff_queued(self, error: BaseException | None = None) -> list:
        """Evict every QUEUED (not yet flushed) request — the fleet's
        drain/handoff primitive, generalizing the pipeline's drain: a
        draining or crashed replica first lets its in-flight batches
        retire (they were dispatched; their futures resolve normally),
        while its queued-but-unflushed requests must MOVE to another
        replica instead of being flushed into a dying engine. Each
        evicted entry's future fails with `error` (so a fleet router's
        failure path picks it up uniformly) and the request objects are
        returned for resubmission elsewhere."""
        if error is None:
            error = RuntimeError("request evicted for handoff")
        evicted = []
        for bucket in list(self._queues):
            entries, self._queues[bucket] = self._queues[bucket], []
            for e in entries:
                evicted.append(e.req)
                e.fut._fail(error)
        return evicted

    def close(self) -> None:
        """Graceful shutdown: drain in-flight work and stop the
        pipeline worker. The engine rejects flushes afterwards."""
        if self._pipeline is not None:
            self._pipeline.flush()
            self._pipeline.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _take_sheds(self) -> list:
        sheds, self._uncollected_sheds = self._uncollected_sheds, []
        return sheds

    def _collect(self):
        """Build results for every batch retired since the last call
        (plus any Shed outcomes since the last call). Runs on the
        caller's thread — the Python-heavy unpadding deliberately
        lives here, not on the pipeline worker, so it overlaps device
        execution instead of starving it via the GIL."""
        if self._pipeline is not None:
            batches = self._pipeline.collect()
        else:
            batches, self._retired_sync = self._retired_sync, []
        results = self._take_sheds()
        for pending in batches:
            results += pending.results()
        return results

    def _ring_for(self, bucket: Bucket) -> StagingRing:
        ring = self._rings.get(bucket)
        if ring is None:
            # the in-flight window holds pipeline_depth queued batches
            # plus the one materializing; one more slot keeps assembly
            # of the next batch from ever waiting on a buffer.
            ring = self._rings[bucket] = StagingRing(
                bucket, d_cov=self._dcov(bucket),
                depth=self.pipeline_depth + 2)
        return ring

    def _flush_bucket(self, bucket: Bucket, *, trigger: str) -> None:
        entries = self._queues[bucket]
        self._queues[bucket] = []
        reqs = [e.req for e in entries]
        ring = self._ring_for(bucket)
        fn = self._executor_for(bucket)
        t0 = self.clock()
        staged = fill_staging(ring.acquire(), reqs, bucket)
        # epoch fence: the (state, epoch) pair is read atomically, so
        # this whole batch dispatches against exactly one predictor
        # generation — a concurrent swap lands before or after, never
        # inside. The generation stays pinned until the batch
        # materializes (_release_gen), which is what delays retirement
        # of superseded device buffers past their last in-flight use.
        state, epoch = ((None, 0) if bucket.tag == LAM_TAG
                        else self._current_gen(bucket.tag))
        # lattice fence, same discipline: the epoch is read under the
        # swap lock, so a concurrent swap_lattice lands before or after
        # this batch, never inside it.
        with self._swap_lock:
            lattice_epoch = self._lattice_epoch
        t_launch = self.clock()
        try:
            out = self._call(fn, bucket, staged, state)  # async: no block
        except BaseException as e:                # noqa: BLE001
            # dispatch itself blew up (bad executable, device OOM, an
            # injected fault): fail this batch's futures so every one
            # still resolves exactly once, and recycle the staging set
            # — the ring is finite, and a leaked buffer would
            # eventually deadlock acquire() under continued load.
            for entry in entries:
                entry.fut._fail(e)
            ring.release(staged)
            if bucket.tag != LAM_TAG:      # nothing dispatched: unpin
                self._release_gen(bucket.tag, epoch)
            raise
        t1 = self.clock()
        # the single-dispatch contract: this _call was the batch's ONE
        # executable invocation — predictor buckets included (λ̂ is
        # predicted inside the executable, never as a separate program)
        # — and it contained the route's static kernel-launch count
        # (ONE for every fused-executor kernel bucket, KNN included
        # since the single-grid predict+rank+audit kernel).
        self.metrics.on_executable_call(self._kernel_launches[bucket])
        pending = PendingBatch(
            bucket=bucket, entries=entries,
            futures=[e.fut for e in entries], out=out, staged=staged,
            ring=ring, t_launch=t_launch, trigger=trigger,
            materialize=self._materialize_batch, build=self._build_result,
            assembly_ms=(t_launch - t0) * 1e3,
            dispatch_ms=(t1 - t_launch) * 1e3, epoch=epoch,
            lattice_epoch=lattice_epoch)
        if self._pipeline is not None:
            self._pipeline.submit(pending)      # may block: backpressure
        else:
            pending.finish()
            self._retired_sync.append(pending)
        self.metrics.on_dispatch(
            bucket, len(reqs), trigger, fill_stats(reqs, bucket),
            assembly_ms=pending.assembly_ms, dispatch_ms=pending.dispatch_ms,
            depth=pending.depth_at_dispatch, t_now=t_launch)

    # -- completion side ----------------------------------------------------

    def _materialize_batch(self, pending: PendingBatch) -> None:
        """Block until one batch's outputs reach the host. Runs on the
        pipeline worker (async mode) or inline (sync mode); this is the
        ONLY blocking step on the completion side — the GIL is released
        while waiting, so the submission thread keeps assembling.

        One bulk device->host copy per output; per-request unpadding is
        then pure numpy (slicing jax arrays row-by-row would dispatch —
        and on first touch compile — one tiny program per slice)."""
        out = pending.out
        # lam comes home with the rest: the refresh lane's telemetry
        # (λ̂ actually served) reads it row-by-row in _build_result, and
        # slicing a device array there would dispatch per row.
        pending.out = RankingOutput(
            perm=np.asarray(out.perm), utility=np.asarray(out.utility),
            exposure=np.asarray(out.exposure),
            compliant=np.asarray(out.compliant), lam=np.asarray(out.lam))
        pending.t_done = self.clock()
        exec_ms = (pending.t_done - pending.t_launch) * 1e3
        self.metrics.on_retire(exec_ms, pending.t_done)
        if self.admission is not None:
            self.admission.observe_service(pending.bucket.name, exec_ms)
        if pending.ring is not None:            # inputs consumed: recycle
            pending.ring.release(pending.staged)
            pending.staged = None
        if pending.bucket.tag != LAM_TAG:       # epoch fence: unpin the gen
            self._release_gen(pending.bucket.tag, pending.epoch)

    def _build_result(self, pending: PendingBatch, i: int) -> RankResult:
        """Unpad row `i` into its RankResult. Runs lazily, exactly once
        per row (memoized by the row's RankFuture), on whichever
        consumer thread first asks — the engine's collect path or a
        direct future.result() call."""
        entry = pending.entries[i]
        req, t_enq = entry.req, entry.t_enq
        perm, utility, exposure, compliant = unpad_result(pending.out, i, req)
        deadline_hit = pending.t_done <= entry.deadline
        # per-rung compliance cost: the exposure shortfall against the
        # request's REAL thresholds, computed from the fused kernel's
        # already-unpadded audit outputs — one tiny numpy op per row.
        shortfall = float(np.clip(req.b - exposure, 0.0, None).sum())
        self.metrics.on_result((pending.t_done - t_enq) * 1e3,
                               (pending.t_launch - t_enq) * 1e3, compliant,
                               deadline_hit=deadline_hit, rung=entry.rung,
                               shortfall=shortfall, surface=req.surface)
        if self.admission is not None:
            # measured-trend feed: the controller's windowed p99-vs-
            # budget tracker shifts the default degradation rung when
            # trailing MEASURED latency (not the submit-time
            # prediction) blows the budget for consecutive windows.
            self.admission.observe_result(
                (pending.t_done - t_enq) * 1e3,
                (entry.deadline - t_enq) * 1e3)
        if self._refresh is not None and pending.bucket.tag != LAM_TAG:
            # feed the refresh lane: covariates + the λ̂ / exposure /
            # threshold rows at the SERVED tag's predictor width (the
            # dual-subgradient triple). All host numpy already — the
            # audit outputs came home with the batch, zero extra
            # device reads.
            K_pred = self._predictors[pending.bucket.tag].K
            K_req = req.b.shape[0]
            expo_row = np.zeros(K_pred, np.float32)
            expo_row[:K_req] = exposure[:K_pred][:K_req]
            b_row = np.zeros(K_pred, np.float32)
            b_row[:K_req] = req.b[:K_pred][:K_req]
            self._refresh.observe(
                pending.bucket.tag, X=req.X,
                lam=np.asarray(pending.out.lam[i, :K_pred], np.float32),
                exposure=expo_row, b=b_row)
        return RankResult(
            rid=req.rid, perm=perm, utility=utility, exposure=exposure,
            compliant=compliant, bucket=pending.bucket.name,
            latency_ms=(pending.t_done - t_enq) * 1e3,
            wait_ms=(pending.t_launch - t_enq) * 1e3,
            deadline_hit=deadline_hit, rung=entry.rung,
            epoch=pending.epoch, lattice_epoch=pending.lattice_epoch)

    # -- convenience driver -------------------------------------------------

    def serve_stream(self, requests, *, warmup: bool = True):
        """Synchronous driver: submit each request in arrival order,
        honoring deadlines between arrivals, and drain at stream end.
        Returns results ordered by completion (retirement order)."""
        requests = list(requests)
        if warmup and not self.metrics.warmed:
            self.warmup(requests)
        results = []
        for req in requests:
            results += self.submit(req)
            results += self.poll()
        results += self.drain()
        return results
