"""Streaming serving engine: shape-bucketed micro-batching over the
constrained-ranking online path.

The unit of work is one RankRequest — one user's candidate utilities,
constraint attributes/thresholds, slot count, and either precomputed
shadow prices (lam) or the covariate vector X for an attached lambda
predictor. Requests stream in with heterogeneous geometry (m1, m2, K)
from heterogeneous upstream recommenders; the engine:

  1. maps each request to a shape Bucket (repro.serving.buckets) and
     appends it to that bucket's queue;
  2. flushes a queue when it reaches the bucket's micro-batch capacity
     (capacity flush) or when its oldest request has waited max_wait_ms
     (deadline flush, checked by `poll`), or on `drain`;
  3. executes the flushed batch through ONE cached, pre-warmed jit
     executable per bucket — the existing online path
     (core.ranking.rank_given_lambda / kernels.ops.fused_rank /
     core.serving_dist.rank_distributed when a mesh is present) — with
     the big staging buffers donated to the runtime;
  4. unpads each row back to its request's real geometry and stamps
     per-request latency.

Steady state therefore never recompiles (the jit cache is the bucket
lattice, populated by `warmup`) and never pays per-request dispatch:
dispatch cost is amortized over the micro-batch. The engine is
single-threaded and event-driven — `submit`/`poll` return completed
results — which keeps it deterministic and testable; async double
buffering is a ROADMAP follow-on.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.ranking import RankingOutput, rank_given_lambda
from repro.serving.buckets import (
    Bucket,
    assemble_batch,
    bucket_for,
    fill_stats,
    unpad_result,
)
from repro.serving.metrics import EngineMetrics

LAM_TAG = "_lam"   # requests that carry shadow prices directly


@dataclass
class RankRequest:
    """One user's ranking problem. Arrays are host (numpy) payloads —
    the engine owns staging/padding and device transfer."""

    rid: int
    u: np.ndarray                     # (m1,) candidate utilities
    a: np.ndarray                     # (K, m1) constraint attributes
    b: np.ndarray                     # (K,) exposure thresholds
    m2: int                           # slots to fill (m2 <= m1)
    lam: np.ndarray | None = None     # (K,) shadow prices, if precomputed
    X: np.ndarray | None = None      # (d,) covariates for the predictor
    tag: str = LAM_TAG                # predictor/arch affinity
    gamma: np.ndarray | None = None  # (m2,) slot discounts; default DCG

    def __post_init__(self):
        if self.lam is None and self.X is None:
            raise ValueError(f"request {self.rid}: need lam or X")
        if self.m2 > self.u.shape[0]:
            raise ValueError(f"request {self.rid}: m2 > m1")


@dataclass
class RankResult:
    rid: int
    perm: np.ndarray                  # (m2,) item indices by slot
    utility: float
    exposure: np.ndarray              # (K,)
    compliant: bool
    bucket: str
    latency_ms: float                 # enqueue -> result materialized
    wait_ms: float                    # enqueue -> batch launch


@dataclass
class _PredictorEntry:
    predictor: Any                    # pytree with .predict(X) -> (n, K)
    d_cov: int
    K: int


class ServingEngine:
    """Shape-bucketed micro-batching executor for ranking requests.

    executor: 'xla'   — rank_given_lambda (default; the jnp hot path)
              'fused' — kernels.ops.fused_rank (Pallas on TPU,
                        interpret-mode on CPU)
              'dist'  — core.serving_dist.rank_distributed on `mesh`
                        (candidate axis sharded; requires mesh)
    """

    def __init__(
        self,
        *,
        max_batch: int = 32,
        max_wait_ms: float = 2.0,
        eps: float = 1e-4,
        executor: str = "xla",
        mesh=None,
        donate: bool | None = None,
        clock: Callable[[], float] = time.perf_counter,
    ):
        if executor not in ("xla", "fused", "dist"):
            raise ValueError(f"unknown executor {executor!r}")
        if executor == "dist" and mesh is None:
            raise ValueError("executor='dist' needs a mesh")
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)
        self.eps = float(eps)
        self.executor = executor
        self.mesh = mesh
        if donate is None:  # CPU ignores donation (and warns); skip there
            donate = jax.default_backend() != "cpu"
        self.donate = bool(donate)
        self.clock = clock
        self.metrics = EngineMetrics()
        self._predictors: dict[str, _PredictorEntry] = {}
        self._exec: dict[Bucket, Callable] = {}
        self._queues: dict[Bucket, list] = {}
        self._warmed: set[Bucket] = set()

    # -- predictors ---------------------------------------------------------

    def register_predictor(self, tag: str, predictor: Any, *, d_cov: int) -> None:
        """Attach a fitted lambda predictor under `tag`; requests with
        X and this tag get lam predicted inside the bucket executable
        (one dispatch for predict + rank)."""
        if tag == LAM_TAG:
            raise ValueError(f"{LAM_TAG!r} is reserved for raw-lam requests")
        probe = predictor.predict(jnp.zeros((1, d_cov), jnp.float32))
        self._predictors[tag] = _PredictorEntry(
            predictor=predictor, d_cov=int(d_cov), K=int(probe.shape[-1]))

    # -- bucketing ----------------------------------------------------------

    def bucket_of(self, req: RankRequest) -> Bucket:
        tag = LAM_TAG if req.lam is not None else req.tag
        K = req.a.shape[0]
        if tag != LAM_TAG:
            if tag not in self._predictors:
                raise KeyError(f"no predictor registered for tag {tag!r}")
            K_pred = self._predictors[tag].K
            if K > K_pred:
                # the predictor cannot price constraints it was not fit
                # for; serving them with lam=0 would silently ignore them.
                raise ValueError(
                    f"request {req.rid}: {K} constraints but predictor "
                    f"{tag!r} emits only {K_pred} shadow prices")
            # the bucket tier must hold every predicted entry; extra
            # predicted entries beyond the request's K hit zero a-rows.
            K = K_pred
        return bucket_for(m1=req.u.shape[0], m2=req.m2, K=K, tag=tag,
                          batch=self.max_batch)

    # -- executables --------------------------------------------------------

    def _rank_fn(self, bucket: Bucket):
        """The bucket's rank body over already-padded device arrays."""
        m2, eps = bucket.m2, self.eps
        if self.executor == "dist":
            mesh = self.mesh
            from repro.core.serving_dist import rank_distributed

            def rank(u, a, b, lam, gamma):
                return rank_distributed(mesh, u, a, b, lam, gamma,
                                        m2=m2, eps=eps)
        elif self.executor == "fused":
            from repro.kernels.ops import fused_rank

            def rank(u, a, b, lam, gamma):
                _, idx = fused_rank(u, a, lam, m2=m2, eps=eps)
                u_sel = jnp.take_along_axis(u, idx, axis=-1)
                utility = jnp.einsum("nm,nm->n", u_sel, gamma)
                a_sel = jnp.take_along_axis(
                    a, idx[:, None, :].repeat(a.shape[1], axis=1), axis=-1)
                exposure = jnp.einsum("nkm,nm->nk", a_sel, gamma)
                compliant = jnp.all(exposure >= b - 1e-6, axis=-1)
                return RankingOutput(perm=idx, utility=utility,
                                     exposure=exposure, compliant=compliant,
                                     lam=lam)
        else:
            rank = partial(rank_given_lambda, m2=m2, eps=eps)
        return rank

    def _build_executor(self, bucket: Bucket) -> Callable:
        """One fresh jit wrapper per bucket: its compile cache holds
        exactly one entry, so `jit_cache_sizes` exposes recompiles."""
        rank = self._rank_fn(bucket)
        donate = (2, 3) if self.donate else ()
        if bucket.tag == LAM_TAG:

            def fn(b, gamma, u, a, lam):
                return rank(u, a, b, lam, gamma)

            return jax.jit(fn, donate_argnums=donate)

        entry = self._predictors[bucket.tag]
        pad_k = bucket.K - entry.K
        pred = entry.predictor      # closed over: baked into the executable

        def fn(b, gamma, u, a, X):
            lam = pred.predict(X)                       # (B, K_pred)
            lam = jnp.pad(lam, ((0, 0), (0, pad_k)))
            return rank(u, a, b, lam, gamma)

        return jax.jit(fn, donate_argnums=donate)

    def _executor_for(self, bucket: Bucket) -> Callable:
        fn = self._exec.get(bucket)
        if fn is None:
            fn = self._exec[bucket] = self._build_executor(bucket)
            self.metrics.on_compile()
        return fn

    def warmup(self, sample) -> dict:
        """Compile every bucket reachable from `sample` (RankRequests or
        Buckets) by executing one phantom batch per bucket. After this,
        any stream inside the lattice runs with zero recompiles."""
        buckets = {r if isinstance(r, Bucket) else self.bucket_of(r)
                   for r in sample}
        for bucket in sorted(buckets):
            fn = self._executor_for(bucket)
            jax.block_until_ready(
                self._call(fn, bucket, assemble_batch([], bucket,
                           d_cov=self._dcov(bucket))).perm)
            self._warmed.add(bucket)
        self.metrics.warmed = True
        return {"buckets": [b.name for b in sorted(buckets)],
                "compiles": self.metrics.compiles}

    def _dcov(self, bucket: Bucket) -> int | None:
        if bucket.tag == LAM_TAG:
            return None
        return self._predictors[bucket.tag].d_cov

    def _call(self, fn, bucket: Bucket, staged: dict) -> RankingOutput:
        if bucket.tag == LAM_TAG:
            return fn(staged["b"], staged["gamma"], staged["u"], staged["a"],
                      staged["lam"])
        return fn(staged["b"], staged["gamma"], staged["u"], staged["a"],
                  staged["X"])

    def jit_cache_sizes(self) -> dict[str, int]:
        """Per-bucket jit compile-cache sizes (1 = exactly the warmed
        executable; >1 = something retraced). The no-recompile test
        asserts every value stays 1 across a mixed-shape stream."""
        return {b.name: fn._cache_size() for b, fn in self._exec.items()}

    # -- queueing / flushing ------------------------------------------------

    def submit(self, req: RankRequest, now: float | None = None):
        """Enqueue; returns any results completed by a capacity flush."""
        now = self.clock() if now is None else now
        bucket = self.bucket_of(req)
        self.metrics.on_submit(bucket, known=bucket in self._warmed)
        q = self._queues.setdefault(bucket, [])
        q.append((req, now))
        if len(q) >= bucket.batch:
            return self._flush_bucket(bucket, trigger="capacity")
        return []

    def poll(self, now: float | None = None):
        """Deadline check: flush every queue whose oldest request has
        waited longer than max_wait_ms."""
        now = self.clock() if now is None else now
        out = []
        for bucket in list(self._queues):
            q = self._queues[bucket]
            if q and (now - q[0][1]) * 1e3 >= self.max_wait_ms:
                out += self._flush_bucket(bucket, trigger="deadline")
        return out

    def drain(self):
        """Flush everything (stream end)."""
        out = []
        for bucket in list(self._queues):
            if self._queues[bucket]:
                out += self._flush_bucket(bucket, trigger="drain")
        return out

    def _flush_bucket(self, bucket: Bucket, *, trigger: str):
        entries = self._queues[bucket]
        self._queues[bucket] = []
        reqs = [r for r, _ in entries]
        staged = assemble_batch(reqs, bucket, d_cov=self._dcov(bucket))
        fn = self._executor_for(bucket)
        t_launch = self.clock()
        out = self._call(fn, bucket, staged)
        # one bulk device->host copy per output; per-request unpadding is
        # then pure numpy (slicing jax arrays row-by-row would dispatch —
        # and on first touch compile — one tiny program per slice).
        out = RankingOutput(
            perm=np.asarray(out.perm), utility=np.asarray(out.utility),
            exposure=np.asarray(out.exposure),
            compliant=np.asarray(out.compliant), lam=out.lam)
        t_done = self.clock()
        self.metrics.on_batch(bucket, len(reqs), (t_done - t_launch) * 1e3,
                              trigger, fill_stats(reqs, bucket))
        results = []
        for i, (req, t_enq) in enumerate(entries):
            perm, utility, exposure, compliant = unpad_result(out, i, req)
            self.metrics.on_result((t_done - t_enq) * 1e3,
                                   (t_launch - t_enq) * 1e3, compliant)
            results.append(RankResult(
                rid=req.rid, perm=perm, utility=utility, exposure=exposure,
                compliant=compliant, bucket=bucket.name,
                latency_ms=(t_done - t_enq) * 1e3,
                wait_ms=(t_launch - t_enq) * 1e3))
        return results

    # -- convenience driver -------------------------------------------------

    def serve_stream(self, requests, *, warmup: bool = True):
        """Synchronous driver: submit each request in arrival order,
        honoring deadlines between arrivals, and drain at stream end.
        Returns results ordered by completion."""
        requests = list(requests)
        if warmup and not self.metrics.warmed:
            self.warmup(requests)
        results = []
        for req in requests:
            results += self.submit(req)
            results += self.poll()
        results += self.drain()
        return results
