"""Streaming serving engine: shape-bucketed micro-batching over the
constrained-ranking online path, with async double-buffered execution.

The unit of work is one RankRequest — one user's candidate utilities,
constraint attributes/thresholds, slot count, and either precomputed
shadow prices (lam) or the covariate vector X for an attached lambda
predictor. Requests stream in with heterogeneous geometry (m1, m2, K)
from heterogeneous upstream recommenders; the engine:

  1. maps each request to a shape Bucket (repro.serving.buckets),
     mints its RankFuture, and appends it to that bucket's queue;
  2. flushes a queue when it reaches the bucket's micro-batch capacity
     (capacity flush) or when its oldest request has waited max_wait_ms
     (deadline flush, checked by `poll`), or on `drain`;
  3. SUBMISSION SIDE (the caller's thread): assembles the flushed batch
     into a recycled StagingRing host buffer and dispatches it through
     ONE cached, pre-warmed jit executable per bucket — the existing
     online path (core.ranking.rank_given_lambda /
     kernels.ops.fused_rank / core.serving_dist.rank_distributed when
     a mesh is present) — with the big staging buffers donated to the
     runtime. Dispatch is asynchronous: the jit call returns device
     futures immediately and the submission side moves on to the next
     batch;
  4. COMPLETION SIDE (the pipeline worker thread): while the device
     executes batch N+1, the worker blocks on batch N's device→host
     transfer (GIL released), stamps completion, recycles N's staging
     buffers, and marks each of N's RankFutures done. Per-row
     unpadding to the request's real geometry is Python work, so it
     runs lazily on the consuming thread — future.result() or the
     collect path behind submit/poll/drain — never on the worker.

Steady state therefore never recompiles (the jit cache is the bucket
lattice, populated by `warmup` — the only place `block_until_ready`
survives), never pays per-request dispatch (amortized over the
micro-batch), and never serializes host assembly against device
execution (the sole job of the old blocking `rank()` call, retired in
favor of futures). `pipeline_depth` bounds the in-flight window —
depth 1 (the default) is classic double buffering: one batch
materializing while the next is assembled and dispatched; depth 0
recovers the synchronous single-threaded engine (same results, no
overlap), which is what the sync column of
benchmarks/latency_serve.py measures and what the equivalence tests
in tests/test_serving_pipeline.py compare against.

See docs/serving.md for timelines and backpressure semantics, and
docs/api.md for the public API.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.ranking import RankingOutput, rank_given_lambda
from repro.serving.buckets import (
    Bucket,
    assemble_batch,
    bucket_for,
    fill_staging,
    fill_stats,
    unpad_result,
)
from repro.serving.metrics import EngineMetrics
from repro.serving.pipeline import (
    ExecutionPipeline,
    PendingBatch,
    RankFuture,
    StagingRing,
)

LAM_TAG = "_lam"   # requests that carry shadow prices directly


@dataclass
class RankRequest:
    """One user's ranking problem. Arrays are host (numpy) payloads —
    the engine owns staging/padding and device transfer."""

    rid: int
    u: np.ndarray                     # (m1,) candidate utilities
    a: np.ndarray                     # (K, m1) constraint attributes
    b: np.ndarray                     # (K,) exposure thresholds
    m2: int                           # slots to fill (m2 <= m1)
    lam: np.ndarray | None = None     # (K,) shadow prices, if precomputed
    X: np.ndarray | None = None      # (d,) covariates for the predictor
    tag: str = LAM_TAG                # predictor/arch affinity
    gamma: np.ndarray | None = None  # (m2,) slot discounts; default DCG

    def __post_init__(self):
        if self.lam is None and self.X is None:
            raise ValueError(f"request {self.rid}: need lam or X")
        if self.m2 > self.u.shape[0]:
            raise ValueError(f"request {self.rid}: m2 > m1")


@dataclass
class RankResult:
    rid: int
    perm: np.ndarray                  # (m2,) item indices by slot
    utility: float
    exposure: np.ndarray              # (K,)
    compliant: bool
    bucket: str
    latency_ms: float                 # enqueue -> result materialized
    wait_ms: float                    # enqueue -> batch launch


@dataclass
class _PredictorEntry:
    predictor: Any                    # pytree with .predict(X) -> (n, K)
    d_cov: int
    K: int


class ServingEngine:
    """Shape-bucketed micro-batching executor for ranking requests.

    executor: 'xla'   — rank_given_lambda (default; the jnp hot path)
              'fused' — kernels.ops.fused_rank (Pallas on TPU,
                        interpret-mode on CPU)
              'dist'  — core.serving_dist.rank_distributed on `mesh`
                        (candidate axis sharded; requires mesh)

    pipeline_depth: how many micro-batches the submission side may run
    ahead of the one currently materializing. 1 (default) is classic
    double buffering — batch N+1 is assembled and dispatched while
    batch N's outputs transfer back — and measures best on CPU, where
    deeper windows make XLA execute batches concurrently and thrash
    the cores; on an accelerator backend a deeper window can hide
    longer transfer tails. The submission side blocks (backpressure)
    once the window is full. 0 disables the pipeline: every flush
    dispatches, materializes, and resolves inline on the calling
    thread — bitwise the same results, strictly serial timing.
    """

    def __init__(
        self,
        *,
        max_batch: int = 32,
        max_wait_ms: float = 2.0,
        eps: float = 1e-4,
        executor: str = "xla",
        mesh=None,
        donate: bool | None = None,
        pipeline_depth: int = 1,
        clock: Callable[[], float] = time.perf_counter,
    ):
        if executor not in ("xla", "fused", "dist"):
            raise ValueError(f"unknown executor {executor!r}")
        if executor == "dist" and mesh is None:
            raise ValueError("executor='dist' needs a mesh")
        if pipeline_depth < 0:
            raise ValueError(f"pipeline_depth must be >= 0, got "
                             f"{pipeline_depth}")
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)
        self.eps = float(eps)
        self.executor = executor
        self.mesh = mesh
        if donate is None:  # CPU ignores donation (and warns); skip there
            donate = jax.default_backend() != "cpu"
        self.donate = bool(donate)
        self.pipeline_depth = int(pipeline_depth)
        self.clock = clock
        self.metrics = EngineMetrics()
        self._predictors: dict[str, _PredictorEntry] = {}
        self._exec: dict[Bucket, Callable] = {}
        # Pallas kernel launches per bucket-executable invocation
        # (kernels.ops.kernel_launch_count of the bucket's route) —
        # what metrics.kernel_launches charges each flushed batch.
        self._kernel_launches: dict[Bucket, int] = {}
        self._queues: dict[Bucket, list] = {}
        self._rings: dict[Bucket, StagingRing] = {}
        self._warmed: set[Bucket] = set()
        self._in_warmup = False           # re-warm compiles aren't violations
        self._retired_sync: list = []     # sync-mode batches awaiting collect
        self._pipeline = (ExecutionPipeline(depth=self.pipeline_depth)
                          if self.pipeline_depth > 0 else None)

    # -- predictors ---------------------------------------------------------

    def register_predictor(self, tag: str, predictor: Any, *, d_cov: int) -> None:
        """Attach a fitted lambda predictor under `tag`; requests with
        X and this tag get lam predicted inside the bucket executable
        (one dispatch for predict + rank)."""
        if tag == LAM_TAG:
            raise ValueError(f"{LAM_TAG!r} is reserved for raw-lam requests")
        probe = predictor.predict(jnp.zeros((1, d_cov), jnp.float32))
        self._predictors[tag] = _PredictorEntry(
            predictor=predictor, d_cov=int(d_cov), K=int(probe.shape[-1]))

    # -- bucketing ----------------------------------------------------------

    def bucket_of(self, req: RankRequest) -> Bucket:
        tag = LAM_TAG if req.lam is not None else req.tag
        K = req.a.shape[0]
        if tag != LAM_TAG:
            if tag not in self._predictors:
                raise KeyError(f"no predictor registered for tag {tag!r}")
            K_pred = self._predictors[tag].K
            if K > K_pred:
                # the predictor cannot price constraints it was not fit
                # for; serving them with lam=0 would silently ignore them.
                raise ValueError(
                    f"request {req.rid}: {K} constraints but predictor "
                    f"{tag!r} emits only {K_pred} shadow prices")
            # the bucket tier must hold every predicted entry; extra
            # predicted entries beyond the request's K hit zero a-rows.
            K = K_pred
        return bucket_for(m1=req.u.shape[0], m2=req.m2, K=K, tag=tag,
                          batch=self.max_batch)

    # -- executables --------------------------------------------------------

    def _rank_fn(self, bucket: Bucket):
        """The bucket's rank body over already-padded device arrays."""
        m2, eps = bucket.m2, self.eps
        if self.executor == "dist":
            mesh = self.mesh
            from repro.core.serving_dist import rank_distributed

            def rank(u, a, b, lam, gamma):
                return rank_distributed(mesh, u, a, b, lam, gamma,
                                        m2=m2, eps=eps)
        elif self.executor == "fused":
            # One fused rank+audit kernel: utility/exposure/compliance are
            # computed in VMEM at the flush step — no post-kernel gather
            # or einsum ever reads u/a again (kernels/fused_rank.py).
            from repro.kernels.ops import rank_audited

            def rank(u, a, b, lam, gamma):
                return rank_audited(u, a, b, lam, gamma, m2=m2, eps=eps)
        else:
            rank = partial(rank_given_lambda, m2=m2, eps=eps)
        return rank

    def _build_executor(self, bucket: Bucket) -> Callable:
        """One fresh jit wrapper per bucket: its compile cache holds
        exactly one entry, so `jit_cache_sizes` exposes recompiles."""
        from repro.kernels.ops import kernel_launch_count

        predictor = (None if bucket.tag == LAM_TAG
                     else self._predictors[bucket.tag].predictor)
        self._kernel_launches[bucket] = (
            kernel_launch_count(predictor, bucket.m2)
            if self.executor == "fused" else 0)
        rank = self._rank_fn(bucket)
        donate = (2, 3) if self.donate else ()
        if bucket.tag == LAM_TAG:

            def fn(b, gamma, u, a, lam):
                return rank(u, a, b, lam, gamma)

            return jax.jit(fn, donate_argnums=donate)

        entry = self._predictors[bucket.tag]
        pred = entry.predictor      # closed over: baked into the executable
        if self.executor == "dist":
            # the mesh-sharded rank body keeps its own predict stage
            # (still inside this one jit executable)
            pad_k = bucket.K - entry.K

            def fn(b, gamma, u, a, X):
                lam = pred.predict(X)                   # (B, K_pred)
                lam = jnp.pad(lam, ((0, 0), (0, pad_k)))
                return rank(u, a, b, lam, gamma)

            return jax.jit(fn, donate_argnums=donate)

        # Predictor-tagged buckets route through the single-sweep
        # dispatcher (kernels.ops.predict_rank_audited): predict + rank
        # + audit lower to ONE device program per flushed batch — for
        # the fused executor the affine families fold λ̂ into the rank
        # kernel's VMEM prologue and KNN fuses its weighting into the
        # db sweep; the xla executor runs the same dispatcher's
        # two-stage XLA body (use_kernel=False), still one executable.
        # metrics.executable_calls counts the contract.
        from repro.kernels.ops import predict_rank_audited

        m2, eps = bucket.m2, self.eps
        use_kernel = None if self.executor == "fused" else False

        def fn(b, gamma, u, a, X):
            return predict_rank_audited(X, pred, u, a, b, gamma,
                                        m2=m2, eps=eps,
                                        use_kernel=use_kernel)

        return jax.jit(fn, donate_argnums=donate)

    def _executor_for(self, bucket: Bucket) -> Callable:
        fn = self._exec.get(bucket)
        if fn is None:
            fn = self._exec[bucket] = self._build_executor(bucket)
            self.metrics.on_compile(in_warmup=self._in_warmup)
        return fn

    def warmup(self, sample) -> dict:
        """Compile every bucket reachable from `sample` (RankRequests or
        Buckets) by executing one phantom batch per bucket. After this,
        any stream inside the lattice runs with zero recompiles. This
        is the only place the engine blocks on the device directly."""
        buckets = {r if isinstance(r, Bucket) else self.bucket_of(r)
                   for r in sample}
        self._in_warmup = True
        try:
            for bucket in sorted(buckets):
                fn = self._executor_for(bucket)
                jax.block_until_ready(
                    self._call(fn, bucket, assemble_batch([], bucket,
                               d_cov=self._dcov(bucket))).perm)
                self._warmed.add(bucket)
        finally:
            self._in_warmup = False
        self.metrics.warmed = True
        return {"buckets": [b.name for b in sorted(buckets)],
                "compiles": self.metrics.compiles}

    def _dcov(self, bucket: Bucket) -> int | None:
        if bucket.tag == LAM_TAG:
            return None
        return self._predictors[bucket.tag].d_cov

    def _call(self, fn, bucket: Bucket, staged: dict) -> RankingOutput:
        if bucket.tag == LAM_TAG:
            return fn(staged["b"], staged["gamma"], staged["u"], staged["a"],
                      staged["lam"])
        return fn(staged["b"], staged["gamma"], staged["u"], staged["a"],
                  staged["X"])

    def jit_cache_sizes(self) -> dict[str, int]:
        """Per-bucket jit compile-cache sizes (1 = exactly the warmed
        executable; >1 = something retraced). The no-recompile test
        asserts every value stays 1 across a mixed-shape stream."""
        return {b.name: fn._cache_size() for b, fn in self._exec.items()}

    # -- submission side: queueing / flushing -------------------------------

    def submit(self, req: RankRequest, now: float | None = None):
        """Enqueue; returns whatever results have retired so far (the
        capacity-flushed batch itself, when the pipeline is enabled,
        retires asynchronously — collect it from later submit/poll
        calls or from `drain`)."""
        self._enqueue(req, now)
        return self._collect()

    def submit_future(self, req: RankRequest,
                      now: float | None = None) -> RankFuture:
        """Enqueue and return this request's RankFuture. The future
        resolves when the request's micro-batch retires; completed
        results also keep flowing through submit/poll/drain, so mixing
        the two styles is safe (same underlying results objects)."""
        return self._enqueue(req, now)

    def _enqueue(self, req: RankRequest, now: float | None) -> RankFuture:
        now = self.clock() if now is None else now
        bucket = self.bucket_of(req)
        self.metrics.on_submit(bucket, known=bucket in self._warmed)
        fut = RankFuture(req.rid, bucket.name)
        q = self._queues.setdefault(bucket, [])
        q.append((req, now, fut))
        if len(q) >= bucket.batch:
            self._flush_bucket(bucket, trigger="capacity")
        return fut

    def poll(self, now: float | None = None):
        """Deadline check: flush every queue whose oldest request has
        waited longer than max_wait_ms; returns results retired so far."""
        now = self.clock() if now is None else now
        for bucket in list(self._queues):
            q = self._queues[bucket]
            if q and (now - q[0][1]) * 1e3 >= self.max_wait_ms:
                self._flush_bucket(bucket, trigger="deadline")
        return self._collect()

    def drain(self):
        """Flush every queue and wait for all in-flight batches to
        retire (stream end / graceful shutdown barrier). Returns every
        result not yet collected."""
        for bucket in list(self._queues):
            if self._queues[bucket]:
                self._flush_bucket(bucket, trigger="drain")
        if self._pipeline is not None:
            results = []
            for pending in self._pipeline.flush():
                results += pending.results()
            return results
        return self._collect()

    def close(self) -> None:
        """Graceful shutdown: drain in-flight work and stop the
        pipeline worker. The engine rejects flushes afterwards."""
        if self._pipeline is not None:
            self._pipeline.flush()
            self._pipeline.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _collect(self):
        """Build results for every batch retired since the last call.
        Runs on the caller's thread — the Python-heavy unpadding
        deliberately lives here, not on the pipeline worker, so it
        overlaps device execution instead of starving it via the GIL."""
        if self._pipeline is not None:
            batches = self._pipeline.collect()
        else:
            batches, self._retired_sync = self._retired_sync, []
        results = []
        for pending in batches:
            results += pending.results()
        return results

    def _ring_for(self, bucket: Bucket) -> StagingRing:
        ring = self._rings.get(bucket)
        if ring is None:
            # the in-flight window holds pipeline_depth queued batches
            # plus the one materializing; one more slot keeps assembly
            # of the next batch from ever waiting on a buffer.
            ring = self._rings[bucket] = StagingRing(
                bucket, d_cov=self._dcov(bucket),
                depth=self.pipeline_depth + 2)
        return ring

    def _flush_bucket(self, bucket: Bucket, *, trigger: str) -> None:
        entries = self._queues[bucket]
        self._queues[bucket] = []
        reqs = [r for r, _, _ in entries]
        ring = self._ring_for(bucket)
        fn = self._executor_for(bucket)
        t0 = self.clock()
        staged = fill_staging(ring.acquire(), reqs, bucket)
        t_launch = self.clock()
        out = self._call(fn, bucket, staged)    # async dispatch: no block
        t1 = self.clock()
        # the single-dispatch contract: this _call was the batch's ONE
        # executable invocation — predictor buckets included (λ̂ is
        # predicted inside the executable, never as a separate program)
        # — and it contained the route's static kernel-launch count
        # (ONE for every fused-executor kernel bucket, KNN included
        # since the single-grid predict+rank+audit kernel).
        self.metrics.on_executable_call(self._kernel_launches[bucket])
        pending = PendingBatch(
            bucket=bucket, entries=[(r, t) for r, t, _ in entries],
            futures=[f for _, _, f in entries], out=out, staged=staged,
            ring=ring, t_launch=t_launch, trigger=trigger,
            materialize=self._materialize_batch, build=self._build_result,
            assembly_ms=(t_launch - t0) * 1e3,
            dispatch_ms=(t1 - t_launch) * 1e3)
        if self._pipeline is not None:
            self._pipeline.submit(pending)      # may block: backpressure
        else:
            pending.finish()
            self._retired_sync.append(pending)
        self.metrics.on_dispatch(
            bucket, len(reqs), trigger, fill_stats(reqs, bucket),
            assembly_ms=pending.assembly_ms, dispatch_ms=pending.dispatch_ms,
            depth=pending.depth_at_dispatch, t_now=t_launch)

    # -- completion side ----------------------------------------------------

    def _materialize_batch(self, pending: PendingBatch) -> None:
        """Block until one batch's outputs reach the host. Runs on the
        pipeline worker (async mode) or inline (sync mode); this is the
        ONLY blocking step on the completion side — the GIL is released
        while waiting, so the submission thread keeps assembling.

        One bulk device->host copy per output; per-request unpadding is
        then pure numpy (slicing jax arrays row-by-row would dispatch —
        and on first touch compile — one tiny program per slice)."""
        out = pending.out
        pending.out = RankingOutput(
            perm=np.asarray(out.perm), utility=np.asarray(out.utility),
            exposure=np.asarray(out.exposure),
            compliant=np.asarray(out.compliant), lam=out.lam)
        pending.t_done = self.clock()
        self.metrics.on_retire((pending.t_done - pending.t_launch) * 1e3,
                               pending.t_done)
        if pending.ring is not None:            # inputs consumed: recycle
            pending.ring.release(pending.staged)
            pending.staged = None

    def _build_result(self, pending: PendingBatch, i: int) -> RankResult:
        """Unpad row `i` into its RankResult. Runs lazily, exactly once
        per row (memoized by the row's RankFuture), on whichever
        consumer thread first asks — the engine's collect path or a
        direct future.result() call."""
        req, t_enq = pending.entries[i]
        perm, utility, exposure, compliant = unpad_result(pending.out, i, req)
        self.metrics.on_result((pending.t_done - t_enq) * 1e3,
                               (pending.t_launch - t_enq) * 1e3, compliant)
        return RankResult(
            rid=req.rid, perm=perm, utility=utility, exposure=exposure,
            compliant=compliant, bucket=pending.bucket.name,
            latency_ms=(pending.t_done - t_enq) * 1e3,
            wait_ms=(pending.t_launch - t_enq) * 1e3)

    # -- convenience driver -------------------------------------------------

    def serve_stream(self, requests, *, warmup: bool = True):
        """Synchronous driver: submit each request in arrival order,
        honoring deadlines between arrivals, and drain at stream end.
        Returns results ordered by completion (retirement order)."""
        requests = list(requests)
        if warmup and not self.metrics.warmed:
            self.warmup(requests)
        results = []
        for req in requests:
            results += self.submit(req)
            results += self.poll()
        results += self.drain()
        return results
