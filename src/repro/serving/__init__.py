"""Streaming serving engine: shape-bucketed micro-batching for the
paper's online constrained-ranking stage (see engine.py for the design).
"""

from repro.serving.buckets import (
    Bucket,
    K_TIERS,
    MIN_M1,
    MIN_M2,
    NEG_FILL,
    assemble_batch,
    bucket_for,
    ceil_pow2,
    k_tier,
    unpad_result,
)
from repro.serving.engine import (
    LAM_TAG,
    RankRequest,
    RankResult,
    ServingEngine,
)
from repro.serving.metrics import EngineMetrics
from repro.serving.traffic import DEFAULT_MIX, Scenario, make_request, make_stream

__all__ = [
    "Bucket", "K_TIERS", "MIN_M1", "MIN_M2", "NEG_FILL",
    "assemble_batch", "bucket_for", "ceil_pow2", "k_tier", "unpad_result",
    "LAM_TAG", "RankRequest", "RankResult", "ServingEngine",
    "EngineMetrics",
    "DEFAULT_MIX", "Scenario", "make_request", "make_stream",
]
