"""Streaming serving engine: shape-bucketed micro-batching with async
double-buffered execution for the paper's online constrained-ranking
stage (see engine.py and pipeline.py for the design; docs/serving.md
for the full semantics).
"""

from repro.serving.buckets import (
    Bucket,
    DEFAULT_AUTOTUNE_PATH,
    K_TIERS,
    MIN_M1,
    MIN_M2,
    NEG_FILL,
    alloc_staging,
    assemble_batch,
    bucket_for,
    ceil_pow2,
    fill_staging,
    geometry_key,
    k_tier,
    load_autotune_table,
    resolve_autotune,
    save_autotune_table,
    unpad_result,
)
from repro.serving.lattice import (
    DEFAULT_HISTOGRAM_PATH,
    Lattice,
    LatticeLane,
    ShapeHistogram,
    TroughDetector,
    optimize_lattice,
    padding_waste,
)
from repro.serving.admission import (
    SHED_RUNG,
    AdmissionController,
    AdmissionDecision,
)
from repro.serving.engine import (
    DEFAULT_BUDGET_S,
    LAM_TAG,
    RankRequest,
    RankResult,
    ServingEngine,
    Shed,
)
from repro.serving.faults import (
    FaultInjector,
    FaultPlan,
    ReplicaCrash,
    ReplicaFaults,
)
from repro.serving.fleet import FleetMetrics, FleetRouter, Replica
from repro.serving.health import (
    DEAD,
    HEALTHY,
    RECOVERING,
    SUSPECT,
    HealthConfig,
    ReplicaHealth,
    backoff_s,
)
from repro.serving.metrics import EngineMetrics
from repro.serving.pipeline import (
    ExecutionPipeline,
    PendingBatch,
    RankFuture,
    StagingRing,
)
from repro.serving.refresh import (
    RefreshLane,
    dual_refresh_targets,
    knn_ring_update,
    ridge_refresh,
    running_mean_update,
)
from repro.serving.traffic import (
    DEFAULT_MIX,
    Scenario,
    make_drift_stream,
    make_request,
    make_stream,
    poisson_arrivals,
    serve_open_loop,
)

__all__ = [
    "Bucket", "K_TIERS", "MIN_M1", "MIN_M2", "NEG_FILL",
    "alloc_staging", "assemble_batch", "bucket_for", "ceil_pow2",
    "fill_staging", "k_tier", "resolve_autotune", "unpad_result",
    "DEFAULT_HISTOGRAM_PATH", "Lattice", "LatticeLane",
    "ShapeHistogram", "TroughDetector", "optimize_lattice",
    "padding_waste",
    "SHED_RUNG", "AdmissionController", "AdmissionDecision",
    "DEFAULT_BUDGET_S", "LAM_TAG", "RankRequest", "RankResult",
    "ServingEngine", "Shed",
    "FaultInjector", "FaultPlan", "ReplicaCrash", "ReplicaFaults",
    "FleetMetrics", "FleetRouter", "Replica",
    "DEAD", "HEALTHY", "RECOVERING", "SUSPECT",
    "HealthConfig", "ReplicaHealth", "backoff_s",
    "EngineMetrics",
    "ExecutionPipeline", "PendingBatch", "RankFuture", "StagingRing",
    "RefreshLane", "dual_refresh_targets", "knn_ring_update",
    "ridge_refresh", "running_mean_update",
    "DEFAULT_MIX", "Scenario", "make_drift_stream", "make_request",
    "make_stream", "poisson_arrivals", "serve_open_loop",
]
