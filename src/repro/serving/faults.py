"""Deterministic seed-driven fault injection for the serving fleet.

A fault tolerance claim is only as good as the failures it was tested
against, and ad-hoc monkeypatching (the style the pipeline tests use
for single-engine faults) doesn't compose into a fleet-wide scenario
you can replay. This module makes every failure mode a *plan*: a
`FaultPlan` maps replica names to `ReplicaFaults`, `FaultPlan.chaos`
derives the canonical five-fault scenario deterministically from a
seed, and a `FaultInjector` attached to a replica's engine executes
the plan at the same seams the real failures would hit:

  crash-at-batch-k      the replica's k-th post-warmup micro-batch
                        flush raises ReplicaCrash from inside the
                        bucket executable — exactly where a device
                        reset or OOM surfaces — and the replica stays
                        down (every later call raises too) until the
                        supervisor restarts it.
  heartbeat blackhole   heartbeats in [blackhole_after, blackhole_until)
                        (tick indices) are silently dropped: the
                        replica serves fine but looks SUSPECT, then
                        DEAD — the partition/GC-pause failure mode, and
                        the one that exercises hedging + dedup rather
                        than retry.
  slow replica          every flush sleeps slow_ms first: a wedged-but-
                        alive replica that heartbeats on time and blows
                        every latency budget — caught by the lag EWMA,
                        not the heartbeat deadline.
  poisoned swap         the replica's n-th refresh publishes non-finite
                        state; the engine's swap validation must refuse
                        it and keep serving (and checkpointing) the
                        last good generation.
  partial-drain kill    the replica crashes on the first flush of its
                        drain — queued-but-unflushed requests must be
                        handed off to another replica, not orphaned.

The injector never reaches around the engine's machinery: crashes
raise through `_flush_bucket`'s existing failure path (futures fail,
staging buffers recycle, generations unpin), so what the chaos tests
prove is the recovery behavior of the REAL code, not of a mock.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field, replace

import numpy as np

__all__ = ["ReplicaCrash", "ReplicaFaults", "FaultPlan", "FaultInjector"]


class ReplicaCrash(RuntimeError):
    """A replica process died mid-operation. Fatal for the replica: its
    health machine goes straight to DEAD and only a supervised restart
    brings it back."""


@dataclass(frozen=True)
class ReplicaFaults:
    """The faults scheduled for ONE replica. All indices count
    post-warmup events on that replica, so a plan replays identically
    whenever the request stream (and therefore flush order) does."""

    crash_at_batch: int | None = None   # k-th flush raises ReplicaCrash
    blackhole_after: int | None = None  # heartbeat ticks >= this dropped...
    blackhole_until: int | None = None  # ...until this tick (None = forever)
    slow_ms: float = 0.0                # injected latency per flush
    poison_swap_at: int | None = None   # n-th refresh publishes NaNs
    kill_during_drain: bool = False     # crash on the first drain flush

    def any(self) -> bool:
        return (self.crash_at_batch is not None
                or self.blackhole_after is not None
                or self.slow_ms > 0.0
                or self.poison_swap_at is not None
                or self.kill_during_drain)


@dataclass(frozen=True)
class FaultPlan:
    """A replayable fleet-wide failure scenario: {replica_name:
    ReplicaFaults} plus the seed it was derived from."""

    replicas: dict
    seed: int = 0

    def faults_for(self, name: str) -> ReplicaFaults:
        return self.replicas.get(name, ReplicaFaults())

    @staticmethod
    def none(names) -> "FaultPlan":
        return FaultPlan(replicas={n: ReplicaFaults() for n in names})

    @staticmethod
    def chaos(names, *, seed: int = 0, slow_ms: float = 5.0) -> "FaultPlan":
        """The canonical five-fault scenario over >= 3 replicas, every
        parameter drawn from `seed`:

          names[0]  crash-at-batch-k (k in [2, 5)) AND, once restarted,
                    a kill on the first flush of its drain (the
                    partial-drain kill);
          names[1]  heartbeat blackhole over a tick window, plus a
                    poisoned swap on its first post-blackhole refresh;
          names[2]  slow replica (`slow_ms` per flush).

        Replicas beyond the third stay clean — they are the capacity
        the failover story needs.
        """
        names = list(names)
        if len(names) < 3:
            raise ValueError(
                f"the chaos plan needs >= 3 replicas, got {len(names)}")
        rng = np.random.default_rng(seed)
        crash_k = int(rng.integers(2, 5))
        hole_at = int(rng.integers(2, 5))
        hole_len = int(rng.integers(4, 8))
        poison_at = int(rng.integers(1, 3))
        replicas = {n: ReplicaFaults() for n in names}
        replicas[names[0]] = ReplicaFaults(
            crash_at_batch=crash_k, kill_during_drain=True)
        replicas[names[1]] = ReplicaFaults(
            blackhole_after=hole_at, blackhole_until=hole_at + hole_len,
            poison_swap_at=poison_at)
        replicas[names[2]] = ReplicaFaults(slow_ms=float(slow_ms))
        return FaultPlan(replicas=replicas, seed=seed)


@dataclass
class _WrappedExec:
    """One bucket executable under injection. Crash/slow decisions are
    made by the shared injector so the batch counter spans buckets —
    'crash at batch k' means the replica's k-th flush, whichever
    bucket it lands in."""

    fn: object
    injector: "FaultInjector"

    def __call__(self, *args):
        self.injector._before_flush()
        return self.fn(*args)

    # the engine's no-recompile assertions read per-bucket jit cache
    # sizes through the executor table; forward to the real jit fn.
    def _cache_size(self):
        return self.fn._cache_size()


@dataclass
class FaultInjector:
    """Executes one replica's ReplicaFaults at the engine's seams.
    Attach with `wrap_engine(engine)` AFTER warmup (warmup flushes are
    not traffic); re-attach after a restart only if the plan says the
    fault recurs — the chaos plan's faults are one-shot, so a restarted
    replica comes back clean."""

    faults: ReplicaFaults
    name: str = "replica"
    sleep: object = time.sleep
    flushes: int = 0                    # post-warmup flushes seen
    heartbeat_ticks: int = 0
    refreshes: int = 0
    crashed: bool = False
    draining: bool = False
    drain_killed: bool = False
    wrapped: dict = field(default_factory=dict)

    # -- attachment ----------------------------------------------------------

    def wrap_engine(self, engine) -> None:
        """Interpose on every warmed bucket executable of `engine`."""
        for bucket, fn in list(engine._exec.items()):
            if isinstance(fn, _WrappedExec):      # idempotent
                continue
            wrapped = _WrappedExec(fn=fn, injector=self)
            engine._exec[bucket] = wrapped
            self.wrapped[bucket] = wrapped

    # -- seams ---------------------------------------------------------------

    def _before_flush(self) -> None:
        if self.crashed:
            raise ReplicaCrash(
                f"{self.name}: call into a crashed replica")
        if self.faults.kill_during_drain and self.draining \
                and not self.drain_killed:
            self.drain_killed = True
            self.crashed = True
            raise ReplicaCrash(f"{self.name}: killed mid-drain")
        i = self.flushes
        self.flushes += 1
        if self.faults.slow_ms > 0.0:
            self.sleep(self.faults.slow_ms / 1e3)
        if self.faults.crash_at_batch is not None \
                and i == self.faults.crash_at_batch:
            self.crashed = True
            raise ReplicaCrash(
                f"{self.name}: crashed at batch {i} (planned)")

    def heartbeat_delivered(self) -> bool:
        """One heartbeat tick: True if it reaches the router, False if
        the replica is crashed or the tick falls inside the blackhole
        window."""
        i = self.heartbeat_ticks
        self.heartbeat_ticks += 1
        if self.crashed:
            return False
        after = self.faults.blackhole_after
        if after is not None and i >= after:
            until = self.faults.blackhole_until
            if until is None or i < until:
                return False
        return True

    def poison_state(self, state: dict) -> dict:
        """Applied to each refresh's candidate state before publish: on
        the planned refresh index, every float leaf is replaced with
        NaNs of the same shape/dtype — structurally valid, so only the
        engine's finiteness validation stands between it and serving."""
        i = self.refreshes
        self.refreshes += 1
        if self.faults.poison_swap_at is None \
                or i != self.faults.poison_swap_at:
            return state

        def poison(leaf):
            arr = np.asarray(leaf)
            if np.issubdtype(arr.dtype, np.floating):
                return np.full_like(arr, np.nan)
            return arr
        import jax
        return jax.tree.map(poison, dict(state))

    def restore(self) -> None:
        """Post-restart reset: the restarted incarnation serves clean
        (the chaos plan's faults are one-shot per replica), except that
        kill_during_drain stays armed until it has fired — the plan
        schedules it for the restarted incarnation's drain."""
        self.crashed = False
        self.draining = False
        self.wrapped = {}
        self.faults = replace(
            self.faults, crash_at_batch=None, blackhole_after=None,
            blackhole_until=None, slow_ms=0.0, poison_swap_at=None)
