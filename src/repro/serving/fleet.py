"""Fault-tolerant replica fleet: health-checked consistent-hash routing,
failover with hedged retries, and supervised restart from epoch
checkpoints.

One ServingEngine is a single point of failure: a crash mid-stream
orphans every queued request, and a wedged replica silently blows the
50 ms budget for its whole bucket subset. The fleet layer runs N
replicas (each a full ServingEngine + optional RefreshLane built by a
caller-supplied factory) and routes shape buckets across them with a
consistent-hash ring, so each replica warms only its lattice subset
plus the subset it backs up — the no-recompile contract holds per
replica, and losing one replica moves only ~1/N of the keyspace.

Decision ladder, per request (see docs/serving.md §Fleet):

  1. route   — ring owners of the request's HOME bucket, walked in
               ring order, skipping non-routable (DEAD/RECOVERING)
               replicas;
  2. hedge   — primary is SUSPECT (stale heartbeat, lag EWMA over
               threshold, or a recent failure): the request is ALSO
               submitted to the next routable owner; first completion
               settles the fleet future (RankFuture first-wins), the
               loser's result is deduped by rid;
  3. failover— primary is DEAD or the attempt failed: re-route to the
               next candidate; the dead replica's queued-but-unflushed
               requests are evicted via engine.handoff_queued and
               re-routed the same way (in-flight batches retire
               normally — the pipeline owns them);
  4. restart — a DEAD replica is restarted under supervision with
               capped-exponential + deterministically-jittered backoff
               (health.backoff_s): fresh factory engine, predictor
               state restored from per-epoch checkpoints
               (CheckpointStore.load_predictor_epoch → last-good λ̂,
               never cold), bucket subset re-warmed, then
               mark_recovered.

Threading contract (the one that matters): completion callbacks run on
replica pipeline-worker threads. A callback that resubmitted to
another engine could deadlock against that engine's backpressure
(worker blocked in our callback while the submission it is waiting on
blocks on the pipeline window). So callbacks only settle fleet
futures and push rids onto a retry deque; every engine call
(submit/flush/drain/restart) happens on the router caller's thread,
via _drain_retries from submit/poll/tick/drain.

Chaos: pass a faults.FaultPlan and every replica gets a FaultInjector
(crash-at-batch-k, heartbeat blackhole, slow-replica latency,
poisoned swap, partial-drain kill) — every failure mode above becomes
a replayable, seed-driven test (tests/test_fleet.py, and the `fleet`
gate in benchmarks/latency_serve.py).
"""

from __future__ import annotations

import hashlib
import threading
import time
from bisect import bisect_right
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from repro.serving.buckets import Bucket
from repro.serving.engine import RankRequest, Shed
from repro.serving.lattice import Lattice
from repro.serving.faults import FaultInjector, FaultPlan, ReplicaCrash
from repro.serving.health import (
    DEAD,
    SUSPECT,
    HealthConfig,
    ReplicaHealth,
    backoff_s,
)
from repro.serving.pipeline import RankFuture

__all__ = ["FleetRouter", "FleetMetrics", "Replica"]


def _ring_hash(key: str) -> int:
    # blake2b, not Python hash(): hash() is salted per process, and the
    # ring must assign the same owners in every replay of a chaos plan.
    return int.from_bytes(
        hashlib.blake2b(key.encode(), digest_size=8).digest(), "big")


@dataclass
class FleetMetrics:
    """Cross-replica accounting. `submitted == served + sheds + lost`
    when the stream drains cleanly — and `lost` plus
    `orphaned_futures()` are asserted == 0 by the chaos tests: every
    failure mode re-routes, none drops."""

    submitted: int = 0
    served: int = 0
    sheds: int = 0
    lost: int = 0                     # futures failed after max_attempts
    failovers: int = 0                # sends to a non-primary owner
    hedges: int = 0                   # SUSPECT-triggered duplicate sends
    hedge_wins: int = 0               # hedged requests settled by either copy
    duplicates_deduped: int = 0       # loser completions dropped by rid
    retries: int = 0                  # failed attempts re-queued
    crashes: int = 0                  # fatal replica failures observed
    restarts: int = 0                 # supervised restarts completed
    restart_failures: int = 0         # restarts that themselves failed
    heartbeats_delivered: int = 0
    heartbeats_missed: int = 0

    def as_dict(self) -> dict:
        return {k: int(getattr(self, k)) for k in (
            "submitted", "served", "sheds", "lost", "failovers", "hedges",
            "hedge_wins", "duplicates_deduped", "retries", "crashes",
            "restarts", "restart_failures", "heartbeats_delivered",
            "heartbeats_missed")}


@dataclass
class Replica:
    """One fleet member: the live engine (+ optional RefreshLane), its
    health machine, its chaos injector, and its restart bookkeeping."""

    name: str
    index: int
    engine: Any
    lane: Any = None
    health: ReplicaHealth = None
    injector: FaultInjector | None = None
    warm_buckets: set = field(default_factory=set)
    crashed: bool = False
    restart_attempts: int = 0
    next_restart_at: float | None = None
    # per-restart {tag: epoch} restored from checkpoints — the chaos
    # tests assert the first restart resumed at the last-good epoch.
    restore_history: list = field(default_factory=list)
    # EngineMetrics of engines retired by restarts, so fleet-level
    # aggregation stays cumulative across restarts.
    retired_metrics: list = field(default_factory=list)

    @property
    def store(self):
        """The checkpoint store restarts restore from (the lane's)."""
        return getattr(self.lane, "checkpoint", None)


@dataclass
class _Pending:
    """One fleet-level request in flight: the caller's future plus the
    routing state its retries need."""

    req: RankRequest
    fut: RankFuture
    owners: list                      # ring-ordered replica indices
    tried: list = field(default_factory=list)
    attempts: int = 0
    hedged: bool = False


class FleetRouter:
    """Consistent-hash router over N ServingEngine replicas (module doc
    has the decision ladder and threading contract).

    factory(name) -> engine, or (engine, lane) when the replica runs a
    RefreshLane; the lane's `checkpoint` store (if any) is what a
    supervised restart restores predictor epochs from. The factory is
    called again on every restart — replicas are cattle.

    clock: drives health deadlines and restart backoff ONLY (engines
    keep their own clocks) — inject a frozen/step clock to make every
    transition replayable. heartbeat_interval_s gates the implicit
    tick from submit/poll; pass float('inf') and call tick() yourself
    for fully deterministic heartbeat indices (what the chaos plan's
    blackhole windows count).

    The router duck-types the engine's driver surface — submit /
    submit_future / poll / drain / observe_submission_lag / close — so
    serving.traffic.serve_open_loop and launch.serve drive a fleet and
    a single engine identically.
    """

    def __init__(self, factory: Callable[[str], Any], n_replicas: int = 3, *,
                 names=None, clock: Callable[[], float] = time.perf_counter,
                 health: HealthConfig | None = None, vnodes: int = 16,
                 replication: int = 1, hedging: bool = True,
                 auto_restart: bool = True, max_attempts: int | None = None,
                 backoff_base_s: float = 0.05, backoff_cap_s: float = 2.0,
                 heartbeat_interval_s: float = 0.05, seed: int = 0,
                 fault_plan: FaultPlan | None = None):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        if vnodes < 1:
            raise ValueError(f"vnodes must be >= 1, got {vnodes}")
        if names is None:
            names = [f"r{i}" for i in range(n_replicas)]
        names = [str(n) for n in names]
        if len(names) != n_replicas or len(set(names)) != n_replicas:
            raise ValueError(f"need {n_replicas} distinct names, got {names}")
        self.factory = factory
        self.clock = clock
        self.health_config = health or HealthConfig()
        self.vnodes = int(vnodes)
        # how many ring successors ALSO warm each home bucket's group
        # (1 = primary + first backup): a hedge or failover lands on a
        # replica that already compiled the bucket, so failure paths
        # never trip the no-recompile contract.
        self.replication = max(0, min(int(replication), n_replicas - 1))
        self.hedging = bool(hedging)
        self.auto_restart = bool(auto_restart)
        self.max_attempts = (3 * n_replicas if max_attempts is None
                             else int(max_attempts))
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.heartbeat_interval_s = float(heartbeat_interval_s)
        self.seed = int(seed)
        self.fault_plan = fault_plan
        self.metrics = FleetMetrics()
        now = self.clock()
        self.replicas: list[Replica] = []
        for i, name in enumerate(names):
            rep = self._spawn(name, i, now)
            self.replicas.append(rep)
        # vnode ring: sorted (hash, replica_index)
        points = []
        for i, name in enumerate(names):
            for v in range(self.vnodes):
                points.append((_ring_hash(f"{name}#{v}"), i))
        points.sort()
        self._ring_keys = [h for h, _ in points]
        self._ring_vals = [i for _, i in points]
        self._owner_cache: dict[str, list[int]] = {}
        self._lock = threading.Lock()
        self._pending: dict[int, _Pending] = {}
        self._retry: deque = deque()
        self._done: list = []
        self._last_tick = now
        self._warmed = False
        # fleet-wide adaptive lattice (rewarm_lattice): replicas always
        # flip TOGETHER to a common pinned epoch — routing keys off
        # replica 0's bucket_of, so a replica on a different lattice
        # would receive requests for corners it never warmed. Restarted
        # replicas are restored to this lattice after their re-warm.
        self._lattice: Lattice | None = None
        self._lattice_epoch = 0

    # -- construction / ring -------------------------------------------------

    def _spawn(self, name: str, index: int, now: float) -> Replica:
        made = self.factory(name)
        engine, lane = made if isinstance(made, tuple) else (made, None)
        health = ReplicaHealth(name=name, config=self.health_config,
                               last_heartbeat=now)
        injector = None
        if self.fault_plan is not None:
            injector = FaultInjector(self.fault_plan.faults_for(name), name)
            if lane is not None:
                # poisoned-swap seam: the injector NaN-fills the state
                # the lane is about to publish on the planned refresh.
                lane.publish_filter = (
                    lambda tag, state, inj=injector: inj.poison_state(state))
        return Replica(name=name, index=index, engine=engine, lane=lane,
                       health=health, injector=injector)

    def _owners(self, bucket_name: str) -> list[int]:
        """All replica indices in ring order starting at the bucket's
        hash point — position 0 is the primary, the rest the failover
        chain."""
        cached = self._owner_cache.get(bucket_name)
        if cached is not None:
            return cached
        n = len(self.replicas)
        start = bisect_right(self._ring_keys, _ring_hash(bucket_name))
        owners, seen = [], set()
        for k in range(len(self._ring_vals)):
            i = self._ring_vals[(start + k) % len(self._ring_vals)]
            if i not in seen:
                seen.add(i)
                owners.append(i)
                if len(owners) == n:
                    break
        self._owner_cache[bucket_name] = owners
        return owners

    def warmup(self, sample) -> dict:
        """Assign each home bucket's group (home + every degradation
        rung) to its primary and `replication` ring successors, then
        warm each replica on exactly its subset. Injectors arm AFTER
        warmup — fault batch counters index live flushes only."""
        template = self.replicas[0].engine
        groups: dict = {}
        for r in sample:
            if isinstance(r, Bucket):
                groups.setdefault(r, set()).add(r)
            else:
                home = template.bucket_of(r)
                group = {bk for _, bk in template._rung_buckets(r, home)}
                groups.setdefault(home, set()).update(group)
        for home, group in groups.items():
            owners = self._owners(home.name)
            for i in owners[:1 + self.replication]:
                self.replicas[i].warm_buckets.update(group)
        reports = {}
        for rep in self.replicas:
            reports[rep.name] = rep.engine.warmup(sorted(rep.warm_buckets))
            if rep.injector is not None:
                rep.injector.wrap_engine(rep.engine)
        self._warmed = True
        return reports

    def rewarm_lattice(self, new_lattice: Lattice) -> dict:
        """Fleet-wide adaptive-lattice re-warm: shadow-warm EVERY
        replica off its dispatch path, then flip them all to a common
        pinned epoch.

        Bucket→replica assignment is stable across lattice epochs by
        construction — ring ownership is a pure function of the bucket
        NAME (`_owners`), so corners shared between the old and new
        lattice keep their owners and only genuinely new corners get
        (deterministically) placed. Each replica shadow-warms only its
        subset: the new corners whose primary-or-backup it is, plus
        whatever its OWN traffic histogram says it needs (failover
        routes by the same ring, so `replication` backups suffice
        exactly as they do for warmup()).

        All shadow warms complete before ANY replica flips; a compile
        failure on one replica aborts the whole epoch with zero flips —
        the fleet keeps serving the last-good lattice everywhere.
        Returns {replica: shadow-warm report} plus the common epoch.
        """
        new_lattice.validate()
        # union of every replica's observed reachable set on the new
        # lattice, assigned to primaries + backups by stable name hash
        union: set[Bucket] = set()
        for rep in self.replicas:
            union |= rep.engine._lattice_buckets(new_lattice)
        subsets: dict[str, set[Bucket]] = {r.name: set()
                                           for r in self.replicas}
        for bucket in union:
            owners = self._owners(bucket.name)
            for i in owners[:1 + self.replication]:
                subsets[self.replicas[i].name].add(bucket)
        reports: dict[str, Any] = {}
        for rep in self.replicas:
            # phase 1 everywhere first: nothing flips until every
            # replica holds a warmed copy of its new subset
            reports[rep.name] = rep.engine.shadow_warm_lattice(
                new_lattice, sample=sorted(subsets[rep.name]))
        epoch = max(r.engine.lattice_epoch() for r in self.replicas) + 1
        for rep in self.replicas:
            rep.engine.swap_lattice(
                new_lattice, epoch=epoch,
                warm_ms=reports[rep.name]["warm_ms"])
            rep.warm_buckets.update(subsets[rep.name])
        self._lattice = new_lattice
        self._lattice_epoch = epoch
        # per-epoch ring hygiene: drop memoized owner chains so the
        # epoch starts from a clean (re-derivable, identical for shared
        # corners) cache — new corners fault in lazily.
        self._owner_cache.clear()
        reports["epoch"] = epoch
        return reports

    def arm_faults(self) -> None:
        """(Re-)wrap every replica's engine with its injector — for
        drivers that warm first, serve a fault-free prefix, then arm
        the chaos plan (the gate does this so a checkpointed epoch
        exists before the first crash)."""
        for rep in self.replicas:
            if rep.injector is not None:
                rep.injector.wrap_engine(rep.engine)

    # -- heartbeats / supervision -------------------------------------------

    def _maybe_tick(self, now: float) -> None:
        if now - self._last_tick >= self.heartbeat_interval_s:
            self.tick(now)

    def tick(self, now: float | None = None) -> None:
        """One heartbeat round: pull liveness from every replica
        (through its injector — a crashed or blackholed replica's
        heartbeat is simply not delivered), apply the health deadline
        rules, and fire any due supervised restarts."""
        now = self.clock() if now is None else now
        self._last_tick = now
        for rep in self.replicas:
            if rep.injector is not None:
                delivered = rep.injector.heartbeat_delivered()
            else:
                delivered = not rep.crashed
            if delivered:
                rep.health.heartbeat(now)
                self.metrics.heartbeats_delivered += 1
            else:
                self.metrics.heartbeats_missed += 1
            rep.health.evaluate(now)
        if self.auto_restart:
            for rep in self.replicas:
                if rep.health.state != DEAD:
                    continue
                if rep.next_restart_at is None:
                    rep.next_restart_at = now + backoff_s(
                        rep.restart_attempts, base_s=self.backoff_base_s,
                        cap_s=self.backoff_cap_s,
                        seed=self.seed * 1009 + rep.index)
                elif now >= rep.next_restart_at:
                    self.restart(rep.name, now=now)

    def restart(self, name: str, now: float | None = None) -> dict:
        """Supervised restart of one DEAD replica: close the old engine
        (its in-flight batches retire first — their futures already
        have callbacks), build a fresh one from the factory, restore
        every predictor tag from the newest loadable epoch checkpoint
        (engine.swap_predictor(epoch=...) pins the restored epoch so
        the replica resumes at last-good λ̂, not cold), re-warm its
        bucket subset, and mark it HEALTHY. Returns {tag: epoch}
        restored."""
        now = self.clock() if now is None else now
        rep = next(r for r in self.replicas if r.name == name)
        rep.health.begin_recovery(now)
        store = rep.store
        try:
            try:
                rep.engine.close()
            except BaseException:
                pass  # a crashed engine may refuse its final flush
            rep.retired_metrics.append(rep.engine.metrics)
            made = self.factory(rep.name)
            engine, lane = made if isinstance(made, tuple) else (made, None)
            restored: dict[str, int] = {}
            if store is not None:
                for tag in engine.predictor_tags():
                    try:
                        state, epoch = store.load_predictor_epoch(tag)
                    except FileNotFoundError:
                        continue  # nothing checkpointed yet: serve epoch 0
                    if epoch > engine.predictor_epoch(tag):
                        engine.swap_predictor(tag, state, epoch=epoch)
                        restored[tag] = epoch
            if rep.warm_buckets:
                engine.warmup(sorted(rep.warm_buckets))
            if self._lattice is not None:
                # resume the fleet's lattice generation: the subset was
                # just re-warmed above (warm_buckets accumulated the
                # adaptive corners at rewarm_lattice time), so the flip
                # is compile-free; pinning the epoch keeps result
                # labels consistent with the pre-crash incarnation.
                engine.swap_lattice(self._lattice,
                                    epoch=self._lattice_epoch)
            rep.engine, rep.lane = engine, lane
            rep.crashed = False
            if rep.injector is not None:
                rep.injector.restore()
                rep.injector.wrap_engine(engine)
                if lane is not None:
                    lane.publish_filter = (
                        lambda tag, state, inj=rep.injector:
                        inj.poison_state(state))
        except BaseException:
            rep.health.fail_recovery(now)
            rep.restart_attempts += 1
            rep.next_restart_at = None   # reschedule with bigger backoff
            self.metrics.restart_failures += 1
            raise
        rep.restart_attempts += 1
        rep.next_restart_at = None
        rep.restore_history.append(dict(restored))
        rep.health.mark_recovered(now)
        self.metrics.restarts += 1
        return restored

    def _force_restart(self, now: float) -> bool:
        """No routable candidate left for some request: restart the
        longest-dead replica NOW, ignoring its backoff schedule —
        progress beats politeness once the alternative is a lost
        request."""
        dead = [r for r in self.replicas if r.health.state == DEAD]
        if not dead:
            return False
        rep = min(dead, key=lambda r: (r.next_restart_at or 0.0, r.index))
        try:
            self.restart(rep.name, now=now)
        except BaseException:
            return False
        return True

    # -- failure handling ----------------------------------------------------

    def _replica_failed(self, rep: Replica, err: BaseException,
                        now: float) -> None:
        """An attempt on `rep` failed. Fatal (ReplicaCrash) marks it
        DEAD and evicts its queued requests — their futures fail, which
        funnels their rids into the retry deque via the same completion
        callbacks as the original failure. NEVER called while holding
        self._lock (handoff fires callbacks inline)."""
        fatal = isinstance(err, ReplicaCrash)
        rep.health.on_failure(now, fatal=fatal)
        if fatal and not rep.crashed:
            rep.crashed = True            # set BEFORE handoff: re-entrant
            self.metrics.crashes += 1     # callbacks must not recurse here
            try:
                rep.engine.handoff_queued(error=err)
            except BaseException:
                pass

    def _on_attempt_done(self, rep: Replica, rid: int, rfut, t0: float,
                         ) -> None:
        """Completion callback (runs on a replica pipeline worker, or
        inline for sync engines): settle the fleet future first-wins,
        or queue a retry. Only touches the lock briefly; never calls
        into an engine except the re-entrancy-guarded handoff."""
        now = self.clock()
        try:
            res = rfut.result(timeout=0)
        except BaseException as err:
            self._replica_failed(rep, err, now)
            with self._lock:
                if rid in self._pending:
                    self._retry.append(rid)
                    self.metrics.retries += 1
            return
        rep.health.observe_lag((now - t0) * 1e3)
        rep.health.on_success(now)
        with self._lock:
            entry = self._pending.pop(rid, None)
            if entry is None:
                # hedge loser (or late duplicate): deduped by rid.
                self.metrics.duplicates_deduped += 1
                return
            if isinstance(res, Shed):
                self.metrics.sheds += 1
            else:
                self.metrics.served += 1
            if entry.hedged:
                self.metrics.hedge_wins += 1
            self._done.append(res)
        entry.fut._resolve(res)

    # -- submission ----------------------------------------------------------

    def _bucket_key(self, req: RankRequest) -> str:
        return self.replicas[0].engine.bucket_of(req).name

    def _candidates(self, entry: _Pending) -> list[int]:
        order = entry.owners + [i for i in range(len(self.replicas))
                                if i not in entry.owners]
        cands = [i for i in order if i not in entry.tried
                 and self.replicas[i].health.routable]
        if not cands:
            # every routable replica already tried: let retries revisit
            # them (one may have recovered since).
            cands = [i for i in order if self.replicas[i].health.routable]
        return cands

    def _send(self, entry: _Pending, idx: int, now: float) -> None:
        rep = self.replicas[idx]
        if idx not in entry.tried:
            entry.tried.append(idx)
        entry.attempts += 1
        try:
            rfut = rep.engine.submit_future(entry.req)
        except BaseException as err:
            self._replica_failed(rep, err, self.clock())
            with self._lock:
                if entry.req.rid in self._pending:
                    self._retry.append(entry.req.rid)
                    self.metrics.retries += 1
            return
        rfut.add_done_callback(
            lambda f, rep=rep, rid=entry.req.rid, t0=now:
            self._on_attempt_done(rep, rid, f, t0))

    def _attempt(self, entry: _Pending, now: float) -> None:
        cands = self._candidates(entry)
        if not cands:
            if self._force_restart(now):
                cands = self._candidates(entry)
        if not cands:
            with self._lock:
                if entry.req.rid in self._pending:
                    self._retry.append(entry.req.rid)  # revisit next pass
            return
        primary = cands[0]
        if primary != entry.owners[0]:
            self.metrics.failovers += 1
        targets = [primary]
        if (self.hedging and len(cands) > 1
                and self.replicas[primary].health.state == SUSPECT
                and not entry.hedged):
            entry.hedged = True
            self.metrics.hedges += 1
            targets.append(cands[1])
        for idx in targets:
            self._send(entry, idx, now)

    def _drain_retries(self, now: float) -> None:
        """Re-route every queued retry — on the caller's thread, the
        only thread allowed to call into engines (see module doc)."""
        while True:
            with self._lock:
                if not self._retry:
                    return
                rid = self._retry.popleft()
                entry = self._pending.get(rid)
            if entry is None or entry.fut.done():
                continue
            if entry.attempts >= self.max_attempts:
                with self._lock:
                    self._pending.pop(rid, None)
                    self.metrics.lost += 1
                entry.fut._fail(RuntimeError(
                    f"request {rid}: exhausted {entry.attempts} attempts "
                    f"across the fleet"))
                continue
            self._attempt(entry, now)

    def submit_future(self, req: RankRequest,
                      now: float | None = None) -> RankFuture:
        """Route one request; returns a fleet-level RankFuture that
        settles exactly once (hedged duplicates dedupe by rid)."""
        now = self.clock() if now is None else now
        self._maybe_tick(now)
        bucket_name = self._bucket_key(req)
        fut = RankFuture(req.rid, bucket_name)
        entry = _Pending(req=req, fut=fut, owners=self._owners(bucket_name))
        with self._lock:
            if req.rid in self._pending:
                raise ValueError(f"rid {req.rid} already in flight")
            self._pending[req.rid] = entry
            self.metrics.submitted += 1
        self._attempt(entry, now)
        self._drain_retries(now)
        return fut

    def submit(self, req: RankRequest, now: float | None = None):
        """Enqueue; returns fleet results retired so far (engine-style
        driver surface)."""
        self.submit_future(req, now)
        return self._take_done()

    def poll(self, now: float | None = None):
        """Deadline-flush every live replica, re-route queued retries,
        and return results retired so far."""
        now = self.clock() if now is None else now
        self._maybe_tick(now)
        for rep in self.replicas:
            if rep.crashed or not rep.health.routable:
                continue
            try:
                rep.engine.poll()
            except BaseException as err:
                self._replica_failed(rep, err, self.clock())
        self._drain_retries(now)
        return self._take_done()

    def observe_submission_lag(self, lag_ms: float) -> None:
        for rep in self.replicas:
            if not rep.crashed:
                rep.engine.observe_submission_lag(lag_ms)

    def refresh(self, tag: str | None = None) -> dict:
        """Run one refresh pass on every live replica's lane (replicas
        refresh independently — each lane sees only the telemetry its
        replica served)."""
        reports = {}
        for rep in self.replicas:
            if rep.lane is None or rep.crashed or not rep.health.routable:
                continue
            reports[rep.name] = rep.lane.refresh(tag)
        return reports

    def drain(self, max_rounds: int = 256):
        """Fleet-wide stream-end barrier: keep ticking (so due restarts
        fire), re-routing retries, and draining live replicas until no
        fleet future is unsettled. A replica whose injector holds a
        partial-drain kill crashes HERE — its queued requests hand off
        and re-route, which is exactly what this loop exists to absorb."""
        for _ in range(max_rounds):
            now = self.clock()
            self.tick(now)
            self._drain_retries(now)
            for rep in self.replicas:
                if rep.crashed or not rep.health.routable:
                    continue
                if rep.injector is not None:
                    rep.injector.draining = True
                try:
                    rep.engine.drain()
                except BaseException as err:
                    self._replica_failed(rep, err, self.clock())
                finally:
                    if rep.injector is not None:
                        rep.injector.draining = False
            self._drain_retries(self.clock())
            with self._lock:
                settled = not self._pending and not self._retry
            if settled:
                return self._take_done()
            time.sleep(0.001)  # crashed replicas' in-flight batches retire
        with self._lock:                   # on their worker threads
            stuck = sorted(self._pending)
        raise RuntimeError(f"fleet drain did not converge; rids still "
                           f"pending: {stuck[:16]}{'...' if len(stuck) > 16 else ''}")

    def serve_stream(self, requests, *, warmup: bool = True,
                     tick_every: int = 1):
        """Convenience driver: warm (unless already), then submit the
        stream with an explicit heartbeat tick every `tick_every`
        requests (deterministic tick indices for blackhole windows),
        and drain. Returns every result."""
        requests = list(requests)
        if warmup and not self._warmed:
            self.warmup(requests)
        results = []
        for i, req in enumerate(requests):
            results += self.submit(req)
            results += self.poll()
            if tick_every and i % tick_every == 0:
                self.tick()
        results += self.drain()
        return results

    def close(self) -> None:
        for rep in self.replicas:
            try:
                rep.engine.close()
            except BaseException:
                pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _take_done(self) -> list:
        with self._lock:
            out, self._done = self._done, []
        return out

    # -- accounting ----------------------------------------------------------

    def orphaned_futures(self) -> int:
        """Fleet futures minted but never settled — asserted == 0 after
        every chaos drain (nothing leaks, nothing hangs)."""
        with self._lock:
            return sum(1 for e in self._pending.values()
                       if not e.fut.done())

    def fleet_summary(self) -> dict:
        """FleetMetrics + per-replica health/engine rollup, cumulative
        across restarts (retired engines' metrics are kept)."""
        replicas = {}
        lat: list[float] = []
        for rep in self.replicas:
            metrics = rep.retired_metrics + [rep.engine.metrics]
            lat.extend(x for m in metrics for x in m.latencies_ms)
            replicas[rep.name] = {
                "state": rep.health.state,
                "transitions": len(rep.health.transitions),
                "restarts": len(rep.restore_history),
                "restored_epochs": (rep.restore_history[-1]
                                    if rep.restore_history else {}),
                "requests": sum(m.requests for m in metrics),
                "results": sum(m.results for m in metrics),
                "batches": sum(m.batches for m in metrics),
                "sheds": sum(m.sheds for m in metrics),
                "compiles_post_warmup": sum(m.compiles_post_warmup
                                            for m in metrics),
                "swaps": sum(m.swaps for m in metrics),
                "refresh_failures": sum(m.refresh_failures for m in metrics),
            }
        out = {**self.metrics.as_dict(),
               "orphaned_futures": self.orphaned_futures(),
               "replicas": replicas}
        if lat:
            arr = np.asarray(lat)
            out["latency_ms"] = {"p50": float(np.percentile(arr, 50)),
                                 "p99": float(np.percentile(arr, 99)),
                                 "count": int(arr.size)}
        return out
