"""Checkpointing: versioned, atomic, async-capable, elastic on restore.

Format (no external deps):
  <dir>/step_<n>/manifest.json   pytree structure, shapes, dtypes, step,
                                 logical-axis annotations (for re-sharding)
  <dir>/step_<n>/arrays.npz      raw buffers keyed by flattened path

Design points for 1000+ node scale (DESIGN.md §4):
  * atomic rename: write to step_<n>.tmp-<pid>, fsync, rename — a crashed
    writer never corrupts the latest checkpoint;
  * async save: `save_async` snapshots to host memory synchronously
    (jax.device_get) and writes on a background thread, so the train loop
    stalls only for D2H, not disk;
  * elastic restore: the manifest stores *logical* metadata only; restore
    maps buffers onto the CURRENT mesh via the caller-provided shardings —
    the device count may differ from the saving run;
  * GC: keep_last prunes old steps, newest first.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp
import ml_dtypes  # ships with jax
import numpy as np

PyTree = Any

_SEP = "/"

# dtypes numpy's npz cannot round-trip: store as raw bytes + manifest dtype
_EXOTIC = {"bfloat16", "float8_e4m3fn", "float8_e5m2", "float8_e4m3",
           "float8_e5m2fnuz", "float8_e4m3fnuz"}


def _encode(arr: np.ndarray) -> np.ndarray:
    if str(arr.dtype) in _EXOTIC:
        return arr.view(np.uint8 if arr.dtype.itemsize == 1 else np.uint16)
    return arr


def _decode(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC:
        return arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))
    return arr


def _flatten_with_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


def _key_to_npz(key: str) -> str:
    # npz disallows '/' on some loaders; keep it simple and reversible
    return key.replace(_SEP, "__SL__")


def _npz_to_key(name: str) -> str:
    return name.replace("__SL__", _SEP)


class CheckpointStore:
    def __init__(self, directory: str, *, keep_last: int = 3):
        self.directory = directory
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- paths --------------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_", 1)[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # -- save ----------------------------------------------------------------

    def save(self, step: int, tree: PyTree, *, extra: dict | None = None) -> str:
        """Synchronous checkpoint write with atomic rename."""
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        return self._write(step, host, extra or {})

    def save_async(self, step: int, tree: PyTree, *, extra: dict | None = None):
        """D2H snapshot now; disk write on a background thread. Joins any
        in-flight write first (at most one outstanding)."""
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, host, extra or {}), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: PyTree, extra: dict) -> str:
        flat, _ = _flatten_with_paths(host_tree)
        final = self._step_dir(step)
        tmp = f"{final}.tmp-{os.getpid()}-{threading.get_ident()}"
        os.makedirs(tmp, exist_ok=True)
        manifest = {
            "step": step,
            "time": time.time(),
            "extra": extra,
            "leaves": {
                k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in flat.items()
            },
        }
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{_key_to_npz(k): _encode(v) for k, v in flat.items()})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self):
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep_last)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
        # orphaned tmp dirs from crashed writers
        for name in os.listdir(self.directory):
            if ".tmp-" in name:
                path = os.path.join(self.directory, name)
                if time.time() - os.path.getmtime(path) > 3600:
                    shutil.rmtree(path, ignore_errors=True)

    # -- per-epoch predictor state (the serving fleet's restart path) --------
    #
    # The refresh lane writes one checkpoint per published predictor
    # generation (save_predictor_epoch after every successful
    # engine.swap_predictor), keyed (tag, epoch) under
    # <dir>/predictors/<tag>/step_<epoch>/ — the same atomic
    # manifest+npz format, so a crashed writer never corrupts the
    # newest epoch. A restarted replica restores the newest LOADABLE
    # epoch: load_predictor_epoch validates each candidate (readable
    # manifest/npz, complete leaves, manifest-consistent shapes,
    # finite floats) and on corruption REFUSES it and falls back to
    # the previous epoch rather than serving a half-written λ̂.

    def _predictor_store(self, tag: str) -> "CheckpointStore":
        return CheckpointStore(
            os.path.join(self.directory, "predictors", tag),
            keep_last=self.keep_last)

    def predictor_epochs(self, tag: str) -> list[int]:
        """Epochs checkpointed for `tag`, ascending (post-GC: only the
        newest keep_last survive)."""
        d = os.path.join(self.directory, "predictors", tag)
        if not os.path.isdir(d):
            return []
        return self._predictor_store(tag).steps()

    def save_predictor_epoch(self, tag: str, epoch: int, state: PyTree,
                             *, extra: dict | None = None) -> str:
        """Checkpoint one predictor generation: `state` is the tag's
        state dict (core.predictors.predictor_state) as published at
        `epoch`. Synchronous — the refresh lane calls this after the
        swap flips, off the serving hot path."""
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        sub = self._predictor_store(tag)
        return sub._write(int(epoch), host,
                          {"tag": tag, "epoch": int(epoch), **(extra or {})})

    def load_predictor_epoch(self, tag: str, *, epoch: int | None = None,
                             like: PyTree | None = None
                             ) -> tuple[PyTree, int]:
        """Load the newest loadable epoch for `tag` (or exactly
        `epoch`), returning (state, epoch). Corrupted checkpoints are
        refused — unreadable manifest/npz, leaves missing or extra vs
        the manifest, shapes disagreeing with the manifest, non-finite
        float values, or (with `like`) structure/shape mismatch — and
        the previous epoch is tried instead. Raises FileNotFoundError
        only when no epoch is loadable at all."""
        epochs = self.predictor_epochs(tag)
        candidates = ([int(epoch)] if epoch is not None
                      else list(reversed(epochs)))
        if not candidates:
            raise FileNotFoundError(
                f"no predictor checkpoints for tag {tag!r} in "
                f"{self.directory}")
        sub = self._predictor_store(tag)
        errors = []
        for e in candidates:
            try:
                return self._load_predictor_step(sub, e, like), e
            except Exception as err:  # noqa: BLE001 — refuse + fall back
                errors.append(f"epoch {e}: {err}")
        raise FileNotFoundError(
            f"no loadable predictor checkpoint for tag {tag!r}: "
            + "; ".join(errors))

    @staticmethod
    def _load_predictor_step(sub: "CheckpointStore", step: int,
                             like: PyTree | None) -> PyTree:
        d = sub._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaf_meta = manifest["leaves"]
        with np.load(os.path.join(d, "arrays.npz")) as data:
            buffers = {}
            for name in data.files:
                key = _npz_to_key(name)
                if key not in leaf_meta:
                    raise ValueError(f"leaf {key} absent from manifest")
                arr = _decode(data[name], leaf_meta[key]["dtype"])
                if list(arr.shape) != leaf_meta[key]["shape"]:
                    raise ValueError(
                        f"leaf {key}: array shape {list(arr.shape)} != "
                        f"manifest {leaf_meta[key]['shape']}")
                if np.issubdtype(arr.dtype, np.floating) \
                        and not bool(np.all(np.isfinite(arr))):
                    raise ValueError(f"leaf {key}: non-finite values")
                buffers[key] = arr
        missing = set(leaf_meta) - set(buffers)
        if missing:
            raise ValueError(f"missing leaves: {sorted(missing)[:5]}")
        if like is not None:
            flat_like, treedef = _flatten_with_paths(like)
            absent = set(flat_like) - set(buffers)
            if absent:
                raise KeyError(f"missing leaves vs template: "
                               f"{sorted(absent)[:5]}")
            leaves = []
            for key, ref in flat_like.items():
                buf = buffers[key]
                if tuple(buf.shape) != tuple(ref.shape):
                    raise ValueError(
                        f"leaf {key}: shape {buf.shape} != template "
                        f"{tuple(ref.shape)}")
                leaves.append(buf.astype(ref.dtype)
                              if str(buf.dtype) != str(ref.dtype) else buf)
            return jax.tree_util.tree_unflatten(treedef, leaves)
        # no template: rebuild the (possibly nested) state dict from
        # the flattened '/'-joined keys.
        out: dict = {}
        for key, buf in buffers.items():
            parts = key.split(_SEP)
            node = out
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = buf
        return out

    # -- restore ---------------------------------------------------------------

    def restore(
        self,
        like: PyTree,
        *,
        step: int | None = None,
        shardings: PyTree | None = None,
    ) -> tuple[PyTree, dict]:
        """Restore into the structure of `like` (a pytree of arrays or
        ShapeDtypeStructs). If `shardings` (a matching pytree of
        jax.sharding.Sharding) is given, buffers are placed directly onto
        the current mesh — the ELASTIC path: the mesh/device count may
        differ from the run that saved.

        Returns (tree, manifest_extra).
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        leaf_meta = manifest["leaves"]
        buffers = {
            _npz_to_key(k): _decode(data[k], leaf_meta[_npz_to_key(k)]["dtype"])
            for k in data.files
        }

        flat_like, treedef = _flatten_with_paths(like)
        missing = set(flat_like) - set(buffers)
        if missing:
            raise KeyError(f"checkpoint step {step} missing leaves: {sorted(missing)[:5]}")

        flat_shard = None
        if shardings is not None:
            flat_shard, _ = _flatten_with_paths(shardings)

        out = {}
        for key, ref in flat_like.items():
            buf = buffers[key]
            want_dtype = ref.dtype
            if str(buf.dtype) != str(want_dtype):
                buf = buf.astype(want_dtype)
            if tuple(buf.shape) != tuple(ref.shape):
                raise ValueError(
                    f"leaf {key}: checkpoint shape {buf.shape} != expected {ref.shape}")
            if flat_shard is not None and key in flat_shard:
                out[key] = jax.device_put(buf, flat_shard[key])
            else:
                out[key] = jnp.asarray(buf)
        leaves = [out[k] for k in flat_like]  # same iteration order as flatten
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest.get("extra", {})
