"""Batched dual solver for ranking under constraints.

The paper solves the dual LP (eq. 4) with CBC per user on CPU. On TPU no LP
library exists — and none is needed: under fixed discounting the Lagrangian
dual collapses to a K-dimensional piecewise-linear convex minimization whose
subgradient needs only the *unconstrained argmax assignment*, which is a
sort (rearrangement inequality). We therefore solve

    min_{lambda >= 0}  g(lambda)
    g(lambda) = max_{P} tr((U + sum_k lambda_k A_k)^T P) - lambda^T b
              = sum_{j<=m2} s_(j) gamma_j - lambda^T b,   s = u + a^T lambda

by projected subgradient descent with AdaGrad step sizes, tracking the best
iterate. The subgradient at lambda is  exposure(P*(lambda)) - b.

Everything is shape-static and vmap-able: `solve_dual_batch` solves one dual
per user across the batch in parallel — this is the offline stage of
Algorithm 1 run as a single accelerator program instead of a CPU solver
loop. Complexity per user per iteration: O(m1 K) matvec + O(m1 log m1) sort.

Duality certificates: g(lambda_best) upper-bounds the constrained optimum
(max problem), and any feasible rounded ranking lower-bounds it, so we can
report a per-user duality gap without ever running an LP.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.assignment import rank_by_sort
from repro.core.constraints import ConstraintSet

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class DualSolution:
    lam: Array          # (K,) best shadow prices found
    dual_value: Array   # scalar g(lam) — upper bound on constrained optimum
    primal_value: Array  # utility of the rounded ranking tr(U^T P)
    exposure: Array     # (K,) exposure of the rounded ranking
    compliant: Array    # scalar bool — rounded ranking satisfies constraints
    gap: Array          # dual_value - primal_value (>= 0 up to rounding)
    iters: Array        # scalar int


def _dual_eval(lam: Array, u: Array, a: Array, b: Array, gamma: Array, m2: int):
    """g(lambda) and its subgradient. a: (K, m1)."""
    s = u + lam @ a                       # (m1,)
    top_s, idx = jax.lax.top_k(s, m2)     # rearrangement-optimal assignment
    match_val = jnp.dot(top_s, gamma)
    g = match_val - jnp.dot(lam, b)
    exposure = jnp.take(a, idx, axis=1) @ gamma  # (K,)
    subgrad = exposure - b
    return g, subgrad, idx


@partial(jax.jit, static_argnames=("m2", "num_iters"))
def solve_dual(
    u: Array,
    cons: ConstraintSet,
    gamma: Array,
    *,
    m2: int,
    num_iters: int = 300,
    lr: float = 1.0,
    max_lambda: float = 1e4,
    eps_boost: float = 1e-4,
) -> DualSolution:
    """Solve one user's dual; see module docstring.

    AdaGrad projected subgradient: robust to the relative scaling of u vs. the
    constraint attributes without per-problem tuning. `max_lambda` caps prices
    so infeasible programs terminate with a finite (flagged) solution.
    """
    a, b = cons.a, cons.b
    K = a.shape[0]
    inf = jnp.asarray(jnp.inf, jnp.float32)

    # --- scale invariance -------------------------------------------------
    # The kinks of g live at lambda ~ (utility gaps)/(attribute scale);
    # normalize u to [0, 1] so one lr works for ratings in [1,5], logits,
    # raw scores, ... lambda returned below is rescaled to original units
    # (ranking by u_hat + lam_hat a == ranking by u + sigma lam_hat a).
    u = u.astype(jnp.float32)
    u_lo, u_hi = jnp.min(u), jnp.max(u)
    sigma = jnp.maximum(u_hi - u_lo, 1e-9)
    u_n = (u - u_lo) / sigma

    # Primal recovery: the subgradient iterates oscillate around the dual
    # optimum (a kink; binary a_k rows make exposure nearly all-or-nothing
    # per constraint, so single iterates can anti-phase-lock across
    # constraints). We therefore track three rounding candidates:
    #   (1) the best single iterate by (violation, -utility),
    #   (2) the tail-averaged iterate (ergodic average -> lambda* for
    #       piecewise-linear duals; breaks anti-phase locking),
    #   (3) the best-dual-value iterate (the certificate).
    half = num_iters // 2

    def body(carry, it):
        lam, gsq, best_lam, best_g, r_lam, r_viol, r_util, avg = carry
        g, sub, idx = _dual_eval(lam, u_n, a, b, gamma, m2)
        best_lam = jnp.where(g < best_g, lam, best_lam)
        best_g = jnp.minimum(g, best_g)
        # exposure of the current iterate's rounded ranking = sub + b
        viol = jnp.sum(jnp.maximum(-sub, 0.0))
        util = jnp.dot(jnp.take(u_n, idx), gamma)
        better = jnp.logical_or(
            viol < r_viol - 1e-9,
            jnp.logical_and(viol <= r_viol + 1e-9, util > r_util),
        )
        r_lam = jnp.where(better, lam, r_lam)
        r_viol = jnp.where(better, viol, r_viol)
        r_util = jnp.where(better, util, r_util)
        avg = jnp.where(it >= half, avg + lam / (num_iters - half), avg)
        gsq = gsq + sub * sub
        step = lr / jnp.sqrt(gsq + 1e-12)
        lam = jnp.clip(lam - step * sub, 0.0, max_lambda)
        return (lam, gsq, best_lam, best_g, r_lam, r_viol, r_util, avg), None

    lam0 = jnp.zeros((K,), jnp.float32)
    init = (lam0, jnp.zeros((K,), jnp.float32), lam0, inf, lam0, inf, -inf,
            lam0)
    (lam, _, best_lam, best_g, r_lam, _, _, avg_lam), _ = jax.lax.scan(
        body, init, jnp.arange(num_iters))
    g_fin, _, _ = _dual_eval(lam, u_n, a, b, gamma, m2)
    use_fin = g_fin < best_g
    best_lam = jnp.where(use_fin, lam, best_lam)
    best_g = jnp.where(use_fin, g_fin, best_g)

    # --- pick the rounding lambda: best of the three candidates ----------
    def round_stats(cand):
        s = u_n + (1.0 + eps_boost) * (cand @ a)
        perm = rank_by_sort(s, m2)
        expo = jnp.take(a, perm, axis=1) @ gamma
        viol = jnp.sum(jnp.maximum(b - expo, 0.0))
        util = jnp.dot(jnp.take(u_n, perm), gamma)
        return viol, util

    cands = jnp.stack([r_lam, avg_lam, best_lam])
    viols, utils = jax.vmap(round_stats)(cands)
    # lexicographic (viol, -util): subtract a utility bonus much smaller
    # than any meaningful violation difference
    score = viols - 1e-6 * utils / (jnp.max(jnp.abs(utils)) + 1e-9)
    lam_round = cands[jnp.argmin(score)]

    # --- feasibility polish -----------------------------------------------
    # The LP optimum at lambda* is a fractional mix of sorts; one sort can
    # under-serve a constraint whose lambda*_k sits exactly at a kink. A
    # short multiplicative polish (bump violated coordinates, relax slack
    # ones) walks to a fully-feasible rounding when one exists nearby,
    # keeping the best (violation, -utility) candidate. This is the
    # rounding-stage analogue of the paper's epsilon tie-break and makes
    # the stored lambda a feasible-rounding TARGET for the predictor.
    def polish_body(carry, _):
        lam_c, best_c, best_v, best_u = carry
        s = u_n + (1.0 + eps_boost) * (lam_c @ a)
        perm = rank_by_sort(s, m2)
        expo = jnp.take(a, perm, axis=1) @ gamma
        viol_vec = jnp.maximum(b - expo, 0.0)
        viol = jnp.sum(viol_vec)
        util = jnp.dot(jnp.take(u_n, perm), gamma)
        better = jnp.logical_or(
            viol < best_v - 1e-9,
            jnp.logical_and(viol <= best_v + 1e-9, util > best_u),
        )
        best_c = jnp.where(better, lam_c, best_c)
        best_v = jnp.where(better, viol, best_v)
        best_u = jnp.where(better, util, best_u)
        slack = expo - b
        bump = viol_vec > 1e-9
        lam_c = jnp.where(bump, lam_c * 1.3 + 0.02, lam_c)
        lam_c = jnp.where(
            jnp.logical_and(slack > 0.1 * jnp.abs(b) + 1e-3, ~bump),
            lam_c * 0.97, lam_c)
        lam_c = jnp.clip(lam_c, 0.0, max_lambda)
        return (lam_c, best_c, best_v, best_u), None

    (_, lam_round, _, _), _ = jax.lax.scan(
        polish_body, (lam_round, lam_round, inf, -inf), None, length=40)

    s = u_n + (1.0 + eps_boost) * (lam_round @ a)
    perm = rank_by_sort(s, m2)
    primal = jnp.dot(jnp.take(u, perm), gamma)
    exposure = jnp.take(a, perm, axis=1) @ gamma
    compliant = jnp.all(exposure >= b - 1e-6)
    # `lam` is the recovery iterate in ORIGINAL utility units: downstream
    # consumers round with it and the predictor f(X) -> lambda is trained
    # on it. The dual certificate is reported in original units too.
    return DualSolution(
        lam=lam_round * sigma,
        dual_value=best_g * sigma + u_lo * jnp.sum(gamma),
        primal_value=primal,
        exposure=exposure,
        compliant=compliant,
        gap=(best_g * sigma + u_lo * jnp.sum(gamma)) - primal,
        iters=jnp.asarray(num_iters),
    )


def solve_dual_batch(
    u_batch: Array,          # (n_users, m1)
    a_batch: Array,          # (n_users, K, m1) or (K, m1) shared
    b_batch: Array,          # (n_users, K) or (K,) shared
    gamma: Array,
    *,
    m2: int,
    num_iters: int = 300,
    lr: float = 1.0,
    max_lambda: float = 1e4,
    eps_boost: float = 1e-4,
) -> DualSolution:
    """vmap of `solve_dual` over users — the offline stage of Algorithm 1.

    Under pjit this batch axis is sharded over (pod, data): thousands of
    users' duals are solved concurrently per pod step.
    """
    if a_batch.ndim == 2:
        a_batch = jnp.broadcast_to(a_batch, (u_batch.shape[0],) + a_batch.shape)
    if b_batch.ndim == 1:
        b_batch = jnp.broadcast_to(b_batch, (u_batch.shape[0],) + b_batch.shape)

    def one(u, a, b):
        return solve_dual(
            u, ConstraintSet(a=a, b=b), gamma,
            m2=m2, num_iters=num_iters, lr=lr,
            max_lambda=max_lambda, eps_boost=eps_boost,
        )

    return jax.vmap(one)(u_batch, a_batch, b_batch)


@partial(jax.jit, static_argnames=("m2",))
def serve_rank(
    u: Array, a: Array, lam: Array, gamma: Array, *, m2: int,
    eps_boost: float = 1e-4,
):
    """Online stage: given predicted shadow prices, produce the ranking.

    s = u + (1+eps) * lam @ a ; top-m2 by s. O(m1 K + m1 log m1) — this is
    the <50 ms hot path (also available fused as a Pallas kernel,
    repro.kernels.fused_rank).
    """
    s = u + (1.0 + eps_boost) * (lam @ a)
    perm = rank_by_sort(s, m2)
    utility = jnp.dot(jnp.take(u, perm, axis=-1), gamma)
    return perm, utility
