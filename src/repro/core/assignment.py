"""Assignment (weighted bipartite matching) algorithms.

The paper needs four assignment routines with different generality/speed
trade-offs (Sec. 3.2):

  * ``rank_by_sort``      — O(m log m) sort-based optimal assignment for
                            fixed-discounting / (permuted) inverse-Monge S
                            (rearrangement inequality, Hardy et al. 1952).
  * ``greedy_half_approx``— O(m1·m2) greedy 1/2-approximation (Avis 1983,
                            Preis 1999) for general S.
  * ``auction``           — Bertsekas auction algorithm: exact (up to eps)
                            max-weight matching for general S. TPU-friendly
                            replacement for the Hungarian algorithm (the
                            Hungarian augmenting-path search is serial and
                            does not vectorize; Jacobi-style auction rounds
                            are pure dense argmax/scatter).
  * ``brute_force``       — O(m!) oracle for tests (numpy, m <= 8).

All routines work on the *unbalanced* case (m1 items -> m2 <= m1 rank
positions; every rank holds exactly one item, items may be unassigned).

Conventions
-----------
A ranking is represented as ``perm``: an int array of shape (m2,) where
``perm[j]`` = index of the item placed at rank j (0-based, rank 0 = top).
"""

from __future__ import annotations

import itertools
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


# ---------------------------------------------------------------------------
# Sort-based optimal assignment (fixed discounting / Monge structure)
# ---------------------------------------------------------------------------

def rank_by_sort(s: Array, m2: int | None = None) -> Array:
    """Optimal assignment for fixed-discounting S = s @ gamma^T.

    By the rearrangement inequality, with gamma > 0 descending, sorting s
    descending and assigning rank j to the j-th largest element maximizes
    tr(S^T P). Returns ``perm`` of shape (m2,).

    ``jax.lax.top_k`` is used instead of a full argsort when m2 < m1: the
    serving hot path only needs the top-m2 items.
    """
    m1 = s.shape[-1]
    if m2 is None:
        m2 = m1
    if m2 == m1:
        return jnp.argsort(-s, axis=-1)
    _, idx = jax.lax.top_k(s, m2)
    return idx


def assignment_value(s: Array, gamma: Array, perm: Array) -> Array:
    """tr(S^T P) for S = s gamma^T and the ranking ``perm``."""
    return jnp.sum(jnp.take(s, perm, axis=-1) * gamma, axis=-1)


def assignment_value_dense(S: Array, perm: Array) -> Array:
    """tr(S^T P) for a dense (m1, m2) score matrix."""
    m2 = perm.shape[-1]
    cols = jnp.arange(m2)
    return jnp.sum(S[perm, cols], axis=-1)


def perm_to_matrix(perm: Array, m1: int) -> Array:
    """Ranking -> (m1, m2) permutation (selection) matrix P."""
    m2 = perm.shape[-1]
    P = jnp.zeros((m1, m2), dtype=jnp.float32)
    return P.at[perm, jnp.arange(m2)].set(1.0)


# ---------------------------------------------------------------------------
# Greedy 1/2-approximation (general S, no Monge structure needed)
# ---------------------------------------------------------------------------

def greedy_half_approx(S: Array) -> Array:
    """Greedy max-weight matching: repeatedly take the largest remaining
    entry of S, retiring its row (item) and column (rank). 1/2-approximation
    in the worst case; optimal when S satisfies box inequalities.

    Vectorized as m2 rounds of a masked dense argmax (O(m1·m2) work per
    round -> O(m1·m2^2) total; fine off the hot path).
    """
    m1, m2 = S.shape
    neg_inf = jnp.asarray(-jnp.inf, S.dtype)

    def body(carry, _):
        Sm, perm_accum, step = carry
        flat = jnp.argmax(Sm)
        i, j = flat // m2, flat % m2
        Sm = Sm.at[i, :].set(neg_inf)
        Sm = Sm.at[:, j].set(neg_inf)
        perm_accum = perm_accum.at[j].set(i)
        return (Sm, perm_accum, step + 1), None

    init = (S, jnp.zeros((m2,), jnp.int32), 0)
    (Sm, perm, _), _ = jax.lax.scan(body, init, None, length=m2)
    return perm


# ---------------------------------------------------------------------------
# Auction algorithm (exact general solver; TPU-friendly Hungarian substitute)
# ---------------------------------------------------------------------------

def auction(S: Array, eps: float = 1e-3, max_iters: int = 50_000) -> Array:
    """Gauss-Seidel-flavoured auction, JAX while_loop, simple & correct.

    One bid resolved per iteration (the lowest-index unassigned rank bids).
    Slower than Jacobi rounds but exact and easy to verify; used as a
    general-S oracle off the hot path.
    """
    S = jnp.asarray(S, jnp.float32)
    m1, m2 = S.shape

    def cond(state):
        rank_owner, _, it = state  # rank_owner[j] = item of rank j or -1
        return jnp.logical_and(jnp.any(rank_owner < 0), it < max_iters)

    def body(state):
        rank_owner, prices, it = state
        j = jnp.argmax(rank_owner < 0)  # first unassigned rank
        values = S[:, j] - prices
        top2, idx2 = jax.lax.top_k(values, 2)
        i = idx2[0]
        incr = top2[0] - top2[1] + eps
        prices = prices.at[i].add(incr)
        # evict whoever owns item i
        owns_i = rank_owner == i
        rank_owner = jnp.where(owns_i, -1, rank_owner)
        rank_owner = rank_owner.at[j].set(i)
        return rank_owner, prices, it + 1

    init = (jnp.full((m2,), -1, jnp.int32), jnp.zeros((m1,), jnp.float32), 0)
    rank_owner, _, _ = jax.lax.while_loop(cond, body, init)
    return rank_owner


# ---------------------------------------------------------------------------
# Brute force oracle (tests only)
# ---------------------------------------------------------------------------

def brute_force(S: np.ndarray) -> np.ndarray:
    """Exact max-weight assignment by enumeration. m1 <= 8. Returns perm."""
    S = np.asarray(S)
    m1, m2 = S.shape
    best_val, best_perm = -np.inf, None
    cols = np.arange(m2)
    for items in itertools.permutations(range(m1), m2):
        val = S[list(items), cols].sum()
        if val > best_val:
            best_val, best_perm = val, np.array(items)
    return best_perm


def brute_force_constrained(
    U: np.ndarray, A: np.ndarray, b: np.ndarray, signs: np.ndarray
) -> tuple[np.ndarray | None, float]:
    """Exact *constrained* max-utility assignment by enumeration (tests only).

    U: (m1, m2) utility; A: (K, m1, m2) constraint matrices; b: (K,);
    signs: (K,) +1 for >=, -1 for <=. Returns (perm, value) over feasible
    permutations, or (None, -inf) if infeasible.
    """
    U = np.asarray(U)
    m1, m2 = U.shape
    K = len(b)
    best_val, best_perm = -np.inf, None
    cols = np.arange(m2)
    for items in itertools.permutations(range(m1), m2):
        items_l = list(items)
        ok = True
        for k in range(K):
            v = A[k][items_l, cols].sum()
            if signs[k] > 0 and v < b[k] - 1e-9:
                ok = False
                break
            if signs[k] < 0 and v > b[k] + 1e-9:
                ok = False
                break
        if not ok:
            continue
        val = U[items_l, cols].sum()
        if val > best_val:
            best_val, best_perm = val, np.array(items)
    return best_perm, best_val
