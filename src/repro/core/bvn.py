"""Birkhoff-von Neumann decomposition — the paper's PRIMAL rounding path.

The primal program (eq. 3) relaxes P to a doubly-stochastic matrix; a
fractional solution is served by decomposing it into a convex combination
of permutation matrices (Birkhoff 1940) and SAMPLING rankings from the
mixture — constraints hold in expectation/asymptotically (paper §3.1).

Greedy heuristic (Dufossé & Uçar 2016): repeatedly extract a permutation
supported on the positive entries (found with the auction solver — by
Birkhoff's theorem one always exists for a DS matrix), subtract it scaled
by its minimum entry, renormalize. At most (m-1)^2 + 1 terms; the greedy
min-entry rule typically needs far fewer.

This module completes the paper's method coverage; the DUAL path
(core/dual_solver.py) remains the deployed fast path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.assignment import auction

Array = jax.Array


def is_doubly_stochastic(P: Array, atol: float = 1e-5) -> bool:
    P = np.asarray(P)
    return bool(
        (P >= -atol).all()
        and np.allclose(P.sum(0), 1.0, atol=atol)
        and np.allclose(P.sum(1), 1.0, atol=atol))


def bvn_decompose(P, *, max_terms: int | None = None, tol: float = 1e-6):
    """Doubly-stochastic (m, m) -> (coeffs (T,), perms (T, m)).

    perms[t][j] = item placed at rank j. sum(coeffs) == 1 (up to tol);
    sum_t coeffs[t] * perm_matrix(perms[t]) == P (up to tol).
    """
    P = np.array(P, dtype=np.float64)
    m = P.shape[0]
    if not is_doubly_stochastic(P, atol=1e-3):
        raise ValueError("bvn_decompose needs a doubly-stochastic matrix")
    max_terms = max_terms or (m - 1) ** 2 + 1
    coeffs, perms = [], []
    residual = 1.0
    for _ in range(max_terms):
        if residual <= tol:
            break
        # a permutation supported on positive entries: maximize sum of
        # log-weights so zero entries are never selected
        with np.errstate(divide="ignore"):
            W = np.where(P > tol * 1e-3, np.log(np.maximum(P, 1e-300)), -1e9)
        perm = np.asarray(auction(jnp.asarray(W, jnp.float32), eps=1e-4))
        c = float(P[perm, np.arange(m)].min())
        if c <= tol * 1e-3:
            break
        coeffs.append(c)
        perms.append(perm.copy())
        P[perm, np.arange(m)] -= c
        residual -= c
    if residual > tol:
        # numerical dust: fold into the largest term
        k = int(np.argmax(coeffs))
        coeffs[k] += residual
    coeffs = np.asarray(coeffs)
    coeffs = coeffs / coeffs.sum()
    return coeffs, np.stack(perms)


def sample_ranking(key: Array, coeffs: np.ndarray, perms: np.ndarray) -> Array:
    """Draw one ranking from the BvN mixture (the serving-time sampler)."""
    idx = jax.random.choice(key, len(coeffs), p=jnp.asarray(coeffs, jnp.float32))
    return jnp.asarray(perms)[idx]


def sinkhorn_project(M: Array, *, iters: int = 200) -> Array:
    """Project a positive matrix to (approximately) doubly stochastic by
    Sinkhorn row/column normalization — builds test fixtures and turns
    soft assignment scores into a primal candidate."""
    M = jnp.maximum(jnp.asarray(M, jnp.float64), 1e-12)

    def body(M, _):
        M = M / jnp.sum(M, axis=1, keepdims=True)
        M = M / jnp.sum(M, axis=0, keepdims=True)
        return M, None

    M, _ = jax.lax.scan(body, M, None, length=iters)
    return M
