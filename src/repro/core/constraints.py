"""Constraint specifications for ranking under constraints.

The paper's constraints (Table 1) are all *fixed-discounting* linear
exposure constraints:  tr(A_k^T P) >=/<= b_k  with  A_k = a_k @ gamma^T,
where a_k is a per-item attribute vector (topic indicator, scaled release
year, ...) and gamma is the shared rank-discount vector.

We normalize every constraint internally to ">=" form by flipping the sign
of (a_k, b_k) for "<=" constraints, so the dual shadow prices are always
lambda_k >= 0 against ">=" constraints — matching eq. (4).

ConstraintSet is a pytree; all fields are arrays so it can flow through
jit/vmap/shard_map.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def dcg_discount(m2: int, dtype=jnp.float32) -> Array:
    """gamma_j = 1 / log2(j + 1), j in 1..m2 (descending, positive)."""
    j = jnp.arange(1, m2 + 1, dtype=dtype)
    return 1.0 / jnp.log2(j + 1.0)


def geometric_discount(m2: int, d: float = 0.9, dtype=jnp.float32) -> Array:
    """gamma_j = d^j — the 'simple discounting' alternative in footnote 2."""
    j = jnp.arange(1, m2 + 1, dtype=dtype)
    return jnp.asarray(d, dtype) ** j


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class ConstraintSet:
    """K fixed-discounting constraints, normalized to >= form.

    a: (K, m1) per-item attribute rows (already sign-flipped for <=).
    b: (K,)    thresholds in absolute exposure units (sign-flipped for <=).
    """

    a: Array
    b: Array

    @property
    def num_constraints(self) -> int:
        return self.a.shape[0]

    def exposure(self, perm: Array, gamma: Array) -> Array:
        """tr(A_k^T P) for every k given a ranking perm: (K,)."""
        # a[:, perm[j]] * gamma[j] summed over j
        return jnp.einsum("kj,j->k", jnp.take(self.a, perm, axis=1), gamma)

    def violations(self, perm: Array, gamma: Array) -> Array:
        """Positive part of (b - exposure): 0 where satisfied."""
        return jnp.maximum(self.b - self.exposure(perm, gamma), 0.0)

    def is_compliant(self, perm: Array, gamma: Array, atol: float = 1e-6) -> Array:
        return jnp.all(self.exposure(perm, gamma) >= self.b - atol)


def make_constraints(
    a_list, b_list, signs, dtype=jnp.float32
) -> ConstraintSet:
    """Build a ConstraintSet from raw (a_k, b_k, sign_k) triples.

    sign +1 means `tr(A^T P) >= b`, -1 means `<=`. Internally flips <=
    constraints to >=.
    """
    a = jnp.asarray(np.stack(a_list), dtype)
    b = jnp.asarray(np.asarray(b_list), dtype)
    s = jnp.asarray(np.asarray(signs), dtype)
    return ConstraintSet(a=a * s[:, None], b=b * s)


def exposure_quota_constraints(
    topic_indicators: Array,  # (K_topics, m1) binary
    quota_fracs: Array,  # (K_topics,) fraction of total exposure
    signs: Array,  # (K_topics,) +1 for >=, -1 for <=
    gamma: Array,
) -> ConstraintSet:
    """Table-1-style constraints: topic exposure >= (or <=) quota% of total
    exposure sum_j gamma_j."""
    total = jnp.sum(gamma)
    b = jnp.asarray(quota_fracs) * total
    return make_constraints(
        list(jnp.asarray(topic_indicators)), list(b), list(jnp.asarray(signs))
    )


def movielens_style_constraints(
    topic_indicators: Array,  # (4, m1)
    release_year_delta: Array,  # (m1,) (year - 1990) / 100
    quota_frac: float,
    gamma: Array,
) -> ConstraintSet:
    """The MovieLens experiment set: 4 topic quotas (>=) + exposure-weighted
    mean release-year >= 0 (Table 1a)."""
    total = jnp.sum(gamma)
    a_rows = [topic_indicators[i] for i in range(topic_indicators.shape[0])]
    b_rows = [quota_frac * total] * len(a_rows)
    a_rows.append(release_year_delta)
    b_rows.append(0.0)
    signs = [1.0] * len(a_rows)
    return make_constraints(a_rows, b_rows, signs)
