# The paper's primary contribution: ranking under constraints with
# prediction replacing optimization (Tkachenko et al., 2022), TPU-native.
from repro.core.assignment import (
    auction,
    brute_force,
    brute_force_constrained,
    greedy_half_approx,
    rank_by_sort,
)
from repro.core.constraints import (
    ConstraintSet,
    dcg_discount,
    exposure_quota_constraints,
    geometric_discount,
    make_constraints,
    movielens_style_constraints,
)
from repro.core.dual_solver import DualSolution, serve_rank, solve_dual, solve_dual_batch
from repro.core.monge import is_inverse_monge, is_permuted_inverse_monge, monge_defect
from repro.core.predictors import (
    KNNLambdaPredictor,
    LinearLambdaPredictor,
    MLPLambdaPredictor,
    MeanLambdaPredictor,
    knn_predict,
)
from repro.core.ranking import (
    AUDIT_TOL,
    EPS_GRID,
    RankingOutput,
    RankingPipeline,
    audit_selected,
    fit_pipeline,
    rank_given_lambda,
    rank_with_strategy,
    serve,
    tune_eps,
)
