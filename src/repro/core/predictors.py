"""Shadow-price predictors f(X) -> lambda (Algorithm 1, online stage).

The paper's estimator is a ball-tree KNN regressor with inverse-distance
weights (k = 10, Euclidean). On TPU a ball tree is pointer-chasing; we use
the *exact same estimator* computed by brute force: a (batch x train_users)
distance matmul that maps perfectly onto the MXU, followed by top-k. For
train databases sharded over the `model` mesh axis the top-k is merged
across shards (lax.top_k per shard -> gather k*shards -> re-top-k), see
`repro.distributed.topk.sharded_knn_topk`.

Beyond-paper predictors (recorded separately in EXPERIMENTS.md):
  * ridge-regression linear predictor (closed form, one (d x d) solve),
  * MLP predictor trained with the repo Adam — both strictly cheaper to
    serve than KNN (no train-database residency) and often as compliant.

All predictors share the interface:
  fit(X_train, lam_train) -> fitted predictor (pytree)
  predict(X) -> lam_hat   (jit-able, vmap-able, shard_map-able)

Hot-swap state seam (serving/refresh.py): `predictor_state` extracts
exactly the ARRAY fields of a predictor (STATE_FIELDS), `with_state`
grafts a compatible state dict back on. The split matters for jit: the
serving engine threads the state dict through its bucket executables as
an ARGUMENT (same pytree structure + shapes/dtypes -> same compile-cache
entry, so refreshing state never recompiles), while non-array statics —
KNN's `k` — stay closed over in the predictor template and keep shaping
the trace (lax.top_k needs a Python int, not a tracer).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.optim import adam_init, adam_update

Array = jax.Array


# ---------------------------------------------------------------------------
# Mean predictor (paper's 'Mean lambda' baseline)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class MeanLambdaPredictor:
    """Intercept-only, covariate-free predictor: lam_hat = mean(lam_train)."""

    mean_lam: Array  # (K,)

    @staticmethod
    def fit(X_train: Array, lam_train: Array) -> "MeanLambdaPredictor":
        del X_train
        return MeanLambdaPredictor(mean_lam=jnp.mean(lam_train, axis=0))

    def predict(self, X: Array) -> Array:
        batch = X.shape[:-1]
        return jnp.broadcast_to(self.mean_lam, batch + self.mean_lam.shape)


# ---------------------------------------------------------------------------
# KNN predictor (paper's proposed 'KNeighbors lambda')
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class KNNLambdaPredictor:
    """Exact k-nearest-neighbour regressor, inverse-distance weighted.

    Identical estimator to the paper's sklearn ball-tree KNN (k=10,
    weights='distance', Euclidean metric); computed by brute force:
      d2(x, xi) = |x|^2 - 2 x.xi + |xi|^2  -> top-k -> 1/d weights.
    The train database (X_db, lam_db) rides along in the pytree so the
    predictor can be donated/sharded like any other model state.

    Optionally the predictor also owns a QUANTIZED copy of the db
    (`quantized()` / pack_knn_db): per-slab int8 or bf16 rows (X_q),
    the per-slab dequant scales (q_scale), and the exact |x̃|^2 of the
    dequantized rows (y2_q, PAD_Y2 on slab-padding rows). The quantized
    sweep is exact ON x̃ — the dequantized rows are the ground truth of
    the packed representation — and the final selection is always
    re-scored in f32 (kernels/common.py). `quant` names the storage
    mode and is a STATIC field: it shapes the trace (kernel routing),
    never travels as a jit argument.
    """

    X_db: Array    # (n_train, d)
    lam_db: Array  # (n_train, K)
    k: int
    X_q: Optional[Array] = None      # (n_pad, d) packed db rows
    q_scale: Optional[Array] = None  # (n_slabs, 1) per-slab scales
    y2_q: Optional[Array] = None     # (n_pad, 1) exact |x̃|^2
    quant: str = field(default="off", metadata=dict(static=True))

    @staticmethod
    def fit(X_train: Array, lam_train: Array, k: int = 10) -> "KNNLambdaPredictor":
        return KNNLambdaPredictor(
            X_db=jnp.asarray(X_train), lam_db=jnp.asarray(lam_train), k=int(k)
        )

    def quantized(self, mode: str = "int8",
                  slab: int = None) -> "KNNLambdaPredictor":
        """A copy of this predictor carrying the packed db for the
        quantized sweep. `slab` MUST equal the serving tile_n (the
        per-slab scales are indexed by serving slab) — defaults to the
        kernel-wide DB_SLAB."""
        from repro.kernels.common import DB_SLAB, QUANT_MODES
        if mode not in QUANT_MODES or mode == "off":
            raise ValueError(f"quantized(): mode must be one of "
                             f"{[m for m in QUANT_MODES if m != 'off']}, "
                             f"got {mode!r}")
        slab = DB_SLAB if slab is None else int(slab)
        X_q, q_scale, y2_q = pack_knn_db(self.X_db, mode=mode, slab=slab)
        return dataclasses.replace(
            self, X_q=X_q, q_scale=q_scale, y2_q=y2_q, quant=mode)

    def predict(self, X: Array) -> Array:
        # Quantized predictors predict through the same quantized-sweep
        # + exact-survivor-rescore selection the serving kernels run, so
        # every consumer of this predictor sees one estimator (exact on
        # the dequantized db x̃), kernel path or not.
        if self.X_q is not None:
            return knn_predict_quant(
                self.X_q, self.q_scale, self.y2_q, self.lam_db, X,
                k=self.k, mode=self.quant)
        # Above the threshold the (b, n_train) distance matrix of the
        # one-matmul path stops fitting comfortably in cache/HBM
        # headroom; the chunked variant streams the train database in
        # (b, chunk) slabs instead, keeping only the running top-k.
        if self.X_db.shape[0] > KNN_CHUNK_THRESHOLD:
            return knn_predict_chunked(self.X_db, self.lam_db, X, k=self.k)
        return knn_predict(self.X_db, self.lam_db, X, k=self.k)


def _idw_lambda(d2_top: Array, x2: Array, y2_sel: Array,
                lam_neighbors: Array) -> Array:
    """Inverse-distance weighting with exact-match override on already
    top-k'd neighbours — the shared tail of the full-matrix and chunked
    KNN paths (identical ops, so the two paths can never drift).

    The expanded-form d2 carries O(eps_f32 * |x|^2) error, so 'exact'
    (query coincides with a database point -> return that point's value,
    sklearn 'distance' weights semantics) is a relative test.
    """
    dist = jnp.sqrt(d2_top)
    scale2 = x2 + y2_sel + 1e-12                            # (b, k)
    exact = d2_top <= 1e-6 * scale2
    any_exact = jnp.any(exact, axis=-1, keepdims=True)
    w_inv = 1.0 / jnp.maximum(dist, 1e-12)
    w = jnp.where(any_exact, exact.astype(d2_top.dtype), w_inv)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return jnp.einsum("bk,bkc->bc", w, lam_neighbors)


@partial(jax.jit, static_argnames=("k",))
def knn_predict(X_db: Array, lam_db: Array, X: Array, *, k: int = 10) -> Array:
    """Inverse-distance-weighted KNN regression, batched over X rows.

    X: (..., d) -> (..., K). Exact: brute-force distances then top-k.
    When a query coincides with a database point (d == 0) the estimator
    returns that point's value (sklearn 'distance' weights semantics).
    """
    squeeze = X.ndim == 1
    Xq = jnp.atleast_2d(X)
    # (b, n) squared distances via the expanded form — one MXU matmul.
    x2 = jnp.sum(Xq * Xq, axis=-1, keepdims=True)          # (b, 1)
    y2 = jnp.sum(X_db * X_db, axis=-1)                      # (n,)
    d2 = x2 - 2.0 * (Xq @ X_db.T) + y2[None, :]             # (b, n)
    d2 = jnp.maximum(d2, 0.0)
    neg_top, idx = jax.lax.top_k(-d2, k)                    # (b, k)
    out = _idw_lambda(-neg_top, x2, y2[idx], lam_db[idx])
    return out[0] if squeeze else out


# Above this many train rows KNNLambdaPredictor.predict switches to the
# chunked path: the one-matmul form's (b, n_train) distance matrix is
# n_train * 4 bytes PER QUERY ROW — at 10^6 train users and batch 32
# that is a 128 MB materialization for 10 neighbours.
KNN_CHUNK_THRESHOLD = 32_768


def knn_topk_scan(
    X_db: Array, Xq: Array, *, k: int = 10, chunk: int = 8192
) -> tuple[Array, Array]:
    """Streaming top-k of -d2: the database scans through in
    `chunk`-row slabs and the carry is only the running top-k
    (neg-d2, global index) per query — O(b * chunk) peak distance
    storage, no (b, n_train) matrix ever.

    Ties break exactly like the one-matmul path (lower global index:
    the running buffer precedes the fresh slab in the merge). Returns
    (neg_top (b, k) descending, idx (b, k)). This is the slab sweep
    shared by knn_predict_chunked and the sharded serving body
    (core.serving_dist.knn_predict_distributed), where it serves as the
    per-shard local selection ahead of the cross-shard merge.
    """
    b = Xq.shape[0]
    n, d = X_db.shape
    if n < k:
        raise ValueError(f"n_train={n} < k={k}")
    x2 = jnp.sum(Xq * Xq, axis=-1, keepdims=True)           # (b, 1)
    # pad with far-away rows (never top-k when n >= k real rows exist)
    pad = (-n) % chunk
    Xdb_p = jnp.pad(X_db, ((0, pad), (0, 0)), constant_values=1e15)
    db_slabs = Xdb_p.reshape(-1, chunk, d)
    bases = jnp.arange(db_slabs.shape[0], dtype=jnp.int32) * chunk

    def body(carry, xs):
        run_v, run_i = carry                                # (b, k) each
        db, base = xs                                       # (chunk, d), ()
        y2c = jnp.sum(db * db, axis=-1)                     # (chunk,)
        d2 = jnp.maximum(x2 - 2.0 * (Xq @ db.T) + y2c[None, :], 0.0)
        cand_v = jnp.concatenate([run_v, -d2], axis=-1)     # (b, k+chunk)
        gidx = base + jnp.broadcast_to(
            jnp.arange(chunk, dtype=jnp.int32), (b, chunk))
        cand_i = jnp.concatenate([run_i, gidx], axis=-1)
        new_v, sel = jax.lax.top_k(cand_v, k)
        new_i = jnp.take_along_axis(cand_i, sel, axis=-1)
        return (new_v, new_i), None

    init = (jnp.full((b, k), -jnp.inf, Xq.dtype),
            jnp.zeros((b, k), jnp.int32))
    (neg_top, idx), _ = jax.lax.scan(body, init, (db_slabs, bases))
    return neg_top, idx


@partial(jax.jit, static_argnames=("k", "chunk"))
def knn_predict_chunked(
    X_db: Array, lam_db: Array, X: Array, *, k: int = 10, chunk: int = 8192
) -> Array:
    """knn_predict for large train databases: identical estimator,
    built on the knn_topk_scan slab sweep. The final weighting is the
    shared _idw_lambda on k gathered neighbours.
    """
    squeeze = X.ndim == 1
    Xq = jnp.atleast_2d(X)
    neg_top, idx = knn_topk_scan(X_db, Xq, k=k, chunk=chunk)
    x2 = jnp.sum(Xq * Xq, axis=-1, keepdims=True)           # (b, 1)
    y2 = jnp.sum(X_db * X_db, axis=-1)                      # (n,) — cheap
    out = _idw_lambda(-neg_top, x2, y2[idx], lam_db[idx])
    return out[0] if squeeze else out


# ---------------------------------------------------------------------------
# Quantized db pack + the XLA quantized-sweep selection
# ---------------------------------------------------------------------------
# The Pallas quantized kernels (kernels/knn_topk.py) and the XLA scan
# below consume the SAME packed arrays and the SAME shared math
# (kernels/common.py), so their selections agree bitwise. The pack is a
# Python loop of per-slab jnp programs — repack_knn_slabs re-runs the
# identical per-slab program on touched slabs only, making an
# incremental repack bitwise-equal to a full repack BY CONSTRUCTION
# (no numpy-vs-jnp reduction-order drift possible).

def _pack_one_slab(x_slab: Array, *, mode: str):
    """Pack one db slab. x_slab (s, d) f32 (already padded to the slab
    size; padding rows must be all-zero) -> (rows_q (s, d) stored,
    scale (1, 1) f32, y2 (s, 1) f32 exact |x̃|^2 of the DEQUANTIZED
    rows)."""
    x = jnp.asarray(x_slab, jnp.float32)
    if mode == "int8":
        scale = jnp.max(jnp.abs(x)) / 127.0
        scale = jnp.where(scale > 0, scale, jnp.float32(1.0))
        rows_q = jnp.clip(jnp.round(x / scale), -127.0, 127.0
                          ).astype(jnp.int8)
        xt = rows_q.astype(jnp.float32) * scale
    elif mode == "bf16":
        rows_q = x.astype(jnp.bfloat16)
        scale = jnp.float32(1.0)
        xt = rows_q.astype(jnp.float32)
    else:
        raise ValueError(f"_pack_one_slab: bad mode {mode!r}")
    y2 = jnp.sum(xt * xt, axis=-1, keepdims=True)           # (s, 1)
    return rows_q, scale.reshape(1, 1), y2


def pack_knn_db(X_db: Array, *, mode: str = "int8", slab: int = 512):
    """Quantize the KNN train db into per-slab low-precision storage.

    Returns (X_q (n_pad, d), q_scale (n_slabs, 1) f32, y2_q (n_pad, 1)
    f32) with n_pad = n rounded up to a slab multiple. Padding rows
    store zero and get y2 = PAD_Y2 so they can never survive a sweep
    (int8 cannot encode the f32 path's 1e15 far-away padding). The slab
    size MUST equal the serving tile_n — q_scale rows are the kernel's
    slab blocks."""
    from repro.kernels.common import PAD_Y2
    X = jnp.asarray(X_db, jnp.float32)
    n, d = X.shape
    pad = (-n) % slab
    rows, scales, y2s = [], [], []
    for s in range(0, n + pad, slab):
        x = X[s:s + slab]
        short = slab - x.shape[0]
        if short:
            x = jnp.pad(x, ((0, short), (0, 0)))
        rows_q, scale, y2 = _pack_one_slab(x, mode=mode)
        if short:
            y2 = y2.at[slab - short:].set(PAD_Y2)
        rows.append(rows_q)
        scales.append(scale)
        y2s.append(y2)
    return (jnp.concatenate(rows, axis=0),
            jnp.concatenate(scales, axis=0),
            jnp.concatenate(y2s, axis=0))


def repack_knn_slabs(X_db: Array, X_q: Array, q_scale: Array, y2_q: Array,
                     rows, *, mode: str, slab: int):
    """Incremental repack after a ring write: re-quantize ONLY the
    slabs containing the touched `rows` (host ints / array of row
    indices into X_db), writing fresh rows AND the slab's fresh scale —
    a stale scale is never served. Each touched slab runs the exact
    per-slab program of pack_knn_db, so the result is bitwise equal to
    a full repack of the updated db."""
    from repro.kernels.common import PAD_Y2
    import numpy as np
    X = jnp.asarray(X_db, jnp.float32)
    n = X.shape[0]
    touched = sorted({int(r) // slab for r in np.asarray(rows).ravel()})
    for s_idx in touched:
        s = s_idx * slab
        x = X[s:s + slab]
        short = slab - x.shape[0]
        if short:
            x = jnp.pad(x, ((0, short), (0, 0)))
        rows_q, scale, y2 = _pack_one_slab(x, mode=mode)
        if short:
            y2 = y2.at[slab - short:].set(PAD_Y2)
        X_q = X_q.at[s:s + slab].set(rows_q)
        q_scale = q_scale.at[s_idx:s_idx + 1].set(scale)
        y2_q = y2_q.at[s:s + slab].set(y2)
    del n
    return X_q, q_scale, y2_q


def knn_quant_scan(X_q: Array, q_scale: Array, y2_q: Array, Xq: Array,
                   *, k: int = 10, k_extra: int = None, mode: str = "int8"):
    """Quantized-sweep selection under XLA: scan the packed db in slab
    blocks at low precision carrying a top-(k + k_extra) survivor set,
    then gather the survivors' dequantized rows, re-score them EXACTLY
    in f32, and re-rank to the final k with ties to the lowest global
    index (the f32 oracle's rule). Returns (d2_top (b, k) ascending
    exact-on-x̃, idx (b, k), guard (b, 1) i32 margin-guard flags).

    This is knn_topk_scan's quantized twin and the per-shard sweep of
    the distributed quantized path: same shared math as the Pallas
    kernels (kernels/common.py), so the selections agree bitwise."""
    from repro.kernels.common import (
        QUANT_EXTRA, bottomk_rerank, exact_rescore, quant_d2_err,
        quant_d2_tile)
    if k_extra is None:
        k_extra = QUANT_EXTRA
    k_keep = k + k_extra
    b = Xq.shape[0]
    n_pad, d = X_q.shape
    n_slabs = q_scale.shape[0]
    slab = n_pad // n_slabs
    db_slabs = X_q.reshape(n_slabs, slab, d)
    y2_slabs = y2_q.reshape(n_slabs, slab)
    bases = jnp.arange(n_slabs, dtype=jnp.int32) * slab

    def body(carry, xs):
        run_v, run_i = carry                                # (b, k_keep)
        db, y2_row, scale, base = xs
        d2q = quant_d2_tile(
            Xq, db, scale[0], jnp.broadcast_to(y2_row[None, :], (b, slab)),
            mode=mode)
        cand_v = jnp.concatenate([run_v, -d2q], axis=-1)
        gidx = base + jnp.broadcast_to(
            jnp.arange(slab, dtype=jnp.int32), (b, slab))
        cand_i = jnp.concatenate([run_i, gidx], axis=-1)
        new_v, sel = jax.lax.top_k(cand_v, k_keep)
        new_i = jnp.take_along_axis(cand_i, sel, axis=-1)
        return (new_v, new_i), None

    init = (jnp.full((b, k_keep), -jnp.inf, jnp.float32),
            jnp.zeros((b, k_keep), jnp.int32))
    (neg_v, idx), _ = jax.lax.scan(
        body, init, (db_slabs, y2_slabs, q_scale, bases))

    # exact f32 re-score of the survivors (gathers are fine under XLA)
    scale_rows = q_scale[idx // slab, 0]                    # (b, k_keep)
    x_sel = X_q[idx].astype(jnp.float32) * scale_rows[..., None]
    y2_sel = y2_q[idx, 0]                                   # (b, k_keep)
    x_cols = x_sel.transpose(0, 2, 1)                       # (b, d, k_keep)
    d2x = exact_rescore(Xq, x_cols, y2_sel)

    # margin guard on the QUANTIZED order (observability — the exact
    # re-score is always applied): gap vs the boundary pair's EXACT
    # quantization errors, the kernels' rule verbatim
    d2q_sorted = -neg_v                                     # (b, k_keep) asc
    gap = d2q_sorted[:, k:k + 1] - d2q_sorted[:, k - 1:k]
    errs = quant_d2_err(Xq, x_cols, mode=mode)              # (b, k_keep)
    guard = (gap <= errs[:, k - 1:k] + errs[:, k:k + 1]).astype(jnp.int32)
    d2_top, idx_top = bottomk_rerank(d2x, idx, k)
    return d2_top, idx_top, guard


@partial(jax.jit, static_argnames=("k", "mode"))
def knn_predict_quant(X_q: Array, q_scale: Array, y2_q: Array,
                      lam_db: Array, X: Array, *, k: int = 10,
                      mode: str = "int8") -> Array:
    """knn_predict through the quantized sweep + exact survivor
    re-score: the estimator every quantized consumer (XLA predict,
    Pallas kernels, distributed shards) agrees on, exact on the
    dequantized db x̃."""
    squeeze = X.ndim == 1
    Xq = jnp.atleast_2d(jnp.asarray(X, jnp.float32))
    d2_top, idx, _guard = knn_quant_scan(
        X_q, q_scale, y2_q, Xq, k=k, mode=mode)
    x2 = jnp.sum(Xq * Xq, axis=-1, keepdims=True)           # (b, 1)
    out = _idw_lambda(d2_top, x2, y2_q[idx, 0], lam_db[idx])
    return out[0] if squeeze else out


# ---------------------------------------------------------------------------
# Ridge-regression predictor (beyond paper)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class LinearLambdaPredictor:
    """Ridge regression lam ~ W x + c, closed form; lam_hat clipped >= 0."""

    W: Array  # (K, d)
    c: Array  # (K,)

    @staticmethod
    def fit(
        X_train: Array, lam_train: Array, l2: float = 1e-3
    ) -> "LinearLambdaPredictor":
        X = jnp.asarray(X_train, jnp.float32)
        Y = jnp.asarray(lam_train, jnp.float32)
        mu_x = jnp.mean(X, axis=0)
        mu_y = jnp.mean(Y, axis=0)
        Xc, Yc = X - mu_x, Y - mu_y
        d = X.shape[1]
        G = Xc.T @ Xc + l2 * jnp.eye(d, dtype=X.dtype)
        W = jnp.linalg.solve(G, Xc.T @ Yc).T               # (K, d)
        c = mu_y - W @ mu_x
        return LinearLambdaPredictor(W=W, c=c)

    def predict(self, X: Array) -> Array:
        return jnp.maximum(X @ self.W.T + self.c, 0.0)


# ---------------------------------------------------------------------------
# MLP predictor (beyond paper)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class MLPLambdaPredictor:
    """Two-layer MLP lam ~ softplus-headed f(x); trained with repo Adam."""

    params: Any

    @staticmethod
    def init_params(key: Array, d_in: int, d_hidden: int, K: int):
        k1, k2 = jax.random.split(key)
        s1 = 1.0 / jnp.sqrt(d_in)
        s2 = 1.0 / jnp.sqrt(d_hidden)
        return {
            "w1": jax.random.normal(k1, (d_in, d_hidden), jnp.float32) * s1,
            "b1": jnp.zeros((d_hidden,), jnp.float32),
            "w2": jax.random.normal(k2, (d_hidden, K), jnp.float32) * s2,
            "b2": jnp.zeros((K,), jnp.float32),
        }

    @staticmethod
    def apply(params, X: Array) -> Array:
        h = jax.nn.relu(X @ params["w1"] + params["b1"])
        # softplus keeps lam_hat >= 0 (dual feasibility) with smooth grads.
        return jax.nn.softplus(h @ params["w2"] + params["b2"])

    @staticmethod
    def fit(
        X_train: Array,
        lam_train: Array,
        *,
        d_hidden: int = 64,
        num_steps: int = 500,
        lr: float = 1e-2,
        seed: int = 0,
        return_trace: bool = False,
        init_params: Any = None,
    ):
        """Full-batch Adam fit as ONE jit dispatch: the training loop is
        a lax.scan inside the compiled program, not `num_steps` Python
        round-trips through the jit cache (the old form paid per-step
        dispatch + host sync ~500 times). The per-step loss trace is
        stacked by the scan for free — pass ``return_trace=True`` to get
        ``(predictor, losses (num_steps,))`` instead of the predictor.

        ``init_params`` warm-starts from an existing parameter pytree
        (``d_hidden``/``seed`` are then ignored) — the refresh lane's
        re-fit path: a few Adam steps from the serving parameters
        instead of a from-scratch train.
        """
        X = jnp.asarray(X_train, jnp.float32)
        Y = jnp.asarray(lam_train, jnp.float32)
        params = init_params if init_params is not None else (
            MLPLambdaPredictor.init_params(
                jax.random.key(seed), X.shape[1], d_hidden, Y.shape[1]))
        opt = adam_init(params)

        def loss_fn(p):
            pred = MLPLambdaPredictor.apply(p, X)
            return jnp.mean((pred - Y) ** 2)

        @partial(jax.jit, static_argnames=("steps",))
        def train(p, o, *, steps):
            def step(carry, _):
                p, o = carry
                loss, g = jax.value_and_grad(loss_fn)(p)
                p, o = adam_update(g, o, p, lr=lr)
                return (p, o), loss

            (p, o), losses = jax.lax.scan(step, (p, o), None, length=steps)
            return p, losses

        params, losses = train(params, opt, steps=num_steps)
        predictor = MLPLambdaPredictor(params=params)
        return (predictor, losses) if return_trace else predictor

    def predict(self, X: Array) -> Array:
        return MLPLambdaPredictor.apply(self.params, X)


PREDICTOR_REGISTRY = {
    "mean": MeanLambdaPredictor,
    "knn": KNNLambdaPredictor,
    "linear": LinearLambdaPredictor,
    "mlp": MLPLambdaPredictor,
}


# ---------------------------------------------------------------------------
# Hot-swap state seam (serving/refresh.py)
# ---------------------------------------------------------------------------

# The ARRAY fields of each family — the refreshable state the serving
# engine threads through its bucket executables as a jit argument.
# Deliberately NOT tree_flatten: KNN's `k` is registered as pytree data
# but must stay a static Python int in the trace. Optional fields (KNN's
# packed-db triple) participate only when PRESENT on the instance —
# state_fields() filters out None-valued entries, so an unquantized
# predictor's state stays exactly {X_db, lam_db} (and its swap
# validation errors unchanged) while a quantized one threads all five
# arrays through the executables and the refresh lane.
STATE_FIELDS = {
    MeanLambdaPredictor: ("mean_lam",),
    KNNLambdaPredictor: ("X_db", "lam_db", "X_q", "q_scale", "y2_q"),
    LinearLambdaPredictor: ("W", "c"),
    MLPLambdaPredictor: ("params",),
}


def state_fields(predictor) -> tuple:
    """The refreshable array fields PRESENT on this instance: the
    family's STATE_FIELDS minus any optional field currently None."""
    return tuple(f for f in STATE_FIELDS.get(type(predictor), ())
                 if getattr(predictor, f, None) is not None)


def predictor_state(predictor) -> dict:
    """The predictor's refreshable array state as a flat dict. Unknown
    (duck-typed) predictor families have no registered state and return
    {} — the engine then closes over them whole, exactly the
    pre-refresh behavior: they serve fine but cannot be hot-swapped."""
    return {f: getattr(predictor, f) for f in state_fields(predictor)}


def with_state(predictor, state: dict):
    """The predictor with its array state replaced by `state` (same
    keys as predictor_state). Non-array statics (KNN's k, quant mode)
    carry over from the template, so a jit trace through the result
    keeps them as Python constants while the state arrays may be
    tracers. An empty state (unknown family) returns the predictor
    unchanged."""
    fields = state_fields(predictor)
    if set(state) != set(fields):
        raise ValueError(f"state keys {sorted(state)} != "
                         f"{sorted(fields)} for "
                         f"{type(predictor).__name__}")
    if not fields:
        return predictor
    return dataclasses.replace(predictor, **state)
