"""Shadow-price predictors f(X) -> lambda (Algorithm 1, online stage).

The paper's estimator is a ball-tree KNN regressor with inverse-distance
weights (k = 10, Euclidean). On TPU a ball tree is pointer-chasing; we use
the *exact same estimator* computed by brute force: a (batch x train_users)
distance matmul that maps perfectly onto the MXU, followed by top-k. For
train databases sharded over the `model` mesh axis the top-k is merged
across shards (lax.top_k per shard -> gather k*shards -> re-top-k), see
`repro.distributed.topk.sharded_knn_topk`.

Beyond-paper predictors (recorded separately in EXPERIMENTS.md):
  * ridge-regression linear predictor (closed form, one (d x d) solve),
  * MLP predictor trained with the repo Adam — both strictly cheaper to
    serve than KNN (no train-database residency) and often as compliant.

All predictors share the interface:
  fit(X_train, lam_train) -> fitted predictor (pytree)
  predict(X) -> lam_hat   (jit-able, vmap-able, shard_map-able)

Hot-swap state seam (serving/refresh.py): `predictor_state` extracts
exactly the ARRAY fields of a predictor (STATE_FIELDS), `with_state`
grafts a compatible state dict back on. The split matters for jit: the
serving engine threads the state dict through its bucket executables as
an ARGUMENT (same pytree structure + shapes/dtypes -> same compile-cache
entry, so refreshing state never recompiles), while non-array statics —
KNN's `k` — stay closed over in the predictor template and keep shaping
the trace (lax.top_k needs a Python int, not a tracer).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.optim import adam_init, adam_update

Array = jax.Array


# ---------------------------------------------------------------------------
# Mean predictor (paper's 'Mean lambda' baseline)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class MeanLambdaPredictor:
    """Intercept-only, covariate-free predictor: lam_hat = mean(lam_train)."""

    mean_lam: Array  # (K,)

    @staticmethod
    def fit(X_train: Array, lam_train: Array) -> "MeanLambdaPredictor":
        del X_train
        return MeanLambdaPredictor(mean_lam=jnp.mean(lam_train, axis=0))

    def predict(self, X: Array) -> Array:
        batch = X.shape[:-1]
        return jnp.broadcast_to(self.mean_lam, batch + self.mean_lam.shape)


# ---------------------------------------------------------------------------
# KNN predictor (paper's proposed 'KNeighbors lambda')
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class KNNLambdaPredictor:
    """Exact k-nearest-neighbour regressor, inverse-distance weighted.

    Identical estimator to the paper's sklearn ball-tree KNN (k=10,
    weights='distance', Euclidean metric); computed by brute force:
      d2(x, xi) = |x|^2 - 2 x.xi + |xi|^2  -> top-k -> 1/d weights.
    The train database (X_db, lam_db) rides along in the pytree so the
    predictor can be donated/sharded like any other model state.
    """

    X_db: Array    # (n_train, d)
    lam_db: Array  # (n_train, K)
    k: int

    @staticmethod
    def fit(X_train: Array, lam_train: Array, k: int = 10) -> "KNNLambdaPredictor":
        return KNNLambdaPredictor(
            X_db=jnp.asarray(X_train), lam_db=jnp.asarray(lam_train), k=int(k)
        )

    def predict(self, X: Array) -> Array:
        # Above the threshold the (b, n_train) distance matrix of the
        # one-matmul path stops fitting comfortably in cache/HBM
        # headroom; the chunked variant streams the train database in
        # (b, chunk) slabs instead, keeping only the running top-k.
        if self.X_db.shape[0] > KNN_CHUNK_THRESHOLD:
            return knn_predict_chunked(self.X_db, self.lam_db, X, k=self.k)
        return knn_predict(self.X_db, self.lam_db, X, k=self.k)


def _idw_lambda(d2_top: Array, x2: Array, y2_sel: Array,
                lam_neighbors: Array) -> Array:
    """Inverse-distance weighting with exact-match override on already
    top-k'd neighbours — the shared tail of the full-matrix and chunked
    KNN paths (identical ops, so the two paths can never drift).

    The expanded-form d2 carries O(eps_f32 * |x|^2) error, so 'exact'
    (query coincides with a database point -> return that point's value,
    sklearn 'distance' weights semantics) is a relative test.
    """
    dist = jnp.sqrt(d2_top)
    scale2 = x2 + y2_sel + 1e-12                            # (b, k)
    exact = d2_top <= 1e-6 * scale2
    any_exact = jnp.any(exact, axis=-1, keepdims=True)
    w_inv = 1.0 / jnp.maximum(dist, 1e-12)
    w = jnp.where(any_exact, exact.astype(d2_top.dtype), w_inv)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return jnp.einsum("bk,bkc->bc", w, lam_neighbors)


@partial(jax.jit, static_argnames=("k",))
def knn_predict(X_db: Array, lam_db: Array, X: Array, *, k: int = 10) -> Array:
    """Inverse-distance-weighted KNN regression, batched over X rows.

    X: (..., d) -> (..., K). Exact: brute-force distances then top-k.
    When a query coincides with a database point (d == 0) the estimator
    returns that point's value (sklearn 'distance' weights semantics).
    """
    squeeze = X.ndim == 1
    Xq = jnp.atleast_2d(X)
    # (b, n) squared distances via the expanded form — one MXU matmul.
    x2 = jnp.sum(Xq * Xq, axis=-1, keepdims=True)          # (b, 1)
    y2 = jnp.sum(X_db * X_db, axis=-1)                      # (n,)
    d2 = x2 - 2.0 * (Xq @ X_db.T) + y2[None, :]             # (b, n)
    d2 = jnp.maximum(d2, 0.0)
    neg_top, idx = jax.lax.top_k(-d2, k)                    # (b, k)
    out = _idw_lambda(-neg_top, x2, y2[idx], lam_db[idx])
    return out[0] if squeeze else out


# Above this many train rows KNNLambdaPredictor.predict switches to the
# chunked path: the one-matmul form's (b, n_train) distance matrix is
# n_train * 4 bytes PER QUERY ROW — at 10^6 train users and batch 32
# that is a 128 MB materialization for 10 neighbours.
KNN_CHUNK_THRESHOLD = 32_768


def knn_topk_scan(
    X_db: Array, Xq: Array, *, k: int = 10, chunk: int = 8192
) -> tuple[Array, Array]:
    """Streaming top-k of -d2: the database scans through in
    `chunk`-row slabs and the carry is only the running top-k
    (neg-d2, global index) per query — O(b * chunk) peak distance
    storage, no (b, n_train) matrix ever.

    Ties break exactly like the one-matmul path (lower global index:
    the running buffer precedes the fresh slab in the merge). Returns
    (neg_top (b, k) descending, idx (b, k)). This is the slab sweep
    shared by knn_predict_chunked and the sharded serving body
    (core.serving_dist.knn_predict_distributed), where it serves as the
    per-shard local selection ahead of the cross-shard merge.
    """
    b = Xq.shape[0]
    n, d = X_db.shape
    if n < k:
        raise ValueError(f"n_train={n} < k={k}")
    x2 = jnp.sum(Xq * Xq, axis=-1, keepdims=True)           # (b, 1)
    # pad with far-away rows (never top-k when n >= k real rows exist)
    pad = (-n) % chunk
    Xdb_p = jnp.pad(X_db, ((0, pad), (0, 0)), constant_values=1e15)
    db_slabs = Xdb_p.reshape(-1, chunk, d)
    bases = jnp.arange(db_slabs.shape[0], dtype=jnp.int32) * chunk

    def body(carry, xs):
        run_v, run_i = carry                                # (b, k) each
        db, base = xs                                       # (chunk, d), ()
        y2c = jnp.sum(db * db, axis=-1)                     # (chunk,)
        d2 = jnp.maximum(x2 - 2.0 * (Xq @ db.T) + y2c[None, :], 0.0)
        cand_v = jnp.concatenate([run_v, -d2], axis=-1)     # (b, k+chunk)
        gidx = base + jnp.broadcast_to(
            jnp.arange(chunk, dtype=jnp.int32), (b, chunk))
        cand_i = jnp.concatenate([run_i, gidx], axis=-1)
        new_v, sel = jax.lax.top_k(cand_v, k)
        new_i = jnp.take_along_axis(cand_i, sel, axis=-1)
        return (new_v, new_i), None

    init = (jnp.full((b, k), -jnp.inf, Xq.dtype),
            jnp.zeros((b, k), jnp.int32))
    (neg_top, idx), _ = jax.lax.scan(body, init, (db_slabs, bases))
    return neg_top, idx


@partial(jax.jit, static_argnames=("k", "chunk"))
def knn_predict_chunked(
    X_db: Array, lam_db: Array, X: Array, *, k: int = 10, chunk: int = 8192
) -> Array:
    """knn_predict for large train databases: identical estimator,
    built on the knn_topk_scan slab sweep. The final weighting is the
    shared _idw_lambda on k gathered neighbours.
    """
    squeeze = X.ndim == 1
    Xq = jnp.atleast_2d(X)
    neg_top, idx = knn_topk_scan(X_db, Xq, k=k, chunk=chunk)
    x2 = jnp.sum(Xq * Xq, axis=-1, keepdims=True)           # (b, 1)
    y2 = jnp.sum(X_db * X_db, axis=-1)                      # (n,) — cheap
    out = _idw_lambda(-neg_top, x2, y2[idx], lam_db[idx])
    return out[0] if squeeze else out


# ---------------------------------------------------------------------------
# Ridge-regression predictor (beyond paper)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class LinearLambdaPredictor:
    """Ridge regression lam ~ W x + c, closed form; lam_hat clipped >= 0."""

    W: Array  # (K, d)
    c: Array  # (K,)

    @staticmethod
    def fit(
        X_train: Array, lam_train: Array, l2: float = 1e-3
    ) -> "LinearLambdaPredictor":
        X = jnp.asarray(X_train, jnp.float32)
        Y = jnp.asarray(lam_train, jnp.float32)
        mu_x = jnp.mean(X, axis=0)
        mu_y = jnp.mean(Y, axis=0)
        Xc, Yc = X - mu_x, Y - mu_y
        d = X.shape[1]
        G = Xc.T @ Xc + l2 * jnp.eye(d, dtype=X.dtype)
        W = jnp.linalg.solve(G, Xc.T @ Yc).T               # (K, d)
        c = mu_y - W @ mu_x
        return LinearLambdaPredictor(W=W, c=c)

    def predict(self, X: Array) -> Array:
        return jnp.maximum(X @ self.W.T + self.c, 0.0)


# ---------------------------------------------------------------------------
# MLP predictor (beyond paper)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class MLPLambdaPredictor:
    """Two-layer MLP lam ~ softplus-headed f(x); trained with repo Adam."""

    params: Any

    @staticmethod
    def init_params(key: Array, d_in: int, d_hidden: int, K: int):
        k1, k2 = jax.random.split(key)
        s1 = 1.0 / jnp.sqrt(d_in)
        s2 = 1.0 / jnp.sqrt(d_hidden)
        return {
            "w1": jax.random.normal(k1, (d_in, d_hidden), jnp.float32) * s1,
            "b1": jnp.zeros((d_hidden,), jnp.float32),
            "w2": jax.random.normal(k2, (d_hidden, K), jnp.float32) * s2,
            "b2": jnp.zeros((K,), jnp.float32),
        }

    @staticmethod
    def apply(params, X: Array) -> Array:
        h = jax.nn.relu(X @ params["w1"] + params["b1"])
        # softplus keeps lam_hat >= 0 (dual feasibility) with smooth grads.
        return jax.nn.softplus(h @ params["w2"] + params["b2"])

    @staticmethod
    def fit(
        X_train: Array,
        lam_train: Array,
        *,
        d_hidden: int = 64,
        num_steps: int = 500,
        lr: float = 1e-2,
        seed: int = 0,
        return_trace: bool = False,
        init_params: Any = None,
    ):
        """Full-batch Adam fit as ONE jit dispatch: the training loop is
        a lax.scan inside the compiled program, not `num_steps` Python
        round-trips through the jit cache (the old form paid per-step
        dispatch + host sync ~500 times). The per-step loss trace is
        stacked by the scan for free — pass ``return_trace=True`` to get
        ``(predictor, losses (num_steps,))`` instead of the predictor.

        ``init_params`` warm-starts from an existing parameter pytree
        (``d_hidden``/``seed`` are then ignored) — the refresh lane's
        re-fit path: a few Adam steps from the serving parameters
        instead of a from-scratch train.
        """
        X = jnp.asarray(X_train, jnp.float32)
        Y = jnp.asarray(lam_train, jnp.float32)
        params = init_params if init_params is not None else (
            MLPLambdaPredictor.init_params(
                jax.random.key(seed), X.shape[1], d_hidden, Y.shape[1]))
        opt = adam_init(params)

        def loss_fn(p):
            pred = MLPLambdaPredictor.apply(p, X)
            return jnp.mean((pred - Y) ** 2)

        @partial(jax.jit, static_argnames=("steps",))
        def train(p, o, *, steps):
            def step(carry, _):
                p, o = carry
                loss, g = jax.value_and_grad(loss_fn)(p)
                p, o = adam_update(g, o, p, lr=lr)
                return (p, o), loss

            (p, o), losses = jax.lax.scan(step, (p, o), None, length=steps)
            return p, losses

        params, losses = train(params, opt, steps=num_steps)
        predictor = MLPLambdaPredictor(params=params)
        return (predictor, losses) if return_trace else predictor

    def predict(self, X: Array) -> Array:
        return MLPLambdaPredictor.apply(self.params, X)


PREDICTOR_REGISTRY = {
    "mean": MeanLambdaPredictor,
    "knn": KNNLambdaPredictor,
    "linear": LinearLambdaPredictor,
    "mlp": MLPLambdaPredictor,
}


# ---------------------------------------------------------------------------
# Hot-swap state seam (serving/refresh.py)
# ---------------------------------------------------------------------------

# The ARRAY fields of each family — the refreshable state the serving
# engine threads through its bucket executables as a jit argument.
# Deliberately NOT tree_flatten: KNN's `k` is registered as pytree data
# but must stay a static Python int in the trace.
STATE_FIELDS = {
    MeanLambdaPredictor: ("mean_lam",),
    KNNLambdaPredictor: ("X_db", "lam_db"),
    LinearLambdaPredictor: ("W", "c"),
    MLPLambdaPredictor: ("params",),
}


def predictor_state(predictor) -> dict:
    """The predictor's refreshable array state as a flat dict. Unknown
    (duck-typed) predictor families have no registered state and return
    {} — the engine then closes over them whole, exactly the
    pre-refresh behavior: they serve fine but cannot be hot-swapped."""
    fields = STATE_FIELDS.get(type(predictor), ())
    return {f: getattr(predictor, f) for f in fields}


def with_state(predictor, state: dict):
    """The predictor with its array state replaced by `state` (same
    keys as predictor_state). Non-array statics (KNN's k) carry over
    from the template, so a jit trace through the result keeps them as
    Python constants while the state arrays may be tracers. An empty
    state (unknown family) returns the predictor unchanged."""
    fields = STATE_FIELDS.get(type(predictor), ())
    if set(state) != set(fields):
        raise ValueError(f"state keys {sorted(state)} != "
                         f"{sorted(fields)} for "
                         f"{type(predictor).__name__}")
    if not fields:
        return predictor
    return dataclasses.replace(predictor, **state)
