"""RankingPipeline — Algorithm 1 of the paper as a deployable module.

Offline stage (speed non-critical, runs as one batched accelerator program):
  1. For each train user l: solve the dual LP for optimal shadow prices
     lambda^(l)  (repro.core.dual_solver, batched subgradient).
  2. Fit a predictor f(X) -> lambda on (covariates, shadow prices).
  3. Tune the epsilon tie-break on the train subset (paper footnote 3:
     grid {0} U {i * 10^-j}).

Online stage (the < 50 ms hot path):
  4. Predict lam_hat = f(X) for the incoming user.
  5. Rank by s = u + (1 + eps) * lam_hat @ a — a sort (rearrangement
     inequality) or the fused Pallas kernel repro.kernels.fused_rank.

The pipeline also exposes the paper's four benchmark strategies
('none' / 'optimal' / 'mean' / 'knn', plus beyond-paper 'linear'/'mlp')
behind one `rank_with_strategy` entry point so benchmarks/fig2 can sweep
them uniformly.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from functools import partial
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.assignment import rank_by_sort
from repro.core.constraints import ConstraintSet
from repro.core.dual_solver import DualSolution, solve_dual_batch
from repro.core.predictors import (
    KNNLambdaPredictor,
    LinearLambdaPredictor,
    MLPLambdaPredictor,
    MeanLambdaPredictor,
)

Array = jax.Array

# Paper footnote 3: eps candidate grid {0} U {i*10^-j | i in 1:9, j in 1:4}.
# Kept in ascending order for readability; tune_eps sorts whatever grid it
# is given, so the "ties -> smaller eps" rule never depends on grid order.
EPS_GRID = tuple([0.0] + [i * 10.0 ** (-j) for j in range(4, 0, -1) for i in range(1, 10)])

# Compliance slack: exposure >= b - AUDIT_TOL counts as satisfied. Shared by
# every audit path (jnp, kernel flush, distributed merge).
AUDIT_TOL = 1e-6


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class RankingOutput:
    """Batched serving result."""

    perm: Array        # (n, m2) item index per rank
    utility: Array     # (n,) tr(U^T P) under the *primary* utility
    exposure: Array    # (n, K)
    compliant: Array   # (n,) bool
    lam: Array         # (n, K) shadow prices used


@dataclass(frozen=True)
class RankingPipeline:
    """Fitted pipeline state. Frozen dataclass (not a pytree: holds ints &
    heterogeneous predictors); its arrays live inside the predictor pytrees."""

    m2: int
    gamma: Array
    eps: float
    predictors: dict[str, Any]
    lam_train: Array      # (n_train, K) optimal shadow prices (offline)
    train_solution: DualSolution


# ---------------------------------------------------------------------------
# Offline stage
# ---------------------------------------------------------------------------

def offline_solve(
    u_train: Array,
    a_train: Array,
    b: Array,
    gamma: Array,
    *,
    m2: int,
    num_iters: int = 400,
) -> DualSolution:
    """Batched dual solve over the train users (Algorithm 1, offline loop)."""
    return solve_dual_batch(u_train, a_train, b, gamma, m2=m2, num_iters=num_iters)


def tune_eps(
    u: Array, a: Array, b: Array, lam: Array, gamma: Array, *, m2: int,
    grid=EPS_GRID,
) -> float:
    """Pick eps minimizing train-set constraint-violation probability
    (ties -> smaller eps), per paper footnote 3.

    The strict-improvement comparison keeps the FIRST grid point reaching
    the minimum, so the grid is iterated in ascending order regardless of
    how the caller's `grid` is arranged — a descending (or interleaved,
    like the i*10^-j enumeration) sweep would keep a larger eps on ties.
    """
    best_eps, best_viol = 0.0, np.inf
    for eps in sorted(float(e) for e in grid):
        out = rank_given_lambda(u, a, b, lam, gamma, m2=m2, eps=float(eps))
        viol = float(jnp.mean(1.0 - out.compliant.astype(jnp.float32)))
        if viol < best_viol - 1e-12:
            best_viol, best_eps = viol, float(eps)
    return best_eps


def fit_pipeline(
    X_train: Array,
    u_train: Array,
    a_train: Array,
    b: Array,
    gamma: Array,
    *,
    m2: int,
    num_iters: int = 400,
    knn_k: int = 10,
    with_mlp: bool = False,
    mlp_steps: int = 300,
) -> RankingPipeline:
    """Full offline stage: dual solve -> fit all predictors -> tune eps."""
    sol = offline_solve(u_train, a_train, b, gamma, m2=m2, num_iters=num_iters)
    lam_train = sol.lam
    predictors: dict[str, Any] = {
        "mean": MeanLambdaPredictor.fit(X_train, lam_train),
        "knn": KNNLambdaPredictor.fit(X_train, lam_train, k=knn_k),
        "linear": LinearLambdaPredictor.fit(X_train, lam_train),
    }
    if with_mlp:
        predictors["mlp"] = MLPLambdaPredictor.fit(
            X_train, lam_train, num_steps=mlp_steps
        )
    eps = tune_eps(u_train, a_train, b, lam_train, gamma, m2=m2)
    return RankingPipeline(
        m2=m2, gamma=gamma, eps=eps, predictors=predictors,
        lam_train=lam_train, train_solution=sol,
    )


# ---------------------------------------------------------------------------
# Online stage
# ---------------------------------------------------------------------------

def audit_selected(
    u_sel: Array,       # (..., m2) selected items' raw utilities
    a_sel: Array,       # (..., K, m2) selected items' attribute values
    gamma: Array,       # (..., m2) slot discounts
    b: Array,           # (..., K) exposure thresholds
    *,
    tol: float = AUDIT_TOL,
):
    """The audit epilogue on already-SELECTED per-slot values: utility,
    per-constraint exposure, and compliance. This is the single source of
    truth for the audit math — used by the jnp path (rank_given_lambda),
    the distributed merge (core.serving_dist), the XLA fallback oracle
    (kernels.ref.rank_audited_ref); the Pallas rank+audit kernel's flush
    step mirrors it op-for-op in VMEM so outputs stay bitwise identical.

    Written as multiply + last-axis reductions (not einsum) so the jnp
    and in-kernel lowerings accumulate in the same order.
    """
    utility = jnp.sum(u_sel * gamma, axis=-1)                    # (...,)
    exposure = jnp.sum(a_sel * gamma[..., None, :], axis=-1)     # (..., K)
    compliant = jnp.all(exposure >= b - tol, axis=-1)            # (...,)
    return utility, exposure, compliant


@partial(jax.jit, static_argnames=("m2", "eps", "backend"))
def rank_given_lambda(
    u: Array,           # (n, m1)
    a: Array,           # (n, K, m1) or (K, m1)
    b: Array,           # (n, K) or (K,)
    lam: Array,         # (n, K)
    gamma: Array,       # (m2,) or (n, m2)
    *,
    m2: int,
    eps: float = 1e-4,
    backend: str = "xla",
) -> RankingOutput:
    """The hot path, batched: s = u + (1+eps) lam @ a; top-m2 by s.

    ``backend='xla'`` is the pure-jnp reference. ``backend='kernel'``
    routes through the fused Pallas rank+audit kernel
    (repro.kernels.ops.rank_audited): selection AND the audit epilogue
    happen inside one VMEM sweep — no post-kernel reads of ``u``/``a``
    (it degrades to this XLA path itself when the kernel's static
    constraints don't hold, e.g. m2 > MAX_KERNEL_M2).

    ``gamma`` may be per-request (n, m2): shape-bucketed serving pads
    requests with fewer real slots by zeroing their trailing discounts,
    which leaves utility/exposure/compliance identical to the unpadded
    problem (repro.serving.buckets).
    """
    if backend == "kernel":
        from repro.kernels.ops import rank_audited  # deferred: no cycle

        return rank_audited(u, a, b, lam, gamma, m2=m2, eps=eps)
    if backend != "xla":
        raise ValueError(f"unknown backend {backend!r}")
    if a.ndim == 2:
        a = jnp.broadcast_to(a, (u.shape[0],) + a.shape)
    if b.ndim == 1:
        b = jnp.broadcast_to(b, (u.shape[0],) + b.shape)
    if gamma.ndim == 1:
        gamma = jnp.broadcast_to(gamma, (u.shape[0],) + gamma.shape)
    s = u + (1.0 + eps) * jnp.einsum("nk,nkm->nm", lam, a)
    perm = rank_by_sort(s, m2)                                   # (n, m2)
    u_sel = jnp.take_along_axis(u, perm, axis=-1)                # (n, m2)
    # broadcast gather: perm (n, 1, m2) indexes every constraint row
    # without materializing an (n, K, m2) index tensor
    a_sel = jnp.take_along_axis(a, perm[:, None, :], axis=-1)    # (n, K, m2)
    utility, exposure, compliant = audit_selected(u_sel, a_sel, gamma, b)
    return RankingOutput(
        perm=perm, utility=utility, exposure=exposure,
        compliant=compliant, lam=lam,
    )


def serve(
    pipe: RankingPipeline,
    X: Array,            # (n, d) user covariates
    u: Array,            # (n, m1) utilities from the recommender backbone
    a: Array,            # (n, K, m1) or (K, m1)
    b: Array,            # (n, K) or (K,)
    *,
    predictor: str = "knn",
    backend: str = "xla",
) -> RankingOutput:
    """Online serving: predict lam_hat from covariates, then rank.

    ``backend='kernel'`` collapses the whole online stage into ONE
    device program via kernels.ops.predict_rank_audited — the affine
    predictor families fold λ̂ into the rank kernel's VMEM prologue,
    KNN fuses its inverse-distance weighting into the database sweep,
    and the MLP joins the same executable — instead of a predict
    program whose λ̂ round-trips HBM ahead of a rank program.
    """
    if backend == "kernel":
        from repro.kernels.ops import predict_rank_audited  # no cycle

        return predict_rank_audited(
            X, pipe.predictors[predictor], u, a, b, pipe.gamma,
            m2=pipe.m2, eps=pipe.eps)
    if backend != "xla":
        raise ValueError(f"unknown backend {backend!r}")
    lam_hat = pipe.predictors[predictor].predict(X)
    return rank_given_lambda(
        u, a, b, lam_hat, pipe.gamma, m2=pipe.m2, eps=pipe.eps
    )


def rank_with_strategy(
    pipe: RankingPipeline,
    strategy: str,
    X: Array,
    u: Array,
    a: Array,
    b: Array,
    *,
    dual_iters: int = 400,
) -> RankingOutput:
    """The paper's Fig-2 strategy sweep entry point.

    'none'     lam = 0 (no constraint accounting)
    'optimal'  solve the dual per holdout user (time-intensive benchmark)
    'mean' / 'knn' / 'linear' / 'mlp'  -> fitted predictors
    """
    n, K = u.shape[0], pipe.lam_train.shape[1]
    if strategy == "none":
        lam = jnp.zeros((n, K), u.dtype)
        return rank_given_lambda(u, a, b, lam, pipe.gamma, m2=pipe.m2, eps=0.0)
    if strategy == "optimal":
        sol = solve_dual_batch(u, a, b, pipe.gamma, m2=pipe.m2, num_iters=dual_iters)
        return rank_given_lambda(
            u, a, b, sol.lam, pipe.gamma, m2=pipe.m2, eps=pipe.eps
        )
    return serve(pipe, X, u, a, b, predictor=strategy)


def with_predictor(pipe: RankingPipeline, name: str, predictor: Any) -> RankingPipeline:
    preds = dict(pipe.predictors)
    preds[name] = predictor
    return replace(pipe, predictors=preds)
