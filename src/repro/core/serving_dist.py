"""Distributed (fleet-scale) variants of the online serving stage.

Beyond-paper optimizations, recorded separately in EXPERIMENTS.md §Perf:
the paper's online stage is per-user CPU code; at fleet scale the
GSPMD-global formulation (core/predictors.knn_predict + a global top_k)
makes XLA all-gather the (batch x n_db) distance matrix over the model
axis before selecting. These shard_map versions move only k candidates
per shard across the interconnect:

  knn_predict_distributed   per-shard distances + local top-k -> merge
                            (collective: B*k*shards*12 bytes, down from
                            B*n_db*4)
  rank_distributed          adjusted scores + top-m2 with the item axis
                            sharded (serve_retrieval's 2^20 candidates)

Numerically identical to the dense versions (exact KNN, exact top-k) —
asserted in tests/test_multidevice.py.

These are the rank bodies the streaming engine bakes into its
per-bucket executables when constructed with ``executor='dist'`` and a
mesh (repro.serving.engine._rank_fn): the engine's submission side
dispatches them asynchronously like any other bucket executable, and
nothing in this module blocks — the only host-side wait lives in the
engine pipeline's materialization step (and in ``warmup``). shard_map
/ set_mesh go through repro.distributed.compat (see its docstring for
when those shims can be dropped).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.predictors import knn_topk_scan
from repro.distributed.compat import shard_map
from repro.distributed.topk import distributed_top_k, gather_merge_top_k

Array = jax.Array


def knn_predict_distributed(
    mesh: Mesh,
    X_db: Array,     # (n_db, d) row-sharded over `db_axis`
    lam_db: Array,   # (n_db, K) REPLICATED (tiny: n_db*K floats)
    X: Array,        # (B, d) sharded over batch axes
    *,
    k: int = 10,
    db_axis: str = "model",
    batch_axes=("pod", "data"),
    chunk: int = 8192,
) -> Array:
    """Inverse-distance-weighted KNN regression, database sharded by rows.

    Matches core.predictors.knn_predict exactly (same weighting and
    relative exact-match override). The per-shard selection is the
    knn_topk_scan slab sweep — the db shard streams through in
    (B_l, chunk) slabs with only the running top-k as carry, so the
    (B_l, n_l) per-shard distance matrix of the old body never
    materializes (at 10^6 rows over 8 shards that matrix was
    B_l * 125k * 4 bytes per shard). The |x_n|^2 norms needed for the
    exact-match override are gathered per selected neighbour and ride
    the cross-shard merge — nothing database-sized crosses the
    interconnect OR sits in shard-local HBM beyond one slab.
    """
    batch_axes = tuple(a for a in batch_axes if a in mesh.axis_names)

    def body(xq, xdb_local, lam_all):
        n_l = xdb_local.shape[0]
        kk = min(k, n_l)
        x2 = jnp.sum(xq * xq, axis=-1, keepdims=True)        # (B_l, 1)
        neg_v, idx_l = knn_topk_scan(xdb_local, xq, k=kk,
                                     chunk=min(chunk, n_l))
        y2l = jnp.sum(xdb_local * xdb_local, axis=-1)        # (n_l,)
        y2_sel_l = y2l[idx_l]                                # (B_l, kk)
        gidx = idx_l + jax.lax.axis_index(db_axis) * n_l
        neg_d2, idx, y2_sel = gather_merge_top_k(
            neg_v, gidx, k, db_axis, payload=y2_sel_l)
        d2k = -neg_d2                                        # (B_l, k) asc
        lam_nb = lam_all[idx]                                # (B_l, k, K)
        scale2 = x2 + y2_sel + 1e-12
        exact = d2k <= 1e-6 * scale2
        any_exact = jnp.any(exact, axis=-1, keepdims=True)
        w_inv = 1.0 / jnp.maximum(jnp.sqrt(d2k), 1e-12)
        w = jnp.where(any_exact, exact.astype(d2k.dtype), w_inv)
        w = w / jnp.sum(w, axis=-1, keepdims=True)
        return jnp.einsum("bk,bkc->bc", w, lam_nb)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_axes, None), P(db_axis, None), P()),
        out_specs=P(batch_axes, None),
        check_vma=False,
    )(X, X_db, lam_db)


def knn_predict_quant_distributed(
    mesh: Mesh,
    X_q: Array,      # (n_db, d) packed db rows, row-sharded over `db_axis`
    q_scale: Array,  # (n_slabs, 1) per-slab scales, row-sharded likewise
    y2_q: Array,     # (n_db, 1) exact |x̃|^2, row-sharded likewise
    lam_db: Array,   # (n_db, K) REPLICATED (tiny: n_db*K floats)
    X: Array,        # (B, d) sharded over batch axes
    *,
    k: int = 10,
    mode: str = "int8",
    db_axis: str = "model",
    batch_axes=("pod", "data"),
) -> Array:
    """knn_predict_distributed over a QUANTIZED row-sharded db: each
    shard runs the quantized slab sweep + exact f32 survivor re-score
    (core.predictors.knn_quant_scan) on its rows, so the values that
    cross the interconnect are already EXACT-on-x̃ — the k·shards
    merge (gather_merge_top_k) and the inline IDW tail are untouched
    from the f32 path, and the result matches the dense
    knn_predict_quant selection bitwise (each shard's exact local
    top-k is a superset of its contribution to the global top-k; ties
    resolve to the lowest global index on both paths).

    Contract: pack with pack_knn_db at a slab that divides the
    per-shard row count so the global pack row-shards cleanly with no
    pad rows (X_q.shape[0] == lam_db.shape[0]) and each shard holds
    whole slabs with their scales.
    """
    if X_q.shape[0] != lam_db.shape[0]:
        raise ValueError(
            f"sharded quantized db must carry no pad rows: X_q has "
            f"{X_q.shape[0]} rows but lam_db {lam_db.shape[0]} — pack "
            f"with a slab dividing the per-shard row count")
    batch_axes = tuple(a for a in batch_axes if a in mesh.axis_names)

    def body(xq, dbq_l, scale_l, y2q_l, lam_all):
        from repro.core.predictors import knn_quant_scan  # deferred

        n_l = dbq_l.shape[0]
        kk = min(k, n_l)
        x2 = jnp.sum(xq * xq, axis=-1, keepdims=True)        # (B_l, 1)
        # exact-on-x̃ local top-k: quantized sweep, exact re-score
        d2_l, idx_l, _ = knn_quant_scan(dbq_l, scale_l, y2q_l, xq,
                                        k=kk, mode=mode)
        y2_sel_l = y2q_l[idx_l, 0]                           # (B_l, kk)
        gidx = idx_l + jax.lax.axis_index(db_axis) * n_l
        neg_d2, idx, y2_sel = gather_merge_top_k(
            -d2_l, gidx, k, db_axis, payload=y2_sel_l)
        d2k = -neg_d2                                        # (B_l, k) asc
        lam_nb = lam_all[idx]                                # (B_l, k, K)
        scale2 = x2 + y2_sel + 1e-12
        exact = d2k <= 1e-6 * scale2
        any_exact = jnp.any(exact, axis=-1, keepdims=True)
        w_inv = 1.0 / jnp.maximum(jnp.sqrt(d2k), 1e-12)
        w = jnp.where(any_exact, exact.astype(d2k.dtype), w_inv)
        w = w / jnp.sum(w, axis=-1, keepdims=True)
        return jnp.einsum("bk,bkc->bc", w, lam_nb)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_axes, None), P(db_axis, None),
                  P(db_axis, None), P(db_axis, None), P()),
        out_specs=P(batch_axes, None),
        check_vma=False,
    )(X, X_q, q_scale, y2_q, lam_db)


def rank_distributed(
    mesh: Mesh,
    u: Array,        # (B, m1) items sharded over `item_axis`
    a: Array,        # (K, m1) shared or (B, K, m1) per-request, items sharded
    b: Array,        # (K,) shared or (B, K) per-request
    lam: Array,      # (B, K) sharded over batch axes
    gamma: Array,    # (m2,) shared or (B, m2) per-request
    *,
    m2: int,
    eps: float = 1e-4,
    item_axis: str = "model",
    batch_axes=("pod", "data"),
):
    """Online ranking with the item/candidate axis sharded: adjusted
    scores computed per item shard, local top-m2 per shard, merge of
    m2*shards candidates. Raw utilities AND the K constraint-attribute
    rows ride the merge as payloads, so utility / exposure / compliance
    need no second gather: the merged payloads feed the shared audit
    epilogue (core.ranking.audit_selected — the same math the Pallas
    rank+audit kernel runs in VMEM) and the outputs match
    rank_given_lambda exactly.

    Accepts the same shared-vs-per-request broadcast forms as
    rank_given_lambda (per-request a/b/gamma is what the shape-bucketed
    serving engine feeds when a mesh is present).

    Returns a RankingOutput.
    """
    from repro.core.ranking import RankingOutput, audit_selected

    batch_axes = tuple(ax for ax in batch_axes if ax in mesh.axis_names)
    a_spec = (P(batch_axes, None, item_axis) if a.ndim == 3
              else P(None, item_axis))
    b_spec = P(batch_axes, None) if b.ndim == 2 else P()
    gamma_spec = P(batch_axes, None) if gamma.ndim == 2 else P()

    def body(u_l, a_l, b_r, lam_l, gamma_r):
        B_l = u_l.shape[0]
        if a_l.ndim == 2:
            a_l = jnp.broadcast_to(a_l[None], (B_l,) + a_l.shape)
        if gamma_r.ndim == 1:
            gamma_r = jnp.broadcast_to(gamma_r[None], (B_l,) + gamma_r.shape)
        s = u_l + (1.0 + eps) * jnp.einsum("bk,bkm->bm", lam_l, a_l)
        payload = {"u": u_l,
                   "a": jnp.moveaxis(a_l, 1, 0)}              # (K, B_l, m1_l)
        vals, idx, sel = distributed_top_k(s, m2, item_axis, payload=payload)
        utility, exposure, compliant = audit_selected(
            sel["u"], jnp.moveaxis(sel["a"], 0, 1), gamma_r, b_r)
        return RankingOutput(perm=idx, utility=utility, exposure=exposure,
                             compliant=compliant, lam=lam_l)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_axes, item_axis), a_spec, b_spec,
                  P(batch_axes, None), gamma_spec),
        out_specs=RankingOutput(
            perm=P(batch_axes, None), utility=P(batch_axes),
            exposure=P(batch_axes, None), compliant=P(batch_axes),
            lam=P(batch_axes, None)),
        check_vma=False,
    )(u, a, b, lam, gamma)
