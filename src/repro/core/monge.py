"""(Inverse) Monge structure checks and rearrangement utilities.

A matrix S is *inverse Monge* iff for all i1 < i2, j1 < j2:
    S[i1,j1] + S[i2,j2] >= S[i1,j2] + S[i2,j1]
which is equivalent to the adjacent condition
    S[i,j] + S[i+1,j+1] >= S[i,j+1] + S[i+1,j]   for all i, j.

For inverse Monge S the identity permutation is an optimal assignment
(Burkard et al. 1996); `S = s gamma^T` with s, gamma non-increasing is
inverse Monge (paper Appendix A, footnote 10).

S is *permuted inverse Monge* if sorting its rows (by any column when the
structure is fixed-discounting: all columns induce the same order) makes it
inverse Monge. The paper's O(m log m) ranking = sort rows on first column +
identity permutation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def is_inverse_monge(S: Array, atol: float = 1e-6) -> Array:
    """Adjacent 2x2 minor check; returns scalar bool."""
    lhs = S[:-1, :-1] + S[1:, 1:]
    rhs = S[:-1, 1:] + S[1:, :-1]
    return jnp.all(lhs + atol >= rhs)


def is_permuted_inverse_monge(S: Array, atol: float = 1e-6) -> Array:
    """True if sorting rows by the first column yields inverse Monge."""
    order = jnp.argsort(-S[:, 0])
    return is_inverse_monge(S[order], atol=atol)


def monge_defect(S: Array) -> Array:
    """max violation of the adjacent inverse-Monge condition (0 = Monge).

    Used by tests and by the serving path to decide between the O(m log m)
    sort route and the general auction route (paper Sec. 3.2.2)."""
    lhs = S[:-1, :-1] + S[1:, 1:]
    rhs = S[:-1, 1:] + S[1:, :-1]
    return jnp.maximum(jnp.max(rhs - lhs), 0.0)
