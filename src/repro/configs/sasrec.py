"""sasrec — self-attentive sequential recommendation [arXiv:1808.09781; paper].

embed_dim=50 n_blocks=2 n_heads=1 seq_len=50 interaction=self-attn-seq.
10^6-item catalogue, sampled softmax (1 + 127 negatives).
"""

from repro.configs.recsys_family import recsys_arch
from repro.configs.registry import register

FULL = dict(n_items=1_000_000, embed_dim=50, n_blocks=2, n_heads=1,
            seq_len=50)
SMOKE = dict(n_items=1000, embed_dim=16, n_blocks=2, n_heads=1, seq_len=12)

SPEC = register(recsys_arch(
    "sasrec", "sasrec", FULL, SMOKE,
    variants={
        # the 10^6 x 50 table is only 200 MB: replicating beats
        # row-sharding (all lookup/negative gathers become local)
        "replicated-table": dict(replicate_tables=True),
    },
))
