"""paper-ranking — the paper's own pipeline as a first-class arch.

Three cells (beyond the 40 assigned cells; these drive §Perf for the
technique itself):

  offline_dual     batched dual solve: 8 192 users x (m1=1000, K=5) per
                   step — Algorithm 1's offline stage as one program.
  serve_online     the < 50 ms online stage at fleet batch: KNN shadow
                   prices over a 1M-user database + adjusted-score
                   ranking, 8 192 users/step, m1=1000 -> top-50.
  serve_retrieval  the large-m1 regime: 2^20 candidates per user,
                   batch 256 -> constrained top-50.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ArchSpec, Cell, Lowerable, register, sds
from repro.core.constraints import dcg_discount
from repro.core.dual_solver import solve_dual_batch
from repro.core.predictors import knn_predict
from repro.core.ranking import rank_given_lambda
from repro.distributed.sharding import PAPER_RULES, filter_rules

PAPER_CELLS = (
    # m1 = 1024: the paper's 1000-object scenario, padded to the mesh
    Cell("offline_dual", "offline",
         {"batch": 8192, "m1": 1024, "K": 5, "m2": 50, "iters": 300}),
    Cell("serve_online", "serve",
         {"batch": 8192, "m1": 1024, "K": 5, "m2": 50, "d_cov": 20,
          "n_db": 1_048_576}),
    Cell("serve_retrieval", "serve_retrieval",
         {"batch": 256, "m1": 1_048_576, "K": 5, "m2": 50, "d_cov": 20,
          "n_db": 65536}),
)

PAPER_SMOKE_CELLS = (
    Cell("offline_dual", "offline",
         {"batch": 8, "m1": 64, "K": 3, "m2": 16, "iters": 50}),
    Cell("serve_online", "serve",
         {"batch": 8, "m1": 64, "K": 3, "m2": 16, "d_cov": 10, "n_db": 128}),
    Cell("serve_retrieval", "serve_retrieval",
         {"batch": 4, "m1": 1024, "K": 3, "m2": 16, "d_cov": 10,
          "n_db": 128}),
)


@dataclass(frozen=True)
class PaperConfig:
    name: str = "paper-ranking"
    knn_k: int = 10
    eps: float = 1e-4
    dual_iters: int = 300
    # §Perf variant: shard_map distributed KNN + top-k (k per shard
    # crosses the interconnect instead of the full distance matrix)
    distributed: bool = False


def build_paper(cfg: PaperConfig, cell: Cell, mesh) -> Lowerable:
    rules = filter_rules(PAPER_RULES, mesh)
    B, m1, K, m2 = cell["batch"], cell["m1"], cell["K"], cell["m2"]
    gamma = dcg_discount(m2)
    batch_sh = NamedSharding(mesh, rules.resolve("batch"))
    u_sh = NamedSharding(mesh, rules.resolve("batch", "items"))
    a_sh = NamedSharding(mesh, rules.resolve("batch", None, "items"))
    rep = NamedSharding(mesh, P())

    u = sds((B, m1), jnp.float32, u_sh)
    a = sds((B, K, m1), jnp.float32, a_sh)
    b = sds((K,), jnp.float32, rep)

    if cell.kind == "offline":
        iters = cell["iters"]

        def fn(u, a, b):
            return solve_dual_batch(u, a, b, gamma, m2=m2, num_iters=iters)

        return Lowerable(fn=fn, args=(u, a, b), rules=rules)

    # online cells: covariates + KNN database + ranking
    d_cov, n_db = cell["d_cov"], cell["n_db"]
    db_sh = NamedSharding(mesh, rules.resolve("users_db", None))
    X = sds((B, d_cov), jnp.float32,
            NamedSharding(mesh, rules.resolve("batch", None)))
    X_db = sds((n_db, d_cov), jnp.float32, db_sh)
    eps = cfg.eps
    k = cfg.knn_k

    if cfg.distributed and mesh.devices.size > 1:
        # §Perf variant: distributed KNN + distributed constrained top-k.
        # lam_db is replicated (n_db*K floats — tiny); constraints are
        # shared (K, m1) rows, sharded over items.
        from repro.core.serving_dist import (
            knn_predict_distributed,
            rank_distributed,
        )
        lam_db = sds((n_db, K), jnp.float32, NamedSharding(mesh, P()))
        a_shared = sds((K, m1), jnp.float32,
                       NamedSharding(mesh, rules.resolve(None, "items")))

        def fn(X, u, a, b, X_db, lam_db):
            lam_hat = knn_predict_distributed(mesh, X_db, lam_db, X, k=k)
            return rank_distributed(mesh, u, a, b, lam_hat, gamma,
                                    m2=m2, eps=eps)

        return Lowerable(fn=fn, args=(X, u, a_shared, b, X_db, lam_db),
                         rules=rules)

    lam_db = sds((n_db, K), jnp.float32, db_sh)

    def fn(X, u, a, b, X_db, lam_db):
        lam_hat = knn_predict(X_db, lam_db, X, k=k)
        return rank_given_lambda(u, a, b, lam_hat, gamma, m2=m2, eps=eps)

    return Lowerable(fn=fn, args=(X, u, a, b, X_db, lam_db), rules=rules)


SPEC = register(ArchSpec(
    name="paper-ranking", family="paper",
    cells=PAPER_CELLS,
    make_config=lambda full=True: PaperConfig(),
    build=build_paper,
    notes="the paper's technique as its own arch (extra cells beyond "
          "the assigned 40).",
    variants={"dist-topk": lambda: PaperConfig(distributed=True)},
))
