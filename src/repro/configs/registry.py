"""Architecture registry: every assigned arch is a selectable config
(``--arch <id>``) with its own shape-cell set.

Each ArchSpec provides:
  * make_config(full)   — the exact public-literature config (full=True)
    or a reduced same-family smoke config (full=False);
  * cells               — the assigned input shapes;
  * build(cfg, cell, mesh, rules) -> Lowerable — the jit-ready program +
    abstract (ShapeDtypeStruct, NamedSharding) arguments for that cell.

The dry-run (launch/dryrun.py) iterates the registry x cells x meshes;
smoke tests instantiate make_config(full=False) with real arrays.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Mapping

import jax

from repro.distributed.sharding import AxisRules


@dataclass(frozen=True)
class Cell:
    """One assigned input shape for an architecture."""

    name: str                   # e.g. 'train_4k'
    kind: str                   # train | prefill | decode | serve | retrieval
    params: Mapping[str, Any] = field(default_factory=dict)

    def __getitem__(self, k):
        return self.params[k]


@dataclass(frozen=True)
class Lowerable:
    """A jit-ready program with abstract args (dry-run unit)."""

    fn: Callable                # positional-args function to jit
    args: tuple                 # pytrees of ShapeDtypeStruct w/ shardings
    donate: tuple = ()          # donate_argnums
    rules: AxisRules | None = None
    static: Mapping[str, Any] = field(default_factory=dict)


@dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str                                  # lm | gnn | recsys | paper
    cells: tuple[Cell, ...]
    make_config: Callable[[bool], Any]
    build: Callable[[Any, Cell, Any], Lowerable]  # (cfg, cell, mesh) -> Lowerable
    notes: str = ""
    # §Perf hillclimb variants: name -> () -> optimized full-scale config.
    # The baseline (make_config) stays paper-exact; variants are the
    # beyond-paper optimized versions recorded separately.
    variants: Mapping[str, Callable[[], Any]] = field(default_factory=dict)

    def cell(self, name: str) -> Cell:
        for c in self.cells:
            if c.name == name:
                return c
        raise KeyError(f"{self.name}: no cell {name!r}; have "
                       f"{[c.name for c in self.cells]}")


ARCH_REGISTRY: dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    ARCH_REGISTRY[spec.name] = spec
    return spec


def get_arch(name: str) -> ArchSpec:
    # import side-effect registration on first use
    import repro.configs  # noqa: F401
    if name not in ARCH_REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCH_REGISTRY)}")
    return ARCH_REGISTRY[name]


def all_archs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(ARCH_REGISTRY)


# ---------------------------------------------------------------------------
# Shared abstract-arg helpers
# ---------------------------------------------------------------------------

def abstract_like(tree, shardings=None):
    """Pytree of arrays/ShapeDtypeStructs -> ShapeDtypeStructs with
    shardings attached (None shardings -> no placement constraint).
    Non-divisible dims are relaxed to replication per leaf."""
    from jax.sharding import NamedSharding

    from repro.distributed.sharding import drop_nondivisible

    if shardings is None:
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)

    def leaf(x, s):
        if isinstance(s, NamedSharding):
            s = NamedSharding(s.mesh, drop_nondivisible(s.spec, x.shape, s.mesh))
        return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s)

    return jax.tree.map(leaf, tree, shardings)


def sds(shape, dtype, sharding=None):
    from jax.sharding import NamedSharding

    from repro.distributed.sharding import drop_nondivisible

    if isinstance(sharding, NamedSharding):
        sharding = NamedSharding(
            sharding.mesh, drop_nondivisible(sharding.spec, shape, sharding.mesh))
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def pad_up(n: int, mult: int = 512) -> int:
    """Data-pipeline padding: spec sizes rounded up so every sharded axis
    divides the mesh (512 = lcm-safe for all our meshes). Loaders pad the
    real arrays the same way."""
    return -(-n // mult) * mult
