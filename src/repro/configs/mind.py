"""mind — multi-interest dynamic-routing retrieval [arXiv:1904.08030;
unverified].

embed_dim=64 n_interests=4 capsule_iters=3 interaction=multi-interest.
Behaviour window seq_len=50 (MIND paper's short-term window); serve =
max over interest capsules.
"""

from repro.configs.recsys_family import recsys_arch
from repro.configs.registry import register

FULL = dict(n_items=1_000_000, embed_dim=64, seq_len=50,
            n_interests=4, capsule_iters=3)
SMOKE = dict(n_items=1000, embed_dim=16, seq_len=12, n_interests=2,
             capsule_iters=2)

SPEC = register(recsys_arch("mind", "mind", FULL, SMOKE))
