"""LM-family cell builders: train_4k / prefill_32k / decode_32k / long_500k.

All four cells share one parameterization (models/transformer.py); the
cells differ in which entry point they lower:

  train_4k     train_step  (fwd + bwd + Adam), tokens (256, 4096)
  prefill_32k  prefill     (build KV cache),   tokens (32, 32768)
  decode_32k   decode_step (1 token vs 32k KV cache), batch 128
  long_500k    decode_step (1 token vs 524 288 KV cache), batch 1
               — decode against a long cache is O(L) per token, so full
               attention runs this cell (DESIGN.md §6); the cache is
               re-sharded: sequence over ('data','model'), batch axes
               unsharded (B=1).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ArchSpec, Cell, Lowerable, abstract_like, sds
from repro.distributed.sharding import LM_RULES, filter_rules, param_shardings
from repro.models.transformer import (
    LMConfig,
    cache_logical_axes,
    lm_decode_step,
    lm_init,
    lm_logical_axes,
    lm_prefill,
    lm_train_step,
    make_decode_cache,
)
from repro.optim import adam_init

LM_CELLS = (
    Cell("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    Cell("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    Cell("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    Cell("long_500k", "decode", {"seq_len": 524288, "global_batch": 1}),
)

# Reduced cells for smoke tests (same kinds, tiny sizes).
LM_SMOKE_CELLS = (
    Cell("train_4k", "train", {"seq_len": 64, "global_batch": 2}),
    Cell("prefill_32k", "prefill", {"seq_len": 32, "global_batch": 2}),
    Cell("decode_32k", "decode", {"seq_len": 32, "global_batch": 2}),
    Cell("long_500k", "decode", {"seq_len": 128, "global_batch": 1}),
)


def _cell_rules(cell: Cell, cfg: LMConfig):
    rules = LM_RULES
    if cell.name == "long_500k":
        # B = 1: nothing to gain from batch sharding; spread the 131 GB KV
        # cache over ('data','model') instead.
        rules = rules.override(batch=None, kv_batch=None,
                               seq_shard=("data", "model"))
    return rules


def _abstract_params(cfg: LMConfig, mesh, rules):
    shapes = jax.eval_shape(lambda k: lm_init(k, cfg), jax.random.key(0))
    shard = param_shardings(lm_logical_axes(cfg), mesh, rules)
    return abstract_like(shapes, shard)


def _abstract_opt(params_sds, cfg: LMConfig, mesh, rules):
    opt_shapes = jax.eval_shape(
        partial(adam_init, moment_dtype=cfg.moment_dtype), params_sds)
    shard = param_shardings(lm_logical_axes(cfg), mesh, rules)
    from repro.optim import AdamState
    return AdamState(
        step=sds((), jnp.int32, NamedSharding(mesh, P())),
        mu=abstract_like(opt_shapes.mu, shard),
        nu=abstract_like(opt_shapes.nu, shard),
    )


def build_lm(cfg: LMConfig, cell: Cell, mesh) -> Lowerable:
    rules = filter_rules(_cell_rules(cell, cfg), mesh)
    S, B = cell["seq_len"], cell["global_batch"]
    batch_sh = NamedSharding(mesh, rules.resolve("batch", None))
    params = _abstract_params(cfg, mesh, rules)

    if cell.kind == "train":
        opt = _abstract_opt(params, cfg, mesh, rules)
        batch = {
            "tokens": sds((B, S), jnp.int32, batch_sh),
            "labels": sds((B, S), jnp.int32, batch_sh),
        }

        def fn(params, opt, batch):
            return lm_train_step(params, opt, batch, cfg)

        return Lowerable(fn=fn, args=(params, opt, batch), donate=(0, 1),
                         rules=rules)

    if cell.kind == "prefill":
        tokens = sds((B, S), jnp.int32, batch_sh)

        def fn(params, tokens):
            return lm_prefill(params, tokens, cfg)

        return Lowerable(fn=fn, args=(params, tokens), rules=rules)

    if cell.kind == "decode":
        cache_shapes = jax.eval_shape(
            lambda: make_decode_cache(cfg, B, S))
        cache_shard = param_shardings(cache_logical_axes(cfg), mesh, rules)
        cache = abstract_like(cache_shapes, cache_shard)
        token = sds((B,), jnp.int32, NamedSharding(mesh, rules.resolve("batch")))
        pos = sds((), jnp.int32, NamedSharding(mesh, P()))

        def fn(params, cache, token, pos):
            return lm_decode_step(params, cache, token, pos, cfg)

        return Lowerable(fn=fn, args=(params, cache, token, pos),
                         donate=(1,), rules=rules)

    raise ValueError(cell.kind)


def lm_arch(name: str, full_kwargs: dict, smoke_kwargs: dict,
            notes: str = "", variants: dict | None = None) -> ArchSpec:
    def make_config(full: bool = True) -> LMConfig:
        kw = full_kwargs if full else smoke_kwargs
        return LMConfig(name=name, **kw)

    variant_fns = {
        vname: (lambda kw=vkw: LMConfig(name=name, **{**full_kwargs, **kw}))
        for vname, vkw in (variants or {}).items()
    }
    return ArchSpec(
        name=name, family="lm",
        cells=LM_CELLS,
        make_config=make_config,
        build=build_lm,
        notes=notes,
        variants=variant_fns,
    )
