"""GNN-family (meshgraphnet) cell builders.

Shape cells span three data regimes:
  full_graph_sm   2 708 nodes / 10 556 edges / d_feat 1433 (full-batch)
  minibatch_lg    232 965-node graph, sampled blocks: 1 024 seeds x
                  fanout (15, 10) -> 169 984 nodes / 168 960 edges
                  (static shapes; the uniform sampler is
                  models/gnn.neighbor_sample)
  ogb_products    2 449 029 nodes / 61 859 140 edges / d_feat 100
                  (full-batch-large; edges sharded over (pod, data))
  molecule        128 x (30 nodes / 64 edges) batched small graphs

The MeshGraphNet core config (15 layers, d_hidden 128, sum aggregation,
2-layer MLPs) is fixed; encoder/decoder widths adapt per cell's feature
and target dims (dataclasses.replace).
"""

from __future__ import annotations

from dataclasses import replace

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import (
    ArchSpec,
    Cell,
    Lowerable,
    abstract_like,
    pad_up,
    sds,
)
from repro.distributed.sharding import GNN_RULES, filter_rules, param_shardings
from repro.models.gnn import GNNConfig, MeshGraphNet, sampled_sizes
from repro.optim import AdamState, adam_init

GNN_CELLS = (
    Cell("full_graph_sm", "train",
         {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433, "d_out": 7}),
    Cell("minibatch_lg", "train_sampled",
         {"n_graph_nodes": 232_965, "batch_nodes": 1024,
          "fanouts": (15, 10), "d_feat": 602, "d_out": 41}),
    Cell("ogb_products", "train",
         {"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100,
          "d_out": 47}),
    Cell("molecule", "train_batched",
         {"batch": 128, "n_nodes": 30, "n_edges": 64, "d_feat": 16,
          "d_out": 3}),
)

GNN_SMOKE_CELLS = (
    Cell("full_graph_sm", "train",
         {"n_nodes": 40, "n_edges": 120, "d_feat": 24, "d_out": 4}),
    Cell("minibatch_lg", "train_sampled",
         {"n_graph_nodes": 200, "batch_nodes": 8, "fanouts": (3, 2),
          "d_feat": 12, "d_out": 4}),
    Cell("ogb_products", "train",
         {"n_nodes": 60, "n_edges": 150, "d_feat": 10, "d_out": 5}),
    Cell("molecule", "train_batched",
         {"batch": 4, "n_nodes": 6, "n_edges": 10, "d_feat": 8, "d_out": 2}),
)

D_EDGE = 8  # relative-feature edge dim (mesh-relative coordinate stand-in)


def cell_config(cfg: GNNConfig, cell: Cell) -> GNNConfig:
    return replace(cfg, d_node_in=cell["d_feat"], d_edge_in=D_EDGE,
                   d_out=cell["d_out"])


def _graph_specs(cell: Cell, mesh, rules, *, batched: bool = False):
    nodes_sh = NamedSharding(mesh, rules.resolve("nodes", None))
    edges_sh = NamedSharding(mesh, rules.resolve("edges", None))
    evec_sh = NamedSharding(mesh, rules.resolve("edges"))
    if cell.kind == "train_sampled":
        N, E = sampled_sizes(cell["batch_nodes"], tuple(cell["fanouts"]))
    else:
        N, E = cell["n_nodes"], cell["n_edges"]
    # graph loaders pad node/edge arrays to mesh-divisible sizes
    # (padding edges self-loop onto padding nodes with zero features)
    N, E = pad_up(N), pad_up(E)
    g = {
        "nodes": sds((N, cell["d_feat"]), jnp.float32, nodes_sh),
        "edges": sds((E, D_EDGE), jnp.float32, edges_sh),
        "senders": sds((E,), jnp.int32, evec_sh),
        "receivers": sds((E,), jnp.int32, evec_sh),
        "targets": sds((N, cell["d_out"]), jnp.float32, nodes_sh),
    }
    if cell.kind == "train_sampled":
        g["node_mask"] = sds((N,), jnp.float32,
                             NamedSharding(mesh, rules.resolve("nodes")))
    if batched:
        B = cell["batch"]
        bsh3 = NamedSharding(mesh, rules.resolve("batch", None, None))
        bsh2 = NamedSharding(mesh, rules.resolve("batch", None))
        g = {k: sds((B,) + v.shape, v.dtype,
                    bsh3 if len(v.shape) == 2 else bsh2)
             for k, v in g.items()}
    return g


def build_gnn(cfg: GNNConfig, cell: Cell, mesh) -> Lowerable:
    rules = filter_rules(GNN_RULES, mesh)
    ccfg = cell_config(cfg, cell)
    model = MeshGraphNet(ccfg)
    shapes = jax.eval_shape(lambda k: model.init(k), jax.random.key(0))
    pshard = param_shardings(model.logical_axes(), mesh, rules)
    params = abstract_like(shapes, pshard)
    opt_shapes = jax.eval_shape(adam_init, params)
    opt = AdamState(
        step=sds((), jnp.int32, NamedSharding(mesh, P())),
        mu=abstract_like(opt_shapes.mu, pshard),
        nu=abstract_like(opt_shapes.nu, pshard),
    )
    graph = _graph_specs(cell, mesh, rules,
                         batched=(cell.kind == "train_batched"))

    def fn(params, opt, graph):
        return model.train_step(params, opt, graph)

    return Lowerable(fn=fn, args=(params, opt, graph), donate=(0, 1),
                     rules=rules)


def make_config(full: bool = True) -> GNNConfig:
    if full:
        return GNNConfig(name="meshgraphnet", n_layers=15, d_hidden=128,
                         mlp_layers=2, aggregator="sum", remat=True)
    return GNNConfig(name="meshgraphnet", n_layers=3, d_hidden=32,
                     mlp_layers=2, aggregator="sum", remat=False)
