"""mistral-nemo-12b — 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407; hf].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, d_head=128
(the HF config's non-square attention: H*Dh = 4096 != d_model).
"""

import jax.numpy as jnp

from repro.configs.lm_family import lm_arch
from repro.configs.registry import register

FULL = dict(
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab=131072,
    rope_theta=1_000_000.0,
    dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, moment_dtype=jnp.bfloat16,
    remat="full",
)

SMOKE = dict(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=112, vocab=256,
    dtype=jnp.float32, param_dtype=jnp.float32,
    dense_attn_threshold=4096,
)

SPEC = register(lm_arch(
    "mistral-nemo-12b", FULL, SMOKE,
    variants={
        # Sq-sharded dense attention at train length (§Perf lever C)
        "opt": dict(dense_attn_threshold=4096),
    },
))
