"""llama3.2-1b — small llama3 [hf:meta-llama/Llama-3.2-1B].

16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256, d_head=64,
tied embeddings (as shipped).
"""

import jax.numpy as jnp

from repro.configs.lm_family import lm_arch
from repro.configs.registry import register

FULL = dict(
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, d_head=64,
    d_ff=8192, vocab=128256, tie_embeddings=True,
    dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, moment_dtype=jnp.float32,
    remat="full",
)

SMOKE = dict(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256, tie_embeddings=True,
    dtype=jnp.float32, param_dtype=jnp.float32,
    dense_attn_threshold=4096,
)

SPEC = register(lm_arch("llama3.2-1b", FULL, SMOKE))
