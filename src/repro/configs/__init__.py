# Importing this package registers every architecture (side effect).
from repro.configs import (  # noqa: F401
    bert4rec,
    deepfm,
    kimi_k2_1t_a32b,
    llama3_2_1b,
    llama4_scout_17b_16e,
    meshgraphnet,
    mind,
    mistral_nemo_12b,
    paper,
    phi3_medium_14b,
    sasrec,
)
from repro.configs.registry import (  # noqa: F401
    ARCH_REGISTRY,
    ArchSpec,
    Cell,
    Lowerable,
    all_archs,
    get_arch,
)
