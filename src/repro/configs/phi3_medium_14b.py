"""phi3-medium-14b — dense RoPE SwiGLU GQA [arXiv:2404.14219; unverified].

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352.
"""

import jax.numpy as jnp

from repro.configs.lm_family import lm_arch
from repro.configs.registry import register

FULL = dict(
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, d_head=128,
    d_ff=17920, vocab=100352,
    dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, moment_dtype=jnp.bfloat16,
    remat="full",
)

SMOKE = dict(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=160, vocab=256,
    dtype=jnp.float32, param_dtype=jnp.float32,
    dense_attn_threshold=4096,
)

SPEC = register(lm_arch(
    "phi3-medium-14b", FULL, SMOKE,
    variants={
        # 40 heads don't divide the 16-way TP axis -> chunked attention
        # replicates score tiles per device. Dense attention with the
        # q-sequence axis sharded over 'model' (4096 % 16 == 0) restores
        # 16-way activation parallelism for any head count.
        "attn-seq-shard": dict(dense_attn_threshold=4096),
    },
))
