"""RecSys-family cell builders: train_batch / serve_p99 / serve_bulk /
retrieval_cand.

retrieval_cand is the paper's native regime and lowers the FULL
integrated program: backbone covariates -> KNN shadow-price prediction
over a 64k-user database -> adjusted-score constrained top-50 over 10^6
candidates (Algorithm 1 online stage as one accelerator program).
"""

from __future__ import annotations

from dataclasses import replace
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import (
    ArchSpec,
    Cell,
    Lowerable,
    abstract_like,
    pad_up,
    sds,
)
from repro.core.predictors import knn_predict
from repro.distributed.sharding import RECSYS_RULES, filter_rules, param_shardings
from repro.models.recsys import RECSYS_REGISTRY, RecsysConfig
from repro.optim import AdamState, adam_init

N_NEG = 127          # sampled-softmax negatives (training)
N_MASK = 20          # bert4rec masked positions (10% of seq 200)
RETRIEVAL_K = 5      # constraints in the retrieval head
RETRIEVAL_M2 = 50    # ranking slots
KNN_DB = 65536       # shadow-price train-user database (serving fleet)

RECSYS_CELLS = (
    Cell("train_batch", "train", {"batch": 65536}),
    Cell("serve_p99", "serve", {"batch": 512}),
    Cell("serve_bulk", "serve", {"batch": 262144}),
    Cell("retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 1_000_000}),
)

RECSYS_SMOKE_CELLS = (
    Cell("train_batch", "train", {"batch": 16}),
    Cell("serve_p99", "serve", {"batch": 8}),
    Cell("serve_bulk", "serve", {"batch": 32}),
    Cell("retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 512}),
)


def _covariate_dim(cfg: RecsysConfig) -> int:
    if cfg.kind == "deepfm":
        return cfg.embed_dim
    if cfg.kind == "mind":
        return cfg.n_interests * cfg.embed_dim
    return cfg.embed_dim


def _abstract_params(model, mesh, rules):
    shapes = jax.eval_shape(lambda k: model.init(k), jax.random.key(0))
    shard = param_shardings(model.logical_axes(), mesh, rules)
    return abstract_like(shapes, shard)


def make_train_batch_specs(cfg: RecsysConfig, B: int) -> dict:
    """Abstract train-batch schema per model kind (mirrors data/batches.py).
    Shardings are attached by the caller (rank-dependent)."""
    S = cfg.seq_len
    if cfg.kind == "deepfm":
        return {"ids": sds((B, cfg.n_sparse), jnp.int32),
                "labels": sds((B,), jnp.int32)}
    if cfg.kind == "sasrec":
        return {"seq": sds((B, S), jnp.int32),
                "pos": sds((B, S), jnp.int32),
                "neg": sds((B, S, N_NEG), jnp.int32)}
    if cfg.kind == "bert4rec":
        return {"seq": sds((B, S), jnp.int32),
                "mask_pos": sds((B, N_MASK), jnp.int32),
                "mask_target": sds((B, N_MASK), jnp.int32),
                "neg": sds((B, N_MASK, N_NEG), jnp.int32)}
    if cfg.kind == "mind":
        return {"seq": sds((B, S), jnp.int32),
                "pos": sds((B,), jnp.int32),
                "neg": sds((B, N_NEG), jnp.int32)}
    raise ValueError(cfg.kind)


def build_recsys(cfg: RecsysConfig, cell: Cell, mesh) -> Lowerable:
    base_rules = RECSYS_RULES
    if cfg.replicate_tables:
        base_rules = base_rules.override(table_rows=None)
    rules = filter_rules(base_rules, mesh)
    model = RECSYS_REGISTRY[cfg.kind](cfg)
    params = _abstract_params(model, mesh, rules)
    batch_vec = NamedSharding(mesh, rules.resolve("batch"))
    batch_mat = NamedSharding(mesh, rules.resolve("batch", None))
    batch_3d = NamedSharding(mesh, rules.resolve("batch", None, None))

    def _sh(spec: jax.ShapeDtypeStruct):
        by_rank = {1: batch_vec, 2: batch_mat, 3: batch_3d}
        return jax.ShapeDtypeStruct(
            spec.shape, spec.dtype, sharding=by_rank[len(spec.shape)])

    if cell.kind == "train":
        B = cell["batch"]
        batch = {k: _sh(v) for k, v in make_train_batch_specs(cfg, B).items()}
        opt_shapes = jax.eval_shape(adam_init, params)
        pshard = param_shardings(model.logical_axes(), mesh, rules)
        opt = AdamState(
            step=sds((), jnp.int32, NamedSharding(mesh, P())),
            mu=abstract_like(opt_shapes.mu, pshard),
            nu=abstract_like(opt_shapes.nu, pshard),
        )

        def fn(params, opt, batch):
            return model.train_step(params, opt, batch)

        return Lowerable(fn=fn, args=(params, opt, batch), donate=(0, 1),
                         rules=rules)

    if cell.kind == "serve":
        B = cell["batch"]
        if cfg.kind == "deepfm":
            args = (params, sds((B, cfg.n_sparse), jnp.int32, batch_mat))

            def fn(params, ids):
                return model.serve(params, ids)
        else:
            args = (params,
                    sds((B, cfg.seq_len), jnp.int32, batch_mat),
                    sds((B,), jnp.int32, batch_vec))

            def fn(params, seq, target):
                return model.serve(params, seq, target)

        return Lowerable(fn=fn, args=args, rules=rules)

    if cell.kind == "retrieval":
        # batch = 1: one query against 10^6 candidates -> the candidate
        # axis carries all the parallelism; pipeline pads it to the mesh.
        B, n_cand = cell["batch"], pad_up(cell["n_candidates"])
        cand_sh = NamedSharding(mesh, rules.resolve("candidates"))
        cand_mat = NamedSharding(mesh, rules.resolve(None, "candidates"))
        db_sh = NamedSharding(mesh, rules.resolve("users_db", None))
        d_cov = _covariate_dim(cfg)
        n_db = KNN_DB

        cand_ids = sds((n_cand,), jnp.int32, cand_sh)
        a = sds((RETRIEVAL_K, n_cand), jnp.float32, cand_mat)
        X_db = sds((n_db, d_cov), jnp.float32, db_sh)
        lam_db = sds((n_db, RETRIEVAL_K), jnp.float32, db_sh)
        if cfg.kind == "deepfm":
            user_in = sds((B, cfg.n_sparse - 1), jnp.int32, batch_mat)
        else:
            user_in = sds((B, cfg.seq_len), jnp.int32, batch_mat)

        m2 = min(RETRIEVAL_M2, n_cand)

        def fn(params, user_in, cand_ids, a, X_db, lam_db):
            # Algorithm 1 online stage, end to end:
            scores = model.retrieval_scores(params, user_in, cand_ids)
            X = model.user_covariates(params, user_in)        # (B, d)
            lam_hat = knn_predict(X_db, lam_db, X, k=10)      # (B, K)
            s = scores + (1.0 + 1e-4) * lam_hat @ a           # adjusted
            vals, idx = jax.lax.top_k(s, m2)
            return vals, idx, lam_hat

        return Lowerable(
            fn=fn, args=(params, user_in, cand_ids, a, X_db, lam_db),
            rules=rules)

    raise ValueError(cell.kind)


def recsys_arch(name: str, kind: str, full_kwargs: dict, smoke_kwargs: dict,
                notes: str = "", variants: dict | None = None) -> ArchSpec:
    def make_config(full: bool = True) -> RecsysConfig:
        kw = full_kwargs if full else smoke_kwargs
        return RecsysConfig(name=name, kind=kind, **kw)

    variant_fns = {
        vname: (lambda kw=vkw: RecsysConfig(name=name, kind=kind,
                                            **{**full_kwargs, **kw}))
        for vname, vkw in (variants or {}).items()
    }
    return ArchSpec(
        name=name, family="recsys",
        cells=RECSYS_CELLS,
        make_config=make_config,
        build=build_recsys,
        notes=notes,
        variants=variant_fns,
    )
