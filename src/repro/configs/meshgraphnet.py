"""meshgraphnet — encode-process-decode GNN [arXiv:2010.03409; unverified].

n_layers=15 d_hidden=128 aggregator=sum mlp_layers=2. Message passing is
segment_sum over an explicit edge list (JAX sparse is BCOO-only — the
scatter substrate IS part of this system; see models/gnn.py).

Paper-technique applicability: the constrained-ranking head does not
apply to a physics rollout (no ranking decision) — the arch is
implemented WITHOUT the technique; API-compatibility (node_scores ->
ranking head) is exercised in tests only. DESIGN.md §5.
"""

from repro.configs.gnn_family import (
    GNN_CELLS,
    build_gnn,
    make_config,
)
from repro.configs.registry import ArchSpec, register

SPEC = register(ArchSpec(
    name="meshgraphnet", family="gnn",
    cells=GNN_CELLS,
    make_config=make_config,
    build=build_gnn,
    notes="paper technique inapplicable (no ranking decision); "
          "implemented without it per instructions.",
))
