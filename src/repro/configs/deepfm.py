"""deepfm — FM + deep CTR [arXiv:1703.04247; paper].

n_sparse=39 embed_dim=10 mlp=400-400-400 interaction=fm. Criteo-scale
tables: 10^6 rows per field -> one flat 39M x 10 table, row-sharded.
"""

from repro.configs.recsys_family import recsys_arch
from repro.configs.registry import register

FULL = dict(n_sparse=39, field_vocab=1_000_000, embed_dim=10,
            mlp_dims=(400, 400, 400))
SMOKE = dict(n_sparse=6, field_vocab=500, embed_dim=8, mlp_dims=(32, 32))

SPEC = register(recsys_arch("deepfm", "deepfm", FULL, SMOKE))
