"""llama4-scout-17b-a16e — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) vocab=202048, MoE 16 experts top-1,
expert d_ff=8192. The '[vlm]'-ish early-fusion frontend is out of scope
per the assignment (LM backbone only); text tokens in, logits out.
"""

import jax.numpy as jnp

from repro.configs.lm_family import lm_arch
from repro.configs.registry import register

FULL = dict(
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab=202048,
    moe=True, n_experts=16, top_k=1, d_ff_moe=8192, shared_expert=True,
    dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, moment_dtype=jnp.bfloat16,
    remat="full",
)

SMOKE = dict(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256,
    moe=True, n_experts=4, top_k=1, d_ff_moe=128, shared_expert=True,
    dtype=jnp.float32, param_dtype=jnp.float32,
    dense_attn_threshold=4096,
)

SPEC = register(lm_arch(
    "llama4-scout-17b-a16e", FULL, SMOKE,
    notes="top-1 routed + shared expert (Llama-4 routing).",
    variants={
        # same two levers as kimi-k2/phi3 (40 heads, MoE dispatch)
        "opt": dict(moe_dispatch="shmap", dense_attn_threshold=4096),
    },
))
