"""kimi-k2-1t-a32b — trillion-param MoE [arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8) vocab=163840, MoE 384 experts top-8 with
per-expert d_ff=2048 (the paper-table 'd_ff=2048' is the expert hidden).
~1.03T total / ~32B active params.

Adaptations (recorded):
  * d_head 7168/64 = 112 -> 128 (MXU lane alignment; attention is
    non-square wq: (D, H*128), wo: (H*128, D) — standard practice, e.g.
    Mistral-Nemo ships exactly this).
  * bf16 Adam moments: fp32 moments alone would be 8.2 TB. Fit math per
    mesh is recorded in EXPERIMENTS.md §Dry-run; train cells need the
    multi-pod mesh (ZeRO-3 over ('pod','data') for expert shards).
"""

import jax.numpy as jnp

from repro.configs.lm_family import lm_arch
from repro.configs.registry import register

FULL = dict(
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=2048, vocab=163840,
    moe=True, n_experts=384, top_k=8, d_ff_moe=2048,
    dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, moment_dtype=jnp.bfloat16,
    remat="full",
)

SMOKE = dict(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256,
    moe=True, n_experts=8, top_k=2, d_ff_moe=64,
    dtype=jnp.float32, param_dtype=jnp.float32,
    dense_attn_threshold=4096,
)

SPEC = register(lm_arch(
    "kimi-k2-1t-a32b", FULL, SMOKE,
    notes="1T MoE; d_head 112->128 aligned; bf16 moments; "
          "train cells sized for the multi-pod mesh.",
    variants={
        "moe-sort-dispatch": dict(moe_dispatch="sort"),
        "moe-shmap": dict(moe_dispatch="shmap"),
        # combined winners: shard_map EP MoE + Sq-sharded dense attention
        "opt": dict(moe_dispatch="shmap", dense_attn_threshold=4096),
    },
))
