"""bert4rec — bidirectional masked-item model [arXiv:1904.06690; paper].

embed_dim=64 n_blocks=2 n_heads=2 seq_len=200 interaction=bidir-seq.
Encoder-only: recsys shape cells apply unchanged (no decode cells in
this family — nothing to skip).
"""

from repro.configs.recsys_family import recsys_arch
from repro.configs.registry import register

FULL = dict(n_items=1_000_000, embed_dim=64, n_blocks=2, n_heads=2,
            seq_len=200)
SMOKE = dict(n_items=1000, embed_dim=16, n_blocks=2, n_heads=2, seq_len=16)

SPEC = register(recsys_arch("bert4rec", "bert4rec", FULL, SMOKE))
