"""Synthetic training/serving batches for every assigned architecture.

Two entry points per family:

  * ``make_*_batch``  — real arrays (smoke tests, examples, CPU training);
    deterministic from a seed.
  * the configs' ``input_specs()`` (src/repro/configs) — ShapeDtypeStruct
    stand-ins for the dry-run; THESE functions define the layouts those
    specs mirror.

The LM stream is a Zipf-ish token source with enough structure (bigram
bias) that a few hundred training steps show a falling loss in the
end-to-end example.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


# --------------------------------------------------------------------------
# LM batches
# --------------------------------------------------------------------------

def make_lm_batch(key: Array, *, batch: int, seq: int, vocab: int) -> dict:
    """Causal-LM batch with learnable bigram structure:
    next token = (3 * tok + noise) mod vocab."""
    k1, k2 = jax.random.split(key)
    first = jax.random.randint(k1, (batch, 1), 0, vocab)
    noise = jax.random.randint(k2, (batch, seq), 0, 7)

    def step(tok, n):
        nxt = (3 * tok + n) % vocab
        return nxt, nxt

    _, rest = jax.lax.scan(
        lambda c, n: step(c, n), first[:, 0], noise[:, :-1].T)
    tokens = jnp.concatenate([first, rest.T], axis=1)
    _, nxt = step(tokens[:, -1], noise[:, -1])
    labels = jnp.concatenate([tokens[:, 1:], nxt[:, None]], axis=1)
    return {"tokens": tokens.astype(jnp.int32), "labels": labels.astype(jnp.int32)}


# --------------------------------------------------------------------------
# RecSys batches
# --------------------------------------------------------------------------

def make_deepfm_batch(key: Array, *, batch: int, n_sparse: int,
                      field_vocab: int) -> dict:
    """CTR batch: per-field global ids + clicks correlated with id parity."""
    k1, k2 = jax.random.split(key)
    local = jax.random.randint(k1, (batch, n_sparse), 0, field_vocab)
    offsets = jnp.arange(n_sparse) * field_vocab
    ids = local + offsets[None, :]
    click_p = 0.2 + 0.6 * (jnp.mean(local % 2, axis=1))
    labels = (jax.random.uniform(k2, (batch,)) < click_p).astype(jnp.int32)
    return {"ids": ids.astype(jnp.int32), "labels": labels}


def make_seqrec_batch(key: Array, *, batch: int, seq_len: int, n_items: int,
                      n_neg: int, kind: str = "sasrec",
                      n_mask: int = 8) -> dict:
    """Sequence batches. sasrec/mind: next-item; bert4rec: masked-item."""
    ks = jax.random.split(key, 5)
    # random-walk item sequences (neighbourhood structure -> learnable)
    start = jax.random.randint(ks[0], (batch, 1), 0, n_items)
    steps = jax.random.randint(ks[1], (batch, seq_len), -3, 4)
    seq = (start + jnp.cumsum(steps, axis=1)) % n_items
    if kind == "sasrec":
        pos = (seq + 1) % n_items                    # next-item targets (B,S)
        neg = jax.random.randint(ks[2], (batch, seq_len, n_neg), 0, n_items)
        return {"seq": seq.astype(jnp.int32), "pos": pos.astype(jnp.int32),
                "neg": neg.astype(jnp.int32)}
    if kind == "bert4rec":
        n_mask = min(n_mask, seq_len)
        mask_pos = jax.random.randint(ks[2], (batch, n_mask), 0, seq_len)
        target = jnp.take_along_axis(seq, mask_pos, axis=1)
        seq_masked = seq.at[jnp.arange(batch)[:, None], mask_pos].set(0)
        neg = jax.random.randint(ks[3], (batch, n_mask, n_neg), 0, n_items)
        return {"seq": seq_masked.astype(jnp.int32),
                "mask_pos": mask_pos.astype(jnp.int32),
                "mask_target": target.astype(jnp.int32),
                "neg": neg.astype(jnp.int32)}
    if kind == "mind":
        pos = ((seq[:, -1] + 1) % n_items)
        neg = jax.random.randint(ks[2], (batch, n_neg), 0, n_items)
        return {"seq": seq.astype(jnp.int32), "pos": pos.astype(jnp.int32),
                "neg": neg.astype(jnp.int32)}
    raise ValueError(kind)


# --------------------------------------------------------------------------
# Graph batches
# --------------------------------------------------------------------------

def make_random_graph(key: Array, *, n_nodes: int, n_edges: int,
                      d_node: int, d_edge: int, d_out: int,
                      np_rng: bool = False) -> dict:
    """Random graph with smooth targets (sum of neighbour features) so the
    GNN has learnable signal."""
    ks = jax.random.split(key, 4)
    nodes = jax.random.normal(ks[0], (n_nodes, d_node))
    senders = jax.random.randint(ks[1], (n_edges,), 0, n_nodes)
    receivers = jax.random.randint(ks[2], (n_edges,), 0, n_nodes)
    edges = jnp.abs(nodes[senders, :d_edge] - nodes[receivers, :d_edge])
    agg = jax.ops.segment_sum(nodes[senders, :d_out], receivers,
                              num_segments=n_nodes)
    targets = jnp.tanh(agg)
    return {"nodes": nodes, "edges": edges,
            "senders": senders.astype(jnp.int32),
            "receivers": receivers.astype(jnp.int32), "targets": targets}


def make_csr_graph(key: Array, *, n_nodes: int, avg_degree: int):
    """CSR adjacency for the neighbor sampler (minibatch_lg pipeline)."""
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
    deg = rng.poisson(avg_degree, n_nodes).astype(np.int64)
    deg = np.maximum(deg, 1)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(deg, out=indptr[1:])
    indices = rng.integers(0, n_nodes, int(indptr[-1]))
    return jnp.asarray(indptr, jnp.int32), jnp.asarray(indices, jnp.int32)


def make_molecule_batch(key: Array, *, batch: int, n_nodes: int, n_edges: int,
                        d_node: int, d_edge: int, d_out: int) -> dict:
    """Batched small graphs (molecule cell): leading batch dim on every leaf."""
    keys = jax.random.split(key, batch)
    return jax.vmap(lambda k: make_random_graph(
        k, n_nodes=n_nodes, n_edges=n_edges, d_node=d_node, d_edge=d_edge,
        d_out=d_out))(keys)
