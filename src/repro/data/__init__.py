from repro.data.synthetic import (
    Corpus,
    InteractionData,
    RankingExperiment,
    build_experiment,
    make_interactions,
    make_movielens_corpus,
    make_yow_corpus,
    movielens_constraints,
    yow_constraints,
)
from repro.data.batches import (
    make_csr_graph,
    make_deepfm_batch,
    make_lm_batch,
    make_molecule_batch,
    make_random_graph,
    make_seqrec_batch,
)
