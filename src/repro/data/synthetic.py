"""Synthetic matched-statistics datasets for the paper's experiments.

MovieLens 25M / YOW are not available offline (DESIGN.md §2); these
generators reproduce the *statistical shape* the paper's experiments
depend on:

  * a latent-factor ground truth producing 1..5 ratings (so the
    Appendix-B recommender has real signal to learn),
  * per-item binary topic indicators with the paper's topic frequencies
    (MovieLens: 4 tags at 5% base rate + release-year; YOW: 8 topics at
    Table-1b frequencies),
  * Table-1 constraint sets (quota fractions per scenario).

Everything is generated from a seed; the experiments are exactly
reproducible.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constraints import ConstraintSet, dcg_discount, make_constraints

Array = jax.Array


# --------------------------------------------------------------------------
# Latent-factor interaction data (feeds the Appendix-B recommender)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class InteractionData:
    n_users: int
    n_items: int
    uid: Array          # (n_obs,)
    iid: Array          # (n_obs,)
    rating: Array       # (n_obs,) int in 1..5
    true_user: Array    # (n_users, d_latent) ground-truth factors
    true_item: Array    # (n_items, d_latent)


def make_interactions(
    key: Array, *, n_users: int, n_items: int, n_obs: int, d_latent: int = 8,
    noise: float = 0.35,
) -> InteractionData:
    """Ratings r = clip(round(3 + u.v + eps), 1, 5) from latent factors."""
    ku, ki, ko, kn = jax.random.split(key, 4)
    U = jax.random.normal(ku, (n_users, d_latent)) / jnp.sqrt(d_latent)
    V = jax.random.normal(ki, (n_items, d_latent))
    uid = jax.random.randint(ko, (n_obs,), 0, n_users)
    iid = jax.random.randint(jax.random.fold_in(ko, 1), (n_obs,), 0, n_items)
    raw = 3.0 + 1.8 * jnp.sum(U[uid] * V[iid], axis=-1)
    raw = raw + noise * jax.random.normal(kn, (n_obs,))
    rating = jnp.clip(jnp.round(raw), 1, 5).astype(jnp.int32)
    return InteractionData(
        n_users=n_users, n_items=n_items, uid=uid, iid=iid, rating=rating,
        true_user=U, true_item=V,
    )


# --------------------------------------------------------------------------
# MovieLens-like corpus (topics + release year) and Table-1a constraints
# --------------------------------------------------------------------------

MOVIELENS_TOPICS = ("queer", "race_issues", "free_speech", "scifi")
# Paper: "top 5% of movies on the tag" -> 5% base rate per topic.
MOVIELENS_TOPIC_RATE = 0.05
# Table 1a quota per scenario (fraction of total exposure), m2 -> frac.
MOVIELENS_QUOTA = {50: 0.10, 500: 0.05, 1000: 0.015}

YOW_TOPICS = ("scitech", "health", "business", "entertainment",
              "world", "politics", "sport", "environment")
# Table 1b: empirical share of documents per topic in the YOW data.
YOW_TOPIC_RATE = (0.156, 0.096, 0.101, 0.141, 0.155, 0.092, 0.036, 0.019)
# (sign, {m2: quota_frac}) per Table 1b; +1 = ">=", -1 = "<=".
YOW_CONSTRAINTS = (
    (+1, {50: 0.30, 500: 0.30, 1000: 0.20}),   # sci&tech >=
    (+1, {50: 0.20, 500: 0.20, 1000: 0.15}),   # health >=
    (-1, {50: 0.10, 500: 0.10, 1000: 0.20}),   # business <=
    (-1, {50: 0.10, 500: 0.10, 1000: 0.20}),   # entertainment <=
    (-1, {50: 0.10, 500: 0.10, 1000: 0.20}),   # world <=
    (-1, {50: 0.10, 500: 0.10, 1000: 0.20}),   # politics <=
    (-1, {50: 0.10, 500: 0.10, 1000: 0.20}),   # sport <=
    (+1, {50: 0.05, 500: 0.05, 1000: 0.02}),   # environment >=
)


@dataclass(frozen=True)
class Corpus:
    """Item-side metadata: binary topic indicators (K_topics, n_items) and
    optional extra attribute rows (e.g. scaled release-year delta)."""

    topics: Array                   # (K_topics, n_items) float 0/1
    extra: Array | None = None      # (K_extra, n_items)
    topic_names: tuple = ()


def make_movielens_corpus(key: Array, n_items: int) -> Corpus:
    kt, ky = jax.random.split(key)
    topics = (jax.random.uniform(kt, (len(MOVIELENS_TOPICS), n_items))
              < MOVIELENS_TOPIC_RATE).astype(jnp.float32)
    # Release years skew recent (MovieLens rating activity does): an
    # exponential tail back from 2019, clipped at 1950 — mean ~2007.
    # (A uniform 1950-2019 draw makes the Table-1a "mean release year
    # >= 1990" row infeasible at the m2 = 1000 scenario where EVERY item
    # is ranked and the exposure-weighted mean has little reorder room.)
    age = jnp.floor(jax.random.exponential(ky, (n_items,)) * 12.0)
    year = jnp.clip(2019.0 - age, 1950.0, 2019.0)
    year_delta = (year - 1990.0) / 100.0
    return Corpus(topics=topics, extra=year_delta[None, :],
                  topic_names=MOVIELENS_TOPICS)


def make_yow_corpus(key: Array, n_items: int) -> Corpus:
    rates = jnp.asarray(YOW_TOPIC_RATE)[:, None]
    topics = (jax.random.uniform(key, (len(YOW_TOPICS), n_items))
              < rates).astype(jnp.float32)
    return Corpus(topics=topics, topic_names=YOW_TOPICS)


def _scenario(table: dict, m2: int):
    """Exact Table-1 entry when m2 is a paper scenario size; otherwise the
    nearest scenario (reduced smoke configs use small m2)."""
    if m2 in table:
        return table[m2]
    nearest = min(table, key=lambda k: abs(k - m2))
    return table[nearest]


def movielens_constraints(
    corpus: Corpus, item_idx: Array, gamma: Array, m2: int
) -> ConstraintSet:
    """Table 1a for the m1 candidate items of one user: 4 topic quotas (>=)
    + exposure-weighted release-year delta >= 0.

    item_idx: (m1,) global item ids of this user's candidate slate.
    """
    quota = _scenario(MOVIELENS_QUOTA, m2)
    total = float(jnp.sum(gamma))
    a_rows = [corpus.topics[k][item_idx] for k in range(corpus.topics.shape[0])]
    b_rows = [quota * total] * len(a_rows)
    a_rows.append(corpus.extra[0][item_idx])
    b_rows.append(0.0)
    signs = [1.0] * len(a_rows)
    return make_constraints(a_rows, b_rows, signs)


def yow_constraints(
    corpus: Corpus, item_idx: Array, gamma: Array, m2: int
) -> ConstraintSet:
    """Table 1b: 8 topic quotas with mixed >= / <= signs."""
    total = float(jnp.sum(gamma))
    a_rows, b_rows, signs = [], [], []
    for k, (sign, by_m2) in enumerate(YOW_CONSTRAINTS):
        a_rows.append(corpus.topics[k][item_idx])
        b_rows.append(_scenario(by_m2, m2) * total)
        signs.append(float(sign))
    return make_constraints(a_rows, b_rows, signs)


# --------------------------------------------------------------------------
# Full experiment bundle: per-user (u, X, a, b) arrays, train/holdout split
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class RankingExperiment:
    """Everything Algorithm 1 needs, batched over users.

    u:   (n, m1) per-user utilities over their top-m1 candidate items
    X:   (n, d)  user covariates (learned embeddings)
    a:   (n, K, m1) per-user constraint attribute rows (sign-normalized)
    b:   (K,)    thresholds (sign-normalized)
    gamma: (m2,) rank discounts
    """

    u: Array
    X: Array
    a: Array
    b: Array
    gamma: Array
    m2: int
    train_idx: Array
    test_idx: Array

    def split(self, which: str):
        idx = self.train_idx if which == "train" else self.test_idx
        return self.u[idx], self.X[idx], self.a[idx]


def build_experiment(
    key: Array,
    *,
    dataset: str = "movielens",      # movielens | yow
    n_users: int = 200,
    n_items: int = 4000,
    m1: int = 1000,
    m2: int = 50,
    n_obs: int | None = None,
    train_frac: float = 0.75,
    recommender_epochs: int = 3,
) -> RankingExperiment:
    """End-to-end data stage of the paper's experiment:

    1. generate latent-factor interactions; train the Appendix-B
       recommender on them;
    2. per user, take the m1 highest-utility items as the candidate slate
       (the paper ranks "top 50/500/1000 from among the 1000
       highest-utility items");
    3. build Table-1 constraints over each user's slate;
    4. user covariates = learned user embeddings.
    """
    from repro.models.recommender import PaperRecommender, RecommenderConfig

    kd, kc, kt, ks = jax.random.split(key, 4)
    n_obs = n_obs or n_users * 60
    inter = make_interactions(kd, n_users=n_users, n_items=n_items, n_obs=n_obs)

    cfg = RecommenderConfig(n_users=n_users, n_items=n_items)
    rec = PaperRecommender(cfg)
    params = rec.init(kt)
    params, _ = rec.train(
        params, {"uid": inter.uid, "iid": inter.iid, "rating": inter.rating},
        key=jax.random.fold_in(kt, 1), epochs=recommender_epochs,
    )

    corpus = (make_movielens_corpus(kc, n_items) if dataset == "movielens"
              else make_yow_corpus(kc, n_items))
    gamma = dcg_discount(m2)

    uid = jnp.arange(n_users)
    # chunk the all-items utility computation to bound memory
    chunks = []
    step = max(1, 65536 // max(n_items, 1))
    for s in range(0, n_users, step):
        chunks.append(rec.utilities(params, uid[s:s + step]))
    u_all = jnp.concatenate(chunks, axis=0)              # (n_users, n_items)
    top_u, top_idx = jax.lax.top_k(u_all, m1)            # candidate slates

    cons_fn = movielens_constraints if dataset == "movielens" else yow_constraints
    a_rows, b_ref = [], None
    for l in range(n_users):
        cs = cons_fn(corpus, top_idx[l], gamma, m2)
        a_rows.append(cs.a)
        b_ref = cs.b
    a = jnp.stack(a_rows)                                # (n, K, m1)

    X = rec.user_covariates(params, uid)                 # (n, d_embed)

    n_train = int(round(train_frac * n_users))
    perm = jax.random.permutation(ks, n_users)
    return RankingExperiment(
        u=top_u, X=X, a=a, b=b_ref, gamma=gamma, m2=m2,
        train_idx=perm[:n_train], test_idx=perm[n_train:],
    )


# --------------------------------------------------------------------------
# Drifting-traffic generators (the refresh lane's scenario class)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class DriftSpec:
    """A mid-stream distribution shift, parameterized by stream
    position t in [0, 1]: nothing before `start`, a linear ramp to full
    `magnitude` by `end`, held thereafter.

    kind:
      'none'     stationary control (phase stays 0; every draw is the
                 same distribution as t=0 — the bitwise-neutrality
                 baseline for refresh tests).
      'tighten'  constraint tightening: thresholds b scale by
                 1 + (magnitude-1)·phase — the regulator raised the
                 exposure floor mid-stream. Utilities/covariates are
                 untouched, so a frozen predictor keeps serving the
                 stale (now too-small) λ̂.
      'shift'    covariate shift: the user-covariate mean translates by
                 magnitude·phase along a fixed unit direction — the
                 serving distribution walks away from the train db.
      'grow'     support growth: with probability min(phase, 1) a user
                 is drawn from a NEW population cluster centered
                 magnitude away — the db-growth regime (the world's
                 user base expands past what the predictor was fit on;
                 the KNN ring-write is how the frozen-shape db absorbs
                 it).
    """

    kind: str = "none"
    start: float = 0.25
    end: float = 0.75
    magnitude: float = 3.0

    def __post_init__(self):
        if self.kind not in ("none", "tighten", "shift", "grow"):
            raise ValueError(f"unknown drift kind {self.kind!r}")
        if not 0.0 <= self.start <= self.end <= 1.0:
            raise ValueError(f"need 0 <= start <= end <= 1, got "
                             f"[{self.start}, {self.end}]")


def drift_phase(spec: DriftSpec, t: float) -> float:
    """Ramp position in [0, 1] at stream fraction `t`."""
    if spec.kind == "none" or t <= spec.start:
        return 0.0
    if t >= spec.end:
        return 1.0
    return (t - spec.start) / (spec.end - spec.start)


def drift_request_params(
    rng: np.random.Generator, spec: DriftSpec, t: float, *,
    m1: int, m2: int, K: int, d_cov: int,
    topic_rate: float = 0.15, b_frac: float = 0.03,
) -> dict:
    """One request's synthetic payload at stream fraction `t` under
    `spec` (numpy host arrays, the serving engine's input convention):
    utilities ~ U[1, 5], sparse binary topic attributes, thresholds as
    a fraction of the total slot discount, standard-normal covariates —
    the same conventions as serving/traffic.py — with the drift kind's
    transformation applied at the current ramp phase."""
    phase = drift_phase(spec, t)
    u = rng.uniform(1.0, 5.0, m1).astype(np.float32)
    a = (rng.random((K, m1)) < topic_rate).astype(np.float32)
    gamma = np.asarray(dcg_discount(m2), np.float32)
    frac = b_frac
    if spec.kind == "tighten":
        frac = b_frac * (1.0 + (spec.magnitude - 1.0) * phase)
    b = (frac * float(gamma.sum()) * np.ones(K, np.float32))
    X = rng.normal(size=d_cov).astype(np.float32)
    if spec.kind == "shift":
        direction = np.ones(d_cov, np.float32) / np.sqrt(d_cov)
        X = X + np.float32(spec.magnitude * phase) * direction
    elif spec.kind == "grow" and rng.random() < phase:
        center = np.full(d_cov, spec.magnitude / np.sqrt(d_cov), np.float32)
        X = X + center
    return {"u": u, "a": a, "b": b, "gamma": gamma, "X": X}
