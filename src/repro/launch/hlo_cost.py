"""Trip-count-aware cost walk over compiled (post-SPMD, per-device) HLO.

Why this exists: XLA's ``compiled.cost_analysis()`` visits every
computation ONCE — a 61-layer ``lax.scan`` body is counted as one
iteration (verified empirically; see EXPERIMENTS.md §Dry-run), so FLOPs,
bytes, and any text-level collective count undercount loops by the trip
count. This module re-derives the three roofline inputs with loops
multiplied out:

  flops             MXU work: 2 * prod(result dims) * prod(contracting
                    dims) per ``dot`` (vector-unit transcendentals are
                    deliberately excluded — the compute roofline term is
                    MXU peak).
  bytes             HBM traffic: per op, operand + result buffer sizes,
                    with the three aliasing patterns that matter handled:
                      * fused dynamic-slice reads count the SLICE, not
                        the full operand (layer-stacked weight scans);
                      * dynamic-update-slice writes count the UPDATE
                        (KV-cache append);
                      * gather/scatter count touched rows, not the whole
                        table (embedding lookups).
                    Fusion internals are free (one pass over inputs and
                    outputs — XLA's own fusion cost convention).
  collectives       result-buffer bytes per collective kind.

All three are multiplied by while-loop trip counts (parsed from the loop
condition's comparison constant) and averaged over conditional branches.
Shapes in post-SPMD HLO are per-device, so every number here is
PER-DEVICE per step.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "f8e4m3": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while",
    "conditional", "call",
}

# Elementwise ops count RESULT bytes only: on TPU, XLA fuses them into
# their producers (one read-modify-write pass); the CPU backend we
# compile on fuses less, and charging operands+result would bake the CPU
# fusion boundaries into the TPU roofline.
_ELEMENTWISE_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "select", "compare", "convert", "broadcast", "exponential", "tanh",
    "negate", "abs", "and", "or", "not", "xor", "power", "rsqrt", "sqrt",
    "log", "exp", "floor", "ceil", "sign", "clamp", "reshape",
    "transpose", "reverse", "expm1", "log1p", "logistic", "cosine",
    "sine", "rem", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "pad", "concatenate", "reduce-window",
}


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _buffer_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(type_str):
        total += math.prod(dims) * _DTYPE_BYTES[dtype]
    return total


@dataclass
class Op:
    name: str
    type_str: str
    op: str
    operands: list[str]
    attrs: str
    line: str


@dataclass
class Computation:
    name: str
    ops: list[Op] = field(default_factory=list)
    symbols: dict = field(default_factory=dict)   # %name -> type_str


# op line:  %name = TYPE opname(...), attrs
# TYPE may be a (possibly NESTED) tuple — match greedily and let the
# opname anchor backtrack to the correct split.
_OPLINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s+=\s+"
    r"((?:\(.*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"([a-z][a-z0-9\-]*)"
    r"\((.*?)\)(.*)$")

_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")


def parse_module(hlo: str) -> tuple[dict[str, Computation], str]:
    """-> ({name: Computation}, entry_name)."""
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _HDR_RE.match(line.strip())
            if m and ("->" in line):
                cur = Computation(name=m.group(1))
                if line.strip().startswith("ENTRY"):
                    entry = cur.name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OPLINE_RE.match(line)
        if not m:
            continue
        name, type_str, op, operand_str, attrs = m.groups()
        operands = re.findall(r"%([\w.\-]+)", operand_str)
        cur.symbols[name] = type_str
        cur.ops.append(Op(name=name, type_str=type_str, op=op,
                          operands=operands, attrs=attrs, line=line))
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


def _called(attrs: str, key: str) -> list[str]:
    m = re.search(key + r"=\{?%?([\w.\-]+(?:, ?%[\w.\-]+)*)\}?", attrs)
    if not m:
        return []
    return [s.strip().lstrip("%") for s in m.group(1).split(",")]


def _trip_count(cond: Computation) -> int:
    """Loop condition compares the induction var (starting at 0) against a
    constant: take the largest integer constant in the condition."""
    best = 1
    for op in cond.ops:
        if op.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", op.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(op: Op, comp: Computation) -> float:
    res = _shape_dims(op.type_str)
    if not res:
        return 0.0
    result_elems = math.prod(res[0][1]) if res[0][1] else 1
    lhs_type = comp.symbols.get(op.operands[0], "") if op.operands else ""
    lhs = _shape_dims(lhs_type)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    contract = 1
    if lhs and m and m.group(1):
        dims = lhs[0][1]
        for d in m.group(1).split(","):
            contract *= dims[int(d)]
    return 2.0 * result_elems * contract


def _operand_bytes(op: Op, comp: Computation) -> int:
    return sum(_buffer_bytes(comp.symbols.get(o, "")) for o in op.operands)


def _fusion_bytes(op: Op, comp: Computation,
                  comps: dict[str, Computation]) -> int:
    """Result + operands, but slice-consumed / DUS-produced params count at
    their touched size."""
    called = _called(op.attrs, "calls")
    inner = comps.get(called[0]) if called else None
    out_bytes = _buffer_bytes(op.type_str)
    if inner is None:
        return out_bytes + _operand_bytes(op, comp)
    # map fused-computation parameter index -> caller operand
    param_sizes = {}
    for iop in inner.ops:
        if iop.op == "parameter":
            m = re.search(r"parameter\((\d+)\)", iop.line)
            if m:
                param_sizes[iop.name] = int(m.group(1))
    # resolve pass-through chains (copy/bitcast/convert/reshape) so a
    # parameter consumed by a slice THROUGH a bitcast still counts as
    # sliced (the lax.scan carry-stash DUS pattern)
    _PASSTHRU = {"copy", "bitcast", "convert", "reshape", "transpose"}
    origin = dict.fromkeys(param_sizes, None)
    for p in param_sizes:
        origin[p] = p
    for iop in inner.ops:
        if iop.op in _PASSTHRU and iop.operands:
            src = origin.get(iop.operands[0])
            if src is not None:
                origin[iop.name] = src

    def _param_of(name):
        return origin.get(name)

    sliced_params = set()
    sliced_bytes = 0
    for iop in inner.ops:
        if iop.op in ("dynamic-slice", "slice"):
            for o in iop.operands:
                p = _param_of(o)
                if p is not None:
                    sliced_params.add(p)
                    sliced_bytes += _buffer_bytes(iop.type_str)
        elif iop.op == "gather":
            p = _param_of(iop.operands[0]) if iop.operands else None
            if p is not None:
                sliced_params.add(p)
                sliced_bytes += _buffer_bytes(iop.type_str)
        elif iop.op == "dynamic-update-slice":
            p = _param_of(iop.operands[0]) if iop.operands else None
            if p is not None:
                sliced_params.add(p)
                upd = iop.operands[1] if len(iop.operands) > 1 else None
                sliced_bytes += _buffer_bytes(inner.symbols.get(upd, ""))
                # output buffer aliases the input: don't charge full result
                out_bytes = min(out_bytes,
                                _buffer_bytes(inner.symbols.get(upd, "")))
    full = 0
    for pname, idx in param_sizes.items():
        if pname in sliced_params:
            continue
        if idx < len(op.operands):
            full += _buffer_bytes(comp.symbols.get(op.operands[idx], ""))
    return out_bytes + full + sliced_bytes


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: dict = field(default_factory=dict)
    unknown_trip_loops: int = 0

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0) + v
        self.unknown_trip_loops += other.unknown_trip_loops
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f,
                    {k: v * f for k, v in self.collectives.items()},
                    self.unknown_trip_loops)


def _comp_cost(comp: Computation, comps: dict[str, Computation],
               memo: dict) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    total = Cost()
    memo[comp.name] = total  # break cycles defensively
    for op in comp.ops:
        kind = None
        base = op.op[:-6] if op.op.endswith("-start") else op.op
        for ck in COLLECTIVE_KINDS:
            if base == ck:
                kind = ck
                break
        if op.op.endswith("-done"):
            continue
        if kind is not None:
            b = _buffer_bytes(op.type_str)
            total.collectives[kind] = total.collectives.get(kind, 0) + b
            total.bytes += b  # collectives also touch HBM
            continue
        if op.op == "while":
            body = _called(op.attrs, "body")
            cond = _called(op.attrs, "condition")
            trips = 1
            if cond and cond[0] in comps:
                trips = _trip_count(comps[cond[0]])
            if body and body[0] in comps:
                total += _comp_cost(comps[body[0]], comps, memo).scaled(trips)
            continue
        if op.op == "conditional":
            branches = _called(op.attrs, "branch_computations")
            if not branches:
                branches = [c for c in (_called(op.attrs, "true_computation")
                                        + _called(op.attrs, "false_computation"))]
            costs = [_comp_cost(comps[b], comps, memo) for b in branches
                     if b in comps]
            if costs:
                # branch probabilities unknown -> average (documents the
                # causal-chunk-skip pattern without assuming it)
                total += Cost(
                    sum(c.flops for c in costs) / len(costs),
                    sum(c.bytes for c in costs) / len(costs),
                    {k: sum(c.collectives.get(k, 0) for c in costs) / len(costs)
                     for c in costs for k in c.collectives},
                )
            continue
        if op.op == "call":
            for c in _called(op.attrs, "to_apply"):
                if c in comps:
                    total += _comp_cost(comps[c], comps, memo)
            continue
        if op.op == "fusion":
            total.bytes += _fusion_bytes(op, comp, comps)
            called = _called(op.attrs, "calls")
            if called and called[0] in comps:
                inner = comps[called[0]]
                for iop in inner.ops:
                    if iop.op == "dot":
                        total.flops += _dot_flops(iop, inner)
            continue
        if op.op == "dot":
            total.flops += _dot_flops(op, comp)
            total.bytes += _buffer_bytes(op.type_str) + _operand_bytes(op, comp)
            continue
        if op.op in ("gather", "scatter"):
            res = _buffer_bytes(op.type_str)
            idx = (_buffer_bytes(comp.symbols.get(op.operands[1], ""))
                   if len(op.operands) > 1 else 0)
            total.bytes += 2 * res + idx  # touched rows, not the full table
            continue
        if op.op == "dynamic-update-slice":
            upd = (_buffer_bytes(comp.symbols.get(op.operands[1], ""))
                   if len(op.operands) > 1 else 0)
            total.bytes += 2 * upd
            continue
        if op.op in _SKIP_BYTES_OPS:
            continue
        if op.op in _ELEMENTWISE_OPS:
            total.bytes += _buffer_bytes(op.type_str)
            continue
        # default: one pass over operands + result
        total.bytes += _buffer_bytes(op.type_str) + _operand_bytes(op, comp)
    memo[comp.name] = total
    return total


def hlo_cost(hlo_text: str) -> dict:
    """Per-device, per-step cost of the compiled module."""
    comps, entry = parse_module(hlo_text)
    memo: dict = {}
    # fused computations are charged at their call sites; only walk entry
    cost = _comp_cost(comps[entry], comps, memo) if entry in comps else Cost()
    coll = dict(cost.collectives)
    coll["total"] = sum(coll.values())
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collectives": coll,
        "unknown_trip_loops": cost.unknown_trip_loops,
        "n_computations": len(comps),
    }
