"""Production mesh definitions.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE first jax
init; everything else sees the real single CPU device).

Mesh axes:
  single-pod  (16, 16)      ('data', 'model')   — 256 chips (one v5e pod)
  multi-pod   (2, 16, 16)   ('pod', 'data', 'model') — 512 chips
Growing the 'pod' axis scales to 1000+ nodes: cross-pod traffic is the
DP gradient all-reduce (optionally int8-compressed,
repro.optim.compression) — matched to the DCN-vs-ICI bandwidth split.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(*, multi_pod: bool = False):
    """Tiny meshes for plumbing tests (8 host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
