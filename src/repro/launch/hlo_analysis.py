"""HLO post-mortem for the dry-run: collective bytes, op census, memory.

cost_analysis() gives FLOPs and HBM bytes but NOT collective traffic —
we parse the post-SPMD per-device HLO text and sum the RESULT buffer
sizes of every collective op, bucketed by kind. Result-size is the
per-device bytes landed by the collective; for ring algorithms actual
link traffic is within 2x of this, uniformly across ops, so relative
comparisons (the §Perf deltas) are exact and absolute terms conservative.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "f8e4m3": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

# one buffer type like  bf16[8,128]{1,0:T(8,128)}  or f32[] or pred[4]
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _buffer_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


# "%name = TYPE op-name(" where TYPE may be a tuple; capture lazily up to
# the op name we care about.
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*))\s+"
    r"([a-z0-9-]+)(?:-start|-done)?\(")


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-buffer bytes per collective kind. Returns
    {kind: bytes, ..., 'total': int, 'count': int}."""
    out: dict = defaultdict(int)
    count = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _OP_RE.search(s)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        kind = None
        for ck in COLLECTIVE_KINDS:
            if op == ck or op.startswith(ck + "-"):
                kind = ck
                break
        if kind is None:
            continue
        # '-done' ops alias the '-start' buffer; count once (at start/plain)
        if op.endswith("-done"):
            continue
        out[kind] += _buffer_bytes(type_str)
        count += 1
    out = dict(out)
    out["total"] = sum(v for k, v in out.items())
    out["count"] = count
    return out


def op_census(hlo_text: str, ops=("fusion", "custom-call", "while",
                                  "dot", "convolution", "scatter",
                                  "gather", "sort")) -> dict:
    """Rough op histogram — used to spot remat recompute and layout
    churn (duplicate op names) when hillclimbing."""
    census: dict = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m and m.group(2) in ops:
            census[m.group(2)] += 1
    return dict(census)


def memory_analysis_dict(compiled) -> dict:
    """compiled.memory_analysis() -> plain dict (None-safe: the CPU
    backend may not implement it)."""
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def cost_analysis_dict(compiled) -> dict:
    """compiled.cost_analysis() -> plain dict of floats."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {str(k): float(v) for k, v in dict(ca).items()}
