"""Roofline terms from dry-run artifacts (TPU v5e targets).

  compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
  memory     = HLO_bytes / (chips * HBM_BW)
  collective = collective_bytes / (chips * LINK_BW)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(). On the host
platform XLA reports the PRE-partition (global) program cost, so both
are divided by the chip count; collective_bytes is parsed from the
post-SPMD per-device HLO (already per-device, counted once per chip).

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per the assignment;
useful_fraction = MODEL_FLOPS / HLO_FLOPs flags remat/redundancy waste
(train cells; >1 would mean the compiler pruned declared compute).
"""

from __future__ import annotations

from dataclasses import dataclass

PEAK_FLOPS = 197e12      # bf16 FLOP/s per v5e chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link (~3 links usable/chip)


@dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_accessed: float
    collective_bytes: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def compute_fraction(self) -> float:
        """Fraction of the step spent at peak-FLOPs usefulness if the
        dominant term were perfectly overlapped with the others."""
        return self.compute_s / self.bound_s if self.bound_s else 0.0

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "bound_s": self.bound_s,
            "compute_fraction": self.compute_fraction,
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "chips": self.chips,
        }


def terms_from_artifact(artifact: dict) -> RooflineTerms:
    """artifact: one dry-run JSON record (launch/dryrun.py).

    Prefers the trip-count-aware per-device hlo_cost walk; falls back to
    XLA's cost_analysis (global, loop-undercounting) when absent.
    """
    chips = int(artifact["mesh_devices"])
    hc = artifact.get("hlo_cost")
    if hc:
        flops = float(hc["flops"])          # per-device, loops multiplied
        bytes_accessed = float(hc["bytes"])
        coll = float(hc["collectives"].get("total", 0.0))
        return RooflineTerms(
            compute_s=flops / PEAK_FLOPS,
            memory_s=bytes_accessed / HBM_BW,
            collective_s=coll / LINK_BW,
            flops=flops, bytes_accessed=bytes_accessed,
            collective_bytes=coll, chips=chips,
        )
    ca = artifact.get("xla_cost_analysis_raw",
                      artifact.get("cost_analysis", {}))
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    coll = float(artifact.get("collective_bytes", {}).get("total", 0.0))
    return RooflineTerms(
        compute_s=flops / (chips * PEAK_FLOPS),
        memory_s=bytes_accessed / (chips * HBM_BW),
        collective_s=coll / LINK_BW,
        flops=flops,
        bytes_accessed=bytes_accessed,
        collective_bytes=coll,
        chips=chips,
    )


def model_flops(arch_name: str, cfg, cell) -> float | None:
    """6*N(_active)*D for LM train cells; None where the 6ND convention
    does not define a number (inference steps use 2ND per token)."""
    family = getattr(cfg, "name", "")
    if not hasattr(cfg, "active_params_per_token"):
        return None
    tokens = cell["seq_len"] * cell["global_batch"]
    n_active = cfg.active_params_per_token
    if cell.kind == "train":
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        return 2.0 * n_active * tokens
    if cell.kind == "decode":
        return 2.0 * n_active * cell["global_batch"]
    return None
