"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs REAL steps on the available devices (reduced configs on CPU; the
same code path pjit-shards on a pod) through the fault-tolerant runner:
checkpoint/restart, deterministic batch replay, straggler accounting.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --steps 50 --smoke --ckpt-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch deepfm --steps 100 --smoke
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointStore
from repro.configs import get_arch
from repro.data.batches import (
    make_deepfm_batch,
    make_lm_batch,
    make_random_graph,
    make_seqrec_batch,
)
from repro.distributed.runner import FaultTolerantRunner
from repro.optim import adam_init


def build_training(arch_name: str, *, smoke: bool, batch: int | None,
                   seq: int | None):
    """-> (state, step_fn, batch_fn, describe)."""
    spec = get_arch(arch_name)
    cfg = spec.make_config(not smoke)
    key = jax.random.key(0)

    if spec.family == "lm":
        from repro.models.transformer import TransformerLM
        model = TransformerLM(cfg)
        B = batch or (2 if smoke else 8)
        S = seq or (64 if smoke else 512)
        params = model.init(key)
        state = (params, adam_init(params, cfg.moment_dtype))

        @jax.jit
        def step_fn_jit(params, opt, batch):
            return model.train_step(params, opt, batch)

        def step_fn(state, batch):
            params, opt = state
            params, opt, metrics = step_fn_jit(params, opt, batch)
            return (params, opt), metrics

        def batch_fn(step):
            return make_lm_batch(jax.random.key(step), batch=B, seq=S,
                                 vocab=cfg.vocab)

    elif spec.family == "recsys":
        from repro.models.recsys import RECSYS_REGISTRY
        model = RECSYS_REGISTRY[cfg.kind](cfg)
        B = batch or (16 if smoke else 4096)
        params = model.init(key)
        state = (params, adam_init(params))

        @jax.jit
        def step_fn_jit(params, opt, batch):
            return model.train_step(params, opt, batch)

        def step_fn(state, batch):
            params, opt = state
            params, opt, metrics = step_fn_jit(params, opt, batch)
            return (params, opt), metrics

        def batch_fn(step):
            k = jax.random.key(step)
            if cfg.kind == "deepfm":
                return make_deepfm_batch(k, batch=B, n_sparse=cfg.n_sparse,
                                         field_vocab=cfg.field_vocab)
            return make_seqrec_batch(k, batch=B, seq_len=cfg.seq_len,
                                     n_items=cfg.n_items, n_neg=15,
                                     kind=cfg.kind)

    elif spec.family == "gnn":
        from dataclasses import replace

        from repro.models.gnn import MeshGraphNet
        N, E = (64, 160) if smoke else (2048, 8192)
        cfg = replace(cfg, d_node_in=16, d_edge_in=8, d_out=3)
        model = MeshGraphNet(cfg)
        params = model.init(key)
        state = (params, adam_init(params))

        @jax.jit
        def step_fn_jit(params, opt, graph):
            return model.train_step(params, opt, graph)

        def step_fn(state, graph):
            params, opt = state
            params, opt, metrics = step_fn_jit(params, opt, graph)
            return (params, opt), metrics

        def batch_fn(step):
            return make_random_graph(jax.random.key(step), n_nodes=N,
                                     n_edges=E, d_node=16, d_edge=8, d_out=3)

    else:
        raise ValueError(f"{arch_name}: train driver supports lm/recsys/gnn")

    return state, step_fn, batch_fn, {"arch": arch_name, "family": spec.family}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject one failure at this step (chaos drill)")
    args = ap.parse_args()

    state, step_fn, batch_fn, desc = build_training(
        args.arch, smoke=args.smoke, batch=args.batch, seq=args.seq)
    store = CheckpointStore(f"{args.ckpt_dir}/{args.arch}", keep_last=2)
    runner = FaultTolerantRunner(
        store, step_fn, batch_fn, ckpt_every=args.ckpt_every)

    injected = {args.fail_at} if args.fail_at is not None else set()
    t0 = time.perf_counter()
    state, report = runner.run(
        state, args.steps,
        fail_at=(lambda s: s in injected and not injected.discard(s)))
    dt = time.perf_counter() - t0
    losses = [m.get("loss") for m in report.metrics_history if "loss" in m]
    print(json.dumps({
        **desc, "steps": report.steps_run, "restarts": report.restarts,
        "checkpoints": report.checkpoints,
        "stragglers": report.straggler_steps,
        "wall_s": round(dt, 2),
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
    }, indent=1))


if __name__ == "__main__":
    main()
