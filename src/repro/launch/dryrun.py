import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above run BEFORE any other import (jax locks the device
count at first init). Do NOT replicate them anywhere global — smoke
tests and benchmarks must see the single real CPU device.

Per cell:
  with mesh:
      lowered = jax.jit(step, donate_argnums=...).lower(*abstract_args)
      compiled = lowered.compile()
      memory_analysis / cost_analysis / collective-bytes(HLO)

and a JSON artifact lands in experiments/dryrun/<mesh>/<arch>__<cell>.json
for the roofline report. Failures are recorded (and are bugs to fix).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch kimi-k2-1t-a32b \
      --cell train_4k --mesh multi [--smoke] [--force]
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import all_archs, get_arch
from repro.distributed.sharding import use_mesh_rules
from repro.launch.hlo_analysis import (
    collective_bytes,
    cost_analysis_dict,
    memory_analysis_dict,
    op_census,
)
from repro.launch.mesh import make_production_mesh, make_smoke_mesh
from repro.launch.roofline import terms_from_artifact

DEFAULT_OUT = "experiments/dryrun"


def run_cell(arch_name: str, cell_name: str, mesh, mesh_tag: str,
             *, smoke: bool = False, variant: str | None = None) -> dict:
    spec = get_arch(arch_name)
    cell = spec.cell(cell_name)
    if smoke:
        # reduced cells keep the same names
        from repro.configs.gnn_family import GNN_SMOKE_CELLS
        from repro.configs.lm_family import LM_SMOKE_CELLS
        from repro.configs.paper import PAPER_SMOKE_CELLS
        from repro.configs.recsys_family import RECSYS_SMOKE_CELLS
        table = {"lm": LM_SMOKE_CELLS, "gnn": GNN_SMOKE_CELLS,
                 "recsys": RECSYS_SMOKE_CELLS, "paper": PAPER_SMOKE_CELLS}
        cell = next(c for c in table[spec.family] if c.name == cell_name)
    if variant:
        cfg = spec.variants[variant]()
    else:
        cfg = spec.make_config(not smoke)

    record = {
        "arch": arch_name, "cell": cell_name, "kind": cell.kind,
        "mesh": mesh_tag, "mesh_shape": list(mesh.devices.shape),
        "mesh_devices": mesh.devices.size, "smoke": smoke,
        "variant": variant,
        "cell_params": {k: (list(v) if isinstance(v, tuple) else v)
                        for k, v in cell.params.items()},
        "status": "error",
    }
    t0 = time.perf_counter()
    low = spec.build(cfg, cell, mesh)
    with use_mesh_rules(mesh, low.rules):
        jitted = jax.jit(low.fn, donate_argnums=low.donate)
        lowered = jitted.lower(*low.args)
        record["lower_s"] = round(time.perf_counter() - t0, 2)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        record["compile_s"] = round(time.perf_counter() - t1, 2)

    record["memory_analysis"] = memory_analysis_dict(compiled)
    # XLA's cost_analysis counts while bodies ONCE (verified; see
    # launch/hlo_cost.py) — kept for reference only. The roofline reads
    # hlo_cost: the trip-count-aware per-device walk.
    record["xla_cost_analysis_raw"] = {
        k: v for k, v in cost_analysis_dict(compiled).items()
        if not k.startswith("operand ")
    }
    hlo = compiled.as_text()
    from repro.launch.hlo_cost import hlo_cost
    record["hlo_cost"] = hlo_cost(hlo)
    record["collective_bytes"] = record["hlo_cost"]["collectives"]
    record["collective_bytes_static"] = collective_bytes(hlo)
    record["op_census"] = op_census(hlo)
    record["hlo_lines"] = hlo.count("\n")
    ma = record["memory_analysis"]
    if ma:
        per_dev = (ma.get("argument_size_in_bytes", 0)
                   + ma.get("temp_size_in_bytes", 0)
                   + ma.get("output_size_in_bytes", 0)
                   - ma.get("alias_size_in_bytes", 0))
        record["per_device_bytes"] = int(per_dev)
    record["roofline"] = terms_from_artifact(record).as_dict()
    record["status"] = "ok"
    return record


def save_record(record: dict, out_dir: str):
    d = os.path.join(out_dir, record["mesh"])
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"{record['arch']}__{record['cell']}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--cell", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs + 8-device meshes (plumbing test)")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells that already have artifacts")
    ap.add_argument("--variant", default=None,
                    help="named optimized config variant (§Perf hillclimb)")
    args = ap.parse_args()

    archs = all_archs() if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_fail = n_skip = 0
    for multi in meshes:
        mesh_tag = ("smoke_" if args.smoke else "") + ("multi" if multi else "single")
        if args.variant:
            mesh_tag = f"{mesh_tag}@{args.variant}"
        mesh = (make_smoke_mesh(multi_pod=multi) if args.smoke
                else make_production_mesh(multi_pod=multi))
        for arch in archs:
            spec = get_arch(arch)
            cells = ([c.name for c in spec.cells] if args.cell == "all"
                     else args.cell.split(","))
            for cell in cells:
                if cell not in [c.name for c in spec.cells]:
                    continue
                path = os.path.join(args.out, mesh_tag, f"{arch}__{cell}.json")
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        if json.load(f).get("status") == "ok":
                            n_skip += 1
                            continue
                try:
                    rec = run_cell(arch, cell, mesh, mesh_tag,
                                   smoke=args.smoke, variant=args.variant)
                    n_ok += 1
                except Exception as e:
                    rec = {
                        "arch": arch, "cell": cell, "mesh": mesh_tag,
                        "mesh_devices": mesh.devices.size,
                        "smoke": args.smoke, "status": "error",
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-3000:],
                    }
                    n_fail += 1
                save_record(rec, args.out)
                jax.clear_caches()  # bound compile-cache memory across cells
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(f"[{mesh_tag}] {arch}/{cell}: OK "
                          f"lower {rec['lower_s']}s compile {rec['compile_s']}s "
                          f"| compute {r['compute_s']:.3e}s "
                          f"memory {r['memory_s']:.3e}s "
                          f"coll {r['collective_s']:.3e}s "
                          f"-> {r['dominant']}", flush=True)
                else:
                    print(f"[{mesh_tag}] {arch}/{cell}: FAIL {rec['error']}",
                          flush=True)
    print(f"dry-run done: {n_ok} ok, {n_fail} failed, {n_skip} cached")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
