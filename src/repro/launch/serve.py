"""Serving driver: ``python -m repro.launch.serve --arch <recsys-id>``.

The deployment shape of the paper's system: train (or load) a retrieval
backbone, run Algorithm 1's offline stage (batched dual solve on a user
sample + KNN predictor fit), then serve a STREAM of heterogeneous
requests through the shape-bucketed, async double-buffered
micro-batching engine (repro.serving) and report per-request latency
percentiles, compliance, pipeline overlap, and jit-cache behaviour
(steady state must not recompile). --pipeline-depth 0 serves
synchronously (the pre-pipeline engine) for A/B comparison.

Backbone scoring runs as one fixed-shape jit program per arrival chunk;
each user then becomes an individual RankRequest whose candidate count
is jittered (live retrieval returns varying candidate sets), exercising
the engine's bucket lattice the way live traffic would.

  PYTHONPATH=src python -m repro.launch.serve --arch sasrec --requests 256
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.constraints import dcg_discount
from repro.core.dual_solver import solve_dual_batch
from repro.core.predictors import KNNLambdaPredictor, MeanLambdaPredictor
from repro.data.batches import make_deepfm_batch, make_seqrec_batch
from repro.models.recsys import RECSYS_REGISTRY
from repro.optim import adam_init
from repro.serving import FleetRouter, RankRequest, RankResult, ServingEngine


def _request_batch(cfg, B, seed):
    k = jax.random.key(seed)
    if cfg.kind == "deepfm":
        return make_deepfm_batch(k, batch=B, n_sparse=cfg.n_sparse,
                                 field_vocab=cfg.field_vocab)["ids"]
    return make_seqrec_batch(k, batch=B, seq_len=cfg.seq_len,
                             n_items=cfg.n_items, n_neg=1,
                             kind=cfg.kind)["seq"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="sasrec",
                    choices=["deepfm", "sasrec", "bert4rec", "mind"])
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--candidates", type=int, default=512)
    ap.add_argument("--m2", type=int, default=50)
    ap.add_argument("--constraints", type=int, default=5)
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--offline-users", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=32,
                    help="engine micro-batch capacity")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="micro-batch assembly deadline")
    ap.add_argument("--executor", default="xla", choices=["xla", "fused"])
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="in-flight micro-batch window; 1 = double "
                         "buffering, 0 = synchronous engine")
    ap.add_argument("--m1-jitter", type=float, default=0.5,
                    help="per-request candidate-count jitter in "
                         "[1-jitter, 1] * --candidates")
    ap.add_argument("--admission", action="store_true",
                    help="enable deadline-aware admission control with a "
                         "KNN -> mean degradation ladder")
    ap.add_argument("--budget-ms", type=float, default=50.0,
                    help="per-request latency budget (the paper's SLA)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through a fault-tolerant FleetRouter "
                         "over N engine replicas (health-checked "
                         "consistent-hash routing, hedged retries, "
                         "supervised restart); 1 = single engine")
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.make_config(full=False)
    model = RECSYS_REGISTRY[cfg.kind](cfg)
    params = model.init(jax.random.key(0))

    # --- 1. backbone training (reduced scale on CPU) -----------------------
    opt = adam_init(params)

    @jax.jit
    def train_step(p, o, b):
        return model.train_step(p, o, b, lr=3e-3)

    for step in range(args.train_steps):
        if cfg.kind == "deepfm":
            batch = make_deepfm_batch(jax.random.key(step), batch=64,
                                      n_sparse=cfg.n_sparse,
                                      field_vocab=cfg.field_vocab)
        else:
            batch = make_seqrec_batch(jax.random.key(step), batch=64,
                                      seq_len=cfg.seq_len,
                                      n_items=cfg.n_items, n_neg=15,
                                      kind=cfg.kind)
        params, opt, metrics = train_step(params, opt, batch)

    # --- 2. offline stage: duals + predictor -------------------------------
    n_cand = min(args.candidates, cfg.n_items)
    m2, K = min(args.m2, n_cand), args.constraints
    gamma = np.asarray(dcg_discount(m2), np.float32)
    cand_ids = jnp.arange(n_cand)
    topics = np.asarray(
        (jax.random.uniform(jax.random.key(7), (K, n_cand)) < 0.15),
        np.float32)
    b = (0.08 * gamma.sum() * np.ones(K, np.float32))

    @jax.jit
    def score(params, req):
        """Backbone inference: utilities over the full candidate set +
        user covariates. Fixed shape -> one compile, amortized."""
        user_in = req[:, 1:] if cfg.kind == "deepfm" else req
        u = model.retrieval_scores(params, user_in, cand_ids)
        X = model.user_covariates(params, req)
        return u, X

    off_req = _request_batch(cfg, args.offline_users, seed=10_000)
    u_off, X_off = score(params, off_req)
    sol = solve_dual_batch(u_off, jnp.asarray(topics), jnp.asarray(b),
                           jnp.asarray(gamma), m2=m2, num_iters=300)
    knn = KNNLambdaPredictor.fit(X_off, sol.lam, k=10)

    # --- 3. streaming online stage -----------------------------------------
    def make_engine(_name=None):
        eng = ServingEngine(max_batch=args.max_batch,
                            max_wait_ms=args.max_wait_ms,
                            executor=args.executor,
                            pipeline_depth=args.pipeline_depth,
                            admission=args.admission,
                            default_budget_s=args.budget_ms / 1e3)
        eng.register_predictor(args.arch, knn, d_cov=int(X_off.shape[1]))
        if args.admission:
            # Cheapest rung: intercept-only predictor over the same
            # duals. Pre-warmed like every other bucket, so degrading
            # never compiles.
            mean = MeanLambdaPredictor.fit(X_off, sol.lam)
            eng.register_predictor(f"{args.arch}_mean", mean,
                                   d_cov=int(X_off.shape[1]))
            eng.set_degradation_ladder(args.arch, [f"{args.arch}_mean"])
        return eng

    # materialize the arrival stream: chunked backbone scoring, then one
    # RankRequest per user with a jittered candidate-subset size.
    rng = np.random.default_rng(0)
    chunk = 64
    requests = []
    m1_lo = max(m2, int(n_cand * (1.0 - args.m1_jitter)))
    for c in range(-(-args.requests // chunk)):
        req_in = _request_batch(cfg, chunk, seed=20_000 + c)
        u, X = score(params, req_in)
        u, X = np.asarray(u), np.asarray(X)
        for i in range(min(chunk, args.requests - c * chunk)):
            m1 = int(rng.integers(m1_lo, n_cand + 1))
            m2_req = min(m2, m1)
            requests.append(RankRequest(
                rid=c * chunk + i, u=u[i, :m1], a=topics[:, :m1], b=b,
                m2=m2_req, X=X[i], tag=args.arch, gamma=gamma[:m2_req]))

    report = {
        "arch": args.arch,
        "n_candidates": n_cand, "m2": m2, "K": K,
        "executor": args.executor,
        "max_batch": args.max_batch, "max_wait_ms": args.max_wait_ms,
        "pipeline_depth": args.pipeline_depth,
        "admission": args.admission, "budget_ms": args.budget_ms,
        "replicas": args.replicas,
        "offline_compliance": round(float(sol.compliant.mean()), 3),
    }
    if args.replicas > 1:
        # fleet path: health-checked consistent-hash routing over N
        # replica engines — each warms only its bucket subset (+ backup).
        router = FleetRouter(make_engine, args.replicas)
        warm = router.warmup(requests)
        results = router.serve_stream(requests, warmup=False)
        router.close()
        served = [r for r in results if isinstance(r, RankResult)]
        s = router.fleet_summary()
        lat = s.get("latency_ms", {"p99": float("nan")})
        report.update({
            "requests": len(results),
            "served": len(served), "shed": len(results) - len(served),
            "buckets": {n: w["buckets"] for n, w in warm.items()},
            "compiles_post_warmup": sum(
                r["compiles_post_warmup"] for r in s["replicas"].values()),
            "latency_ms": lat,
            "fleet": {k: s[k] for k in (
                "failovers", "hedges", "duplicates_deduped", "retries",
                "crashes", "restarts", "lost", "orphaned_futures")},
            "replica_states": {n: r["state"]
                               for n, r in s["replicas"].items()},
            "within_budget": bool(lat["p99"] <= args.budget_ms),
        })
    else:
        engine = make_engine()
        warm = engine.warmup(requests)
        results = engine.serve_stream(requests)
        engine.close()
        served = [r for r in results if isinstance(r, RankResult)]
        s = engine.metrics.summary()
        report.update({
            "requests": len(results),
            "served": len(served), "shed": len(results) - len(served),
            "buckets": warm["buckets"],
            "compiles": s["compiles"],
            "compiles_post_warmup": s["compiles_post_warmup"],
            "fill_rate": s["fill_rate"],
            "latency_ms": s["latency_ms"],
            "queue_wait_ms": s["queue_wait_ms"],
            "pipeline": s["pipeline"],
            "online_compliance": s["compliance"],
            "deadline": s["deadline"],
            "within_budget": bool(s["latency_ms"]["p99"] <= args.budget_ms),
        })
    print(json.dumps(report, indent=1))


if __name__ == "__main__":
    main()
