"""Serving driver: ``python -m repro.launch.serve --arch <recsys-id>``.

The deployment shape of the paper's system: train (or load) a retrieval
backbone, run Algorithm 1's offline stage (batched dual solve on a user
sample + KNN predictor fit), then serve batched requests through the
integrated online path and report latency percentiles + compliance.

Runs real inference on the available devices (reduced configs on CPU;
the same code path pjit-shards on a pod — the compiled counterpart is
the dry-run's retrieval_cand / serve_online cells).

  PYTHONPATH=src python -m repro.launch.serve --arch sasrec --requests 256
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.constraints import dcg_discount
from repro.core.dual_solver import solve_dual_batch
from repro.core.predictors import KNNLambdaPredictor
from repro.core.ranking import rank_given_lambda
from repro.data.batches import make_deepfm_batch, make_seqrec_batch
from repro.models.recsys import RECSYS_REGISTRY
from repro.optim import adam_init


def _request_batch(cfg, B, seed):
    k = jax.random.key(seed)
    if cfg.kind == "deepfm":
        return make_deepfm_batch(k, batch=B, n_sparse=cfg.n_sparse,
                                 field_vocab=cfg.field_vocab)["ids"]
    return make_seqrec_batch(k, batch=B, seq_len=cfg.seq_len,
                             n_items=cfg.n_items, n_neg=1,
                             kind=cfg.kind)["seq"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="sasrec",
                    choices=["deepfm", "sasrec", "bert4rec", "mind"])
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--candidates", type=int, default=512)
    ap.add_argument("--m2", type=int, default=50)
    ap.add_argument("--constraints", type=int, default=5)
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--offline-users", type=int, default=256)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.make_config(full=False)
    model = RECSYS_REGISTRY[cfg.kind](cfg)
    params = model.init(jax.random.key(0))

    # --- 1. backbone training (reduced scale on CPU) -----------------------
    opt = adam_init(params)

    @jax.jit
    def train_step(p, o, b):
        return model.train_step(p, o, b, lr=3e-3)

    for step in range(args.train_steps):
        if cfg.kind == "deepfm":
            batch = make_deepfm_batch(jax.random.key(step), batch=64,
                                      n_sparse=cfg.n_sparse,
                                      field_vocab=cfg.field_vocab)
        else:
            batch = make_seqrec_batch(jax.random.key(step), batch=64,
                                      seq_len=cfg.seq_len,
                                      n_items=cfg.n_items, n_neg=15,
                                      kind=cfg.kind)
        params, opt, metrics = train_step(params, opt, batch)

    # --- 2. offline stage: duals + predictor -------------------------------
    n_cand = min(args.candidates, cfg.n_items)
    m2, K = min(args.m2, n_cand), args.constraints
    gamma = dcg_discount(m2)
    cand_ids = jnp.arange(n_cand)
    topics = (jax.random.uniform(jax.random.key(7), (K, n_cand)) < 0.15
              ).astype(jnp.float32)
    b = 0.08 * jnp.sum(gamma) * jnp.ones((K,))

    off_req = _request_batch(cfg, args.offline_users, seed=10_000)
    if cfg.kind == "deepfm":
        u_off = model.retrieval_scores(params, off_req[:, 1:], cand_ids)
        X_off = model.user_covariates(params, off_req)
    else:
        u_off = model.retrieval_scores(params, off_req, cand_ids)
        X_off = model.user_covariates(params, off_req)
    sol = solve_dual_batch(u_off, topics, b, gamma, m2=m2, num_iters=300)
    knn = KNNLambdaPredictor.fit(X_off, sol.lam, k=10)

    # --- 3. online loop -----------------------------------------------------
    @jax.jit
    def serve(params, req):
        if cfg.kind == "deepfm":
            u = model.retrieval_scores(params, req[:, 1:], cand_ids)
            X = model.user_covariates(params, req)
        else:
            u = model.retrieval_scores(params, req, cand_ids)
            X = model.user_covariates(params, req)
        lam_hat = knn.predict(X)
        return rank_given_lambda(u, topics, b, lam_hat, gamma, m2=m2)

    warm = _request_batch(cfg, args.batch_size, seed=1)
    jax.block_until_ready(serve(params, warm).perm)

    lat, compl = [], []
    n_batches = max(args.requests // args.batch_size, 1)
    for i in range(n_batches):
        req = _request_batch(cfg, args.batch_size, seed=20_000 + i)
        t0 = time.perf_counter()
        out = serve(params, req)
        jax.block_until_ready(out.perm)
        lat.append((time.perf_counter() - t0) * 1e3)
        compl.append(float(out.compliant.mean()))
    lat = np.asarray(lat)
    print(json.dumps({
        "arch": args.arch, "requests": n_batches * args.batch_size,
        "batch_size": args.batch_size, "n_candidates": n_cand,
        "m2": m2, "K": K,
        "offline_compliance": round(float(sol.compliant.mean()), 3),
        "p50_ms_batch": round(float(np.percentile(lat, 50)), 2),
        "p99_ms_batch": round(float(np.percentile(lat, 99)), 2),
        "ms_per_user_p50": round(float(np.percentile(lat, 50))
                                 / args.batch_size, 4),
        "online_compliance": round(float(np.mean(compl)), 3),
        "within_50ms_budget": bool(np.percentile(lat, 99) <= 50.0),
    }, indent=1))


if __name__ == "__main__":
    main()
