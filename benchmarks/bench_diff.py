"""Bench-artifact diff: fresh BENCH_*.json vs committed baselines.

Every gate writes a machine-readable BENCH_<name>.json (see
benchmarks.common.write_bench_json). This tool closes the loop by
comparing a fresh artifact directory against the baselines committed
under benchmarks/baselines/, so a PR that silently regresses a derived
health number (padding-waste improvement, swap counts, parity flags)
FAILS CI, while wall-clock drift on shared runners only WARNS by
default:

  * A bench present in the baselines but absent from the fresh
    artifacts is a FAIL (a gate stopped running is the worst silent
    regression there is). Extra fresh benches are fine — they are new
    gates that simply have no baseline yet.
  * A record name present in a baseline bench but missing fresh is a
    FAIL (a renamed record needs its baseline refreshed on purpose).
  * Boolean derived values (parity_ok, rollback_ok, ...) flipping
    True -> False is a FAIL; numeric derived values REGRESSING by more
    than the tolerance band is a FAIL when the baseline marks the
    direction (see DERIVED_HIGHER_IS_BETTER), ignored otherwise.
  * us_per_call outside (1 + tol) x baseline is a WARN — timing on CI
    runners is noisy — unless --strict-timing promotes it to FAIL.

Refreshing a baseline is one command (run the gate with --json
benchmarks/baselines) and one reviewed diff.

Usage:

  python benchmarks/bench_diff.py --fresh bench-artifacts \\
      [--baseline benchmarks/baselines] [--tol 0.5] [--strict-timing]
"""

from __future__ import annotations

import argparse
import glob
import json
import math
import os
import sys

DEFAULT_BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baselines")

# Derived metrics with a known good direction: higher is better. A
# fresh value below baseline * (1 - tol) fails; above never does.
# Lower-is-better counters that must stay exactly at their baseline
# (dispatch-path compiles, rollbacks) are compared as "worse if it
# grew past baseline * (1 + tol)".
DERIVED_HIGHER_IS_BETTER = {
    "waste_improvement", "swaps", "shadow_compiles", "improvement",
}
DERIVED_LOWER_IS_BETTER = {
    "compiles_post_warmup", "waste_adaptive", "lost_requests",
    "orphaned_futures",
}


def _load_benches(dirpath: str) -> dict:
    benches = {}
    for path in sorted(glob.glob(os.path.join(dirpath, "BENCH_*.json"))):
        with open(path) as f:
            payload = json.load(f)
        benches[payload.get("bench", os.path.basename(path))] = payload
    return benches


def _records_by_name(payload: dict) -> dict:
    return {r["name"]: r for r in payload.get("records", ())}


def _is_bool(v) -> bool:
    return isinstance(v, bool)


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def diff_bench(name: str, base: dict, fresh: dict, *, tol: float,
               strict_timing: bool) -> tuple[list, list]:
    """(failures, warnings) for one bench's record set."""
    fails, warns = [], []
    base_recs = _records_by_name(base)
    fresh_recs = _records_by_name(fresh)
    for rname, brec in base_recs.items():
        frec = fresh_recs.get(rname)
        if frec is None:
            fails.append(f"{name}: record '{rname}' present in baseline "
                         f"but missing from fresh artifacts")
            continue
        # timing band (WARN unless --strict-timing)
        b_us, f_us = brec.get("us_per_call"), frec.get("us_per_call")
        if (_is_num(b_us) and _is_num(f_us)
                and not math.isnan(b_us) and not math.isnan(f_us)
                and b_us > 0 and f_us > b_us * (1.0 + tol)):
            msg = (f"{name}/{rname}: us_per_call {f_us:.1f} vs baseline "
                   f"{b_us:.1f} (> +{tol:.0%} band)")
            (fails if strict_timing else warns).append(msg)
        # derived values
        bd, fd = brec.get("derived", {}), frec.get("derived", {})
        for key, bval in bd.items():
            if key not in fd:
                fails.append(f"{name}/{rname}: derived '{key}' vanished")
                continue
            fval = fd[key]
            if _is_bool(bval):
                if bval and not fval:
                    fails.append(f"{name}/{rname}: derived '{key}' "
                                 f"flipped True -> {fval!r}")
            elif _is_num(bval) and _is_num(fval):
                if key in DERIVED_HIGHER_IS_BETTER:
                    if fval < bval * (1.0 - tol):
                        fails.append(
                            f"{name}/{rname}: derived '{key}' {fval} "
                            f"regressed below baseline {bval} "
                            f"(-{tol:.0%} band)")
                elif key in DERIVED_LOWER_IS_BETTER:
                    floor = bval * (1.0 + tol) if bval else 0.0
                    if fval > floor:
                        fails.append(
                            f"{name}/{rname}: derived '{key}' {fval} "
                            f"grew past baseline {bval} "
                            f"(+{tol:.0%} band)")
    return fails, warns


def run_diff(*, fresh_dir: str, baseline_dir: str = DEFAULT_BASELINE_DIR,
             tol: float = 0.5, strict_timing: bool = False,
             verbose: bool = True) -> dict:
    baselines = _load_benches(baseline_dir)
    fresh = _load_benches(fresh_dir)
    fails, warns, compared = [], [], []
    if not baselines:
        fails.append(f"no baselines found under {baseline_dir} — commit "
                     f"at least one BENCH_*.json there")
    for name, base in baselines.items():
        if name not in fresh:
            fails.append(f"bench '{name}' has a committed baseline but "
                         f"no fresh BENCH json in {fresh_dir}")
            continue
        compared.append(name)
        f, w = diff_bench(name, base, fresh[name], tol=tol,
                          strict_timing=strict_timing)
        fails += f
        warns += w
    out = {"compared": compared,
           "extra_fresh": sorted(set(fresh) - set(baselines)),
           "failures": fails, "warnings": warns}
    if verbose:
        for w in warns:
            print(f"WARN  {w}")
        for f in fails:
            print(f"FAIL  {f}")
        print(f"# bench-diff: {len(compared)} bench(es) compared "
              f"({', '.join(compared) or 'none'}), "
              f"{len(out['extra_fresh'])} new without baselines, "
              f"{len(warns)} warning(s), {len(fails)} failure(s)")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True,
                    help="directory of freshly produced BENCH_*.json")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE_DIR,
                    help="directory of committed baseline BENCH_*.json")
    ap.add_argument("--tol", type=float, default=0.5,
                    help="relative tolerance band (default 0.5 = 50%%)")
    ap.add_argument("--strict-timing", action="store_true",
                    help="promote us_per_call band violations to FAIL")
    args = ap.parse_args()
    res = run_diff(fresh_dir=args.fresh, baseline_dir=args.baseline,
                   tol=args.tol, strict_timing=args.strict_timing)
    if res["failures"]:
        sys.exit(1)
    print("# bench-diff acceptance (fresh artifacts within baseline "
          "tolerance band): PASS")


if __name__ == "__main__":
    main()
