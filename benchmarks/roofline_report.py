"""Roofline report: read dry-run artifacts -> the §Roofline table.

Per (arch x cell x mesh): the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness ratio (LM cells), and a
one-line lever on the dominant term. Emits markdown to
experiments/roofline.md and CSV records for benchmarks.run.

The default run reads the CHECKED-IN `experiments/dryrun` artifacts
and emits the full table. `--refresh-dryrun` regenerates the artifacts
first (`python -m repro.launch.dryrun`, both meshes — minutes of XLA
lowering, meant for a machine with headroom) and then reports. When
artifacts are absent for a mesh the report does not fail or silently
truncate: it emits a clearly-labeled partial table naming that mesh
and the command that fills it (documented in docs/benchmarks.md).

    python -m benchmarks.roofline_report [--refresh-dryrun] [--json OUT]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys

from benchmarks.common import Record, write_bench_json
from repro.launch.roofline import PEAK_FLOPS, terms_from_artifact

DRYRUN_DIR = "experiments/dryrun"
OUT_MD = "experiments/roofline.md"

LEVERS = {
    ("lm", "compute"): "more per-chip batch or lower remat recompute",
    ("lm", "memory"): "shard/fuse MoE dispatch buffers; bf16 end-to-end; "
                      "larger microbatch raises arithmetic intensity",
    ("lm", "collective"): "reduce FSDP gather volume (group layers, "
                          "bigger per-chip batch) or cut TP degree",
    ("recsys", "memory"): "fuse lookup+pool (embedding_bag kernel); "
                          "row-shard tables to cut gather footprint",
    ("recsys", "collective"): "distributed top-k (k per shard, not full "
                              "gather); batch-parallel lookups",
    ("gnn", "memory"): "cast messages bf16; fuse edge-MLP chain",
    ("gnn", "collective"): "edge-cut partitioning to shrink halo gathers",
    ("paper", "collective"): "shard_map distributed top-k over the "
                             "database axis (k*shards, not n_db)",
    ("paper", "memory"): "fused_rank kernel: adjusted scores stay in VMEM",
}


def model_flops_for(rec: dict) -> float | None:
    """6*N(_active)*D for LM train cells; 2*N*D for prefill; 2*N*B decode."""
    if rec.get("kind") not in ("train", "prefill", "decode"):
        return None
    try:
        from repro.configs import get_arch
        spec = get_arch(rec["arch"])
        if spec.family != "lm":
            return None
        cfg = spec.make_config(True)
        tokens = rec["cell_params"]["seq_len"] * rec["cell_params"]["global_batch"]
        n_act = cfg.active_params_per_token
        if rec["kind"] == "train":
            return 6.0 * n_act * tokens
        if rec["kind"] == "prefill":
            return 2.0 * n_act * tokens
        return 2.0 * n_act * rec["cell_params"]["global_batch"]
    except Exception:
        return None


def load_records(mesh: str = "single") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, mesh, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def family_of(arch: str) -> str:
    from repro.configs import get_arch
    try:
        return get_arch(arch).family
    except Exception:
        return "?"


def build_table(mesh: str = "single"):
    rows = []
    for rec in load_records(mesh):
        if rec.get("status") != "ok":
            rows.append({"arch": rec["arch"], "cell": rec["cell"],
                         "status": "FAIL", "error": rec.get("error")})
            continue
        t = terms_from_artifact(rec)
        mf = model_flops_for(rec)
        useful = (mf / (t.flops * t.chips)) if (mf and t.flops) else None
        fam = family_of(rec["arch"])
        rows.append({
            "arch": rec["arch"], "cell": rec["cell"], "status": "ok",
            "family": fam,
            "compute_s": t.compute_s, "memory_s": t.memory_s,
            "collective_s": t.collective_s, "dominant": t.dominant,
            "bound_s": t.bound_s,
            "compute_fraction": t.compute_fraction,
            "useful_flops_ratio": useful,
            "lever": LEVERS.get((fam, t.dominant), "raise per-chip work"),
            "per_device_gb": rec.get("per_device_bytes", 0) / 1e9
            if rec.get("per_device_bytes") else None,
        })
    return rows


def to_markdown(rows, mesh: str) -> str:
    lines = [
        f"## Roofline — mesh `{mesh}` "
        f"(v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link)",
        "",
        "| arch | cell | compute s | memory s | collective s | dominant | "
        "compute-frac | useful-FLOPs | lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['cell']} | FAIL | | | | | | "
                         f"{r.get('error','')[:60]} |")
            continue
        uf = (f"{r['useful_flops_ratio']:.2f}"
              if r["useful_flops_ratio"] else "—")
        lines.append(
            f"| {r['arch']} | {r['cell']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['compute_fraction']:.3f} | {uf} | "
            f"{r['lever']} |")
    return "\n".join(lines)


def records(rows, mesh):
    out = []
    for r in rows:
        if r["status"] != "ok":
            continue
        out.append(Record(
            name=f"roofline/{mesh}/{r['arch']}/{r['cell']}",
            us_per_call=r["bound_s"] * 1e6,
            derived={"dominant": r["dominant"],
                     "compute_frac": round(r["compute_fraction"], 4)}))
    return out


def missing_section(mesh: str) -> str:
    """Explicit placeholder for a mesh with no dry-run artifacts."""
    return "\n".join([
        f"## Roofline — mesh `{mesh}` — PARTIAL: no dry-run artifacts",
        "",
        f"No artifacts under `{DRYRUN_DIR}/{mesh}/`. This table is a",
        "placeholder, not a truncation: regenerate the artifacts on a",
        "machine with headroom (the 512-device dry-run is too heavy for",
        "the 2-core CI container) and re-run this report:",
        "",
        "```bash",
        f"PYTHONPATH=src python -m repro.launch.dryrun   # fills {DRYRUN_DIR}/",
        "PYTHONPATH=src python -m benchmarks.roofline_report",
        "```",
    ])


def refresh_dryrun() -> None:
    """Regenerate the dry-run artifacts in a subprocess (same
    interpreter, PYTHONPATH inherited). Raises on a failed run — a
    half-refreshed artifact tree is worse than a stale one."""
    subprocess.run([sys.executable, "-m", "repro.launch.dryrun",
                    "--force"], check=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--refresh-dryrun", action="store_true",
                    help="regenerate experiments/dryrun artifacts first "
                         "(python -m repro.launch.dryrun --force; "
                         "minutes of XLA lowering)")
    ap.add_argument("--json", metavar="OUT", default=None,
                    help="write BENCH_roofline.json to OUT (a directory,"
                         " or an explicit *.json path)")
    args = ap.parse_args()
    if args.refresh_dryrun:
        refresh_dryrun()
    md = []
    all_records = []
    missing = []
    for mesh in ("single", "multi"):
        rows = build_table(mesh)
        if not rows:
            missing.append(mesh)
            md.append(missing_section(mesh))
            continue
        md.append(to_markdown(rows, mesh))
        all_records += records(rows, mesh)
    os.makedirs(os.path.dirname(OUT_MD), exist_ok=True)
    with open(OUT_MD, "w") as f:
        f.write("\n\n".join(md) + "\n")
    for rec in all_records:
        print(rec.csv())
    if missing:
        print(f"# PARTIAL report: no dry-run artifacts for mesh(es) "
              f"{', '.join(missing)} under {DRYRUN_DIR}/ — "
              f"run `python -m repro.launch.dryrun` to fill them "
              f"(see docs/benchmarks.md)")
    if args.json:
        write_bench_json(args.json, "roofline", all_records,
                         meta={"missing_meshes": missing})
    print(f"# wrote {OUT_MD}")


if __name__ == "__main__":
    main()
