"""Kernel-path benchmarks: oracle (XLA) paths timed on CPU, kernel HBM
models derived analytically — plus a kernel-health gate for CI.

interpret=True Pallas runs execute the kernel body in Python per grid
step — meaningful for CORRECTNESS, meaningless for wall time. So here we
time the XLA oracle path (what the CPU actually runs) and report, per
kernel, the analytic HBM-traffic ratio oracle/kernel — the quantity the
TPU kernel improves (validated against the dry-run roofline for the
paper cells in EXPERIMENTS.md §Perf).

The rank+audit section compares the two ways of producing a complete
RankingOutput from the kernel path:

  baseline  rank kernel, then a separate post-rank XLA audit program
            that re-reads u/a: gathers the (K+1)*m2 selected values
            back out of HBM via a materialized (n, K, m2) int32 index
            tensor (the pre-fusion serving code, kept here as the
            measured baseline);
  fused     the rank+audit kernel: the merge carries the selected
            values as VMEM payload, the audit runs at the flush step,
            and the audit's HBM traffic collapses to the gamma/b reads
            and the tiny outputs.

Both the analytic audit-traffic ratio and the measured wall-time delta
between the corresponding XLA programs (two dispatches + index
materialization vs one fused program with a broadcast gather) are
reported.

The predict+rank section does the same for the λ-predictor handoff the
single-sweep dispatcher (kernels.ops.predict_rank_audited) deletes:

  baseline  TWO device programs — a predict executable whose λ̂ (and,
            for KNN, whose (B, n_train) distance matrix) round-trips
            HBM, then a separate rank+audit executable that reads λ̂
            back;
  fused     ONE program: affine predictors fold into the rank kernel's
            VMEM prologue, KNN fuses its weighting into the db sweep's
            flush step, and λ̂ never exists between programs.

The knn_fused section covers the single-grid KNN kernel
(`kernels/knn_topk.knn_rank_audited_pallas`) specifically: the HBM
traffic model for the three ways of serving a KNN micro-batch (XLA
chunked predict -> rank with its per-slab d2 materializations; the PR 4
two-kernel chain with its λ̂ HBM round-trip; the single grid), and the
measured two-dispatch-vs-one wall of the corresponding XLA stand-in
programs.

`python -m benchmarks.kernel_bench --quick` is the CI smoke: small
shapes, plus `check_rank_audited`, `check_predict_rank` and
`check_knn_fused` — hard gates that fail the build if interpret-mode
parity with the predict-then-rank oracle breaks, if the dispatchers
stop engaging the kernels for kernel-eligible shapes, if the
m2 > MAX_KERNEL_M2 fallbacks stop engaging, or if the serving engine's
KNN buckets stop recording exactly one kernel launch per flushed
micro-batch. `--json OUT` writes machine-readable
BENCH_kernel_bench.json / BENCH_knn_fused.json (medians, geometry,
backend) for the cross-PR perf trajectory; CI uploads both as
artifacts. `--budget-s` bounds the --quick wall clock: blowing it
fails the job with a named per-section timing table instead of the
runner's silent timeout.
"""

from __future__ import annotations

import argparse
import os
import time
from contextlib import contextmanager

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Record, timed, write_bench_json
from repro.core.ranking import AUDIT_TOL
from repro.kernels import ref


def _rank_audit_problem(n, m1, K, m2):
    ks = jax.random.split(jax.random.key(7), 5)
    u = jax.random.uniform(ks[0], (n, m1), minval=1.0, maxval=5.0)
    a = (jax.random.uniform(ks[1], (n, K, m1)) < 0.1).astype(jnp.float32)
    lam = jnp.abs(jax.random.normal(ks[2], (n, K)))
    b = jnp.abs(jax.random.normal(ks[3], (n, K)))
    gamma = jnp.abs(jax.random.normal(ks[4], (n, m2)))
    return u, a, b, lam, gamma


def _xla_audit_epilogue(u, a, b, gamma, idx):
    """The pre-fusion post-rank audit, verbatim: gather the selected
    values back out of u/a through a materialized (n, K, m2) index
    tensor, then einsum against gamma. Kept as the measured baseline."""
    u_sel = jnp.take_along_axis(u, idx, axis=-1)
    utility = jnp.einsum("nm,nm->n", u_sel, gamma)
    a_sel = jnp.take_along_axis(
        a, idx[:, None, :].repeat(a.shape[1], axis=1), axis=-1)
    exposure = jnp.einsum("nkm,nm->nk", a_sel, gamma)
    compliant = jnp.all(exposure >= b - AUDIT_TOL, axis=-1)
    return utility, exposure, compliant


def _audit_traffic_model(K: int, m2: int) -> dict:
    """Per-request HBM bytes of the audit step alone (rank traffic is
    identical on both sides: read u/a once, write the top-m2 pairs).

      XLA epilogue: read back idx (m2 i32), materialize the broadcast
      (K, m2) i32 index tensor (write + read), random-gather the
      (K+1)*m2 selected f32 values out of the HBM-resident u/a
      (counted at the 4-byte compulsory floor — real gathers touch a
      full cache line per hit), read gamma/b, write the audit outputs.

      fused kernel: the (K+1)*m2 selected values are already in VMEM
      scratch when the flush step runs — the audit's only HBM traffic
      is reading gamma/b and writing the audit outputs.
    """
    out_bytes = (1 + K + 1) * 4                    # utility, exposure, compliant
    gb_bytes = (m2 + K) * 4                        # gamma + b reads
    xla = (m2 * 4                                  # idx read-back
           + 2 * K * m2 * 4                        # materialized index tensor
           + (K + 1) * m2 * 4                      # gathered u/a values
           + gb_bytes + out_bytes)
    fused = gb_bytes + out_bytes
    return {"audit_xla_bytes": xla, "audit_fused_bytes": fused,
            "audit_ratio_xla_over_fused": round(xla / fused, 3)}


def run_rank_audit(n, m1, K, m2, *, iters=7):
    """rank-vs-rank+audit at one problem shape. Three measurements:

    * end-to-end: (rank program; audit program) — two dispatches, the
      audit re-reading u/a — vs the single fused XLA program. Both
      sides share the dominant argsort, so this delta is small and
      noise-prone on a busy host; reported for completeness.
    * audit step isolated: the post-rank XLA epilogue alone vs the
      flush-equivalent arithmetic the fused kernel adds (the shared
      audit on already-selected (K+1)*m2 values, no gather, no index
      materialization). This is precisely the work fusion deletes /
      keeps, and is the robust measured win.
    * the analytic per-request audit HBM-traffic model.
    """
    from repro.core.ranking import audit_selected

    u, a, b, lam, gamma = _rank_audit_problem(n, m1, K, m2)
    rank_j = jax.jit(lambda u, a, lam: ref.fused_rank_ref(u, a, lam, m2)[1])
    audit_j = jax.jit(_xla_audit_epilogue)
    fused_j = jax.jit(
        lambda u, a, b, lam, gamma: ref.rank_audited_ref(
            u, a, b, lam, gamma, m2)[2])
    flush_j = jax.jit(
        lambda u_sel, a_sel, gamma, b: audit_selected(
            u_sel, a_sel, gamma, b, tol=AUDIT_TOL)[0])

    idx = jax.block_until_ready(rank_j(u, a, lam))
    u_sel = jnp.take_along_axis(u, idx, axis=-1)
    a_sel = jnp.take_along_axis(a, idx[:, None, :], axis=-1)

    base_us = timed(lambda: audit_j(u, a, b, gamma, rank_j(u, a, lam))[0],
                    iters=iters)
    fused_us = timed(lambda: fused_j(u, a, b, lam, gamma), iters=iters)
    epi_us = timed(lambda: audit_j(u, a, b, gamma, idx)[0], iters=iters)
    flush_us = timed(lambda: flush_j(u_sel, a_sel, gamma, b), iters=iters)
    model = _audit_traffic_model(K, m2)
    return {
        "name": f"rank_audit/m1={m1}/K={K}/m2={m2}/n={n}",
        "us": fused_us,
        "derived": {
            **model,
            "us_baseline_end_to_end": round(base_us, 1),
            "wall_end_to_end": round(base_us / fused_us, 3),
            "us_audit_epilogue": round(epi_us, 1),
            "us_audit_flush_equiv": round(flush_us, 1),
            "wall_audit_step": round(epi_us / flush_us, 3),
        },
    }


def _fit_predictors(K, d, n_db, seed=11):
    from repro.core.predictors import KNNLambdaPredictor, LinearLambdaPredictor

    ks = jax.random.split(jax.random.key(seed), 2)
    X_tr = jax.random.uniform(ks[0], (n_db, d))
    lam_tr = jnp.abs(jax.random.normal(ks[1], (n_db, K)))
    return {"linear": LinearLambdaPredictor.fit(X_tr, lam_tr),
            "knn": KNNLambdaPredictor.fit(X_tr, lam_tr, k=10)}


def _predict_traffic_model(family: str, B: int, N: int, D: int,
                           K: int) -> dict:
    """Per-batch HBM bytes of the predict stage + λ̂ handoff alone
    (rank+audit traffic is identical on both sides).

      two-dispatch: the predict program's own traffic, plus λ̂ written
      out by program 1 and read back by program 2 (2·B·K floats). For
      KNN the predict program also materializes the (B, N) distance
      matrix (write + read around the top-k) — the paper-scale killer.

      fused: λ̂ never exists in HBM. Affine families re-read X/W (they
      were reading them anyway); the KNN kernel re-streams the db once
      per resident query tile (tile_q = 32 when the batch allows) and
      keeps distances, weights, and λ̂ in VMEM.
    """
    if family == "linear":
        xla = (B * D + K * D + 2 * B * K) * 4
        fused = (B * D + K * D) * 4
    else:
        from repro.kernels.ops import knn_lambda_tile_q

        sweeps = -(-B // knn_lambda_tile_q(B))
        xla = (N * D + 2 * B * N + 2 * B * K) * 4
        fused = sweeps * N * D * 4
    return {"predict_xla_bytes": xla, "predict_fused_bytes": fused,
            "predict_ratio_xla_over_fused": round(xla / fused, 3)}


def run_predict_rank(n, m1, K, m2, *, d=20, n_db=8192, iters=7):
    """predict+rank+audit at one problem shape, per predictor family.

    Measured (CPU XLA stand-ins): the two-dispatch baseline — a jit'd
    predict program, then a jit'd rank+audit program reading λ̂ back —
    vs the single fused program. Both sides share the dominant
    rank work, so the wall delta isolates the dispatch + λ̂ (and KNN
    d2-matrix) round-trip the fusion deletes. The analytic per-batch
    traffic model for the predict stage rides along.
    """
    u, a, b, _, gamma = _rank_audit_problem(n, m1, K, m2)
    X = jax.random.normal(jax.random.key(23), (n, d))
    rows = []
    for family, pred in _fit_predictors(K, d, n_db).items():
        predict_j = jax.jit(pred.predict)
        rank_j = jax.jit(
            lambda u, a, b, lam, gamma: ref.rank_audited_ref(
                u, a, b, lam, gamma, m2)[2])
        fused_j = jax.jit(
            lambda X, u, a, b, gamma: ref.predict_rank_audited_ref(
                X, pred, u, a, b, gamma, m2)[2])
        two_us = timed(lambda: rank_j(u, a, b, predict_j(X), gamma),
                       iters=iters)
        one_us = timed(lambda: fused_j(X, u, a, b, gamma), iters=iters)
        model = _predict_traffic_model(family, n, n_db, d, K)
        rows.append({
            "name": f"predict_rank/{family}/m1={m1}/K={K}/m2={m2}"
                    f"/n={n}/n_db={n_db}",
            "us": one_us,
            "derived": {
                **model,
                "us_two_dispatch": round(two_us, 1),
                "wall_two_over_one": round(two_us / one_us, 3),
            },
        })
    return rows


def _knn_fused_traffic_model(B: int, N: int, D: int, K: int,
                             m1: int, m2: int, k: int = 10) -> dict:
    """Per-micro-batch HBM bytes of the three ways to serve a KNN
    bucket, at the tile geometry the dispatcher actually runs
    (kernels.common.TILE_B batch rows resident per db sweep):

      xla_chunked  predict program (knn_predict_chunked: every db slab's
                   (B, chunk) d2 block materializes — write + read —
                   summing to 2·B·N floats across the sweep, plus the db
                   stream) writes λ̂ out; rank+audit program reads λ̂
                   back and streams u/a. Two dispatches.
      chain        PR 4: knn_lambda kernel (db streamed once per
                   resident query tile, d2 never leaves VMEM) writes λ̂
                   to HBM; rank_audited kernel reads it back and
                   streams u/a. One executable, two kernel launches,
                   one λ̂ round-trip.
      single_grid  this PR: one kernel launch; the λ̂ round-trip is
                   gone (the (B, K) lam output is written once as
                   observability, never read back) and so is the
                   second launch's pipeline drain/fill.

    Rank-side traffic (u/a streamed once + outputs) is identical
    everywhere and included so the ratios reflect whole micro-batches.
    """
    from repro.kernels.ops import knn_lambda_tile_q

    # db sweeps per micro-batch: one per resident query tile, at the
    # same tile rule the dispatcher runs (32-wide when the batch fills)
    sweeps = -(-B // knn_lambda_tile_q(B))
    db_stream = sweeps * N * (D + K) * 4       # db rows + λ rows, per sweep
    rank_stream = B * (K + 1) * m1 * 4         # u + a, read once
    outputs = B * (2 * m2 + K + K + 2) * 4     # vals/idx/util/expo/comp/lam
    lam_rt = 2 * B * K * 4                     # λ̂ write + read back
    d2_slabs = 2 * B * N * 4                   # chunked-scan d2 blocks
    # the XLA path streams the db once and gathers only the B·k
    # selected λ rows (the kernels stream the full (N, K) λ database
    # per sweep instead — payload ride-along trades λ bytes for never
    # touching HBM with d2/idx)
    xla = (d2_slabs + N * D * 4 + B * k * K * 4
           + lam_rt + rank_stream + outputs)
    chain = db_stream + lam_rt + rank_stream + outputs
    fused = db_stream + rank_stream + outputs
    return {
        "knn_xla_bytes": xla, "knn_chain_bytes": chain,
        "knn_fused_bytes": fused,
        "ratio_xla_over_fused": round(xla / fused, 3),
        "ratio_chain_over_fused": round(chain / fused, 3),
        "lam_roundtrip_bytes_eliminated": lam_rt,
        "kernel_launches_chain": 2, "kernel_launches_fused": 1,
    }


def run_knn_fused(n, m1, K, m2, *, d=20, n_db=8192, k=10, iters=7):
    """The single-grid KNN kernel's section: the three-way traffic
    model above, plus a measured CPU stand-in for the dispatch overhead
    the fusion deletes — the two-dispatch XLA baseline (a jit'd
    knn_predict_chunked program, then a jit'd rank+audit program
    reading λ̂ back) against the same math as ONE jit program. Both
    sides run the slab-streaming predictor, so the wall delta isolates
    the λ̂ handoff + second dispatch; interpret-mode Pallas wall time
    would be meaningless (see module docstring)."""
    from repro.core.predictors import KNNLambdaPredictor, knn_predict_chunked

    u, a, b, _, gamma = _rank_audit_problem(n, m1, K, m2)
    ks = jax.random.split(jax.random.key(29), 3)
    X = jax.random.normal(ks[0], (n, d))
    X_tr = jax.random.uniform(ks[1], (n_db, d))
    lam_tr = jnp.abs(jax.random.normal(ks[2], (n_db, K)))
    pred = KNNLambdaPredictor.fit(X_tr, lam_tr, k=k)

    chunk = min(2048, n_db)
    predict_j = jax.jit(lambda X: knn_predict_chunked(
        pred.X_db, pred.lam_db, X, k=k, chunk=chunk))
    rank_j = jax.jit(
        lambda u, a, b, lam, gamma: ref.rank_audited_ref(
            u, a, b, lam, gamma, m2)[2])
    one_j = jax.jit(
        lambda X, u, a, b, gamma: ref.rank_audited_ref(
            u, a, b, knn_predict_chunked(
                pred.X_db, pred.lam_db, X, k=k, chunk=chunk),
            gamma, m2)[2])
    two_us = timed(lambda: rank_j(u, a, b, predict_j(X), gamma), iters=iters)
    one_us = timed(lambda: one_j(X, u, a, b, gamma), iters=iters)
    model = _knn_fused_traffic_model(n, n_db, d, K, m1, m2, k=k)
    return {
        "name": f"knn_fused/m1={m1}/K={K}/m2={m2}/n={n}/n_db={n_db}",
        "us": one_us,
        "derived": {
            **model,
            "us_two_dispatch": round(two_us, 1),
            "wall_two_over_one": round(two_us / one_us, 3),
        },
    }


def _knn_quant_traffic_model(B: int, N: int, D: int, K: int,
                              *, slab: int = 512, k: int = 10) -> dict:
    """Per-micro-batch db-sweep HBM bytes, f32 vs quantized storage.

    The sweep streams, per resident query tile, the db rows plus the λ
    payload. Quantization changes only the ROW stream: f32 rows are
    N·D·4 bytes; int8 rows are N·D·1 plus the exact |x̃|² sidecar
    (N·4) and the per-slab scales (N/slab · 4); bf16 rows are N·D·2
    plus the same sidecars. The λ payload (N·K·4) and the tiny
    survivor re-score traffic (B·(k+QUANT_EXTRA)·D·4, already in VMEM
    as kernel payload — counted 0 here) are identical across modes, so
    the headline ratio is reported on the row stream (what the
    tentpole optimizes) and the whole-sweep ratio alongside."""
    from repro.kernels.ops import knn_lambda_tile_q

    sweeps = -(-B // knn_lambda_tile_q(B))
    sidecar = N * 4 + -(-N // slab) * 4           # y2_q + per-slab scales
    rows_f32 = sweeps * N * D * 4
    rows_int8 = sweeps * (N * D * 1 + sidecar)
    rows_bf16 = sweeps * (N * D * 2 + sidecar)
    lam_stream = sweeps * N * K * 4
    return {
        "db_rows_f32_bytes": rows_f32,
        "db_rows_int8_bytes": rows_int8,
        "db_rows_bf16_bytes": rows_bf16,
        "rows_ratio_f32_over_int8": round(rows_f32 / rows_int8, 3),
        "rows_ratio_f32_over_bf16": round(rows_f32 / rows_bf16, 3),
        "sweep_ratio_f32_over_int8": round(
            (rows_f32 + lam_stream) / (rows_int8 + lam_stream), 3),
        "kernel_launches_quant": 1,
    }


def run_knn_quant(n, m1, K, m2, *, d=20, n_db=8192, k=10, iters=7):
    """Quantized-db sweep section: the storage-traffic model above plus
    a measured CPU stand-in — the jitted XLA quant-scan path
    (predictors.knn_predict_quant: int8 slab sweep + exact survivor
    re-score) against the f32 chunked scan on the same db. CPU wall
    does not see the MXU/HBM win (interpret-mode Pallas would be
    meaningless, and XLA CPU widens int8 dots anyway), so the wall
    numbers are recorded for trajectory, not gated; the byte model is
    what CI gates (check_knn_quant)."""
    from repro.core.predictors import (
        KNNLambdaPredictor, knn_predict_chunked, knn_predict_quant)

    ks = jax.random.split(jax.random.key(37), 3)
    X = jax.random.normal(ks[0], (n, d))
    X_tr = jax.random.uniform(ks[1], (n_db, d))
    lam_tr = jnp.abs(jax.random.normal(ks[2], (n_db, K)))
    pred = KNNLambdaPredictor.fit(X_tr, lam_tr, k=k)
    slab = min(512, n_db)
    predq = pred.quantized(mode="int8", slab=slab)

    f32_j = jax.jit(lambda X: knn_predict_chunked(
        pred.X_db, pred.lam_db, X, k=k, chunk=slab))
    q_j = jax.jit(lambda X: knn_predict_quant(
        predq.X_q, predq.q_scale, predq.y2_q, predq.lam_db, X, k=k,
        mode="int8"))
    f32_us = timed(lambda: f32_j(X), iters=iters)
    q_us = timed(lambda: q_j(X), iters=iters)
    model = _knn_quant_traffic_model(n, n_db, d, K, slab=slab, k=k)
    return {
        "name": f"knn_quant/d={d}/K={K}/n={n}/n_db={n_db}",
        "us": q_us,
        "derived": {
            **model,
            "us_f32_scan": round(f32_us, 1),
            "wall_f32_over_quant": round(f32_us / q_us, 3),
        },
    }


def check_knn_quant() -> None:
    """Quantized-db kernel health gate (CI smoke): raises on any
    regression.

    1. parity sweep: the int8 engine path (quantized predictor through
       ops.predict_rank_audited — the quantized single-grid kernel)
       matches the quantized oracle (ref.predict_rank_audited_ref over
       the same packed arrays) BITWISE on perm/utility/exposure/
       compliant (λ̂ to 1-ulp) for both quant modes, at a slab dividing
       n_train and one that does not — INCLUDING a db with planted
       near-ties that force the margin-guard fallback (guard fires,
       selection still exact).
    2. lossless bitwise: on an int8-representable db the int8 engine's
       RankingOutput — λ̂ included — is bit-for-bit the f32 engine's.
    3. launches: the quantized route engages the quantized single-grid
       kernel exactly once per batch (kernel_launches_per_batch == 1.0
       in a fused-executor engine serving a quantized predictor).
    4. bytes: the storage model gives int8 >= 2x fewer db-row bytes
       than f32 at every swept geometry.
    """
    import repro.kernels.ops as ops_mod
    from repro.core.predictors import KNNLambdaPredictor
    from repro.kernels.ops import knn_rank_audited
    from repro.serving import Scenario, ServingEngine, make_stream

    n, m1, K, m2, d, n_db = 8, 640, 4, 16, 12, 600
    ks = jax.random.split(jax.random.key(41), 7)
    u = jax.random.uniform(ks[0], (n, m1), minval=1.0, maxval=5.0)
    a = (jax.random.uniform(ks[1], (n, K, m1)) < 0.15).astype(jnp.float32)
    b = jnp.abs(jax.random.normal(ks[2], (n, K)))
    gamma = jnp.abs(jax.random.normal(ks[3], (n, m2)))
    X = jax.random.normal(ks[4], (n, d))
    X_tr = np.asarray(jax.random.uniform(ks[5], (n_db, d)))
    lam_tr = jnp.abs(jax.random.normal(ks[6], (n_db, K)))

    # planted near-tie: two db rows closer together than the query-
    # quantization error around query 0's neighbourhood — forces the
    # margin guard on at least one row of the parity sweep
    X_adv = X_tr.copy()
    X_adv[50] = np.asarray(X[0]) + 0.31
    X_adv[51] = X_adv[50] + 1e-4

    for X_base in (X_tr, X_adv):
        base = KNNLambdaPredictor.fit(
            X_base.astype(np.float32), lam_tr, k=5)
        for mode in ("int8", "bf16"):
            for slab in (200, 512):        # divides 600 / does not
                pred = base.quantized(mode=mode, slab=slab)
                got = ops_mod.predict_rank_audited(
                    X, pred, u, a, b, gamma, m2=m2)
                # the oracle under jit: eager jnp.sum reduces in a
                # different order than the compiled audit (1-ulp in
                # utility), and the contract is vs the COMPILED oracle
                want = jax.jit(
                    lambda X_, u_, a_, b_, g_, p_=pred:
                    ref.predict_rank_audited_ref(
                        X_, p_, u_, a_, b_, g_, m2))(X, u, a, b, gamma)
                w = dict(zip(("vals", "perm", "utility", "exposure",
                              "compliant", "lam"), want))
                for f in ("perm", "utility", "exposure", "compliant"):
                    np.testing.assert_array_equal(
                        np.asarray(getattr(got, f)), np.asarray(w[f]),
                        err_msg=f"quant parity broke on {f} "
                                f"({mode}, slab={slab})")
                np.testing.assert_allclose(
                    np.asarray(got.lam), np.asarray(w["lam"]),
                    rtol=2e-7, atol=2e-7,
                    err_msg=f"quant λ̂ drifted ({mode}, slab={slab})")

    # forced fallback is observable: the adversarial db fires the guard
    adv = KNNLambdaPredictor.fit(
        X_adv.astype(np.float32), lam_tr, k=5).quantized(
            mode="int8", slab=200)
    _, guard = knn_rank_audited(
        X, adv.X_db, adv.lam_db, u, a, b, gamma, k=5, m2=m2,
        quant="int8", X_q=adv.X_q, q_scale=adv.q_scale, y2_q=adv.y2_q,
        tile_n=200, return_guard=True)
    if int(np.asarray(guard).sum()) < 1:
        raise AssertionError(
            "quant guard regression: planted near-tie did not force "
            "the margin-guard fallback")

    # lossless db -> int8 engine bitwise == f32 engine (λ̂ included)
    rng = np.random.default_rng(7)
    X_ll = np.clip(np.round(rng.uniform(-63.0, 63.0, size=(n_db, d))
                            * 2.0) / 2.0, -63.5, 63.5)
    X_ll[::200] = 63.5                      # every slab hits the absmax
    ll = KNNLambdaPredictor.fit(X_ll.astype(np.float32), lam_tr, k=5)
    llq = ll.quantized(mode="int8", slab=200)
    X_q32 = jnp.asarray(np.round(
        rng.uniform(-10, 10, size=(n, d)) * 2.0).astype(np.float32) / 2.0)
    o32 = ops_mod.predict_rank_audited(X_q32, ll, u, a, b, gamma, m2=m2)
    oq = ops_mod.predict_rank_audited(X_q32, llq, u, a, b, gamma, m2=m2)
    for f in ("perm", "utility", "exposure", "compliant", "lam"):
        np.testing.assert_array_equal(
            np.asarray(getattr(o32, f)), np.asarray(getattr(oq, f)),
            err_msg=f"lossless int8-vs-f32 engine broke on {f}")

    # fused-executor engine on a quantized predictor: exactly one
    # kernel launch and one executable call per flushed micro-batch
    knn = KNNLambdaPredictor.fit(
        rng.normal(size=(96, d)).astype(np.float32),
        np.abs(rng.normal(size=(96, K))).astype(np.float32),
        k=5).quantized(mode="int8", slab=32)
    with _count_kernel_calls(
            {"quant": "knn_rank_audited_quant_pallas"}) as calls:
        with ServingEngine(max_batch=8, max_wait_ms=2.0,
                           executor="fused") as eng:
            eng.register_predictor("knn_arch", knn, d_cov=d)
            mix = (Scenario("feed", m1=300, m2=16, K=K, tag="knn_arch",
                            d_cov=d),)
            reqs = make_stream(mix, n_requests=24, seed=3)
            eng.warmup(reqs)
            results = eng.serve_stream(reqs)
            m = eng.metrics
            if len(results) != 24 or m.batches == 0:
                raise AssertionError("quant engine smoke failed to serve")
            if m.kernel_launches / m.batches != 1.0:
                raise AssertionError(
                    f"quant launch accounting: "
                    f"{m.kernel_launches / m.batches} launches/batch "
                    f"(expected exactly 1.0)")
            if m.executable_calls != m.batches:
                raise AssertionError(
                    f"quant dispatch: {m.executable_calls} executable "
                    f"calls for {m.batches} batches")
    if calls["quant"] < 1:
        raise AssertionError(
            "quant dispatch regression: the fused engine never engaged "
            "the quantized single-grid kernel")

    # storage model: >= 2x fewer db-row bytes at every geometry
    for (BB, NN, DD, KK) in ((32, 16384, 20, 5), (64, 65536, 64, 8)):
        mdl = _knn_quant_traffic_model(BB, NN, DD, KK)
        if mdl["rows_ratio_f32_over_int8"] < 2.0:
            raise AssertionError(
                f"quant traffic regression: f32/int8 db-row byte ratio "
                f"{mdl['rows_ratio_f32_over_int8']} < 2.0 at "
                f"B={BB} N={NN} D={DD}")
    print("# knn_quant acceptance (bitwise parity incl. forced "
          "fallbacks, lossless int8==f32 engine, 1 launch/batch, "
          ">=2x db-row bytes): PASS")


def run(quick: bool = False):
    rows = []
    key = jax.random.key(0)

    # fused_rank: oracle materializes s (read+write) vs kernel streaming
    n, m1, K, m2 = (64, 100_000, 5, 50) if not quick else (16, 10_000, 5, 50)
    ks = jax.random.split(key, 3)
    u = jax.random.uniform(ks[0], (n, m1))
    a = (jax.random.uniform(ks[1], (n, K, m1)) < 0.1).astype(jnp.float32)
    lam = jnp.abs(jax.random.normal(ks[2], (n, K)))
    f = jax.jit(lambda u, a, lam: ref.fused_rank_ref(u, a, lam, m2))
    us = timed(lambda: f(u, a, lam)[0], iters=3)
    compulsory = (K + 1) * m1 * 4          # read u + a once
    oracle_traffic = (K + 1) * m1 * 4 + 2 * m1 * 4  # + write s + read s
    rows.append({"name": f"fused_rank/m1={m1}/K={K}", "us": us,
                 "derived": {"hbm_ratio_oracle_over_kernel":
                             round(oracle_traffic / compulsory, 3)}})

    # rank+audit: fused kernel vs kernel + post-rank XLA audit epilogue,
    # at the retrieval shape (huge m1) and the serving-bucket shape
    # (engine micro-batch: the lattice cell the fused executor dispatches).
    shapes = ([(16, 10_000, 5, 50), (64, 2048, 8, 64)] if quick
              else [(64, 100_000, 5, 50), (256, 2048, 8, 128)])
    for n_ra, m1_ra, K_ra, m2_ra in shapes:
        rows.append(run_rank_audit(n_ra, m1_ra, K_ra, m2_ra))

    # predict+rank+audit: two-dispatch predict->rank vs one fused
    # program, at an engine micro-batch shape (covariate traffic)
    pr_shapes = ([(32, 2048, 5, 32, 20, 4096)] if quick
                 else [(32, 2048, 5, 32, 20, 16384),
                       (64, 8192, 8, 50, 20, 65536)])
    for n_pr, m1_pr, K_pr, m2_pr, d_pr, ndb_pr in pr_shapes:
        rows += run_predict_rank(n_pr, m1_pr, K_pr, m2_pr,
                                 d=d_pr, n_db=ndb_pr)

    # knn_fused: the single-grid KNN kernel vs the two-kernel chain vs
    # the XLA chunked path, at engine micro-batch shapes
    kf_shapes = ([(32, 2048, 5, 32, 20, 4096)] if quick
                 else [(32, 2048, 5, 32, 20, 16384),
                       (64, 8192, 8, 50, 20, 65536)])
    for n_kf, m1_kf, K_kf, m2_kf, d_kf, ndb_kf in kf_shapes:
        rows.append(run_knn_fused(n_kf, m1_kf, K_kf, m2_kf,
                                  d=d_kf, n_db=ndb_kf))

    # knn_quant: int8/bf16 db storage vs f32 — the row-stream byte
    # model plus the XLA quant-scan wall stand-in
    for n_kf, m1_kf, K_kf, m2_kf, d_kf, ndb_kf in kf_shapes:
        rows.append(run_knn_quant(n_kf, m1_kf, K_kf, m2_kf,
                                  d=d_kf, n_db=ndb_kf))

    # knn_topk: oracle materializes the (B, N) distance matrix
    B, N, D, k = (256, 65536, 20, 10) if not quick else (64, 8192, 20, 10)
    kq, kd = jax.random.split(key)
    xq = jax.random.normal(kq, (B, D))
    xdb = jax.random.normal(kd, (N, D))
    g = jax.jit(lambda xq, xdb: ref.knn_topk_ref(xq, xdb, k))
    us = timed(lambda: g(xq, xdb)[0], iters=3)
    kernel_traffic = N * D * 4             # stream db once (q tile resident)
    oracle_traffic = N * D * 4 + 2 * B * N * 4   # + write/read d2
    rows.append({"name": f"knn_topk/B={B}/N={N}", "us": us,
                 "derived": {"hbm_ratio_oracle_over_kernel":
                             round(oracle_traffic / kernel_traffic, 3)}})

    # embedding_bag: oracle materializes gathered rows
    V, Dd, nb, bag = (1_000_000, 64, 4096, 32) if not quick else (
        10_000, 64, 512, 32)
    kt, ki = jax.random.split(key)
    table = jax.random.normal(kt, (V, Dd))
    idx = jax.random.randint(ki, (nb, bag), 0, V)
    h = jax.jit(lambda t, i: ref.embedding_bag_ref(t, i))
    us = timed(lambda: h(table, idx), iters=3)
    rows.append({"name": f"embedding_bag/V={V}/bag={bag}", "us": us,
                 "derived": {"hbm_ratio_oracle_over_kernel": 2.0}})
    return rows


@contextmanager
def _count_kernel_calls(mapping: dict):
    """Monkeypatch-count Pallas kernel engagements through the ops
    dispatchers: ``mapping`` is {label: attribute name on
    repro.kernels.ops}; yields the live {label: count} dict. The shared
    scaffolding of every health gate below — wrappers restore on exit,
    so a failing gate can never leak a counting shim into later
    sections."""
    import repro.kernels.ops as ops_mod

    calls = {label: 0 for label in mapping}
    real = {label: getattr(ops_mod, attr) for label, attr in mapping.items()}

    def counting(label, fn):
        def wrapped(*args, **kwargs):
            calls[label] += 1
            return fn(*args, **kwargs)
        return wrapped

    for label, attr in mapping.items():
        setattr(ops_mod, attr, counting(label, real[label]))
    try:
        yield calls
    finally:
        for label, attr in mapping.items():
            setattr(ops_mod, attr, real[label])


def check_rank_audited() -> None:
    """Kernel-health gate (CI smoke): raises on any regression.

    1. interpret-mode parity: the rank+audit kernel's outputs match the
       rank_given_lambda oracle BITWISE (perm/utility/exposure/compliant).
    2. dispatch: the default path actually engages the Pallas kernel for
       kernel-eligible m2 (a silently-engaging fallback would keep tests
       green while TPU hosts quietly run the slow path).
    3. fallback: m2 > MAX_KERNEL_M2 routes to the XLA oracle, and its
       outputs match the oracle too.
    """
    import repro.kernels.ops as ops_mod
    from repro.core.ranking import rank_given_lambda

    n, m1, K, m2 = 8, 640, 4, 16
    ks = jax.random.split(jax.random.key(3), 5)
    u = jax.random.uniform(ks[0], (n, m1), minval=1.0, maxval=5.0)
    a = (jax.random.uniform(ks[1], (n, K, m1)) < 0.15).astype(jnp.float32)
    lam = jnp.abs(jax.random.normal(ks[2], (n, K)))
    b = jnp.abs(jax.random.normal(ks[3], (n, K)))
    gamma = jnp.abs(jax.random.normal(ks[4], (n, m2)))

    with _count_kernel_calls({"kernel": "rank_audited_pallas"}) as calls:
        got = ops_mod.rank_audited(u, a, b, lam, gamma, m2=m2)
        big = ops_mod.rank_audited(
            u, a, b, lam, jnp.abs(jax.random.normal(ks[4], (n, 256))), m2=256)
    if calls["kernel"] != 1:
        raise AssertionError(
            f"kernel dispatch regression: rank_audited_pallas engaged "
            f"{calls['kernel']} times across (kernel-eligible, fallback) "
            f"calls, expected exactly 1")

    want = rank_given_lambda(u, a, b, lam, gamma, m2=m2)
    for field in ("perm", "utility", "exposure", "compliant"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, field)), np.asarray(getattr(want, field)),
            err_msg=f"rank+audit interpret parity broke on {field}")
    want_big = rank_given_lambda(
        u, a, b, lam, jnp.abs(jax.random.normal(ks[4], (n, 256))), m2=256)
    for field in ("perm", "utility", "exposure", "compliant"):
        np.testing.assert_array_equal(
            np.asarray(getattr(big, field)),
            np.asarray(getattr(want_big, field)),
            err_msg=f"rank+audit XLA fallback parity broke on {field}")
    print("# rank+audit health: kernel engaged, interpret parity bitwise, "
          "fallback parity bitwise -> PASS")


def check_predict_rank() -> None:
    """Predict+rank+audit health gate (CI smoke): raises on regression.

    1. interpret-mode parity: ops.predict_rank_audited matches
       predictor.predict(X) -> rank_given_lambda for every family —
       BITWISE for the affine prologue (linear/mean) and the
       in-executable MLP; λ̂ to tight tolerance for the fused KNN
       weighting (selection/audit still exact on this problem).
    2. dispatch: kernel-eligible shapes actually engage the fused
       kernels (the affine-prologue kernel for linear/mean; the KNN λ
       kernel chained into the rank+audit kernel for knn).
    3. fallback: m2 > MAX_KERNEL_M2 engages no kernel and matches the
       two-stage XLA oracle.
    """
    import repro.kernels.ops as ops_mod
    from repro.core.predictors import (
        KNNLambdaPredictor,
        LinearLambdaPredictor,
        MeanLambdaPredictor,
        MLPLambdaPredictor,
    )
    from repro.core.ranking import rank_given_lambda

    n, m1, K, m2, d = 8, 640, 4, 16, 12
    ks = jax.random.split(jax.random.key(17), 7)
    u = jax.random.uniform(ks[0], (n, m1), minval=1.0, maxval=5.0)
    a = (jax.random.uniform(ks[1], (n, K, m1)) < 0.15).astype(jnp.float32)
    b = jnp.abs(jax.random.normal(ks[2], (n, K)))
    gamma = jnp.abs(jax.random.normal(ks[3], (n, m2)))
    X = jax.random.normal(ks[4], (n, d))
    X_tr = jax.random.uniform(ks[5], (48, d))
    lam_tr = jnp.abs(jax.random.normal(ks[6], (48, K)))
    families = {
        "linear": LinearLambdaPredictor.fit(X_tr, lam_tr),
        "mean": MeanLambdaPredictor.fit(X_tr, lam_tr),
        "knn": KNNLambdaPredictor.fit(X_tr, lam_tr, k=5),
        "mlp": MLPLambdaPredictor.fit(X_tr, lam_tr, num_steps=20),
    }

    with _count_kernel_calls({
            "linear": "linear_rank_audited_pallas",
            "knn_fused": "knn_rank_audited_pallas",
            "rank": "rank_audited_pallas"}) as calls:
        got = {name: ops_mod.predict_rank_audited(
                   X, pred, u, a, b, gamma, m2=m2)
               for name, pred in families.items()}
        gamma_big = jnp.abs(jax.random.normal(ks[3], (n, 256)))
        big = ops_mod.predict_rank_audited(
            X, families["linear"], u, a, b, gamma_big, m2=256)

    # knn engages the single-grid kernel; only mlp still chains into a
    # standalone rank kernel
    want_calls = {"linear": 2, "knn_fused": 1, "rank": 1}
    if calls != want_calls:
        raise AssertionError(
            f"predict+rank dispatch regression: kernel engagement "
            f"{calls}, expected {want_calls} (fallback must engage none)")

    for name, pred in families.items():
        want = rank_given_lambda(u, a, b, pred.predict(X), gamma, m2=m2)
        for field in ("perm", "utility", "exposure", "compliant"):
            np.testing.assert_array_equal(
                np.asarray(getattr(got[name], field)),
                np.asarray(getattr(want, field)),
                err_msg=f"predict+rank parity broke on {field} [{name}]")
        if name == "knn":
            np.testing.assert_allclose(
                np.asarray(got[name].lam), np.asarray(want.lam),
                rtol=1e-5, atol=1e-6,
                err_msg="fused KNN λ̂ drifted")
        else:
            np.testing.assert_array_equal(
                np.asarray(got[name].lam), np.asarray(want.lam),
                err_msg=f"λ̂ parity broke [{name}]")

    _, idx_w, util_w, expo_w, comp_w, _ = ref.predict_rank_audited_ref(
        X, families["linear"], u, a, b, gamma_big, 256)
    for field, want_f in (("perm", idx_w), ("utility", util_w),
                          ("exposure", expo_w), ("compliant", comp_w)):
        np.testing.assert_array_equal(
            np.asarray(getattr(big, field)), np.asarray(want_f),
            err_msg=f"predict+rank XLA fallback parity broke on {field}")
    print("# predict+rank health: kernels engaged per family, affine "
          "prologue bitwise, KNN λ̂ within tolerance, fallback parity "
          "-> PASS")


def check_knn_fused() -> None:
    """Single-grid KNN kernel health gate (CI smoke): raises on any
    regression.

    1. parity: ops.predict_rank_audited on a KNN predictor — the
       single-grid knn_rank_audited_pallas — matches the PR 4
       two-kernel chain (knn_chain=True, matched tile geometry)
       BITWISE on every RankingOutput field INCLUDING λ̂, and matches
       the rank_given_lambda oracle exactly on
       perm/utility/exposure/compliant (λ̂ to tight tolerance — the
       per-slab distance accumulation differs from the oracle's
       one-matmul form in the last ulp).
    2. dispatch: the kernel-eligible shape engages the single-grid
       kernel exactly once and the chain kernels not at all; the
       m2 > MAX_KERNEL_M2 fallback engages none.
    3. engine accounting: a fused-executor engine serving a KNN
       covariate stream records exactly ONE kernel launch AND one
       executable call per flushed micro-batch post-warmup
       (EngineMetrics.kernel_launches / executable_calls).
    """
    import repro.kernels.ops as ops_mod
    from repro.core.predictors import KNNLambdaPredictor
    from repro.core.ranking import rank_given_lambda
    from repro.serving import Scenario, ServingEngine, make_stream

    n, m1, K, m2, d = 8, 640, 4, 16, 12
    ks = jax.random.split(jax.random.key(31), 7)
    u = jax.random.uniform(ks[0], (n, m1), minval=1.0, maxval=5.0)
    a = (jax.random.uniform(ks[1], (n, K, m1)) < 0.15).astype(jnp.float32)
    b = jnp.abs(jax.random.normal(ks[2], (n, K)))
    gamma = jnp.abs(jax.random.normal(ks[3], (n, m2)))
    X = jax.random.normal(ks[4], (n, d))
    X_tr = jax.random.uniform(ks[5], (600, d))
    lam_tr = jnp.abs(jax.random.normal(ks[6], (600, K)))
    pred = KNNLambdaPredictor.fit(X_tr, lam_tr, k=5)

    gamma_big = jnp.abs(jax.random.normal(ks[3], (n, 256)))
    with _count_kernel_calls({
            "fused": "knn_rank_audited_pallas",
            "chain_knn": "knn_lambda_pallas",
            "chain_rank": "rank_audited_pallas"}) as calls:
        got = ops_mod.predict_rank_audited(X, pred, u, a, b, gamma, m2=m2)
        fast_calls = dict(calls)
        big = ops_mod.predict_rank_audited(X, pred, u, a, b, gamma_big,
                                           m2=256)
        fallback_calls = dict(calls)

    if fast_calls != {"fused": 1, "chain_knn": 0, "chain_rank": 0}:
        raise AssertionError(
            f"knn_fused dispatch regression: kernel engagement "
            f"{fast_calls}, expected the single grid exactly once")
    if fallback_calls != fast_calls:
        raise AssertionError(
            f"knn_fused fallback regression: m2 > MAX_KERNEL_M2 engaged "
            f"kernels {fallback_calls} (expected {fast_calls})")

    chain = ops_mod.predict_rank_audited(X, pred, u, a, b, gamma, m2=m2,
                                         knn_chain=True)
    for field in ("perm", "utility", "exposure", "compliant", "lam"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, field)), np.asarray(getattr(chain, field)),
            err_msg=f"single-grid vs two-kernel chain broke on {field}")
    want = rank_given_lambda(u, a, b, pred.predict(X), gamma, m2=m2)
    for field in ("perm", "utility", "exposure", "compliant"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, field)), np.asarray(getattr(want, field)),
            err_msg=f"single-grid vs oracle broke on {field}")
    np.testing.assert_allclose(
        np.asarray(got.lam), np.asarray(want.lam), rtol=1e-5, atol=1e-6,
        err_msg="single-grid λ̂ drifted from the predictor")
    want_big = rank_given_lambda(u, a, b, pred.predict(X), gamma_big, m2=256)
    for field in ("perm", "utility", "exposure", "compliant"):
        np.testing.assert_array_equal(
            np.asarray(getattr(big, field)),
            np.asarray(getattr(want_big, field)),
            err_msg=f"knn_fused XLA fallback parity broke on {field}")

    rng = np.random.default_rng(5)
    knn = KNNLambdaPredictor.fit(
        rng.normal(size=(96, d)).astype(np.float32),
        np.abs(rng.normal(size=(96, K))).astype(np.float32), k=5)
    with ServingEngine(max_batch=8, max_wait_ms=2.0,
                       executor="fused") as eng:
        eng.register_predictor("knn_arch", knn, d_cov=d)
        mix = (Scenario("feed", m1=300, m2=16, K=K, tag="knn_arch",
                        d_cov=d),)
        reqs = make_stream(mix, n_requests=24, seed=3)
        eng.warmup(reqs)
        results = eng.serve_stream(reqs)
        m = eng.metrics
        if len(results) != 24 or m.batches == 0:
            raise AssertionError("knn_fused engine smoke did not serve")
        if (m.kernel_launches != m.batches
                or m.executable_calls != m.batches):
            raise AssertionError(
                f"knn_fused engine accounting regression: "
                f"{m.batches} batches but {m.executable_calls} "
                f"executable calls / {m.kernel_launches} kernel "
                f"launches (want exactly one of each per batch)")
    print("# knn_fused health: single grid engaged (chain kernels idle), "
          "bitwise vs chain, oracle parity, fallback clean, engine "
          "1 launch/batch -> PASS")


def records(rows):
    return [Record(name=f"kernel/{r['name']}", us_per_call=r["us"],
                   derived=r["derived"]) for r in rows]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized shapes + the kernel health gates")
    ap.add_argument("--json", metavar="OUT", default=None,
                    help="write BENCH_kernel_bench.json / "
                         "BENCH_knn_fused.json to OUT (a directory, or "
                         "an explicit *.json path for the main file)")
    ap.add_argument("--budget-s", type=float, default=300.0,
                    help="--quick wall-clock budget: exceeding it fails "
                         "the run with a per-section timing table "
                         "(instead of the CI runner's silent timeout)")
    args = ap.parse_args()

    sections: list[tuple[str, float]] = []

    def section(name, fn):
        t0 = time.perf_counter()
        out = fn()
        sections.append((name, time.perf_counter() - t0))
        return out

    section("check_rank_audited", check_rank_audited)   # hard gates:
    section("check_predict_rank", check_predict_rank)   # raise on
    section("check_knn_fused", check_knn_fused)         # regression
    section("check_knn_quant", check_knn_quant)
    rows = section("bench_sweep", lambda: run(quick=args.quick))
    recs = records(rows)
    for rec in recs:
        print(rec.csv())
    if args.json:
        write_bench_json(args.json, "kernel_bench", recs,
                         meta={"quick": args.quick})
        kf_recs = [r for r in recs if "/knn_fused/" in r.name]
        out_dir = (args.json if not args.json.endswith(".json")
                   else (os.path.dirname(args.json) or "."))
        write_bench_json(out_dir, "knn_fused", kf_recs,
                         meta={"quick": args.quick})
        kq_recs = [r for r in recs if "/knn_quant/" in r.name]
        write_bench_json(out_dir, "knn_quant", kq_recs,
                         meta={"quick": args.quick})
    ras = [r for r in rows if r["name"].startswith("rank_audit/")]
    if any(r["derived"]["audit_ratio_xla_over_fused"] <= 1.0 for r in ras):
        raise SystemExit("# rank+audit acceptance: FAIL — audit traffic "
                         "model does not favor the fused kernel")
    best = max(r["derived"]["wall_audit_step"] for r in ras)
    if best >= 1.0:
        print(f"# rank+audit acceptance: PASS — audit traffic ratio "
              f"{max(r['derived']['audit_ratio_xla_over_fused'] for r in ras)}"
              f"x, measured audit-step wall win up to {best:.1f}x over the "
              f"XLA epilogue")
    else:
        # parity + traffic model hold; a wall-time shortfall on a noisy
        # shared host is measurement jitter, not a dataflow change.
        print(f"# rank+audit acceptance: WARN — traffic model holds but "
              f"measured audit-step wall win {best:.2f}x < 1.0x "
              f"(noisy host?)")
    prs = [r for r in rows if r["name"].startswith("predict_rank/")]
    if any(r["derived"]["predict_ratio_xla_over_fused"] <= 1.0 for r in prs):
        raise SystemExit("# predict+rank acceptance: FAIL — predict "
                         "traffic model does not favor the fused path")
    best_pr = max(r["derived"]["wall_two_over_one"] for r in prs)
    if best_pr >= 1.0:
        print(f"# predict+rank acceptance: PASS — predict traffic ratio up "
              f"to "
              f"{max(r['derived']['predict_ratio_xla_over_fused'] for r in prs)}"
              f"x, two-dispatch/fused wall up to {best_pr:.2f}x")
    else:
        print(f"# predict+rank acceptance: WARN — traffic model holds but "
              f"measured two-dispatch/fused wall {best_pr:.2f}x < 1.0x "
              f"(noisy host?)")
    kfs = [r for r in rows if r["name"].startswith("knn_fused/")]
    # bytes compared unrounded: the chain/fused edge is the λ̂
    # round-trip (small but strictly positive) plus the deleted second
    # kernel launch; the xla/fused edge is the d2 slab materialization
    if any(r["derived"]["knn_fused_bytes"] >= r["derived"]["knn_chain_bytes"]
           or (r["derived"]["knn_fused_bytes"]
               >= r["derived"]["knn_xla_bytes"]) for r in kfs):
        raise SystemExit("# knn_fused acceptance: FAIL — traffic model "
                         "does not favor the single-grid kernel over the "
                         "chain AND the XLA chunked path")
    best_kf = max(r["derived"]["wall_two_over_one"] for r in kfs)
    print(f"# knn_fused acceptance: PASS — xla/fused traffic up to "
          f"{max(r['derived']['ratio_xla_over_fused'] for r in kfs)}x, "
          f"chain/fused {max(r['derived']['ratio_chain_over_fused'] for r in kfs)}x "
          f"(+1 fewer kernel launch), two-dispatch/fused wall up to "
          f"{best_kf:.2f}x" if best_kf >= 1.0 else
          f"# knn_fused acceptance: WARN — traffic model holds but "
          f"measured wall {best_kf:.2f}x < 1.0x (noisy host?)")

    # --- wall-clock budget: a growing bench suite must fail loudly, ---
    # --- with names, not eat the CI runner's timeout silently       ---
    total = sum(s for _, s in sections)
    width = max(len(n) for n, _ in sections)
    print(f"# section timings (budget {args.budget_s:.0f}s, "
          f"{'enforced' if args.quick else 'informational'}):")
    for name, secs in sections + [("TOTAL", total)]:
        print(f"#   {name:<{width}}  {secs:7.1f}s")
    if args.quick and total > args.budget_s:
        raise SystemExit(
            f"# kernel_bench budget: FAIL — --quick took {total:.1f}s "
            f"> {args.budget_s:.0f}s; trim the slowest section above "
            f"or raise --budget-s deliberately")


if __name__ == "__main__":
    main()
