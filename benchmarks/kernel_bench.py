"""Kernel-path benchmarks: oracle (XLA) paths timed on CPU, kernel HBM
models derived analytically.

interpret=True Pallas runs execute the kernel body in Python per grid
step — meaningful for CORRECTNESS, meaningless for wall time. So here we
time the XLA oracle path (what the CPU actually runs) and report, per
kernel, the analytic HBM-traffic ratio oracle/kernel — the quantity the
TPU kernel improves (validated against the dry-run roofline for the
paper cells in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Record, timed
from repro.kernels import ref


def run(quick: bool = False):
    rows = []
    key = jax.random.key(0)

    # fused_rank: oracle materializes s (read+write) vs kernel streaming
    n, m1, K, m2 = (64, 100_000, 5, 50) if not quick else (16, 10_000, 5, 50)
    ks = jax.random.split(key, 3)
    u = jax.random.uniform(ks[0], (n, m1))
    a = (jax.random.uniform(ks[1], (n, K, m1)) < 0.1).astype(jnp.float32)
    lam = jnp.abs(jax.random.normal(ks[2], (n, K)))
    f = jax.jit(lambda u, a, lam: ref.fused_rank_ref(u, a, lam, m2))
    us = timed(lambda: f(u, a, lam)[0], iters=3)
    compulsory = (K + 1) * m1 * 4          # read u + a once
    oracle_traffic = (K + 1) * m1 * 4 + 2 * m1 * 4  # + write s + read s
    rows.append({"name": f"fused_rank/m1={m1}/K={K}", "us": us,
                 "derived": {"hbm_ratio_oracle_over_kernel":
                             round(oracle_traffic / compulsory, 3)}})

    # knn_topk: oracle materializes the (B, N) distance matrix
    B, N, D, k = (256, 65536, 20, 10) if not quick else (64, 8192, 20, 10)
    kq, kd = jax.random.split(key)
    xq = jax.random.normal(kq, (B, D))
    xdb = jax.random.normal(kd, (N, D))
    g = jax.jit(lambda xq, xdb: ref.knn_topk_ref(xq, xdb, k))
    us = timed(lambda: g(xq, xdb)[0], iters=3)
    kernel_traffic = N * D * 4             # stream db once (q tile resident)
    oracle_traffic = N * D * 4 + 2 * B * N * 4   # + write/read d2
    rows.append({"name": f"knn_topk/B={B}/N={N}", "us": us,
                 "derived": {"hbm_ratio_oracle_over_kernel":
                             round(oracle_traffic / kernel_traffic, 3)}})

    # embedding_bag: oracle materializes gathered rows
    V, Dd, nb, bag = (1_000_000, 64, 4096, 32) if not quick else (
        10_000, 64, 512, 32)
    kt, ki = jax.random.split(key)
    table = jax.random.normal(kt, (V, Dd))
    idx = jax.random.randint(ki, (nb, bag), 0, V)
    h = jax.jit(lambda t, i: ref.embedding_bag_ref(t, i))
    us = timed(lambda: h(table, idx), iters=3)
    rows.append({"name": f"embedding_bag/V={V}/bag={bag}", "us": us,
                 "derived": {"hbm_ratio_oracle_over_kernel": 2.0}})
    return rows


def records(rows):
    return [Record(name=f"kernel/{r['name']}", us_per_call=r["us"],
                   derived=r["derived"]) for r in rows]


def main():
    for rec in records(run()):
        print(rec.csv())


if __name__ == "__main__":
    main()
