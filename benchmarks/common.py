"""Shared benchmark utilities: timing, result records, CSV emission."""

from __future__ import annotations

import json
import os
import time
from dataclasses import asdict, dataclass, field

import jax

RESULTS_DIR = "experiments/bench"


@dataclass
class Record:
    name: str
    us_per_call: float = float("nan")
    derived: dict = field(default_factory=dict)

    def csv(self) -> str:
        extra = ";".join(f"{k}={v}" for k, v in self.derived.items())
        return f"{self.name},{self.us_per_call:.1f},{extra}"


def timed(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median-ish wall time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def write_bench_json(out: str, name: str, records, *, meta: dict | None = None) -> str:
    """Machine-readable benchmark artifact: BENCH_<name>.json.

    `out` is either a directory (the file is named BENCH_<name>.json
    inside it — the CI-artifact convention) or an explicit *.json path.
    The payload carries the backend + jax version alongside every
    Record (medians in us_per_call, geometry in name/derived) so the
    perf trajectory is comparable across PRs and hosts.
    """
    payload = {
        "bench": name,
        "backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "device_count": jax.device_count(),
        "records": [asdict(r) for r in records],
        **(meta or {}),
    }
    if out.endswith(".json"):
        path = out
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    else:
        os.makedirs(out, exist_ok=True)
        path = os.path.join(out, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {path}")
    return path


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def load_json(name: str):
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)
