"""Online-stage latency: the paper's < 50 ms claim, measured.

Times the full online hot path — predict lambda via KNN over the train
database, adjust scores, take the top-m2 — end to end under jit on this
machine (CPU), per problem size. The paper's headline (>= 500 objects,
>= 5 constraints inside 50 ms on a 2015 quad-core CPU) is checked
directly; TPU latency bounds for the same program come from the roofline
report (experiments/dryrun).

Batched serving throughput is reported too: the deployed system serves
batches, so per-user cost at batch 512 is the fleet-relevant number.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Record, save_json, timed
from repro.core.constraints import dcg_discount
from repro.core.predictors import knn_predict
from repro.core.ranking import rank_given_lambda

LATENCY_BUDGET_MS = 50.0


def _serve_fn(m2):
    @jax.jit
    def serve(X, u, a, b, X_db, lam_db):
        lam_hat = knn_predict(X_db, lam_db, X, k=10)
        return rank_given_lambda(u, a, b, lam_hat, dcg_discount(m2), m2=m2)
    return serve


def run(*, sizes=((1000, 5, 50), (1000, 5, 1000), (10000, 8, 50),
                  (100_000, 5, 50)),
        batches=(1, 512), n_db=10_000, d_cov=20, verbose=True):
    rows = []
    for m1, K, m2 in sizes:
        for B in batches:
            key = jax.random.key(m1 + B)
            ks = jax.random.split(key, 5)
            X = jax.random.normal(ks[0], (B, d_cov))
            u = jax.random.uniform(ks[1], (B, m1), minval=1, maxval=5)
            a = (jax.random.uniform(ks[2], (B, K, m1)) < 0.1).astype(jnp.float32)
            b = 0.03 * jnp.sum(dcg_discount(m2)) * jnp.ones((K,))
            X_db = jax.random.normal(ks[3], (n_db, d_cov))
            lam_db = jnp.abs(jax.random.normal(ks[4], (n_db, K)))
            serve = _serve_fn(m2)
            us = timed(lambda: serve(X, u, a, b, X_db, lam_db).perm, iters=5)
            rows.append({
                "m1": m1, "K": K, "m2": m2, "batch": B,
                "us_total": us, "us_per_user": us / B,
                "within_50ms": bool(us / 1e3 <= LATENCY_BUDGET_MS),
            })
            if verbose:
                r = rows[-1]
                print(f"serve m1={m1:6d} K={K} m2={m2:4d} B={B:4d} "
                      f"{r['us_total']/1e3:8.2f} ms/batch "
                      f"({r['us_per_user']:8.1f} us/user) "
                      f"<=50ms: {r['within_50ms']}", flush=True)
    save_json("latency_serve", rows)
    return rows


def records(rows):
    return [Record(
        name=f"serve/m1={r['m1']}/K={r['K']}/m2={r['m2']}/B={r['batch']}",
        us_per_call=r["us_total"],
        derived={"us_per_user": round(r["us_per_user"], 1),
                 "within_50ms": r["within_50ms"]})
        for r in rows]


def main():
    for rec in records(run()):
        print(rec.csv())


if __name__ == "__main__":
    main()
