"""Online-stage latency: the paper's < 50 ms claim, measured.

Three measurement modes (docs/benchmarks.md walks through them):

  * direct: the full online hot path — predict lambda via KNN over the
    train database, adjust scores, take the top-m2 — end to end under
    jit, per (m1, K, m2, batch) problem size. The paper's headline
    (>= 500 objects, >= 5 constraints inside 50 ms on a 2015 quad-core
    CPU) is checked directly.

  * engine: the same mixed-shape request stream served through the
    streaming engine (repro.serving) twice — synchronous
    (pipeline_depth=0: every flush blocks on its own transfer) and
    pipelined (pipeline_depth=1 double buffering) — reported side by
    side: per-request p50/p95/p99 (enqueue -> result) from a
    deadline-driven run, saturated wall-clock throughput and the
    pipelined/sync speedup from paired interleaved trials, overlap
    ratio, compliance, bucket fill rate, and recompiles after warmup
    (must stay 0). Both modes must produce identical perms per rid
    (verified here, not just in tests). This is the fleet-relevant
    number: the deployed system sees a stream, not a fixed batch.

    Measurement notes (full discussion in docs/benchmarks.md):
    - throughput trials submit back-to-back with a frozen arrival
      clock, so the capacity-flush batch structure is identical across
      modes and trials — the comparison never measures two different
      batchings;
    - trials are paired and interleaved (sync, pipelined, sync, ...)
      and summarized by the median of per-pair ratios, which cancels
      the machine-load drift that dominates small CI boxes;
    - on a CPU-only host the engine comparison runs in a subprocess
      with XLA's intra-op threading disabled
      (--xla_cpu_multi_thread_eigen=false): host/device overlap only
      exists when device execution does not consume every host core,
      which is the deployment reality on any accelerator backend. On
      a 2-core CI container with XLA spanning both cores, sync and
      pipelined are both CPU-bound on identical total work and the
      comparison measures scheduler noise instead of the pipeline.

  * frontier (`--frontier` / `--only frontier`): p99 latency vs OFFERED
    load, paced open-loop — Poisson arrivals at target QPS fractions of
    the measured closed-loop capacity (`serving.traffic.poisson_arrivals`
    + `serve_open_loop`). Closed-loop drivers cannot offer more load
    than the server absorbs, so they never see queueing delay; the
    open-loop sweep reports the tail below saturation and marks the
    rows past it. Each row also reports the deadline-hit rate against
    the 50 ms budget next to p99, and saturation is detected on the
    decomposed QUEUE lag (pacing clock-drift excluded).

  * deadline (`--only deadline`): the admission-control health gate
    (`check_deadline`) — zero missed deadlines at <= 0.8x detected
    saturation with admission on, an admission-off baseline that
    misses past saturation, and a forced-degrade pass whose rung-1
    compliance cost comes from the fused-kernel audit outputs. Writes
    BENCH_deadline.json with `--json`; AssertionError on regression.

  * refresh (`--only refresh`): the λ-refresh hot-swap health gate
    (`check_refresh`) — real telemetry drives >= 2 mid-stream swaps
    with zero recompiles, per-bucket jit caches pinned at the warmed
    executable, one dispatch per flushed batch, every epoch bitwise
    identical to a cold engine started on that epoch's published
    state, and rollback restoring the last-good generation bitwise.
    Also times the refresh publish (drain + update + device_put +
    fenced swap). Writes BENCH_refresh.json with `--json`.

  * drift (`--only drift`): the drift regression gate (`check_drift`)
    — under 8x mid-stream constraint tightening, refresh-on must
    strictly reduce accumulated exposure shortfall vs the frozen
    predictor with zero recompiles, and must be a bitwise no-op on a
    compliant stationary stream. Writes BENCH_drift.json with
    `--json`.

  * quant (`--only quant`): the quantized-serving gate (`check_quant`)
    — an engine on an int8-quantized KNN predictor serves a lossless
    stream bitwise identical to the f32 engine (perm, utility,
    exposure, compliant per request), with exactly one kernel launch
    per flushed micro-batch and zero post-warmup recompiles on both
    sides. Writes BENCH_quant_serve.json with `--json`.

  * fleet (`--only fleet`): the fault-tolerance gate (`check_fleet`) —
    a 3-replica FleetRouter serves a 512-request mixed stream under
    the seeded chaos plan (crash-at-batch-k, heartbeat blackhole,
    slow replica, partial-drain kill), armed AFTER a fault-free
    prefix + refresh so an epoch checkpoint exists before the first
    crash. Asserts zero orphaned futures, zero lost requests, every
    rid served exactly once, the restarted replica resuming at the
    last-good checkpointed epoch (not cold), zero post-warmup
    recompiles on every incarnation, and p99 within the latency
    budget x a CI tolerance. Writes BENCH_fleet.json with `--json`.

  * lattice (`--only lattice`): the adaptive-lattice gate
    (`check_lattice`) — one engine serves a skewed two-phase
    multi-surface stream while a LatticeLane learns bucket corners
    from the shape histogram and re-warms them in detected troughs.
    Asserts >= 2 detector-gated mid-stream swaps with ZERO
    dispatch-path compiles, measured padding waste (padded/real sweep
    FLOPs) cut >= 1.5x vs a power-of-two engine on identical chunks,
    per-epoch results bitwise-equal to a cold engine built on that
    epoch's lattice, and a poisoned proposal rolling back to
    last-good without pausing the stream. Writes BENCH_lattice.json
    with `--json`.

Usage:

  python -m benchmarks.latency_serve \\
      [--quick] [--frontier] [--json OUT] \\
      [--only direct|engine|frontier|deadline|refresh|drift|quant|lattice]

`--json OUT` additionally writes a machine-readable
BENCH_latency_serve.json (medians, geometry, backend — see
benchmarks.common.write_bench_json) so the serving-latency trajectory
is trackable across PRs; CI uploads it as an artifact.
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Record, save_json, timed, write_bench_json
from repro.core.constraints import dcg_discount
from repro.core.predictors import (
    KNNLambdaPredictor,
    MeanLambdaPredictor,
    knn_predict,
    with_state,
)
from repro.core.ranking import rank_given_lambda
from repro.data.synthetic import DriftSpec
from repro.checkpoint import CheckpointStore
from repro.serving import (
    DEFAULT_MIX,
    AdmissionController,
    FaultInjector,
    FaultPlan,
    FleetRouter,
    HealthConfig,
    Lattice,
    LatticeLane,
    RefreshLane,
    Scenario,
    ServingEngine,
    Shed,
    TroughDetector,
    make_drift_stream,
    make_stream,
    poisson_arrivals,
    serve_open_loop,
)

LATENCY_BUDGET_MS = 50.0

# Engine-comparison child process marker + the dedicated-device-core
# XLA config it runs under (see module docstring).
_CHILD_ENV = "REPRO_ENGINE_BENCH_CHILD"
_DEDICATED_CORE_FLAGS = "--xla_cpu_multi_thread_eigen=false"


def _serve_fn(m2):
    @jax.jit
    def serve(X, u, a, b, X_db, lam_db):
        lam_hat = knn_predict(X_db, lam_db, X, k=10)
        return rank_given_lambda(u, a, b, lam_hat, dcg_discount(m2), m2=m2)
    return serve


def run(*, sizes=((1000, 5, 50), (1000, 5, 1000), (10000, 8, 50),
                  (100_000, 5, 50)),
        batches=(1, 512), n_db=10_000, d_cov=20, verbose=True):
    rows = []
    for m1, K, m2 in sizes:
        for B in batches:
            key = jax.random.key(m1 + B)
            ks = jax.random.split(key, 5)
            X = jax.random.normal(ks[0], (B, d_cov))
            u = jax.random.uniform(ks[1], (B, m1), minval=1, maxval=5)
            a = (jax.random.uniform(ks[2], (B, K, m1)) < 0.1).astype(jnp.float32)
            b = 0.03 * jnp.sum(dcg_discount(m2)) * jnp.ones((K,))
            X_db = jax.random.normal(ks[3], (n_db, d_cov))
            lam_db = jnp.abs(jax.random.normal(ks[4], (n_db, K)))
            serve = _serve_fn(m2)
            us = timed(lambda: serve(X, u, a, b, X_db, lam_db).perm, iters=5)
            rows.append({
                "m1": m1, "K": K, "m2": m2, "batch": B,
                "us_total": us, "us_per_user": us / B,
                "within_50ms": bool(us / 1e3 <= LATENCY_BUDGET_MS),
            })
            if verbose:
                r = rows[-1]
                print(f"serve m1={m1:6d} K={K} m2={m2:4d} B={B:4d} "
                      f"{r['us_total']/1e3:8.2f} ms/batch "
                      f"({r['us_per_user']:8.1f} us/user) "
                      f"<=50ms: {r['within_50ms']}", flush=True)
    save_json("latency_serve", rows)
    return rows


def _saturated_serve(engine, requests):
    """Back-to-back submission with a frozen arrival clock: the
    capacity-flush batch structure is deterministic (identical across
    modes/trials), so wall clock measures execution, not batching."""
    t0 = time.perf_counter()
    out = []
    for r in requests:
        out += engine.submit(r, now=0.0)
    out += engine.drain()
    return out, time.perf_counter() - t0


def _perms_of(results):
    return {r.rid: np.asarray(r.perm) for r in results}


def _perms_equal(a, b):
    return sorted(a) == sorted(b) and all(
        np.array_equal(a[rid], b[rid]) for rid in a)


def _run_engine_inproc(*, n_requests, max_batch, max_wait_ms, scenarios,
                       seed, depths, trials, verbose):
    requests = make_stream(scenarios, n_requests=n_requests, seed=seed)
    engines, rows = {}, []
    for depth in depths:
        engines[depth] = ServingEngine(max_batch=max_batch,
                                       max_wait_ms=max_wait_ms,
                                       pipeline_depth=depth)
        engines[depth].warmup(requests)

    # latency profile: one deadline-driven pass (real arrival clock),
    # metrics snapshotted before the throughput trials pollute them.
    latency, perms = {}, {}
    for depth, eng in engines.items():
        results = eng.serve_stream(requests)
        latency[depth] = eng.metrics.summary()
        perms[depth] = _perms_of(results)

    # throughput: paired interleaved trials over the frozen-clock
    # saturated stream; per-pair ratios cancel machine-load drift.
    walls = {d: [] for d in depths}
    diverged = set()
    for _ in range(max(1, trials)):
        for depth, eng in engines.items():
            out, wall = _saturated_serve(eng, requests)
            walls[depth].append(wall)
            if not _perms_equal(_perms_of(out), perms[depths[0]]):
                diverged.add(depth)
    base = depths[0]
    for depth in depths:
        s = latency[depth]
        ratios = sorted(ws / wp for ws, wp in zip(walls[base], walls[depth]))
        wall_med = statistics.median(walls[depth])
        identical = (_perms_equal(perms[depth], perms[base])
                     and depth not in diverged)
        rows.append({
            "mode": "sync" if depth == 0 else f"pipelined(depth={depth})",
            "pipeline_depth": depth,
            "n_requests": n_requests,
            "scenarios": [sc.name for sc in scenarios],
            "max_batch": max_batch, "max_wait_ms": max_wait_ms,
            "trials": trials,
            "buckets": s["buckets_used"],
            "compiles_post_warmup": s["compiles_post_warmup"],
            "fill_rate": s["fill_rate"],
            "p50_ms": s["latency_ms"]["p50"],
            "p95_ms": s["latency_ms"]["p95"],
            "p99_ms": s["latency_ms"]["p99"],
            "wall_median_s": round(wall_med, 4),
            "throughput_rps": round(n_requests / wall_med, 1),
            "speedup_vs_sync": round(statistics.median(ratios), 2),
            "speedup_spread": [round(ratios[0], 2), round(ratios[-1], 2)],
            "overlap_ratio": s["pipeline"]["overlap_ratio"],
            "queue_depth_max": s["pipeline"]["queue_depth_max"],
            "perms_match_baseline": bool(identical),
            "compliance": s["compliance"],
            "within_50ms": bool(s["latency_ms"]["p99"] <= LATENCY_BUDGET_MS),
        })
        if verbose:
            r = rows[-1]
            print(f"engine[{r['mode']:18s}] n={n_requests} "
                  f"p50 {r['p50_ms']:6.2f} p95 {r['p95_ms']:6.2f} "
                  f"p99 {r['p99_ms']:6.2f} ms  "
                  f"{r['throughput_rps']:7.1f} req/s "
                  f"(median {r['speedup_vs_sync']:.2f}x, spread "
                  f"{r['speedup_spread'][0]:.2f}-{r['speedup_spread'][1]:.2f})"
                  f"  overlap {r['overlap_ratio']:.2f}  "
                  f"perms_match {r['perms_match_baseline']}  "
                  f"recompiles {r['compiles_post_warmup']}", flush=True)
    for eng in engines.values():
        eng.close()
    return rows


def run_engine(*, n_requests=512, max_batch=32, max_wait_ms=2.0,
               scenarios=DEFAULT_MIX, seed=0, depths=(0, 1), trials=7,
               dedicated_device_core=True, verbose=True):
    """Mixed-shape stream through the engine, sync vs pipelined.

    depths[0] is the baseline (0 = synchronous); every other depth is
    reported with its paired-median speedup over that baseline and
    checked for identical perms per rid.

    With dedicated_device_core=True (default) on a CPU backend, the
    whole comparison re-runs in a subprocess with XLA intra-op
    threading disabled so device execution models an accelerator that
    does not consume host cores (both modes run under the SAME flags;
    see module docstring). Pass False to measure in-process under
    whatever XLA config is already loaded.
    """
    use_child = (dedicated_device_core
                 and not os.environ.get(_CHILD_ENV)
                 and jax.default_backend() == "cpu")
    if not use_child:
        rows = _run_engine_inproc(
            n_requests=n_requests, max_batch=max_batch,
            max_wait_ms=max_wait_ms, scenarios=scenarios, seed=seed,
            depths=depths, trials=trials, verbose=verbose)
        if not os.environ.get(_CHILD_ENV):
            save_json("latency_serve_engine", rows)
        return rows

    cfg = dict(n_requests=n_requests, max_batch=max_batch,
               max_wait_ms=max_wait_ms, seed=seed, depths=list(depths),
               trials=trials, verbose=verbose,
               scenarios=[vars(sc) for sc in scenarios])
    env = dict(os.environ)
    env[_CHILD_ENV] = "1"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " "
                        + _DEDICATED_CORE_FLAGS).strip()
    with tempfile.NamedTemporaryFile("r", suffix=".json") as out_f:
        subprocess.run(
            [sys.executable, "-m", "benchmarks.latency_serve",
             "--engine-child", out_f.name, "--engine-config",
             json.dumps(cfg)],
            env=env, check=True)
        rows = json.load(open(out_f.name))
    save_json("latency_serve_engine", rows)
    return rows


def run_frontier(*, n_requests=512,
                 load_fracs=(0.25, 0.5, 0.7, 0.85, 1.0, 1.2, 2.0),
                 max_batch=32, max_wait_ms=2.0, scenarios=DEFAULT_MIX,
                 seed=0, pipeline_depth=1, verbose=True):
    """The latency/throughput frontier: p99 vs OFFERED load, paced
    open-loop (Poisson arrivals at a target QPS — serving.traffic).

    A closed-loop (back-to-back) driver can only ever measure the
    saturated operating point; real deployments run below saturation
    and care about the tail there. The sweep first probes saturated
    capacity with one closed-loop pass, then offers Poisson traffic at
    fractions of it. Below saturation p99 is batching + service time
    (deadline-bounded); past it, queueing delay dominates and achieved
    throughput caps at capacity — `saturated` marks those rows.
    """
    requests = make_stream(scenarios, n_requests=n_requests, seed=seed)

    def fresh_engine():
        eng = ServingEngine(max_batch=max_batch, max_wait_ms=max_wait_ms,
                            pipeline_depth=pipeline_depth)
        eng.warmup(requests)
        return eng

    probe = fresh_engine()
    _, wall = _saturated_serve(probe, requests)
    probe.close()
    capacity = n_requests / wall
    if verbose:
        print(f"frontier: closed-loop capacity ~ {capacity:.1f} req/s",
              flush=True)

    rows = []
    for frac in load_fracs:
        qps = capacity * frac
        eng = fresh_engine()
        arrivals = poisson_arrivals(n_requests, qps, seed=seed + 1)
        results, ol = serve_open_loop(eng, requests, arrivals)
        s = eng.metrics.summary()
        dl = s["deadline"]
        eng.close()
        # Saturation telltale: QUEUEING lag at the last submission —
        # lateness carried into an arrival by earlier submits blocking
        # on engine backpressure. serve_open_loop separates this from
        # pacing clock-drift (sleep-granularity overshoot), so the
        # detector no longer trips on timer jitter on a loaded host.
        # Threshold: 10 arrival slots or 5 ms, whichever is larger.
        lag_thresh_ms = max(5.0, 1e4 / qps)
        saturated = ol["queue_lag_ms"]["last"] > lag_thresh_ms
        rows.append({
            "offered_qps": round(qps, 1),
            "offered_frac_of_capacity": frac,
            "achieved_qps": round(ol["achieved_qps"], 1),
            "capacity_qps": round(capacity, 1),
            "n_requests": n_requests,
            "max_batch": max_batch, "max_wait_ms": max_wait_ms,
            "pipeline_depth": pipeline_depth,
            "p50_ms": s["latency_ms"]["p50"],
            "p95_ms": s["latency_ms"]["p95"],
            "p99_ms": s["latency_ms"]["p99"],
            "queue_lag_ms_last": round(ol["queue_lag_ms"]["last"], 3),
            "drift_ms_p99": round(ol["drift_ms"]["p99"], 3),
            "submit_lag_ms_p99": round(ol["lag_ms"]["p99"], 3),
            "submit_lag_ms_last": round(ol["lag_ms"]["last"], 3),
            "deadline_hit_rate": dl["hit_rate"],
            "deadline_misses": dl["misses"],
            "sheds": dl["sheds"],
            "degrades": dl["degrades"],
            "fill_rate": s["fill_rate"],
            "compiles_post_warmup": s["compiles_post_warmup"],
            "saturated": bool(saturated),
            "within_50ms": bool(s["latency_ms"]["p99"] <= LATENCY_BUDGET_MS),
        })
        if verbose:
            r = rows[-1]
            print(f"frontier offered {r['offered_qps']:8.1f} req/s "
                  f"({frac:4.2f}x cap)  achieved {r['achieved_qps']:8.1f}  "
                  f"p50 {r['p50_ms']:6.2f}  p95 {r['p95_ms']:6.2f}  "
                  f"p99 {r['p99_ms']:7.2f} ms  queue_lag_last "
                  f"{r['queue_lag_ms_last']:7.2f} ms  "
                  f"hit_rate {r['deadline_hit_rate']:.3f}  "
                  f"saturated {r['saturated']}", flush=True)
    save_json("latency_frontier", rows)
    return rows


def _deadline_mix(seed):
    """Quick synthetic mix for the deadline gate: a KNN-served surface
    with a mean-predictor degradation rung, plus a raw-lam surface."""
    rng = np.random.default_rng(seed)
    d, K = 12, 4
    knn = KNNLambdaPredictor.fit(
        rng.normal(size=(64, d)).astype(np.float32),
        np.abs(rng.normal(size=(64, K))).astype(np.float32), k=5)
    mean = MeanLambdaPredictor.fit(
        np.zeros((4, d), np.float32),
        np.abs(rng.normal(size=(4, K))).astype(np.float32))
    mix = (Scenario("feed_knn", m1=300, m2=24, K=K, weight=2.0,
                    tag="knn", d_cov=d),
           Scenario("notif_lam", m1=120, m2=8, K=3, weight=1.0))
    return mix, knn, mean, d


def run_deadline(*, n_requests=256, max_batch=16, max_wait_ms=2.0,
                 seed=0, pipeline_depth=1, verbose=True):
    """Deadline-hit-rate frontier for the admission health gate.

    Probes closed-loop capacity on the quick synthetic mix, fixes a
    feasible per-request budget (max(50 ms, 5x the low-load p99) —
    generous enough that below-saturation service can always make it,
    so a miss means the engine queued past the deadline, not that the
    budget was impossible), then measures:

      * admission ON  at 0.5x and 0.8x capacity — the gate requires
        ZERO deadline misses (sheds/degrades are the controller doing
        its job and are reported, not failed);
      * admission OFF at 2.5x capacity — the baseline must show misses
        past saturation (otherwise the gate proves nothing);
      * a deterministic forced-degrade pass (KNN rungs poisoned with a
        huge observed service time) — degraded buckets must serve from
        rung 1 and report their compliance cost from the fused-kernel
        audit outputs.
    """
    mix, knn, mean, d = _deadline_mix(seed)
    requests = make_stream(mix, n_requests=n_requests, seed=seed)

    def fresh(admission, budget_s):
        eng = ServingEngine(max_batch=max_batch, max_wait_ms=max_wait_ms,
                            pipeline_depth=pipeline_depth,
                            admission=admission, default_budget_s=budget_s)
        eng.register_predictor("knn", knn, d_cov=d)
        eng.register_predictor("mean", mean, d_cov=d)
        eng.set_degradation_ladder("knn", ["mean"])
        eng.warmup(requests)
        return eng

    probe = fresh(None, 1.0)
    _, wall = _saturated_serve(probe, requests)
    probe.close()
    capacity = n_requests / wall

    eng = fresh(None, 1.0)                      # low-load budget reference
    arrivals = poisson_arrivals(n_requests, 0.3 * capacity, seed=seed + 1)
    serve_open_loop(eng, requests, arrivals)
    p99_low = eng.metrics.summary()["latency_ms"]["p99"]
    eng.close()
    budget_ms = max(LATENCY_BUDGET_MS, 5.0 * p99_low)
    if verbose:
        print(f"deadline: capacity ~ {capacity:.1f} req/s, low-load p99 "
              f"{p99_low:.2f} ms -> budget {budget_ms:.1f} ms", flush=True)

    # The overload baseline needs a stream long enough that queueing
    # lateness actually exceeds the budget: at frac x capacity the last
    # arrival is ~ (n/capacity)(1 - 1/frac) seconds late, so size n for
    # ~2 budgets of accumulated lateness (bounded for beefy hosts).
    n_over = int(min(20_000, max(
        n_requests, np.ceil(2.0 * (budget_ms / 1e3) * capacity / 0.6))))
    requests_over = make_stream(mix, n_requests=n_over, seed=seed)

    rows = []
    for frac, use_admission in ((0.5, True), (0.8, True), (2.5, False)):
        reqs = requests if use_admission else requests_over
        eng = fresh(AdmissionController() if use_admission else None,
                    budget_ms / 1e3)
        arrivals = poisson_arrivals(len(reqs), capacity * frac,
                                    seed=seed + 2)
        # deadlines anchored at scheduled ARRIVAL (absolute stamps):
        # lateness the generator accumulates blocking on backpressure
        # counts against the budget, as a caller-side SLA would.
        results, ol = serve_open_loop(eng, reqs, arrivals,
                                      deadline_budget_s=budget_ms / 1e3)
        dl = eng.metrics.deadline_summary()
        served = sum(1 for r in results if not isinstance(r, Shed))
        eng.close()
        rows.append({
            "admission": use_admission,
            "offered_frac_of_capacity": frac,
            "offered_qps": round(capacity * frac, 1),
            "capacity_qps": round(capacity, 1),
            "budget_ms": round(budget_ms, 1),
            "n_requests": len(reqs),
            "served": served,
            "deadline_hit_rate": dl["hit_rate"],
            "deadline_misses": dl["misses"],
            "sheds": dl["sheds"],
            "degrades": dl["degrades"],
            "queue_lag_ms_last": round(ol["queue_lag_ms"]["last"], 3),
            "rungs": dl["rungs"],
        })
        if verbose:
            r = rows[-1]
            print(f"deadline[admission={'on ' if use_admission else 'off'}] "
                  f"{frac:4.2f}x cap  served {r['served']:4d}  "
                  f"hit_rate {r['deadline_hit_rate']:.3f}  "
                  f"misses {r['deadline_misses']:3d}  "
                  f"sheds {r['sheds']:3d}  degrades {r['degrades']:3d}",
                  flush=True)

    # deterministic forced-degrade pass: every KNN rung predicted late
    for r in requests:
        r.deadline = None       # drop the open-loop runs' absolute stamps
    ctrl = AdmissionController()
    eng = fresh(ctrl, budget_ms / 1e3)
    for b in eng._warmed:
        if b.tag == "knn":
            ctrl.observe_service(b.name, 1e6)
    eng.serve_stream(requests, warmup=False)
    dl = eng.metrics.deadline_summary()
    eng.close()
    degrade = {
        "degrades": dl["degrades"],
        "sheds": dl["sheds"],
        "rung1_served": dl["rungs"].get("1", {}).get("served", 0),
        "rung1_compliance": dl["rungs"].get("1", {}).get(
            "compliance", float("nan")),
        "rung1_mean_shortfall": dl["rungs"].get("1", {}).get(
            "mean_shortfall", float("nan")),
    }
    if verbose:
        print(f"deadline[forced degrade] degrades {degrade['degrades']} "
              f"rung1_served {degrade['rung1_served']} "
              f"rung1_compliance {degrade['rung1_compliance']} "
              f"rung1_mean_shortfall {degrade['rung1_mean_shortfall']}",
              flush=True)
    out = {"capacity_qps": round(capacity, 1),
           "budget_ms": round(budget_ms, 1),
           "rows": rows, "degrade": degrade}
    save_json("latency_deadline", out)
    return out


def check_deadline(*, quick=False, verbose=True):
    """Admission health gate (kernel_bench-style: AssertionError on
    regression): zero missed deadlines below 80% of detected
    saturation with admission on; the admission-off baseline must miss
    past saturation; degraded buckets must report compliance cost."""
    kw = dict(n_requests=160) if quick else {}
    res = run_deadline(verbose=verbose, **kw)
    for r in res["rows"]:
        if r["admission"] and r["offered_frac_of_capacity"] <= 0.8:
            assert r["deadline_misses"] == 0, (
                f"deadline gate: {r['deadline_misses']} misses at "
                f"{r['offered_frac_of_capacity']}x capacity with admission "
                f"on (budget {r['budget_ms']} ms)")
            assert r["served"] > 0, (
                "deadline gate: admission shed the entire below-saturation "
                "stream — the controller is overpredicting")
    baseline = [r for r in res["rows"] if not r["admission"]]
    assert baseline and any(r["deadline_misses"] > 0 for r in baseline), (
        "deadline gate: the admission-off overload baseline shows no "
        "misses — the gate is not exercising saturation")
    dg = res["degrade"]
    assert dg["degrades"] > 0 and dg["rung1_served"] > 0, (
        f"deadline gate: forced-degrade pass served nothing from rung 1 "
        f"({dg})")
    assert np.isfinite(dg["rung1_mean_shortfall"]), (
        "deadline gate: rung 1 reported no compliance cost")
    print("# deadline acceptance (0 misses <= 0.8x capacity with "
          "admission, baseline misses past saturation, degraded rungs "
          "report compliance cost): PASS")
    return res


def records_deadline(res):
    recs = [Record(
        name=f"serve_deadline/admission={'on' if r['admission'] else 'off'}"
             f"/frac={r['offered_frac_of_capacity']}",
        us_per_call=float("nan"),
        derived={"hit_rate": r["deadline_hit_rate"],
                 "misses": r["deadline_misses"],
                 "sheds": r["sheds"], "degrades": r["degrades"],
                 "served": r["served"], "budget_ms": r["budget_ms"],
                 "capacity_qps": r["capacity_qps"]})
        for r in res["rows"]]
    dg = res["degrade"]
    recs.append(Record(
        name="serve_deadline/forced_degrade",
        us_per_call=float("nan"),
        derived={"degrades": dg["degrades"],
                 "rung1_served": dg["rung1_served"],
                 "rung1_compliance": dg["rung1_compliance"],
                 "rung1_mean_shortfall": dg["rung1_mean_shortfall"]}))
    return recs


REFRESH_TAG = "arch"
REFRESH_D, REFRESH_K = 10, 4


def _refresh_engine(pred, *, max_batch=8, pipeline_depth=1):
    """Deterministic refresh-gate engine: max_wait_ms=1e9 means the
    deadline flush never fires, so batch composition is a pure function
    of the stream (capacity flushes + end-of-stream drain) and hot- vs
    cold-engine runs are bitwise comparable without a frozen clock."""
    eng = ServingEngine(max_batch=max_batch, max_wait_ms=1e9,
                        pipeline_depth=pipeline_depth)
    eng.register_predictor(REFRESH_TAG, pred, d_cov=REFRESH_D)
    return eng


def _bitwise_same(got, ref):
    return (np.array_equal(got.perm, ref.perm)
            and np.array_equal(got.exposure, ref.exposure)
            and got.utility == ref.utility
            and got.compliant == ref.compliant)


def run_refresh(*, n_requests=192, chunk=32, max_batch=8, seed=0,
                verbose=True):
    """Hot-swap health probe for the online λ-refresh lane.

    Serves a shortfall-heavy stationary stream in chunks with a
    `lane.refresh()` between chunks (real telemetry -> real swaps),
    then checks the zero-recompile contract the tests prove, on the
    benchmark box: swaps happened, compiles_post_warmup stayed 0,
    per-bucket jit caches stayed at exactly the warmed executable,
    executable_calls stayed one per flushed micro-batch — and each
    epoch's results are BITWISE what a cold engine started from that
    epoch's published state serves. A final rollback() must republish
    the pre-swap generation bitwise. Also times the refresh publish
    (drain + update rule + device_put + fenced swap), the number that
    has to stay tiny for the lane to ride the serving box.
    """
    rng = np.random.default_rng(seed)
    pred = KNNLambdaPredictor.fit(
        rng.normal(size=(96, REFRESH_D)).astype(np.float32),
        np.abs(rng.normal(size=(96, REFRESH_K))).astype(np.float32), k=5)
    reqs = make_drift_stream(
        DriftSpec(kind="none"), tag=REFRESH_TAG, n_requests=n_requests,
        m1=128, m2=16, K=REFRESH_K, d_cov=REFRESH_D, b_frac=0.25,
        seed=seed)

    eng = _refresh_engine(pred, max_batch=max_batch)
    lane = RefreshLane(eng, eta=0.5, min_samples=8)
    eng.warmup(reqs)

    # epoch -> host copy of the published state, for cold-engine replay
    states = {0: jax.device_get(eng.predictor_state_of(REFRESH_TAG))}
    chunks, swap_us = [], []
    for i in range(0, len(reqs), chunk):
        got = eng.serve_stream(reqs[i:i + chunk], warmup=False)
        chunks.append((eng.predictor_epoch(REFRESH_TAG),
                       reqs[i:i + chunk], got))
        t0 = time.perf_counter()
        rep = lane.refresh()[REFRESH_TAG]
        dt = time.perf_counter() - t0
        if rep["swapped"]:
            swap_us.append(dt * 1e6)
            states[rep["epoch"]] = jax.device_get(
                eng.predictor_state_of(REFRESH_TAG))

    m = eng.metrics
    sizes = eng.jit_cache_sizes()
    swaps = m.refresh_summary()["swaps"]

    # hot-vs-cold parity, per epoch: refresh() runs between chunks and
    # serve_stream drains fully, so every chunk is entirely one epoch.
    parity_ok = True
    for epoch in sorted({e for e, _, _ in chunks}):
        cold = _refresh_engine(with_state(pred, states[epoch]),
                               max_batch=max_batch)
        for e, creqs, got in chunks:
            if e != epoch:
                continue
            ref = {r.rid: r for r in cold.serve_stream(creqs)}
            parity_ok &= all(_bitwise_same(r, ref[r.rid]) for r in got)
        cold.close()

    # rollback republishes the pre-swap generation (as a NEW epoch —
    # the fence applies to rollback too) bitwise.
    pre_rollback_epoch = eng.predictor_epoch(REFRESH_TAG)
    rollback_ok = False
    if swaps >= 1:
        t0 = time.perf_counter()
        rb_epoch = lane.rollback(REFRESH_TAG)
        rollback_us = (time.perf_counter() - t0) * 1e6
        prev = states[pre_rollback_epoch - 1]
        now = jax.device_get(eng.predictor_state_of(REFRESH_TAG))
        rollback_ok = (rb_epoch == pre_rollback_epoch + 1
                       and set(now) == set(prev)
                       and all(np.array_equal(now[k], prev[k])
                               for k in now))
    else:
        rollback_us = float("nan")

    out = {
        "n_requests": n_requests,
        "swaps": swaps,
        "final_epoch": eng.predictor_epoch(REFRESH_TAG),
        "compiles_post_warmup": m.compiles_post_warmup,
        "executable_calls": m.executable_calls,
        "batches": m.batches,
        "jit_cache_sizes": dict(sizes),
        "parity_ok": bool(parity_ok),
        "rollback_ok": bool(rollback_ok),
        "swap_us_p50": (round(statistics.median(swap_us), 1)
                        if swap_us else float("nan")),
        "rollback_us": round(rollback_us, 1),
    }
    eng.close()
    if verbose:
        print(f"refresh: swaps {out['swaps']}  epoch {out['final_epoch']}  "
              f"compiles_post_warmup {out['compiles_post_warmup']}  "
              f"exec_calls/batches {out['executable_calls']}/"
              f"{out['batches']}  swap_p50 {out['swap_us_p50']} us  "
              f"parity {out['parity_ok']}  rollback {out['rollback_ok']}",
              flush=True)
    save_json("latency_refresh", out)
    return out


def check_refresh(*, quick=False, verbose=True):
    """Refresh-lane health gate (kernel_bench-style: AssertionError on
    regression): real telemetry must drive >= 2 hot swaps with zero
    recompiles and one dispatch per batch, every epoch must serve
    bitwise what a cold engine on that state serves, and rollback must
    restore the last-good generation bitwise."""
    kw = dict(n_requests=128) if quick else {}
    res = run_refresh(verbose=verbose, **kw)
    assert res["swaps"] >= 2, (
        f"refresh gate: only {res['swaps']} swaps — the shortfall-heavy "
        f"stream should force repeated refreshes")
    assert res["compiles_post_warmup"] == 0, (
        f"refresh gate: {res['compiles_post_warmup']} recompiles after "
        f"warmup — a swap broke the frozen-shape contract")
    assert all(v == 1 for v in res["jit_cache_sizes"].values()), (
        f"refresh gate: jit cache grew past the warmed executable: "
        f"{res['jit_cache_sizes']}")
    assert res["executable_calls"] == res["batches"], (
        f"refresh gate: {res['executable_calls']} executable calls for "
        f"{res['batches']} batches — a swap added a dispatch")
    assert res["parity_ok"], (
        "refresh gate: hot-swapped serving diverged from a cold engine "
        "started on the published state")
    assert res["rollback_ok"], (
        "refresh gate: rollback did not restore the pre-swap state "
        "bitwise")
    print("# refresh acceptance (>= 2 hot swaps, 0 recompiles, 1 "
          "dispatch/batch, hot == cold bitwise per epoch, rollback "
          "restores last-good): PASS")
    return res


def records_refresh(res):
    return [Record(
        name=f"serve_refresh/hot_swap/n={res['n_requests']}",
        us_per_call=res["swap_us_p50"],
        derived={"swaps": res["swaps"],
                 "final_epoch": res["final_epoch"],
                 "compiles_post_warmup": res["compiles_post_warmup"],
                 "executable_calls": res["executable_calls"],
                 "batches": res["batches"],
                 "parity_ok": res["parity_ok"],
                 "rollback_ok": res["rollback_ok"],
                 "rollback_us": res["rollback_us"]})]


def run_drift(*, n_requests=256, chunk=32, seed=10, verbose=True):
    """Drift regression: refresh-on vs refresh-off under mid-stream
    constraint tightening, plus the stationarity control.

    The KNN predictor is fit in the compliant era (zero-λ database);
    the stream tightens its thresholds 8x between 25% and 75% of the
    stream. Refresh-off keeps serving the stale λ̂ and accumulates
    exposure shortfall against the requests' REAL thresholds;
    refresh-on folds the dual-subgradient telemetry back between
    chunks and must strictly reduce it — with zero recompiles. On a
    stationary stream with no dual pressure (compliant, and served
    with λ̂ = 0 so the symmetric decay side of the gate is quiet too)
    the lane must publish nothing and serving must stay bitwise
    identical to refresh-off.
    """
    def shortfall_run(reqs, *, refresh_on, eta=1.0, knn_scale=0.0,
                      knn_seed=9):
        rng = np.random.default_rng(knn_seed)
        pred = KNNLambdaPredictor.fit(
            rng.normal(size=(64, REFRESH_D)).astype(np.float32),
            knn_scale * np.abs(rng.normal(
                size=(64, REFRESH_K))).astype(np.float32), k=5)
        eng = _refresh_engine(pred, pipeline_depth=0)
        lane = (RefreshLane(eng, eta=eta, min_samples=8)
                if refresh_on else None)
        eng.warmup(reqs)
        results = []
        for i in range(0, len(reqs), chunk):
            results += eng.serve_stream(reqs[i:i + chunk], warmup=False)
            if lane is not None:
                lane.refresh()
        by_rid = {r.rid: r for r in reqs}
        shortfall = sum(
            float(np.clip(by_rid[r.rid].b - r.exposure, 0.0, None).sum())
            for r in results)
        m = eng.metrics
        out = {"shortfall": round(shortfall, 4),
               "swaps": m.refresh_summary()["swaps"],
               "compiles_post_warmup": m.compiles_post_warmup,
               "results": results}
        eng.close()
        return out

    spec = DriftSpec(kind="tighten", magnitude=8.0, start=0.25, end=0.75)
    reqs = make_drift_stream(
        spec, tag=REFRESH_TAG, n_requests=n_requests, m1=128, m2=16,
        K=REFRESH_K, d_cov=REFRESH_D, b_frac=0.03, seed=seed)
    off = shortfall_run(reqs, refresh_on=False)
    on = shortfall_run(reqs, refresh_on=True)

    # stationarity control: compliant stream, refresh must be a no-op
    stat = make_drift_stream(
        DriftSpec(kind="none"), tag=REFRESH_TAG, n_requests=96, m1=128,
        m2=16, K=REFRESH_K, d_cov=REFRESH_D, topic_rate=0.45,
        b_frac=0.01, seed=seed + 1)
    # knn_scale=0.0: a compliant stream served with positive λ̂ now
    # legitimately publishes (decay pressure relaxes over-satisfied
    # constraints), so the bitwise-neutrality control serves unpriced
    s_off = shortfall_run(stat, refresh_on=False, knn_scale=0.0,
                          knn_seed=seed + 2)
    s_on = shortfall_run(stat, refresh_on=True, knn_scale=0.0,
                         knn_seed=seed + 2)
    ref = {r.rid: r for r in s_off["results"]}
    neutral = (s_on["swaps"] == 0
               and all(_bitwise_same(r, ref[r.rid])
                       for r in s_on["results"]))

    out = {
        "n_requests": n_requests,
        "drift": {"kind": spec.kind, "magnitude": spec.magnitude,
                  "start": spec.start, "end": spec.end},
        "shortfall_off": off["shortfall"],
        "shortfall_on": on["shortfall"],
        "shortfall_ratio": round(on["shortfall"]
                                 / max(off["shortfall"], 1e-12), 4),
        "swaps_on": on["swaps"],
        "compiles_post_warmup": (off["compiles_post_warmup"]
                                 + on["compiles_post_warmup"]),
        "stationary_neutral": bool(neutral),
        "stationary_swaps": s_on["swaps"],
    }
    if verbose:
        print(f"drift[{spec.kind} x{spec.magnitude}] shortfall "
              f"off {out['shortfall_off']:.2f} -> on "
              f"{out['shortfall_on']:.2f} (ratio "
              f"{out['shortfall_ratio']:.3f}, {out['swaps_on']} swaps)  "
              f"stationary_neutral {out['stationary_neutral']}",
              flush=True)
    save_json("latency_drift", out)
    return out


def check_drift(*, quick=False, verbose=True):
    """Drift health gate (AssertionError on regression): refresh-on
    strictly reduces accumulated shortfall under tighten drift with
    zero recompiles, and is bitwise neutral on a compliant stationary
    stream."""
    kw = dict(n_requests=160) if quick else {}
    res = run_drift(verbose=verbose, **kw)
    assert res["shortfall_on"] < res["shortfall_off"], (
        f"drift gate: refresh-on shortfall {res['shortfall_on']} did not "
        f"beat refresh-off {res['shortfall_off']}")
    assert res["swaps_on"] >= 1, (
        "drift gate: refresh-on published nothing under drift")
    assert res["compiles_post_warmup"] == 0, (
        f"drift gate: {res['compiles_post_warmup']} recompiles after "
        f"warmup across the drift runs")
    assert res["stationary_neutral"], (
        f"drift gate: refresh was not a bitwise no-op on the "
        f"no-pressure stationary stream ({res['stationary_swaps']} "
        f"swaps)")
    print("# drift acceptance (refresh-on < refresh-off shortfall under "
          "tighten drift, 0 recompiles, bitwise-neutral when "
          "stationary): PASS")
    return res


def records_drift(res):
    return [Record(
        name=f"serve_drift/{res['drift']['kind']}"
             f"/mag={res['drift']['magnitude']}/n={res['n_requests']}",
        us_per_call=float("nan"),
        derived={"shortfall_off": res["shortfall_off"],
                 "shortfall_on": res["shortfall_on"],
                 "shortfall_ratio": res["shortfall_ratio"],
                 "swaps_on": res["swaps_on"],
                 "compiles_post_warmup": res["compiles_post_warmup"],
                 "stationary_neutral": res["stationary_neutral"]})]


QUANT_TAG, QUANT_D, QUANT_K, QUANT_SLAB = "quant_arch", 12, 4, 32


def run_quant(*, n_requests=96, n_db=96, max_batch=8, seed=21,
              verbose=True):
    """Serve one stream through TWO fused-executor engines — one on the
    f32 KNN predictor, one on its int8-quantized twin — and compare the
    served results request by request. The train db is LOSSLESS under
    int8 (values on the 0.5 grid inside [-63.5, 63.5] with the absmax
    planted in every slab, so each slab scale is exactly 0.5): the
    quantized sweep then reconstructs the db bitwise and every served
    field (perm, utility, exposure, compliant) must match the f32
    engine exactly — the 'unchanged RankingOutput' contract measured at
    the serving boundary rather than the kernel boundary."""
    rng = np.random.default_rng(seed)
    X_db = np.clip(np.round(rng.uniform(
        -63.0, 63.0, size=(n_db, QUANT_D)) * 2.0) / 2.0, -63.5, 63.5)
    X_db[::QUANT_SLAB] = 63.5            # every slab sees the absmax
    lam_db = np.abs(rng.normal(size=(n_db, QUANT_K))).astype(np.float32)
    base = KNNLambdaPredictor.fit(X_db.astype(np.float32), lam_db, k=5)
    quant = base.quantized(mode="int8", slab=QUANT_SLAB)

    mix = (Scenario("feed", m1=300, m2=16, K=QUANT_K, tag=QUANT_TAG,
                    d_cov=QUANT_D),)
    reqs = make_stream(mix, n_requests=n_requests, seed=seed + 1)
    served, metrics = {}, {}
    for name, pred in (("f32", base), ("int8", quant)):
        with ServingEngine(max_batch=max_batch, max_wait_ms=1e9,
                           executor="fused") as eng:
            eng.register_predictor(QUANT_TAG, pred, d_cov=QUANT_D)
            eng.warmup(reqs)
            results = eng.serve_stream(reqs, warmup=False)
            m = eng.metrics
            served[name] = {r.rid: r for r in results}
            metrics[name] = {
                "batches": m.batches,
                "launches_per_batch": (m.kernel_launches / m.batches
                                       if m.batches else float("nan")),
                "compiles_post_warmup": m.compiles_post_warmup,
                "p50_ms": m.summary()["latency_ms"]["p50"]}
    bitwise = (sorted(served["f32"]) == sorted(served["int8"])
               and all(_bitwise_same(served["int8"][rid],
                                     served["f32"][rid])
                       for rid in served["f32"]))
    out = {"n_requests": n_requests, "n_db": n_db, "slab": QUANT_SLAB,
           "bitwise_vs_f32": bool(bitwise), "metrics": metrics}
    if verbose:
        print(f"# quant serve: int8 engine bitwise == f32 engine: "
              f"{bitwise}; launches/batch "
              f"f32={metrics['f32']['launches_per_batch']:.2f} "
              f"int8={metrics['int8']['launches_per_batch']:.2f}; "
              f"recompiles f32={metrics['f32']['compiles_post_warmup']} "
              f"int8={metrics['int8']['compiles_post_warmup']}",
              flush=True)
    return out


def check_quant(*, quick=False, verbose=True):
    """Quantized-serving health gate (AssertionError on regression):
    the int8 engine serves the lossless stream bitwise identical to
    the f32 engine, both keep exactly one kernel launch per flushed
    micro-batch, and neither recompiles after warmup."""
    kw = dict(n_requests=48) if quick else {}
    res = run_quant(verbose=verbose, **kw)
    assert res["bitwise_vs_f32"], (
        "quant gate: int8 engine diverged from the f32 engine on a "
        "lossless db (served RankingOutput must be unchanged)")
    for name, m in res["metrics"].items():
        assert m["launches_per_batch"] == 1.0, (
            f"quant gate: {name} engine at {m['launches_per_batch']} "
            f"kernel launches per batch (expected exactly 1.0)")
        assert m["compiles_post_warmup"] == 0, (
            f"quant gate: {name} engine recompiled "
            f"{m['compiles_post_warmup']}x after warmup")
    print("# quant serve acceptance (int8 engine bitwise == f32 engine"
          ", 1 launch/batch, 0 recompiles): PASS")
    return res


def records_quant(res):
    m = res["metrics"]
    return [Record(
        name=f"serve_quant/n={res['n_requests']}/db={res['n_db']}"
             f"/slab={res['slab']}",
        us_per_call=float("nan"),
        derived={"bitwise_vs_f32": res["bitwise_vs_f32"],
                 "p50_ms_f32": m["f32"]["p50_ms"],
                 "p50_ms_int8": m["int8"]["p50_ms"],
                 "launches_per_batch": m["int8"]["launches_per_batch"],
                 "compiles_post_warmup":
                     m["int8"]["compiles_post_warmup"]})]


FLEET_TAG = "fleet_arch"
FLEET_D, FLEET_K = 12, 4


def _fleet_step_clock(step_s=1e-3):
    """Router clock for the fleet gate: advances a fixed step per call,
    so health deadlines and restart backoff depend on the CALL pattern
    (deterministic given the stream + plan), not wall time. Engines
    keep their real clocks — the p99 the gate reports is real."""
    t = [0.0]

    def clock():
        t[0] += step_s
        return t[0]
    return clock


def run_fleet(*, n_requests=512, max_batch=8, seed=17, slow_ms=1.0,
              ckpt_dir=None, verbose=True):
    """Chaos probe for the replica fleet (serving/fleet.py).

    Builds a 3-replica FleetRouter (each replica a full engine +
    RefreshLane + per-replica CheckpointStore), serves a fault-free
    prefix (n/4 requests) and runs one refresh so the busiest
    replica publishes AND checkpoints epoch 1, then arms the seeded
    chaos plan — crash-at-batch-k and a partial-drain kill on the
    primary of the busiest bucket, a heartbeat blackhole + poisoned
    swap on the second, injected latency on the third — and serves
    the remaining 3n/4 through the failures, with periodic refreshes
    so the poisoned swap actually fires. The fault schedule is
    derived from `seed` and the router runs on a step clock, so the
    same failures replay on every box."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(96, FLEET_D)).astype(np.float32)
    lam = np.abs(rng.normal(size=(96, FLEET_K))).astype(np.float32)
    if ckpt_dir is None:
        tmp = tempfile.TemporaryDirectory(prefix="fleet-gate-")
        ckpt_dir = tmp.name
    else:
        tmp = None

    def factory(name):
        eng = ServingEngine(max_batch=max_batch, max_wait_ms=1e9,
                            pipeline_depth=1)
        eng.register_predictor(
            FLEET_TAG, KNNLambdaPredictor.fit(X, lam, k=5), d_cov=FLEET_D)
        store = CheckpointStore(os.path.join(ckpt_dir, name), keep_last=3)
        lane = RefreshLane(eng, eta=0.5, min_samples=8, checkpoint=store)
        return eng, lane

    mix = (Scenario("feed", m1=128, m2=16, K=FLEET_K, tag=FLEET_TAG,
                    d_cov=FLEET_D, m1_jitter=0.0, b_frac=0.25, weight=2.0,
                    surface="feed"),
           Scenario("strip", m1=192, m2=16, K=FLEET_K, tag=FLEET_TAG,
                    d_cov=FLEET_D, m1_jitter=0.0, b_frac=0.25, weight=1.0,
                    surface="strip"))
    reqs = make_stream(mix, n_requests=n_requests, seed=seed + 1)

    router = FleetRouter(
        factory, 3, clock=_fleet_step_clock(),
        health=HealthConfig(suspect_after_s=0.02, dead_after_s=0.5),
        heartbeat_interval_s=float("inf"),
        backoff_base_s=0.02, backoff_cap_s=0.2, seed=seed)
    router.warmup(reqs)

    def serve(chunk, out):
        for r in chunk:
            out += router.submit(r)
            out += router.poll()
            router.tick()

    # ---- fault-free prefix: a checkpointed epoch must exist before
    # the first planned crash, or "restart resumes at last-good λ̂"
    # would be vacuous.
    results = []
    prefix = n_requests // 4
    serve(reqs[:prefix], results)
    results += router.drain()
    # crash target: the primary of the busiest bucket — the chaos plan
    # is keyed by name, the ring decides who that is.
    order = []
    for r in reqs:
        name = router.replicas[
            router._owners(router._bucket_key(r))[0]].name
        if name not in order:
            order.append(name)
    order += [rep.name for rep in router.replicas if rep.name not in order]
    pre = router.refresh()[order[0]][FLEET_TAG]
    assert pre["swapped"] and pre["checkpointed"], (
        f"fleet gate setup: prefix refresh did not checkpoint: {pre}")
    last_good = pre["epoch"]

    # ---- arm the chaos plan and serve the remainder through it
    plan = FaultPlan.chaos(order, seed=seed, slow_ms=slow_ms)
    router.fault_plan = plan
    for rep in router.replicas:
        rep.injector = FaultInjector(plan.faults_for(rep.name), rep.name)
        if rep.lane is not None:
            rep.lane.publish_filter = (
                lambda tag, state, inj=rep.injector: inj.poison_state(state))
    router.arm_faults()
    rest = reqs[prefix:]
    refused = 0
    step = max(1, len(rest) // 3)
    for i in range(0, len(rest), step):
        serve(rest[i:i + step], results)
        for reports in router.refresh().values():   # poisoned swap fires
            rep = reports.get(FLEET_TAG, {})        # on a planned index
            refused += int(str(rep.get("reason", "")).startswith("refused"))
    results += router.drain()

    s = router.fleet_summary()
    served = sorted(r.rid for r in results if not isinstance(r, Shed))
    crash_rep = next(r for r in router.replicas if r.name == order[0])
    restored = (crash_rep.restore_history[0].get(FLEET_TAG)
                if crash_rep.restore_history else None)
    out = {
        "n_requests": n_requests,
        "replicas": 3,
        "exactly_once": served == list(range(n_requests)),
        "orphaned_futures": s["orphaned_futures"],
        "lost": s["lost"],
        "crashes": s["crashes"],
        "restarts": s["restarts"],
        "failovers": s["failovers"],
        "hedges": s["hedges"],
        "duplicates_deduped": s["duplicates_deduped"],
        "retries": s["retries"],
        "heartbeats_missed": s["heartbeats_missed"],
        "poisoned_swaps_refused": refused,
        "last_good_epoch": last_good,
        "restored_epoch": restored,
        "compiles_post_warmup": sum(
            rep.engine.metrics.compiles_post_warmup
            for rep in router.replicas),
        "p50_ms": s.get("latency_ms", {}).get("p50", float("nan")),
        "p99_ms": s.get("latency_ms", {}).get("p99", float("nan")),
    }
    router.close()
    if tmp is not None:
        tmp.cleanup()
    if verbose:
        print(f"fleet: served {len(served)}/{n_requests}  crashes "
              f"{out['crashes']}  restarts {out['restarts']}  failovers "
              f"{out['failovers']}  hedges {out['hedges']}  retries "
              f"{out['retries']}  lost {out['lost']}  orphans "
              f"{out['orphaned_futures']}  restored epoch "
              f"{out['restored_epoch']} (last-good {out['last_good_epoch']})"
              f"  p99 {out['p99_ms']:.2f} ms", flush=True)
    save_json("latency_fleet", out)
    return out


# CI boxes are noisy shared CPUs, and a supervised restart re-warms
# (recompiles) the replica's bucket subset on the caller thread, which
# stalls requests queued on the HEALTHY replicas for the duration —
# so the fleet gate checks the budget with a generous multiple. The
# tight per-request budget is the deadline gate's job; here the p99
# bound only catches pathological stalls (a failover path that
# serializes the fleet, a drain that spins) well past that restart
# pause.
FLEET_P99_TOLERANCE = 40.0


def check_fleet(*, quick=False, verbose=True):
    """Fleet fault-tolerance gate (AssertionError on regression): under
    the full seeded chaos plan, every request is served exactly once,
    nothing is lost or orphaned, the crashed replica restarts and
    resumes at the last-good checkpointed epoch, no incarnation
    recompiles after warmup, and p99 stays within budget x tolerance."""
    kw = dict(n_requests=256) if quick else {}
    res = run_fleet(verbose=verbose, **kw)
    assert res["exactly_once"], (
        "fleet gate: served rids != submitted rids (dropped or "
        "duplicated requests)")
    assert res["orphaned_futures"] == 0, (
        f"fleet gate: {res['orphaned_futures']} fleet futures never "
        f"settled")
    assert res["lost"] == 0, (
        f"fleet gate: {res['lost']} requests exhausted their retry "
        f"budget")
    assert res["crashes"] >= 1 and res["restarts"] >= 1, (
        f"fleet gate: chaos plan did not exercise crash+restart "
        f"(crashes={res['crashes']}, restarts={res['restarts']})")
    assert res["restored_epoch"] == res["last_good_epoch"], (
        f"fleet gate: restarted replica resumed at epoch "
        f"{res['restored_epoch']}, expected last-good "
        f"{res['last_good_epoch']} (cold restart?)")
    assert res["compiles_post_warmup"] == 0, (
        f"fleet gate: {res['compiles_post_warmup']} recompiles after "
        f"warmup across the fleet — a failover path hit a cold bucket")
    budget = LATENCY_BUDGET_MS * FLEET_P99_TOLERANCE
    assert res["p99_ms"] <= budget, (
        f"fleet gate: p99 {res['p99_ms']:.1f} ms over {budget:.0f} ms "
        f"(budget x {FLEET_P99_TOLERANCE:g} CI tolerance)")
    print("# fleet acceptance (exactly-once under chaos, 0 orphans, "
          "0 lost, restart resumes last-good epoch, 0 recompiles, "
          "p99 within tolerance): PASS")
    return res


def records_fleet(res):
    return [Record(
        name=f"serve_fleet/chaos/n={res['n_requests']}"
             f"/replicas={res['replicas']}",
        us_per_call=res["p99_ms"] * 1e3,
        derived={"p50_ms": res["p50_ms"], "p99_ms": res["p99_ms"],
                 "exactly_once": res["exactly_once"],
                 "orphaned_futures": res["orphaned_futures"],
                 "lost": res["lost"], "crashes": res["crashes"],
                 "restarts": res["restarts"],
                 "failovers": res["failovers"],
                 "hedges": res["hedges"], "retries": res["retries"],
                 "restored_epoch": res["restored_epoch"],
                 "compiles_post_warmup": res["compiles_post_warmup"]})]


# Skewed multi-surface mixes for the lattice gate: nominal candidate
# counts sit just past a power-of-two boundary (540 -> 1024,
# 300 -> 512, 140 -> 256) with jitter tight enough that the WHOLE
# jitter range stays in that ceiling's bucket, so the static lattice
# pads 2-3x while the learned corners hug the traffic. Phase 1 shifts
# the heavy surface (a feed redesign doubling its candidate pool past
# the NEXT boundary, 1100 -> 2048) so the second swap has something
# genuinely new to learn.
LATTICE_MIX_P0 = (
    Scenario("feed_a", m1=540, m2=10, K=3, weight=4.0, m1_jitter=0.05,
             surface="feed"),
    Scenario("strip_a", m1=300, m2=8, K=5, weight=2.0, m1_jitter=0.1,
             surface="strip"),
    Scenario("notif_a", m1=140, m2=6, K=3, weight=1.0, m1_jitter=0.05,
             surface="notif"),
)
LATTICE_MIX_P1 = (
    Scenario("feed_b", m1=1100, m2=12, K=3, weight=4.0, m1_jitter=0.05,
             surface="feed"),
    Scenario("strip_a", m1=300, m2=8, K=5, weight=2.0, m1_jitter=0.1,
             surface="strip"),
)


def _lattice_engine(*, max_batch=8, pipeline_depth=1, lattice=None):
    """Deterministic lattice-gate engine (same trick as the refresh
    gate: max_wait_ms=1e9 kills the deadline flush, so batch
    composition is a pure function of the stream and two engines
    serving the same chunks are bitwise comparable)."""
    return ServingEngine(max_batch=max_batch, max_wait_ms=1e9,
                         pipeline_depth=pipeline_depth, lattice=lattice)


def run_lattice(*, chunk=64, max_batch=8, seed=29, pipeline_depth=1,
                verbose=True):
    """Adaptive-lattice health probe.

    Serves the skewed two-phase mix in chunks on one engine with a
    LatticeLane attached: chunk c0 builds the shape histogram on the
    boot power-of-two lattice, a detector-gated re-warm flips to
    learned corners (swap 1), c1 is measured adaptive; c2 switches to
    the phase-1 mix (new shapes fall back to warmed power-of-two
    buckets — out-of-lattice traffic degrades, never compiles), a
    second re-warm learns the shifted mix (swap 2), c3 is measured
    adaptive again. A separate power-of-two engine serves IDENTICAL
    c1+c3 chunks as the padding-waste baseline. Then a poisoned
    proposal (an m2 > m1 corner) exercises the rollback path, and a
    final chunk proves the served stream never paused.

    Checks, per the refined no-recompile contract: zero compiles on
    the dispatch path across both swaps (jit caches frozen at the
    warmed executables; cache growth only inside shadow-warm windows),
    one executable call per flushed batch, every chunk's results
    bitwise-equal to a COLD engine constructed directly on that
    chunk's lattice epoch, and adaptive padding waste (padded/real
    sweep FLOPs) at least 1.5x lower than the power-of-two baseline's
    on the measured chunks.
    """
    s0, s1, s2, s3, s4 = seed, seed + 1, seed + 2, seed + 3, seed + 4
    c0 = make_stream(LATTICE_MIX_P0, n_requests=chunk, seed=s0)
    c1 = make_stream(LATTICE_MIX_P0, n_requests=chunk, seed=s1)
    c2 = make_stream(LATTICE_MIX_P1, n_requests=chunk, seed=s2)
    c3 = make_stream(LATTICE_MIX_P1, n_requests=chunk, seed=s3)
    c4 = make_stream(LATTICE_MIX_P1, n_requests=max_batch, seed=s4)
    for i, r in enumerate(c1 + c2 + c3 + c4):
        r.rid = 10_000 + i            # distinct rids across chunks
    full = c0 + c1 + c2 + c3 + c4

    eng = _lattice_engine(max_batch=max_batch,
                          pipeline_depth=pipeline_depth)
    lane = LatticeLane(
        eng, max_executables=8, min_samples=32,
        detector=TroughDetector(rate_threshold_qps=50.0,
                                lag_threshold_ms=5.0, patience_s=0.25))
    # warm on the FULL stream: every power-of-two bucket either phase
    # reaches is compiled up front, so post-swap out-of-lattice
    # fallbacks are warm too — the zero-dispatch-compile guarantee
    # covers the WHOLE run, swaps and phase shift included.
    eng.warmup(full)

    chunks = []                        # (lattice_epoch, lattice, reqs, got)
    stamps_ok = True

    def serve_chunk(reqs):
        nonlocal stamps_ok
        got = eng.serve_stream(reqs, warmup=False)
        epoch = eng.lattice_epoch()
        stamps_ok &= all(r.lattice_epoch == epoch for r in got)
        chunks.append((epoch, eng.lattice(), reqs, got))
        return got

    def trough_rewarm():
        """Detector-gated re-warm, as the background lane would run it:
        quiet for longer than the patience window -> trough -> propose
        + shadow-warm + flip."""
        now = eng.clock()
        early = lane.maybe_rewarm(now + 0.1)       # patience not yet met
        later = lane.maybe_rewarm(now + 1.0)       # quiet >= patience
        return early, later

    def flops():
        return (eng.metrics.real_flops, eng.metrics.padded_flops)

    serve_chunk(c0)
    no_trough, swap1 = trough_rewarm()
    f0 = flops()
    serve_chunk(c1)                    # measured adaptive (epoch 1)
    f1 = flops()
    serve_chunk(c2)                    # phase shift: pow2 fallbacks
    _, swap2 = trough_rewarm()
    f2 = flops()
    serve_chunk(c3)                    # measured adaptive (epoch 2)
    f3 = flops()

    # rollback: a poisoned proposal (m2 > m1 is not a well-posed
    # ranking corner) must fail validation BEFORE anything flips
    epoch_before = eng.lattice_epoch()
    lane.propose = lambda: Lattice(corners=((64, 128, 4),))
    rollback_rep = lane.rewarm()
    del lane.propose
    rollback_ok = (not rollback_rep["swapped"]
                   and eng.lattice_epoch() == epoch_before
                   and eng.metrics.lattice_rollbacks >= 1)
    got4 = serve_chunk(c4)             # stream uninterrupted after it
    rollback_ok = rollback_ok and len(got4) == len(c4)

    m = eng.metrics
    sizes = eng.jit_cache_sizes()

    # measured padding waste on the adaptive chunks ONLY (c1 under
    # epoch 1, c3 under epoch 2 — c2 deliberately excluded: it is the
    # phase-shift chunk serving out-of-lattice shapes on the pow2
    # fallback) vs a power-of-two engine serving the SAME chunks
    adaptive_waste = (
        ((f1[1] - f0[1]) + (f3[1] - f2[1]))
        / ((f1[0] - f0[0]) + (f3[0] - f2[0])))
    base = _lattice_engine(max_batch=max_batch,
                           pipeline_depth=pipeline_depth)
    base.warmup(full)
    bf0 = (base.metrics.real_flops, base.metrics.padded_flops)
    base.serve_stream(c1, warmup=False)
    base.serve_stream(c3, warmup=False)
    bf1 = (base.metrics.real_flops, base.metrics.padded_flops)
    pow2_waste = (bf1[1] - bf0[1]) / (bf1[0] - bf0[0])
    base_cpw = base.metrics.compiles_post_warmup
    base.close()

    # per-epoch parity: each chunk vs a COLD engine built directly on
    # that chunk's lattice (the boot pow2 lattice for epoch 0)
    parity_ok = True
    for _, lattice, creqs, got in chunks:
        cold = _lattice_engine(max_batch=max_batch,
                               pipeline_depth=pipeline_depth,
                               lattice=lattice)
        ref = {r.rid: r for r in cold.serve_stream(creqs)}
        parity_ok &= all(_bitwise_same(r, ref[r.rid]) for r in got)
        cold.close()

    out = {
        "n_requests": len(full),
        "chunk": chunk,
        "swaps": m.lattice_swaps,
        "final_epoch": eng.lattice_epoch(),
        "corners": [list(map(list, c[1].corners or ()))
                    for c in chunks if c[1].adaptive][-1:],
        "detector_gated": bool(no_trough["reason"] == "no-trough"
                               and swap1["swapped"] and swap2["swapped"]),
        "compiles_post_warmup": m.compiles_post_warmup,
        "base_compiles_post_warmup": base_cpw,
        "shadow_compiles": m.shadow_compiles,
        "shadow_warm_ms_p50": m._pct(m.shadow_warm_ms)["p50"],
        "executable_calls": m.executable_calls,
        "batches": m.batches,
        "jit_cache_sizes": dict(sizes),
        "lattice_rollbacks": m.lattice_rollbacks,
        "rollback_ok": bool(rollback_ok),
        "parity_ok": bool(parity_ok),
        "stamps_ok": bool(stamps_ok),
        "padding_waste_pow2": round(pow2_waste, 4),
        "padding_waste_adaptive": round(adaptive_waste, 4),
        "waste_improvement": round(pow2_waste / adaptive_waste, 4),
        "epoch_of_chunk": [c[0] for c in chunks],
    }
    eng.close()
    if verbose:
        print(f"lattice: swaps {out['swaps']}  epoch {out['final_epoch']}  "
              f"waste pow2 {out['padding_waste_pow2']} vs adaptive "
              f"{out['padding_waste_adaptive']} "
              f"({out['waste_improvement']}x)  "
              f"compiles_post_warmup {out['compiles_post_warmup']}  "
              f"shadow {out['shadow_compiles']}  "
              f"parity {out['parity_ok']}  rollback {out['rollback_ok']}",
              flush=True)
    save_json("latency_lattice", out)
    return out


def check_lattice(*, quick=False, verbose=True):
    """Adaptive-lattice health gate (kernel_bench-style: AssertionError
    on regression): the traffic-learned lattice must cut measured
    padding waste >= 1.5x vs power-of-two on the skewed mix, across
    >= 2 detector-gated mid-stream swaps with ZERO dispatch-path
    compiles (cache growth only inside shadow-warm windows), one
    dispatch per batch, per-epoch serving bitwise-equal to a cold
    engine on that epoch's lattice, and a poisoned proposal rolling
    back with the served stream uninterrupted."""
    kw = dict(chunk=48) if quick else {}
    res = run_lattice(verbose=verbose, **kw)
    assert res["swaps"] >= 2, (
        f"lattice gate: only {res['swaps']} lattice swaps — the two-phase "
        f"mix should force a re-warm per phase")
    assert res["detector_gated"], (
        "lattice gate: the trough detector did not gate the re-warms "
        "(no-trough refusal then patience-window swap)")
    assert res["waste_improvement"] >= 1.5, (
        f"lattice gate: adaptive lattice only cut padding waste "
        f"{res['waste_improvement']}x (pow2 {res['padding_waste_pow2']} "
        f"vs adaptive {res['padding_waste_adaptive']}) — need >= 1.5x")
    assert res["compiles_post_warmup"] == 0, (
        f"lattice gate: {res['compiles_post_warmup']} dispatch-path "
        f"compiles — the refined contract allows cache growth only in "
        f"shadow-warm windows")
    assert res["shadow_compiles"] >= 1, (
        "lattice gate: no shadow compiles recorded — the swaps served "
        "stale executables?")
    assert all(v == 1 for v in res["jit_cache_sizes"].values()), (
        f"lattice gate: jit cache grew past the warmed executable: "
        f"{res['jit_cache_sizes']}")
    assert res["executable_calls"] == res["batches"], (
        f"lattice gate: {res['executable_calls']} executable calls for "
        f"{res['batches']} batches — a swap added a dispatch")
    assert res["parity_ok"], (
        "lattice gate: post-swap serving diverged bitwise from a cold "
        "engine warmed directly on that epoch's lattice")
    assert res["stamps_ok"], (
        "lattice gate: a served row's lattice_epoch stamp disagreed "
        "with the lattice generation live at its dispatch")
    assert res["rollback_ok"], (
        "lattice gate: poisoned proposal did not roll back to last-good "
        "with the stream uninterrupted")
    print("# lattice acceptance (>= 1.5x waste cut, >= 2 detector-gated "
          "swaps, 0 dispatch-path compiles, hot == cold bitwise per "
          "epoch, poisoned proposal rolls back): PASS")
    return res


def records_lattice(res):
    return [Record(
        name=f"serve_lattice/rewarm/n={res['n_requests']}"
             f"/chunk={res['chunk']}",
        us_per_call=res["shadow_warm_ms_p50"] * 1e3,
        derived={"swaps": res["swaps"],
                 "final_epoch": res["final_epoch"],
                 "waste_pow2": res["padding_waste_pow2"],
                 "waste_adaptive": res["padding_waste_adaptive"],
                 "waste_improvement": res["waste_improvement"],
                 "compiles_post_warmup": res["compiles_post_warmup"],
                 "shadow_compiles": res["shadow_compiles"],
                 "executable_calls": res["executable_calls"],
                 "batches": res["batches"],
                 "parity_ok": res["parity_ok"],
                 "rollback_ok": res["rollback_ok"],
                 "detector_gated": res["detector_gated"]})]


def records(rows):
    return [Record(
        name=f"serve/m1={r['m1']}/K={r['K']}/m2={r['m2']}/B={r['batch']}",
        us_per_call=r["us_total"],
        derived={"us_per_user": round(r["us_per_user"], 1),
                 "within_50ms": r["within_50ms"]})
        for r in rows]


def records_frontier(rows):
    return [Record(
        name=f"serve_frontier/offered={r['offered_qps']}qps"
             f"/frac={r['offered_frac_of_capacity']}",
        us_per_call=r["p99_ms"] * 1e3,
        derived={"p50_ms": r["p50_ms"], "p95_ms": r["p95_ms"],
                 "p99_ms": r["p99_ms"],
                 "achieved_qps": r["achieved_qps"],
                 "deadline_hit_rate": r["deadline_hit_rate"],
                 "deadline_misses": r["deadline_misses"],
                 "queue_lag_ms_last": r["queue_lag_ms_last"],
                 "saturated": r["saturated"],
                 "within_50ms": r["within_50ms"]})
        for r in rows]


def records_engine(rows):
    return [Record(
        name=f"serve_engine/{r['mode']}/n={r['n_requests']}"
             f"/B={r['max_batch']}/wait={r['max_wait_ms']}ms",
        us_per_call=r["p50_ms"] * 1e3,
        derived={"p50_ms": r["p50_ms"], "p95_ms": r["p95_ms"],
                 "p99_ms": r["p99_ms"], "fill": r["fill_rate"],
                 "throughput_rps": r["throughput_rps"],
                 "speedup_vs_sync": r["speedup_vs_sync"],
                 "overlap": r["overlap_ratio"],
                 "perms_match": r["perms_match_baseline"],
                 "recompiles_post_warmup": r["compiles_post_warmup"],
                 "within_50ms": r["within_50ms"]})
        for r in rows]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: small direct sweep, 256-request stream")
    ap.add_argument("--only", default="all",
                    choices=["all", "direct", "engine", "frontier",
                             "deadline", "refresh", "drift", "quant",
                             "fleet", "lattice"])
    ap.add_argument("--frontier", action="store_true",
                    help="also sweep p99 vs offered load (paced open-loop "
                         "Poisson arrivals below/around saturation)")
    ap.add_argument("--trials", type=int, default=None,
                    help="paired throughput trials (default 7; quick 3)")
    ap.add_argument("--json", metavar="OUT", default=None,
                    help="write BENCH_latency_serve.json to OUT (a "
                         "directory, or an explicit *.json path)")
    ap.add_argument("--engine-child", metavar="OUT_JSON",
                    help=argparse.SUPPRESS)     # internal: subprocess mode
    ap.add_argument("--engine-config", metavar="JSON",
                    help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.engine_child:                       # dedicated-core subprocess
        from repro.serving import Scenario
        cfg = json.loads(args.engine_config)
        cfg["depths"] = tuple(cfg["depths"])
        cfg["scenarios"] = tuple(Scenario(**sc) for sc in cfg["scenarios"])
        rows = run_engine(**cfg)
        with open(args.engine_child, "w") as f:
            json.dump(rows, f)
        return

    if args.only == "deadline":
        # the admission health gate writes its own BENCH_deadline.json
        # (never the engine step's BENCH_latency_serve.json — the two
        # run as separate CI steps against the same artifact dir).
        res = check_deadline(quick=args.quick)
        recs = records_deadline(res)
        for rec in recs:
            print(rec.csv())
        if args.json:
            write_bench_json(args.json, "deadline", recs,
                             meta={"quick": args.quick})
        return

    if args.only == "refresh":
        # the refresh-lane health gate writes its own BENCH_refresh.json
        res = check_refresh(quick=args.quick)
        recs = records_refresh(res)
        for rec in recs:
            print(rec.csv())
        if args.json:
            write_bench_json(args.json, "refresh", recs,
                             meta={"quick": args.quick})
        return

    if args.only == "drift":
        # the drift regression gate writes its own BENCH_drift.json
        res = check_drift(quick=args.quick)
        recs = records_drift(res)
        for rec in recs:
            print(rec.csv())
        if args.json:
            write_bench_json(args.json, "drift", recs,
                             meta={"quick": args.quick})
        return

    if args.only == "quant":
        # the quantized-serving gate writes its own BENCH_quant_serve.json
        res = check_quant(quick=args.quick)
        recs = records_quant(res)
        for rec in recs:
            print(rec.csv())
        if args.json:
            write_bench_json(args.json, "quant_serve", recs,
                             meta={"quick": args.quick})
        return

    if args.only == "fleet":
        # the fleet fault-tolerance gate writes its own BENCH_fleet.json
        res = check_fleet(quick=args.quick)
        recs = records_fleet(res)
        for rec in recs:
            print(rec.csv())
        if args.json:
            write_bench_json(args.json, "fleet", recs,
                             meta={"quick": args.quick})
        return

    if args.only == "lattice":
        # the adaptive-lattice gate writes its own BENCH_lattice.json
        res = check_lattice(quick=args.quick)
        recs = records_lattice(res)
        for rec in recs:
            print(rec.csv())
        if args.json:
            write_bench_json(args.json, "lattice", recs,
                             meta={"quick": args.quick})
        return

    all_recs = []
    if args.only in ("all", "direct"):
        kw = (dict(sizes=((1000, 5, 50), (10000, 8, 50)), batches=(1, 64),
                   n_db=2000) if args.quick else {})
        for rec in records(run(**kw)):
            all_recs.append(rec)
            print(rec.csv())
    if args.frontier or args.only == "frontier":
        fkw = (dict(n_requests=192, load_fracs=(0.5, 0.85, 2.0))
               if args.quick else {})
        for rec in records_frontier(run_frontier(**fkw)):
            all_recs.append(rec)
            print(rec.csv())
    engine_rows = None
    if args.only in ("all", "engine"):
        ekw = (dict(n_requests=320, trials=3) if args.quick else {})
        if args.trials is not None:
            ekw["trials"] = args.trials
        engine_rows = run_engine(**ekw)
        for rec in records_engine(engine_rows):
            all_recs.append(rec)
            print(rec.csv())
    if args.json:           # artifact lands even if acceptance exits 1
        write_bench_json(args.json, "latency_serve", all_recs,
                         meta={"quick": args.quick, "only": args.only})
    if engine_rows is not None:
        rows = engine_rows
        piped = [r for r in rows if r["pipeline_depth"] > 0]
        correct = (all(r["perms_match_baseline"] for r in rows)
                   and all(r["compiles_post_warmup"] == 0 for r in rows))
        fast = any(r["speedup_vs_sync"] >= 1.2 for r in piped)
        if not correct:
            print("# pipeline acceptance: FAIL (results diverged or "
                  "recompiled after warmup)")
            raise SystemExit(1)
        if fast:
            print("# pipeline acceptance (>=1.2x, identical perms, "
                  "0 recompiles): PASS")
        else:
            # correctness holds; the speedup shortfall on a loaded CI
            # box is measurement noise, not a result change -> warn.
            print("# pipeline acceptance: WARN — correctness PASS, "
                  f"median speedup "
                  f"{max(r['speedup_vs_sync'] for r in piped):.2f}x < 1.2x "
                  "(noisy/starved host? see docs/benchmarks.md)")


if __name__ == "__main__":
    main()
