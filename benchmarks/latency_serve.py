"""Online-stage latency: the paper's < 50 ms claim, measured.

Two measurement modes:

  * direct: the full online hot path — predict lambda via KNN over the
    train database, adjust scores, take the top-m2 — end to end under
    jit, per (m1, K, m2, batch) problem size. The paper's headline
    (>= 500 objects, >= 5 constraints inside 50 ms on a 2015 quad-core
    CPU) is checked directly.

  * engine: a mixed-shape request stream served through the streaming
    engine (repro.serving): shape-bucketed micro-batching with a
    max-wait deadline and pre-warmed per-bucket executables. Reports
    per-request p50/p95/p99 (enqueue -> result), compliance, bucket
    fill rate, and asserts-by-reporting that steady state compiled
    nothing after warmup. This is the fleet-relevant number: the
    deployed system sees a stream, not a fixed batch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Record, save_json, timed
from repro.core.constraints import dcg_discount
from repro.core.predictors import knn_predict
from repro.core.ranking import rank_given_lambda
from repro.serving import DEFAULT_MIX, ServingEngine, make_stream

LATENCY_BUDGET_MS = 50.0


def _serve_fn(m2):
    @jax.jit
    def serve(X, u, a, b, X_db, lam_db):
        lam_hat = knn_predict(X_db, lam_db, X, k=10)
        return rank_given_lambda(u, a, b, lam_hat, dcg_discount(m2), m2=m2)
    return serve


def run(*, sizes=((1000, 5, 50), (1000, 5, 1000), (10000, 8, 50),
                  (100_000, 5, 50)),
        batches=(1, 512), n_db=10_000, d_cov=20, verbose=True):
    rows = []
    for m1, K, m2 in sizes:
        for B in batches:
            key = jax.random.key(m1 + B)
            ks = jax.random.split(key, 5)
            X = jax.random.normal(ks[0], (B, d_cov))
            u = jax.random.uniform(ks[1], (B, m1), minval=1, maxval=5)
            a = (jax.random.uniform(ks[2], (B, K, m1)) < 0.1).astype(jnp.float32)
            b = 0.03 * jnp.sum(dcg_discount(m2)) * jnp.ones((K,))
            X_db = jax.random.normal(ks[3], (n_db, d_cov))
            lam_db = jnp.abs(jax.random.normal(ks[4], (n_db, K)))
            serve = _serve_fn(m2)
            us = timed(lambda: serve(X, u, a, b, X_db, lam_db).perm, iters=5)
            rows.append({
                "m1": m1, "K": K, "m2": m2, "batch": B,
                "us_total": us, "us_per_user": us / B,
                "within_50ms": bool(us / 1e3 <= LATENCY_BUDGET_MS),
            })
            if verbose:
                r = rows[-1]
                print(f"serve m1={m1:6d} K={K} m2={m2:4d} B={B:4d} "
                      f"{r['us_total']/1e3:8.2f} ms/batch "
                      f"({r['us_per_user']:8.1f} us/user) "
                      f"<=50ms: {r['within_50ms']}", flush=True)
    save_json("latency_serve", rows)
    return rows


def run_engine(*, n_requests=512, max_batch=32, max_wait_ms=2.0,
               scenarios=DEFAULT_MIX, seed=0, verbose=True):
    """Mixed-shape stream through the micro-batching engine."""
    engine = ServingEngine(max_batch=max_batch, max_wait_ms=max_wait_ms)
    requests = make_stream(scenarios, n_requests=n_requests, seed=seed)
    engine.warmup(requests)
    results = engine.serve_stream(requests)
    s = engine.metrics.summary()
    row = {
        "n_requests": len(results),
        "scenarios": [sc.name for sc in scenarios],
        "max_batch": max_batch, "max_wait_ms": max_wait_ms,
        "buckets": s["buckets_used"], "batches": s["batches"],
        "compiles": s["compiles"],
        "compiles_post_warmup": s["compiles_post_warmup"],
        "fill_rate": s["fill_rate"],
        "p50_ms": s["latency_ms"]["p50"],
        "p95_ms": s["latency_ms"]["p95"],
        "p99_ms": s["latency_ms"]["p99"],
        "compliance": s["compliance"],
        "within_50ms": bool(s["latency_ms"]["p99"] <= LATENCY_BUDGET_MS),
    }
    if verbose:
        print(f"engine stream n={row['n_requests']} "
              f"buckets={row['buckets']} batches={row['batches']} "
              f"p50 {row['p50_ms']:6.2f} ms  p95 {row['p95_ms']:6.2f} ms  "
              f"p99 {row['p99_ms']:6.2f} ms  fill {row['fill_rate']:.0%}  "
              f"recompiles {row['compiles_post_warmup']}", flush=True)
    save_json("latency_serve_engine", row)
    return [row]


def records(rows):
    return [Record(
        name=f"serve/m1={r['m1']}/K={r['K']}/m2={r['m2']}/B={r['batch']}",
        us_per_call=r["us_total"],
        derived={"us_per_user": round(r["us_per_user"], 1),
                 "within_50ms": r["within_50ms"]})
        for r in rows]


def records_engine(rows):
    return [Record(
        name=f"serve_engine/n={r['n_requests']}/B={r['max_batch']}"
             f"/wait={r['max_wait_ms']}ms",
        us_per_call=r["p50_ms"] * 1e3,
        derived={"p50_ms": r["p50_ms"], "p95_ms": r["p95_ms"],
                 "p99_ms": r["p99_ms"], "fill": r["fill_rate"],
                 "recompiles_post_warmup": r["compiles_post_warmup"],
                 "within_50ms": r["within_50ms"]})
        for r in rows]


def main():
    for rec in records(run()):
        print(rec.csv())
    for rec in records_engine(run_engine()):
        print(rec.csv())


if __name__ == "__main__":
    main()
