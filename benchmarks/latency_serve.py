"""Online-stage latency: the paper's < 50 ms claim, measured.

Three measurement modes (docs/benchmarks.md walks through them):

  * direct: the full online hot path — predict lambda via KNN over the
    train database, adjust scores, take the top-m2 — end to end under
    jit, per (m1, K, m2, batch) problem size. The paper's headline
    (>= 500 objects, >= 5 constraints inside 50 ms on a 2015 quad-core
    CPU) is checked directly.

  * engine: the same mixed-shape request stream served through the
    streaming engine (repro.serving) twice — synchronous
    (pipeline_depth=0: every flush blocks on its own transfer) and
    pipelined (pipeline_depth=1 double buffering) — reported side by
    side: per-request p50/p95/p99 (enqueue -> result) from a
    deadline-driven run, saturated wall-clock throughput and the
    pipelined/sync speedup from paired interleaved trials, overlap
    ratio, compliance, bucket fill rate, and recompiles after warmup
    (must stay 0). Both modes must produce identical perms per rid
    (verified here, not just in tests). This is the fleet-relevant
    number: the deployed system sees a stream, not a fixed batch.

    Measurement notes (full discussion in docs/benchmarks.md):
    - throughput trials submit back-to-back with a frozen arrival
      clock, so the capacity-flush batch structure is identical across
      modes and trials — the comparison never measures two different
      batchings;
    - trials are paired and interleaved (sync, pipelined, sync, ...)
      and summarized by the median of per-pair ratios, which cancels
      the machine-load drift that dominates small CI boxes;
    - on a CPU-only host the engine comparison runs in a subprocess
      with XLA's intra-op threading disabled
      (--xla_cpu_multi_thread_eigen=false): host/device overlap only
      exists when device execution does not consume every host core,
      which is the deployment reality on any accelerator backend. On
      a 2-core CI container with XLA spanning both cores, sync and
      pipelined are both CPU-bound on identical total work and the
      comparison measures scheduler noise instead of the pipeline.

  * frontier (`--frontier` / `--only frontier`): p99 latency vs OFFERED
    load, paced open-loop — Poisson arrivals at target QPS fractions of
    the measured closed-loop capacity (`serving.traffic.poisson_arrivals`
    + `serve_open_loop`). Closed-loop drivers cannot offer more load
    than the server absorbs, so they never see queueing delay; the
    open-loop sweep reports the tail below saturation and marks the
    rows past it.

Usage:

  python -m benchmarks.latency_serve [--quick] [--frontier]
                                     [--only direct|engine|frontier]
                                     [--json OUT]

`--json OUT` additionally writes a machine-readable
BENCH_latency_serve.json (medians, geometry, backend — see
benchmarks.common.write_bench_json) so the serving-latency trajectory
is trackable across PRs; CI uploads it as an artifact.
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Record, save_json, timed, write_bench_json
from repro.core.constraints import dcg_discount
from repro.core.predictors import knn_predict
from repro.core.ranking import rank_given_lambda
from repro.serving import (
    DEFAULT_MIX,
    ServingEngine,
    make_stream,
    poisson_arrivals,
    serve_open_loop,
)

LATENCY_BUDGET_MS = 50.0

# Engine-comparison child process marker + the dedicated-device-core
# XLA config it runs under (see module docstring).
_CHILD_ENV = "REPRO_ENGINE_BENCH_CHILD"
_DEDICATED_CORE_FLAGS = "--xla_cpu_multi_thread_eigen=false"


def _serve_fn(m2):
    @jax.jit
    def serve(X, u, a, b, X_db, lam_db):
        lam_hat = knn_predict(X_db, lam_db, X, k=10)
        return rank_given_lambda(u, a, b, lam_hat, dcg_discount(m2), m2=m2)
    return serve


def run(*, sizes=((1000, 5, 50), (1000, 5, 1000), (10000, 8, 50),
                  (100_000, 5, 50)),
        batches=(1, 512), n_db=10_000, d_cov=20, verbose=True):
    rows = []
    for m1, K, m2 in sizes:
        for B in batches:
            key = jax.random.key(m1 + B)
            ks = jax.random.split(key, 5)
            X = jax.random.normal(ks[0], (B, d_cov))
            u = jax.random.uniform(ks[1], (B, m1), minval=1, maxval=5)
            a = (jax.random.uniform(ks[2], (B, K, m1)) < 0.1).astype(jnp.float32)
            b = 0.03 * jnp.sum(dcg_discount(m2)) * jnp.ones((K,))
            X_db = jax.random.normal(ks[3], (n_db, d_cov))
            lam_db = jnp.abs(jax.random.normal(ks[4], (n_db, K)))
            serve = _serve_fn(m2)
            us = timed(lambda: serve(X, u, a, b, X_db, lam_db).perm, iters=5)
            rows.append({
                "m1": m1, "K": K, "m2": m2, "batch": B,
                "us_total": us, "us_per_user": us / B,
                "within_50ms": bool(us / 1e3 <= LATENCY_BUDGET_MS),
            })
            if verbose:
                r = rows[-1]
                print(f"serve m1={m1:6d} K={K} m2={m2:4d} B={B:4d} "
                      f"{r['us_total']/1e3:8.2f} ms/batch "
                      f"({r['us_per_user']:8.1f} us/user) "
                      f"<=50ms: {r['within_50ms']}", flush=True)
    save_json("latency_serve", rows)
    return rows


def _saturated_serve(engine, requests):
    """Back-to-back submission with a frozen arrival clock: the
    capacity-flush batch structure is deterministic (identical across
    modes/trials), so wall clock measures execution, not batching."""
    t0 = time.perf_counter()
    out = []
    for r in requests:
        out += engine.submit(r, now=0.0)
    out += engine.drain()
    return out, time.perf_counter() - t0


def _perms_of(results):
    return {r.rid: np.asarray(r.perm) for r in results}


def _perms_equal(a, b):
    return sorted(a) == sorted(b) and all(
        np.array_equal(a[rid], b[rid]) for rid in a)


def _run_engine_inproc(*, n_requests, max_batch, max_wait_ms, scenarios,
                       seed, depths, trials, verbose):
    requests = make_stream(scenarios, n_requests=n_requests, seed=seed)
    engines, rows = {}, []
    for depth in depths:
        engines[depth] = ServingEngine(max_batch=max_batch,
                                       max_wait_ms=max_wait_ms,
                                       pipeline_depth=depth)
        engines[depth].warmup(requests)

    # latency profile: one deadline-driven pass (real arrival clock),
    # metrics snapshotted before the throughput trials pollute them.
    latency, perms = {}, {}
    for depth, eng in engines.items():
        results = eng.serve_stream(requests)
        latency[depth] = eng.metrics.summary()
        perms[depth] = _perms_of(results)

    # throughput: paired interleaved trials over the frozen-clock
    # saturated stream; per-pair ratios cancel machine-load drift.
    walls = {d: [] for d in depths}
    diverged = set()
    for _ in range(max(1, trials)):
        for depth, eng in engines.items():
            out, wall = _saturated_serve(eng, requests)
            walls[depth].append(wall)
            if not _perms_equal(_perms_of(out), perms[depths[0]]):
                diverged.add(depth)
    base = depths[0]
    for depth in depths:
        s = latency[depth]
        ratios = sorted(ws / wp for ws, wp in zip(walls[base], walls[depth]))
        wall_med = statistics.median(walls[depth])
        identical = (_perms_equal(perms[depth], perms[base])
                     and depth not in diverged)
        rows.append({
            "mode": "sync" if depth == 0 else f"pipelined(depth={depth})",
            "pipeline_depth": depth,
            "n_requests": n_requests,
            "scenarios": [sc.name for sc in scenarios],
            "max_batch": max_batch, "max_wait_ms": max_wait_ms,
            "trials": trials,
            "buckets": s["buckets_used"],
            "compiles_post_warmup": s["compiles_post_warmup"],
            "fill_rate": s["fill_rate"],
            "p50_ms": s["latency_ms"]["p50"],
            "p95_ms": s["latency_ms"]["p95"],
            "p99_ms": s["latency_ms"]["p99"],
            "wall_median_s": round(wall_med, 4),
            "throughput_rps": round(n_requests / wall_med, 1),
            "speedup_vs_sync": round(statistics.median(ratios), 2),
            "speedup_spread": [round(ratios[0], 2), round(ratios[-1], 2)],
            "overlap_ratio": s["pipeline"]["overlap_ratio"],
            "queue_depth_max": s["pipeline"]["queue_depth_max"],
            "perms_match_baseline": bool(identical),
            "compliance": s["compliance"],
            "within_50ms": bool(s["latency_ms"]["p99"] <= LATENCY_BUDGET_MS),
        })
        if verbose:
            r = rows[-1]
            print(f"engine[{r['mode']:18s}] n={n_requests} "
                  f"p50 {r['p50_ms']:6.2f} p95 {r['p95_ms']:6.2f} "
                  f"p99 {r['p99_ms']:6.2f} ms  "
                  f"{r['throughput_rps']:7.1f} req/s "
                  f"(median {r['speedup_vs_sync']:.2f}x, spread "
                  f"{r['speedup_spread'][0]:.2f}-{r['speedup_spread'][1]:.2f})"
                  f"  overlap {r['overlap_ratio']:.2f}  "
                  f"perms_match {r['perms_match_baseline']}  "
                  f"recompiles {r['compiles_post_warmup']}", flush=True)
    for eng in engines.values():
        eng.close()
    return rows


def run_engine(*, n_requests=512, max_batch=32, max_wait_ms=2.0,
               scenarios=DEFAULT_MIX, seed=0, depths=(0, 1), trials=7,
               dedicated_device_core=True, verbose=True):
    """Mixed-shape stream through the engine, sync vs pipelined.

    depths[0] is the baseline (0 = synchronous); every other depth is
    reported with its paired-median speedup over that baseline and
    checked for identical perms per rid.

    With dedicated_device_core=True (default) on a CPU backend, the
    whole comparison re-runs in a subprocess with XLA intra-op
    threading disabled so device execution models an accelerator that
    does not consume host cores (both modes run under the SAME flags;
    see module docstring). Pass False to measure in-process under
    whatever XLA config is already loaded.
    """
    use_child = (dedicated_device_core
                 and not os.environ.get(_CHILD_ENV)
                 and jax.default_backend() == "cpu")
    if not use_child:
        rows = _run_engine_inproc(
            n_requests=n_requests, max_batch=max_batch,
            max_wait_ms=max_wait_ms, scenarios=scenarios, seed=seed,
            depths=depths, trials=trials, verbose=verbose)
        if not os.environ.get(_CHILD_ENV):
            save_json("latency_serve_engine", rows)
        return rows

    cfg = dict(n_requests=n_requests, max_batch=max_batch,
               max_wait_ms=max_wait_ms, seed=seed, depths=list(depths),
               trials=trials, verbose=verbose,
               scenarios=[vars(sc) for sc in scenarios])
    env = dict(os.environ)
    env[_CHILD_ENV] = "1"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " "
                        + _DEDICATED_CORE_FLAGS).strip()
    with tempfile.NamedTemporaryFile("r", suffix=".json") as out_f:
        subprocess.run(
            [sys.executable, "-m", "benchmarks.latency_serve",
             "--engine-child", out_f.name, "--engine-config",
             json.dumps(cfg)],
            env=env, check=True)
        rows = json.load(open(out_f.name))
    save_json("latency_serve_engine", rows)
    return rows


def run_frontier(*, n_requests=512,
                 load_fracs=(0.25, 0.5, 0.7, 0.85, 1.0, 1.2, 2.0),
                 max_batch=32, max_wait_ms=2.0, scenarios=DEFAULT_MIX,
                 seed=0, pipeline_depth=1, verbose=True):
    """The latency/throughput frontier: p99 vs OFFERED load, paced
    open-loop (Poisson arrivals at a target QPS — serving.traffic).

    A closed-loop (back-to-back) driver can only ever measure the
    saturated operating point; real deployments run below saturation
    and care about the tail there. The sweep first probes saturated
    capacity with one closed-loop pass, then offers Poisson traffic at
    fractions of it. Below saturation p99 is batching + service time
    (deadline-bounded); past it, queueing delay dominates and achieved
    throughput caps at capacity — `saturated` marks those rows.
    """
    requests = make_stream(scenarios, n_requests=n_requests, seed=seed)

    def fresh_engine():
        eng = ServingEngine(max_batch=max_batch, max_wait_ms=max_wait_ms,
                            pipeline_depth=pipeline_depth)
        eng.warmup(requests)
        return eng

    probe = fresh_engine()
    _, wall = _saturated_serve(probe, requests)
    probe.close()
    capacity = n_requests / wall
    if verbose:
        print(f"frontier: closed-loop capacity ~ {capacity:.1f} req/s",
              flush=True)

    rows = []
    for frac in load_fracs:
        qps = capacity * frac
        eng = fresh_engine()
        arrivals = poisson_arrivals(n_requests, qps, seed=seed + 1)
        results, ol = serve_open_loop(eng, requests, arrivals)
        s = eng.metrics.summary()
        eng.close()
        # Saturation telltale: submission falls behind its schedule.
        # Below capacity, lag is bounded sleep-granularity/scheduler
        # noise (a few ms on a loaded host); past it, lag accumulates
        # over the stream. Threshold: 10 arrival slots or 5 ms,
        # whichever is larger, by the LAST submission.
        lag_thresh_ms = max(5.0, 1e4 / qps)
        saturated = ol["lag_ms"]["last"] > lag_thresh_ms
        rows.append({
            "offered_qps": round(qps, 1),
            "offered_frac_of_capacity": frac,
            "achieved_qps": round(ol["achieved_qps"], 1),
            "capacity_qps": round(capacity, 1),
            "n_requests": n_requests,
            "max_batch": max_batch, "max_wait_ms": max_wait_ms,
            "pipeline_depth": pipeline_depth,
            "p50_ms": s["latency_ms"]["p50"],
            "p95_ms": s["latency_ms"]["p95"],
            "p99_ms": s["latency_ms"]["p99"],
            "submit_lag_ms_p99": round(ol["lag_ms"]["p99"], 3),
            "submit_lag_ms_last": round(ol["lag_ms"]["last"], 3),
            "fill_rate": s["fill_rate"],
            "compiles_post_warmup": s["compiles_post_warmup"],
            "saturated": bool(saturated),
            "within_50ms": bool(s["latency_ms"]["p99"] <= LATENCY_BUDGET_MS),
        })
        if verbose:
            r = rows[-1]
            print(f"frontier offered {r['offered_qps']:8.1f} req/s "
                  f"({frac:4.2f}x cap)  achieved {r['achieved_qps']:8.1f}  "
                  f"p50 {r['p50_ms']:6.2f}  p95 {r['p95_ms']:6.2f}  "
                  f"p99 {r['p99_ms']:7.2f} ms  lag_last "
                  f"{r['submit_lag_ms_last']:7.2f} ms  "
                  f"saturated {r['saturated']}", flush=True)
    save_json("latency_frontier", rows)
    return rows


def records(rows):
    return [Record(
        name=f"serve/m1={r['m1']}/K={r['K']}/m2={r['m2']}/B={r['batch']}",
        us_per_call=r["us_total"],
        derived={"us_per_user": round(r["us_per_user"], 1),
                 "within_50ms": r["within_50ms"]})
        for r in rows]


def records_frontier(rows):
    return [Record(
        name=f"serve_frontier/offered={r['offered_qps']}qps"
             f"/frac={r['offered_frac_of_capacity']}",
        us_per_call=r["p99_ms"] * 1e3,
        derived={"p50_ms": r["p50_ms"], "p95_ms": r["p95_ms"],
                 "p99_ms": r["p99_ms"],
                 "achieved_qps": r["achieved_qps"],
                 "saturated": r["saturated"],
                 "within_50ms": r["within_50ms"]})
        for r in rows]


def records_engine(rows):
    return [Record(
        name=f"serve_engine/{r['mode']}/n={r['n_requests']}"
             f"/B={r['max_batch']}/wait={r['max_wait_ms']}ms",
        us_per_call=r["p50_ms"] * 1e3,
        derived={"p50_ms": r["p50_ms"], "p95_ms": r["p95_ms"],
                 "p99_ms": r["p99_ms"], "fill": r["fill_rate"],
                 "throughput_rps": r["throughput_rps"],
                 "speedup_vs_sync": r["speedup_vs_sync"],
                 "overlap": r["overlap_ratio"],
                 "perms_match": r["perms_match_baseline"],
                 "recompiles_post_warmup": r["compiles_post_warmup"],
                 "within_50ms": r["within_50ms"]})
        for r in rows]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: small direct sweep, 256-request stream")
    ap.add_argument("--only", default="all",
                    choices=["all", "direct", "engine", "frontier"])
    ap.add_argument("--frontier", action="store_true",
                    help="also sweep p99 vs offered load (paced open-loop "
                         "Poisson arrivals below/around saturation)")
    ap.add_argument("--trials", type=int, default=None,
                    help="paired throughput trials (default 7; quick 3)")
    ap.add_argument("--json", metavar="OUT", default=None,
                    help="write BENCH_latency_serve.json to OUT (a "
                         "directory, or an explicit *.json path)")
    ap.add_argument("--engine-child", metavar="OUT_JSON",
                    help=argparse.SUPPRESS)     # internal: subprocess mode
    ap.add_argument("--engine-config", metavar="JSON",
                    help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.engine_child:                       # dedicated-core subprocess
        from repro.serving import Scenario
        cfg = json.loads(args.engine_config)
        cfg["depths"] = tuple(cfg["depths"])
        cfg["scenarios"] = tuple(Scenario(**sc) for sc in cfg["scenarios"])
        rows = run_engine(**cfg)
        with open(args.engine_child, "w") as f:
            json.dump(rows, f)
        return

    all_recs = []
    if args.only in ("all", "direct"):
        kw = (dict(sizes=((1000, 5, 50), (10000, 8, 50)), batches=(1, 64),
                   n_db=2000) if args.quick else {})
        for rec in records(run(**kw)):
            all_recs.append(rec)
            print(rec.csv())
    if args.frontier or args.only == "frontier":
        fkw = (dict(n_requests=192, load_fracs=(0.5, 0.85, 2.0))
               if args.quick else {})
        for rec in records_frontier(run_frontier(**fkw)):
            all_recs.append(rec)
            print(rec.csv())
    engine_rows = None
    if args.only in ("all", "engine"):
        ekw = (dict(n_requests=320, trials=3) if args.quick else {})
        if args.trials is not None:
            ekw["trials"] = args.trials
        engine_rows = run_engine(**ekw)
        for rec in records_engine(engine_rows):
            all_recs.append(rec)
            print(rec.csv())
    if args.json:           # artifact lands even if acceptance exits 1
        write_bench_json(args.json, "latency_serve", all_recs,
                         meta={"quick": args.quick, "only": args.only})
    if engine_rows is not None:
        rows = engine_rows
        piped = [r for r in rows if r["pipeline_depth"] > 0]
        correct = (all(r["perms_match_baseline"] for r in rows)
                   and all(r["compiles_post_warmup"] == 0 for r in rows))
        fast = any(r["speedup_vs_sync"] >= 1.2 for r in piped)
        if not correct:
            print("# pipeline acceptance: FAIL (results diverged or "
                  "recompiled after warmup)")
            raise SystemExit(1)
        if fast:
            print("# pipeline acceptance (>=1.2x, identical perms, "
                  "0 recompiles): PASS")
        else:
            # correctness holds; the speedup shortfall on a loaded CI
            # box is measurement noise, not a result change -> warn.
            print("# pipeline acceptance: WARN — correctness PASS, "
                  f"median speedup "
                  f"{max(r['speedup_vs_sync'] for r in piped):.2f}x < 1.2x "
                  "(noisy/starved host? see docs/benchmarks.md)")


if __name__ == "__main__":
    main()
