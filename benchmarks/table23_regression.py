"""Paper Tables 2-3: per-strategy performance deltas vs KNeighbors.

The paper fits OLS with HC3 errors; with our synthetic replication the
point estimates are what matters — we report, per dataset, the mean
difference vs the 'knn' strategy in (log10 time, compliance, utility),
aggregated over scenarios, plus per-size effects vs the top-50 scenario.
Reads the fig2 sweep results (benchmarks/fig2_strategies.py).
"""

from __future__ import annotations

import math
from collections import defaultdict

from benchmarks.common import Record, load_json
from benchmarks.fig2_strategies import run as run_fig2


def build_table(rows) -> dict:
    by_ds: dict = defaultdict(lambda: defaultdict(list))
    for r in rows:
        by_ds[r["dataset"]][(r["strategy"], r["m2"])].append(r)

    tables = {}
    for ds, cells in by_ds.items():
        m2s = sorted({m2 for (_, m2) in cells})
        strategies = sorted({s for (s, _) in cells})

        def mean(strategy, key):
            vals = [r[key] for m2 in m2s for r in cells[(strategy, m2)]]
            return sum(vals) / len(vals)

        table = {}
        for s in strategies:
            if s == "knn":
                continue
            table[f"{s}_vs_knn"] = {
                "log10_time_delta": round(
                    math.log10(mean(s, "us_per_user"))
                    - math.log10(mean("knn", "us_per_user")), 3),
                "compliance_delta": round(
                    mean(s, "compliance") - mean("knn", "compliance"), 3),
                "utility_delta": round(
                    mean(s, "utility") - mean("knn", "utility"), 3),
            }
        base_m2 = m2s[0]

        def mean_m2(m2, key):
            vals = [r[key] for s in strategies for r in cells[(s, m2)]]
            return sum(vals) / len(vals)

        for m2 in m2s[1:]:
            table[f"size_{m2}_vs_{base_m2}"] = {
                "log10_time_delta": round(
                    math.log10(mean_m2(m2, "us_per_user"))
                    - math.log10(mean_m2(base_m2, "us_per_user")), 3),
                "compliance_delta": round(
                    mean_m2(m2, "compliance") - mean_m2(base_m2, "compliance"), 3),
                "utility_delta": round(
                    mean_m2(m2, "utility") - mean_m2(base_m2, "utility"), 3),
            }
        tables[ds] = table
    return tables


def records(tables) -> list[Record]:
    out = []
    for ds, table in tables.items():
        for row_name, vals in table.items():
            out.append(Record(
                name=f"table23/{ds}/{row_name}", us_per_call=float("nan"),
                derived=vals))
    return out


def main():
    rows = load_json("fig2")
    if rows is None:
        rows = run_fig2()
    tables = build_table(rows)
    for rec in records(tables):
        print(rec.csv())


if __name__ == "__main__":
    main()
