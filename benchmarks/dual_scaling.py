"""Dual-solver scaling: wall time per user vs (m1, K), batched.

The offline stage of Algorithm 1. The paper's CBC solver scales
super-linearly in m1 and K and is serial per user; the batched
subgradient solver is O(iters * (m1 K + m1 log m1)) per user and
data-parallel across the batch — this benchmark quantifies the per-user
amortized cost on CPU (on a pod slice, divide by the batch sharding).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import Record, save_json, timed
from repro.core.constraints import dcg_discount
from repro.core.dual_solver import solve_dual_batch


def run(*, batch=64, iters=300, sweeps=((100, 5), (1000, 5), (1000, 8),
                                        (10000, 5)), verbose=True):
    rows = []
    for m1, K in sweeps:
        m2 = min(m1, 50)
        key = jax.random.key(m1 + K)
        u = jax.random.uniform(key, (batch, m1), minval=1.0, maxval=5.0)
        a = (jax.random.uniform(jax.random.fold_in(key, 1), (batch, K, m1))
             < 0.1).astype(jnp.float32)
        gamma = dcg_discount(m2)
        b = 0.05 * jnp.sum(gamma) * jnp.ones((K,))

        def call():
            return solve_dual_batch(u, a, b, gamma, m2=m2, num_iters=iters)

        us = timed(lambda: call().lam, iters=3)
        sol = call()
        rows.append({
            "m1": m1, "K": K, "batch": batch, "iters": iters,
            "us_per_user": us / batch,
            "compliance": float(sol.compliant.mean()),
            "mean_gap": float(jnp.nanmean(sol.gap)),
        })
        if verbose:
            r = rows[-1]
            print(f"dual m1={m1:6d} K={K} {r['us_per_user']/1e3:8.2f} ms/user "
                  f"compl {r['compliance']:.2f}", flush=True)
    save_json("dual_scaling", rows)
    return rows


def records(rows):
    return [Record(name=f"dual_scaling/m1={r['m1']}/K={r['K']}",
                   us_per_call=r["us_per_user"],
                   derived={"compliance": round(r["compliance"], 3)})
            for r in rows]


def main():
    for rec in records(run()):
        print(rec.csv())


if __name__ == "__main__":
    main()
