"""Paper Figure 2: computation time vs constraint compliance per strategy.

Sweeps (dataset x scenario m2 x strategy) on synthetic matched-statistics
data (DESIGN.md §2): strategies none / optimal / mean / knn (+
beyond-paper linear), scenarios rank top-{50, 500, 1000} of m1 = 1000
candidates. Reports per-user computation time (batched program wall time
/ users — the deployment model; the paper times a per-user solver loop),
compliance probability, and mean utility on holdout users.

Defaults are sized for the CPU container; --full approaches paper scale.
"""

from __future__ import annotations

import argparse
import time

import jax

from benchmarks.common import Record, save_json, timed
from repro.core.ranking import fit_pipeline, rank_with_strategy
from repro.data.synthetic import build_experiment

STRATEGIES = ("none", "optimal", "mean", "knn", "linear")


def run(*, n_users=500, n_items=8000, m1=1000, scenarios=(50, 500, 1000),
        datasets=("movielens", "yow"), dual_iters=300, seed=0,
        recommender_epochs=3, verbose=True) -> list[dict]:
    rows = []
    for dataset in datasets:
        for m2 in scenarios:
            exp = build_experiment(
                jax.random.key(seed), dataset=dataset, n_users=n_users,
                n_items=n_items, m1=m1, m2=m2,
                recommender_epochs=recommender_epochs)
            u_tr, X_tr, a_tr = exp.split("train")
            u_te, X_te, a_te = exp.split("test")
            n_te = int(u_te.shape[0])
            pipe = fit_pipeline(X_tr, u_tr, a_tr, exp.b, exp.gamma,
                                m2=exp.m2, num_iters=dual_iters)
            for strat in STRATEGIES:
                def call():
                    return rank_with_strategy(
                        pipe, strat, X_te, u_te, a_te, exp.b,
                        dual_iters=dual_iters)
                us = timed(lambda: call().perm, iters=3)
                out = call()
                row = {
                    "dataset": dataset, "m2": m2, "strategy": strat,
                    "us_per_user": us / n_te,
                    "compliance": float(out.compliant.mean()),
                    "utility": float(out.utility.mean()),
                    "n_te": n_te, "m1": m1,
                }
                rows.append(row)
                if verbose:
                    print(f"fig2 {dataset} m2={m2} {strat:8s} "
                          f"{row['us_per_user']/1e3:9.3f} ms/user "
                          f"compl {row['compliance']:.2f} "
                          f"util {row['utility']:.1f}", flush=True)
    save_json("fig2", rows)
    return rows


def records(rows) -> list[Record]:
    out = []
    for r in rows:
        out.append(Record(
            name=f"fig2/{r['dataset']}/m2={r['m2']}/{r['strategy']}",
            us_per_call=r["us_per_user"],
            derived={"compliance": round(r["compliance"], 3),
                     "utility": round(r["utility"], 2)},
        ))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale users (slower)")
    args = ap.parse_args()
    kw = dict(n_users=1000, n_items=20000) if args.full else {}
    rows = run(**kw)
    for rec in records(rows):
        print(rec.csv())


if __name__ == "__main__":
    main()
