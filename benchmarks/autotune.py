"""Per-geometry kernel autotuner for the serving bucket lattice.

The fused predict+rank+audit kernel has three tile knobs (TILE_B batch
tile, TILE_M candidate tile, DB_SLAB db-sweep slab) plus the quantized
db mode — and the winning combination depends on the bucket geometry
(m1/m2/K/batch) and the backend. This tool sweeps the candidate grid
per geometry, picks the fastest configuration, and caches the winners
as a JSON table next to the bucket lattice
(serving.buckets.DEFAULT_AUTOTUNE_PATH). A ServingEngine constructed
with ``autotune_table=`` (a dict or the JSON path) applies each
bucket's entry when it builds that bucket's executable — warmup
compiles straight into the tuned tiles.

On TPU the sweep times the real fused dispatcher per combination.
Off-TPU it degrades to a STRUCTURAL smoke: interpret-mode Pallas wall
time is meaningless, so every candidate is validated for shape/tiling
legality through the XLA oracle path once, the default combination is
recorded as the winner, and the table/engine round-trip is exercised
exactly as on TPU (the CI gate is the plumbing, not the numbers).

    python benchmarks/autotune.py [--quick] [--json OUT] [--table PATH]

check_autotune() is the CI gate: the table round-trips through
save/load bit-for-bit and an engine warmed from it applies at least
one entry (engine.autotuned_buckets >= 1) with zero post-warmup
recompiles.
"""

from __future__ import annotations

import argparse
import itertools
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import jax
import jax.numpy as jnp
import numpy as np

from common import Record, timed, write_bench_json
from repro.core.predictors import KNNLambdaPredictor
from repro.kernels.ops import predict_rank_audited
from repro.serving import (
    DEFAULT_AUTOTUNE_PATH,
    Lattice,
    ServingEngine,
    Scenario,
    bucket_for,
    geometry_key,
    load_autotune_table,
    make_stream,
    save_autotune_table,
)

# the candidate grid: modest on purpose — the table is per geometry,
# so the sweep runs |grid| x |geometries| end-to-end dispatches
TILE_B_CAND = (8, 16, 32)
TILE_M_CAND = (128, 256)
TILE_N_CAND = (256, 512)
QUANT_CAND = ("off", "int8")

# the geometries swept by default: the bucket lattice corners the
# serving scenarios actually hit (see serving.buckets)
GEOMETRIES = (
    dict(m1=128, m2=8, K=4, batch=8),
    dict(m1=256, m2=16, K=8, batch=32),
)

N_TRAIN, D_COV = 1024, 16


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _problem(geom: dict, *, seed: int = 0):
    """Synthetic batch + fitted predictor at one bucket geometry."""
    rng = np.random.default_rng(seed)
    B, m1, m2, K = geom["batch"], geom["m1"], geom["m2"], geom["K"]
    X_db = rng.normal(size=(N_TRAIN, D_COV)).astype(np.float32)
    lam_db = np.abs(rng.normal(size=(N_TRAIN, K))).astype(np.float32)
    pred = KNNLambdaPredictor.fit(X_db, lam_db, k=10)
    X = jnp.asarray(rng.normal(size=(B, D_COV)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(B, m1)).astype(np.float32))
    a = jnp.asarray(
        (rng.uniform(size=(B, K, m1)) < 0.3).astype(np.float32))
    b = jnp.asarray(
        0.05 * np.ones((B, K), np.float32))
    gamma = jnp.asarray(
        (1.0 / np.log2(np.arange(2, m2 + 2)))[None, :]
        .repeat(B, 0).astype(np.float32))
    return pred, (X, u, a, b, gamma, m2)


def _candidates(geom: dict):
    for tb, tm, tn, q in itertools.product(
            TILE_B_CAND, TILE_M_CAND, TILE_N_CAND, QUANT_CAND):
        if tb > geom["batch"] or tm > geom["m1"]:
            continue
        yield {"tile_b": tb, "tile_m": tm, "tile_n": tn, "quant": q}


def _run_one(pred, prob, cand: dict, *, tpu: bool) -> float:
    """One candidate's figure of merit (us/call on TPU, nan off-TPU
    after a structural validation pass)."""
    X, u, a, b, gamma, m2 = prob
    p = (pred.quantized(mode=cand["quant"], slab=cand["tile_n"])
         if cand["quant"] != "off" else pred)

    def call():
        return predict_rank_audited(
            X, p, u, a, b, gamma, m2=m2,
            use_kernel=True if tpu else False,
            tile_b=cand["tile_b"], tile_m=cand["tile_m"],
            tile_n=cand["tile_n"])

    if tpu:
        return timed(call, warmup=2, iters=5)
    out = call()                      # structural smoke: must execute
    jax.block_until_ready(out.perm)
    return float("nan")


def run_autotune(*, geometries=GEOMETRIES, quick: bool = False,
                 table_path: str = DEFAULT_AUTOTUNE_PATH,
                 verbose: bool = True) -> dict:
    """Sweep the candidate grid per geometry, write the winner table,
    and prove the engine round-trip. Returns the report dict."""
    tpu = _on_tpu()
    if quick:
        geometries = geometries[:1]
    table: dict[str, dict] = {}
    rows = []
    for geom in geometries:
        bucket = bucket_for(tag="arch", **geom)
        # key on the ACTUAL tuned geometry — (m1, m2, K, B, d_cov) —
        # not the bucket's position in whatever lattice is live, so a
        # lattice swap re-resolves the same entry (exact key, or the
        # nearest covering geometry via serving.resolve_autotune)
        key = geometry_key(bucket, d_cov=D_COV)
        pred, prob = _problem(geom)
        best, best_us = None, float("inf")
        n_cand = 0
        for cand in _candidates(geom):
            # off-TPU: validate every candidate structurally, but only
            # ONE quant repack per mode is interesting — skip the rest
            # of the grid for speed (the tiles are validated by the
            # first combo that carries them)
            if not tpu and quick and n_cand >= 4:
                break
            us = _run_one(pred, prob, cand, tpu=tpu)
            n_cand += 1
            if tpu and us < best_us:
                best, best_us = cand, us
        if best is None:              # off-TPU: defaults win by decree
            best, best_us = {"tile_b": min(8, geom["batch"]),
                             "tile_m": min(128, geom["m1"]),
                             "tile_n": 512, "quant": "int8"}, float("nan")
        table[key] = best
        rows.append({"key": key, "us": best_us, "candidates": n_cand,
                     **best})
        if verbose:
            print(f"autotune[{key}] -> {best} "
                  f"({'%.1f us' % best_us if tpu else 'structural'}, "
                  f"{n_cand} candidates)", flush=True)

    path = save_autotune_table(table, table_path)
    loaded = load_autotune_table(table_path)
    roundtrip_ok = loaded == table

    # engine warms from the table: every registered bucket whose
    # geometry has an entry gets its tiles, with zero recompiles after
    sc = Scenario(name="autotune", m1=geometries[0]["m1"],
                  m2=geometries[0]["m2"], K=geometries[0]["K"],
                  tag="arch", d_cov=D_COV, m1_jitter=0.0)
    reqs = make_stream([sc], n_requests=geometries[0]["batch"] * 2,
                       seed=3)
    rng = np.random.default_rng(4)
    pred = KNNLambdaPredictor.fit(
        rng.normal(size=(64, D_COV)).astype(np.float32),
        np.abs(rng.normal(size=(64, geometries[0]["K"])))
        .astype(np.float32), k=5)
    eng = ServingEngine(max_batch=geometries[0]["batch"],
                        pipeline_depth=0, autotune_table=path)
    eng.register_predictor("arch", pred, d_cov=D_COV)
    eng.warmup(reqs)
    res = eng.serve_stream(reqs, warmup=False)
    engine_ok = (eng.autotuned_buckets >= 1
                 and eng.metrics.compiles_post_warmup == 0
                 and len(res) == len(reqs))

    # geometry keys must survive lattice swaps: re-warm onto an
    # adaptive lattice whose corner IS the tuned geometry (epoch 1 —
    # the exact key resolves again), then onto a shifted corner the
    # table does not cover (epoch 2 — degrades to defaults, never to a
    # dispatch-path compile), serving the same stream after each flip.
    g = geometries[0]
    tuned_before = eng.autotuned_buckets
    eng.rewarm_lattice(Lattice(corners=((g["m1"], g["m2"], g["K"]),)))
    res1 = eng.serve_stream(reqs, warmup=False)
    eng.rewarm_lattice(
        Lattice(corners=((g["m1"] + 64, g["m2"], g["K"]),)))
    res2 = eng.serve_stream(reqs, warmup=False)
    swap_ok = (eng.lattice_epoch() == 2
               and eng.autotuned_buckets >= tuned_before
               and eng.metrics.compiles_post_warmup == 0
               and len(res1) == len(reqs) and len(res2) == len(reqs))
    eng.close()

    out = {"backend": jax.default_backend(), "tpu": tpu,
           "table_path": path, "table": table, "rows": rows,
           "roundtrip_ok": bool(roundtrip_ok),
           "engine_ok": bool(engine_ok),
           "swap_ok": bool(swap_ok)}
    if verbose:
        print(f"# table -> {path} (roundtrip {roundtrip_ok}, engine "
              f"warmed with {eng.autotuned_buckets} tuned bucket(s): "
              f"{engine_ok})")
    return out


def check_autotune(*, quick: bool = True, verbose: bool = True) -> dict:
    """CI gate (AssertionError on regression): table round-trips
    bit-for-bit and an engine warmed from it applies >= 1 entry with
    zero post-warmup recompiles."""
    res = run_autotune(quick=quick, verbose=verbose)
    assert res["roundtrip_ok"], (
        f"autotune gate: table did not round-trip through "
        f"{res['table_path']}")
    assert res["engine_ok"], (
        "autotune gate: engine did not warm from the saved table "
        "(no tuned bucket, a post-warmup recompile, or a dropped "
        "request)")
    assert res["swap_ok"], (
        "autotune gate: tuned geometry keys did not survive two "
        "lattice swaps (lost entry, dispatch-path compile, or a "
        "dropped request)")
    print("# autotune acceptance (JSON round-trip, engine warms from "
          "table, keys survive 2 lattice swaps, 0 recompiles): PASS")
    return res


def records(res):
    return [Record(
        name=f"autotune/{r['key']}",
        us_per_call=r["us"],
        derived={k: r[k] for k in
                 ("tile_b", "tile_m", "tile_n", "quant", "candidates")})
        for r in res["rows"]]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="first geometry only, truncated off-TPU grid")
    ap.add_argument("--table", default=DEFAULT_AUTOTUNE_PATH,
                    help="where to write the winner table")
    ap.add_argument("--json", metavar="OUT", default=None,
                    help="write BENCH_autotune.json to OUT (a directory"
                         ", or an explicit *.json path)")
    args = ap.parse_args()
    t0 = time.perf_counter()
    res = run_autotune(quick=args.quick, table_path=args.table)
    assert res["roundtrip_ok"] and res["engine_ok"] and res["swap_ok"], res
    if args.json:
        write_bench_json(args.json, "autotune", records(res),
                         meta={"quick": args.quick,
                               "table_path": res["table_path"]})
    print(f"# autotune done in {time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
