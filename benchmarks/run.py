"""Benchmark orchestrator: one suite per paper table/figure + roofline.

  python -m benchmarks.run [--quick] [--only fig2,dual,...]

Prints ``name,us_per_call,derived`` CSV (one line per measurement) and
persists raw JSON under experiments/bench/.
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweeps (CI-sized)")
    ap.add_argument("--only", default="all",
                    help="comma list: fig2,table23,dual,serve,kernels,roofline")
    args = ap.parse_args()
    which = set(args.only.split(",")) if args.only != "all" else {
        "fig2", "table23", "dual", "serve", "kernels", "roofline"}

    print("name,us_per_call,derived")
    failures = []

    def section(name, fn):
        if name not in which:
            return
        try:
            for rec in fn():
                print(rec.csv(), flush=True)
        except Exception as e:
            failures.append((name, e))
            traceback.print_exc()

    if "fig2" in which or "table23" in which:
        try:
            from benchmarks import fig2_strategies
            kw = (dict(n_users=60, n_items=2000, scenarios=(50, 500),
                       dual_iters=200) if args.quick else {})
            rows = fig2_strategies.run(**kw)
            section("fig2", lambda: fig2_strategies.records(rows))

            from benchmarks import table23_regression
            tables = table23_regression.build_table(rows)
            section("table23", lambda: table23_regression.records(tables))
        except Exception as e:
            failures.append(("fig2", e))
            traceback.print_exc()

    if "dual" in which:
        from benchmarks import dual_scaling
        kw = (dict(batch=32, iters=200, sweeps=((100, 5), (1000, 5)))
              if args.quick else {})
        section("dual", lambda: dual_scaling.records(dual_scaling.run(**kw)))

    if "serve" in which:
        from benchmarks import latency_serve
        kw = (dict(sizes=((1000, 5, 50), (10000, 8, 50)), batches=(1, 64),
                   n_db=2000) if args.quick else {})
        section("serve", lambda: latency_serve.records(latency_serve.run(**kw)))
        ekw = dict(n_requests=320, trials=3) if args.quick else {}
        section("serve", lambda: latency_serve.records_engine(
            latency_serve.run_engine(**ekw)))

    if "kernels" in which:
        from benchmarks import kernel_bench
        section("kernels", lambda: kernel_bench.records(
            kernel_bench.run(quick=args.quick)))

    if "roofline" in which:
        from benchmarks import roofline_report
        recs = []
        for mesh in ("single", "multi"):
            rows = roofline_report.build_table(mesh)
            recs += roofline_report.records(rows, mesh)
        section("roofline", lambda: recs)

    if failures:
        print(f"# {len(failures)} benchmark sections failed", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
