"""Train a small LM end to end through the fault-tolerant runner.

Demonstrates the full training substrate: data pipeline, Adam, global-
norm clipping, checkpoint/restart (with an injected mid-run failure to
prove recovery), deterministic batch replay.

  PYTHONPATH=src python examples/train_lm.py [--steps 120] [--d-model 128]
"""

import argparse
import shutil
import tempfile

import jax

from repro.checkpoint import CheckpointStore
from repro.data.batches import make_lm_batch
from repro.distributed.runner import FaultTolerantRunner
from repro.models.transformer import LMConfig, TransformerLM
from repro.optim import adam_init

import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = LMConfig(
        n_layers=args.layers, d_model=args.d_model, n_heads=4, n_kv_heads=2,
        d_head=args.d_model // 4, d_ff=args.d_model * 4, vocab=256,
        dtype=jnp.float32, param_dtype=jnp.float32, remat="none",
        dense_attn_threshold=4096)
    model = TransformerLM(cfg)
    print(f"model: {cfg.n_params/1e6:.2f} M params")
    params = model.init(jax.random.key(0))
    state = (params, adam_init(params))

    @jax.jit
    def jit_step(params, opt, batch):
        return model.train_step(params, opt, batch, lr=3e-3)

    def step_fn(state, batch):
        params, opt = state
        params, opt, metrics = jit_step(params, opt, batch)
        return (params, opt), metrics

    def batch_fn(step):
        return make_lm_batch(jax.random.key(step), batch=args.batch,
                             seq=args.seq, vocab=cfg.vocab)

    ckpt_dir = tempfile.mkdtemp(prefix="repro_lm_")
    store = CheckpointStore(ckpt_dir, keep_last=2)
    runner = FaultTolerantRunner(store, step_fn, batch_fn, ckpt_every=25)

    # chaos drill: one injected failure mid-run; the runner must restore
    # the latest checkpoint and replay deterministically
    fail_step = args.steps // 2
    fails = {fail_step}
    print(f"training {args.steps} steps "
          f"(failure injected at step {fail_step})...")
    state, report = runner.run(
        state, args.steps,
        fail_at=lambda s: s in fails and not fails.discard(s))

    losses = [m["loss"] for m in report.metrics_history]
    k = max(len(losses) // 6, 1)
    for i in range(0, len(losses), k):
        print(f"  step {i:4d}  loss {losses[i]:.4f}")
    print(f"  step {len(losses)-1:4d}  loss {losses[-1]:.4f}")
    print(f"restarts: {report.restarts}  checkpoints: {report.checkpoints}  "
          f"stragglers: {report.straggler_steps}")
    assert losses[-1] < losses[0], "loss must decrease"
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}  OK")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
