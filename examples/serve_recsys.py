"""End-to-end serving driver (the paper's deployment scenario).

Trains a small SASRec retrieval backbone, fits the constrained-ranking
head (Algorithm 1 offline stage) on top of its scores/covariates, then
serves a stream of individual, shape-heterogeneous requests through the
async double-buffered micro-batching engine (repro.serving): backbone
scores -> shape bucket -> micro-batch -> KNN shadow prices ->
constrained top-k, with one pre-warmed executable per bucket so nothing
recompiles in steady state, and batch N+1 assembled while batch N's
outputs transfer back (docs/serving.md walks through the pipeline).

  PYTHONPATH=src python examples/serve_recsys.py [--requests 200]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constraints import dcg_discount
from repro.core.dual_solver import solve_dual_batch
from repro.core.predictors import KNNLambdaPredictor, MeanLambdaPredictor
from repro.data.batches import make_seqrec_batch
from repro.models.recsys import SASRec, RecsysConfig
from repro.optim import adam_init
from repro.serving import RankRequest, RankResult, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="0 = synchronous engine (pre-pipeline behavior)")
    ap.add_argument("--admission", action="store_true",
                    help="deadline-aware admission control with a "
                         "KNN -> mean degradation ladder")
    ap.add_argument("--budget-ms", type=float, default=50.0,
                    help="per-request latency budget (the paper's SLA)")
    args = ap.parse_args()

    # ---- 1. train the backbone --------------------------------------------
    cfg = RecsysConfig(kind="sasrec", n_items=2000, embed_dim=32,
                       n_blocks=2, n_heads=1, seq_len=20)
    model = SASRec(cfg)
    params = model.init(jax.random.key(0))
    opt = adam_init(params)

    @jax.jit
    def train_step(p, o, b):
        return model.train_step(p, o, b, lr=3e-3)

    print("training sasrec backbone (100 steps)...")
    for step in range(100):
        batch = make_seqrec_batch(jax.random.key(step), batch=64,
                                  seq_len=cfg.seq_len, n_items=cfg.n_items,
                                  n_neg=15, kind="sasrec")
        params, opt, metrics = train_step(params, opt, batch)
    print(f"  final loss {float(metrics['loss']):.3f}")

    # ---- 2. constrained-ranking head: offline stage -----------------------
    m1, m2, K = 512, 50, 4
    gamma = np.asarray(dcg_discount(m2), np.float32)
    cand_ids = jnp.arange(m1)
    # item topics (e.g. content categories needing exposure quotas)
    topics = np.asarray(
        (jax.random.uniform(jax.random.key(7), (K, m1)) < 0.15), np.float32)
    b = 0.08 * gamma.sum() * np.ones(K, np.float32)

    @jax.jit
    def score(params, seqs):
        return (model.retrieval_scores(params, seqs, cand_ids),
                model.user_covariates(params, seqs))

    n_offline = 256
    seqs = make_seqrec_batch(jax.random.key(1000), batch=n_offline,
                             seq_len=cfg.seq_len, n_items=cfg.n_items,
                             n_neg=1, kind="sasrec")["seq"]
    u_off, X_off = score(params, seqs)
    print(f"offline: solving {n_offline} duals (m1={m1}, K={K})...")
    sol = solve_dual_batch(u_off, jnp.asarray(topics), jnp.asarray(b),
                           jnp.asarray(gamma), m2=m2, num_iters=300)
    print(f"  offline compliance {float(sol.compliant.mean()):.2f}")
    knn = KNNLambdaPredictor.fit(X_off, sol.lam, k=10)

    # ---- 3. streaming online serving --------------------------------------
    engine = ServingEngine(max_batch=args.max_batch,
                           max_wait_ms=args.max_wait_ms,
                           pipeline_depth=args.pipeline_depth,
                           admission=args.admission,
                           default_budget_s=args.budget_ms / 1e3)
    engine.register_predictor("sasrec", knn, d_cov=cfg.embed_dim)
    if args.admission:
        mean = MeanLambdaPredictor.fit(X_off, sol.lam)
        engine.register_predictor("sasrec_mean", mean, d_cov=cfg.embed_dim)
        engine.set_degradation_ladder("sasrec", ["sasrec_mean"])

    # arrival stream: score in chunks, then one request per user with a
    # jittered candidate count (live retrieval returns varying sets).
    rng = np.random.default_rng(0)
    requests, chunk = [], 64
    for c in range(-(-args.requests // chunk)):
        seqs = make_seqrec_batch(jax.random.key(5000 + c), batch=chunk,
                                 seq_len=cfg.seq_len, n_items=cfg.n_items,
                                 n_neg=1, kind="sasrec")["seq"]
        u, X = score(params, seqs)
        u, X = np.asarray(u), np.asarray(X)
        for i in range(min(chunk, args.requests - c * chunk)):
            n_c = int(rng.integers(m1 // 2, m1 + 1))
            m2_req = min(m2, n_c)
            requests.append(RankRequest(
                rid=c * chunk + i, u=u[i, :n_c], a=topics[:, :n_c], b=b,
                m2=m2_req, X=X[i], tag="sasrec", gamma=gamma[:m2_req]))

    warm = engine.warmup(requests)
    print(f"warmed {len(warm['buckets'])} buckets "
          f"({warm['compiles']} compiles): {warm['buckets']}")

    results = engine.serve_stream(requests)
    engine.close()

    served = [r for r in results if isinstance(r, RankResult)]
    s = engine.metrics.summary()
    lat = s["latency_ms"]
    print(f"served {len(served)}/{len(results)} requests through "
          f"{s['batches']} micro-batches ({s['buckets_used']} buckets, "
          f"fill rate {s['fill_rate']:.0%}):")
    print(f"  latency  p50 {lat['p50']:7.2f} ms   p95 {lat['p95']:7.2f} ms   "
          f"p99 {lat['p99']:7.2f} ms  (per request, enqueue -> result)")
    print(f"  compliance {s['compliance']:.2f}")
    p = s["pipeline"]
    print(f"  pipeline depth {args.pipeline_depth}: overlap "
          f"{p['overlap_ratio']:.0%}, max in-flight {p['queue_depth_max']}, "
          f"exec p50 {p['exec_ms_per_batch']['p50']:.2f} ms/batch")
    print(f"  recompiles after warmup: {s['compiles_post_warmup']}")
    d = s["deadline"]
    print(f"  deadline ({args.budget_ms:.0f} ms budget): hit rate "
          f"{d['hit_rate']:.1%}, sheds {d['sheds']}, "
          f"degrades {d['degrades']}")
    print(f"  within the {args.budget_ms:.0f} ms budget: "
          f"{lat['p99'] <= args.budget_ms}")


if __name__ == "__main__":
    main()
