"""End-to-end serving driver (the paper's deployment scenario).

Trains a small SASRec retrieval backbone, fits the constrained-ranking
head (Algorithm 1 offline stage) on top of its scores/covariates, then
serves batched requests through the integrated online path —
backbone scores -> KNN shadow prices -> constrained top-k — and reports
latency percentiles and constraint compliance.

  PYTHONPATH=src python examples/serve_recsys.py [--requests 200]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constraints import dcg_discount
from repro.core.dual_solver import solve_dual_batch
from repro.core.predictors import KNNLambdaPredictor
from repro.core.ranking import rank_given_lambda
from repro.data.batches import make_seqrec_batch
from repro.models.recsys import SASRec, RecsysConfig
from repro.optim import adam_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=16)
    args = ap.parse_args()

    # ---- 1. train the backbone --------------------------------------------
    cfg = RecsysConfig(kind="sasrec", n_items=2000, embed_dim=32,
                       n_blocks=2, n_heads=1, seq_len=20)
    model = SASRec(cfg)
    params = model.init(jax.random.key(0))
    opt = adam_init(params)

    @jax.jit
    def train_step(p, o, b):
        return model.train_step(p, o, b, lr=3e-3)

    print("training sasrec backbone (100 steps)...")
    for step in range(100):
        batch = make_seqrec_batch(jax.random.key(step), batch=64,
                                  seq_len=cfg.seq_len, n_items=cfg.n_items,
                                  n_neg=15, kind="sasrec")
        params, opt, metrics = train_step(params, opt, batch)
    print(f"  final loss {float(metrics['loss']):.3f}")

    # ---- 2. constrained-ranking head: offline stage -----------------------
    m1, m2, K = 512, 50, 4
    gamma = dcg_discount(m2)
    cand_ids = jnp.arange(m1)
    # item topics (e.g. content categories needing exposure quotas)
    topics = (jax.random.uniform(jax.random.key(7), (K, m1)) < 0.15
              ).astype(jnp.float32)
    b = 0.08 * jnp.sum(gamma) * jnp.ones((K,))

    n_offline = 256
    seqs = make_seqrec_batch(jax.random.key(1000), batch=n_offline,
                             seq_len=cfg.seq_len, n_items=cfg.n_items,
                             n_neg=1, kind="sasrec")["seq"]
    u_off = model.retrieval_scores(params, seqs, cand_ids)
    X_off = model.user_covariates(params, seqs)
    print(f"offline: solving {n_offline} duals (m1={m1}, K={K})...")
    sol = solve_dual_batch(u_off, topics, b, gamma, m2=m2, num_iters=300)
    print(f"  offline compliance {float(sol.compliant.mean()):.2f}")
    knn = KNNLambdaPredictor.fit(X_off, sol.lam, k=10)

    # ---- 3. online serving loop -------------------------------------------
    @jax.jit
    def serve(params, seqs):
        u = model.retrieval_scores(params, seqs, cand_ids)
        X = model.user_covariates(params, seqs)
        lam_hat = knn.predict(X)
        return rank_given_lambda(u, topics, b, lam_hat, gamma, m2=m2)

    warm = make_seqrec_batch(jax.random.key(1), batch=args.batch_size,
                             seq_len=cfg.seq_len, n_items=cfg.n_items,
                             n_neg=1, kind="sasrec")["seq"]
    jax.block_until_ready(serve(params, warm).perm)  # compile

    lat_ms, compl = [], []
    n_batches = max(args.requests // args.batch_size, 1)
    for i in range(n_batches):
        seqs = make_seqrec_batch(jax.random.key(5000 + i),
                                 batch=args.batch_size, seq_len=cfg.seq_len,
                                 n_items=cfg.n_items, n_neg=1,
                                 kind="sasrec")["seq"]
        t0 = time.perf_counter()
        out = serve(params, seqs)
        jax.block_until_ready(out.perm)
        lat_ms.append((time.perf_counter() - t0) * 1e3)
        compl.append(float(out.compliant.mean()))

    lat = np.asarray(lat_ms)
    print(f"served {n_batches * args.batch_size} requests "
          f"in batches of {args.batch_size}:")
    print(f"  latency  p50 {np.percentile(lat, 50):7.2f} ms/batch   "
          f"p99 {np.percentile(lat, 99):7.2f} ms/batch "
          f"({np.percentile(lat, 50)/args.batch_size:6.3f} ms/user p50)")
    print(f"  compliance {np.mean(compl):.2f}")
    print(f"  within the paper's 50 ms budget: "
          f"{bool(np.percentile(lat, 99) <= 50.0)}")


if __name__ == "__main__":
    main()
