"""Constrained content feed (the paper's motivating product scenario).

A YOW-news-style feed: every user gets a top-20 ranking of news items
under editorial exposure constraints with MIXED signs (boost health &
environment coverage, cap business/entertainment/politics/sport) — the
Table-1b shape. Shows per-topic exposure before/after, per strategy.

  PYTHONPATH=src python examples/constrained_feed.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ranking import fit_pipeline, rank_with_strategy
from repro.data.synthetic import YOW_TOPICS, build_experiment


def topic_exposure(exp, out, topic_k):
    """Mean exposure share for constraint k across served users."""
    sel = jnp.take_along_axis(
        jnp.abs(exp.a[exp.test_idx][:, topic_k, :]), out.perm, axis=-1)
    total = float(jnp.sum(exp.gamma))
    return float(jnp.mean(sel @ exp.gamma)) / total


def main():
    exp = build_experiment(
        jax.random.key(3), dataset="yow", n_users=60, n_items=800,
        m1=200, m2=50, recommender_epochs=2)
    u_tr, X_tr, a_tr = exp.split("train")
    u_te, X_te, a_te = exp.split("test")
    pipe = fit_pipeline(X_tr, u_tr, a_tr, exp.b, exp.gamma, m2=exp.m2,
                        num_iters=400)

    print("YOW-style feed: 8 topic constraints (>= boosts, <= caps)")
    print(f"{'topic':15s} {'dir':4s} {'no-opt':>8s} {'knn':>8s} "
          f"{'optimal':>8s}")
    outs = {s: rank_with_strategy(pipe, s, X_te, u_te, a_te, exp.b,
                                  dual_iters=400)
            for s in ("none", "knn", "optimal")}
    from repro.data.synthetic import YOW_CONSTRAINTS
    for k, name in enumerate(YOW_TOPICS):
        sign = ">=" if YOW_CONSTRAINTS[k][0] > 0 else "<="
        row = [topic_exposure(exp, outs[s], k) for s in ("none", "knn",
                                                         "optimal")]
        print(f"{name:15s} {sign:4s} {row[0]:8.3f} {row[1]:8.3f} "
              f"{row[2]:8.3f}")
    print()
    for s, out in outs.items():
        print(f"{s:8s}: compliance {float(out.compliant.mean()):.2f}  "
              f"utility {float(out.utility.mean()):.2f}")


if __name__ == "__main__":
    main()
