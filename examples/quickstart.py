"""Quickstart: constrained ranking with prediction in ~60 lines.

Builds a tiny MovieLens-style problem, runs Algorithm 1 end to end, and
prints the paper's Figure-2 comparison (strategy -> compliance/utility).

  PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.core.ranking import fit_pipeline, rank_with_strategy
from repro.data.synthetic import build_experiment


def main():
    print("=== 1. data: synthetic MovieLens-style ranking problems ===")
    exp = build_experiment(
        jax.random.key(0), dataset="movielens",
        n_users=80,        # users (75% train / 25% holdout)
        n_items=600,       # catalogue
        m1=200,            # candidate slate per user
        m2=50,             # ranking slots (paper scenario a)
        recommender_epochs=2,
    )
    u_tr, X_tr, a_tr = exp.split("train")
    u_te, X_te, a_te = exp.split("test")
    print(f"    {u_tr.shape[0]} train / {u_te.shape[0]} holdout users, "
          f"m1={exp.u.shape[1]}, K={exp.b.shape[0]} constraints, m2={exp.m2}")

    print("=== 2. offline: batched dual solve + predictor fit ===")
    pipe = fit_pipeline(X_tr, u_tr, a_tr, exp.b, exp.gamma, m2=exp.m2,
                        num_iters=400)
    print(f"    fitted predictors: {sorted(pipe.predictors)}  "
          f"(eps tie-break = {pipe.eps})")
    sol = pipe.train_solution
    print(f"    train compliance {float(sol.compliant.mean()):.2f}, "
          f"mean duality gap {float(sol.gap.mean()):.4f}")

    print("=== 3. online: rank holdout users under each strategy ===")
    print(f"    {'strategy':10s} {'compliance':>10s} {'utility':>9s}")
    for strat in ("none", "mean", "knn", "optimal"):
        out = rank_with_strategy(pipe, strat, X_te, u_te, a_te, exp.b,
                                 dual_iters=400)
        print(f"    {strat:10s} {float(out.compliant.mean()):10.2f} "
              f"{float(out.utility.mean()):9.2f}")

    out = rank_with_strategy(pipe, "knn", X_te, u_te, a_te, exp.b)
    print("=== 4. a served ranking (user 0, top 10 item ids) ===")
    print("   ", out.perm[0, :10].tolist())


if __name__ == "__main__":
    main()
