"""The single-grid KNN predict+rank+audit kernel
(kernels.knn_topk.knn_rank_audited_pallas) vs its two oracles:

  * the PR 4 two-kernel chain (knn_lambda_pallas -> rank_audited_pallas,
    λ̂ through an HBM buffer) — BITWISE on every RankingOutput field
    including λ̂, at matched tile geometry, because the fused grid runs
    the chain's own merge/flush bodies (_db_slab_merge, _idw_lambda_flush,
    _merge_scored_tile, _audit_flush);
  * the two-stage predictor.predict(X) -> rank_given_lambda oracle —
    exact on perm/utility/exposure/compliant (score gaps dwarf the λ̂
    perturbation on these problems), λ̂ to tight tolerance (per-slab vs
    one-matmul distance accumulation differs in the last ulp).

Plus the geometry battery the kernel's phased grid makes interesting:
slab sizes that do and do not divide n_train, bucket-padded engine
micro-batches, the m2 = MAX_KERNEL_M2 edge, exact-match neighbours
sitting in a slab past the first, and slab/tile-width invariance of λ̂.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.predictors import KNNLambdaPredictor
from repro.core.ranking import rank_given_lambda
from repro.kernels import ops
from repro.kernels.fused_rank import MAX_KERNEL_M2

KEY = jax.random.key(29)

FIELDS = ("perm", "utility", "exposure", "compliant")
N_TRAIN = 600


def _problem(n, m1, K, m2, d=12, n_train=N_TRAIN, k=5, salt=0):
    ks = jax.random.split(jax.random.fold_in(KEY, n * m1 + m2 + salt), 7)
    u = jax.random.uniform(ks[0], (n, m1), minval=1.0, maxval=5.0)
    a = (jax.random.uniform(ks[1], (n, K, m1)) < 0.15).astype(jnp.float32)
    b = jnp.abs(jax.random.normal(ks[2], (n, K)))
    gamma = jnp.abs(jax.random.normal(ks[3], (n, m2)))
    X = jax.random.normal(ks[4], (n, d))
    X_tr = jax.random.uniform(ks[5], (n_train, d))
    lam_tr = jnp.abs(jax.random.normal(ks[6], (n_train, K)))
    return u, a, b, gamma, X, KNNLambdaPredictor.fit(X_tr, lam_tr, k=k)


def _assert_parity(got, chain, want, msg=""):
    for field in FIELDS + ("lam",):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, field)),
            np.asarray(getattr(chain, field)),
            err_msg=f"single-grid vs chain broke on {field} {msg}")
    for field in FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, field)),
            np.asarray(getattr(want, field)),
            err_msg=f"single-grid vs oracle broke on {field} {msg}")


@pytest.mark.parametrize("tile_n", [200, 600, 160, 512])
def test_single_grid_parity_across_slab_sizes(tile_n):
    """tile_n in {200, 600} divides n_train = 600; {160, 512} does not
    (the db pads with far-away rows). All four give the chain's answer
    bitwise and the oracle's fields exactly."""
    n, m1, K, m2 = 11, 700, 4, 16
    u, a, b, gamma, X, pred = _problem(n, m1, K, m2)
    got = ops.predict_rank_audited(X, pred, u, a, b, gamma, m2=m2,
                                   interpret=True, tile_n=tile_n)
    chain = ops.predict_rank_audited(X, pred, u, a, b, gamma, m2=m2,
                                     interpret=True, tile_n=tile_n,
                                     knn_chain=True)
    want = rank_given_lambda(u, a, b, pred.predict(X), gamma, m2=m2)
    _assert_parity(got, chain, want, msg=f"[tile_n={tile_n}]")
    np.testing.assert_allclose(
        np.asarray(got.lam), np.asarray(pred.predict(X)),
        rtol=1e-5, atol=1e-6, err_msg=f"λ̂ drifted [tile_n={tile_n}]")


def test_single_grid_wide_batch_tile():
    """A batch that fills the 32-wide resident query tile (the default
    above 32 rows, matching the chain's knn_lambda_tile_q) — plus a
    ragged row count so the last tile is phantom-padded."""
    n, m1, K, m2 = 40, 512, 3, 10
    u, a, b, gamma, X, pred = _problem(n, m1, K, m2, salt=1)
    got = ops.predict_rank_audited(X, pred, u, a, b, gamma, m2=m2,
                                   interpret=True)
    chain = ops.predict_rank_audited(X, pred, u, a, b, gamma, m2=m2,
                                     interpret=True, knn_chain=True)
    want = rank_given_lambda(u, a, b, pred.predict(X), gamma, m2=m2)
    _assert_parity(got, chain, want, msg="[wide tile]")


def test_single_grid_m2_kernel_edge():
    """m2 = MAX_KERNEL_M2: the widest rank scratch the kernel path
    serves — one slot before the XLA fallback takes over."""
    n, m1, K, m2 = 8, 1024, 3, MAX_KERNEL_M2
    u, a, b, gamma, X, pred = _problem(n, m1, K, m2, salt=2)
    got = ops.predict_rank_audited(X, pred, u, a, b, gamma, m2=m2,
                                   interpret=True)
    chain = ops.predict_rank_audited(X, pred, u, a, b, gamma, m2=m2,
                                     interpret=True, knn_chain=True)
    want = rank_given_lambda(u, a, b, pred.predict(X), gamma, m2=m2)
    _assert_parity(got, chain, want, msg="[m2 edge]")


def test_single_grid_bucket_padded_batch():
    """An engine-style micro-batch: phantom rows, NEG_FILL candidate
    padding, and a constraint tier WIDER than the predictor's output —
    the padded constraints must price at exactly 0.0 (zero lam_db
    columns through the flush-step einsum), phantom rows must audit to
    zero utility and trivial compliance."""
    from repro.serving import Scenario, assemble_batch, bucket_for, make_request

    d, K_pred = 10, 4
    rng = np.random.default_rng(7)
    sc = Scenario("cov", m1=300, m2=20, K=K_pred, tag="arch", d_cov=d)
    reqs = [make_request(rng, sc, rid) for rid in range(5)]
    bucket = bucket_for(m1=max(r.u.shape[0] for r in reqs), m2=20,
                        K=8, tag="arch", batch=8)    # padded K tier + rows
    staged = assemble_batch(reqs, bucket, d_cov=d)
    u, a = jnp.asarray(staged["u"]), jnp.asarray(staged["a"])
    b, gamma = jnp.asarray(staged["b"]), jnp.asarray(staged["gamma"])
    X = jnp.asarray(staged["X"])
    X_tr = jnp.asarray(rng.uniform(0, 1, (64, d)), jnp.float32)
    lam_tr = jnp.asarray(np.abs(rng.normal(size=(64, K_pred))), jnp.float32)
    pred = KNNLambdaPredictor.fit(X_tr, lam_tr, k=5)

    got = ops.predict_rank_audited(X, pred, u, a, b, gamma,
                                   m2=bucket.m2, interpret=True)
    lam = jnp.pad(pred.predict(X), ((0, 0), (0, bucket.K - K_pred)))
    want = rank_given_lambda(u, a, b, lam, gamma, m2=bucket.m2)
    n_real = len(reqs)
    for field in FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, field))[:n_real],
            np.asarray(getattr(want, field))[:n_real],
            err_msg=f"padded KNN micro-batch broke on {field}")
    # the bucket-padded constraint columns price at exactly zero
    np.testing.assert_array_equal(np.asarray(got.lam)[:, K_pred:], 0.0)
    # phantom rows: zero gamma -> zero utility, trivially compliant
    np.testing.assert_array_equal(np.asarray(got.utility[n_real:]), 0.0)
    assert bool(np.all(np.asarray(got.compliant[n_real:])))


def test_exact_match_neighbour_inside_later_slab():
    """A query that coincides with a db row whose global index lands in
    a slab PAST the first (index > tile_n): the exact-match override at
    the λ̂ flush must return that row's training value even though the
    match was merged k slabs into the sweep (sklearn 'distance'
    semantics, relative test)."""
    n, m1, K, m2, tile_n = 8, 512, 3, 8, 128
    u, a, b, gamma, X, pred = _problem(n, m1, K, m2, salt=3)
    # rows 0/1 coincide with db rows in slab 2 and the final slab
    X = X.at[0].set(pred.X_db[300])
    X = X.at[1].set(pred.X_db[N_TRAIN - 1])
    got = ops.predict_rank_audited(X, pred, u, a, b, gamma, m2=m2,
                                   interpret=True, tile_n=tile_n)
    np.testing.assert_allclose(np.asarray(got.lam[0]),
                               np.asarray(pred.lam_db[300]),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got.lam[1]),
                               np.asarray(pred.lam_db[N_TRAIN - 1]),
                               rtol=1e-4, atol=1e-5)
    # and the full output still matches the chain bitwise
    chain = ops.predict_rank_audited(X, pred, u, a, b, gamma, m2=m2,
                                     interpret=True, tile_n=tile_n,
                                     knn_chain=True)
    want = rank_given_lambda(u, a, b, pred.predict(X), gamma, m2=m2)
    _assert_parity(got, chain, want, msg="[exact match]")


def test_lambda_slab_size_invariance():
    """Slab geometry is a traffic knob, not semantics: λ̂ agrees across
    slab sizes (the tile_q-invariance contract of the chain's knn_lambda
    kernel, inherited by the fused grid)."""
    n, m1, K, m2 = 16, 512, 3, 8
    u, a, b, gamma, X, pred = _problem(n, m1, K, m2, salt=4)
    lams = [
        np.asarray(ops.predict_rank_audited(
            X, pred, u, a, b, gamma, m2=m2, interpret=True,
            tile_n=tile_n).lam)
        for tile_n in (128, 200, 600)
    ]
    np.testing.assert_allclose(lams[0], lams[1], rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(lams[0], lams[2], rtol=1e-6, atol=1e-7)


def test_lambda_batch_tile_width_invariance():
    """The narrow (8) and wide (32) resident query tiles give the same
    λ̂ and the same ranking fields."""
    n, m1, K, m2 = 40, 512, 3, 8
    u, a, b, gamma, X, pred = _problem(n, m1, K, m2, salt=5)
    narrow = ops.predict_rank_audited(X, pred, u, a, b, gamma, m2=m2,
                                      interpret=True, tile_b=8)
    wide = ops.predict_rank_audited(X, pred, u, a, b, gamma, m2=m2,
                                    interpret=True, tile_b=32)
    for field in FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(narrow, field)),
            np.asarray(getattr(wide, field)),
            err_msg=f"batch-tile width changed {field}")
    np.testing.assert_allclose(np.asarray(narrow.lam), np.asarray(wide.lam),
                               rtol=1e-6, atol=1e-7)


def test_knn_rank_audited_rejects_bad_shapes():
    """The kernel wrapper keeps the KNN contract (n_train >= k) and the
    row-consistency checks loud."""
    n, m1, K, m2 = 8, 512, 3, 8
    u, a, b, gamma, X, _ = _problem(n, m1, K, m2, salt=6)
    with pytest.raises(ValueError, match="n_train"):
        ops.knn_rank_audited(X, jnp.zeros((4, 12)), jnp.zeros((4, K)),
                             u, a, b, gamma, k=10, m2=m2, interpret=True)
    with pytest.raises(ValueError, match="shadow prices"):
        ops.knn_rank_audited(X, jnp.zeros((64, 12)), jnp.zeros((64, K + 2)),
                             u, a, b, gamma, k=5, m2=m2, interpret=True)
    with pytest.raises(ValueError, match="covariate rows"):
        ops.knn_rank_audited(X[:4], jnp.zeros((64, 12)), jnp.zeros((64, K)),
                             u, a, b, gamma, k=5, m2=m2, interpret=True)
