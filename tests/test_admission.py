"""Deadline-aware admission control: property-based invariants over the
bucket lattice and the admission decision rule, plus deterministic
engine-level shed/degrade/deadline behavior.

The property layer (hypothesis) proves the two load-stability
invariants the controller's monotone prediction model was designed for:

  * a request admitted at queue depth q is admitted at every depth < q
    (no admit/shed flapping while a queue drains), and
  * the chosen degradation rung is monotone non-decreasing in the
    predicted lag (load only ever pushes DOWN the ladder);

and the serving layer's geometric exactness claims: bucket quantization
is monotone and idempotent, and the pad/unpad roundtrip is bitwise
exact over random (m1, m2, K, d) geometries.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from conftest import FrozenClock

from repro.core.constraints import dcg_discount
from repro.core.predictors import KNNLambdaPredictor, MeanLambdaPredictor
from repro.core.ranking import RankingOutput
from repro.serving import (
    SHED_RUNG,
    AdmissionController,
    RankRequest,
    Scenario,
    ServingEngine,
    Shed,
    bucket_for,
    fill_staging,
    alloc_staging,
    make_stream,
    unpad_result,
)

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # pragma: no cover
    given = None

if given is not None:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")

    # -----------------------------------------------------------------------
    # Bucket quantization: monotone + idempotent (property)
    # -----------------------------------------------------------------------

    geometries = st.tuples(st.integers(1, 5000), st.integers(1, 5000),
                           st.integers(1, 64)).map(
        lambda t: (max(t[0], t[1]), min(t[0], t[1]), t[2]))  # m1 >= m2

    @given(geometries)
    def test_bucket_contains_and_is_fixed_point(geom):
        m1, m2, K = geom
        b = bucket_for(m1=m1, m2=m2, K=K, tag="t", batch=8)
        # containment: the bucket holds the request
        assert b.m1 >= m1 and b.m2 >= m2 and b.K >= K
        # idempotence: bucketing a bucket geometry changes nothing
        b2 = bucket_for(m1=b.m1, m2=b.m2, K=b.K, tag="t", batch=8)
        assert b2 == b

    @given(geometries, geometries)
    def test_bucket_quantization_is_monotone(g1, g2):
        """Componentwise-larger geometry never maps to a smaller bucket
        — the property that makes the lattice warmable from the
        scenario maxima."""
        lo = (min(g1[0], g2[0]), min(g1[1], g2[1]), min(g1[2], g2[2]))
        hi = (max(g1[0], g2[0]), max(g1[1], g2[1]), max(g1[2], g2[2]))
        bl = bucket_for(m1=lo[0], m2=lo[1], K=lo[2], tag="t", batch=8)
        bh = bucket_for(m1=hi[0], m2=hi[1], K=hi[2], tag="t", batch=8)
        assert bl.m1 <= bh.m1 and bl.m2 <= bh.m2 and bl.K <= bh.K

    # -----------------------------------------------------------------------
    # Pad/unpad roundtrip exactness (property, array-level)
    # -----------------------------------------------------------------------

    @given(st.integers(0, 10_000), st.integers(1, 600), st.integers(1, 64),
           st.integers(1, 12), st.integers(1, 24))
    def test_pad_unpad_roundtrip_is_bitwise_exact(seed, m1, m2, K, d):
        """fill_staging embeds the request bitwise; unpad_result
        recovers exactly the rows/slices a phantom-free batch would
        have."""
        m2 = min(m2, m1)
        rng = np.random.default_rng(seed)
        req = RankRequest(
            rid=0, u=rng.uniform(1, 5, m1).astype(np.float32),
            a=(rng.random((K, m1)) < 0.3).astype(np.float32),
            b=rng.uniform(0, 1, K).astype(np.float32), m2=m2,
            X=rng.normal(size=d).astype(np.float32), tag="arch",
            gamma=np.asarray(dcg_discount(m2), np.float32))
        bucket = bucket_for(m1=m1, m2=m2, K=K, tag="arch", batch=3)
        staged = fill_staging(alloc_staging(bucket, d_cov=d), [req], bucket)
        # embedded slices are bitwise the request's arrays
        np.testing.assert_array_equal(staged["u"][0, :m1], req.u)
        np.testing.assert_array_equal(staged["a"][0, :K, :m1], req.a)
        np.testing.assert_array_equal(staged["b"][0, :K], req.b)
        np.testing.assert_array_equal(staged["gamma"][0, :m2], req.gamma)
        np.testing.assert_array_equal(staged["X"][0], req.X)
        # padding is the additive/ordering identity
        assert np.all(staged["u"][0, m1:] == -1.0e30)
        assert np.all(staged["a"][0, :, m1:] == 0) and np.all(
            staged["a"][0, K:, :] == 0)
        assert np.all(staged["b"][0, K:] == 0)
        assert np.all(staged["gamma"][0, m2:] == 0)
        # unpad recovers exactly what a batched output carries in-row
        out = RankingOutput(
            perm=np.arange(bucket.batch * bucket.m2).reshape(
                bucket.batch, bucket.m2),
            utility=rng.normal(size=bucket.batch).astype(np.float32),
            exposure=rng.normal(
                size=(bucket.batch, bucket.K)).astype(np.float32),
            compliant=np.ones(bucket.batch, bool), lam=None)
        perm, utility, exposure, compliant = unpad_result(out, 0, req)
        np.testing.assert_array_equal(perm, out.perm[0, :m2])
        assert utility == float(out.utility[0])
        np.testing.assert_array_equal(exposure, out.exposure[0, :K])
        assert compliant is True

    # -----------------------------------------------------------------------
    # Admission decision invariants (property)
    # -----------------------------------------------------------------------

    @given(st.floats(0, 100), st.floats(0, 50), st.integers(0, 64),
           st.integers(0, 8), st.floats(0.5, 50))
    def test_predict_ms_is_monotone_in_load(lag, exec_ms, q, inflight, wait):
        ctrl = AdmissionController()
        ctrl.observe_lag(lag)
        ctrl.observe_service("b", exec_ms)
        p = ctrl.predict_ms("b", queue_len=q, batch_cap=16,
                            inflight=inflight, max_wait_ms=wait)
        # deeper queue, deeper pipeline, more lag: never smaller
        assert ctrl.predict_ms("b", queue_len=q + 1, batch_cap=16,
                               inflight=inflight, max_wait_ms=wait) >= p
        assert ctrl.predict_ms("b", queue_len=q, batch_cap=16,
                               inflight=inflight + 1, max_wait_ms=wait) >= p
        ctrl.observe_lag(lag + 100.0)           # EWMA moves strictly up
        assert ctrl.predict_ms("b", queue_len=q, batch_cap=16,
                               inflight=inflight, max_wait_ms=wait) >= p

    @given(st.floats(1.0, 200.0), st.floats(0.1, 30.0), st.integers(1, 64),
           st.integers(0, 4))
    def test_admitted_at_depth_q_admitted_below_q(budget_ms, exec_ms, q,
                                                  inflight):
        """No admit/shed flapping as a queue drains: if the controller
        admits at depth q it admits at every depth < q."""
        ctrl = AdmissionController()
        ctrl.observe_service("b", exec_ms)

        def decision_at(depth):
            pred = ctrl.predict_ms("b", queue_len=depth, batch_cap=16,
                                   inflight=inflight, max_wait_ms=2.0)
            return ctrl.decide(budget_ms=budget_ms,
                               rung_predictions=[(0, pred)])

        if decision_at(q).admitted:
            assert all(decision_at(d).admitted for d in range(q))

    @given(st.lists(st.floats(0.1, 50.0), min_size=1, max_size=5),
           st.floats(1.0, 100.0),
           st.lists(st.floats(0.0, 200.0), min_size=2, max_size=6))
    def test_chosen_rung_is_monotone_in_lag(base_ms, budget_ms, lags):
        """Load only ever pushes DOWN the ladder: a uniform lag shift
        never moves the first-fit decision back UP to a costlier rung
        (shed counts as the bottom)."""
        ctrl = AdmissionController()
        base = sorted(base_ms, reverse=True)    # rung 0 costliest

        def rung_at(lag):
            preds = [(i, b + lag) for i, b in enumerate(base)]
            d = ctrl.decide(budget_ms=budget_ms, rung_predictions=preds)
            return len(base) if d.rung == SHED_RUNG else d.rung

        chosen = [rung_at(lag) for lag in sorted(lags)]
        assert chosen == sorted(chosen)

else:                                            # keep the skip visible

    def test_property_layer_requires_hypothesis():
        pytest.importorskip("hypothesis")


# ---------------------------------------------------------------------------
# Controller validation + decision bookkeeping (deterministic)
# ---------------------------------------------------------------------------


def test_controller_validates_parameters():
    with pytest.raises(ValueError, match="headroom"):
        AdmissionController(headroom=0.0)
    with pytest.raises(ValueError, match="ewma_alpha"):
        AdmissionController(ewma_alpha=1.5)
    with pytest.raises(ValueError, match="at least rung 0"):
        AdmissionController().decide(budget_ms=50, rung_predictions=[])


def test_decide_first_fit_and_tallies():
    ctrl = AdmissionController(headroom=1.0)
    d = ctrl.decide(budget_ms=10, rung_predictions=[(0, 5.0), (1, 1.0)])
    assert (d.action, d.rung, d.admitted) == ("admit", 0, True)
    d = ctrl.decide(budget_ms=10, rung_predictions=[(0, 50.0), (1, 1.0)])
    assert (d.action, d.rung) == ("degrade", 1)
    d = ctrl.decide(budget_ms=10, rung_predictions=[(0, 50.0), (1, 20.0)])
    assert (d.action, d.rung) == ("shed", SHED_RUNG)
    assert d.predicted_ms == 20.0               # best the engine had
    assert ctrl.decisions == {"admit": 1, "degrade": 1, "shed": 1}


def test_ewma_seeding_and_updates():
    ctrl = AdmissionController(ewma_alpha=0.5, prior_exec_ms=7.0)
    assert ctrl.service_ms("unseen") == 7.0     # prior until observed
    ctrl.observe_service("b", 10.0)
    assert ctrl.service_ms("b") == 10.0         # first observation seeds
    ctrl.observe_service("b", 20.0)
    assert ctrl.service_ms("b") == 15.0


# ---------------------------------------------------------------------------
# Engine-level: deadlines, sheds, degrades (deterministic)
# ---------------------------------------------------------------------------


def _knn_mean_engine(**kw):
    """Engine with a knn predictor degrading to a mean predictor.
    Runs on a frozen clock so the admission EWMAs seed to exactly 0 ms
    in warmup (the second timed phantom execution measures zero
    elapsed) — rung predictions are then deterministic instead of
    riding whatever this CI box measured; tests that want a rung to
    miss say so explicitly via observe_service."""
    rng = np.random.default_rng(0)
    d, K = 8, 4
    knn = KNNLambdaPredictor.fit(
        rng.normal(size=(32, d)).astype(np.float32),
        np.abs(rng.normal(size=(32, K))).astype(np.float32), k=5)
    mean = MeanLambdaPredictor.fit(
        np.zeros((4, d), np.float32),
        np.abs(rng.normal(size=(4, K))).astype(np.float32))
    kw.setdefault("clock", FrozenClock())
    eng = ServingEngine(max_batch=4, max_wait_ms=2.0, **kw)
    eng.register_predictor("knn", knn, d_cov=d)
    eng.register_predictor("mean", mean, d_cov=d)
    eng.set_degradation_ladder("knn", ["mean"])
    mix = (Scenario("s", m1=200, m2=16, K=K, tag="knn", d_cov=d),)
    return eng, make_stream(mix, n_requests=8, seed=1)


def test_deadline_tracking_without_admission():
    """An admission-disabled engine still reports hits/misses against
    the 50 ms default budget — every served result is checked. On a
    frozen clock zero time elapses, so every check is deterministically
    a hit (the wall-clock version of this test could only assert that
    SOME verdict was recorded)."""
    eng = ServingEngine(max_batch=4, pipeline_depth=0, clock=FrozenClock())
    res = eng.serve_stream(make_stream(n_requests=8, seed=2))
    assert all(r.deadline_hit is True and r.rung == 0 for r in res)
    m = eng.metrics
    assert m.deadline_hits == len(res) and m.deadline_misses == 0
    assert m.sheds == 0 and m.degrades == 0


def test_absolute_deadline_wins_over_budget():
    """On a ticking clock (1 ms per read) the 1 ns relative budget has
    certainly expired by materialization — the hit can only come from
    the absolute deadline taking precedence over the budget."""
    eng = ServingEngine(max_batch=4, pipeline_depth=0,
                        clock=FrozenClock(tick=1e-3))
    req = make_stream(n_requests=1, seed=3)[0]
    req.deadline, req.budget_s = 1e9, 1e-9      # absolute wins: hit
    hit = eng.serve_stream([req], warmup=True)[0]
    assert hit.deadline_hit is True
    req.deadline, req.budget_s = -1.0, 1e9      # already expired: miss
    miss = eng.serve_stream([req], warmup=False)[0]
    assert miss.deadline_hit is False


def test_shed_resolves_future_with_typed_result():
    ctrl = AdmissionController()
    eng, reqs = _knn_mean_engine(pipeline_depth=0, admission=ctrl)
    eng.warmup(reqs)
    for name in (eng.bucket_of(reqs[0]).name,
                 *(b.name for _, b in eng._rung_buckets(
                     reqs[0], eng.bucket_of(reqs[0])))):
        ctrl.observe_service(name, 1e6)         # every rung predicted late
    fut = eng.submit_future(reqs[0])
    out = fut.result(timeout=1.0)
    assert isinstance(out, Shed)
    assert out.rid == reqs[0].rid and out.rung == SHED_RUNG
    assert out.predicted_ms > out.budget_ms
    assert fut.done()
    drained = eng.drain()                       # shed flows to the driver too
    assert any(isinstance(x, Shed) and x.rid == reqs[0].rid for x in drained)
    assert eng.metrics.sheds == 1 and eng.metrics.results == 0


def test_degrade_routes_to_fallback_bucket_and_accounts_cost():
    """When rung 0 is predicted to miss but the mean rung fits, the
    request is served from the mean bucket, carries rung=1, and the
    per-rung compliance-cost accumulator records its shortfall."""
    ctrl = AdmissionController()
    eng, reqs = _knn_mean_engine(pipeline_depth=0, admission=ctrl)
    eng.warmup(reqs)
    home = eng.bucket_of(reqs[0])
    rungs = dict(eng._rung_buckets(reqs[0], home))
    assert set(rungs) == {0, 1} and rungs[1].tag == "mean"
    for b in eng._warmed:                       # every knn bucket (m1
        if b.tag == "knn":                      # jitter spans two) is
            ctrl.observe_service(b.name, 1e6)   # predicted to miss
    res = eng.serve_stream(reqs, warmup=False)
    served = [r for r in res if not isinstance(r, Shed)]
    assert served and all(r.rung == 1 for r in served)
    assert all(r.bucket.startswith("mean/") for r in served)
    assert eng.metrics.degrades == len(served)
    assert eng.metrics.compiles_post_warmup == 0   # fallback was pre-warmed
    dl = eng.metrics.deadline_summary()
    assert dl["rungs"]["1"]["served"] == len(served)
    assert np.isfinite(dl["rungs"]["1"]["mean_shortfall"])


def test_ladder_validation():
    eng, _ = _knn_mean_engine(pipeline_depth=0)
    with pytest.raises(KeyError, match="not a registered"):
        eng.set_degradation_ladder("knn", ["nope"])
    with pytest.raises(KeyError, match="no predictor"):
        eng.set_degradation_ladder("nope", ["mean"])
    rng = np.random.default_rng(5)
    small = MeanLambdaPredictor.fit(
        np.zeros((4, 8), np.float32),
        np.abs(rng.normal(size=(4, 2))).astype(np.float32))
    eng.register_predictor("small", small, d_cov=8)
    with pytest.raises(ValueError, match="shadow"):
        eng.set_degradation_ladder("knn", ["small"])


def test_raw_lam_requests_have_no_ladder():
    """A raw-lam request is already the cheapest program: its ladder is
    rung 0 only, so admission can only admit or shed it."""
    eng = ServingEngine(max_batch=4, pipeline_depth=0, admission=True)
    req = make_stream(n_requests=1, seed=4)[0]
    assert req.lam is not None
    assert eng._rung_buckets(req, eng.bucket_of(req)) == [
        (0, eng.bucket_of(req))]


# ---------------------------------------------------------------------------
# Windowed p99 tracker: measured-trend default rung with hysteresis
# ---------------------------------------------------------------------------


def _feed_window(ctrl, ratio, n=None):
    """Feed one full window of identical latency/budget ratios."""
    for _ in range(n or ctrl.p99_window):
        ctrl.observe_result(ratio * 100.0, 100.0)


def test_p99_tracker_shifts_default_rung_after_patience():
    ctrl = AdmissionController(p99_window=8, p99_patience=3)
    assert ctrl.default_rung == 0
    _feed_window(ctrl, 1.5)
    _feed_window(ctrl, 1.5)
    assert ctrl.default_rung == 0               # patience not yet met
    _feed_window(ctrl, 1.5)
    assert ctrl.default_rung == 1               # 3 consecutive over-windows
    assert ctrl.rung_shifts == [("down", 1, pytest.approx(1.5))]
    # decisions now skip rung 0 even when it would fit
    d = ctrl.decide(budget_ms=100, rung_predictions=[(0, 1.0), (1, 2.0)])
    assert (d.action, d.rung) == ("degrade", 1)


def test_p99_tracker_recovers_through_hysteresis():
    ctrl = AdmissionController(p99_window=8, p99_patience=2,
                               p99_hysteresis=0.7)
    for _ in range(2):
        _feed_window(ctrl, 2.0)
    assert ctrl.default_rung == 1
    # hovering in the hysteresis band (0.7 <= r < 1.0): NO recovery
    for _ in range(10):
        _feed_window(ctrl, 0.85)
    assert ctrl.default_rung == 1
    # clearly under the hysteresis threshold: recovery after patience
    _feed_window(ctrl, 0.3)
    assert ctrl.default_rung == 1
    _feed_window(ctrl, 0.3)
    assert ctrl.default_rung == 0
    assert ctrl.rung_shifts[-1][0] == "up"


def test_transient_spike_does_not_flap_the_rung():
    """The anti-flap regression: a single over-budget window (a GC
    pause, one slow batch) inside an otherwise-healthy stream must not
    move the default rung — and alternating spikes never accumulate
    because every healthy window resets the over-counter."""
    ctrl = AdmissionController(p99_window=8, p99_patience=3)
    for _ in range(20):                         # spike, recover, spike, ...
        _feed_window(ctrl, 5.0)
        _feed_window(ctrl, 0.2)
    assert ctrl.default_rung == 0
    assert ctrl.rung_shifts == []


def test_p99_floor_degrades_but_never_sheds():
    """A ladder too short to reach the floor keeps its deepest rung —
    the measured-trend floor turns into MORE degradation, never into a
    shed the per-request prediction wouldn't have made."""
    ctrl = AdmissionController(p99_window=4, p99_patience=1,
                               max_default_rung=8)
    for _ in range(6):
        _feed_window(ctrl, 3.0)
    assert ctrl.default_rung == 6               # far beyond this ladder
    d = ctrl.decide(budget_ms=100, rung_predictions=[(0, 1.0), (1, 2.0)])
    assert (d.action, d.rung) == ("degrade", 1)


def test_p99_tracker_ignores_unbudgeted_results():
    ctrl = AdmissionController(p99_window=2, p99_patience=1)
    for _ in range(64):
        ctrl.observe_result(500.0, 0.0)         # no budget: no ratio
    assert ctrl.default_rung == 0 and ctrl._ratio_win == []


def test_p99_parameters_validated():
    with pytest.raises(ValueError, match="p99_window"):
        AdmissionController(p99_window=0)
    with pytest.raises(ValueError, match="p99_patience"):
        AdmissionController(p99_patience=0)
    with pytest.raises(ValueError, match="p99_hysteresis"):
        AdmissionController(p99_hysteresis=1.0)


def test_engine_feeds_tracker_from_measured_results():
    """The engine wires every SERVED result's measured latency/budget
    ratio into the tracker at result-build time: with a window too
    large to ever close, the ratio buffer holds exactly one sample per
    served result (and on a ticking clock each ratio is positive)."""
    ctrl = AdmissionController(p99_window=10_000)
    eng = ServingEngine(max_batch=4, pipeline_depth=0, admission=ctrl,
                        clock=FrozenClock(tick=1e-3))
    res = eng.serve_stream(make_stream(n_requests=16, seed=6))
    served = [r for r in res if not isinstance(r, Shed)]
    assert served
    assert len(ctrl._ratio_win) == len(served)
    assert all(r > 0.0 for r in ctrl._ratio_win)


# ---------------------------------------------------------------------------
# Per-surface budget classes
# ---------------------------------------------------------------------------


def test_surface_budget_classes_set_deadlines():
    """A request without deadline/budget_s gets its SURFACE's default
    budget; unknown surfaces fall back to default_budget_s; an explicit
    budget_s still wins over the surface class."""
    eng = ServingEngine(max_batch=4, pipeline_depth=0,
                        default_budget_s=0.050,
                        surface_budgets={"feed": 0.025, "search": 0.100})
    req = make_stream(n_requests=1, seed=7)[0]
    req.surface = "feed"
    assert eng._deadline_of(req, 1.0) == pytest.approx(1.025)
    req.surface = "search"
    assert eng._deadline_of(req, 1.0) == pytest.approx(1.100)
    req.surface = "unknown"
    assert eng._deadline_of(req, 1.0) == pytest.approx(1.050)
    req.surface, req.budget_s = "feed", 0.200
    assert eng._deadline_of(req, 1.0) == pytest.approx(1.200)


def test_surface_stats_reported_per_class():
    """hit/miss accounting lands in the submitting request's surface
    class, and deadline_summary reports per-surface hit rates."""
    mix = (Scenario("f", m1=64, m2=8, K=3, surface="feed", weight=1.0),
           Scenario("s", m1=64, m2=8, K=3, surface="search", weight=1.0))
    eng = ServingEngine(max_batch=4, pipeline_depth=0,
                        surface_budgets={"feed": 0.05, "search": 1.0},
                        clock=FrozenClock())
    res = eng.serve_stream(make_stream(mix, n_requests=24, seed=8))
    ss = eng.metrics.surface_stats
    assert set(ss) == {"feed", "search"}
    assert sum(s["hits"] + s["misses"] for s in ss.values()) == len(res)
    surf = eng.metrics.deadline_summary()["surfaces"]
    for name in ("feed", "search"):
        assert 0.0 <= surf[name]["hit_rate"] <= 1.0


def test_surface_sheds_counted_per_class():
    ctrl = AdmissionController()
    eng, reqs = _knn_mean_engine(pipeline_depth=0, admission=ctrl)
    for r in reqs:
        r.surface = "feed"
    eng.warmup(reqs)
    for b in eng._warmed:
        ctrl.observe_service(b.name, 1e6)       # every rung predicted late
    res = eng.serve_stream(reqs, warmup=False)
    assert all(isinstance(r, Shed) for r in res)
    assert eng.metrics.surface_stats["feed"]["sheds"] == len(reqs)
