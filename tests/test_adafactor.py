"""Factored second-moment optimizer (optim/adafactor.py)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adafactor import (
    adafactor_init,
    adafactor_update,
    state_bytes,
)


def test_converges_quadratic():
    params = {"w": jnp.asarray([[5.0, -3.0], [2.0, -4.0]])}
    st = adafactor_init(params)
    for _ in range(400):
        g = {"w": 2 * params["w"]}
        params, st = adafactor_update(g, st, params, lr=0.05)
    np.testing.assert_allclose(params["w"], 0.0, atol=5e-2)


def test_matches_adam_direction_early():
    """First-step update equals lr in magnitude (like Adam)."""
    params = {"w": jnp.ones((4, 8))}
    g = {"w": jnp.full((4, 8), 3.0)}
    st = adafactor_init(params)
    new, _ = adafactor_update(g, st, params, lr=0.1)
    np.testing.assert_allclose(np.abs(np.asarray(new["w"] - params["w"])),
                               0.1, rtol=1e-3)


def test_mixed_rank_pytree():
    params = {"mat": jnp.ones((6, 4)), "vec": jnp.ones((5,)),
              "scalar": jnp.ones(())}
    st = adafactor_init(params)
    g = jax.tree.map(lambda p: 0.5 * p, params)
    new, st2 = adafactor_update(g, st, params, lr=0.01)
    for a, b in zip(jax.tree.leaves(new), jax.tree.leaves(params)):
        assert a.shape == b.shape
        assert bool(jnp.all(jnp.isfinite(a)))
    assert int(st2.step) == 1


def test_memory_factorization_wins():
    """The point of the exercise: 1T-scale second moments collapse."""
    params = {"w": jnp.zeros((4096, 4096), jnp.bfloat16)}
    dense = state_bytes(params, factored=False)
    fact = state_bytes(params, factored=True)
    # mu is the same; nu goes from n*m*4 to (n+m)*4
    assert fact < dense * 0.35
    # kimi-k2-scale estimate: nu for a 7168x2048 expert weight is ~37 KB
    # factored vs 58 MB dense
    assert (7168 + 2048) * 4 < 7168 * 2048 * 4 / 1000
